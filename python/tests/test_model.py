"""L2 correctness: network shapes, quantization plumbing, metadata
consistency between the analytic shape walk and the real forward pass."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers, model, nets

ALL_NETS = nets.NET_ORDER


@pytest.fixture(scope="module")
def built():
    """Init (untrained) params for every net once."""
    out = {}
    for name in ALL_NETS:
        net = nets.get(name)
        names, arrays = layers.init_params(net.groups, net.input_shape, seed=5)
        out[name] = (net, names, [jnp.asarray(a) for a in arrays])
    return out


@pytest.mark.parametrize("name", ALL_NETS)
def test_forward_shape_and_finiteness(built, name):
    net, _, params = built[name]
    fwd = model.make_forward(net, use_pallas=False)
    x = jnp.asarray(np.random.RandomState(0).rand(4, *net.input_shape).astype(np.float32))
    L = len(net.groups)
    logits = fwd(params, x, model.passthrough_cfg(L), model.passthrough_cfg(L))
    assert logits.shape == (4, net.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", ALL_NETS)
def test_shape_walk_matches_traced_output(built, name):
    net, _, params = built[name]
    meta, out_shape = layers.shape_walk(net.groups, net.input_shape)
    assert out_shape == (net.num_classes,)
    # weight totals agree with actual parameter sizes
    walk_weights = sum(m["weight_elems"] for m in meta)
    real_weights = sum(int(np.prod(p.shape)) for p in params)
    assert walk_weights == real_weights
    # layer chain is consistent
    for a, b in zip(meta, meta[1:]):
        assert a["out_elems"] == b["in_elems"]


@pytest.mark.parametrize("name", ALL_NETS)
def test_paper_layer_structure(built, name):
    net, _, _ = built[name]
    kinds = [g.kind for g in net.groups]
    expected = {
        "lenet": (2, 2, 0),
        "convnet": (3, 2, 0),
        "alexnet": (5, 3, 0),
        "nin": (12, 0, 0),
        "googlenet": (2, 0, 9),
    }[name]
    assert (kinds.count("conv"), kinds.count("fc"), kinds.count("inception")) == expected


def test_sentinel_config_equals_unquantized(built):
    net, _, params = built["lenet"]
    x = jnp.asarray(np.random.RandomState(1).rand(2, *net.input_shape).astype(np.float32))
    L = len(net.groups)
    fwd = model.make_forward(net, use_pallas=True)
    sent = model.passthrough_cfg(L)
    quantized_path = fwd(params, x, sent, sent)
    plain = layers.apply(net.groups, params, x, sent, sent, lambda v, c: v)
    np.testing.assert_allclose(np.asarray(quantized_path), np.asarray(plain), atol=1e-5)


def test_pallas_and_ref_forwards_agree(built):
    net, _, params = built["convnet"]
    x = jnp.asarray(np.random.RandomState(2).rand(2, *net.input_shape).astype(np.float32))
    L = len(net.groups)
    wq = model.uniform_cfg(L, 1.0, 6.0)
    dq = model.uniform_cfg(L, 8.0, 2.0)
    a = model.make_forward(net, use_pallas=True)(params, x, wq, dq)
    b = model.make_forward(net, use_pallas=False)(params, x, wq, dq)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_quantization_actually_changes_logits(built):
    net, _, params = built["lenet"]
    x = jnp.asarray(np.random.RandomState(3).rand(2, *net.input_shape).astype(np.float32))
    L = len(net.groups)
    fwd = model.make_forward(net, use_pallas=False)
    base = fwd(params, x, model.passthrough_cfg(L), model.passthrough_cfg(L))
    harsh = fwd(params, x, model.uniform_cfg(L, 1.0, 2.0), model.uniform_cfg(L, 2.0, 0.0))
    assert float(jnp.max(jnp.abs(base - harsh))) > 1e-4


def test_group_param_counts_cover_all_params(built):
    for name in ALL_NETS:
        net, names, params = built[name]
        counts = layers.group_param_counts(net.groups)
        assert sum(counts) == len(params)
        assert len(counts) == len(net.groups)


def test_group_quantize_equals_per_tensor(built):
    net, _, params = built["convnet"]
    counts = layers.group_param_counts(net.groups)
    L = len(net.groups)
    wq = model.uniform_cfg(L, 1.0, 3.0)
    from compile.kernels import ref

    grouped = layers.quantize_group_params(
        params, counts, wq, lambda v, c: ref.quantize_ref(v, c[0], c[1])
    )
    idx = 0
    for g, n in enumerate(counts):
        for p in params[idx : idx + n]:
            direct = ref.quantize_ref(p, wq[g, 0], wq[g, 1])
            np.testing.assert_array_equal(np.asarray(grouped[idx - 0]), np.asarray(direct))
            idx += 1
            break  # first tensor of each group suffices (same code path)
        idx = sum(counts[: g + 1])


def test_stage_forward_matches_standard_when_sentinel(built):
    net, _, params = built["alexnet"]
    x = jnp.asarray(np.random.RandomState(4).rand(2, *net.input_shape).astype(np.float32))
    L = len(net.groups)
    n_stages = len(net.groups[1].ops)
    sent = model.passthrough_cfg(L)
    sq = model.passthrough_cfg(n_stages)
    a = model.make_forward(net, use_pallas=False, stage_group=1)(params, x, sent, sent, sq)
    b = model.make_forward(net, use_pallas=False)(params, x, sent, sent)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_stage_quantization_differs_from_layer_quantization(built):
    net, _, params = built["alexnet"]
    x = jnp.asarray(np.random.RandomState(5).rand(2, *net.input_shape).astype(np.float32))
    L = len(net.groups)
    n_stages = len(net.groups[1].ops)
    sent = model.passthrough_cfg(L)
    # quantize only the first stage (conv output) harshly
    sq = np.full((n_stages, 2), -1.0, np.float32)
    sq[0] = [3.0, 0.0]
    a = model.make_forward(net, use_pallas=False, stage_group=1)(
        params, x, sent, sent, jnp.asarray(sq)
    )
    b = model.make_forward(net, use_pallas=False)(params, x, sent, sent)
    assert float(jnp.max(jnp.abs(a - b))) > 1e-5


def test_lrn_normalizes_across_channels():
    x = jnp.ones((1, 2, 2, 8), jnp.float32) * 2.0
    y = layers._lrn(x, n=5, alpha=1e-1, beta=0.75)
    assert y.shape == x.shape
    # with alpha>0 the response is strictly damped
    assert float(jnp.max(y)) < 2.0
    # border channels have smaller windows -> less damping
    assert float(y[0, 0, 0, 0]) > float(y[0, 0, 0, 4])
