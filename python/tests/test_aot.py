"""AOT pipeline: HLO lowering sanity and manifest consistency (uses a tiny
untrained net so the test stays fast; the full pipeline is exercised by
`make artifacts` + the rust integration tests)."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, layers, model, nets


@pytest.fixture(scope="module")
def lenet_params():
    net = nets.get("lenet")
    names, arrays = layers.init_params(net.groups, net.input_shape, seed=11)
    return net, names, [jnp.asarray(a) for a in arrays]


def test_lowered_hlo_is_parseable_text(lenet_params):
    net, _, params = lenet_params
    hlo = aot.lower_forward(net, params)
    assert "HloModule" in hlo
    assert "ENTRY" in hlo
    # parameters: weights + images + wq + dq
    assert hlo.count("parameter(") >= len(params) + 3
    # tuple-rooted (return_tuple=True contract with the rust loader)
    assert "ROOT" in hlo


def test_stage_variant_has_extra_parameter(lenet_params):
    net = nets.get("alexnet")
    names, arrays = layers.init_params(net.groups, net.input_shape, seed=12)
    params = [jnp.asarray(a) for a in arrays]
    hlo_std = aot.lower_forward(net, params)
    hlo_stage = aot.lower_forward(net, params, stage_group=aot.STAGE_GROUP)

    def entry_arity(hlo: str) -> int:
        # count tensors in the entry layout: "entry_computation_layout={(...)}"
        layout = hlo.split("entry_computation_layout={(", 1)[1].split(")}", 1)[0]
        return layout.count("f32[")

    assert entry_arity(hlo_stage) == entry_arity(hlo_std) + 1


def test_manifest_contents(lenet_params):
    net, names, params = lenet_params
    info = {"top1": 0.5, "final_loss": 1.0, "train_seconds": 0.0, "steps": 1}
    m = aot.build_manifest(net, names, params, info, {"hlo": "x", "weights": "y", "dataset": "z"})
    assert m["batch"] == aot.BATCH
    assert len(m["layers"]) == len(net.groups)
    assert len(m["params"]) == len(params)
    # weight accounting matches
    total_meta = sum(l["weight_elems"] for l in m["layers"])
    total_real = sum(int(np.prod(p["shape"])) for p in m["params"])
    assert total_meta == total_real
    # chain consistency (what the rust validator enforces)
    for a, b in zip(m["layers"], m["layers"][1:]):
        assert a["out_elems"] == b["in_elems"]
    assert m["stage_variant"] is None  # lenet has no stage variant
    assert json.dumps(m)  # serializable


def test_golden_quant_writer(tmp_path):
    aot.write_golden_quant(str(tmp_path))
    from compile import ntf

    g = ntf.read(os.path.join(str(tmp_path), "golden_quant.ntf"))
    assert "x" in g and "q_sentinel" in g
    assert sum(1 for k in g if k.startswith("q_")) >= 40
    np.testing.assert_array_equal(g["q_sentinel"], g["x"])


def test_shipped_artifacts_if_present():
    """When `make artifacts` has run, validate the shipped manifests."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    idx_path = os.path.join(art, "index.json")
    if not os.path.exists(idx_path):
        pytest.skip("artifacts not built")
    idx = json.load(open(idx_path))
    assert {n["name"] for n in idx["nets"]} == set(nets.NET_ORDER)
    for entry in idx["nets"]:
        man = json.load(open(os.path.join(art, f"{entry['name']}.manifest.json")))
        assert os.path.exists(os.path.join(art, man["files"]["hlo"]))
        assert os.path.exists(os.path.join(art, man["files"]["weights"]))
        assert os.path.exists(os.path.join(art, man["files"]["dataset"]))
        assert 0.2 < man["baseline_top1"] <= 1.0
