"""L1 correctness: Pallas kernel vs the pure-jnp oracle — the core
cross-implementation lock, with hypothesis sweeping shapes and formats."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fixedpoint as fp
from compile.kernels import ref

SHAPES = [(7,), (64,), (3, 5), (64, 28, 28, 1), (2, 130, 7), (1, 1), (8192,), (8193,)]


def rand(shape, seed=0, scale=8.0):
    return (np.random.RandomState(seed).randn(*shape) * scale).astype(np.float32)


@pytest.mark.parametrize("shape", SHAPES)
def test_kernel_matches_ref_across_shapes(shape):
    x = rand(shape, seed=1)
    cfg = jnp.array([6.0, 3.0], jnp.float32)
    a = fp.quantize_fixed(x, cfg)
    b = ref.quantize_ref(x, 6.0, 3.0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=60, deadline=None)
@given(
    ibits=st.integers(min_value=0, max_value=16),
    fbits=st.integers(min_value=0, max_value=14),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    n=st.integers(min_value=1, max_value=3000),
    scale=st.sampled_from([0.1, 1.0, 16.0, 1e4]),
)
def test_kernel_matches_ref_hypothesis(ibits, fbits, seed, n, scale):
    x = rand((n,), seed=seed, scale=scale)
    cfg = jnp.array([float(ibits), float(fbits)], jnp.float32)
    a = np.asarray(fp.quantize_fixed(x, cfg))
    b = np.asarray(ref.quantize_ref(x, float(ibits), float(fbits)))
    np.testing.assert_array_equal(a, b)


@settings(max_examples=30, deadline=None)
@given(
    ibits=st.integers(min_value=1, max_value=12),
    fbits=st.integers(min_value=0, max_value=10),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_quantize_lands_on_grid_and_in_range(ibits, fbits, seed):
    x = rand((500,), seed=seed, scale=2.0 ** (ibits - 1) * 2)
    q = np.asarray(ref.quantize_ref(x, float(ibits), float(fbits)))
    lo, hi, step = ref.qformat_range(float(ibits), float(fbits))
    assert q.min() >= lo and q.max() <= hi
    scaled = q.astype(np.float64) * 2.0**fbits
    np.testing.assert_allclose(scaled, np.round(scaled), atol=1e-6)


def test_sentinel_passthrough_is_bit_exact():
    x = rand((1000,), seed=3, scale=1e6)
    out = np.asarray(fp.quantize_fixed(x, jnp.array([-1.0, 0.0], jnp.float32)))
    np.testing.assert_array_equal(out, x)


def test_quantize_idempotent():
    x = rand((2048,), seed=4)
    cfg = jnp.array([5.0, 2.0], jnp.float32)
    once = fp.quantize_fixed(x, cfg)
    twice = fp.quantize_fixed(once, cfg)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


def test_round_half_to_even():
    x = np.array([0.5, 1.5, 2.5, -0.5, -1.5, 3.5], np.float32)
    q = np.asarray(ref.quantize_ref(x, 8.0, 0.0))
    np.testing.assert_array_equal(q, [0.0, 2.0, 2.0, 0.0, -2.0, 4.0])


def test_saturation_bounds_are_exact_powers():
    # the rint-snapped grid must hit exact powers of two (the XLA exp2 fix)
    q = np.asarray(ref.quantize_ref(np.array([1e9], np.float32), 16.0, 0.0))
    assert q[0] == 32767.0
    q = np.asarray(ref.quantize_ref(np.array([-1e9], np.float32), 16.0, 0.0))
    assert q[0] == -32768.0


def test_i_zero_pure_fraction_format():
    x = np.array([0.4, -0.7, 0.1], np.float32)
    q = np.asarray(ref.quantize_ref(x, 0.0, 3.0))
    np.testing.assert_allclose(q, [0.375, -0.5, 0.125])


def test_stochastic_kernel_matches_ref():
    x = rand((4096,), seed=5)
    u = np.random.RandomState(6).rand(4096).astype(np.float32)
    cfg = jnp.array([6.0, 2.0], jnp.float32)
    a = np.asarray(fp.quantize_stochastic(x, cfg, u))
    b = np.asarray(ref.quantize_stochastic_ref(x, 6.0, 2.0, u))
    np.testing.assert_array_equal(a, b)


def test_stochastic_rounding_unbiased():
    # mean of stochastic rounding approaches the true value
    x = np.full((20000,), 0.3, np.float32)
    u = np.random.RandomState(7).rand(20000).astype(np.float32)
    q = np.asarray(ref.quantize_stochastic_ref(x, 4.0, 0.0, u))
    assert abs(q.mean() - 0.3) < 0.02
    assert set(np.unique(q)) == {0.0, 1.0}


def test_block_padding_edges():
    # shapes straddling the block boundary quantize identically
    for n in [fp.LANE - 1, fp.LANE, fp.LANE + 1, fp.MAX_BLOCK, fp.MAX_BLOCK + 17]:
        x = rand((n,), seed=n % 97)
        cfg = jnp.array([7.0, 1.0], jnp.float32)
        a = np.asarray(fp.quantize_fixed(x, cfg))
        b = np.asarray(ref.quantize_ref(x, 7.0, 1.0))
        np.testing.assert_array_equal(a, b)


def test_kernel_under_jit_and_vmap_composition():
    x = rand((4, 256), seed=9)
    cfg = jnp.array([5.0, 1.0], jnp.float32)
    jitted = jax.jit(lambda v: fp.quantize_fixed(v, cfg))
    np.testing.assert_array_equal(
        np.asarray(jitted(x)), np.asarray(ref.quantize_ref(x, 5.0, 1.0))
    )
