"""Dataset generators: determinism, shapes, value ranges, learnability
signal (class separation), label-noise calibration."""

import numpy as np
import pytest

from compile import datasets


@pytest.mark.parametrize("name", list(datasets.DATASETS))
def test_shapes_and_ranges(name):
    spec = datasets.DATASETS[name]
    xs, ys = spec["fn"](64, seed=3)
    assert xs.shape == (64, *spec["shape"])
    assert xs.dtype == np.float32
    assert ys.dtype == np.int32
    assert xs.min() >= 0.0 and xs.max() <= 1.0
    assert ys.min() >= 0 and ys.max() < spec["classes"]


@pytest.mark.parametrize("name", list(datasets.DATASETS))
def test_deterministic_given_seed(name):
    fn = datasets.DATASETS[name]["fn"]
    a_x, a_y = fn(32, seed=7)
    b_x, b_y = fn(32, seed=7)
    np.testing.assert_array_equal(a_x, b_x)
    np.testing.assert_array_equal(a_y, b_y)
    c_x, _ = fn(32, seed=8)
    assert not np.array_equal(a_x, c_x)


def test_train_eval_splits_disjoint_streams():
    tx, ty, ex, ey = datasets.load("synmnist", 64, 64, seed=0)
    assert tx.shape[0] == 64 and ex.shape[0] == 64
    assert not np.array_equal(tx, ex)


def test_classes_are_visually_distinct_synmnist():
    # mean image per class should differ clearly from other classes
    xs, ys = datasets.synmnist(1500, seed=1)
    means = np.stack([xs[ys == c].mean(axis=0) for c in range(10)])
    for a in range(10):
        for b in range(a + 1, 10):
            d = np.abs(means[a] - means[b]).mean()
            assert d > 0.005, f"classes {a},{b} indistinct ({d})"


def test_texture_classes_separable_on_average():
    xs, ys = datasets.syncifar(1200, seed=2)
    means = np.stack([xs[ys == c].mean(axis=0) for c in range(10)])
    dists = []
    for a in range(10):
        for b in range(a + 1, 10):
            dists.append(np.abs(means[a] - means[b]).mean())
    assert np.mean(dists) > 0.02


def test_label_noise_rate_synmnist_low():
    # ~0.5% flips: glyph class and label agree almost always; proxy — the
    # per-class mean images should carry strong signal (tested above);
    # here verify labels cover all classes roughly uniformly
    _, ys = datasets.synmnist(3000, seed=4)
    counts = np.bincount(ys, minlength=10)
    assert counts.min() > 200


def test_synimagenet_has_20_classes():
    _, ys = datasets.synimagenet(2000, seed=5)
    assert ys.max() == 19
    assert len(np.unique(ys)) == 20


def test_glyph_font_complete_and_5x7():
    for d in range(10):
        g = datasets._glyph(d)
        assert g.shape == (7, 5)
        assert g.sum() > 5  # non-trivial ink
