"""NTF container: round-trip, corruption detection, dtype handling —
the python half of the cross-language format lock."""

import numpy as np
import pytest

from compile import ntf


def sample():
    return {
        "w": np.arange(12, dtype=np.float32).reshape(3, 4) * -1.5,
        "labels": np.array([0, 5, -3], np.int32),
        "scalar_ish": np.array([2.5], np.float32),
    }


def test_roundtrip(tmp_path):
    p = str(tmp_path / "t.ntf")
    ntf.write(p, sample())
    back = ntf.read(p)
    for k, v in sample().items():
        np.testing.assert_array_equal(back[k], v)
        assert back[k].dtype == v.dtype


def test_crc_detects_corruption(tmp_path):
    p = str(tmp_path / "t.ntf")
    ntf.write(p, sample())
    raw = bytearray(open(p, "rb").read())
    raw[len(raw) // 2] ^= 0x20
    open(p, "wb").write(bytes(raw))
    with pytest.raises(ValueError, match="CRC"):
        ntf.read(p)


def test_bad_magic(tmp_path):
    p = str(tmp_path / "t.ntf")
    open(p, "wb").write(b"JUNKdata")
    with pytest.raises(ValueError):
        ntf.read(p)


def test_unsupported_dtype_rejected(tmp_path):
    p = str(tmp_path / "t.ntf")
    with pytest.raises(TypeError):
        ntf.write(p, {"bad": np.zeros(3, np.float64)})


def test_empty_container(tmp_path):
    p = str(tmp_path / "t.ntf")
    ntf.write(p, {})
    assert ntf.read(p) == {}


def test_preserves_insertion_order_content(tmp_path):
    p = str(tmp_path / "t.ntf")
    tensors = {f"t{i}": np.full((i + 1,), float(i), np.float32) for i in range(20)}
    ntf.write(p, tensors)
    back = ntf.read(p)
    assert set(back) == set(tensors)


def test_high_dim_and_big_tensor(tmp_path):
    p = str(tmp_path / "t.ntf")
    t = {"big": np.random.RandomState(0).randn(4, 3, 2, 5, 2).astype(np.float32)}
    ntf.write(p, t)
    np.testing.assert_array_equal(ntf.read(p)["big"], t["big"])
