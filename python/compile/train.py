"""Build-time training for the five networks (fp32, plain JAX).

The paper uses pre-trained Model-Zoo weights; this repo trains its scaled
networks from scratch at build time (`make artifacts`). Training is pure
fp32 with no quantization in the graph — matching the paper's setting
where quantization is applied only at classification time (§2.1).

Optimizer: hand-rolled Adam (optax is not available in this environment).
Everything is seeded and deterministic.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets, layers
from .nets import NetDef


def _plain_forward(net: NetDef):
    """fp32 forward with quantization compiled out entirely."""
    sentinel = jnp.full((len(net.groups), 2), -1.0, jnp.float32)

    def fwd(params, x):
        return layers.apply(net.groups, params, x, sentinel, sentinel, lambda v, cfg: v)

    return fwd


def _loss_fn(fwd):
    def loss(params, x, y):
        logits = fwd(params, x)
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
        return nll

    return loss


def adam_init(params):
    zeros = [jnp.zeros_like(p) for p in params]
    return {"m": zeros, "v": [jnp.zeros_like(p) for p in params], "t": jnp.zeros((), jnp.int32)}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = [b1 * m_ + (1 - b1) * g for m_, g in zip(state["m"], grads)]
    v = [b2 * v_ + (1 - b2) * g * g for v_, g in zip(state["v"], grads)]
    tf = t.astype(jnp.float32)
    bc1 = 1 - b1**tf
    bc2 = 1 - b2**tf
    new = [
        p - lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        for p, m_, v_ in zip(params, m, v)
    ]
    return new, {"m": m, "v": v, "t": t}


def train(net: NetDef, seed: int = 0, verbose: bool = True):
    """Train `net` on its dataset; return (param_names, params, info dict)."""
    t0 = time.time()
    tx, ty, ex, ey = datasets.load(net.dataset, net.n_train, net.n_eval, seed=seed)
    names, arrays = layers.init_params(net.groups, net.input_shape, seed=seed + 77)
    params = [jnp.asarray(a) for a in arrays]
    fwd = _plain_forward(net)
    loss = _loss_fn(fwd)
    grad_fn = jax.jit(jax.value_and_grad(loss))

    @jax.jit
    def eval_logits(params, x):
        return fwd(params, x)

    state = adam_init(params)
    rng = np.random.RandomState(seed + 1)
    B = net.batch
    losses = []
    for step in range(net.train_steps):
        idx = rng.randint(0, tx.shape[0], size=B)
        lv, grads = grad_fn(params, jnp.asarray(tx[idx]), jnp.asarray(ty[idx]))
        params, state = adam_step(params, grads, state, net.lr)
        losses.append(float(lv))
        if verbose and (step % 200 == 0 or step == net.train_steps - 1):
            print(f"  [{net.name}] step {step:5d} loss {float(lv):.4f}")

    # eval top-1 (batched to bound memory)
    correct = 0
    for i in range(0, ex.shape[0], B):
        lg = eval_logits(params, jnp.asarray(ex[i : i + B]))
        correct += int(jnp.sum(jnp.argmax(lg, axis=-1) == jnp.asarray(ey[i : i + B])))
    top1 = correct / ex.shape[0]
    info = {
        "top1": top1,
        "final_loss": float(np.mean(losses[-25:])),
        "train_seconds": time.time() - t0,
        "steps": net.train_steps,
    }
    if verbose:
        print(f"  [{net.name}] baseline top-1 {top1:.4f} ({info['train_seconds']:.1f}s)")
    return names, params, (ex, ey), info
