"""Procedural datasets standing in for MNIST / CIFAR10 / ImageNet.

The environment has no network access and no multi-GB corpora, so the
paper's datasets are substituted with deterministic, seeded, procedurally
generated classification tasks of matching rank and shape (DESIGN.md §2):

  * ``synmnist``    — 28x28x1, 10 classes: rendered digit glyphs with
                      affine jitter, stroke dropout and noise (LeNet task).
  * ``syncifar``    — 32x32x3, 10 classes: parametric colour textures with
                      heavy noise (Convnet task).
  * ``synimagenet`` — 32x32x3, 20 classes: composited texture + object
                      patterns with distractors (AlexNet / NiN / GoogLeNet
                      task; class count reduced from 1000 — see DESIGN.md).

Difficulty is tuned so fp32 baseline accuracies land near the paper's
Table-1 regimes: ~0.99 for the digit task, ~0.6-0.75 for the texture
tasks. Two knobs: image noise/distractors, and a calibrated label-flip
rate applied identically to train and eval splits (a flip rate p caps
top-1 at ~1-p+p/k, mirroring the irreducible confusion of the real
corpora). What matters for the reproduction is that the networks are
*really trained* and their weight/activation distributions are realistic,
since per-layer precision tolerance is a property of those distributions.
"""

from __future__ import annotations

import numpy as np

# ----------------------------------------------------------------------------
# 7x5 digit glyph font (classic seven-segment-ish bitmaps).
# ----------------------------------------------------------------------------

_DIGIT_FONT = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00010", "00100", "01000", "11111"],
    3: ["11111", "00010", "00100", "00010", "00001", "10001", "01110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}


def _glyph(digit: int) -> np.ndarray:
    rows = _DIGIT_FONT[digit]
    return np.array([[1.0 if c == "1" else 0.0 for c in r] for r in rows], np.float32)


def _upscale(img: np.ndarray, sy: int, sx: int) -> np.ndarray:
    return np.repeat(np.repeat(img, sy, axis=0), sx, axis=1)


def _box_blur(img: np.ndarray) -> np.ndarray:
    """Cheap 3x3 box blur, edge-padded — softens glyph edges."""
    p = np.pad(img, 1, mode="edge")
    out = np.zeros_like(img)
    for dy in range(3):
        for dx in range(3):
            out += p[dy : dy + img.shape[0], dx : dx + img.shape[1]]
    return out / 9.0


def _flip_labels(ys: np.ndarray, rate: float, k: int, rng: np.random.RandomState) -> np.ndarray:
    """Replace a `rate` fraction of labels with uniform-random classes."""
    flip = rng.rand(ys.shape[0]) < rate
    noisy = ys.copy()
    noisy[flip] = rng.randint(0, k, size=int(flip.sum()))
    return noisy


def synmnist(n: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Digit-glyph dataset: (n, 28, 28, 1) fp32 in [0,1], labels int32."""
    rng = np.random.RandomState(seed)
    xs = np.zeros((n, 28, 28, 1), np.float32)
    ys = rng.randint(0, 10, size=n).astype(np.int32)
    for k in range(n):
        g = _glyph(int(ys[k]))
        sy = rng.randint(2, 4)  # 14..21 rows
        sx = rng.randint(2, 5)  # 10..20 cols
        big = _upscale(g, sy, sx)
        # stroke dropout: kill a few pixels of the upscaled glyph
        drop = rng.rand(*big.shape) < 0.06
        big = big * (1.0 - drop)
        h, w = big.shape
        oy = rng.randint(0, 28 - h + 1)
        ox = rng.randint(0, 28 - w + 1)
        canvas = np.zeros((28, 28), np.float32)
        canvas[oy : oy + h, ox : ox + w] = big
        canvas = _box_blur(canvas)
        canvas = canvas * rng.uniform(0.75, 1.0) + rng.randn(28, 28).astype(np.float32) * 0.08
        xs[k, :, :, 0] = np.clip(canvas, 0.0, 1.0)
    return xs, _flip_labels(ys, 0.005, 10, rng)


# ----------------------------------------------------------------------------
# Parametric colour textures.
# ----------------------------------------------------------------------------


def _texture(cls_params: dict, rng: np.random.RandomState, size: int) -> np.ndarray:
    """Render one 3-channel parametric texture sample in [0,1]."""
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32) / size
    kind = cls_params["kind"]
    fx, fy = cls_params["fx"], cls_params["fy"]
    phase = rng.uniform(0, 2 * np.pi)
    rot = cls_params["rot"] + rng.uniform(-0.2, 0.2)
    u = np.cos(rot) * xx + np.sin(rot) * yy
    v = -np.sin(rot) * xx + np.cos(rot) * yy
    if kind == "stripes":
        base = np.sin(2 * np.pi * fx * u + phase)
    elif kind == "checks":
        base = np.sign(np.sin(2 * np.pi * fx * u + phase)) * np.sign(
            np.sin(2 * np.pi * fy * v + phase * 0.7)
        )
    elif kind == "radial":
        cy, cx = rng.uniform(0.3, 0.7, size=2)
        r = np.sqrt((xx - cx) ** 2 + (yy - cy) ** 2)
        base = np.cos(2 * np.pi * fx * r + phase)
    elif kind == "blob":
        cy, cx = rng.uniform(0.25, 0.75, size=2)
        r2 = (xx - cx) ** 2 + (yy - cy) ** 2
        base = 2.0 * np.exp(-r2 * fx * 8.0) - 1.0 + 0.4 * np.sin(2 * np.pi * fy * v)
    else:  # gradient
        base = 2.0 * (np.cos(rot) * xx + np.sin(rot) * yy) - 1.0 + 0.3 * np.sin(
            2 * np.pi * fx * u + phase
        )
    img = np.zeros((size, size, 3), np.float32)
    col = np.asarray(cls_params["color"], np.float32)
    alt = np.asarray(cls_params["alt"], np.float32)
    w = (base.astype(np.float32) + 1.0) / 2.0
    for c in range(3):
        img[:, :, c] = w * col[c] + (1.0 - w) * alt[c]
    return img


def _texture_classes(num_classes: int, seed: int) -> list[dict]:
    """Deterministic class->texture-parameter table."""
    rng = np.random.RandomState(seed)
    kinds = ["stripes", "checks", "radial", "blob", "gradient"]
    out = []
    for c in range(num_classes):
        out.append(
            {
                "kind": kinds[c % len(kinds)],
                "fx": float(1.5 + 1.1 * (c // len(kinds)) + 0.37 * c % 3),
                "fy": float(1.0 + 0.9 * (c % 4)),
                "rot": float(rng.uniform(0, np.pi)),
                "color": rng.uniform(0.3, 1.0, size=3).tolist(),
                "alt": rng.uniform(0.0, 0.6, size=3).tolist(),
            }
        )
    return out


def syncifar(n: int, seed: int = 1) -> tuple[np.ndarray, np.ndarray]:
    """Texture dataset: (n, 32, 32, 3) fp32 in [0,1], 10 classes."""
    rng = np.random.RandomState(seed)
    table = _texture_classes(10, seed=1234)
    xs = np.zeros((n, 32, 32, 3), np.float32)
    ys = rng.randint(0, 10, size=n).astype(np.int32)
    for k in range(n):
        img = _texture(table[int(ys[k])], rng, 32)
        img += rng.randn(32, 32, 3).astype(np.float32) * 0.30
        xs[k] = np.clip(img, 0.0, 1.0)
    return xs, _flip_labels(ys, 0.30, 10, rng)


def synimagenet(n: int, seed: int = 2, num_classes: int = 20) -> tuple[np.ndarray, np.ndarray]:
    """Harder composited dataset: (n, 32, 32, 3) fp32, 20 classes.

    Each sample composites the class texture with a random distractor
    texture at random opacity, plus noise — raising confusability so the
    baseline lands in the paper's ImageNet-network accuracy regime.
    """
    rng = np.random.RandomState(seed)
    table = _texture_classes(num_classes, seed=4321)
    xs = np.zeros((n, 32, 32, 3), np.float32)
    ys = rng.randint(0, num_classes, size=n).astype(np.int32)
    for k in range(n):
        img = _texture(table[int(ys[k])], rng, 32)
        d = int(rng.randint(0, num_classes))
        distract = _texture(table[d], rng, 32)
        alpha = rng.uniform(0.20, 0.50)
        img = (1 - alpha) * img + alpha * distract
        img += rng.randn(32, 32, 3).astype(np.float32) * 0.26
        xs[k] = np.clip(img, 0.0, 1.0)
    return xs, _flip_labels(ys, 0.38, num_classes, rng)


DATASETS = {
    "synmnist": {"fn": synmnist, "shape": (28, 28, 1), "classes": 10},
    "syncifar": {"fn": syncifar, "shape": (32, 32, 3), "classes": 10},
    "synimagenet": {"fn": synimagenet, "shape": (32, 32, 3), "classes": 20},
}


def load(name: str, n_train: int, n_eval: int, seed: int = 0):
    """Return (train_x, train_y, eval_x, eval_y); eval drawn from a disjoint seed."""
    spec = DATASETS[name]
    tx, ty = spec["fn"](n_train, seed=seed * 2 + 11)
    ex, ey = spec["fn"](n_eval, seed=seed * 2 + 12)
    return tx, ty, ex, ey
