"""NTF — the repo's tiny named-tensor container format (python writer).

Layout (little-endian):

    magic   b"NTF1"
    u32     entry count
    entries:
        u16     name length, then name bytes (utf-8)
        u8      dtype  (0 = f32, 1 = i32)
        u8      ndim
        u64*nd  dims
        raw     data  (len = prod(dims) * 4)
    u32     CRC32 of everything before the footer

The rust reader lives in ``rust/src/tensor/ntf.rs``; the two are locked
together by round-trip tests on both sides (python writes → rust reads the
shipped artifacts; rust writes → python reads in pytest via this module).
"""

from __future__ import annotations

import struct
import zlib

import numpy as np

MAGIC = b"NTF1"
DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}
DTYPES_INV = {0: np.float32, 1: np.int32}


def write(path: str, tensors: dict[str, np.ndarray]) -> None:
    """Write `tensors` (name -> f32/i32 ndarray) to `path`."""
    buf = bytearray()
    buf += MAGIC
    buf += struct.pack("<I", len(tensors))
    for name, arr in tensors.items():
        arr = np.ascontiguousarray(arr)
        if arr.dtype not in DTYPES:
            raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
        nb = name.encode("utf-8")
        buf += struct.pack("<H", len(nb))
        buf += nb
        buf += struct.pack("<BB", DTYPES[arr.dtype], arr.ndim)
        for d in arr.shape:
            buf += struct.pack("<Q", d)
        buf += arr.tobytes()
    crc = zlib.crc32(bytes(buf)) & 0xFFFFFFFF
    buf += struct.pack("<I", crc)
    with open(path, "wb") as f:
        f.write(bytes(buf))


def read(path: str) -> dict[str, np.ndarray]:
    """Read an NTF file, verifying magic and CRC."""
    with open(path, "rb") as f:
        raw = f.read()
    if raw[:4] != MAGIC:
        raise ValueError("bad magic")
    crc_stored = struct.unpack("<I", raw[-4:])[0]
    if zlib.crc32(raw[:-4]) & 0xFFFFFFFF != crc_stored:
        raise ValueError("CRC mismatch")
    off = 4
    (count,) = struct.unpack_from("<I", raw, off)
    off += 4
    out: dict[str, np.ndarray] = {}
    for _ in range(count):
        (nlen,) = struct.unpack_from("<H", raw, off)
        off += 2
        name = raw[off : off + nlen].decode("utf-8")
        off += nlen
        dtype_id, ndim = struct.unpack_from("<BB", raw, off)
        off += 2
        dims = struct.unpack_from(f"<{ndim}Q", raw, off)
        off += 8 * ndim
        n = int(np.prod(dims)) if ndim else 1
        dt = DTYPES_INV[dtype_id]
        arr = np.frombuffer(raw, dtype=dt, count=n, offset=off).reshape(dims)
        off += n * 4
        out[name] = arr.copy()
    return out
