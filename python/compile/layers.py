"""Layer-2 building blocks: ops, parameter init, shape/MAC accounting.

A network is described declaratively as a list of `LayerGroup`s, each a
list of `Op`s. The same description drives four consumers:

  1. `init_params`  — parameter initialization (He-normal),
  2. `apply`        — the jit-able forward pass (with quantization hooks),
  3. `shape_walk`   — analytic shape/weight/MAC accounting used for the
                      paper's traffic model (Fig 4) and the manifest,
  4. the AOT manifest consumed by the rust coordinator.

Grouping follows the paper's Appendix A: each "layer" is a main conv/FC
stage plus its trailing relu/pool/LRN/dropout stages, and for GoogLeNet a
whole inception module is one group. Data quantization is applied to each
group's *output*; weight quantization to each group's weights.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

# ----------------------------------------------------------------------------
# Ops
# ----------------------------------------------------------------------------


@dataclass
class Conv:
    """2-D convolution, NHWC x HWIO -> NHWC, with bias."""

    out_c: int
    k: int
    stride: int = 1
    padding: str = "SAME"  # or "VALID"
    name: str = "conv"


@dataclass
class Dense:
    """Fully-connected layer (expects flattened input), with bias."""

    out: int
    name: str = "fc"


@dataclass
class ReLU:
    name: str = "relu"


@dataclass
class MaxPool:
    k: int
    stride: int
    name: str = "pool"


@dataclass
class AvgPool:
    k: int
    stride: int
    name: str = "avgpool"


@dataclass
class GlobalAvgPool:
    name: str = "gap"


@dataclass
class LRN:
    """Local response normalization across channels (AlexNet norm1/norm2)."""

    n: int = 5
    alpha: float = 1e-4
    beta: float = 0.75
    name: str = "norm"


@dataclass
class Flatten:
    name: str = "flatten"


@dataclass
class Dropout:
    """Identity at inference (classification study only)."""

    rate: float = 0.5
    name: str = "drop"


@dataclass
class Inception:
    """GoogLeNet inception module: 1x1 / 3x3(reduce) / 5x5(reduce) / pool-proj.

    All six convolutions (plus their biases) belong to one precision group,
    matching the paper's treatment of inception modules as single layers.
    """

    b1: int
    b3r: int
    b3: int
    b5r: int
    b5: int
    pp: int
    name: str = "inception"

    @property
    def out_c(self) -> int:
        return self.b1 + self.b3 + self.b5 + self.pp


@dataclass
class LayerGroup:
    """One paper-granularity 'layer': name, kind, and its op pipeline."""

    name: str
    kind: str  # "conv" | "fc" | "inception"
    ops: list = field(default_factory=list)


# ----------------------------------------------------------------------------
# Parameter init
# ----------------------------------------------------------------------------


def _he(rng: np.random.RandomState, shape: tuple[int, ...], fan_in: int) -> np.ndarray:
    return (rng.randn(*shape) * math.sqrt(2.0 / fan_in)).astype(np.float32)


def _conv_params(rng, op: Conv, in_c: int, prefix: str) -> list[tuple[str, np.ndarray]]:
    w = _he(rng, (op.k, op.k, in_c, op.out_c), op.k * op.k * in_c)
    b = np.zeros((op.out_c,), np.float32)
    return [(f"{prefix}.w", w), (f"{prefix}.b", b)]


def init_params(groups: list[LayerGroup], input_shape: tuple[int, int, int], seed: int):
    """Return (names, arrays) in deterministic order; shapes from shape_walk."""
    rng = np.random.RandomState(seed)
    names: list[str] = []
    arrays: list[np.ndarray] = []
    shape = input_shape  # (H, W, C)
    for g in groups:
        for op in g.ops:
            prefix = f"{g.name}.{op.name}"
            if isinstance(op, Conv):
                for n, a in _conv_params(rng, op, shape[2], prefix):
                    names.append(n)
                    arrays.append(a)
            elif isinstance(op, Dense):
                fan_in = int(np.prod(shape))
                w = _he(rng, (fan_in, op.out), fan_in)
                b = np.zeros((op.out,), np.float32)
                names += [f"{prefix}.w", f"{prefix}.b"]
                arrays += [w, b]
            elif isinstance(op, Inception):
                in_c = shape[2]
                branches = [
                    (f"{prefix}.b1", 1, in_c, op.b1),
                    (f"{prefix}.b3r", 1, in_c, op.b3r),
                    (f"{prefix}.b3", 3, op.b3r, op.b3),
                    (f"{prefix}.b5r", 1, in_c, op.b5r),
                    (f"{prefix}.b5", 5, op.b5r, op.b5),
                    (f"{prefix}.pp", 1, in_c, op.pp),
                ]
                for n, k, ic, oc in branches:
                    names.append(f"{n}.w")
                    arrays.append(_he(rng, (k, k, ic, oc), k * k * ic))
                    names.append(f"{n}.b")
                    arrays.append(np.zeros((oc,), np.float32))
            shape = _op_out_shape(op, shape)
    return names, arrays


# ----------------------------------------------------------------------------
# Shape / MAC walk (analytic — no tracing)
# ----------------------------------------------------------------------------


def _conv_out_hw(h: int, w: int, k: int, s: int, padding: str) -> tuple[int, int]:
    if padding == "SAME":
        return (h + s - 1) // s, (w + s - 1) // s
    return (h - k) // s + 1, (w - k) // s + 1


def _op_out_shape(op, shape: tuple[int, ...]) -> tuple[int, ...]:
    if isinstance(op, Conv):
        h, w = _conv_out_hw(shape[0], shape[1], op.k, op.stride, op.padding)
        return (h, w, op.out_c)
    if isinstance(op, Dense):
        return (op.out,)
    if isinstance(op, (MaxPool, AvgPool)):
        h, w = _conv_out_hw(shape[0], shape[1], op.k, op.stride, "SAME")
        return (h, w, shape[2])
    if isinstance(op, GlobalAvgPool):
        return (shape[2],)
    if isinstance(op, Flatten):
        return (int(np.prod(shape)),)
    if isinstance(op, Inception):
        return (shape[0], shape[1], op.out_c)
    return shape  # ReLU, LRN, Dropout


def _op_counts(op, in_shape: tuple[int, ...]) -> tuple[int, int]:
    """(weight_elems incl. bias, MACs) for one op given its input shape."""
    if isinstance(op, Conv):
        h, w = _conv_out_hw(in_shape[0], in_shape[1], op.k, op.stride, op.padding)
        wts = op.k * op.k * in_shape[2] * op.out_c + op.out_c
        macs = h * w * op.out_c * op.k * op.k * in_shape[2]
        return wts, macs
    if isinstance(op, Dense):
        fan_in = int(np.prod(in_shape))
        return fan_in * op.out + op.out, fan_in * op.out
    if isinstance(op, Inception):
        h, w, c = in_shape
        wts = macs = 0
        for k, ic, oc in [
            (1, c, op.b1),
            (1, c, op.b3r),
            (3, op.b3r, op.b3),
            (1, c, op.b5r),
            (5, op.b5r, op.b5),
            (1, c, op.pp),
        ]:
            wts += k * k * ic * oc + oc
            macs += h * w * oc * k * k * ic
        return wts, macs
    return 0, 0


def shape_walk(groups: list[LayerGroup], input_shape: tuple[int, int, int]):
    """Per-group metadata: dict with in/out elems, weights, MACs, stages."""
    meta = []
    shape = input_shape
    for g in groups:
        in_elems = int(np.prod(shape))
        wts = 0
        macs = 0
        stages = []
        for op in g.ops:
            w, m = _op_counts(op, shape)
            wts += w
            macs += m
            shape = _op_out_shape(op, shape)
            stages.append({"name": op.name, "out_shape": list(shape)})
        meta.append(
            {
                "name": g.name,
                "kind": g.kind,
                "in_elems": in_elems,
                "out_elems": int(np.prod(shape)),
                "weight_elems": int(wts),
                "macs": int(macs),
                "stages": stages,
            }
        )
    return meta, shape


# ----------------------------------------------------------------------------
# Forward
# ----------------------------------------------------------------------------

_DIMNUMS = ("NHWC", "HWIO", "NHWC")


def _conv2d(x, w, b, stride: int, padding: str):
    y = lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding, dimension_numbers=_DIMNUMS
    )
    return y + b


def _maxpool(x, k: int, s: int):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, k, k, 1), (1, s, s, 1), "SAME"
    )


def _avgpool(x, k: int, s: int):
    summed = lax.reduce_window(x, 0.0, lax.add, (1, k, k, 1), (1, s, s, 1), "SAME")
    ones = jnp.ones_like(x)
    counts = lax.reduce_window(ones, 0.0, lax.add, (1, k, k, 1), (1, s, s, 1), "SAME")
    return summed / counts


def _lrn(x, n: int, alpha: float, beta: float):
    """Caffe-style across-channel LRN: x / (1 + alpha/n * sum x^2)^beta."""
    half = n // 2
    sq = x * x
    pad = jnp.pad(sq, ((0, 0), (0, 0), (0, 0), (half, half)))
    acc = jnp.zeros_like(x)
    for d in range(n):
        acc = acc + lax.dynamic_slice_in_dim(pad, d, x.shape[3], axis=3)
    return x / jnp.power(1.0 + (alpha / n) * acc, beta)


def group_param_counts(groups: list[LayerGroup]) -> list[int]:
    """Number of flat parameter tensors consumed by each group."""
    counts = []
    for g in groups:
        n = 0
        for op in g.ops:
            if isinstance(op, (Conv, Dense)):
                n += 2
            elif isinstance(op, Inception):
                n += 12
        counts.append(n)
    return counts


def quantize_group_params(params: list, counts: list[int], wq, quantize):
    """Quantize each group's parameters with its (I, F) row — batched.

    All tensors of a group are flattened into ONE vector and quantized with
    a single kernel invocation (elementwise op, so semantics are identical
    to per-tensor quantization), then split back. This keeps the number of
    Pallas calls proportional to the number of *layers*, not tensors —
    GoogLeNet drops from 114 to 11 weight-quant kernel launches.
    """
    out = []
    idx = 0
    for gi, n in enumerate(counts):
        group = params[idx : idx + n]
        idx += n
        if not group:
            continue
        flats = [p.reshape(-1) for p in group]
        sizes = [f.shape[0] for f in flats]
        q = quantize(jnp.concatenate(flats), wq[gi])
        off = 0
        for p, s in zip(group, sizes):
            out.append(q[off : off + s].reshape(p.shape))
            off += s
    return out


class ParamCursor:
    """Sequential reader over the flat parameter list (order = init order)."""

    def __init__(self, params: list):
        self.params = params
        self.idx = 0

    def take(self, n: int = 1):
        out = self.params[self.idx : self.idx + n]
        self.idx += n
        return out if n > 1 else out[0]


def _apply_op(op, x, cursor: ParamCursor, qw):
    """Apply one op; `qw` quantizes any weight tensor it consumes."""
    if isinstance(op, Conv):
        w, b = cursor.take(2)
        return _conv2d(x, qw(w), qw(b), op.stride, op.padding)
    if isinstance(op, Dense):
        w, b = cursor.take(2)
        return x @ qw(w) + qw(b)
    if isinstance(op, ReLU):
        return jax.nn.relu(x)
    if isinstance(op, MaxPool):
        return _maxpool(x, op.k, op.stride)
    if isinstance(op, AvgPool):
        return _avgpool(x, op.k, op.stride)
    if isinstance(op, GlobalAvgPool):
        return jnp.mean(x, axis=(1, 2))
    if isinstance(op, LRN):
        return _lrn(x, op.n, op.alpha, op.beta)
    if isinstance(op, Flatten):
        return x.reshape(x.shape[0], -1)
    if isinstance(op, Dropout):
        return x  # inference
    if isinstance(op, Inception):
        ps = cursor.take(12)
        w1, b1, w3r, b3r, w3, b3, w5r, b5r, w5, b5, wp, bp = [qw(p) for p in ps]
        br1 = jax.nn.relu(_conv2d(x, w1, b1, 1, "SAME"))
        br3 = jax.nn.relu(_conv2d(x, w3r, b3r, 1, "SAME"))
        br3 = jax.nn.relu(_conv2d(br3, w3, b3, 1, "SAME"))
        br5 = jax.nn.relu(_conv2d(x, w5r, b5r, 1, "SAME"))
        br5 = jax.nn.relu(_conv2d(br5, w5, b5, 1, "SAME"))
        brp = _maxpool(x, 3, 1)
        brp = jax.nn.relu(_conv2d(brp, wp, bp, 1, "SAME"))
        return jnp.concatenate([br1, br3, br5, brp], axis=3)
    raise TypeError(f"unknown op {op!r}")


def apply(
    groups: list[LayerGroup],
    params: list,
    x,
    wq,
    dq,
    quantize,
    stage_group: int | None = None,
    stage_cfg=None,
):
    """Forward pass with per-layer quantization.

    Args:
      params: flat parameter list (init_params order).
      x: (B, H, W, C) fp32 batch.
      wq: (L, 2) per-group weight (I, F); sentinel I<0 = fp32.
      dq: (L, 2) per-group *output-data* (I, F); the network input is
        quantized with dq[0] (the first layer's data format — see DESIGN.md).
      quantize: fn(x, cfg2) -> x (the L1 kernel or the oracle).
      stage_group: if set (Fig 1 mode), group index whose intermediate
        stage outputs are quantized with rows of `stage_cfg`
        ((n_ops, 2)); that group's normal output quant is skipped in
        favour of the final stage row.
    """
    counts = group_param_counts(groups)
    qparams = quantize_group_params(params, counts, wq, quantize)
    cursor = ParamCursor(qparams)
    ident = lambda w: w  # weights already quantized group-wise above
    h = quantize(x, dq[0])
    for gi, g in enumerate(groups):
        for oi, op in enumerate(g.ops):
            h = _apply_op(op, h, cursor, ident)
            if stage_group is not None and gi == stage_group:
                h = quantize(h, stage_cfg[oi])
        if not (stage_group is not None and gi == stage_group):
            h = quantize(h, dq[gi])
    assert cursor.idx == len(qparams), "parameter list length mismatch"
    return h
