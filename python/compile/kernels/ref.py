"""Pure-jnp oracle for the fixed-point quantization kernels.

This module is the *semantic source of truth* for the numeric format used
throughout the repo (paper §2.1 "Target Numerical Representation"):

    Q(I.F)  — a fixed-point value with I integer bits (including the sign
              bit) and F fractional bits.

Representable values are ``k * 2^-F`` for integer ``k`` in
``[-2^(I-1+F), 2^(I-1+F) - 1]``, i.e. the closed range

    lo = -2^(I-1)            hi = 2^(I-1) - 2^-F

Quantization is round-to-nearest-even (``rint``) followed by saturation,
performed on fp32 values and returned as fp32 — exactly the paper's
"convert at layer read/write, compute in fp32" methodology.

A configuration with ``I < 0`` is the *pass-through sentinel*: the value
is returned untouched (fp32 baseline). This lets one AOT-compiled
executable serve both the baseline and every quantized configuration.

The Rust-side quantizer (``rust/src/quant``) and the Pallas kernel
(``fixedpoint.py``) are locked bit-for-bit against this definition by
tests on both sides of the language boundary.
"""

from __future__ import annotations

import jax.numpy as jnp


def quantize_ref(x: jnp.ndarray, ibits: jnp.ndarray, fbits: jnp.ndarray) -> jnp.ndarray:
    """Round-trip ``x`` through the Q(ibits.fbits) fixed-point grid.

    Args:
      x: fp32 array of any shape.
      ibits: scalar (or broadcastable) fp32 — integer bits incl. sign.
        Negative means pass-through.
      fbits: scalar (or broadcastable) fp32 — fractional bits (>= 0).

    Returns:
      fp32 array of the same shape as ``x``.
    """
    x = jnp.asarray(x, jnp.float32)
    i = jnp.asarray(ibits, jnp.float32)
    f = jnp.asarray(fbits, jnp.float32)
    scale, inv, lo, hi = _grid(i, f)
    q = jnp.clip(jnp.rint(x * scale) * inv, lo, hi)
    return jnp.where(i < 0.0, x, q).astype(jnp.float32)


def _grid(i, f):
    """Exact Q(I.F) grid parameters.

    XLA lowers ``exp2`` through ``exp(x * ln 2)``, which is NOT exact for
    integer exponents (e.g. 2^15 -> 32767.998) — and the rust host
    quantizer uses the exactly-rounded libm ``exp2f``. To keep the three
    implementations bit-identical we snap the (always power-of-two)
    magnitudes to integers with ``rint`` and derive the reciprocal by exact
    division: 1/2^k is exact in fp32 for the k used here (|k| <= 16).
    """
    scale = jnp.rint(jnp.exp2(f))          # 2^F, exact after rounding
    inv = 1.0 / scale                      # 2^-F, exact (power of two)
    hipow = jnp.rint(jnp.exp2(i)) * 0.5    # 2^(I-1); snap 2^I (integer for
    lo = -hipow                            # I >= 0) then halve exactly —
    hi = hipow - inv                       # keeps I = 0 formats correct
    return scale, inv, lo, hi


def quantize_stochastic_ref(
    x: jnp.ndarray, ibits: jnp.ndarray, fbits: jnp.ndarray, u: jnp.ndarray
) -> jnp.ndarray:
    """Stochastic-rounding variant (paper §4 future work; Gupta et al. 2015).

    ``u`` is uniform noise in [0, 1) of the same shape as ``x``; the value
    is rounded down with probability equal to its distance to the upper
    grid point. Saturation and the sentinel behave as in `quantize_ref`.
    """
    x = jnp.asarray(x, jnp.float32)
    i = jnp.asarray(ibits, jnp.float32)
    f = jnp.asarray(fbits, jnp.float32)
    scale, inv, lo, hi = _grid(i, f)
    q = jnp.clip(jnp.floor(x * scale + u) * inv, lo, hi)
    return jnp.where(i < 0.0, x, q).astype(jnp.float32)


def qformat_range(ibits: float, fbits: float) -> tuple[float, float, float]:
    """(lo, hi, step) of the Q(I.F) grid — mirrors rust `QFormat::range`."""
    step = 2.0 ** (-fbits)
    hi = 2.0 ** (ibits - 1.0) - step
    lo = -(2.0 ** (ibits - 1.0))
    return lo, hi, step
