"""Layer-1 Pallas kernels: fixed-point quantize/dequantize round-trip.

The paper's hot spot is the representation conversion applied to every
value crossing a layer boundary (§2.1).  On TPU we express it as a Pallas
kernel so the HBM<->VMEM schedule is explicit:

  * the activation tensor is flattened and tiled into ``(1, BLOCK)`` VMEM
    blocks (BLOCK a multiple of 128 lanes x 8 sublanes for fp32);
  * the per-layer ``(I, F)`` configuration is a tiny operand mapped to the
    same (0,)-block for every grid step — the scalar-prefetch idiom — so a
    single compiled executable serves *every* precision configuration;
  * the body is pure VPU work (exp2 / rint / clip / mul): arithmetic
    intensity ~1 flop/byte, i.e. memory-bound; see DESIGN.md
    §Hardware-Adaptation for the roofline discussion.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered through the Pallas interpreter into
plain HLO.  Numerics are identical; TPU performance is estimated
analytically in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile geometry. Blocks are multiples of (8 sublanes x 128 lanes) fp32
# vregs; MAX_BLOCK = 2^20 fp32 = 4 MiB, which double-buffers comfortably
# inside a 16 MiB VMEM budget. Small tensors get a single right-sized
# block (grid=1) instead of padding up to MAX_BLOCK — under the Pallas
# interpreter every grid step costs a serialized dynamic-slice copy, so
# the schedule minimizes grid steps first, block size second
# (EXPERIMENTS.md §Perf records the 8192->adaptive change: interpret-mode
# quantize of 2M fp32 went 396 ms -> ~8 ms).
LANE = 1024  # 8 sublanes x 128 lanes
MAX_BLOCK = 1 << 20


def _block_for(n: int) -> int:
    """Smallest LANE-multiple block covering n, capped at MAX_BLOCK."""
    b = (n + LANE - 1) // LANE * LANE
    return min(b, MAX_BLOCK)


def _grid(i, f):
    """Exact Q(I.F) grid parameters (see ref._grid for the exp2 story:
    XLA's exp2 is exp(x·ln2) and drifts off integer powers; rint snaps it
    back so rust/oracle/kernel stay bit-identical)."""
    scale = jnp.rint(jnp.exp2(f))
    inv = 1.0 / scale
    hipow = jnp.rint(jnp.exp2(i)) * 0.5  # exact for I >= 0 incl. I = 0
    return scale, inv, -hipow, hipow - inv


def _quantize_kernel(cfg_ref, x_ref, o_ref):
    """Pallas body: o = clip(rint(x * 2^F) * 2^-F, lo, hi); sentinel I<0."""
    i = cfg_ref[0]
    f = cfg_ref[1]
    scale, inv, lo, hi = _grid(i, f)
    x = x_ref[...]
    q = jnp.clip(jnp.rint(x * scale) * inv, lo, hi)
    o_ref[...] = jnp.where(i < 0.0, x, q)


def _stochastic_kernel(cfg_ref, x_ref, u_ref, o_ref):
    """Stochastic-rounding body (extension): floor(x*2^F + u) * 2^-F."""
    i = cfg_ref[0]
    f = cfg_ref[1]
    scale, inv, lo, hi = _grid(i, f)
    x = x_ref[...]
    q = jnp.clip(jnp.floor(x * scale + u_ref[...]) * inv, lo, hi)
    o_ref[...] = jnp.where(i < 0.0, x, q)


def _pad_to_block(flat: jnp.ndarray, block: int) -> tuple[jnp.ndarray, int]:
    n = flat.shape[0]
    padded = (n + block - 1) // block * block
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    return flat, n


@functools.partial(jax.jit, static_argnames=())
def quantize_fixed(x: jnp.ndarray, cfg: jnp.ndarray) -> jnp.ndarray:
    """Quantize ``x`` (any shape, fp32) to the Q(I.F) grid given by ``cfg``.

    ``cfg`` is a ``(2,)`` fp32 array ``[I, F]``; ``I < 0`` is the
    fp32-pass-through sentinel.  Returns fp32 of the same shape.
    """
    x = jnp.asarray(x, jnp.float32)
    shape = x.shape
    block = _block_for(x.size)
    flat, n = _pad_to_block(x.reshape(-1), block)
    tiles = flat.reshape(-1, block)
    grid = (tiles.shape[0],)
    out = pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),          # cfg: same block each step
            pl.BlockSpec((1, block), lambda i: (i, 0)),  # x: stream tiles
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(tiles.shape, jnp.float32),
        interpret=True,
    )(jnp.asarray(cfg, jnp.float32), tiles)
    return out.reshape(-1)[:n].reshape(shape)


@functools.partial(jax.jit, static_argnames=())
def quantize_stochastic(x: jnp.ndarray, cfg: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Stochastic-rounding quantize; ``u`` ~ U[0,1) with the shape of ``x``."""
    x = jnp.asarray(x, jnp.float32)
    shape = x.shape
    block = _block_for(x.size)
    flat, n = _pad_to_block(x.reshape(-1), block)
    uflat, _ = _pad_to_block(jnp.asarray(u, jnp.float32).reshape(-1), block)
    tiles = flat.reshape(-1, block)
    utiles = uflat.reshape(-1, block)
    out = pl.pallas_call(
        _stochastic_kernel,
        grid=(tiles.shape[0],),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(tiles.shape, jnp.float32),
        interpret=True,
    )(jnp.asarray(cfg, jnp.float32), tiles, utiles)
    return out.reshape(-1)[:n].reshape(shape)


def quantize(x: jnp.ndarray, cfg: jnp.ndarray, *, use_pallas: bool = True) -> jnp.ndarray:
    """Dispatch between the Pallas kernel and the jnp oracle.

    The network graphs call this; ``use_pallas=True`` is the shipped
    configuration so the kernel lowers into the same HLO artifact the rust
    runtime executes.  The oracle path exists for A/B perf comparisons
    (EXPERIMENTS.md §Perf) and as the hypothesis-test reference.
    """
    if use_pallas:
        return quantize_fixed(x, cfg)
    from . import ref

    return ref.quantize_ref(x, cfg[0], cfg[1])
