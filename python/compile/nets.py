"""The five CNN architectures of the paper (Table 1 / Appendix A), scaled.

Every network keeps the paper's *structure* — layer count, layer kinds,
grouping of stages into precision "layers", inception-module treatment —
while channel widths and input resolution are scaled to this CPU-only
testbed (DESIGN.md §2 documents the substitution argument).

| net            | paper                        | here                           |
|----------------|------------------------------|--------------------------------|
| lenet          | 2 CONV + 2 FC, MNIST         | 2 CONV + 2 FC, synmnist 28x28  |
| convnet        | 3 CONV + 2 FC, CIFAR10       | 3 CONV + 2 FC, syncifar 32x32  |
| alexnet        | 5 CONV + 3 FC, ImageNet      | 5 CONV + 3 FC, synimagenet     |
| nin            | 12 CONV, ImageNet            | 12 CONV, synimagenet           |
| googlenet      | 2 CONV + 9 IM, ImageNet      | 2 CONV + 9 IM, synimagenet     |
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .layers import (
    LRN,
    AvgPool,
    Conv,
    Dense,
    Dropout,
    Flatten,
    GlobalAvgPool,
    Inception,
    LayerGroup,
    MaxPool,
    ReLU,
)


@dataclass
class NetDef:
    name: str
    dataset: str
    input_shape: tuple[int, int, int]
    num_classes: int
    groups: list[LayerGroup] = field(default_factory=list)
    # training hyper-parameters (build path only)
    train_steps: int = 600
    batch: int = 64
    lr: float = 1e-3
    n_train: int = 8192
    n_eval: int = 1024


def lenet() -> NetDef:
    """LeNet: conv1+pool / conv2+pool / ip1+relu / ip2 (Appendix A)."""
    g = [
        LayerGroup("L1", "conv", [Conv(8, 5, padding="VALID"), MaxPool(2, 2)]),
        LayerGroup("L2", "conv", [Conv(16, 5, padding="VALID"), MaxPool(2, 2)]),
        LayerGroup("L3", "fc", [Flatten(), Dense(64), ReLU()]),
        LayerGroup("L4", "fc", [Dense(10)]),
    ]
    return NetDef("lenet", "synmnist", (28, 28, 1), 10, g, train_steps=2000)


def convnet() -> NetDef:
    """cuda-convnet CIFAR10 model: 3 conv+pool layers, 2 FC (ip1, ip2)."""
    g = [
        LayerGroup("L1", "conv", [Conv(16, 5), MaxPool(3, 2), ReLU()]),
        LayerGroup("L2", "conv", [Conv(16, 5), ReLU(), MaxPool(3, 2)]),
        LayerGroup("L3", "conv", [Conv(16, 5), ReLU(), MaxPool(3, 2)]),
        LayerGroup("L4", "fc", [Flatten(), Dense(32)]),
        LayerGroup("L5", "fc", [Dense(10)]),
    ]
    return NetDef("convnet", "syncifar", (32, 32, 3), 10, g, train_steps=900)


def alexnet() -> NetDef:
    """AlexNet: 5 conv (first two with pool+LRN) + 3 FC, Appendix-A grouping."""
    g = [
        LayerGroup("L1", "conv", [Conv(24, 3), ReLU(), MaxPool(3, 2), LRN()]),
        LayerGroup("L2", "conv", [Conv(32, 3), ReLU(), MaxPool(3, 2), LRN()]),
        LayerGroup("L3", "conv", [Conv(48, 3), ReLU()]),
        LayerGroup("L4", "conv", [Conv(48, 3), ReLU()]),
        LayerGroup("L5", "conv", [Conv(32, 3), ReLU(), MaxPool(3, 2)]),
        LayerGroup("L6", "fc", [Flatten(), Dense(128), ReLU(), Dropout()]),
        LayerGroup("L7", "fc", [Dense(128), ReLU(), Dropout()]),
        LayerGroup("L8", "fc", [Dense(20)]),
    ]
    return NetDef("alexnet", "synimagenet", (32, 32, 3), 20, g, train_steps=1100)


def nin() -> NetDef:
    """Network-in-Network: 4 blocks of conv+2x(1x1 cccp), global avg pool."""
    g = [
        LayerGroup("L1", "conv", [Conv(32, 5), ReLU()]),
        LayerGroup("L2", "conv", [Conv(24, 1, name="cccp"), ReLU()]),
        LayerGroup("L3", "conv", [Conv(16, 1, name="cccp"), ReLU(), MaxPool(3, 2)]),
        LayerGroup("L4", "conv", [Conv(48, 5), ReLU()]),
        LayerGroup("L5", "conv", [Conv(32, 1, name="cccp"), ReLU()]),
        LayerGroup("L6", "conv", [Conv(32, 1, name="cccp"), ReLU(), MaxPool(3, 2)]),
        LayerGroup("L7", "conv", [Conv(48, 3), ReLU()]),
        LayerGroup("L8", "conv", [Conv(48, 1, name="cccp"), ReLU()]),
        LayerGroup("L9", "conv", [Conv(32, 1, name="cccp"), ReLU(), MaxPool(3, 2), Dropout()]),
        LayerGroup("L10", "conv", [Conv(64, 3), ReLU()]),
        LayerGroup("L11", "conv", [Conv(48, 1, name="cccp"), ReLU()]),
        LayerGroup("L12", "conv", [Conv(20, 1, name="cccp"), ReLU(), GlobalAvgPool()]),
    ]
    return NetDef("nin", "synimagenet", (32, 32, 3), 20, g, train_steps=1100)


def googlenet() -> NetDef:
    """GoogLeNet: 2 conv layers + 9 inception modules (+ classifier in L11)."""
    g = [
        LayerGroup("L1", "conv", [Conv(16, 3), ReLU(), MaxPool(3, 2)]),
        LayerGroup("L2", "conv", [Conv(32, 3), ReLU(), MaxPool(3, 2)]),
        LayerGroup("L3", "inception", [Inception(8, 8, 16, 4, 8, 8, name="i3a")]),
        LayerGroup(
            "L4", "inception", [Inception(16, 16, 24, 4, 8, 8, name="i3b"), MaxPool(3, 2)]
        ),
        LayerGroup("L5", "inception", [Inception(16, 12, 24, 4, 8, 8, name="i4a")]),
        LayerGroup("L6", "inception", [Inception(16, 12, 24, 4, 8, 8, name="i4b")]),
        LayerGroup("L7", "inception", [Inception(16, 12, 24, 4, 8, 8, name="i4c")]),
        LayerGroup("L8", "inception", [Inception(16, 12, 24, 4, 8, 8, name="i4d")]),
        LayerGroup(
            "L9", "inception", [Inception(24, 16, 32, 6, 12, 12, name="i4e"), MaxPool(3, 2)]
        ),
        LayerGroup("L10", "inception", [Inception(24, 16, 32, 6, 12, 12, name="i5a")]),
        LayerGroup(
            "L11",
            "inception",
            [Inception(24, 16, 32, 6, 12, 12, name="i5b"), GlobalAvgPool(), Dense(20)],
        ),
    ]
    return NetDef("googlenet", "synimagenet", (32, 32, 3), 20, g, train_steps=1200)


NETS = {
    "lenet": lenet,
    "convnet": convnet,
    "alexnet": alexnet,
    "nin": nin,
    "googlenet": googlenet,
}

# Order used throughout the repo (reports, manifests, reproduction).
NET_ORDER = ["lenet", "convnet", "alexnet", "nin", "googlenet"]


def get(name: str) -> NetDef:
    return NETS[name]()
