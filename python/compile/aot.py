"""AOT build driver: train → lower → serialize artifacts for the rust L3.

Per network this emits into ``artifacts/``:

    <net>.hlo.txt        — HLO text of forward(params…, images, wq, dq)
    <net>.weights.ntf    — trained parameters (manifest order)
    <net>.dataset.ntf    — eval images (N,H,W,C f32) + labels (N i32)
    <net>.manifest.json  — everything the rust side needs: layer metadata
                           (elems/weights/MACs for the Fig-4 traffic
                           model), parameter names/shapes, baseline top-1,
                           batch size, file names
    alexnet_stages.hlo.txt — Fig-1 variant with per-stage quantization
                           inputs for AlexNet layer 2

plus once: ``golden_quant.ntf`` (cross-language quantizer lock vectors)
and ``index.json`` (build metadata + net list).

HLO **text** is the interchange format (NOT serialized protos): jax ≥ 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Run via ``make artifacts`` — a no-op when inputs are unchanged.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datasets, layers, model, ntf, train
from .nets import NET_ORDER, NetDef, get

BATCH = 64
STAGE_NET = "alexnet"
STAGE_GROUP = 1  # paper Fig 1: AlexNet's *second* convolution layer


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_forward(net: NetDef, params, *, stage_group: int | None = None) -> str:
    """Lower forward(params…, images, wq, dq[, sq]) at batch=BATCH to HLO text."""
    L = len(net.groups)
    img_spec = jax.ShapeDtypeStruct((BATCH, *net.input_shape), jnp.float32)
    cfg_spec = jax.ShapeDtypeStruct((L, 2), jnp.float32)
    param_specs = [jax.ShapeDtypeStruct(p.shape, jnp.float32) for p in params]
    fwd = model.make_forward(net, use_pallas=True, stage_group=stage_group)

    if stage_group is None:

        def fn(*args):
            ps = list(args[:-3])
            images, wq, dq = args[-3:]
            return (fwd(ps, images, wq, dq),)

        specs = [*param_specs, img_spec, cfg_spec, cfg_spec]
    else:
        n_stages = len(net.groups[stage_group].ops)
        sq_spec = jax.ShapeDtypeStruct((n_stages, 2), jnp.float32)

        def fn(*args):
            ps = list(args[:-4])
            images, wq, dq, sq = args[-4:]
            return (fwd(ps, images, wq, dq, sq),)

        specs = [*param_specs, img_spec, cfg_spec, cfg_spec, sq_spec]

    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def build_manifest(net: NetDef, names, params, info, files: dict) -> dict:
    meta, out_shape = layers.shape_walk(net.groups, net.input_shape)
    return {
        "name": net.name,
        "dataset": net.dataset,
        "num_classes": net.num_classes,
        "input_shape": list(net.input_shape),
        "batch": BATCH,
        "n_eval": net.n_eval,
        "baseline_top1": info["top1"],
        "train": {
            "steps": info["steps"],
            "final_loss": info["final_loss"],
            "seconds": round(info["train_seconds"], 2),
        },
        "layers": meta,
        "params": [
            {"name": n, "shape": list(p.shape)} for n, p in zip(names, params)
        ],
        "files": files,
        "stage_variant": (
            {
                "hlo": files.get("stages_hlo"),
                "group_index": STAGE_GROUP,
                "n_stages": len(net.groups[STAGE_GROUP].ops),
                "stage_names": [op.name for op in net.groups[STAGE_GROUP].ops],
            }
            if net.name == STAGE_NET
            else None
        ),
    }


KERNEL_N = 65536  # element count of the standalone kernel executables


def write_kernel_artifacts(out_dir: str) -> None:
    """Standalone L1-kernel executables (beyond the in-net use):

    kernel_rne.hlo.txt — quantize_fixed(x[N], cfg[2]) -> q[N]
    kernel_sr.hlo.txt  — quantize_stochastic(x[N], cfg[2], u[N]) -> q[N]

    Used by the rust side for (a) device-vs-host bit-parity tests on the
    *compiled* kernel (closing the loop the golden vectors only test via
    the oracle), (b) kernel throughput benches, and (c) the stochastic-
    vs-RNE rounding study (paper §4 future work; Gupta et al. 2015).
    """
    from .kernels import fixedpoint as fp

    x = jax.ShapeDtypeStruct((KERNEL_N,), jnp.float32)
    cfg = jax.ShapeDtypeStruct((2,), jnp.float32)

    lowered = jax.jit(lambda x, c: (fp.quantize_fixed(x, c),)).lower(x, cfg)
    with open(os.path.join(out_dir, "kernel_rne.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    lowered = jax.jit(lambda x, c, u: (fp.quantize_stochastic(x, c, u),)).lower(x, cfg, x)
    with open(os.path.join(out_dir, "kernel_sr.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))


def write_golden_quant(out_dir: str, seed: int = 123) -> None:
    """Cross-language lock vectors: x plus q(x) for a grid of (I, F)."""
    rng = np.random.RandomState(seed)
    x = np.concatenate(
        [
            rng.randn(512).astype(np.float32) * 8.0,
            rng.uniform(-1, 1, 256).astype(np.float32),
            np.array(
                [0.0, -0.0, 0.5, -0.5, 0.25, -0.25, 1.5, 2.5, -1.5, -2.5, 1e6, -1e6, 1e-6],
                np.float32,
            ),
        ]
    )
    tensors: dict[str, np.ndarray] = {"x": x}
    from .kernels import ref

    for i in [0, 1, 2, 4, 8, 12, 16]:
        for f in [0, 1, 2, 4, 8, 12]:
            q = np.asarray(ref.quantize_ref(x, float(i), float(f)))
            tensors[f"q_{i}_{f}"] = q
    tensors["q_sentinel"] = np.asarray(ref.quantize_ref(x, -1.0, 0.0))
    ntf.write(os.path.join(out_dir, "golden_quant.ntf"), tensors)


def load_or_train(net: NetDef, out_dir: str, retrain: bool):
    """Reuse previously-trained weights when the artifacts already carry
    them (training is the expensive build phase; re-lowering after a
    kernel/graph change should not repeat it). `--retrain` forces a fresh
    run. The eval split is regenerated deterministically either way.
    """
    import jax.numpy as jnp

    from . import datasets as ds

    wpath = os.path.join(out_dir, f"{net.name}.weights.ntf")
    mpath = os.path.join(out_dir, f"{net.name}.manifest.json")
    if not retrain and os.path.exists(wpath) and os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        if old.get("n_eval") == net.n_eval and old.get("train", {}).get("steps") == net.train_steps:
            tensors = ntf.read(wpath)
            names, _ = layers.init_params(net.groups, net.input_shape, seed=77)
            if all(n in tensors for n in names):
                print(f"  reusing trained weights from {wpath}")
                params = [jnp.asarray(tensors[n]) for n in names]
                _, _, ex, ey = ds.load(net.dataset, 1, net.n_eval, seed=0)
                info = {
                    "top1": old["baseline_top1"],
                    "final_loss": old["train"]["final_loss"],
                    "train_seconds": 0.0,
                    "steps": old["train"]["steps"],
                }
                return names, params, (ex, ey), info
    return train.train(net)


def build_net(net: NetDef, out_dir: str, quick: bool, retrain: bool = False) -> dict:
    if quick:
        net.train_steps = max(60, net.train_steps // 10)
        net.n_eval = 256
    print(f"== {net.name} ({net.dataset}) ==")
    names, params, (ex, ey), info = load_or_train(net, out_dir, retrain)

    files = {
        "hlo": f"{net.name}.hlo.txt",
        "weights": f"{net.name}.weights.ntf",
        "dataset": f"{net.name}.dataset.ntf",
    }
    t0 = time.time()
    hlo = lower_forward(net, params)
    print(f"  lowered HLO: {len(hlo)/1e6:.2f} MB in {time.time()-t0:.1f}s")
    with open(os.path.join(out_dir, files["hlo"]), "w") as f:
        f.write(hlo)

    if net.name == STAGE_NET:
        files["stages_hlo"] = f"{net.name}_stages.hlo.txt"
        hlo_s = lower_forward(net, params, stage_group=STAGE_GROUP)
        with open(os.path.join(out_dir, files["stages_hlo"]), "w") as f:
            f.write(hlo_s)

    ntf.write(
        os.path.join(out_dir, files["weights"]),
        {n: np.asarray(p) for n, p in zip(names, params)},
    )
    ntf.write(
        os.path.join(out_dir, files["dataset"]),
        {"images": ex.astype(np.float32), "labels": ey.astype(np.int32)},
    )
    manifest = build_manifest(net, names, params, info, files)
    with open(os.path.join(out_dir, f"{net.name}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return {"name": net.name, "baseline_top1": info["top1"]}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--nets", default=",".join(NET_ORDER))
    ap.add_argument(
        "--quick", action="store_true", help="tiny training run (CI / smoke only)"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    t0 = time.time()
    entries = []
    for name in args.nets.split(","):
        entries.append(build_net(get(name), args.out_dir, args.quick))
    write_golden_quant(args.out_dir)
    write_kernel_artifacts(args.out_dir)
    index = {
        "nets": entries,
        "batch": BATCH,
        "kernel_n": KERNEL_N,
        "quick": args.quick,
        "jax_version": jax.__version__,
        "built_unix": int(time.time()),
        "build_seconds": round(time.time() - t0, 1),
    }
    with open(os.path.join(args.out_dir, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    print(f"artifacts complete in {index['build_seconds']}s -> {args.out_dir}")


if __name__ == "__main__":
    main()
