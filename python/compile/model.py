"""Layer-2 entry point: the quantized forward pass that gets AOT-lowered.

`forward` is the function whose lowered HLO the rust coordinator executes.
Its signature is designed so that ONE compiled executable serves every
precision configuration (precision arrives as runtime operands):

    forward(params..., images, wq, dq) -> (logits,)

  * ``params...`` — the network's flat weight list (manifest order);
  * ``images``    — (B, H, W, C) fp32 batch (fixed B at lowering time);
  * ``wq``        — (L, 2) fp32 per-layer weight (I, F), I<0 = fp32;
  * ``dq``        — (L, 2) fp32 per-layer output-data (I, F);
  * for the Fig-1 stage-granularity variant, an extra
    ``sq`` — (S, 2) per-stage config for one designated group.

Quantization uses the L1 Pallas kernel so it lowers into the same HLO
module (kernels/fixedpoint.py; interpret=True — see DESIGN.md).
"""

from __future__ import annotations

import jax.numpy as jnp

from . import layers
from .kernels import fixedpoint
from .nets import NetDef


def make_forward(net: NetDef, *, use_pallas: bool = True, stage_group: int | None = None):
    """Build the jit-able forward for `net`.

    Returns fn(params_list, images, wq, dq[, sq]) -> logits.
    """

    def quantize(x, cfg):
        return fixedpoint.quantize(x, cfg, use_pallas=use_pallas)

    if stage_group is None:

        def forward(params, images, wq, dq):
            return layers.apply(net.groups, params, images, wq, dq, quantize)

        return forward

    def forward_stages(params, images, wq, dq, sq):
        return layers.apply(
            net.groups,
            params,
            images,
            wq,
            dq,
            quantize,
            stage_group=stage_group,
            stage_cfg=sq,
        )

    return forward_stages


def passthrough_cfg(n_layers: int) -> jnp.ndarray:
    """(L, 2) all-sentinel config: fp32 baseline."""
    cfg = jnp.full((n_layers, 2), -1.0, jnp.float32)
    return cfg


def uniform_cfg(n_layers: int, ibits: float, fbits: float) -> jnp.ndarray:
    return jnp.tile(jnp.array([[ibits, fbits]], jnp.float32), (n_layers, 1))


def top1_accuracy(logits, labels) -> float:
    pred = jnp.argmax(logits, axis=-1)
    return float(jnp.mean((pred == labels).astype(jnp.float32)))
