//! End-to-end inference benchmarks: per-batch latency of every network
//! at fp32 and quantized on **both pure-Rust backends** (reference vs
//! fast), plus the eval-cache hit path. The emitted `BENCH_*.json` is
//! the per-commit record of the reference-vs-fast speedup — the perf
//! trajectory CI archives.
//!
//! The keyed-infer A/B and the coordinator section run on the backend
//! selected by `QBOUND_BACKEND` (default: reference), so the same bench
//! binary also measures the PJRT path on machines that have it. The
//! fast backend's thread budget comes from `QBOUND_THREADS`.

use qbound::backend::fast::FastBackend;
use qbound::backend::kernels;
use qbound::backend::{Backend, BackendKind, NetExecutor, Variant};
use qbound::coordinator::{Coordinator, EvalJob};
use qbound::eval::{Dataset, Evaluator};
use qbound::memory::StorageMode;
use qbound::nets::{ArtifactIndex, NetManifest};
use qbound::quant::QFormat;
use qbound::search::space::PrecisionConfig;

fn main() {
    qbound::util::init_logging();
    let dir = qbound::testkit::ensure_artifacts();
    let index = ArtifactIndex::load(&dir).unwrap();
    let env_kind = BackendKind::from_env().unwrap();
    let mut suite = qbound::benchkit::BenchSuite::new(
        "engine inference per batch, reference vs fast + eval cache",
    );

    // Per-network, per-backend infer throughput: the reference-vs-fast
    // comparison the acceptance gate reads from the JSON.
    let kinds = [BackendKind::Reference, BackendKind::Fast];
    for net in &index.nets {
        let m = NetManifest::load(&dir, net).unwrap();
        let dataset = Dataset::load(&m).unwrap();
        let nl = m.n_layers();
        let images = dataset.batch_images(0, m.batch).to_vec();
        let fp32 = PrecisionConfig::fp32(nl);
        let quant = PrecisionConfig::uniform(nl, QFormat::new(1, 8), QFormat::new(10, 2));

        for kind in kinds {
            let backend = kind.create().unwrap();
            let t0 = std::time::Instant::now();
            let mut exec = backend.load(&m, Variant::Standard).unwrap();
            suite.record_once(&format!("{net} [{}]: load", kind.label()), t0.elapsed());
            for (label, cfg) in [("fp32", &fp32), ("q(1.8/10.2)", &quant)] {
                let wq = cfg.wire_wq();
                let dq = cfg.wire_dq();
                suite.bench_elems(
                    &format!("{net} [{}]: infer batch {} {label}", kind.label(), m.batch),
                    m.batch as f64,
                    || {
                        std::hint::black_box(exec.infer(&images, &wq, &dq, None).unwrap());
                    },
                );
            }
        }

        // §Perf A/B: keyed (backend may keep the batch resident) vs
        // plain, on the env-selected backend.
        let backend = env_kind.create().unwrap();
        let mut exec = backend.load(&m, Variant::Standard).unwrap();
        let wq = quant.wire_wq();
        let dq = quant.wire_dq();
        suite.bench_elems(
            &format!("{net} [{}]: infer batch {} q, keyed images", env_kind.label(), m.batch),
            m.batch as f64,
            || {
                std::hint::black_box(exec.infer_keyed(0, &images, &wq, &dq, None).unwrap());
            },
        );

        // Packed-vs-f32 storage ratio per kernel variant: the archived
        // `ratios` rows CI reads to check the SIMD decode narrows the
        // packed gap relative to the scalar kernels on the same host.
        let auto = kernels::active_kind();
        for kernel in kernels::available() {
            kernels::force(kernel);
            let mut means = [0.0f64; 2];
            for (slot, storage) in
                [StorageMode::F32, StorageMode::Packed].into_iter().enumerate()
            {
                let backend = FastBackend::with_options(1, storage);
                let mut exec = backend.load(&m, Variant::Standard).unwrap();
                let res = suite.bench_elems(
                    &format!(
                        "{net} [fast/{}]: infer batch {} q, storage {}",
                        kernel.label(),
                        m.batch,
                        storage.label()
                    ),
                    m.batch as f64,
                    || {
                        std::hint::black_box(exec.infer(&images, &wq, &dq, None).unwrap());
                    },
                );
                means[slot] = res.stats.mean.as_secs_f64();
            }
            suite.record_ratio(net, kernel.label(), means[1] / means[0]);
        }
        kernels::force(auto);
    }

    // Evaluator memo-cache hit path (must be ~ns — the search leans on it).
    let m = NetManifest::load(&dir, &index.nets[0]).unwrap();
    let backend = env_kind.create().unwrap();
    let mut ev = Evaluator::new(backend.as_ref(), &m).unwrap();
    let cfg = PrecisionConfig::fp32(m.n_layers());
    ev.accuracy(&cfg, 0).unwrap(); // warm (miss)
    suite.bench("evaluator cache hit", || {
        std::hint::black_box(ev.accuracy(&cfg, 0).unwrap());
    });

    // Coordinator dispatch overhead on a fully-cached burst.
    let mut coord = Coordinator::with_backend(&dir, 2, env_kind).unwrap();
    let jobs: Vec<EvalJob> = (0..64)
        .map(|_| EvalJob { net: index.nets[0].clone(), cfg: cfg.clone(), n_images: 128 })
        .collect();
    coord.eval_batch(&jobs[..1]).unwrap(); // warm
    suite.bench_elems("coordinator cached burst of 64", 64.0, || {
        std::hint::black_box(coord.eval_batch(&jobs).unwrap());
    });

    suite.finish();
}
