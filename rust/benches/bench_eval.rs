//! End-to-end inference benchmarks: per-batch latency of every network
//! through the PJRT runtime at fp32 and quantized, plus the eval-cache
//! hit path. These are the numbers every sweep/search cost estimate in
//! EXPERIMENTS.md §Perf is built from.

use qbound::benchkit::BenchSuite;
use qbound::coordinator::{Coordinator, EvalJob};
use qbound::eval::{Dataset, Evaluator};
use qbound::nets::{ArtifactIndex, NetManifest};
use qbound::quant::QFormat;
use qbound::runtime::{Session, Variant};
use qbound::search::space::PrecisionConfig;

fn main() {
    qbound::util::init_logging();
    let dir = qbound::util::artifacts_dir().expect("run `make artifacts` first");
    let index = ArtifactIndex::load(&dir).unwrap();
    let mut suite = BenchSuite::new("engine inference (per batch) + eval cache");
    let session = Session::cpu().unwrap();

    for net in &index.nets {
        let m = NetManifest::load(&dir, net).unwrap();
        let t0 = std::time::Instant::now();
        let engine = session.load_engine(&m, Variant::Standard).unwrap();
        suite.record_once(&format!("{net}: load+compile"), t0.elapsed());
        let dataset = Dataset::load(&m).unwrap();
        let nl = m.n_layers();
        let images = dataset.batch_images(0, m.batch).to_vec();

        let fp32 = PrecisionConfig::fp32(nl);
        let quant = PrecisionConfig::uniform(nl, QFormat::new(1, 8), QFormat::new(10, 2));
        for (label, cfg) in [("fp32", &fp32), ("q(1.8/10.2)", &quant)] {
            let wq = cfg.wire_wq();
            let dq = cfg.wire_dq();
            suite.bench_elems(
                &format!("{net}: infer batch {} {label}", m.batch),
                m.batch as f64,
                || {
                    std::hint::black_box(
                        engine.infer(&session, &images, &wq, &dq, None).unwrap(),
                    );
                },
            );
        }
        // §Perf A/B: per-call image upload vs device-resident batch.
        let img_buf = engine.upload_images(&session, &images).unwrap();
        let wq = quant.wire_wq();
        let dq = quant.wire_dq();
        suite.bench_elems(
            &format!("{net}: infer batch {} q, preloaded images", m.batch),
            m.batch as f64,
            || {
                std::hint::black_box(
                    engine.infer_prepared(&session, &img_buf, &wq, &dq, None).unwrap(),
                );
            },
        );
    }

    // Evaluator memo-cache hit path (must be ~ns — the search leans on it).
    let m = NetManifest::load(&dir, &index.nets[0]).unwrap();
    let mut ev = Evaluator::new(&session, &m).unwrap();
    let cfg = PrecisionConfig::fp32(m.n_layers());
    ev.accuracy(&session, &cfg, 0).unwrap(); // warm (miss)
    suite.bench("evaluator cache hit", || {
        std::hint::black_box(ev.accuracy(&session, &cfg, 0).unwrap());
    });

    // Coordinator dispatch overhead on a fully-cached burst.
    let mut coord = Coordinator::new(&dir, 2).unwrap();
    let jobs: Vec<EvalJob> = (0..64)
        .map(|_| EvalJob { net: index.nets[0].clone(), cfg: cfg.clone(), n_images: 128 })
        .collect();
    coord.eval_batch(&jobs[..1]).unwrap(); // warm
    suite.bench_elems("coordinator cached burst of 64", 64.0, || {
        std::hint::black_box(coord.eval_batch(&jobs).unwrap());
    });

    suite.finish();
}
