//! L3 hot-loop micro-benchmarks: the host-side quantizer, top-1 scoring,
//! traffic-model evaluation, and NTF parsing throughput.

use qbound::benchkit::BenchSuite;
use qbound::eval::top1;
use qbound::prng::Xoshiro256pp;
use qbound::quant::QFormat;
use qbound::search::space::PrecisionConfig;
use qbound::tensor::{ntf, Tensor};
use qbound::traffic::{self, Mode};

fn main() {
    qbound::util::init_logging();
    let mut suite = BenchSuite::new("quantize + host hot paths");
    let mut rng = Xoshiro256pp::new(1);

    // Host quantizer over 1M floats (the rust mirror of the L1 kernel).
    let n = 1 << 20;
    let xs: Vec<f32> = (0..n).map(|_| rng.uniform_f32(-16.0, 16.0)).collect();
    let fmt = QFormat::new(8, 4);
    let mut buf = xs.clone();
    suite.bench_bytes("quantize_slice 1M f32 (Q8.4)", (n * 4) as f64, || {
        buf.copy_from_slice(&xs);
        fmt.quantize_slice(&mut buf);
        std::hint::black_box(&buf);
    });

    // top-1 scoring of a logits block (64 x 20).
    let logits: Vec<f32> = (0..64 * 20).map(|_| rng.uniform_f32(-4.0, 4.0)).collect();
    let labels: Vec<i32> = (0..64).map(|_| rng.below(20) as i32).collect();
    suite.bench_elems("top1 64x20 logits", 64.0, || {
        std::hint::black_box(top1(&logits, &labels, 20));
    });

    // Traffic-model evaluation for a 12-layer manifest-shaped config.
    let dir = qbound::testkit::ensure_artifacts();
    let m = qbound::nets::NetManifest::load(&dir, "nin").expect("nin manifest");
    let cfg = PrecisionConfig::uniform(m.n_layers(), QFormat::new(1, 7), QFormat::new(9, 0));
    suite.bench("traffic_ratio nin (12 layers)", || {
        std::hint::black_box(traffic::traffic_ratio(&m, Mode::Batch(64), &cfg));
    });

    // Descent-neighbour generation (search inner loop).
    let opts = qbound::search::space::DescentOptions::default();
    let big = PrecisionConfig::uniform(12, QFormat::new(1, 8), QFormat::new(11, 2));
    suite.bench("descent_neighbours 12 layers", || {
        std::hint::black_box(big.descent_neighbours(&opts));
    });

    // NTF round-trip of a weights-sized container.
    let mut tensors = std::collections::BTreeMap::new();
    tensors.insert(
        "w".to_string(),
        Tensor::from_f32(vec![64, 1024], (0..64 * 1024).map(|i| i as f32).collect()).unwrap(),
    );
    let bytes = ntf::write_bytes(&tensors).unwrap();
    suite.bench_bytes("ntf parse 256 KiB", bytes.len() as f64, || {
        std::hint::black_box(ntf::read_bytes(&bytes).unwrap());
    });

    suite.finish();
}
