//! Packed-storage benchmarks: pack/unpack bandwidth across widths vs
//! the plain `quantize_slice` baseline, plus end-to-end infer latency
//! under `--storage packed` vs default f32 storage on the fast backend,
//! swept across every GEMM kernel variant the host supports. The
//! archived JSON tracks the cost of making the reduced-width
//! representation the thing that actually lives in memory, and the
//! per-variant `ratios` rows track how much of that cost the SIMD
//! decode path buys back.

use qbound::backend::fast::FastBackend;
use qbound::backend::kernels;
use qbound::backend::{Backend, NetExecutor, Variant};
use qbound::eval::Dataset;
use qbound::memory::{PackedBuf, StorageMode};
use qbound::nets::NetManifest;
use qbound::quant::QFormat;
use qbound::search::space::PrecisionConfig;

fn main() {
    qbound::util::init_logging();
    let dir = qbound::testkit::ensure_artifacts();
    let mut suite = qbound::benchkit::BenchSuite::new("packed storage pack unpack + infer");

    // Kernel bandwidth: 256k activations through pack+unpack per width,
    // against the in-f32 quantize baseline.
    let n = 1 << 18;
    let mut rng = qbound::prng::Xoshiro256pp::new(11);
    let xs: Vec<f32> = (0..n).map(|_| rng.uniform_f32(-8.0, 8.0)).collect();
    let bytes = (n * 4) as f64;
    let mut base = xs.clone();
    suite.bench_bytes("quantize_slice q(6.2) baseline", bytes, || {
        base.copy_from_slice(&xs);
        QFormat::new(6, 2).quantize_slice(&mut base);
        std::hint::black_box(&base);
    });
    for fmt in [
        QFormat::new(2, 2),  // 4-bit
        QFormat::new(6, 2),  // 8-bit
        QFormat::new(9, 3),  // 12-bit
        QFormat::new(12, 4), // 16-bit
        QFormat::new(12, 12), // 24-bit
        QFormat::FP32,       // word-aligned fallback
    ] {
        let mut buf = PackedBuf::default();
        let mut work = xs.clone();
        suite.bench_bytes(
            &format!("pack+unpack roundtrip {fmt} ({} bits)", buf_width(fmt)),
            bytes,
            || {
                work.copy_from_slice(&xs);
                buf.roundtrip(fmt, &mut work);
                std::hint::black_box(&work);
            },
        );
    }

    // End-to-end: fast-backend batch infer, f32 vs packed storage,
    // swept across every kernel variant the host supports. The ratio
    // rows archive how close the packed path sits to f32 per variant
    // (the SIMD decode should narrow the gap vs the scalar row).
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let dataset = Dataset::load(&m).unwrap();
    let images = dataset.batch_images(0, m.batch).to_vec();
    let cfg = PrecisionConfig::uniform(m.n_layers(), QFormat::new(1, 8), QFormat::new(10, 2));
    let (wq, dq) = (cfg.wire_wq(), cfg.wire_dq());
    let auto = kernels::active_kind();
    for kind in kernels::available() {
        kernels::force(kind);
        let mut means = [0.0f64; 2];
        for (slot, storage) in [StorageMode::F32, StorageMode::Packed].into_iter().enumerate() {
            let backend = FastBackend::with_options(2, storage);
            let mut exec = backend.load(&m, Variant::Standard).unwrap();
            let res = suite.bench_elems(
                &format!(
                    "lenet [fast/{}]: infer batch {} q, storage {}",
                    kind.label(),
                    m.batch,
                    storage.label()
                ),
                m.batch as f64,
                || {
                    std::hint::black_box(exec.infer(&images, &wq, &dq, None).unwrap());
                },
            );
            means[slot] = res.stats.mean.as_secs_f64();
        }
        suite.record_ratio("lenet", kind.label(), means[1] / means[0]);
    }
    kernels::force(auto);

    suite.finish();
}

fn buf_width(fmt: QFormat) -> u32 {
    qbound::memory::storage_width(fmt)
}
