//! Coordinator benchmarks: dispatch overhead on the cached path, uncached
//! burst wall-time vs worker count, and dedup behaviour — the L3
//! contribution's own performance characteristics.
//!
//! Note: this testbed is single-core, so multi-worker speedup is bounded
//! by XLA's own CPU usage; the interesting numbers are the µs-scale
//! dispatch overheads (L3 must never be the bottleneck — DESIGN.md §8).

use std::time::Instant;

use qbound::benchkit::BenchSuite;
use qbound::coordinator::{Coordinator, EvalJob};
use qbound::quant::QFormat;
use qbound::search::space::PrecisionConfig;

/// Distinct-by-construction configs: a counter spread over a product
/// space far larger than any iteration count here.
fn unique_cfg(counter: &mut u32) -> PrecisionConfig {
    let c = *counter;
    *counter += 1;
    let mut cfg = PrecisionConfig::uniform(
        4,
        QFormat::new(1, 2 + (c % 13) as i8),
        QFormat::new(2 + ((c / 13) % 13) as i8, (c / 169 % 7) as i8),
    );
    cfg.dq[(c % 4) as usize].ibits += 1;
    cfg
}

fn main() {
    qbound::util::init_logging();
    let dir = qbound::testkit::ensure_artifacts();
    let mut suite = BenchSuite::new("coordinator (lenet, 128-image evals)");
    let net = "lenet";
    let n_images = 128;
    let mut counter = 0u32;

    // (a) uncached burst of 24 unique evals, 1 vs 2 workers (wall once).
    for workers in [1usize, 2] {
        let mut coord = Coordinator::new(&dir, workers).unwrap();
        let warm: Vec<EvalJob> = (0..workers)
            .map(|_| EvalJob {
                net: net.into(),
                cfg: PrecisionConfig::fp32(4),
                n_images,
            })
            .collect();
        coord.eval_batch(&warm).unwrap(); // compile off the clock
        let jobs: Vec<EvalJob> = (0..24)
            .map(|_| EvalJob { net: net.into(), cfg: unique_cfg(&mut counter), n_images })
            .collect();
        let t0 = Instant::now();
        coord.eval_batch(&jobs).unwrap();
        let wall = t0.elapsed();
        suite.record_once(&format!("24 unique evals, {workers} worker(s)"), wall);
        let busy = coord.busy_time().as_secs_f64();
        eprintln!(
            "    utilization {:.0}% (busy {:.2}s / wall {:.2}s x {workers})",
            100.0 * busy / (wall.as_secs_f64() * workers as f64),
            busy,
            wall.as_secs_f64()
        );
    }

    // (b) dedup: one burst of 32 *identical* fresh jobs ≈ cost of 1 eval.
    let mut coord = Coordinator::new(&dir, 1).unwrap();
    coord
        .eval_one(EvalJob { net: net.into(), cfg: PrecisionConfig::fp32(4), n_images })
        .unwrap();
    let single = {
        let t0 = Instant::now();
        coord
            .eval_one(EvalJob { net: net.into(), cfg: unique_cfg(&mut counter), n_images })
            .unwrap();
        t0.elapsed()
    };
    suite.record_once("1 unique eval (reference)", single);
    let dup_jobs: Vec<EvalJob> = {
        let cfg = unique_cfg(&mut counter);
        (0..32).map(|_| EvalJob { net: net.into(), cfg: cfg.clone(), n_images }).collect()
    };
    let t0 = Instant::now();
    coord.eval_batch(&dup_jobs).unwrap();
    suite.record_once("32 identical jobs (dedup) ≈ 1 eval", t0.elapsed());
    let s = coord.stats();
    eprintln!(
        "    stats: submitted {} executed {} deduped {} cache hits {}",
        s.submitted, s.executed, s.deduped, s.cache_hits
    );

    // (c) cached-path dispatch overhead: the same 32 jobs again must cost µs.
    suite.bench_elems("cached burst of 32 (dispatch overhead)", 32.0, || {
        std::hint::black_box(coord.eval_batch(&dup_jobs).unwrap());
    });

    suite.finish();
}
