//! One bench per paper table/figure: times each reproduction harness on a
//! reduced evaluation subset and prints its headline rows. `cargo bench`
//! therefore regenerates (a small-n version of) every artifact of the
//! paper's evaluation section; `qbound repro all` is the full-size run.

use std::time::Instant;

use qbound::benchkit::BenchSuite;
use qbound::repro::{self, ReproCtx};

fn main() {
    qbound::util::init_logging();
    qbound::testkit::ensure_artifacts();
    let out = std::path::PathBuf::from("reports/bench");
    // Small subset + 4 workers keeps the full suite in benchable territory.
    let n_images = std::env::var("QBOUND_BENCH_IMAGES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128);
    let mut ctx = ReproCtx::new(&out, 0, n_images).expect("artifacts present");
    let mut suite = BenchSuite::new(&format!("paper reproduction suite (n_images={n_images})"));

    let t = Instant::now();
    repro::table1(&mut ctx).unwrap();
    suite.record_once("table1: nets + baselines", t.elapsed());

    let t = Instant::now();
    repro::fig4(&mut ctx).unwrap();
    suite.record_once("fig4: traffic model", t.elapsed());

    let t = Instant::now();
    repro::fig2(&mut ctx).unwrap();
    suite.record_once("fig2: uniform sweeps", t.elapsed());

    let t = Instant::now();
    repro::fig1(&mut ctx).unwrap();
    suite.record_once("fig1: stage sweep", t.elapsed());

    // The per-layer sweeps and the greedy exploration are quadratic-ish
    // in layer count; smoke runs keep them to the small nets so the CI
    // job stays in budget. QBOUND_BENCH_FULL=1 restores the full suite.
    if std::env::var_os("QBOUND_BENCH_FULL").is_none() {
        let keep = ["lenet", "convnet"];
        ctx.index.nets.retain(|n| keep.contains(&n.as_str()));
        ctx.manifests.retain(|m| keep.contains(&m.name.as_str()));
        eprintln!("(smoke mode: fig3/fig5 on {keep:?} only; QBOUND_BENCH_FULL=1 for all nets)");
    }

    let t = Instant::now();
    repro::fig3(&mut ctx).unwrap();
    suite.record_once("fig3: per-layer sweeps", t.elapsed());

    let t = Instant::now();
    repro::fig5_table2(&mut ctx).unwrap();
    suite.record_once("fig5+table2: greedy exploration", t.elapsed());

    let stats = ctx.coord.stats();
    eprintln!(
        "coordinator totals: {} submitted, {} executed, {} cache hits, {} deduped",
        stats.submitted, stats.executed, stats.cache_hits, stats.deduped
    );
    suite.finish();
}
