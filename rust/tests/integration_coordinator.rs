//! Coordinator integration: dedup, cross-burst caching, multi-worker
//! correctness, order preservation, stream replay.

use std::time::Duration;

use qbound::coordinator::{Coordinator, EvalJob};
use qbound::quant::QFormat;
use qbound::search::space::PrecisionConfig;
use qbound::testkit;

fn coord(workers: usize) -> Coordinator {
    Coordinator::new(&testkit::ensure_artifacts(), workers).unwrap()
}

fn job(f: i8, n: usize) -> EvalJob {
    EvalJob {
        net: "lenet".into(),
        cfg: PrecisionConfig::uniform(4, QFormat::new(1, f), QFormat::new(9, 2)),
        n_images: n,
    }
}

#[test]
fn identical_jobs_deduped_within_burst() {
    let mut c = coord(1);
    let jobs = vec![job(8, 128); 8];
    let res = c.eval_batch(&jobs).unwrap();
    assert!(res.windows(2).all(|w| w[0] == w[1]));
    let s = c.stats();
    assert_eq!(s.submitted, 8);
    assert_eq!(s.executed, 1, "dedup failed: {s:?}");
    assert_eq!(s.deduped, 7);
}

#[test]
fn cache_hits_across_bursts() {
    let mut c = coord(1);
    let a = c.eval_one(job(7, 128)).unwrap();
    let before = c.stats().executed;
    let b = c.eval_one(job(7, 128)).unwrap();
    assert_eq!(a, b);
    assert_eq!(c.stats().executed, before, "second burst must be pure cache");
    assert!(c.stats().cache_hits >= 1);
}

#[test]
fn multi_worker_results_match_single_worker() {
    let mut c1 = coord(1);
    let mut c2 = coord(2);
    let jobs: Vec<EvalJob> = (2..10).map(|f| job(f, 128)).collect();
    let r1 = c1.eval_batch(&jobs).unwrap();
    let r2 = c2.eval_batch(&jobs).unwrap();
    assert_eq!(r1, r2, "determinism across worker counts");
}

#[test]
fn results_positionally_aligned() {
    let mut c = coord(2);
    // interleave two distinct configs; alignment must hold
    let jobs: Vec<EvalJob> = (0..10).map(|i| job(if i % 2 == 0 { 3 } else { 9 }, 128)).collect();
    let res = c.eval_batch(&jobs).unwrap();
    let a = res[0];
    let b = res[1];
    assert_ne!(a, b, "3-bit and 9-bit weights should differ on lenet");
    for (i, r) in res.iter().enumerate() {
        assert_eq!(*r, if i % 2 == 0 { a } else { b });
    }
}

#[test]
fn unknown_network_is_an_error_not_a_hang() {
    let mut c = coord(1);
    let bad = EvalJob {
        net: "resnet152".into(),
        cfg: PrecisionConfig::fp32(4),
        n_images: 64,
    };
    let err = c.eval_batch(&[bad]).unwrap_err().to_string();
    assert!(err.contains("resnet152"), "{err}");
    // pool still alive afterwards
    assert!(c.eval_one(job(8, 128)).is_ok());
}

#[test]
fn mismatched_config_width_is_an_error() {
    let mut c = coord(1);
    let bad = EvalJob {
        net: "lenet".into(),
        cfg: PrecisionConfig::fp32(7), // lenet has 4 layers
        n_images: 64,
    };
    assert!(c.eval_batch(&[bad]).is_err());
}

#[test]
fn run_stream_completes_all_and_reports_latency() {
    let mut c = coord(2);
    // warm engine so stream latencies are service latencies
    c.eval_one(job(8, 64)).unwrap();
    let arrivals: Vec<(Duration, EvalJob)> = (0..6)
        .map(|i| (Duration::from_millis(20 * i as u64), job(2 + i as i8, 64)))
        .collect();
    let lat = c.run_stream(&arrivals).unwrap();
    assert_eq!(lat.len(), 6);
    assert!(lat.iter().all(|l| *l > Duration::ZERO && *l < Duration::from_secs(60)));
}

#[test]
fn busy_time_accumulates() {
    let mut c = coord(1);
    c.eval_one(job(5, 128)).unwrap();
    assert!(c.busy_time() > Duration::ZERO);
}
