//! Backend-layer integration: the trait seam every engine plugs into.
//!
//! Locks the behaviours the tentpole refactor introduced: backend
//! selection, reference-backend correctness on every network, executor
//! caching hints, arch/manifest cross-validation, and the coordinator
//! running end-to-end on the reference backend.

use qbound::backend::{Backend, BackendKind, Variant};
use qbound::coordinator::{Coordinator, EvalJob};
use qbound::eval::{top1, Dataset, Evaluator};
use qbound::nets::{arch, ArtifactIndex, NetManifest};
use qbound::quant::QFormat;
use qbound::search::space::PrecisionConfig;
use qbound::testkit;

fn artifacts() -> std::path::PathBuf {
    testkit::ensure_artifacts()
}

fn reference() -> Box<dyn Backend> {
    BackendKind::Reference.create().unwrap()
}

#[test]
fn backend_kind_env_default_is_reference() {
    // (QBOUND_BACKEND is unset in the test environment)
    if std::env::var_os("QBOUND_BACKEND").is_none() {
        assert_eq!(BackendKind::from_env().unwrap(), BackendKind::Reference);
    }
    assert_eq!(reference().name(), "reference");
}

#[test]
fn every_network_loads_and_infers_on_the_reference_backend() {
    let dir = artifacts();
    let idx = ArtifactIndex::load(&dir).unwrap();
    let backend = reference();
    for net in &idx.nets {
        let m = NetManifest::load(&dir, net).unwrap();
        let mut exec = backend.load(&m, Variant::Standard).unwrap();
        assert_eq!(exec.batch(), m.batch);
        assert_eq!(exec.num_classes(), m.num_classes);
        let d = Dataset::load(&m).unwrap();
        let cfg = PrecisionConfig::fp32(m.n_layers());
        let logits = exec
            .infer(d.batch_images(0, m.batch), &cfg.wire_wq(), &cfg.wire_dq(), None)
            .unwrap();
        assert_eq!(logits.len(), m.batch * m.num_classes, "{net}");
        assert!(logits.iter().all(|v| v.is_finite()), "{net}");
        // Teacher labelling: the fp32 batch must be perfectly classified.
        let acc = top1(&logits, d.batch_labels(0, m.batch), m.num_classes);
        assert!((acc - 1.0).abs() < 1e-12, "{net}: fp32 batch top-1 {acc}");
        assert_eq!(exec.executions(), 1);
    }
}

#[test]
fn arch_registry_agrees_with_generated_manifests() {
    let dir = artifacts();
    let idx = ArtifactIndex::load(&dir).unwrap();
    for net in &idx.nets {
        let m = NetManifest::load(&dir, net).unwrap();
        let a = arch::get(net).expect("registered arch");
        arch::check_manifest(&a, &m).unwrap();
    }
}

#[test]
fn arch_mismatch_is_detected() {
    let dir = artifacts();
    let mut m = NetManifest::load(&dir, "lenet").unwrap();
    let a = arch::get("lenet").unwrap();
    m.layers[1].macs += 1;
    assert!(arch::check_manifest(&a, &m).is_err());
}

#[test]
fn infer_keyed_matches_infer() {
    let dir = artifacts();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let backend = reference();
    let mut exec = backend.load(&m, Variant::Standard).unwrap();
    let d = Dataset::load(&m).unwrap();
    let cfg = PrecisionConfig::uniform(m.n_layers(), QFormat::new(1, 7), QFormat::new(9, 3));
    let (wq, dq) = (cfg.wire_wq(), cfg.wire_dq());
    let a = exec.infer(d.batch_images(0, m.batch), &wq, &dq, None).unwrap();
    let b = exec.infer_keyed(0, d.batch_images(0, m.batch), &wq, &dq, None).unwrap();
    assert_eq!(a, b);
}

#[test]
fn weight_quantization_is_per_layer() {
    // Quantizing only the LAST layer's weights must not change earlier
    // layers' computation when data stays fp32 — checked by comparing
    // against the fully-fp32 logits of a narrowed final layer config.
    let dir = artifacts();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let backend = reference();
    let mut exec = backend.load(&m, Variant::Standard).unwrap();
    let d = Dataset::load(&m).unwrap();
    let nl = m.n_layers();
    let fp32 = PrecisionConfig::fp32(nl);
    let mut only_first = fp32.clone();
    only_first.wq[0] = QFormat::new(1, 2);
    let mut only_last = fp32.clone();
    only_last.wq[nl - 1] = QFormat::new(1, 2);
    let imgs = d.batch_images(0, m.batch);
    let base = exec.infer(imgs, &fp32.wire_wq(), &fp32.wire_dq(), None).unwrap();
    let first = exec.infer(imgs, &only_first.wire_wq(), &fp32.wire_dq(), None).unwrap();
    let last = exec.infer(imgs, &only_last.wire_wq(), &fp32.wire_dq(), None).unwrap();
    assert_ne!(base, first, "quantizing L1 weights must perturb logits");
    assert_ne!(base, last, "quantizing L4 weights must perturb logits");
    assert_ne!(first, last, "different layers, different perturbation");
}

#[test]
fn evaluator_runs_on_trait_object() {
    let dir = artifacts();
    let m = NetManifest::load(&dir, "convnet").unwrap();
    let backend = reference();
    let mut ev = Evaluator::new(backend.as_ref(), &m).unwrap();
    let base = ev.accuracy(&PrecisionConfig::fp32(m.n_layers()), 128).unwrap();
    assert!((base - 1.0).abs() < 1e-12, "teacher baseline {base}");
    let rel = ev
        .relative_error(
            &PrecisionConfig::uniform(m.n_layers(), QFormat::new(1, 6), QFormat::new(8, 3)),
            128,
        )
        .unwrap();
    // probe-stable config: no relative error on the filtered split
    assert!(rel.abs() < 0.05, "probe config rel err {rel}");
}

#[test]
fn coordinator_serves_reference_backend_jobs() {
    let dir = artifacts();
    let mut c = Coordinator::with_backend(&dir, 2, BackendKind::Reference).unwrap();
    assert_eq!(c.backend, BackendKind::Reference);
    let jobs: Vec<EvalJob> = (4..10)
        .map(|f| EvalJob {
            net: "lenet".into(),
            cfg: PrecisionConfig::uniform(4, QFormat::new(1, f), QFormat::new(9, 2)),
            n_images: 128,
        })
        .collect();
    let accs = c.eval_batch(&jobs).unwrap();
    assert_eq!(accs.len(), jobs.len());
    assert!(accs.iter().all(|a| (0.0..=1.0).contains(a)));
    // more weight bits never collapses: widest config beats narrowest
    // by a sane margin on the teacher-labelled split
    assert!(accs.last().unwrap() + 0.2 >= accs[0], "{accs:?}");
}

#[test]
fn stage_quantization_affects_only_that_stage_config() {
    // Harsh quantization of one stage must change logits vs sentinel.
    let dir = artifacts();
    let m = NetManifest::load(&dir, "alexnet").unwrap();
    let sv = m.stage_variant.clone().unwrap();
    let backend = reference();
    let mut exec = backend.load(&m, Variant::Stages).unwrap();
    let d = Dataset::load(&m).unwrap();
    let fp32 = PrecisionConfig::fp32(m.n_layers());
    let sentinel: Vec<f32> = (0..sv.n_stages).flat_map(|_| [-1.0f32, 0.0]).collect();
    let base = exec
        .infer(d.batch_images(0, m.batch), &fp32.wire_wq(), &fp32.wire_dq(), Some(&sentinel))
        .unwrap();
    let mut harsh = sentinel.clone();
    harsh[0] = 1.0; // stage 0 (conv) data -> Q(1.1)
    harsh[1] = 1.0;
    let quantized = exec
        .infer(d.batch_images(0, m.batch), &fp32.wire_wq(), &fp32.wire_dq(), Some(&harsh))
        .unwrap();
    assert_ne!(base, quantized);
}
