//! Packed-weight store integration: concurrency, corruption rejection,
//! gc-vs-live safety, and the warm-start zero-pack contract through the
//! fast backend — the on-disk half of the serving warm-start story.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use qbound::backend::{Backend, Variant};
use qbound::backend::fast::FastBackend;
use qbound::memory::{PackedBuf, PackedPanels, StorageMode};
use qbound::nets::NetManifest;
use qbound::quant::QFormat;
use qbound::search::space::PrecisionConfig;
use qbound::store::{bias_key, panels_key, Store};
use qbound::testkit;

/// A fresh store directory for one test (distinct names — the store is
/// a per-directory process singleton, so reuse would leak counters
/// between tests).
fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qbound-store-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn tensor(n: usize, seed: u32) -> Vec<f32> {
    (0..n)
        .map(|i| ((i as u32).wrapping_mul(2654435761).wrapping_add(seed) % 997) as f32 / 499.0 - 1.0)
        .collect()
}

#[test]
fn concurrent_same_key_loaders_race_cleanly() {
    let store = Store::open(&fresh_dir("race")).unwrap();
    let raw = Arc::new(tensor(48 * 20, 7));
    let (fmt, kd, n) = (QFormat::new(2, 7), 48, 20);
    let packs = Arc::new(AtomicUsize::new(0));

    let mut handles = Vec::new();
    for _ in 0..8 {
        let (store, raw, packs) = (Arc::clone(&store), Arc::clone(&raw), Arc::clone(&packs));
        handles.push(std::thread::spawn(move || {
            store.panels_for(&raw, fmt, kd, n, 16, || {
                packs.fetch_add(1, Ordering::SeqCst);
                PackedPanels::pack(fmt, &qbound::backend::gemm::pack_b_panels(&raw, kd, n), kd, 16)
            })
        }));
    }
    let results: Vec<PackedPanels> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    // Every racer decodes the same bits as a plain owned pack.
    let reference =
        PackedPanels::pack(fmt, &qbound::backend::gemm::pack_b_panels(&raw, kd, n), kd, 16);
    let strip_len = reference.nr() * reference.kd();
    let mut want = vec![0f32; strip_len];
    let mut got = vec![0f32; strip_len];
    for pp in &results {
        assert_eq!((pp.kd(), pp.nr(), pp.len()), (reference.kd(), reference.nr(), reference.len()));
        for panel in 0..reference.n_panels() {
            reference.read_strip(panel, 0, kd, &mut want);
            pp.read_strip(panel, 0, kd, &mut got);
            assert_eq!(want, got, "panel {panel} diverged under the race");
        }
    }
    // At least one racer packed; the published file validates.
    assert!(packs.load(Ordering::SeqCst) >= 1);
    let key = panels_key(&raw, fmt, kd, n, 16);
    let entry = store
        .ls()
        .unwrap()
        .into_iter()
        .find(|e| e.key == key)
        .expect("published store file listed");
    assert!(entry.valid, "store file invalid after the race: {}", entry.desc);

    // A later loader needs no pack at all — not even a shared hit
    // requirement, just: the closure must not run.
    drop(results);
    let before = packs.load(Ordering::SeqCst);
    let _again = store.panels_for(&raw, fmt, kd, n, 16, || {
        packs.fetch_add(1, Ordering::SeqCst);
        PackedPanels::pack(fmt, &qbound::backend::gemm::pack_b_panels(&raw, kd, n), kd, 16)
    });
    assert_eq!(packs.load(Ordering::SeqCst), before, "warm load invoked pack()");
}

#[test]
fn corrupted_files_are_rejected_and_repacked() {
    let store = Store::open(&fresh_dir("corrupt")).unwrap();
    let raw = tensor(300, 3);
    let fmt = QFormat::new(1, 8);
    let key = bias_key(&raw, fmt);
    let path = store.dir().join(format!("{key}.qbw"));

    let packs = AtomicUsize::new(0);
    let pack = || {
        packs.fetch_add(1, Ordering::SeqCst);
        PackedBuf::pack(fmt, &raw)
    };
    drop(store.buf_for(&raw, fmt, pack)); // publish + drop the mapping
    assert_eq!(packs.load(Ordering::SeqCst), 1);
    assert!(path.exists());

    // Three corruption shapes; each must be detected, quarantined
    // (file removed) and transparently re-packed.
    type Corrupt = fn(&std::path::Path);
    let corruptions: [(&str, Corrupt); 3] = [
        ("payload bit flip", |p| {
            let mut bytes = std::fs::read(p).unwrap();
            let last = bytes.len() - 1;
            bytes[last] ^= 0x10;
            std::fs::write(p, bytes).unwrap();
        }),
        ("truncation", |p| {
            let bytes = std::fs::read(p).unwrap();
            std::fs::write(p, &bytes[..bytes.len() - 8]).unwrap();
        }),
        ("garbled magic", |p| {
            let mut bytes = std::fs::read(p).unwrap();
            bytes[0] ^= 0xff;
            std::fs::write(p, bytes).unwrap();
        }),
    ];
    for (i, (what, corrupt)) in corruptions.iter().enumerate() {
        corrupt(&path);
        let invalid_before = store.stats().invalid;
        let buf = store.buf_for(&raw, fmt, || {
            packs.fetch_add(1, Ordering::SeqCst);
            PackedBuf::pack(fmt, &raw)
        });
        assert_eq!(packs.load(Ordering::SeqCst), 2 + i, "{what}: expected a re-pack");
        assert!(store.stats().invalid > invalid_before, "{what}: not counted invalid");
        // The re-published file is valid again and the returned buffer
        // decodes like a fresh pack.
        let reference = PackedBuf::pack(fmt, &raw);
        for j in [0usize, 1, 7, 299] {
            assert_eq!(buf.get(fmt, j), reference.get(fmt, j), "{what}: bits diverged");
        }
        drop(buf);
        let entry =
            store.ls().unwrap().into_iter().find(|e| e.key == key).expect("file republished");
        assert!(entry.valid, "{what}: re-published file invalid: {}", entry.desc);
    }
}

#[test]
fn gc_keeps_live_mappings_and_removes_dead_files() {
    let store = Store::open(&fresh_dir("gc")).unwrap();
    let (live_raw, dead_raw) = (tensor(200, 11), tensor(200, 12));
    let fmt = QFormat::new(3, 4);
    let live = store.buf_for(&live_raw, fmt, || PackedBuf::pack(fmt, &live_raw));
    drop(store.buf_for(&dead_raw, fmt, || PackedBuf::pack(fmt, &dead_raw)));
    assert!(live.is_shared(), "live buffer must be store-backed for this test");

    let report = store.gc(Duration::ZERO, false).unwrap();
    assert_eq!(report.kept_live, 1, "the mapped key must survive gc");
    assert_eq!(report.removed, 1, "the dropped key must be collected");

    let live_key = bias_key(&live_raw, fmt);
    let keys: Vec<String> = store.ls().unwrap().into_iter().map(|e| e.key).collect();
    assert_eq!(keys, vec![live_key], "exactly the live key remains");
    // The survivor still decodes — and so would the removed mapping,
    // had anyone held it (unlink never invalidates live regions).
    assert_eq!(live.get(fmt, 13), PackedBuf::pack(fmt, &live_raw).get(fmt, 13));
}

#[test]
fn warm_backend_start_packs_nothing_and_is_bit_identical() {
    let store = Store::open(&fresh_dir("warm")).unwrap();
    let dir = testkit::ensure_artifacts();
    let manifest = NetManifest::load(&dir, "lenet").unwrap();
    let cfg = PrecisionConfig::uniform(manifest.n_layers(), QFormat::new(1, 8), QFormat::new(9, 2));
    let (wq, dq) = (cfg.wire_wq(), cfg.wire_dq());
    let img_elems = {
        let ds = qbound::eval::Dataset::load(&manifest).unwrap();
        ds.images[..ds.image_elems].to_vec()
    };

    let infer = |backend: &FastBackend| -> Vec<f32> {
        let mut exec = backend.load(&manifest, Variant::Standard).unwrap();
        exec.infer(&img_elems, &wq, &dq, None).unwrap()
    };

    // Cold: packs and publishes every lenet weight tensor at this wq.
    let cold_backend = FastBackend::with_options(1, StorageMode::Packed)
        .with_store(Some(Arc::clone(&store)));
    let cold_logits = infer(&cold_backend);
    let packs_cold = store.stats().packs;
    assert!(packs_cold > 0, "cold start must pack");
    drop(cold_backend);

    // Warm: a fresh backend against the same store dir loads every
    // bitstream from disk — zero pack calls, bit-identical logits.
    let warm_backend = FastBackend::with_options(1, StorageMode::Packed)
        .with_store(Some(Arc::clone(&store)));
    let warm_logits = infer(&warm_backend);
    assert_eq!(store.stats().packs, packs_cold, "warm start re-packed");
    assert!(store.stats().hits_disk + store.stats().hits_shared > 0, "warm start never hit");
    assert_eq!(cold_logits, warm_logits, "store-backed logits drifted across restart");

    // And both agree bit-for-bit with a store-free packed executor.
    let plain = infer(&FastBackend::with_options(1, StorageMode::Packed).with_store(None));
    assert_eq!(plain, warm_logits, "store-backed logits diverge from the owned pack path");
}
