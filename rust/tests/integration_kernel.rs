//! Device-vs-host parity on the COMPILED Pallas kernel: the golden-vector
//! test locks rust to the jnp oracle; this locks rust to the actual HLO
//! executable the runtime executes — closing the full tri-implementation
//! loop. Plus the stochastic-rounding extension study invariants.
//!
//! PJRT-native: needs `--features pjrt`, real kernel HLO from the python
//! build path, and xla_extension — hence feature-gated and `#[ignore]`d
//! (run with `cargo test --features pjrt -- --ignored`).

#![cfg(feature = "pjrt")]

use qbound::nets::ArtifactIndexExt;
use qbound::prng::Xoshiro256pp;
use qbound::quant::QFormat;
use qbound::runtime::kernel::{KernelEngine, Rounding};
use qbound::runtime::Session;
use qbound::testkit;

fn setup(rounding: Rounding) -> (Session, KernelEngine, usize) {
    let dir = testkit::ensure_artifacts();
    let session = Session::cpu().unwrap();
    let n = ArtifactIndexExt::kernel_n(&dir).unwrap();
    let engine = KernelEngine::load(&session, &dir, rounding).unwrap();
    (session, engine, n)
}

fn inputs(n: usize, seed: u64, scale: f32) -> Vec<f32> {
    let mut rng = Xoshiro256pp::new(seed);
    (0..n).map(|_| (rng.normal() as f32) * scale).collect()
}

#[test]
#[ignore = "needs compiled kernel HLO (make artifacts) + xla_extension"]
fn compiled_kernel_matches_host_quantizer_bit_for_bit() {
    let (session, engine, n) = setup(Rounding::Nearest);
    let cases = [(8i8, 4i8, 16.0f32), (1, 7, 0.6), (12, 0, 3000.0), (4, 2, 40.0), (0, 5, 0.4)];
    for (i, f, scale) in cases {
        let fmt = QFormat::new(i, f);
        let x = inputs(n, 42 + i as u64, scale);
        let dev = engine.quantize(&session, &x, fmt, None).unwrap();
        for (k, (&xi, &di)) in x.iter().zip(&dev).enumerate() {
            let host = fmt.quantize(xi);
            assert!(
                host.to_bits() == di.to_bits() || (host == 0.0 && di == 0.0),
                "Q{i}.{f}[{k}]: host q({xi}) = {host:e}, device {di:e}"
            );
        }
    }
}

#[test]
#[ignore = "needs compiled kernel HLO (make artifacts) + xla_extension"]
fn compiled_kernel_sentinel_passthrough() {
    let (session, engine, n) = setup(Rounding::Nearest);
    let x = inputs(n, 7, 1e5);
    let dev = engine.quantize(&session, &x, QFormat::FP32, None).unwrap();
    assert_eq!(x, dev);
}

#[test]
#[ignore = "needs compiled kernel HLO (make artifacts) + xla_extension"]
fn stochastic_kernel_is_unbiased_and_on_grid() {
    let (session, engine, n) = setup(Rounding::Stochastic);
    let fmt = QFormat::new(4, 0);
    let x = vec![0.3f32; n];
    let mut rng = Xoshiro256pp::new(11);
    let u: Vec<f32> = (0..n).map(|_| rng.uniform() as f32).collect();
    let q = engine.quantize(&session, &x, fmt, Some(&u)).unwrap();
    // every output on the integer grid, in {0, 1}
    assert!(q.iter().all(|&v| v == 0.0 || v == 1.0));
    // unbiased: mean ≈ 0.3
    let mean: f64 = q.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
    assert!((mean - 0.3).abs() < 0.01, "mean {mean}");
}

#[test]
#[ignore = "needs compiled kernel HLO (make artifacts) + xla_extension"]
fn stochastic_reduces_to_floor_and_ceil_bounds() {
    let (session, engine, n) = setup(Rounding::Stochastic);
    let fmt = QFormat::new(6, 2);
    let x = inputs(n, 13, 5.0);
    let u0 = vec![0.0f32; n]; // u=0 → floor... (+0 keeps exact values)
    let q = engine.quantize(&session, &x, fmt, Some(&u0)).unwrap();
    let step = fmt.step();
    for (&xi, &qi) in x.iter().zip(&q) {
        let (lo, hi) = fmt.range();
        let expect = (xi / step).floor() * step;
        let expect = expect.clamp(lo, hi);
        assert!(
            (qi - expect).abs() < 1e-6,
            "u=0 must floor: x {xi} q {qi} expect {expect}"
        );
    }
}

#[test]
#[ignore = "needs compiled kernel HLO (make artifacts) + xla_extension"]
fn rounding_mode_study_rne_beats_sr_on_correlated_error() {
    // RNE error is deterministic per value; SR error has higher variance
    // per element but is unbiased in aggregate — verify both properties.
    let (session, rne, n) = setup(Rounding::Nearest);
    let (session_sr, sr, _) = setup(Rounding::Stochastic);
    let fmt = QFormat::new(3, 1);
    let x = inputs(n, 29, 1.5);
    let qr = rne.quantize(&session, &x, fmt, None).unwrap();
    let mut rng = Xoshiro256pp::new(31);
    let u: Vec<f32> = (0..n).map(|_| rng.uniform() as f32).collect();
    // buffers must be created on the same client the executable was
    // compiled with — use the sr engine's own session
    let qs = sr.quantize(&session_sr, &x, fmt, Some(&u)).unwrap();

    let mse = |q: &[f32]| -> f64 {
        x.iter().zip(q).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>() / n as f64
    };
    let bias = |q: &[f32]| -> f64 {
        x.iter().zip(q).map(|(a, b)| (b - a) as f64).sum::<f64>() / n as f64
    };
    assert!(mse(&qr) <= mse(&qs) + 1e-9, "RNE must minimize MSE");
    assert!(bias(&qs).abs() < 0.01, "SR must be unbiased: {}", bias(&qs));
}
