//! Property tests for the packed reduced-precision storage: pack→unpack
//! must be bit-exact vs `QFormat::quantize_slice` for every I/F width
//! combination — including negative values, clamp edges, exact ties and
//! non-word-aligned lengths — up to zero-sign canonicalization (two's
//! complement has one zero, so a quantized `-0.0` is recovered as
//! `+0.0`; `+ 0.0` applies the same canonicalization to the reference
//! side and is the identity on every other value).

use qbound::memory::{storage_width, PackedBuf, PackedCursor, PackedPanels, MAX_PACK_BITS};
use qbound::quant::QFormat;
use qbound::testkit::{
    cases, forall, gen_f32, gen_i64, gen_vec, prop, quantized_canonical, GenPair, Outcome,
};

fn check_roundtrip(fmt: QFormat, xs: &[f32]) -> Outcome {
    let want = quantized_canonical(fmt, xs);
    let buf = PackedBuf::pack(fmt, xs);
    let mut got = vec![f32::NAN; xs.len()];
    buf.unpack_into(fmt, &mut got);
    for (i, (w, g)) in want.iter().zip(&got).enumerate() {
        if w.to_bits() != g.to_bits() {
            return Outcome::Fail(format!(
                "{fmt}: elem {i} ({}) packs to {g:?}, quantizer says {w:?}",
                xs[i]
            ));
        }
    }
    prop(buf.len() == xs.len(), "len preserved")
}

/// Every packable (I, F) combination, swept exhaustively over a value
/// set that covers the clamp edges, exact rounding ties, negatives and
/// a non-word-aligned length.
#[test]
fn every_width_combo_roundtrips_edge_values() {
    for ibits in 0..=12i8 {
        for fbits in 0..=12i8 {
            if ibits + fbits == 0 {
                continue;
            }
            let fmt = QFormat::new(ibits, fbits);
            let (lo, hi) = fmt.range();
            let step = fmt.step();
            // 13 values: in-range grid points, half-step ties, both
            // clamp edges and beyond, negatives, zero — odd length so
            // the bitstream never ends word-aligned.
            let xs = [
                0.0f32,
                -0.0,
                step,
                -step,
                step * 0.5, // exact tie
                -step * 1.5, // exact tie
                lo,
                hi,
                lo - step, // below the clamp
                hi + step, // above the clamp
                lo * 10.0,
                hi * 10.0,
                0.37,
            ];
            if let Outcome::Fail(msg) = check_roundtrip(fmt, &xs) {
                panic!("{msg}");
            }
        }
    }
}

/// Randomized sweep: random format, random non-word-aligned length,
/// random values spanning several format ranges.
#[test]
fn random_formats_and_lengths_roundtrip() {
    forall(
        cases(256),
        GenPair(
            GenPair(gen_i64(0, 13), gen_i64(0, 13)),
            gen_vec(gen_f32(-600.0, 600.0), 1, 67),
        ),
        |((ibits, fbits), xs)| {
            let (mut i, f) = (*ibits as i8, *fbits as i8);
            if i + f == 0 {
                i = 1;
            }
            let fmt = QFormat::new(i, f);
            check_roundtrip(fmt, xs)
        },
    );
}

/// Formats wider than MAX_PACK_BITS and the fp32 sentinel take the
/// word-aligned 32-bit fallback and must still match the quantizer.
#[test]
fn wide_and_fp32_formats_roundtrip() {
    let wide = QFormat::new(14, 12); // 26 bits
    assert_eq!(storage_width(wide), 32);
    forall(cases(128), gen_vec(gen_f32(-20000.0, 20000.0), 1, 33), |xs| {
        check_roundtrip(wide, xs)
    });
    forall(cases(128), gen_vec(gen_f32(-1e9, 1e9), 1, 33), |xs| {
        // fp32 passthrough: raw bits, including -0.0.
        let buf = PackedBuf::pack(QFormat::FP32, xs);
        let mut got = vec![0f32; xs.len()];
        buf.unpack_into(QFormat::FP32, &mut got);
        prop(
            xs.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
            "fp32 raw-bit roundtrip",
        )
    });
}

/// Packing is idempotent: packing an unpacked buffer reproduces it.
#[test]
fn pack_is_idempotent_on_quantized_data() {
    forall(
        cases(128),
        GenPair(gen_i64(1, 10), gen_vec(gen_f32(-50.0, 50.0), 1, 50)),
        |(fbits, xs)| {
            let fmt = QFormat::new(3, *fbits as i8);
            let buf = PackedBuf::pack(fmt, xs);
            let mut once = vec![0f32; xs.len()];
            buf.unpack_into(fmt, &mut once);
            let buf2 = PackedBuf::pack(fmt, &once);
            let mut twice = vec![0f32; xs.len()];
            buf2.unpack_into(fmt, &mut twice);
            prop(
                once.iter().zip(&twice).all(|(a, b)| a.to_bits() == b.to_bits()),
                "second roundtrip must be the identity",
            )
        },
    );
}

/// The streaming window reader: for every packable `I+F` width, over a
/// non-word-aligned row length, every `(row0, rows)` window of
/// `unpack_rows` is bit-identical to the matching slice of a full
/// `unpack` — including windows whose first value straddles a `u64`
/// word boundary.
#[test]
fn every_width_window_matches_full_unpack() {
    let row_elems = 7usize; // odd: row starts sweep all bit offsets
    let rows = 11usize;
    let xs: Vec<f32> = (0..row_elems * rows).map(|i| i as f32 * 0.83 - 31.0).collect();
    for ibits in 0..=12i8 {
        for fbits in 0..=12i8 {
            if ibits + fbits == 0 {
                continue;
            }
            let fmt = QFormat::new(ibits, fbits);
            let buf = PackedBuf::pack(fmt, &xs);
            let mut want = vec![0f32; xs.len()];
            buf.unpack_into(fmt, &mut want);
            for row0 in 0..rows {
                for take in 1..=(rows - row0).min(3) {
                    let mut got = vec![f32::NAN; take * row_elems];
                    buf.unpack_rows(fmt, row_elems, row0, &mut got);
                    let wslice = &want[row0 * row_elems..(row0 + take) * row_elems];
                    for (i, (a, b)) in got.iter().zip(wslice).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{fmt}: window row0={row0} take={take} elem {i}"
                        );
                    }
                }
            }
        }
    }
}

/// A cursor consuming the stream in uneven chunks reproduces the full
/// unpack exactly, for random formats, lengths and chunk patterns.
#[test]
fn cursor_chunked_reads_match_full_unpack() {
    forall(
        cases(256),
        GenPair(
            GenPair(gen_i64(0, 13), gen_i64(0, 13)),
            GenPair(gen_vec(gen_f32(-300.0, 300.0), 1, 97), gen_i64(1, 13)),
        ),
        |((ibits, fbits), (xs, chunk))| {
            let (mut i, f) = (*ibits as i8, *fbits as i8);
            if i + f == 0 {
                i = 1;
            }
            let fmt = QFormat::new(i, f);
            let buf = PackedBuf::pack(fmt, xs);
            let mut want = vec![0f32; xs.len()];
            buf.unpack_into(fmt, &mut want);
            let mut cur = PackedCursor::new(&buf, fmt);
            let mut got = Vec::with_capacity(xs.len());
            while cur.remaining() > 0 {
                let take = (*chunk as usize).min(cur.remaining());
                let mut w = vec![f32::NAN; take];
                cur.read_into(&mut w);
                got.extend_from_slice(&w);
            }
            prop(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "cursor stream must equal full unpack",
            )
        },
    );
}

/// Panel strips decode with the format captured at pack time — for
/// every packable width plus both 32-bit fallbacks, every strip of
/// every panel is bit-identical to the quantizer over the same range.
/// Since `read_strip` takes no format, a same-width wrong-format decode
/// (the old parallel-`fmts`-vec hazard) is structurally impossible.
#[test]
fn panel_strips_decode_with_stored_format_for_every_width() {
    let (kd, nr, n_panels) = (5usize, 4usize, 2usize);
    let xs: Vec<f32> = (0..kd * nr * n_panels).map(|i| i as f32 * 0.47 - 9.0).collect();
    let mut fmts = vec![QFormat::FP32, QFormat::new(14, 12)]; // 32-bit fallbacks
    for ibits in 0..=12i8 {
        for fbits in 0..=12i8 {
            if ibits + fbits > 0 {
                fmts.push(QFormat::new(ibits, fbits));
            }
        }
    }
    for fmt in fmts {
        let want = if fmt.is_fp32() { xs.clone() } else { quantized_canonical(fmt, &xs) };
        let pp = PackedPanels::pack(fmt, &xs, kd, nr);
        assert_eq!(pp.fmt(), fmt);
        assert_eq!(pp.width(), storage_width(fmt));
        for p in 0..n_panels {
            for (k0, k1) in [(0usize, kd), (1, 3), (kd - 1, kd)] {
                let mut got = vec![f32::NAN; (k1 - k0) * nr];
                pp.read_strip(p, k0, k1, &mut got);
                let lo = (p * kd + k0) * nr;
                for (i, (a, b)) in got.iter().zip(&want[lo..lo + got.len()]).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{fmt}: panel {p} rows {k0}..{k1} elem {i}"
                    );
                }
            }
        }
    }
}

/// The physical footprint matches the bit arithmetic for every width.
#[test]
fn packed_bytes_match_width_arithmetic() {
    for width_fmt in [
        QFormat::new(1, 0),
        QFormat::new(2, 3),
        QFormat::new(1, 7),
        QFormat::new(8, 8),
        QFormat::new(12, 12),
    ] {
        for len in [1usize, 7, 8, 63, 64, 65, 1000] {
            let buf = PackedBuf::pack(width_fmt, &vec![0.25; len]);
            let bits = len * storage_width(width_fmt) as usize;
            assert_eq!(buf.packed_bytes(), (bits + 7) / 8, "{width_fmt} len {len}");
            assert!(storage_width(width_fmt) <= MAX_PACK_BITS || storage_width(width_fmt) == 32);
        }
    }
}
