//! `--storage packed` parity: on every registered architecture, both
//! CPU executors must produce results numerically identical to the
//! default quantize-in-f32 path when boundary activations live as
//! packed bitstreams — zero logit difference (|a - b| = 0 admits only
//! the sign of zero, which two's complement canonicalizes) and
//! bit-identical top-1 on every row.

use qbound::backend::fast::FastBackend;
use qbound::backend::reference::ReferenceBackend;
use qbound::backend::{Backend, NetExecutor, Variant};
use qbound::eval::Dataset;
use qbound::memory::StorageMode;
use qbound::nets::{ArtifactIndex, NetManifest};
use qbound::quant::QFormat;
use qbound::search::space::PrecisionConfig;
use qbound::testkit;

/// Images per parity batch — ≠ the manifest batch so the variable-batch
/// path is exercised.
const PARITY_IMAGES: usize = 16;

fn artifacts() -> std::path::PathBuf {
    testkit::ensure_artifacts()
}

fn top1_rows(logits: &[f32], classes: usize) -> Vec<usize> {
    logits
        .chunks(classes)
        .map(|row| {
            let mut best = 0;
            for (i, v) in row.iter().enumerate() {
                if *v > row[best] {
                    best = i;
                }
            }
            best
        })
        .collect()
}

/// fp32 sentinel layers, a healthy uniform config, a mixed per-layer
/// config, and a deliberately narrow one (wide clamping, many zeros).
fn storage_configs(nl: usize) -> Vec<(&'static str, PrecisionConfig)> {
    let mut mixed = PrecisionConfig::fp32(nl);
    for l in 0..nl {
        mixed.wq[l] = if l % 2 == 0 { QFormat::new(1, 8) } else { QFormat::new(2, 7) };
        mixed.dq[l] = if l % 3 == 0 { QFormat::new(10, 3) } else { QFormat::new(9, 4) };
    }
    vec![
        ("fp32", PrecisionConfig::fp32(nl)),
        ("uniform", PrecisionConfig::uniform(nl, QFormat::new(1, 8), QFormat::new(10, 2))),
        ("mixed", mixed),
        ("narrow", PrecisionConfig::uniform(nl, QFormat::new(1, 4), QFormat::new(4, 1))),
    ]
}

fn assert_identical(net: &str, label: &str, classes: usize, a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "{net}/{label}: logit count");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        // |x - y| == 0.0 admits -0.0 vs 0.0, nothing else.
        assert!(
            (x - y).abs() == 0.0,
            "{net}/{label}: logit {i} differs: {x} vs {y}"
        );
    }
    assert_eq!(top1_rows(a, classes), top1_rows(b, classes), "{net}/{label}: top-1");
}

#[test]
fn packed_storage_is_identical_on_every_arch_both_backends() {
    let dir = artifacts();
    let idx = ArtifactIndex::load(&dir).unwrap();
    for net in &idx.nets {
        let m = NetManifest::load(&dir, net).unwrap();
        let d = Dataset::load(&m).unwrap();
        let n = PARITY_IMAGES.min(d.n);
        let imgs = &d.images[..n * d.image_elems];

        let mut rf32 =
            ReferenceBackend::with_storage(StorageMode::F32).load(&m, Variant::Standard).unwrap();
        let mut rpacked = ReferenceBackend::with_storage(StorageMode::Packed)
            .load(&m, Variant::Standard)
            .unwrap();
        let mut ff32 = FastBackend::with_options(2, StorageMode::F32)
            .load(&m, Variant::Standard)
            .unwrap();
        let mut fpacked = FastBackend::with_options(2, StorageMode::Packed)
            .load(&m, Variant::Standard)
            .unwrap();

        for (label, cfg) in storage_configs(m.n_layers()) {
            let (wq, dq) = (cfg.wire_wq(), cfg.wire_dq());
            let want = rf32.infer(imgs, &wq, &dq, None).unwrap();
            let rp = rpacked.infer(imgs, &wq, &dq, None).unwrap();
            assert_identical(net, &format!("{label}/reference"), m.num_classes, &want, &rp);
            let fwant = ff32.infer(imgs, &wq, &dq, None).unwrap();
            let fp = fpacked.infer(imgs, &wq, &dq, None).unwrap();
            assert_identical(net, &format!("{label}/fast"), m.num_classes, &fwant, &fp);
        }
    }
}

#[test]
fn packed_storage_parity_on_stage_variants() {
    let dir = artifacts();
    let idx = ArtifactIndex::load(&dir).unwrap();
    let mut covered = 0;
    for net in &idx.nets {
        let m = NetManifest::load(&dir, net).unwrap();
        let Some(sv) = m.stage_variant.clone() else { continue };
        covered += 1;
        let d = Dataset::load(&m).unwrap();
        let n = PARITY_IMAGES.min(d.n);
        let imgs = &d.images[..n * d.image_elems];
        let mut sq: Vec<f32> = (0..sv.n_stages).flat_map(|_| [-1.0f32, 0.0]).collect();
        sq[0] = 4.0; // stage 0 data -> Q(4.4)
        sq[1] = 4.0;
        let cfg = PrecisionConfig::uniform(m.n_layers(), QFormat::new(1, 8), QFormat::new(10, 2));
        let (wq, dq) = (cfg.wire_wq(), cfg.wire_dq());
        let pairs: [(Box<dyn Backend>, Box<dyn Backend>); 2] = [
            (
                Box::new(ReferenceBackend::with_storage(StorageMode::F32)),
                Box::new(ReferenceBackend::with_storage(StorageMode::Packed)),
            ),
            (
                Box::new(FastBackend::with_options(2, StorageMode::F32)),
                Box::new(FastBackend::with_options(2, StorageMode::Packed)),
            ),
        ];
        for (mk_f32, mk_packed) in pairs {
            let mut a = mk_f32.load(&m, Variant::Stages).unwrap();
            let mut b = mk_packed.load(&m, Variant::Stages).unwrap();
            let la = a.infer(imgs, &wq, &dq, Some(&sq)).unwrap();
            let lb = b.infer(imgs, &wq, &dq, Some(&sq)).unwrap();
            assert_identical(net, &format!("stages/{}", mk_f32.name()), m.num_classes, &la, &lb);
        }
    }
    assert!(covered >= 1, "no stage variant in the artifact set");
}

#[test]
fn packed_fast_is_bit_deterministic_across_thread_counts() {
    let dir = artifacts();
    for net in ["lenet", "googlenet"] {
        let m = NetManifest::load(&dir, net).unwrap();
        let d = Dataset::load(&m).unwrap();
        let cfg =
            PrecisionConfig::uniform(m.n_layers(), QFormat::new(1, 8), QFormat::new(10, 2));
        let (wq, dq) = (cfg.wire_wq(), cfg.wire_dq());
        let n = 8.min(d.n);
        let imgs = &d.images[..n * d.image_elems];
        let mut base: Option<Vec<f32>> = None;
        for threads in [1usize, 2, 5] {
            let backend = FastBackend::with_options(threads, StorageMode::Packed);
            let mut exec = backend.load(&m, Variant::Standard).unwrap();
            let logits = exec.infer(imgs, &wq, &dq, None).unwrap();
            match &base {
                None => base = Some(logits),
                Some(want) => {
                    assert!(
                        want.iter().zip(&logits).all(|(a, b)| a.to_bits() == b.to_bits()),
                        "{net}: packed threads={threads} changed bits"
                    );
                }
            }
        }
    }
}

#[test]
fn evaluator_accuracy_identical_under_packed_storage() {
    // The acceptance-criteria form of the contract: top-1 accuracy on a
    // whole eval split is bit-identical between storage modes on every
    // registered arch (both backends). The packed evaluators also serve
    // their batches from the spilled PackedSplit bitstream — the input
    // set is packed end-to-end, not just the inter-layer activations.
    let dir = artifacts();
    let idx = ArtifactIndex::load(&dir).unwrap();
    for net in &idx.nets {
        let m = NetManifest::load(&dir, net).unwrap();
        let cfg =
            PrecisionConfig::uniform(m.n_layers(), QFormat::new(1, 7), QFormat::new(9, 3));
        let mut accs = Vec::new();
        let backends: Vec<(Box<dyn Backend>, StorageMode)> = vec![
            (Box::new(ReferenceBackend::with_storage(StorageMode::F32)), StorageMode::F32),
            (
                Box::new(ReferenceBackend::with_storage(StorageMode::Packed)),
                StorageMode::Packed,
            ),
            (Box::new(FastBackend::with_options(2, StorageMode::F32)), StorageMode::F32),
            (
                Box::new(FastBackend::with_options(2, StorageMode::Packed)),
                StorageMode::Packed,
            ),
        ];
        for (backend, storage) in &backends {
            let mut ev =
                qbound::eval::Evaluator::with_storage(backend.as_ref(), &m, *storage).unwrap();
            accs.push(ev.accuracy(&cfg, 64).unwrap());
        }
        assert!(
            accs.iter().all(|a| *a == accs[0]),
            "{net}: storage modes disagree on accuracy: {accs:?}"
        );
    }
}
