//! Search-stack integration on real artifacts: uniform/per-layer sweeps
//! behave physically (more bits ≥ accuracy at the knee), the greedy
//! descent makes monotone traffic progress, and Table-2 selection returns
//! configurations that actually verify.

use qbound::coordinator::{Coordinator, EvalJob};
use qbound::nets::NetManifest;
use qbound::search::greedy::{self, GreedyOptions};
use qbound::search::space::{DescentOptions, PrecisionConfig};
use qbound::search::{perlayer, table2, uniform, Param};
use qbound::testkit;
use qbound::traffic::{self, Mode};

const N: usize = 128; // eval subset for test speed

fn setup() -> (std::path::PathBuf, Coordinator) {
    let dir = testkit::ensure_artifacts();
    let coord = Coordinator::new(&dir, 2).unwrap();
    (dir, coord)
}

#[test]
fn uniform_weight_sweep_has_a_knee() {
    let (dir, mut coord) = setup();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let pts =
        uniform::sweep(&mut coord, "lenet", m.n_layers(), Param::WeightF, (1, 10), N).unwrap();
    // accuracy at 10 fraction bits ~ baseline; at 1 bit far below
    let at = |b: i8| pts.iter().find(|p| p.bits == b).unwrap().relative;
    assert!(at(10) > 0.98, "rel at 10 bits {}", at(10));
    assert!(at(1) < at(10), "1-bit weights should hurt");
    let knee = uniform::min_bits_within(&pts, 0.01).expect("knee exists");
    assert!((2..=10).contains(&knee), "knee {knee}");
}

#[test]
fn per_layer_requirements_vary_within_network() {
    let (dir, mut coord) = setup();
    let m = NetManifest::load(&dir, "convnet").unwrap();
    let matrix =
        perlayer::sweep_all_layers(&mut coord, "convnet", m.n_layers(), &[Param::DataI], (1, 12), N)
            .unwrap();
    let mins = perlayer::min_bits_per_layer(&matrix[0], 0.01);
    let known: Vec<i8> = mins.iter().flatten().copied().collect();
    assert!(known.len() >= 3, "need at least 3 determinable layers: {mins:?}");
    // The paper's central claim: not all layers need the same bits.
    // (Weak form — strict inequality may collapse on tiny eval subsets.)
    let lo = known.iter().min().unwrap();
    let hi = known.iter().max().unwrap();
    assert!(hi >= lo);
}

#[test]
fn single_layer_quantization_hurts_less_than_whole_net() {
    let (dir, mut coord) = setup();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let nl = m.n_layers();
    let harsh = 2i8;
    let base = coord
        .eval_one(EvalJob { net: "lenet".into(), cfg: PrecisionConfig::fp32(nl), n_images: N })
        .unwrap();
    let one = perlayer::single_layer_cfg(nl, 0, Param::DataI, harsh);
    let acc_one = coord
        .eval_one(EvalJob { net: "lenet".into(), cfg: one, n_images: N })
        .unwrap();
    let all = uniform::uniform_cfg(nl, Param::DataI, harsh);
    let acc_all =
        coord.eval_one(EvalJob { net: "lenet".into(), cfg: all, n_images: N }).unwrap();
    assert!(acc_one >= acc_all, "one-layer {acc_one} vs all-layers {acc_all} (base {base})");
}

#[test]
fn greedy_descent_reduces_traffic_and_respects_floors() {
    let (dir, mut coord) = setup();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let start = PrecisionConfig::uniform(
        m.n_layers(),
        qbound::quant::QFormat::new(1, 8),
        qbound::quant::QFormat::new(10, 2),
    );
    let opts = GreedyOptions {
        n_images: N,
        descent: DescentOptions::default(),
        stop_rel_err: 0.5,
        max_iters: 25,
        mode: Mode::Batch(64),
        ..Default::default()
    };
    let res = greedy::descend(&mut coord, &m, start.clone(), &opts).unwrap();
    assert!(res.visited.len() > 5, "descent made progress: {}", res.visited.len());
    // traffic strictly decreases along the chosen trajectory
    for w in res.visited.windows(2) {
        assert!(
            w[1].traffic_ratio < w[0].traffic_ratio,
            "traffic must shrink every step: {} -> {}",
            w[0].traffic_ratio,
            w[1].traffic_ratio
        );
    }
    // floors respected everywhere
    for v in &res.explored {
        for q in &v.cfg.dq {
            assert!(q.ibits >= 1 && q.fbits >= 0);
        }
        for q in &v.cfg.wq {
            assert!(q.ibits == 1 && q.fbits >= 1);
        }
    }
}

#[test]
fn table2_rows_verify_against_fresh_evaluation() {
    let (dir, mut coord) = setup();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    // data F=4: the synthetic glyphs carry sub-0.25 pixel detail, so the
    // fraction floor for a within-5% start sits higher than MNIST's.
    let start = PrecisionConfig::uniform(
        m.n_layers(),
        qbound::quant::QFormat::new(1, 8),
        qbound::quant::QFormat::new(10, 4),
    );
    let opts = GreedyOptions {
        n_images: N,
        stop_rel_err: 0.3,
        max_iters: 40,
        ..Default::default()
    };
    let res = greedy::descend(&mut coord, &m, start, &opts).unwrap();
    let rows = table2::select(&res.visited, &[0.05]);
    let row = rows[0].as_ref().expect("a 5% config must exist");
    // Re-evaluate the selected config from scratch: accuracy must agree.
    let again = coord
        .eval_one(EvalJob { net: "lenet".into(), cfg: row.cfg.clone(), n_images: N })
        .unwrap();
    assert!((again - row.accuracy).abs() < 1e-9);
    // Traffic ratio recomputes identically.
    let tr = traffic::traffic_ratio(&m, Mode::Batch(64), &row.cfg);
    assert!((tr - row.traffic_ratio).abs() < 1e-12);
    assert!(tr < 1.0, "selected config must actually reduce traffic");
}

#[test]
fn find_uniform_start_is_accurate() {
    let (dir, mut coord) = setup();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let start = greedy::find_uniform_start(&mut coord, &m, 0.001, None, N).unwrap();
    let base = coord
        .eval_one(EvalJob {
            net: "lenet".into(),
            cfg: PrecisionConfig::fp32(m.n_layers()),
            n_images: N,
        })
        .unwrap();
    let acc = coord
        .eval_one(EvalJob { net: "lenet".into(), cfg: start.clone(), n_images: N })
        .unwrap();
    assert!(
        (base - acc) / base <= 0.011,
        "start {start} rel err {} too high",
        (base - acc) / base
    );
    assert!(start.any_quantized());
}
