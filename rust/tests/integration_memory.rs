//! The measured memory bound: run both storage modes of the fast
//! backend under a counting global allocator and prove that `--storage
//! packed` actually shrinks the process — whole-model (weights +
//! activations) peak live bytes strictly below the f32 run and inside
//! the `FootprintModel` envelope — rather than just modeling the
//! savings. This is the test infrastructure that turns FOOTPRINT.json
//! from a model into a measurement, and the same envelope backs the CI
//! `check-mem` regression gate.
//!
//! Meter state is process-global, so every test here serializes on one
//! mutex and asserts with slack for harness noise. Thread-count
//! determinism of the fused path rides along (it allocates, so it holds
//! the same lock).

use std::sync::Mutex;

use qbound::backend::fast::{packed_weight_bytes, FastBackend};
use qbound::backend::lowering::{self, LoweredPlan};
use qbound::backend::reference::ReferenceBackend;
use qbound::backend::{Backend, Variant};
use qbound::eval::Dataset;
use qbound::memory::{FootprintModel, PackedBuf, StorageMode};
use qbound::nets::{arch, ArtifactIndex, NetManifest};
use qbound::quant::QFormat;
use qbound::search::space::PrecisionConfig;
use qbound::testkit::{self, MeterAlloc};

#[global_allocator]
static METER: MeterAlloc = MeterAlloc;

static SERIAL: Mutex<()> = Mutex::new(());

/// Images per measured infer call.
const MEM_BATCH: usize = 4;
/// Allowance for harness noise and allocator bookkeeping around the
/// modeled quantities (the asserted margins are tens to hundreds of KiB).
const SLACK: f64 = 16.0 * 1024.0;

/// An 8-bit-wide everywhere config: storage widths are exactly 1 byte
/// per value, so modeled bytes are easy to reason about.
fn cfg8(nl: usize) -> PrecisionConfig {
    PrecisionConfig::uniform(nl, QFormat::new(1, 7), QFormat::new(5, 3))
}

#[test]
fn packed_peak_is_below_f32_and_inside_the_model_envelope() {
    let _g = SERIAL.lock().unwrap();
    let dir = testkit::ensure_artifacts();
    let idx = ArtifactIndex::load(&dir).unwrap();
    for net in &idx.nets {
        let m = NetManifest::load(&dir, net).unwrap();
        let d = Dataset::load(&m).unwrap();
        let n = MEM_BATCH.min(d.n);
        let imgs = d.batch_images(0, n).to_vec();
        drop(d);
        let cfg = cfg8(m.n_layers());
        let (wq, dq) = (cfg.wire_wq(), cfg.wire_dq());
        let plan = LoweredPlan::new(&arch::get(net).unwrap(), None).unwrap();
        let fpm = FootprintModel::new(&m);

        // (resident after warm-up, peak of a warm infer, churn of a warm
        // infer), all as deltas from the pre-load live level.
        let measure = |storage: StorageMode| -> (f64, f64, f64) {
            let base = MeterAlloc::live_bytes() as f64;
            let backend = FastBackend::with_options(1, storage);
            let mut exec = backend.load(&m, Variant::Standard).unwrap();
            std::hint::black_box(exec.infer(&imgs, &wq, &dq, None).unwrap());
            let resident = MeterAlloc::live_bytes() as f64 - base;
            MeterAlloc::reset_peak();
            let pre = MeterAlloc::live_bytes() as f64;
            std::hint::black_box(exec.infer(&imgs, &wq, &dq, None).unwrap());
            let peak = MeterAlloc::peak_bytes() as f64 - base;
            let churn = MeterAlloc::peak_bytes() as f64 - pre;
            (resident, peak, churn)
        };
        let (r_f32, p_f32, _) = measure(StorageMode::F32);
        let (r_pk, p_pk, churn_pk) = measure(StorageMode::Packed);

        // Headline: both the steady state and the in-flight peak of the
        // whole-model packed run (weights + activations) are strictly
        // below the f32 run.
        assert!(r_pk < r_f32, "{net}: packed resident {r_pk} >= f32 {r_f32}");
        assert!(p_pk < p_f32, "{net}: packed peak {p_pk} >= f32 peak {p_f32}");

        // Envelope: the f32 path's two max-sized arenas AND its f32
        // weight set (panels incl. NR padding + biases, 4 B/elem) must
        // be gone, replaced by at most the modeled whole-model envelope
        // — packed weights + peak act bitstreams + panel padding + the
        // f32 decode/bias windows and weight-strip cache (everything
        // else — fp32 master params, col/tmp scratch — is identical
        // between the modes).
        let arenas = 8.0 * plan.max_act_elems as f64; // 2 arenas x 4 B/elem
        let w_f32 = 4.0 * (plan.panel_param_elems + plan.bias_param_elems) as f64;
        let envelope =
            fpm.fused_envelope(&cfg, plan.fused_window_elems(1), &plan.weight_pad_elems);
        assert!(
            r_pk <= r_f32 - arenas - w_f32 + envelope + SLACK,
            "{net}: packed residency {r_pk} outside the model envelope \
             (f32 {r_f32}, arenas {arenas}, f32 weights {w_f32}, envelope {envelope})"
        );

        // Transient churn of one fused infer is bounded by the plan's
        // fused f32 high-water plus the logits block (and the
        // decoded-weight-strip cache, which fills lazily on the first
        // warm streamed 1×1 GEMM).
        let churn_bound = 4.0
            * (plan.max_fused_elems + plan.strip_cache_elems + n * m.num_classes) as f64
            + SLACK;
        assert!(
            churn_pk <= churn_bound,
            "{net}: fused infer churn {churn_pk} > bound {churn_bound}"
        );
    }
}

#[test]
fn fused_path_is_bit_deterministic_across_thread_counts_on_every_arch() {
    let _g = SERIAL.lock().unwrap();
    let dir = testkit::ensure_artifacts();
    let idx = ArtifactIndex::load(&dir).unwrap();
    for net in &idx.nets {
        let m = NetManifest::load(&dir, net).unwrap();
        let d = Dataset::load(&m).unwrap();
        let n = 6.min(d.n);
        let imgs = &d.images[..n * d.image_elems];
        let cfg = cfg8(m.n_layers());
        let (wq, dq) = (cfg.wire_wq(), cfg.wire_dq());
        let mut base: Option<Vec<f32>> = None;
        for threads in [1usize, 2, 5] {
            let backend = FastBackend::with_options(threads, StorageMode::Packed);
            let mut exec = backend.load(&m, Variant::Standard).unwrap();
            let logits = exec.infer(imgs, &wq, &dq, None).unwrap();
            match &base {
                None => base = Some(logits),
                Some(want) => assert!(
                    want.iter().zip(&logits).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{net}: fused path changed bits at threads={threads}"
                ),
            }
        }
        // And the reference backend's fused loop agrees numerically.
        let mut rexec = ReferenceBackend::with_storage(StorageMode::Packed)
            .load(&m, Variant::Standard)
            .unwrap();
        let rlogits = rexec.infer(imgs, &wq, &dq, None).unwrap();
        let want = base.unwrap();
        for (i, (a, b)) in want.iter().zip(&rlogits).enumerate() {
            assert!(
                (a - b).abs() == 0.0,
                "{net}: fused fast/reference logit {i} differs: {a} vs {b}"
            );
        }
    }
}

#[test]
fn eval_split_spill_shrinks_the_resident_input_set() {
    let _g = SERIAL.lock().unwrap();
    let dir = testkit::ensure_artifacts();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let fmt = QFormat::new(5, 3); // 8-bit input codes
    let base = MeterAlloc::live_bytes();
    let d = Dataset::load(&m).unwrap();
    let with_f32 = MeterAlloc::live_bytes() - base;
    let (n, elems) = (d.n, d.image_elems);
    let (split, labels) = d.into_packed(fmt);
    let with_packed = MeterAlloc::live_bytes() - base;
    assert_eq!(split.n(), n);
    assert_eq!(labels.len(), n);
    // 8-bit codes: one byte per element, plus word rounding.
    assert!(split.packed_bytes() <= n * elems + 8);
    assert!(
        (with_packed as f64) < with_f32 as f64 / 2.0,
        "packed split {with_packed} not below half of f32 split {with_f32}"
    );
    // Served batches decode to exactly the quantized images (fresh
    // dataset load for the reference values — outside the measurement).
    let d2 = Dataset::load(&m).unwrap();
    let want = qbound::testkit::quantized_canonical(fmt, &d2.images);
    let mut out = Vec::new();
    split.unpack_batch(1, 2, &mut out);
    assert_eq!(out.len(), 2 * elems);
    for (a, b) in out.iter().zip(&want[2 * elems..4 * elems]) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn packed_weight_bytes_below_f32_on_every_arch() {
    // The weight half of the bound, asserted directly: the bitstream
    // weight set a fused executor memoizes (panels incl. NR padding +
    // biases) must undercut the f32 weight set and land on the modeled
    // weight term plus padding.
    let _g = SERIAL.lock().unwrap();
    let dir = testkit::ensure_artifacts();
    let idx = ArtifactIndex::load(&dir).unwrap();
    for net in &idx.nets {
        let m = NetManifest::load(&dir, net).unwrap();
        let plan = LoweredPlan::new(&arch::get(net).unwrap(), None).unwrap();
        let params = lowering::load_network(&m, Variant::Standard).unwrap().params;
        let cfg = cfg8(m.n_layers());
        let f32_bytes = 4 * (plan.panel_param_elems + plan.bias_param_elems);
        let packed = packed_weight_bytes(&plan, &params, &cfg.wq);
        assert!(packed < f32_bytes, "{net}: packed weights {packed} >= f32 {f32_bytes}");
        // The plan-only pricing (what eval --mem-json records) must
        // equal the real packing, tensor-for-tensor.
        assert_eq!(packed, plan.packed_weight_bytes(&cfg.wq), "{net}");
        // 8-bit formats: exactly a quarter, modulo per-tensor byte
        // rounding.
        assert!(
            packed <= f32_bytes / 4 + 4 * params.len(),
            "{net}: packed {packed} not ~1/4 of f32 {f32_bytes}"
        );
        // Realized = modeled weight term + the NR-lane panel padding.
        let fpm = FootprintModel::new(&m);
        let pad_bytes: f64 = plan.weight_pad_elems.iter().map(|&e| e as f64).sum(); // 8-bit
        let modeled = fpm.footprint(&cfg).weight_bytes + pad_bytes;
        assert!(
            (packed as f64 - modeled).abs() <= 4.0 * params.len() as f64,
            "{net}: packed {packed} vs modeled weights+padding {modeled}"
        );
    }
}

#[test]
fn packed_buffers_realize_the_modeled_layer_bytes() {
    let _g = SERIAL.lock().unwrap();
    let dir = testkit::ensure_artifacts();
    let idx = ArtifactIndex::load(&dir).unwrap();
    for net in &idx.nets {
        let m = NetManifest::load(&dir, net).unwrap();
        let cfg = cfg8(m.n_layers());
        let fpm = FootprintModel::new(&m);
        for (l, lf) in fpm.per_layer(&cfg).iter().enumerate() {
            let out_elems = m.layers[l].out_elems as usize;
            let realized = PackedBuf::pack(cfg.dq[l], &vec![0.0f32; out_elems]).packed_bytes();
            assert!(
                (realized as f64 - lf.out_bytes).abs() < 8.0,
                "{net} layer {l}: realized {realized} vs modeled {}",
                lf.out_bytes
            );
        }
    }
}
