//! Property tests over the Q(I.F) quantizer (testkit harness): the
//! invariants that make the format sound regardless of input, plus the
//! cross-implementation locks — the host quantizer must match both the
//! independent f64 oracle and the `golden_quant.ntf` vectors bit-for-bit,
//! and the fp32 sentinel must be an exact pass-through.

use qbound::artifacts::golden_quantize;
use qbound::quant::QFormat;
use qbound::testkit::{all, cases, forall, gen_f32, gen_i64, gen_vec, prop, Gen, GenPair};

/// Generator for sane (I, F) formats: I in [0, 16], F in [0, 14], I+F ≥ 1.
struct GenFormat;

impl Gen for GenFormat {
    type Value = QFormat;

    fn generate(&self, rng: &mut qbound::prng::Xoshiro256pp) -> QFormat {
        loop {
            let i = rng.range_i64(0, 16) as i8;
            let f = rng.range_i64(0, 14) as i8;
            if i + f >= 1 {
                return QFormat::new(i, f);
            }
        }
    }

    fn shrink(&self, v: &QFormat) -> Vec<QFormat> {
        let mut out = Vec::new();
        if v.ibits > 1 {
            out.push(QFormat::new(v.ibits - 1, v.fbits));
        }
        if v.fbits > 0 && v.ibits >= 1 {
            out.push(QFormat::new(v.ibits, v.fbits - 1));
        }
        out
    }
}

#[test]
fn quantize_always_lands_in_range() {
    forall(cases(2000), GenPair(GenFormat, gen_f32(-1e6, 1e6)), |(fmt, x)| {
        let q = fmt.quantize(*x);
        let (lo, hi) = fmt.range();
        prop(q >= lo && q <= hi, &format!("q({x}) = {q} outside [{lo}, {hi}] for {fmt}"))
    });
}

#[test]
fn quantize_is_idempotent() {
    forall(cases(2000), GenPair(GenFormat, gen_f32(-1e4, 1e4)), |(fmt, x)| {
        let once = fmt.quantize(*x);
        let twice = fmt.quantize(once);
        prop(once.to_bits() == twice.to_bits(), &format!("{fmt}: {once} re-quantized to {twice}"))
    });
}

#[test]
fn quantize_is_monotone() {
    forall(
        cases(2000),
        GenPair(GenFormat, GenPair(gen_f32(-100.0, 100.0), gen_f32(-100.0, 100.0))),
        |(fmt, (a, b))| {
            let (lo, hi) = (a.min(*b), a.max(*b));
            prop(
                fmt.quantize(lo) <= fmt.quantize(hi),
                &format!("{fmt}: q({lo}) > q({hi})"),
            )
        },
    );
}

#[test]
fn quantize_error_bounded_by_half_step_inside_range() {
    forall(cases(2000), GenPair(GenFormat, gen_f32(-30.0, 30.0)), |(fmt, x)| {
        let (lo, hi) = fmt.range();
        if *x < lo || *x > hi {
            return prop(true, "");
        }
        let err = (fmt.quantize(*x) - x).abs();
        prop(
            err <= fmt.step() / 2.0 + 1e-6,
            &format!("{fmt}: |q({x}) - {x}| = {err} > step/2 = {}", fmt.step() / 2.0),
        )
    });
}

#[test]
fn quantized_values_are_exact_grid_multiples() {
    forall(cases(2000), GenPair(GenFormat, gen_f32(-50.0, 50.0)), |(fmt, x)| {
        let q = fmt.quantize(*x);
        // q * 2^F must be an integer (exactly representable in f64)
        let scaled = q as f64 * (fmt.fbits as f64).exp2();
        prop(
            (scaled - scaled.round()).abs() < 1e-6,
            &format!("{fmt}: q({x}) = {q} not on the grid (scaled {scaled})"),
        )
    });
}

#[test]
fn widening_fraction_never_increases_error() {
    forall(
        cases(1500),
        GenPair(GenFormat, gen_f32(-10.0, 10.0)),
        |(fmt, x)| {
            if fmt.fbits >= 14 {
                return prop(true, "");
            }
            let wider = QFormat::new(fmt.ibits, fmt.fbits + 1);
            let (lo, hi) = fmt.range();
            if *x < lo || *x > hi {
                return prop(true, ""); // saturation region: range also moves
            }
            let e0 = (fmt.quantize(*x) - x).abs();
            let e1 = (wider.quantize(*x) - x).abs();
            prop(e1 <= e0 + 1e-7, &format!("{fmt}->+1F: err {e0} -> {e1} at {x}"))
        },
    );
}

#[test]
fn bits_and_levels_consistent() {
    forall(cases(500), GenFormat, |fmt| {
        all([
            prop(fmt.bits() == (fmt.ibits + fmt.fbits) as u32, "bits = I + F"),
            prop(
                fmt.levels() == Some(1u64 << fmt.bits()),
                &format!("{fmt}: levels {:?} != 2^bits", fmt.levels()),
            ),
        ])
    });
}

#[test]
fn parse_display_roundtrip_property() {
    forall(cases(500), GenFormat, |fmt| {
        let s = fmt.to_string();
        match QFormat::parse(&s) {
            Ok(back) => prop(back == *fmt, &format!("{s} parsed to {back}")),
            Err(e) => prop(false, &format!("{s} failed to parse: {e}")),
        }
    });
}

#[test]
fn wire_roundtrip_preserves_semantics() {
    forall(cases(800), GenPair(GenFormat, gen_f32(-20.0, 20.0)), |(fmt, x)| {
        let w = fmt.wire();
        // reconstruct from wire floats as the kernel does
        let back = QFormat::new(w[0] as i8, w[1] as i8);
        prop(
            back.quantize(*x).to_bits() == fmt.quantize(*x).to_bits(),
            "wire roundtrip changed semantics",
        )
    });
}

#[test]
fn quantize_slice_matches_scalar_bit_for_bit() {
    // The vectorized fast path (clamp-then-magic-round, I+F ≤ 23) and
    // the wide-format fallback must both replay the scalar quantizer
    // exactly, bit for bit — GenFormat spans I+F up to 30, so both
    // paths are exercised.
    forall(
        cases(1500),
        GenPair(GenFormat, gen_vec(gen_f32(-1e6, 1e6), 0, 48)),
        |(fmt, xs)| {
            let mut ys = xs.clone();
            fmt.quantize_slice(&mut ys);
            for (x, y) in xs.iter().zip(&ys) {
                let want = fmt.quantize(*x);
                if want.to_bits() != y.to_bits() {
                    return prop(
                        false,
                        &format!("{fmt}: slice q({x:e}) = {y:e} != scalar {want:e}"),
                    );
                }
            }
            prop(true, "")
        },
    );
}

#[test]
fn quantize_slice_specials_bit_for_bit() {
    // Signed zeros, ties, saturation and non-finite inputs through both
    // slice paths.
    let specials = [
        0.0f32,
        -0.0,
        0.5,
        -0.5,
        1.5,
        2.5,
        -2.5,
        0.375,
        -0.125,
        7.75,
        -8.0,
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::MAX,
        f32::MIN,
        1e-30,
        -1e-30,
    ];
    for fmt in [
        QFormat::new(1, 8),
        QFormat::new(0, 3),
        QFormat::new(8, 0),
        QFormat::new(12, 2),
        QFormat::new(16, 14), // I+F > 23: scalar fallback path
        QFormat::FP32,
    ] {
        let mut ys = specials.to_vec();
        fmt.quantize_slice(&mut ys);
        for (x, y) in specials.iter().zip(&ys) {
            let want = fmt.quantize(*x);
            assert_eq!(
                want.to_bits(),
                y.to_bits(),
                "{fmt}: slice q({x:e}) = {y:e} != scalar {want:e}"
            );
        }
    }
}

/// Generator restricted to golden-range formats (I+F ≤ 16: every grid
/// point is exactly representable in f32, so the f32 host path and the
/// f64 oracle must agree bit-for-bit).
struct GenGoldenFormat;

impl Gen for GenGoldenFormat {
    type Value = QFormat;

    fn generate(&self, rng: &mut qbound::prng::Xoshiro256pp) -> QFormat {
        loop {
            let i = rng.range_i64(0, 16) as i8;
            let f = rng.range_i64(0, 14) as i8;
            if i + f >= 1 && i + f <= 16 {
                return QFormat::new(i, f);
            }
        }
    }
}

#[test]
fn host_quantizer_matches_independent_oracle() {
    forall(cases(4000), GenPair(GenGoldenFormat, gen_f32(-1e5, 1e5)), |(fmt, x)| {
        let host = fmt.quantize(*x);
        let oracle = golden_quantize(*x, fmt.ibits as i32, fmt.fbits as i32);
        prop(
            host.to_bits() == oracle.to_bits() || (host == 0.0 && oracle == 0.0),
            &format!("{fmt}: host q({x:e}) = {host:e} != oracle {oracle:e}"),
        )
    });
}

#[test]
fn fp32_sentinel_is_exact_passthrough() {
    forall(cases(4000), gen_f32(-1e38, 1e38), |&x| {
        let q = QFormat::FP32.quantize(x);
        prop(q.to_bits() == x.to_bits(), &format!("sentinel altered {x:e} -> {q:e}"))
    });
    // negative zero and subnormals too
    for x in [-0.0f32, f32::MIN_POSITIVE / 2.0, -f32::MIN_POSITIVE / 2.0] {
        assert_eq!(QFormat::FP32.quantize(x).to_bits(), x.to_bits());
    }
}

#[test]
fn golden_file_vectors_replay_bit_for_bit() {
    // The artifact set carries oracle-computed q(x) vectors; the host
    // quantizer must replay every one exactly (same lock the python
    // side enforces against the Pallas kernel).
    let dir = qbound::testkit::ensure_artifacts();
    let golden = qbound::tensor::ntf::read_file(&dir.join("golden_quant.ntf")).unwrap();
    let x = golden["x"].as_f32().unwrap();
    let mut formats = 0;
    for (name, expect) in &golden {
        let Some(spec) = name.strip_prefix("q_") else { continue };
        if spec == "sentinel" {
            continue; // covered by fp32_sentinel_is_exact_passthrough
        }
        let (i, f) = spec.split_once('_').unwrap();
        let fmt = QFormat::new(i.parse().unwrap(), f.parse().unwrap());
        for (&xi, &ei) in x.iter().zip(expect.as_f32().unwrap()) {
            let got = fmt.quantize(xi);
            assert!(
                got.to_bits() == ei.to_bits() || (got == 0.0 && ei == 0.0),
                "{name}: q({xi:e}) = {got:e} != {ei:e}"
            );
        }
        formats += 1;
    }
    assert!(formats >= 40, "only {formats} formats in golden file");
}

#[test]
fn saturation_rate_increases_as_integer_bits_shrink() {
    // statistical property over a fixed heavy-tailed sample
    let mut rng = qbound::prng::Xoshiro256pp::new(5);
    let xs: Vec<f32> = (0..4096).map(|_| (rng.normal() * 8.0) as f32).collect();
    let sat = |i: i8| {
        let fmt = QFormat::new(i, 4);
        qbound::quant::metrics::quant_error(fmt, &xs).sat_rate
    };
    forall(cases(12), gen_i64(1, 7), |&i| {
        prop(
            sat(i as i8) >= sat(i as i8 + 1) - 1e-12,
            &format!("sat({i}) < sat({})", i + 1),
        )
    });
}
