//! Cross-backend parity: the fast (im2col + GEMM) backend must agree
//! with the reference interpreter on every registered architecture, in
//! both variants.
//!
//! Tolerance contract: backends may differ by fp32 accumulation order
//! only. The fast GEMM preserves the interpreter's ascending-k
//! accumulation, so in practice logits match to the bit (up to the sign
//! of zeros where im2col materializes padding); the assertions below
//! allow `MAX_ABS_TOL` of drift so future kernels that genuinely
//! reorder accumulation (packed SIMD, split-k) stay admissible, and
//! additionally require top-1 agreement on every row.

use std::sync::Mutex;

use qbound::backend::fast::FastBackend;
use qbound::backend::kernels::{self, KernelKind};
use qbound::backend::{Backend, BackendKind, NetExecutor, Variant};
use qbound::eval::Dataset;
use qbound::memory::StorageMode;
use qbound::nets::{ArtifactIndex, NetManifest};
use qbound::quant::QFormat;
use qbound::search::space::PrecisionConfig;
use qbound::testkit;

/// [`kernels::force`] is process-global; the variant sweep serializes on
/// this lock. The other tests here run lock-free: every variant is
/// bit-identical by contract, so a concurrent force can't change them.
static KERNEL_LOCK: Mutex<()> = Mutex::new(());

/// Documented cross-backend logit tolerance (fp32 accumulation order).
const MAX_ABS_TOL: f32 = 1e-4;

/// Images per parity batch — deliberately ≠ the manifest batch, so the
/// variable-batch path is exercised on both backends.
const PARITY_IMAGES: usize = 16;

fn artifacts() -> std::path::PathBuf {
    testkit::ensure_artifacts()
}

fn top1_rows(logits: &[f32], classes: usize) -> Vec<usize> {
    logits
        .chunks(classes)
        .map(|row| {
            let mut best = 0;
            for (i, v) in row.iter().enumerate() {
                if *v > row[best] {
                    best = i;
                }
            }
            best
        })
        .collect()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
}

/// The configs every (net, variant) pair is checked under: fp32,
/// a healthy uniform quantization, and a mixed per-layer assignment.
fn parity_configs(nl: usize) -> Vec<(&'static str, PrecisionConfig)> {
    let mut mixed = PrecisionConfig::fp32(nl);
    for l in 0..nl {
        mixed.wq[l] = if l % 2 == 0 { QFormat::new(1, 8) } else { QFormat::new(2, 7) };
        mixed.dq[l] = if l % 3 == 0 { QFormat::new(10, 3) } else { QFormat::new(9, 4) };
    }
    vec![
        ("fp32", PrecisionConfig::fp32(nl)),
        ("uniform", PrecisionConfig::uniform(nl, QFormat::new(1, 8), QFormat::new(10, 2))),
        ("mixed", mixed),
    ]
}

fn assert_parity(net: &str, label: &str, classes: usize, a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "{net}/{label}: logit count");
    let diff = max_abs_diff(a, b);
    assert!(
        diff <= MAX_ABS_TOL,
        "{net}/{label}: max-abs logit diff {diff} > {MAX_ABS_TOL}"
    );
    assert_eq!(
        top1_rows(a, classes),
        top1_rows(b, classes),
        "{net}/{label}: top-1 disagreement"
    );
}

#[test]
fn fast_matches_reference_on_every_arch_standard() {
    let dir = artifacts();
    let idx = ArtifactIndex::load(&dir).unwrap();
    let reference = BackendKind::Reference.create().unwrap();
    let fast = BackendKind::Fast.create().unwrap();
    for net in &idx.nets {
        let m = NetManifest::load(&dir, net).unwrap();
        let d = Dataset::load(&m).unwrap();
        let mut rexec = reference.load(&m, Variant::Standard).unwrap();
        let mut fexec = fast.load(&m, Variant::Standard).unwrap();
        assert_eq!(fexec.max_batch(), usize::MAX, "{net}: fast must take any batch");
        let n = PARITY_IMAGES.min(d.n);
        let imgs = &d.images[..n * d.image_elems];
        for (label, cfg) in parity_configs(m.n_layers()) {
            let (wq, dq) = (cfg.wire_wq(), cfg.wire_dq());
            let a = rexec.infer(imgs, &wq, &dq, None).unwrap();
            let b = fexec.infer(imgs, &wq, &dq, None).unwrap();
            assert_eq!(a.len(), n * m.num_classes, "{net}/{label}: variable batch");
            assert_parity(net, label, m.num_classes, &a, &b);
        }
    }
}

#[test]
fn fast_matches_reference_on_stage_variants() {
    let dir = artifacts();
    let idx = ArtifactIndex::load(&dir).unwrap();
    let reference = BackendKind::Reference.create().unwrap();
    let fast = BackendKind::Fast.create().unwrap();
    let mut covered = 0;
    for net in &idx.nets {
        let m = NetManifest::load(&dir, net).unwrap();
        let Some(sv) = m.stage_variant.clone() else { continue };
        covered += 1;
        let d = Dataset::load(&m).unwrap();
        let mut rexec = reference.load(&m, Variant::Stages).unwrap();
        let mut fexec = fast.load(&m, Variant::Stages).unwrap();
        let n = PARITY_IMAGES.min(d.n);
        let imgs = &d.images[..n * d.image_elems];
        let sentinel: Vec<f32> = (0..sv.n_stages).flat_map(|_| [-1.0f32, 0.0]).collect();
        let mut harsh = sentinel.clone();
        harsh[0] = 4.0; // stage 0 data -> Q(4.4)
        harsh[1] = 4.0;
        for (label, cfg) in parity_configs(m.n_layers()) {
            let (wq, dq) = (cfg.wire_wq(), cfg.wire_dq());
            for (slabel, sq) in [("sentinel", &sentinel), ("harsh", &harsh)] {
                let a = rexec.infer(imgs, &wq, &dq, Some(sq)).unwrap();
                let b = fexec.infer(imgs, &wq, &dq, Some(sq)).unwrap();
                assert_parity(net, &format!("{label}/{slabel}"), m.num_classes, &a, &b);
            }
        }
    }
    assert!(covered >= 1, "no stage variant in the artifact set");
}

#[test]
fn fast_is_bit_deterministic_across_thread_counts() {
    // Image partitioning and GEMM row-block splitting must not change a
    // single bit — rows are independent and accumulation order is fixed.
    let dir = artifacts();
    for net in ["lenet", "googlenet"] {
        let m = NetManifest::load(&dir, net).unwrap();
        let d = Dataset::load(&m).unwrap();
        let cfg =
            PrecisionConfig::uniform(m.n_layers(), QFormat::new(1, 8), QFormat::new(10, 2));
        let (wq, dq) = (cfg.wire_wq(), cfg.wire_dq());
        let n = 8.min(d.n);
        let imgs = &d.images[..n * d.image_elems];
        let mut base: Option<Vec<f32>> = None;
        for threads in [1usize, 2, 5] {
            let backend = FastBackend::with_threads(threads);
            let mut exec = backend.load(&m, Variant::Standard).unwrap();
            let logits = exec.infer(imgs, &wq, &dq, None).unwrap();
            match &base {
                None => base = Some(logits),
                Some(want) => {
                    assert!(
                        want.iter().zip(&logits).all(|(a, b)| a.to_bits() == b.to_bits()),
                        "{net}: threads={threads} changed bits"
                    );
                }
            }
        }
    }
}

#[test]
fn every_kernel_variant_matches_scalar_logits_bit_for_bit() {
    // End-to-end dispatch contract: on every registered architecture and
    // in both storage modes (f32 panels and packed bitstreams — the
    // latter exercises the SIMD unpacker), logits under each kernel
    // variant the host supports must equal the forced-scalar logits to
    // the bit. The sweep ignores `QBOUND_KERNEL` by design — it forces
    // every variant the CPU has, then restores the env-selected one.
    let _g = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = kernels::active_kind();
    let dir = artifacts();
    let idx = ArtifactIndex::load(&dir).unwrap();
    for net in &idx.nets {
        let m = NetManifest::load(&dir, net).unwrap();
        let d = Dataset::load(&m).unwrap();
        let cfg =
            PrecisionConfig::uniform(m.n_layers(), QFormat::new(1, 8), QFormat::new(10, 2));
        let (wq, dq) = (cfg.wire_wq(), cfg.wire_dq());
        let n = 8.min(d.n);
        let imgs = &d.images[..n * d.image_elems];
        for storage in [StorageMode::F32, StorageMode::Packed] {
            let backend = FastBackend::with_options(2, storage);
            let mut exec = backend.load(&m, Variant::Standard).unwrap();
            kernels::force(KernelKind::Scalar);
            let want = exec.infer(imgs, &wq, &dq, None).unwrap();
            for kind in kernels::available() {
                kernels::force(kind);
                let got = exec.infer(imgs, &wq, &dq, None).unwrap();
                assert!(
                    want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{net}: kernel {} changed bits under storage {}",
                    kind.label(),
                    storage.label()
                );
            }
        }
    }
    kernels::force(prev);
}

#[test]
fn fast_scratch_arenas_are_reused_consistently() {
    // Same executor, repeated calls with varying batch sizes: results
    // must not depend on what a previous call left in the arenas.
    let dir = artifacts();
    let m = NetManifest::load(&dir, "convnet").unwrap();
    let d = Dataset::load(&m).unwrap();
    let backend = BackendKind::Fast.create().unwrap();
    let mut exec = backend.load(&m, Variant::Standard).unwrap();
    let cfg = PrecisionConfig::uniform(m.n_layers(), QFormat::new(1, 6), QFormat::new(8, 3));
    let (wq, dq) = (cfg.wire_wq(), cfg.wire_dq());
    let one = &d.images[..d.image_elems];
    let first = exec.infer(one, &wq, &dq, None).unwrap();
    // big batch in between dirties every buffer
    let big = &d.images[..32 * d.image_elems];
    let bulk = exec.infer(big, &wq, &dq, None).unwrap();
    let again = exec.infer(one, &wq, &dq, None).unwrap();
    assert_eq!(first, again, "scratch reuse changed a repeated single-image result");
    assert_eq!(&bulk[..m.num_classes], &first[..], "row 0 of the bulk batch");
    assert_eq!(exec.executions(), 3);
}

#[test]
fn evaluator_accuracy_agrees_across_backends() {
    // The eval hot path (full-split batches on the fast backend vs
    // manifest-sized batches before) must produce identical accuracy.
    let dir = artifacts();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let cfg = PrecisionConfig::uniform(m.n_layers(), QFormat::new(1, 7), QFormat::new(9, 3));
    let mut accs = Vec::new();
    for kind in [BackendKind::Reference, BackendKind::Fast] {
        let backend = kind.create().unwrap();
        let mut ev = qbound::eval::Evaluator::new(backend.as_ref(), &m).unwrap();
        accs.push(ev.accuracy(&cfg, 0).unwrap());
    }
    assert!((accs[0] - accs[1]).abs() < 1e-12, "{accs:?}");
}
