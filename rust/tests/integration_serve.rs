//! Serving integration: a real `Server` on an ephemeral port, driven
//! over live TCP — correctness against the reference oracle, cache
//! hit/eviction accounting under a tight budget, budget refusal (507),
//! protocol error statuses, keep-alive pipelining, and the packed-weight
//! store behaviors (shared-mapping dedup pricing, kill/restart warm
//! start).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};

use qbound::backend::lowering::LoweredPlan;
use qbound::backend::BackendKind;
use qbound::eval::Dataset;
use qbound::memory::{FootprintModel, StorageMode};
use qbound::nets::{arch, NetManifest};
use qbound::quant::QFormat;
use qbound::search::space::PrecisionConfig;
use qbound::serve::{reference_prediction, ServeOptions, Server};
use qbound::store::Store;
use qbound::testkit;
use qbound::util::json::Json;

fn start(budget: f64) -> Server {
    let opts = ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        mem_budget_bytes: budget,
        ..ServeOptions::default()
    };
    Server::start(&testkit::ensure_artifacts(), &opts).unwrap()
}

/// The admission cost the daemon charges for one (net, cfg) executor.
fn envelope(net: &str, cfg: &PrecisionConfig) -> f64 {
    let dir = testkit::ensure_artifacts();
    let m = NetManifest::load(&dir, net).unwrap();
    let plan = LoweredPlan::new(&arch::get(net).unwrap(), None).unwrap();
    let win = plan.fused_window_elems(1);
    FootprintModel::new(&m).fused_envelope(cfg, win, &plan.weight_pad_elems)
}

fn lenet_cfg(wfmt: QFormat) -> PrecisionConfig {
    let dir = testkit::ensure_artifacts();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    PrecisionConfig::uniform(m.n_layers(), wfmt, QFormat::new(9, 2))
}

// ---- tiny blocking HTTP client ------------------------------------------

fn get(addr: SocketAddr, path: &str) -> (u16, Json) {
    let req = format!("GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(req.as_bytes()).unwrap();
    read_response(&mut BufReader::new(s))
}

fn post(addr: SocketAddr, path: &str, body: &str) -> (u16, Json) {
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(req.as_bytes()).unwrap();
    read_response(&mut BufReader::new(s))
}

fn classify_body(net: &str, wfmt: &str, index: usize) -> String {
    format!("{{\"net\":\"{net}\",\"weights\":\"{wfmt}\",\"data\":\"9.2\",\"index\":{index}}}")
}

fn read_response(r: &mut impl BufRead) -> (u16, Json) {
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let status: u16 = line.split_whitespace().nth(1).unwrap().parse().unwrap();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        assert!(r.read_line(&mut h).unwrap() > 0, "eof inside headers");
        let t = h.trim_end();
        if t.is_empty() {
            break;
        }
        if let Some(v) = t.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap();
        }
    }
    let mut body = vec![0u8; content_length];
    r.read_exact(&mut body).unwrap();
    if body.is_empty() {
        return (status, Json::Null);
    }
    (status, Json::parse(std::str::from_utf8(&body).unwrap()).unwrap())
}

// ---- tests --------------------------------------------------------------

#[test]
fn classify_over_tcp_matches_reference_backend() {
    let server = start(1024.0 * 1024.0 * 1024.0);
    let addr = server.addr();
    let dir = testkit::ensure_artifacts();
    let manifest = NetManifest::load(&dir, "lenet").unwrap();
    let dataset = Dataset::load(&manifest).unwrap();
    let oracle = BackendKind::Reference.create().unwrap();
    for (wfmt, index) in [(QFormat::new(1, 8), 3usize), (QFormat::new(2, 7), 11)] {
        let body = classify_body("lenet", &wfmt.to_string(), index);
        let (st, resp) = post(addr, "/v1/classify", &body);
        assert_eq!(st, 200, "{resp}");
        let pred = resp.get("pred").and_then(Json::as_usize).unwrap();
        let cfg = lenet_cfg(wfmt);
        let want = reference_prediction(&manifest, &dataset, oracle.as_ref(), &cfg, index).unwrap();
        assert_eq!(pred, want, "served answer diverges from the reference oracle ({body})");
        assert_eq!(resp.get("label").and_then(Json::as_f64).unwrap(), dataset.labels[index] as f64);
    }
    server.shutdown();
}

#[test]
fn repeat_config_is_a_cache_hit() {
    let server = start(1024.0 * 1024.0 * 1024.0);
    let addr = server.addr();
    let body = classify_body("lenet", "1.8", 0);
    let (st, first) = post(addr, "/v1/classify", &body);
    assert_eq!(st, 200);
    assert_eq!(first.get("cache").and_then(Json::as_str), Some("load"));
    let (st, second) = post(addr, "/v1/classify", &body);
    assert_eq!(st, 200);
    assert_eq!(second.get("cache").and_then(Json::as_str), Some("hit"));
    let (st, stats) = get(addr, "/v1/stats");
    assert_eq!(st, 200);
    let cache = stats.get("cache").unwrap();
    assert!(cache.get("hits").and_then(Json::as_u64).unwrap() >= 1, "{stats}");
    assert_eq!(cache.get("resident").and_then(Json::as_u64), Some(1));
    server.shutdown();
}

#[test]
fn tight_budget_evicts_lru_and_never_exceeds_resident_bound() {
    let a = lenet_cfg(QFormat::new(1, 8));
    let b = lenet_cfg(QFormat::new(2, 7));
    let (ea, eb) = (envelope("lenet", &a), envelope("lenet", &b));
    // Room for either executor alone, never both: A, B, A must evict twice.
    let budget = ea.max(eb) * 1.5;
    assert!(ea + eb > budget, "test premise: both configs can't be co-resident");
    let server = start(budget);
    let addr = server.addr();
    for wfmt in ["1.8", "2.7", "1.8"] {
        let (st, resp) = post(addr, "/v1/classify", &classify_body("lenet", wfmt, 0));
        assert_eq!(st, 200, "{resp}");
        assert_eq!(resp.get("cache").and_then(Json::as_str), Some("load"));
    }
    let (st, stats) = get(addr, "/v1/stats");
    assert_eq!(st, 200);
    let cache = stats.get("cache").unwrap();
    assert!(cache.get("evictions").and_then(Json::as_u64).unwrap() >= 2, "{stats}");
    assert!(cache.get("resident_bytes").and_then(Json::as_f64).unwrap() <= budget, "{stats}");
    server.shutdown();
}

#[test]
fn config_larger_than_budget_is_refused_with_507() {
    let packed = envelope("lenet", &lenet_cfg(QFormat::new(1, 8)));
    let dir = testkit::ensure_artifacts();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let fp32 = envelope("lenet", &PrecisionConfig::fp32(m.n_layers()));
    assert!(fp32 > packed, "fp32 weights must cost more than packed");
    // Midpoint budget: the packed config is admitted, fp32 is impossible.
    let server = start((packed + fp32) / 2.0);
    let addr = server.addr();
    let (st, resp) = post(addr, "/v1/classify", &classify_body("lenet", "1.8", 0));
    assert_eq!(st, 200, "{resp}");
    let (st, resp) = post(addr, "/v1/classify", "{\"net\":\"lenet\"}");
    assert_eq!(st, 507, "{resp}");
    // The refusal must not have evicted the resident executor.
    let (st, resp) = post(addr, "/v1/classify", &classify_body("lenet", "1.8", 1));
    assert_eq!(st, 200, "{resp}");
    assert_eq!(resp.get("cache").and_then(Json::as_str), Some("hit"));
    server.shutdown();
}

#[test]
fn protocol_and_routing_errors_map_to_statuses() {
    let server = start(1024.0 * 1024.0 * 1024.0);
    let addr = server.addr();
    assert_eq!(post(addr, "/v1/classify", "{not json").0, 400);
    assert_eq!(post(addr, "/v1/classify", "{\"net\":\"resnet152\"}").0, 404);
    assert_eq!(post(addr, "/v1/classify", "{\"net\":\"lenet\",\"weights\":\"bogus\"}").0, 400);
    assert_eq!(get(addr, "/v1/classify").0, 405);
    assert_eq!(post(addr, "/v1/stats", "{}").0, 405);
    assert_eq!(get(addr, "/nope").0, 404);
    // Declared body over the cap is refused at the header stage.
    let req = "POST /v1/classify HTTP/1.1\r\nHost: t\r\nContent-Length: 10000000\r\n\r\n";
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(req.as_bytes()).unwrap();
    let (st, _) = read_response(&mut BufReader::new(s));
    assert_eq!(st, 413);
    server.shutdown();
}

#[test]
fn healthz_and_nets_inventory() {
    let server = start(1024.0 * 1024.0 * 1024.0);
    let addr = server.addr();
    let (st, health) = get(addr, "/healthz");
    assert_eq!(st, 200);
    assert_eq!(health.get("ok").and_then(Json::as_bool), Some(true));
    let (st, nets) = get(addr, "/v1/nets");
    assert_eq!(st, 200);
    let Json::Arr(items) = &nets else { panic!("nets must be an array: {nets}") };
    let lenet = items
        .iter()
        .find(|j| j.get("net").and_then(Json::as_str) == Some("lenet"))
        .expect("lenet served");
    assert!(lenet.get("fp32_envelope_bytes").and_then(Json::as_f64).unwrap() > 0.0);
    server.shutdown();
}

// ---- packed-weight store behaviors --------------------------------------

/// A store-backed fast/packed server on a fresh per-test directory.
fn start_with_store(tag: &str, budget: f64) -> (Server, std::path::PathBuf) {
    let dir = std::env::temp_dir()
        .join(format!("qbound-serve-store-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let opts = ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        mem_budget_bytes: budget,
        backend: BackendKind::Fast,
        storage: StorageMode::Packed,
        store_dir: Some(dir.to_string_lossy().into_owned()),
        ..ServeOptions::default()
    };
    (Server::start(&testkit::ensure_artifacts(), &opts).unwrap(), dir)
}

#[test]
fn store_backed_executors_dedup_resident_weight_bytes() {
    let (server, store_dir) = start_with_store("dedup", 1024.0 * 1024.0 * 1024.0);
    let addr = server.addr();
    // Same net, same weight formats, different activation formats: two
    // executors, one physical weight mapping.
    for dfmt in ["9.2", "10.4"] {
        let body = format!(
            "{{\"net\":\"lenet\",\"weights\":\"1.8\",\"data\":\"{dfmt}\",\"index\":0}}"
        );
        let (st, resp) = post(addr, "/v1/classify", &body);
        assert_eq!(st, 200, "{resp}");
    }

    let dir = testkit::ensure_artifacts();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let plan = LoweredPlan::new(&arch::get("lenet").unwrap(), None).unwrap();
    let fpm = FootprintModel::new(&m);
    let mk = |d: QFormat| PrecisionConfig::uniform(m.n_layers(), QFormat::new(1, 8), d);
    let (cfg_a, cfg_b) = (mk(QFormat::new(9, 2)), mk(QFormat::new(10, 4)));
    let win = plan.fused_window_elems(1);
    let (ea, eb) = (
        fpm.fused_envelope(&cfg_a, win, &plan.weight_pad_elems),
        fpm.fused_envelope(&cfg_b, win, &plan.weight_pad_elems),
    );
    let shared = fpm.shared_weight_bytes(&cfg_a, &plan.weight_pad_elems);
    assert!(shared > 0.0);

    let (st, stats) = get(addr, "/v1/stats");
    assert_eq!(st, 200);
    let cache = stats.get("cache").unwrap();
    assert_eq!(cache.get("resident").and_then(Json::as_u64), Some(2), "{stats}");
    let resident = cache.get("resident_bytes").and_then(Json::as_f64).unwrap();
    let saved = cache.get("dedup_saved_bytes").and_then(Json::as_f64).unwrap();
    // The two executors are priced as one weight copy plus both
    // activation slices — not two full envelopes.
    assert!(
        (resident - (ea + eb - shared)).abs() < 1.0,
        "resident {resident} vs {ea}+{eb}-{shared} ({stats})"
    );
    assert!(resident <= ea + eb - 0.9 * shared, "dedup discount missing ({stats})");
    assert!((saved - shared).abs() < 1.0, "saved {saved} != shared {shared} ({stats})");

    // The store really holds live shared mappings for the process.
    let store = stats.get("store").unwrap();
    assert_eq!(store.get("enabled").and_then(Json::as_bool), Some(true), "{stats}");
    assert!(store.get("resident_shared_bytes").and_then(Json::as_f64).unwrap() > 0.0, "{stats}");
    assert!(store.get("packs").and_then(Json::as_f64).unwrap() > 0.0, "{stats}");
    server.shutdown();
    let _ = std::fs::remove_dir_all(&store_dir);
}

#[test]
fn killed_and_restarted_server_warm_starts_with_zero_packs() {
    let (server, store_dir) = start_with_store("restart", 1024.0 * 1024.0 * 1024.0);
    let addr = server.addr();
    let body = classify_body("lenet", "1.8", 5);
    let (st, first) = post(addr, "/v1/classify", &body);
    assert_eq!(st, 200, "{first}");
    let pred_before = first.get("pred").and_then(Json::as_usize).unwrap();
    // The daemon's answer matches the (store-free) reference oracle.
    let dir = testkit::ensure_artifacts();
    let manifest = NetManifest::load(&dir, "lenet").unwrap();
    let dataset = Dataset::load(&manifest).unwrap();
    let oracle = BackendKind::Reference.create().unwrap();
    let want = reference_prediction(
        &manifest,
        &dataset,
        oracle.as_ref(),
        &lenet_cfg(QFormat::new(1, 8)),
        5,
    )
    .unwrap();
    assert_eq!(pred_before, want);
    server.shutdown(); // the "kill": executors and mappings drop

    // The store is a per-directory singleton, so its lifetime counters
    // survive the server: packs must not move across the restart.
    let store = Store::open(&store_dir).unwrap();
    let packs_cold = store.stats().packs;
    assert!(packs_cold > 0, "cold server never packed");

    let opts = ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        mem_budget_bytes: 1024.0 * 1024.0 * 1024.0,
        backend: BackendKind::Fast,
        storage: StorageMode::Packed,
        store_dir: Some(store_dir.to_string_lossy().into_owned()),
        ..ServeOptions::default()
    };
    let server2 = Server::start(&testkit::ensure_artifacts(), &opts).unwrap();
    let (st, second) = post(server2.addr(), "/v1/classify", &body);
    assert_eq!(st, 200, "{second}");
    assert_eq!(
        second.get("pred").and_then(Json::as_usize),
        Some(pred_before),
        "restarted server answers differently"
    );
    assert_eq!(store.stats().packs, packs_cold, "warm restart re-packed weights");
    assert!(
        store.stats().hits_disk + store.stats().hits_shared > 0,
        "warm restart never loaded from the store"
    );
    server2.shutdown();
    let _ = std::fs::remove_dir_all(&store_dir);
}

// ---- precision autoscaling ----------------------------------------------

/// End-to-end autoscale walk: a synthetic (but real-config) lenet
/// ladder, a saturating burst that must degrade the active rung, a
/// drain that must recover it, the accuracy floor clamping off the
/// ladder's too-lossy tail, and every observed rung's answer checked
/// against the reference oracle running that rung's exact config.
#[test]
fn autoscaler_degrades_under_burst_recovers_after_drain_and_honors_floor() {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Mutex;
    use std::time::{Duration, Instant};

    use qbound::serve::autoscale::AutoscaleOptions;
    use qbound::serve::frontier::{Frontier, Rung};

    let dir = testkit::ensure_artifacts();
    let fdir = std::env::temp_dir()
        .join(format!("qbound-serve-autoscale-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&fdir);

    // Three rungs inside a 1% floor plus a fourth that busts it: the
    // loader must clamp the ladder to the first three. Accuracies are
    // fabricated (ladder shape is what's under test); the configs are
    // real, so served predictions can be oracle-checked per rung.
    let mk = |w: QFormat, rel: f64, fp: f64| Rung {
        cfg: lenet_cfg(w),
        accuracy: 0.95 * (1.0 - rel),
        rel_err: rel,
        footprint_ratio: fp,
        envelope_bytes: envelope("lenet", &lenet_cfg(w)),
    };
    let frontier = Frontier {
        net: "lenet".to_string(),
        baseline_accuracy: 0.95,
        rungs: vec![
            mk(QFormat::new(3, 8), 0.0, 1.0),
            mk(QFormat::new(2, 7), 0.004, 0.8),
            mk(QFormat::new(1, 6), 0.008, 0.6),
            mk(QFormat::new(1, 4), 0.05, 0.5),
        ],
    };
    frontier.save(&fdir.join(Frontier::file_name("lenet"))).unwrap();

    let opts = ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        // One worker + a two-slot queue: a concurrent burst pins the
        // occupancy fraction at 1.0 within a tick.
        workers: 1,
        queue_depth: 2,
        mem_budget_bytes: 1024.0 * 1024.0 * 1024.0,
        autoscale: Some(AutoscaleOptions {
            frontier_dir: fdir.to_string_lossy().into_owned(),
            accuracy_floor: 0.01,
            // A lone in-flight request (frac 0.5) sits in the dead band;
            // only the saturated burst (frac 1.0) reads as pressure.
            high_water: 0.6,
            low_water: 0.3,
            burst_ticks: 2,
            hysteresis_ticks: 2,
            tick_ms: 20,
            p99_slo_us: 0.0,
        }),
        ..ServeOptions::default()
    };
    let server = Server::start(&dir, &opts).unwrap();
    let addr = server.addr();

    // Quiet request: answered at rung 0, and the answer says so.
    let (st, resp) = post(addr, "/v1/classify", "{\"net\":\"lenet\",\"index\":0}");
    assert_eq!(st, 200, "{resp}");
    assert_eq!(resp.get("rung").and_then(Json::as_usize), Some(0), "{resp}");
    let (st, stats) = get(addr, "/v1/stats");
    assert_eq!(st, 200);
    assert_eq!(
        stats.at(&["autoscale", "nets", "lenet", "usable_rungs"]).as_u64(),
        Some(3),
        "the 5% rung must be clamped off by the 1% floor: {stats}"
    );

    // Burst phase: saturate the queue until /v1/stats shows a degrade,
    // then linger until an answer served at the narrow rung is in hand.
    let stop = AtomicBool::new(false);
    let observed: Mutex<Vec<(usize, usize, usize)>> = Mutex::new(Vec::new());
    let mut degraded = false;
    std::thread::scope(|s| {
        for _ in 0..6 {
            s.spawn(|| {
                let mut i = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let idx = i % 4;
                    i += 1;
                    let body = format!("{{\"net\":\"lenet\",\"index\":{idx}}}");
                    // 429 backpressure is expected while saturated.
                    let (st, resp) = post(addr, "/v1/classify", &body);
                    if st == 200 {
                        if let (Some(r), Some(p)) = (
                            resp.get("rung").and_then(Json::as_usize),
                            resp.get("pred").and_then(Json::as_usize),
                        ) {
                            observed.lock().unwrap().push((r, idx, p));
                        }
                    }
                }
            });
        }
        let deadline = Instant::now() + Duration::from_secs(60);
        while Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(25));
            let (st, stats) = get(addr, "/v1/stats");
            if st == 200
                && stats.at(&["autoscale", "nets", "lenet", "active_rung"]).as_u64()
                    >= Some(1)
            {
                degraded = true;
                break;
            }
        }
        let grace = Instant::now() + Duration::from_secs(10);
        while degraded && Instant::now() < grace {
            if observed.lock().unwrap().iter().any(|(r, _, _)| *r >= 1) {
                break;
            }
            std::thread::sleep(Duration::from_millis(25));
        }
        stop.store(true, Ordering::Relaxed);
    });
    assert!(degraded, "the burst never degraded the active rung");

    // Drain phase: no traffic — the hysteresis window must walk the
    // rung back to 0 and count at least one recovery.
    let mut recovered = false;
    let deadline = Instant::now() + Duration::from_secs(60);
    while Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(50));
        let (st, stats) = get(addr, "/v1/stats");
        if st == 200
            && stats.at(&["autoscale", "nets", "lenet", "active_rung"]).as_u64() == Some(0)
            && stats.at(&["autoscale", "recoveries"]).as_u64() >= Some(1)
        {
            recovered = true;
            break;
        }
    }
    assert!(recovered, "the drain never recovered the rung");

    // The floor guarantee, checked against the recorded transitions:
    // every rung the controller ever selected is inside the clamped
    // prefix, i.e. within 1% relative accuracy of fp32.
    let (st, stats) = get(addr, "/v1/stats");
    assert_eq!(st, 200);
    assert!(stats.at(&["autoscale", "degrades"]).as_u64() >= Some(1), "{stats}");
    let transitions = stats.at(&["autoscale", "transitions"]).as_arr().unwrap();
    assert!(!transitions.is_empty(), "{stats}");
    for t in transitions {
        let to = t.get("to").and_then(Json::as_usize).unwrap();
        assert!(to < 3, "rung {to} is past the floor-clamped prefix: {stats}");
        assert!(frontier.rungs[to].rel_err <= 0.01, "floor violated at rung {to}");
    }

    // Every observed rung's predictions match the reference oracle
    // running that rung's exact per-layer config.
    let samples = observed.into_inner().unwrap();
    assert!(
        samples.iter().any(|(r, _, _)| *r >= 1),
        "no answer was served at a degraded rung"
    );
    let manifest = NetManifest::load(&dir, "lenet").unwrap();
    let dataset = Dataset::load(&manifest).unwrap();
    let oracle = BackendKind::Reference.create().unwrap();
    let mut seen: std::collections::BTreeMap<usize, usize> = Default::default();
    for (r, idx, pred) in samples {
        let n = seen.entry(r).or_insert(0);
        if *n >= 3 {
            continue; // 3 checks per rung is plenty
        }
        *n += 1;
        let want =
            reference_prediction(&manifest, &dataset, oracle.as_ref(), &frontier.rungs[r].cfg, idx)
                .unwrap();
        assert_eq!(pred, want, "rung {r} index {idx} diverges from the oracle");
    }

    server.shutdown();
    let _ = std::fs::remove_dir_all(&fdir);
}

#[test]
fn keep_alive_connection_pipelines_requests() {
    let server = start(1024.0 * 1024.0 * 1024.0);
    let addr = server.addr();
    let body = classify_body("lenet", "1.6", 2);
    let one = format!(
        "POST /v1/classify HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    let mut s = TcpStream::connect(addr).unwrap();
    // Both requests hit the wire before either response is read.
    s.write_all(format!("{one}{one}").as_bytes()).unwrap();
    let mut r = BufReader::new(s);
    let (s1, first) = read_response(&mut r);
    let (s2, second) = read_response(&mut r);
    assert_eq!((s1, s2), (200, 200), "{first} / {second}");
    assert_eq!(first.get("cache").and_then(Json::as_str), Some("load"));
    assert_eq!(second.get("cache").and_then(Json::as_str), Some("hit"));
    let pred = |j: &Json| j.get("pred").and_then(Json::as_usize);
    assert_eq!(pred(&first), pred(&second), "pipelined answers must agree");
    server.shutdown();
}
