//! Integration tests over a real artifact set: NTF/manifest loading, the
//! golden cross-implementation quantizer lock, backend execution, and
//! accuracy parity with the recorded baselines.
//!
//! Artifacts are synthesized on first use (`testkit::ensure_artifacts`),
//! so these run anywhere — including CI boxes with no python/XLA
//! toolchain. Tests that genuinely need the native PJRT runtime (real
//! HLO from `make artifacts`) are feature-gated and `#[ignore]`d.

use qbound::backend::{Backend, BackendKind, Variant};
use qbound::eval::{Dataset, Evaluator};
use qbound::nets::{ArtifactIndex, NetManifest};
use qbound::quant::QFormat;
use qbound::search::space::PrecisionConfig;
use qbound::testkit;

fn artifacts() -> std::path::PathBuf {
    testkit::ensure_artifacts()
}

fn reference() -> Box<dyn Backend> {
    BackendKind::Reference.create().unwrap()
}

#[test]
fn index_lists_all_five_networks() {
    let idx = ArtifactIndex::load(&artifacts()).unwrap();
    for net in ["lenet", "convnet", "alexnet", "nin", "googlenet"] {
        assert!(idx.nets.iter().any(|n| n == net), "missing {net}");
    }
    assert_eq!(idx.batch, 64);
}

#[test]
fn manifests_parse_and_validate() {
    let dir = artifacts();
    let idx = ArtifactIndex::load(&dir).unwrap();
    for net in &idx.nets {
        let m = NetManifest::load(&dir, net).unwrap();
        assert!(m.baseline_top1 > 0.2, "{net} baseline {}", m.baseline_top1);
        assert!(m.total_weights() > 1000);
        assert!(m.total_macs() > 10_000);
        assert!(m.hlo_path().exists());
        assert!(m.weights_path().exists());
        assert!(m.dataset_path().exists());
    }
}

#[test]
fn paper_layer_structure_preserved() {
    let dir = artifacts();
    let count = |m: &NetManifest, k: &str| m.layers.iter().filter(|l| l.kind == k).count();
    let lenet = NetManifest::load(&dir, "lenet").unwrap();
    assert_eq!((count(&lenet, "conv"), count(&lenet, "fc")), (2, 2));
    let convnet = NetManifest::load(&dir, "convnet").unwrap();
    assert_eq!((count(&convnet, "conv"), count(&convnet, "fc")), (3, 2));
    let alexnet = NetManifest::load(&dir, "alexnet").unwrap();
    assert_eq!((count(&alexnet, "conv"), count(&alexnet, "fc")), (5, 3));
    let nin = NetManifest::load(&dir, "nin").unwrap();
    assert_eq!(count(&nin, "conv"), 12);
    let goog = NetManifest::load(&dir, "googlenet").unwrap();
    assert_eq!((count(&goog, "conv"), count(&goog, "inception")), (2, 9));
}

// (The golden_quant.ntf bit-for-bit replay lives in
// tests/property_quant.rs::golden_file_vectors_replay_bit_for_bit.)

#[test]
fn dataset_loads_and_labels_in_range() {
    let dir = artifacts();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let d = Dataset::load(&m).unwrap();
    assert!(d.n >= 256);
    assert_eq!(d.images.len(), d.n * d.image_elems);
    assert!(d.labels.iter().all(|&l| l >= 0 && (l as usize) < m.num_classes));
    // images are normalized pixels
    assert!(d.images.iter().all(|&p| (0.0..=1.0).contains(&p)));
}

#[test]
fn reference_backend_reproduces_recorded_baseline() {
    // The reference backend must reproduce the recorded fp32 top-1 on
    // the full eval split: same graph, same data, same argmax rule.
    let dir = artifacts();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let backend = reference();
    let mut ev = Evaluator::new(backend.as_ref(), &m).unwrap();
    let acc = ev.accuracy(&PrecisionConfig::fp32(m.n_layers()), 0).unwrap();
    assert!(
        (acc - m.baseline_top1).abs() < 1e-6,
        "reference {acc} vs recorded {}",
        m.baseline_top1
    );
}

#[test]
fn quantization_affects_accuracy_monotonically_at_extremes() {
    let dir = artifacts();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let backend = reference();
    let mut ev = Evaluator::new(backend.as_ref(), &m).unwrap();
    let nl = m.n_layers();
    let base = ev.accuracy(&PrecisionConfig::fp32(nl), 256).unwrap();
    // Generous format: indistinguishable from baseline.
    let wide = PrecisionConfig::uniform(nl, QFormat::new(1, 14), QFormat::new(14, 8));
    let acc_wide = ev.accuracy(&wide, 256).unwrap();
    assert!((acc_wide - base).abs() < 0.02, "wide {acc_wide} vs base {base}");
    // 1-bit data: network must collapse to ~chance.
    let tiny = PrecisionConfig::uniform(nl, QFormat::new(1, 1), QFormat::new(1, 0));
    let acc_tiny = ev.accuracy(&tiny, 256).unwrap();
    assert!(acc_tiny < base * 0.6, "tiny {acc_tiny} vs base {base}");
}

#[test]
fn evaluator_cache_hits_are_consistent() {
    let dir = artifacts();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let backend = reference();
    let mut ev = Evaluator::new(backend.as_ref(), &m).unwrap();
    let cfg = PrecisionConfig::uniform(m.n_layers(), QFormat::new(1, 6), QFormat::new(9, 2));
    let a = ev.accuracy(&cfg, 128).unwrap();
    let b = ev.accuracy(&cfg, 128).unwrap();
    assert_eq!(a, b);
    assert_eq!(ev.hits, 1);
    assert_eq!(ev.misses, 1);
}

#[test]
fn stage_variant_executor_runs_and_matches_baseline_with_sentinels() {
    let dir = artifacts();
    let m = NetManifest::load(&dir, "alexnet").unwrap();
    let sv = m.stage_variant.clone().expect("alexnet stage variant");
    assert_eq!(sv.n_stages, 4); // conv, relu, pool, norm
    let backend = reference();
    let mut exec = backend.load(&m, Variant::Stages).unwrap();
    let dataset = Dataset::load(&m).unwrap();
    let fp32 = PrecisionConfig::fp32(m.n_layers());
    let mut sq = vec![0.0f32; sv.n_stages * 2];
    for s in 0..sv.n_stages {
        sq[s * 2] = -1.0;
    }
    let logits = exec
        .infer(dataset.batch_images(0, m.batch), &fp32.wire_wq(), &fp32.wire_dq(), Some(&sq))
        .unwrap();
    // All-sentinel stage config == standard fp32 path.
    let mut std_exec = backend.load(&m, Variant::Standard).unwrap();
    let logits_std = std_exec
        .infer(dataset.batch_images(0, m.batch), &fp32.wire_wq(), &fp32.wire_dq(), None)
        .unwrap();
    for (a, b) in logits.iter().zip(&logits_std) {
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }
}

#[test]
fn executor_rejects_malformed_inputs() {
    let dir = artifacts();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let backend = reference();
    let mut exec = backend.load(&m, Variant::Standard).unwrap();
    let d = Dataset::load(&m).unwrap();
    let cfg = PrecisionConfig::fp32(m.n_layers());
    // wrong image length
    assert!(exec.infer(&d.images[..10], &cfg.wire_wq(), &cfg.wire_dq(), None).is_err());
    // wrong config length
    assert!(exec
        .infer(d.batch_images(0, m.batch), &[1.0, 2.0], &cfg.wire_dq(), None)
        .is_err());
    // sq on standard variant
    assert!(exec
        .infer(d.batch_images(0, m.batch), &cfg.wire_wq(), &cfg.wire_dq(), Some(&[1.0]))
        .is_err());
}

#[test]
fn unknown_architecture_is_rejected_at_load() {
    let dir = artifacts();
    let mut m = NetManifest::load(&dir, "lenet").unwrap();
    m.name = "resnet152".into();
    let err = reference().load(&m, Variant::Standard).unwrap_err().to_string();
    assert!(err.contains("resnet152"), "{err}");
}

/// Parity against the real PJRT runtime needs artifacts from the python
/// build path (`make artifacts`) and a machine with xla_extension — run
/// explicitly with `cargo test --features pjrt -- --ignored`.
#[cfg(feature = "pjrt")]
mod pjrt_native {
    use super::*;

    #[test]
    #[ignore = "needs real HLO artifacts (make artifacts) + xla_extension"]
    fn pjrt_backend_matches_recorded_baseline_for_lenet() {
        let dir = artifacts();
        let m = NetManifest::load(&dir, "lenet").unwrap();
        let backend = BackendKind::Pjrt.create().unwrap();
        let mut ev = Evaluator::new(backend.as_ref(), &m).unwrap();
        let acc = ev.accuracy(&PrecisionConfig::fp32(m.n_layers()), 0).unwrap();
        assert!((acc - m.baseline_top1).abs() < 1e-6, "pjrt {acc} vs {}", m.baseline_top1);
    }

    #[test]
    #[ignore = "needs real HLO artifacts (make artifacts) + xla_extension"]
    fn pjrt_and_reference_backends_agree() {
        let dir = artifacts();
        let m = NetManifest::load(&dir, "lenet").unwrap();
        let cfg = PrecisionConfig::uniform(m.n_layers(), QFormat::new(1, 8), QFormat::new(10, 2));
        let mut accs = Vec::new();
        for kind in [BackendKind::Reference, BackendKind::Pjrt] {
            let backend = kind.create().unwrap();
            let mut ev = Evaluator::new(backend.as_ref(), &m).unwrap();
            accs.push(ev.accuracy(&cfg, 128).unwrap());
        }
        assert!((accs[0] - accs[1]).abs() < 1e-9, "{accs:?}");
    }
}
