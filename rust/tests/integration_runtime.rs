//! Integration tests over the real artifacts: NTF/manifest loading, the
//! golden cross-language quantizer lock, PJRT execution, and runtime
//! accuracy parity with the python-recorded baselines.
//!
//! These tests require `make artifacts` to have run; they are the
//! end-to-end proof that the three layers compose.

use qbound::eval::{Dataset, Evaluator};
use qbound::nets::{ArtifactIndex, NetManifest};
use qbound::quant::QFormat;
use qbound::runtime::{Session, Variant};
use qbound::search::space::PrecisionConfig;
use qbound::tensor::ntf;
use qbound::util;

fn artifacts() -> std::path::PathBuf {
    util::artifacts_dir().expect("run `make artifacts` before cargo test")
}

#[test]
fn index_lists_all_five_networks() {
    let idx = ArtifactIndex::load(&artifacts()).unwrap();
    for net in ["lenet", "convnet", "alexnet", "nin", "googlenet"] {
        assert!(idx.nets.iter().any(|n| n == net), "missing {net}");
    }
    assert_eq!(idx.batch, 64);
}

#[test]
fn manifests_parse_and_validate() {
    let dir = artifacts();
    let idx = ArtifactIndex::load(&dir).unwrap();
    for net in &idx.nets {
        let m = NetManifest::load(&dir, net).unwrap();
        assert!(m.baseline_top1 > 0.2, "{net} baseline {}", m.baseline_top1);
        assert!(m.total_weights() > 1000);
        assert!(m.total_macs() > 10_000);
        assert!(m.hlo_path().exists());
        assert!(m.weights_path().exists());
        assert!(m.dataset_path().exists());
    }
}

#[test]
fn paper_layer_structure_preserved() {
    let dir = artifacts();
    let count = |m: &NetManifest, k: &str| m.layers.iter().filter(|l| l.kind == k).count();
    let lenet = NetManifest::load(&dir, "lenet").unwrap();
    assert_eq!((count(&lenet, "conv"), count(&lenet, "fc")), (2, 2));
    let convnet = NetManifest::load(&dir, "convnet").unwrap();
    assert_eq!((count(&convnet, "conv"), count(&convnet, "fc")), (3, 2));
    let alexnet = NetManifest::load(&dir, "alexnet").unwrap();
    assert_eq!((count(&alexnet, "conv"), count(&alexnet, "fc")), (5, 3));
    let nin = NetManifest::load(&dir, "nin").unwrap();
    assert_eq!(count(&nin, "conv"), 12);
    let goog = NetManifest::load(&dir, "googlenet").unwrap();
    assert_eq!((count(&goog, "conv"), count(&goog, "inception")), (2, 9));
}

#[test]
fn golden_quant_vectors_lock_rust_quantizer_to_kernel() {
    // python wrote x plus q(x) for a grid of (I, F) via the jnp oracle
    // (itself bit-locked to the pallas kernel by pytest). Replay here.
    let golden = ntf::read_file(&artifacts().join("golden_quant.ntf")).unwrap();
    let x = golden["x"].as_f32().unwrap();
    let mut checked = 0;
    for (name, expect) in &golden {
        let Some(spec) = name.strip_prefix("q_") else { continue };
        let fmt = if spec == "sentinel" {
            QFormat::FP32
        } else {
            let (i, f) = spec.split_once('_').unwrap();
            QFormat::new(i.parse().unwrap(), f.parse().unwrap())
        };
        let expect = expect.as_f32().unwrap();
        for (k, (&xi, &ei)) in x.iter().zip(expect).enumerate() {
            let got = fmt.quantize(xi);
            assert!(
                got.to_bits() == ei.to_bits() || (got == 0.0 && ei == 0.0),
                "{name}[{k}]: q({xi}) = {got:e} != python {ei:e}"
            );
        }
        checked += 1;
    }
    assert!(checked >= 40, "only {checked} golden formats checked");
}

#[test]
fn dataset_loads_and_labels_in_range() {
    let dir = artifacts();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let d = Dataset::load(&m).unwrap();
    assert!(d.n >= 256);
    assert_eq!(d.images.len(), d.n * d.image_elems);
    assert!(d.labels.iter().all(|&l| l >= 0 && (l as usize) < m.num_classes));
    // images are normalized pixels
    assert!(d.images.iter().all(|&p| (0.0..=1.0).contains(&p)));
}

#[test]
fn runtime_matches_python_baseline_exactly_for_lenet() {
    // The rust PJRT path must reproduce the python-measured fp32 top-1 on
    // the full eval split: same HLO graph, same data, same argmax rule.
    let dir = artifacts();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let session = Session::cpu().unwrap();
    let mut ev = Evaluator::new(&session, &m).unwrap();
    let acc = ev.accuracy(&session, &PrecisionConfig::fp32(m.n_layers()), 0).unwrap();
    assert!(
        (acc - m.baseline_top1).abs() < 1e-6,
        "rust {acc} vs python {}",
        m.baseline_top1
    );
}

#[test]
fn quantization_affects_accuracy_monotonically_at_extremes() {
    let dir = artifacts();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let session = Session::cpu().unwrap();
    let mut ev = Evaluator::new(&session, &m).unwrap();
    let nl = m.n_layers();
    let base = ev.accuracy(&session, &PrecisionConfig::fp32(nl), 256).unwrap();
    // Generous format: indistinguishable from baseline.
    let wide = PrecisionConfig::uniform(nl, QFormat::new(1, 14), QFormat::new(14, 8));
    let acc_wide = ev.accuracy(&session, &wide, 256).unwrap();
    assert!((acc_wide - base).abs() < 0.02, "wide {acc_wide} vs base {base}");
    // 1-bit data: network must collapse to ~chance.
    let tiny = PrecisionConfig::uniform(nl, QFormat::new(1, 1), QFormat::new(1, 0));
    let acc_tiny = ev.accuracy(&session, &tiny, 256).unwrap();
    assert!(acc_tiny < base * 0.6, "tiny {acc_tiny} vs base {base}");
}

#[test]
fn evaluator_cache_hits_are_consistent() {
    let dir = artifacts();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let session = Session::cpu().unwrap();
    let mut ev = Evaluator::new(&session, &m).unwrap();
    let cfg = PrecisionConfig::uniform(m.n_layers(), QFormat::new(1, 6), QFormat::new(9, 2));
    let a = ev.accuracy(&session, &cfg, 128).unwrap();
    let b = ev.accuracy(&session, &cfg, 128).unwrap();
    assert_eq!(a, b);
    assert_eq!(ev.hits, 1);
    assert_eq!(ev.misses, 1);
}

#[test]
fn stage_variant_engine_runs_and_matches_baseline_with_sentinels() {
    let dir = artifacts();
    let m = NetManifest::load(&dir, "alexnet").unwrap();
    let sv = m.stage_variant.clone().expect("alexnet stage variant");
    assert_eq!(sv.n_stages, 4); // conv, relu, pool, norm
    let session = Session::cpu().unwrap();
    let engine = session.load_engine(&m, Variant::Stages).unwrap();
    let dataset = Dataset::load(&m).unwrap();
    let fp32 = PrecisionConfig::fp32(m.n_layers());
    let mut sq = vec![0.0f32; sv.n_stages * 2];
    for s in 0..sv.n_stages {
        sq[s * 2] = -1.0;
    }
    let logits = engine
        .infer(&session, dataset.batch_images(0, m.batch), &fp32.wire_wq(), &fp32.wire_dq(), Some(&sq))
        .unwrap();
    // All-sentinel stage config == standard fp32 path.
    let std_engine = session.load_engine(&m, Variant::Standard).unwrap();
    let logits_std = std_engine
        .infer(&session, dataset.batch_images(0, m.batch), &fp32.wire_wq(), &fp32.wire_dq(), None)
        .unwrap();
    for (a, b) in logits.iter().zip(&logits_std) {
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }
}

#[test]
fn engine_rejects_malformed_inputs() {
    let dir = artifacts();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let session = Session::cpu().unwrap();
    let engine = session.load_engine(&m, Variant::Standard).unwrap();
    let d = Dataset::load(&m).unwrap();
    let cfg = PrecisionConfig::fp32(m.n_layers());
    // wrong image length
    assert!(engine.infer(&session, &d.images[..10], &cfg.wire_wq(), &cfg.wire_dq(), None).is_err());
    // wrong config length
    assert!(engine
        .infer(&session, d.batch_images(0, m.batch), &[1.0, 2.0], &cfg.wire_dq(), None)
        .is_err());
    // sq on standard variant
    assert!(engine
        .infer(&session, d.batch_images(0, m.batch), &cfg.wire_wq(), &cfg.wire_dq(), Some(&[1.0]))
        .is_err());
}
