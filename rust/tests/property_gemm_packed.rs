//! Property tests for the packed-B GEMM: with the `B` operand stored as
//! a [`PackedPanels`] bitstream, decoding one `KC`-row strip at a time
//! into the per-thread tile must reproduce the f32-panel GEMM
//! **bit-for-bit** — for every weight width (including the fp32
//! sentinel and the wide word-aligned fallback), across panel shapes
//! that straddle every tile edge, with strided `C` outputs and under
//! row-block threading. This is the contract that lets the fused packed
//! executor swap its weight panels for bitstreams without moving a
//! single logit bit.

use std::sync::Mutex;

use qbound::backend::gemm::{gemm_bias_bits, gemm_bias_packed, pack_b_panels, NR};
use qbound::backend::kernels::{self, KernelKind};
use qbound::memory::PackedPanels;
use qbound::prng::Xoshiro256pp;
use qbound::quant::QFormat;
use qbound::testkit::quantized_canonical;

/// [`kernels::force`] is process-global, so the variant sweep holds this
/// lock to keep its forced windows from interleaving with another sweep.
/// (The non-sweep tests here stay lock-free on purpose: every variant is
/// bit-identical, so a concurrent force cannot change their outcome.)
static KERNEL_LOCK: Mutex<()> = Mutex::new(());

fn rand_vec(rng: &mut Xoshiro256pp, n: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..n).map(|_| rng.uniform_f32(lo, hi)).collect()
}

/// Reference product: the f32-panel GEMM over the quantized weights.
fn panel_gemm(m: usize, n: usize, kd: usize, a: &[f32], qb: &[f32], bias: &[f32]) -> Vec<f32> {
    let bp = pack_b_panels(qb, kd, n);
    let mut c = vec![0f32; m * n];
    gemm_bias_packed(m, n, kd, a, kd, &bp, bias, &mut c, n, 1);
    c
}

fn assert_bits_match(label: &str, want: &[f32], got: &[f32]) {
    for (i, (x, y)) in want.iter().zip(got).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: elem {i}: {x} vs {y}");
    }
}

#[test]
fn every_weight_width_matches_the_f32_panel_path() {
    // kd = 300 crosses the KC strip boundary; n = NR + 1 leaves a
    // ragged second panel.
    let (m, n, kd) = (5usize, NR + 1, 300usize);
    let mut rng = Xoshiro256pp::new(2024);
    let a = rand_vec(&mut rng, m * kd, -2.0, 2.0);
    let bias = rand_vec(&mut rng, n, -0.5, 0.5);
    let raw = rand_vec(&mut rng, kd * n, -3.0, 3.0);
    let mut fmts = vec![QFormat::FP32, QFormat::new(14, 12)]; // 32-bit fallbacks
    for ibits in 0..=12i8 {
        for fbits in 0..=12i8 {
            if ibits + fbits > 0 {
                fmts.push(QFormat::new(ibits, fbits));
            }
        }
    }
    for fmt in fmts {
        // The values a packed-weight GEMM multiplies: quantized, with
        // `-0.0` canonicalized exactly as the bitstream stores it.
        let qb = quantized_canonical(fmt, &raw);
        let want = panel_gemm(m, n, kd, &a, &qb, &bias);
        let bits = PackedPanels::pack(fmt, &pack_b_panels(&raw, kd, n), kd, NR);
        assert_eq!(bits.fmt(), fmt);
        let mut got = vec![f32::NAN; m * n];
        gemm_bias_bits(m, n, kd, &a, kd, &bits, &bias, &mut got, n, 1);
        assert_bits_match(&format!("{fmt}"), &want, &got);
    }
}

#[test]
fn panel_shapes_threads_and_tile_edges_match() {
    // Shapes straddle every tile edge: m % MR, n % NR, kd % KC.
    let fmt = QFormat::new(2, 6);
    for &(m, n, kd) in &[
        (1usize, 1usize, 1usize),
        (1, 10, 256),
        (3, 5, 7),
        (4, 16, 9),
        (5, 17, 300),
        (64, 24, 75),
        (130, 33, 513),
    ] {
        let mut rng = Xoshiro256pp::new(7 + (m * n * kd) as u64);
        let a = rand_vec(&mut rng, m * kd, -2.0, 2.0);
        let bias = rand_vec(&mut rng, n, -0.5, 0.5);
        let qb = quantized_canonical(fmt, &rand_vec(&mut rng, kd * n, -1.5, 1.5));
        let want = panel_gemm(m, n, kd, &a, &qb, &bias);
        let bits = PackedPanels::pack(fmt, &pack_b_panels(&qb, kd, n), kd, NR);
        for threads in [1usize, 2, 3, 8] {
            let mut got = vec![f32::NAN; m * n];
            gemm_bias_bits(m, n, kd, &a, kd, &bits, &bias, &mut got, n, threads);
            assert_bits_match(&format!("({m},{n},{kd}) t={threads}"), &want, &got);
        }
    }
}

#[test]
fn every_kernel_variant_reproduces_the_scalar_gemm_bit_for_bit() {
    // The dispatch contract from `backend::kernels`: AVX2/NEON tiles and
    // unpackers are drop-in replacements, not approximations. Bake the
    // scalar baseline under a forced scalar kernel, then force each
    // variant the host supports and demand identical bits from both the
    // packed-bitstream and the f32-panel GEMM, across tile-edge shapes
    // and thread counts.
    let _g = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = kernels::active_kind();
    let fmt = QFormat::new(3, 5);
    for &(m, n, kd) in &[(1usize, 1usize, 1usize), (4, 16, 9), (5, 17, 300), (64, 24, 75)] {
        let mut rng = Xoshiro256pp::new(0x5117 + (m * n * kd) as u64);
        let a = rand_vec(&mut rng, m * kd, -2.0, 2.0);
        let bias = rand_vec(&mut rng, n, -0.5, 0.5);
        let qb = quantized_canonical(fmt, &rand_vec(&mut rng, kd * n, -1.5, 1.5));
        let bits = PackedPanels::pack(fmt, &pack_b_panels(&qb, kd, n), kd, NR);

        kernels::force(KernelKind::Scalar);
        let want_f32 = panel_gemm(m, n, kd, &a, &qb, &bias);
        let mut want = vec![f32::NAN; m * n];
        gemm_bias_bits(m, n, kd, &a, kd, &bits, &bias, &mut want, n, 1);
        assert_bits_match(&format!("scalar bits vs f32 ({m},{n},{kd})"), &want_f32, &want);

        for kind in kernels::available() {
            kernels::force(kind);
            let got_f32 = panel_gemm(m, n, kd, &a, &qb, &bias);
            assert_bits_match(&format!("{} f32 ({m},{n},{kd})", kind.label()), &want, &got_f32);
            for threads in [1usize, 3] {
                let mut got = vec![f32::NAN; m * n];
                gemm_bias_bits(m, n, kd, &a, kd, &bits, &bias, &mut got, n, threads);
                assert_bits_match(
                    &format!("{} bits ({m},{n},{kd}) t={threads}", kind.label()),
                    &want,
                    &got,
                );
            }
        }
    }
    kernels::force(prev);
}

#[test]
fn strided_c_matches_and_leaves_gaps_untouched() {
    let fmt = QFormat::new(1, 7);
    let (m, n, kd) = (7usize, NR + 3, 40usize);
    let mut rng = Xoshiro256pp::new(99);
    let a = rand_vec(&mut rng, m * kd, -2.0, 2.0);
    let bias = rand_vec(&mut rng, n, -0.5, 0.5);
    let qb = quantized_canonical(fmt, &rand_vec(&mut rng, kd * n, -1.0, 1.0));
    let bits = PackedPanels::pack(fmt, &pack_b_panels(&qb, kd, n), kd, NR);
    let want = panel_gemm(m, n, kd, &a, &qb, &bias);
    let ldc = n + 5;
    let mut c = vec![-7.0f32; (m - 1) * ldc + n + 5];
    gemm_bias_bits(m, n, kd, &a, kd, &bits, &bias, &mut c, ldc, 1);
    for r in 0..m {
        for j in 0..n {
            assert_eq!(c[r * ldc + j].to_bits(), want[r * n + j].to_bits(), "row {r} col {j}");
        }
        if r + 1 < m {
            assert!(c[r * ldc + n..r * ldc + ldc].iter().all(|&v| v == -7.0), "row {r} gap");
        }
    }
}
