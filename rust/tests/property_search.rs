//! Property tests over the pure search/traffic machinery (no PJRT):
//! config-space invariants, Pareto laws, traffic-model arithmetic.

use qbound::nets::{LayerMeta, NetManifest, ParamMeta};
use qbound::prng::Xoshiro256pp;
use qbound::quant::QFormat;
use qbound::search::pareto;
use qbound::search::space::{DescentOptions, PrecisionConfig};
use qbound::testkit::{cases, forall, gen_i64, prop, Gen, GenPair};
use qbound::traffic::{self, Mode};

/// Generator for random-but-valid precision configs of a given width.
struct GenConfig {
    layers: usize,
}

impl Gen for GenConfig {
    type Value = PrecisionConfig;

    fn generate(&self, rng: &mut Xoshiro256pp) -> PrecisionConfig {
        let mut cfg = PrecisionConfig::fp32(self.layers);
        for l in 0..self.layers {
            if rng.below(8) != 0 {
                cfg.wq[l] = QFormat::new(1, rng.range_i64(1, 14) as i8);
            }
            if rng.below(8) != 0 {
                cfg.dq[l] = QFormat::new(rng.range_i64(1, 15) as i8, rng.range_i64(0, 8) as i8);
            }
        }
        cfg
    }
}

/// Synthetic manifest with a consistent layer chain.
fn synth_manifest(rng: &mut Xoshiro256pp, layers: usize) -> NetManifest {
    let mut metas = Vec::new();
    let mut prev_out = 64 + rng.below(512);
    let first_in = prev_out;
    for l in 0..layers {
        let out = 16 + rng.below(1024);
        metas.push(LayerMeta {
            name: format!("L{}", l + 1),
            kind: if l < layers - 1 { "conv".into() } else { "fc".into() },
            in_elems: prev_out,
            out_elems: out,
            weight_elems: 8 + rng.below(4096),
            macs: 1000 + rng.below(1_000_000),
            stages: vec!["conv".into()],
        });
        prev_out = out;
    }
    let total: u64 = metas.iter().map(|l| l.weight_elems).sum();
    NetManifest {
        name: "synth".into(),
        dataset: "synmnist".into(),
        num_classes: 10,
        input_shape: vec![1, 1, first_in as usize],
        batch: 64,
        n_eval: 64,
        baseline_top1: 0.9,
        layers: metas,
        params: vec![ParamMeta { name: "all".into(), shape: vec![total as usize] }],
        hlo_file: "x".into(),
        weights_file: "x".into(),
        dataset_file: "x".into(),
        stage_variant: None,
        dir: std::path::PathBuf::from("/tmp"),
    }
}

#[test]
fn neighbours_change_exactly_one_field_by_one_bit() {
    forall(cases(300), GenConfig { layers: 6 }, |cfg| {
        // descent operates on fully-quantized configs; skip fp32 fields
        let mut c = cfg.clone();
        for l in 0..c.n_layers() {
            if c.wq[l].is_fp32() {
                c.wq[l] = QFormat::new(1, 8);
            }
            if c.dq[l].is_fp32() {
                c.dq[l] = QFormat::new(10, 2);
            }
        }
        let opts = DescentOptions::default();
        for (label, n) in c.descent_neighbours(&opts) {
            let mut delta = 0i32;
            for l in 0..c.n_layers() {
                delta += (c.wq[l].bits() as i32 - n.wq[l].bits() as i32).abs();
                delta += (c.dq[l].bits() as i32 - n.dq[l].bits() as i32).abs();
            }
            if delta != 1 {
                return prop(false, &format!("neighbour {label} changed {delta} bits"));
            }
        }
        prop(true, "")
    });
}

#[test]
fn neighbours_never_violate_floors() {
    forall(cases(300), GenConfig { layers: 5 }, |cfg| {
        let mut c = cfg.clone();
        for l in 0..c.n_layers() {
            if c.wq[l].is_fp32() {
                c.wq[l] = QFormat::new(1, 2);
            }
            if c.dq[l].is_fp32() {
                c.dq[l] = QFormat::new(2, 1);
            }
        }
        let opts = DescentOptions::default();
        for (_, n) in c.descent_neighbours(&opts) {
            for q in &n.dq {
                if q.ibits < opts.min_data_i || q.fbits < opts.min_data_f {
                    return prop(false, &format!("floor violated: {q}"));
                }
            }
            for q in &n.wq {
                if q.fbits < opts.min_weight_f {
                    return prop(false, &format!("weight floor violated: {q}"));
                }
            }
        }
        prop(true, "")
    });
}

#[test]
fn traffic_ratio_bounded_and_monotone_under_bit_reduction() {
    forall(
        cases(200),
        GenPair(gen_i64(2, 12), GenConfig { layers: 8 }),
        |(seed, cfg)| {
            let mut rng = Xoshiro256pp::new(*seed as u64);
            let m = synth_manifest(&mut rng, 8);
            let mode = Mode::Batch(64);
            let r = traffic::traffic_ratio(&m, mode, cfg);
            if !(0.0 < r && r <= 1.0 + 1e-9) {
                return prop(false, &format!("ratio {r} out of (0, 1]"));
            }
            // reduce one quantized field: ratio must not increase
            let mut c2 = cfg.clone();
            if let Some(l) = (0..c2.n_layers()).find(|&l| !c2.dq[l].is_fp32() && c2.dq[l].ibits > 1)
            {
                c2.dq[l].ibits -= 1;
                let r2 = traffic::traffic_ratio(&m, mode, &c2);
                return prop(r2 <= r + 1e-12, &format!("ratio rose {r} -> {r2}"));
            }
            prop(true, "")
        },
    );
}

#[test]
fn batch_mode_never_exceeds_single_mode_traffic() {
    forall(cases(200), GenPair(gen_i64(1, 1000), GenConfig { layers: 5 }), |(seed, cfg)| {
        let mut rng = Xoshiro256pp::new(*seed as u64);
        let m = synth_manifest(&mut rng, 5);
        let b = traffic::traffic_bits(&m, Mode::Batch(64), cfg);
        let s = traffic::traffic_bits(&m, Mode::Single, cfg);
        prop(b <= s + 1e-9, &format!("batch {b} > single {s}"))
    });
}

#[test]
fn pareto_frontier_laws() {
    forall(cases(150), gen_i64(0, i64::MAX / 2), |&seed| {
        let mut rng = Xoshiro256pp::new(seed as u64);
        let n = 2 + rng.below(120) as usize;
        let pts: Vec<(f64, f64)> = (0..n).map(|_| (rng.uniform(), rng.uniform())).collect();
        let f = pareto::frontier(&pts);
        if f.is_empty() {
            return prop(false, "frontier empty on non-empty set");
        }
        // 1. no frontier point is dominated
        for &i in &f {
            if pareto::dominated(pts[i], &pts) {
                return prop(false, &format!("frontier point {i} dominated"));
            }
        }
        // 2. every non-frontier point is dominated by some point
        for i in 0..n {
            if !f.contains(&i) && !pareto::dominated(pts[i], &pts) {
                return prop(false, &format!("point {i} non-dominated but excluded"));
            }
        }
        // 3. frontier sorted by traffic with strictly rising accuracy
        for w in f.windows(2) {
            if pts[w[0]].0 > pts[w[1]].0 || pts[w[0]].1 >= pts[w[1]].1 {
                return prop(false, "frontier not strictly improving");
            }
        }
        prop(true, "")
    });
}

#[test]
fn wire_encoding_roundtrips_for_any_config() {
    forall(cases(300), GenConfig { layers: 7 }, |cfg| {
        let wq = cfg.wire_wq();
        let dq = cfg.wire_dq();
        if wq.len() != 14 || dq.len() != 14 {
            return prop(false, "wire width");
        }
        for (l, q) in cfg.wq.iter().enumerate() {
            let back = if wq[2 * l] < 0.0 {
                QFormat::FP32
            } else {
                QFormat::new(wq[2 * l] as i8, wq[2 * l + 1] as i8)
            };
            if back.bits() != q.bits() || back.is_fp32() != q.is_fp32() {
                return prop(false, &format!("wq[{l}] roundtrip {q} -> {back}"));
            }
        }
        for (l, q) in cfg.dq.iter().enumerate() {
            let back = if dq[2 * l] < 0.0 {
                QFormat::FP32
            } else {
                QFormat::new(dq[2 * l] as i8, dq[2 * l + 1] as i8)
            };
            if back.quantize(1.234) != q.quantize(1.234) {
                return prop(false, &format!("dq[{l}] semantics changed"));
            }
        }
        prop(true, "")
    });
}

#[test]
fn synth_manifest_passes_traffic_sanity() {
    // accesses: weights amortize exactly 1/B
    forall(cases(100), gen_i64(0, 10_000), |&seed| {
        let mut rng = Xoshiro256pp::new(seed as u64);
        let m = synth_manifest(&mut rng, 4);
        let single = traffic::accesses_per_image(&m, Mode::Single);
        let batch = traffic::accesses_per_image(&m, Mode::Batch(64));
        for (s, b) in single.iter().zip(&batch) {
            let expect = s.weight_accesses / 64.0;
            if (b.weight_accesses - expect).abs() > 1e-9 {
                return prop(false, "weight amortization wrong");
            }
            if s.data_accesses != b.data_accesses {
                return prop(false, "data must not amortize");
            }
        }
        prop(true, "")
    });
}
