//! Observability integration: the contracts the `obs` subsystem makes
//! to the rest of the system.
//!
//! * Instrumentation never perturbs numerics — logits are bit-identical
//!   with metrics+tracing on vs fully off, under both storage modes.
//! * The per-layer decode counters reconcile with the
//!   [`FootprintModel`] prediction (the join `qbound profile` performs).
//! * A live server answers `GET /metrics` with a parseable Prometheus
//!   exposition populated by real traffic.
//! * Span rings nest by time containment and drop the *oldest* events
//!   at [`RING_CAP`], keeping memory flat.
//!
//! The obs enable flags are process-global, so every test here holds
//! one file-local mutex and restores the flags before releasing it.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Mutex, MutexGuard};

use qbound::backend::{Backend, BackendKind, Variant};
use qbound::eval::Dataset;
use qbound::memory::{FootprintModel, StorageMode};
use qbound::nets::NetManifest;
use qbound::obs;
use qbound::obs::span::RING_CAP;
use qbound::quant::QFormat;
use qbound::search::space::PrecisionConfig;
use qbound::serve::{ServeOptions, Server};
use qbound::testkit;

/// Serializes every test in this file: obs flags (and `QBOUND_STORAGE`)
/// are process-global state.
static OBS_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    OBS_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// Restore the disabled-by-default flag state on scope exit, even if
/// the test panics (the next test would otherwise inherit live flags).
struct FlagsOff;
impl Drop for FlagsOff {
    fn drop(&mut self) {
        obs::set_metrics(false);
        obs::set_tracing(false);
    }
}

fn fast() -> Box<dyn Backend> {
    BackendKind::Fast.create().unwrap()
}

fn lenet_cfg(nl: usize) -> PrecisionConfig {
    PrecisionConfig::uniform(nl, QFormat::new(1, 8), QFormat::new(10, 4))
}

#[test]
fn instrumentation_preserves_logits_bit_exactly() {
    let _g = lock();
    let _off = FlagsOff;
    let dir = testkit::ensure_artifacts();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let d = Dataset::load(&m).unwrap();
    let cfg = lenet_cfg(m.n_layers());
    let (wq, dq) = (cfg.wire_wq(), cfg.wire_dq());
    for storage in [StorageMode::F32, StorageMode::Packed] {
        storage.set_env();
        let b = fast();
        let mut exec = b.load(&m, Variant::Standard).unwrap();
        let imgs = d.batch_images(0, m.batch);
        obs::set_metrics(false);
        obs::set_tracing(false);
        let plain = exec.infer(imgs, &wq, &dq, None).unwrap();
        obs::set_metrics(true);
        obs::set_tracing(true);
        let observed = exec.infer(imgs, &wq, &dq, None).unwrap();
        obs::set_metrics(false);
        obs::set_tracing(false);
        // Bitwise, not approximate: instrumentation reads clocks and
        // counts bytes but never touches tensor data.
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&plain), bits(&observed), "{storage:?}");
    }
    obs::drain(); // leave no spans behind for later tests
}

#[test]
fn per_layer_counters_reconcile_with_the_footprint_model() {
    let _g = lock();
    let _off = FlagsOff;
    let dir = testkit::ensure_artifacts();
    let m = NetManifest::load(&dir, "lenet").unwrap();
    let d = Dataset::load(&m).unwrap();
    let nl = m.n_layers();
    let cfg = lenet_cfg(nl);
    let fpm = FootprintModel::new(&m);

    // The per-layer model columns must sum to the whole-model weight
    // figure — the reconciliation row `qbound profile` prints.
    let model = fpm.per_layer(&cfg);
    let w_sum: f64 = model.iter().map(|lf| lf.weight_bytes).sum();
    let fp = fpm.footprint(&cfg);
    assert!((w_sum - fp.weight_bytes).abs() < 1e-6, "{w_sum} vs {fp:?}");

    // Registry series are cumulative across the process; measure deltas.
    let layer_labels = |l: &str| [("net", "lenet"), ("layer", l), ("storage", "packed")];
    let before: Vec<(u64, u64)> = (0..nl)
        .map(|l| {
            let ls = l.to_string();
            let h = obs::histogram("qbound_layer_us", "", &layer_labels(&ls)).0.snapshot();
            let c = obs::counter("qbound_layer_decode_bytes_total", "", &layer_labels(&ls));
            (h.count(), c.get())
        })
        .collect();
    let decode0 = obs::decode_bytes();

    StorageMode::Packed.set_env();
    obs::set_metrics(true);
    let b = fast();
    let mut exec = b.load(&m, Variant::Standard).unwrap();
    let (wq, dq) = (cfg.wire_wq(), cfg.wire_dq());
    let n = 3usize;
    for i in 0..n {
        let img = &d.images[i * d.image_elems..(i + 1) * d.image_elems];
        exec.infer(img, &wq, &dq, None).unwrap();
    }
    obs::set_metrics(false);

    let mut layer_decoded = 0u64;
    for (l, (count0, decode_l0)) in before.iter().enumerate() {
        let ls = l.to_string();
        let h = obs::histogram("qbound_layer_us", "", &layer_labels(&ls)).0.snapshot();
        // Every precision group runs at least one lowered step per image.
        assert!(
            h.count() - count0 >= n as u64,
            "layer {l}: {} step timings for {n} images",
            h.count() - count0
        );
        let c = obs::counter("qbound_layer_decode_bytes_total", "", &layer_labels(&ls));
        layer_decoded += c.get() - decode_l0;
    }
    // Per-layer attribution never exceeds the global chokepoint count,
    // and packed inference must actually decode something.
    let global_decoded = obs::decode_bytes() - decode0;
    assert!(layer_decoded > 0, "packed run decoded nothing");
    assert!(
        layer_decoded <= global_decoded,
        "layers claim {layer_decoded} B, chokepoint saw {global_decoded} B"
    );
}

#[test]
fn metrics_endpoint_serves_populated_prometheus_exposition() {
    let _g = lock();
    let _off = FlagsOff;
    let opts = ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        ..ServeOptions::default()
    };
    let server = Server::start(&testkit::ensure_artifacts(), &opts).unwrap();
    let addr = server.addr();

    // Drive real traffic so the request histograms and per-layer series
    // have samples, then scrape.
    let body = r#"{"net":"lenet","weights":"1.8","data":"9.2","index":0}"#;
    let head = format!("POST /v1/classify\r\nContent-Length: {}", body.len());
    let (st, _) = http(addr, &head, body);
    assert_eq!(st, 200);
    let (st, expo) = http(addr, "GET /metrics", "");
    server.shutdown();
    assert_eq!(st, 200);

    // Structural parse: every non-comment line is `name[{labels}] value`.
    let mut series = Vec::new();
    for line in expo.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let (name_labels, value) = line.rsplit_once(' ').expect(line);
        assert!(value.parse::<f64>().is_ok(), "unparsable value: {line}");
        series.push(name_labels.to_string());
    }
    for want in [
        "qbound_http_requests_total{status=\"200\"}",
        "qbound_request_latency_us_bucket",
        "qbound_layer_us_bucket",
        "qbound_layer_us_count",
    ] {
        assert!(series.iter().any(|s| s.starts_with(want)), "missing {want} in:\n{expo}");
    }
}

#[test]
fn span_rings_nest_and_drop_oldest_on_overflow() {
    let _g = lock();
    let _off = FlagsOff;
    obs::drain(); // start from empty rings
    obs::set_tracing(true);
    {
        let _outer = obs::span!("obs_test_outer", "k={}", 1);
        let _inner = obs::span!("obs_test_inner");
    } // inner drops first, then outer
    obs::set_tracing(false);
    let events = obs::drain();
    let outer = events.iter().find(|e| e.name == "obs_test_outer").unwrap();
    let inner = events.iter().find(|e| e.name == "obs_test_inner").unwrap();
    assert_eq!(outer.detail, "k=1");
    assert_eq!(outer.tid, inner.tid);
    // Chrome-trace nesting is inferred from time containment.
    assert!(inner.ts_us >= outer.ts_us, "{inner:?} vs {outer:?}");
    assert!(inner.ts_us + inner.dur_us <= outer.ts_us + outer.dur_us, "{inner:?} vs {outer:?}");

    // Overflow: RING_CAP + extra events on one thread keeps the ring at
    // RING_CAP, drops exactly the oldest `extra`, and counts them.
    let extra = 17u64;
    let dropped0 = obs::dropped_events();
    for i in 0..(RING_CAP as u64 + extra) {
        obs::span::emit("obs_test_overflow", format!("i={i}"), i, 1);
    }
    let events = obs::drain();
    let kept: Vec<&str> = events
        .iter()
        .filter(|e| e.name == "obs_test_overflow")
        .map(|e| e.detail.as_str())
        .collect();
    assert_eq!(kept.len(), RING_CAP);
    assert_eq!(obs::dropped_events() - dropped0, extra);
    assert_eq!(kept.first().copied(), Some(format!("i={extra}").as_str()));
    assert_eq!(kept.last().copied(), Some(format!("i={}", RING_CAP as u64 + extra - 1).as_str()));
}

// ---- tiny blocking HTTP client ------------------------------------------

/// `head` is `"METHOD /path"` plus any extra headers, `\r\n`-separated.
fn http(addr: std::net::SocketAddr, head: &str, body: &str) -> (u16, String) {
    let (req_line, extra) = head.split_once("\r\n").unwrap_or((head, ""));
    let mut req = format!("{req_line} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n");
    if !extra.is_empty() {
        req.push_str(extra);
        req.push_str("\r\n");
    }
    req.push_str("\r\n");
    req.push_str(body);
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(req.as_bytes()).unwrap();
    let mut r = BufReader::new(s);
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let status: u16 = line.split_whitespace().nth(1).unwrap().parse().unwrap();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        assert!(r.read_line(&mut h).unwrap() > 0, "eof inside headers");
        let t = h.trim_end();
        if t.is_empty() {
            break;
        }
        if let Some(v) = t.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap();
        }
    }
    let mut buf = vec![0u8; content_length];
    std::io::Read::read_exact(&mut r, &mut buf).unwrap();
    (status, String::from_utf8(buf).unwrap())
}
