//! API-compatible **stub** of the `xla-rs` PJRT bindings.
//!
//! This crate exists so `cargo build --features pjrt` type-checks the
//! PJRT backend on machines with no `xla_extension` native toolchain
//! (CI among them). Every entry point that would touch the native
//! runtime returns [`XlaError::Unavailable`] at *runtime*; nothing is
//! silently faked.
//!
//! To run the real PJRT path, replace the `rust/vendor/xla` path
//! dependency in `rust/Cargo.toml` with the actual `xla` crate (plus its
//! `xla_extension` install) — the API surface below matches the
//! signatures qbound uses, so no source changes are needed.

#![allow(dead_code)]

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` for the subset of operations used.
#[derive(Debug)]
pub enum XlaError {
    /// The native runtime is not linked into this build.
    Unavailable(&'static str),
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            XlaError::Unavailable(what) => write!(
                f,
                "{what}: this build links the vendored xla API stub \
                 (rust/vendor/xla); install xla_extension and point \
                 Cargo at the real xla crate to enable PJRT"
            ),
        }
    }
}

impl std::error::Error for XlaError {}

type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(XlaError::Unavailable(what))
}

/// Element types accepted by buffer/literal transfers.
pub trait ElementType: Copy {}

impl ElementType for f32 {}
impl ElementType for f64 {}
impl ElementType for i32 {}
impl ElementType for i64 {}

/// A PJRT client (stub: construction always fails).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: ElementType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

/// A parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A compiled executable (stub: never constructed).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// A device-resident buffer (stub: never constructed).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A host-resident literal (stub: never constructed).
pub struct Literal(());

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_stub() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        let msg = err.to_string();
        assert!(msg.contains("vendored xla API stub"), "{msg}");
    }
}
