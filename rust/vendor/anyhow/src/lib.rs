//! Vendored, minimal `anyhow`-compatible error handling.
//!
//! This crate implements the subset of the real `anyhow` API that qbound
//! uses, so the workspace builds with **zero registry access** (the CI
//! machines and the offline dev containers have no crates.io mirror).
//! Drop-in: swap the `[dependencies]` path entry for the real crate and
//! nothing else changes.
//!
//! Supported surface:
//!   * [`Error`] — a context chain with `{}` (top message), `{:#}`
//!     (full `a: b: c` chain) and `{:?}` (anyhow-style "Caused by")
//!     renderings,
//!   * [`Result<T>`] with the `E = Error` default parameter,
//!   * [`Context`] — `.context(..)` / `.with_context(..)` on any
//!     `Result<_, E: Into<Error>>` and on `Option<_>`,
//!   * `anyhow!`, `bail!`, `ensure!` macros,
//!   * `From<E: std::error::Error>` so `?` converts std errors (the
//!     source chain is captured into the context chain).

use std::fmt;

/// `Result<T, anyhow::Error>` with the same default-parameter shape as
/// the real crate (so `Result<f64, String>` still names std's Result).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error: an ordered context chain, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Push a new outermost context layer.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message (what `{}` prints).
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }

    /// The full chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`;
// that is what keeps the blanket `From` below coherent (same trick as
// the real anyhow).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `.context(..)` / `.with_context(..)` extension trait.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or a printable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e = anyhow!("root {}", 7).context("mid").context("top");
        assert_eq!(e.to_string(), "top");
        assert_eq!(format!("{e:#}"), "top: mid: root 7");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading file: gone");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing x");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
        assert_eq!(f(101).unwrap_err().to_string(), "too big: 101");
    }
}
