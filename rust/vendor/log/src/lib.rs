//! Vendored, minimal `log`-facade implementation.
//!
//! API-compatible (for the subset qbound uses) with the real `log` crate
//! so the workspace builds with zero registry access: `Level`,
//! `LevelFilter`, `Record`, `Metadata`, the [`Log`] trait,
//! `set_logger`/`set_max_level`/`max_level`, and the five level macros.
//! Swap the path dependency for crates.io `log` and nothing changes.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a single record.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.pad(s)
    }
}

/// Maximum-verbosity filter installed via [`set_max_level`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        (*self as usize) == (*other as usize)
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        (*self as usize) == (*other as usize)
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of a record: its level and target (module path).
#[derive(Clone, Copy, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the formatted message arguments.
#[derive(Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// The logging backend trait.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        false
    }

    fn log(&self, _: &Record) {}

    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// The installed logger (a no-op logger until [`set_logger`] runs).
pub fn logger() -> &'static dyn Log {
    match LOGGER.get() {
        Some(l) => *l,
        None => &NOP,
    }
}

/// Set the global maximum verbosity.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// The global maximum verbosity.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Implementation detail of the level macros — not public API.
#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments) {
    let metadata = Metadata { level, target };
    let logger = logger();
    if logger.enabled(&metadata) {
        logger.log(&Record { metadata, args });
    }
}

/// Log at an explicit [`Level`].
#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {{
        let lvl = $lvl;
        if lvl <= $crate::max_level() {
            $crate::__private_log(lvl, module_path!(), format_args!($($arg)+));
        }
    }};
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(LevelFilter::Off < Level::Error);
    }

    #[test]
    fn max_level_roundtrip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Info);
        assert_eq!(max_level(), LevelFilter::Info);
    }

    #[test]
    fn macros_expand_without_logger() {
        // No logger installed in this test binary: must be a silent no-op.
        log!(Level::Info, "hello {}", 1);
        info!("x {}", 2);
        debug!("y {y}", y = 3);
    }

    #[test]
    fn display_level() {
        assert_eq!(format!("{:<5}", Level::Warn), "WARN ");
        assert_eq!(Level::Error.to_string(), "ERROR");
    }
}
