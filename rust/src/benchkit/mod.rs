//! Measurement harness (substrate — `criterion` is unavailable offline).
//!
//! Criterion-style flow: warm-up, timed iterations, robust statistics
//! (mean / median / p95 / stddev / min), throughput annotations, and an
//! aligned text report. `cargo bench` targets build a [`BenchSuite`],
//! register closures, and call [`BenchSuite::finish`].
//!
//! For machine consumption (the CI bench-smoke job archives the perf
//! trajectory), [`BenchSuite::write_json`] emits `BENCH_<slug>.json`;
//! [`BenchSuite::finish`] does it automatically when the
//! `QBOUND_BENCH_JSON` env var names a directory.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Statistics over per-iteration wall-clock samples.
#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
    pub stddev: Duration,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<Duration>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_unstable();
        let n = samples.len();
        let sum: Duration = samples.iter().sum();
        let mean = sum / n as u32;
        let mean_s = mean.as_secs_f64();
        let var = samples
            .iter()
            .map(|d| {
                let x = d.as_secs_f64() - mean_s;
                x * x
            })
            .sum::<f64>()
            / n as f64;
        Stats {
            iters: n,
            mean,
            median: samples[n / 2],
            p95: samples[(n as f64 * 0.95) as usize - if n > 20 { 1 } else { 0 }],
            min: samples[0],
            stddev: Duration::from_secs_f64(var.sqrt()),
        }
    }
}

/// One benchmark's result row.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub stats: Stats,
    /// Optional elements-per-iteration for throughput reporting.
    pub elems_per_iter: Option<f64>,
    /// Optional bytes-per-iteration for bandwidth reporting.
    pub bytes_per_iter: Option<f64>,
    /// Mean packed-storage bytes decoded per iteration (from the
    /// [`crate::obs`] decode counter), when any decoding happened.
    pub decoded_bytes_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn throughput_line(&self) -> String {
        let mut extra = String::new();
        let per_s = 1.0 / self.stats.mean.as_secs_f64();
        if let Some(e) = self.elems_per_iter {
            extra.push_str(&format!("  {}/s", crate::util::human_count(e * per_s)));
        }
        if let Some(b) = self.bytes_per_iter {
            extra.push_str(&format!("  {}/s", crate::util::human_bytes(b * per_s)));
        }
        extra
    }
}

/// One packed-vs-f32 comparison, labeled with the kernel variant it ran
/// under: `packed_over_f32` is packed mean time / f32 mean time for the
/// same workload (1.0 = parity, lower is faster). Archived in the
/// suite's `BENCH_*.json` so the perf trajectory tracks how close the
/// bit-exact packed path sits to the f32 path per kernel variant.
#[derive(Clone, Debug)]
pub struct RatioEntry {
    pub net: String,
    pub kernel: &'static str,
    pub packed_over_f32: f64,
}

/// Benchmark registry + runner.
pub struct BenchSuite {
    pub title: String,
    pub warmup: Duration,
    pub measure: Duration,
    pub max_iters: usize,
    /// Kernel variant dispatched when the suite was created (benches
    /// that `force()` a sweep label each [`RatioEntry`] individually).
    pub kernel: &'static str,
    results: Vec<BenchResult>,
    ratios: Vec<RatioEntry>,
}

impl BenchSuite {
    pub fn new(title: &str) -> Self {
        // QBOUND_BENCH_FAST=1 trims times for CI smoke runs.
        let fast = std::env::var("QBOUND_BENCH_FAST").is_ok();
        // Benches report decoded bytes alongside times, so the decode
        // accounting must be live (negligible cost: one relaxed add per
        // decoded span).
        crate::obs::set_metrics(true);
        Self {
            title: title.to_string(),
            warmup: if fast { Duration::from_millis(50) } else { Duration::from_millis(300) },
            measure: if fast { Duration::from_millis(200) } else { Duration::from_secs(2) },
            max_iters: 10_000,
            kernel: crate::backend::kernels::active_kind().label(),
            results: Vec::new(),
            ratios: Vec::new(),
        }
    }

    /// Time `f` repeatedly; `f` should perform one logical iteration.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &mut BenchResult {
        self.bench_with(name, None, None, &mut f)
    }

    /// Variant with throughput annotations.
    pub fn bench_elems(&mut self, name: &str, elems: f64, mut f: impl FnMut()) -> &mut BenchResult {
        self.bench_with(name, Some(elems), None, &mut f)
    }

    pub fn bench_bytes(&mut self, name: &str, bytes: f64, mut f: impl FnMut()) -> &mut BenchResult {
        self.bench_with(name, None, Some(bytes), &mut f)
    }

    fn bench_with(
        &mut self,
        name: &str,
        elems: Option<f64>,
        bytes: Option<f64>,
        f: &mut dyn FnMut(),
    ) -> &mut BenchResult {
        // Warm-up.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut samples = Vec::new();
        let decode0 = crate::obs::decode_bytes();
        let t1 = Instant::now();
        while t1.elapsed() < self.measure && samples.len() < self.max_iters {
            let it = Instant::now();
            f();
            samples.push(it.elapsed());
        }
        let decoded = crate::obs::decode_bytes().saturating_sub(decode0);
        let res = BenchResult {
            name: name.to_string(),
            decoded_bytes_per_iter: (decoded > 0)
                .then(|| decoded as f64 / samples.len().max(1) as f64),
            stats: Stats::from_samples(samples),
            elems_per_iter: elems,
            bytes_per_iter: bytes,
        };
        eprintln!("  {:<44} {}", res.name, summary(&res));
        self.results.push(res);
        self.results.last_mut().unwrap()
    }

    /// Record an externally-measured one-shot duration (end-to-end phases
    /// too slow to iterate).
    pub fn record_once(&mut self, name: &str, elapsed: Duration) {
        let res = BenchResult {
            name: name.to_string(),
            stats: Stats::from_samples(vec![elapsed]),
            elems_per_iter: None,
            bytes_per_iter: None,
            decoded_bytes_per_iter: None,
        };
        eprintln!("  {:<44} {}", res.name, summary(&res));
        self.results.push(res);
    }

    /// Record one packed-vs-f32 time ratio for `net` under `kernel`.
    pub fn record_ratio(&mut self, net: &str, kernel: &'static str, packed_over_f32: f64) {
        eprintln!("  {net}: packed/f32 time ratio {packed_over_f32:.3}x ({kernel})");
        self.ratios.push(RatioEntry { net: net.to_string(), kernel, packed_over_f32 });
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    pub fn ratios(&self) -> &[RatioEntry] {
        &self.ratios
    }

    /// File-system-safe slug of the suite title.
    pub fn slug(&self) -> String {
        let mut s: String = self
            .title
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect();
        while s.contains("__") {
            s = s.replace("__", "_");
        }
        s.trim_matches('_').to_string()
    }

    /// Write the results as `BENCH_<slug>.json` into `dir`.
    pub fn write_json(&self, dir: &Path) -> anyhow::Result<PathBuf> {
        let ns = |d: Duration| Json::num(d.as_nanos() as f64);
        let results: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::str(r.name.clone())),
                    ("iters", Json::num(r.stats.iters as f64)),
                    ("mean_ns", ns(r.stats.mean)),
                    ("median_ns", ns(r.stats.median)),
                    ("p95_ns", ns(r.stats.p95)),
                    ("min_ns", ns(r.stats.min)),
                    ("stddev_ns", ns(r.stats.stddev)),
                    ("elems_per_iter", r.elems_per_iter.map(Json::num).unwrap_or(Json::Null)),
                    ("bytes_per_iter", r.bytes_per_iter.map(Json::num).unwrap_or(Json::Null)),
                    (
                        "decoded_bytes_per_iter",
                        r.decoded_bytes_per_iter.map(Json::num).unwrap_or(Json::Null),
                    ),
                ])
            })
            .collect();
        let ratios: Vec<Json> = self
            .ratios
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("net", Json::str(r.net.clone())),
                    ("kernel", Json::str(r.kernel)),
                    ("packed_over_f32", Json::num(r.packed_over_f32)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("schema", Json::num(1.0)),
            ("title", Json::str(self.title.clone())),
            ("kernel", Json::str(self.kernel)),
            ("results", Json::arr(results)),
            ("ratios", Json::arr(ratios)),
        ]);
        let path = dir.join(format!("BENCH_{}.json", self.slug()));
        crate::util::write_file(&path, doc.pretty().as_bytes())?;
        Ok(path)
    }

    /// Print the aligned report table; returns it as a string too. When
    /// `QBOUND_BENCH_JSON` names a directory, also writes
    /// [`BenchSuite::write_json`] there.
    pub fn finish(&self) -> String {
        if let Ok(dir) = std::env::var("QBOUND_BENCH_JSON") {
            if !dir.is_empty() {
                match self.write_json(Path::new(&dir)) {
                    Ok(p) => eprintln!("  bench json -> {}", p.display()),
                    Err(e) => eprintln!("  bench json failed: {e:#}"),
                }
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        out.push_str(&format!(
            "{:<44} {:>10} {:>10} {:>10} {:>10} {:>7}  throughput\n",
            "benchmark", "mean", "median", "p95", "min", "iters"
        ));
        for r in &self.results {
            out.push_str(&format!(
                "{:<44} {:>10} {:>10} {:>10} {:>10} {:>7} {}\n",
                r.name,
                crate::util::human_duration(r.stats.mean),
                crate::util::human_duration(r.stats.median),
                crate::util::human_duration(r.stats.p95),
                crate::util::human_duration(r.stats.min),
                r.stats.iters,
                r.throughput_line(),
            ));
        }
        print!("{out}");
        out
    }
}

fn summary(r: &BenchResult) -> String {
    format!(
        "mean {} (p95 {}, n={}){}",
        crate::util::human_duration(r.stats.mean),
        crate::util::human_duration(r.stats.p95),
        r.stats.iters,
        r.throughput_line()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_known_samples() {
        let samples: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        let s = Stats::from_samples(samples);
        assert_eq!(s.iters, 100);
        assert_eq!(s.min, Duration::from_micros(1));
        assert_eq!(s.median, Duration::from_micros(51));
        assert!((s.mean.as_micros() as i64 - 50).abs() <= 1);
        assert!(s.p95 >= Duration::from_micros(90));
    }

    #[test]
    fn bench_runs_and_reports() {
        std::env::set_var("QBOUND_BENCH_FAST", "1");
        let mut suite = BenchSuite::new("smoke");
        let mut acc = 0u64;
        suite.bench_elems("noop-ish", 1000.0, || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert_eq!(suite.results().len(), 1);
        let report = suite.finish();
        assert!(report.contains("noop-ish"));
    }

    #[test]
    fn record_once_appears_in_report() {
        let mut suite = BenchSuite::new("once");
        suite.record_once("phase", Duration::from_millis(123));
        assert!(suite.finish().contains("phase"));
    }

    #[test]
    fn slug_is_filesystem_safe() {
        let suite = BenchSuite::new("engine inference (per batch) + eval cache");
        assert_eq!(suite.slug(), "engine_inference_per_batch_eval_cache");
    }

    #[test]
    fn json_roundtrips() {
        let tmp = std::env::temp_dir().join(format!("qbound-benchjson-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&tmp);
        let mut suite = BenchSuite::new("json smoke");
        suite.record_once("phase", Duration::from_millis(5));
        suite.record_ratio("lenet", "scalar", 1.25);
        let path = suite.write_json(&tmp).unwrap();
        assert!(path.file_name().unwrap().to_str().unwrap().starts_with("BENCH_"));
        let text = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.at(&["title"]).as_str(), Some("json smoke"));
        // The dispatched kernel variant is part of the archive schema.
        assert!(j.at(&["kernel"]).as_str().is_some());
        let rs = j.at(&["results"]).as_arr().unwrap();
        assert_eq!(rs.len(), 1);
        assert!(rs[0].at(&["mean_ns"]).as_f64().unwrap() > 0.0);
        let ratios = j.at(&["ratios"]).as_arr().unwrap();
        assert_eq!(ratios[0].at(&["net"]).as_str(), Some("lenet"));
        assert_eq!(ratios[0].at(&["kernel"]).as_str(), Some("scalar"));
        assert_eq!(ratios[0].at(&["packed_over_f32"]).as_f64(), Some(1.25));
        let _ = std::fs::remove_dir_all(&tmp);
    }
}
