//! Read-only file mapping behind a safe API — the zero-copy substrate
//! of the packed-weight store.
//!
//! `libc` is unavailable offline, so on Linux (x86_64 / aarch64) the
//! `mmap`/`munmap` syscalls are issued directly via `core::arch::asm!`
//! inside this module; everywhere else — and whenever the syscall
//! fails — the file is read into an owned, 8-byte-aligned heap buffer
//! instead. Both shapes present the same immutable byte region, so the
//! sharing semantics (one [`Region`] in an `Arc`, many readers) hold on
//! every platform; only the "page cache backs N processes" bonus is
//! Linux-specific.
//!
//! Safety perimeter:
//!
//! * Mappings are `PROT_READ` + `MAP_PRIVATE`: nothing can write
//!   through them, and writes to the underlying file by *other*
//!   processes are not guaranteed visible — irrelevant here because
//!   store files are immutable once published (temp file + `rename`,
//!   never modified in place; see [`crate::store`]). That protocol is
//!   also what rules out `SIGBUS`: the mapped length is captured at map
//!   time and store files are never truncated, only unlinked — and an
//!   unlinked file stays alive until the last mapping drops.
//! * The pointer/length pair never leaves this module; readers only see
//!   `&[u8]` / `&[u64]` borrows tied to the [`Region`]'s lifetime, and
//!   `Drop` unmaps exactly what was mapped.

use std::fs::File;
use std::io::{self, Read, Seek};

/// An immutable byte region holding one store file: mmap'd when the
/// platform allows, an owned heap copy otherwise. `Send + Sync` — the
/// bytes never change after construction.
#[derive(Debug)]
pub struct Region {
    kind: Kind,
}

enum Kind {
    /// File-backed mapping (Linux fast path). `len` is the exact file
    /// length; the kernel rounds the mapping itself up to page size.
    Mapped { ptr: *const u8, len: usize },
    /// Heap fallback: `u64` storage so 8-byte alignment is free;
    /// `len` is the real byte length (the last word may be partial).
    Heap { words: Vec<u64>, len: usize },
}

impl std::fmt::Debug for Kind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Kind::Mapped { len, .. } => write!(f, "Mapped({len} bytes)"),
            Kind::Heap { len, .. } => write!(f, "Heap({len} bytes)"),
        }
    }
}

// SAFETY: the region is immutable for its whole lifetime — `PROT_READ`
// private mapping or an owned Vec nobody can reach mutably — so shared
// access from any thread is sound.
unsafe impl Send for Region {}
unsafe impl Sync for Region {}

impl Region {
    /// Map `file` (its full current length) read-only. Falls back to a
    /// heap copy when mapping is unsupported or fails; `is_mapped`
    /// reports which shape resulted. Empty files are an error — a store
    /// file always has at least a header.
    pub fn map(file: &mut File) -> io::Result<Region> {
        let len = file.metadata()?.len();
        if len == 0 {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "empty file"));
        }
        let len = usize::try_from(len)
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "file too large"))?;
        if let Some(ptr) = sys::mmap_readonly(file, len) {
            return Ok(Region { kind: Kind::Mapped { ptr, len } });
        }
        // Heap fallback: word-aligned storage, exact byte length kept.
        let n_words = len.div_ceil(8);
        let mut words = vec![0u64; n_words];
        // SAFETY: a `[u64; n]` is trivially viewable as `[u8; 8n]`; we
        // only write the first `len` bytes and never read past the Vec.
        let bytes =
            unsafe { std::slice::from_raw_parts_mut(words.as_mut_ptr() as *mut u8, len) };
        file.seek(io::SeekFrom::Start(0))?;
        file.read_exact(bytes)?;
        Ok(Region { kind: Kind::Heap { words, len } })
    }

    /// Byte length of the region (the exact file length at map time).
    pub fn len(&self) -> usize {
        match &self.kind {
            Kind::Mapped { len, .. } => *len,
            Kind::Heap { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether the region is a real file mapping (vs the heap copy).
    pub fn is_mapped(&self) -> bool {
        matches!(self.kind, Kind::Mapped { .. })
    }

    /// The region's bytes.
    pub fn bytes(&self) -> &[u8] {
        match &self.kind {
            // SAFETY: `ptr` is a live `PROT_READ` mapping of exactly
            // `len` bytes, valid until `Drop`, never written.
            Kind::Mapped { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
            Kind::Heap { words, len } => {
                // SAFETY: in-bounds prefix view of the owned words.
                unsafe { std::slice::from_raw_parts(words.as_ptr() as *const u8, *len) }
            }
        }
    }

    /// View `n_words` little-endian `u64`s starting at `byte_off` —
    /// the packed-bitstream payload view. `byte_off` must be 8-aligned
    /// (mmap bases are page-aligned and the heap buffer is word-backed,
    /// so an aligned offset yields an aligned pointer); returns `None`
    /// on misalignment or out-of-bounds instead of panicking, because
    /// callers validate untrusted file headers with it.
    pub fn words_at(&self, byte_off: usize, n_words: usize) -> Option<&[u64]> {
        if byte_off % 8 != 0 {
            return None;
        }
        let end = byte_off.checked_add(n_words.checked_mul(8)?)?;
        if end > self.len() {
            return None;
        }
        let base = self.bytes().as_ptr();
        debug_assert_eq!(base.align_offset(8), 0, "region base must be 8-aligned");
        // SAFETY: range-checked above; base + byte_off is 8-aligned
        // (aligned base, aligned offset); u64 has no invalid bit
        // patterns. Byte order note: words were written to disk as
        // little-endian u64s, so this view is only correct on
        // little-endian hosts — the header validation in
        // `crate::store` rejects foreign-endian files by magic.
        Some(unsafe { std::slice::from_raw_parts(base.add(byte_off) as *const u64, n_words) })
    }
}

impl Drop for Region {
    fn drop(&mut self) {
        if let Kind::Mapped { ptr, len } = &self.kind {
            sys::munmap(*ptr, *len);
        }
    }
}

/// Raw `mmap`/`munmap` on Linux x86_64 / aarch64; stubs elsewhere.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod sys {
    use std::fs::File;
    use std::os::unix::io::AsRawFd;

    const PROT_READ: usize = 1;
    const MAP_PRIVATE: usize = 2;

    /// `mmap(NULL, len, PROT_READ, MAP_PRIVATE, fd, 0)`; `None` on any
    /// failure (caller falls back to a heap copy).
    pub fn mmap_readonly(file: &File, len: usize) -> Option<*const u8> {
        let fd = file.as_raw_fd();
        let ret: isize;
        #[cfg(target_arch = "x86_64")]
        // SAFETY: plain syscall; arguments follow the x86_64 Linux ABI
        // (nr in rax, args rdi/rsi/rdx/r10/r8/r9, rcx+r11 clobbered).
        unsafe {
            core::arch::asm!(
                "syscall",
                inlateout("rax") 9isize => ret, // __NR_mmap
                in("rdi") 0usize,
                in("rsi") len,
                in("rdx") PROT_READ,
                in("r10") MAP_PRIVATE,
                in("r8") fd as isize,
                in("r9") 0usize,
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack)
            );
        }
        #[cfg(target_arch = "aarch64")]
        // SAFETY: plain syscall; aarch64 ABI (nr in x8, args x0..x5).
        unsafe {
            core::arch::asm!(
                "svc 0",
                in("x8") 222usize, // __NR_mmap
                inlateout("x0") 0usize => ret,
                in("x1") len,
                in("x2") PROT_READ,
                in("x3") MAP_PRIVATE,
                in("x4") fd as isize,
                in("x5") 0usize,
                options(nostack)
            );
        }
        // Kernel returns a small negative errno on failure.
        if (-4095..0).contains(&ret) {
            None
        } else {
            Some(ret as *const u8)
        }
    }

    /// `munmap(ptr, len)` — failure is unrecoverable-by-retry and
    /// harmless to ignore (the region leaks, nothing dangles).
    pub fn munmap(ptr: *const u8, len: usize) {
        let _ret: isize;
        #[cfg(target_arch = "x86_64")]
        // SAFETY: unmapping a region this module mapped, exactly once.
        unsafe {
            core::arch::asm!(
                "syscall",
                inlateout("rax") 11isize => _ret, // __NR_munmap
                in("rdi") ptr,
                in("rsi") len,
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack)
            );
        }
        #[cfg(target_arch = "aarch64")]
        // SAFETY: unmapping a region this module mapped, exactly once.
        unsafe {
            core::arch::asm!(
                "svc 0",
                in("x8") 215usize, // __NR_munmap
                inlateout("x0") ptr as usize => _ret,
                in("x1") len,
                options(nostack)
            );
        }
    }
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
mod sys {
    use std::fs::File;

    /// No raw-syscall support on this target; always take the heap path.
    pub fn mmap_readonly(_file: &File, _len: usize) -> Option<*const u8> {
        None
    }

    pub fn munmap(_ptr: *const u8, _len: usize) {
        unreachable!("no mapping can exist without mmap support");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp_file(tag: &str, bytes: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("qbound-mmap-{tag}-{}", std::process::id()));
        std::fs::File::create(&p).unwrap().write_all(bytes).unwrap();
        p
    }

    #[test]
    fn maps_file_bytes_exactly() {
        let data: Vec<u8> = (0..4099u32).map(|i| (i % 251) as u8).collect(); // off page size
        let p = tmp_file("exact", &data);
        let mut f = File::open(&p).unwrap();
        let r = Region::map(&mut f).unwrap();
        assert_eq!(r.len(), data.len());
        assert_eq!(r.bytes(), &data[..]);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn word_view_is_little_endian_and_checked() {
        let mut bytes = Vec::new();
        for w in [0x1122334455667788u64, 0xdeadbeefcafef00d] {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        bytes.push(0xff); // trailing partial word
        let p = tmp_file("words", &bytes);
        let mut f = File::open(&p).unwrap();
        let r = Region::map(&mut f).unwrap();
        assert_eq!(r.words_at(0, 2).unwrap(), &[0x1122334455667788, 0xdeadbeefcafef00d]);
        assert_eq!(r.words_at(8, 1).unwrap(), &[0xdeadbeefcafef00d]);
        assert!(r.words_at(1, 1).is_none(), "misaligned offset");
        assert!(r.words_at(8, 2).is_none(), "past the end");
        assert!(r.words_at(16, 1).is_none(), "partial trailing word");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn mapping_survives_unlink() {
        // The gc safety property: removing a store file must not
        // invalidate live mappings.
        let data = vec![7u8; 1024];
        let p = tmp_file("unlink", &data);
        let mut f = File::open(&p).unwrap();
        let r = Region::map(&mut f).unwrap();
        drop(f);
        std::fs::remove_file(&p).unwrap();
        assert_eq!(r.bytes(), &data[..]);
    }

    #[test]
    fn empty_file_is_an_error() {
        let p = tmp_file("empty", b"");
        let mut f = File::open(&p).unwrap();
        assert!(Region::map(&mut f).is_err());
        let _ = std::fs::remove_file(&p);
    }
}
