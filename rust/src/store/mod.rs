//! Content-addressed on-disk store of packed weight bitstreams, with
//! zero-copy in-process sharing.
//!
//! The serving daemon (and every coordinator worker, and `qbound
//! eval/profile`) used to re-quantize and re-pack the same weight
//! tensors per executor — N workers × M resident configs held N·M
//! copies of bitstreams that are byte-identical by construction. This
//! module collapses that to **one resident copy per distinct tensor**:
//!
//! * **Key** = (SHA-256 of the raw f32 tensor bytes, panel layout,
//!   [`QFormat`]) — content-addressed, so identical weights at the same
//!   format share a file no matter which net/config/worker asks.
//! * **Value** = a self-describing file (`<key>.qbw`): a 128-byte
//!   validated header plus the packed `u64` bitstream words. Files are
//!   written to a unique temp name and published with an atomic
//!   `rename`, so concurrent same-key writers race cleanly — both end
//!   up with a complete, identical file, never a torn one.
//! * **Load** mmaps the file read-only ([`mmap::Region`]) and hands out
//!   [`PackedPanels`]/[`PackedBuf`] values whose words *borrow* the
//!   mapping ([`PackedBuf::from_shared`]): executors decode straight
//!   from the page cache. A per-store registry of `Weak` regions makes
//!   every in-process loader of the same key share one `Arc`-mapped
//!   region (and one strip-cache id), so the marginal cost of another
//!   executor with the same weights is zero bytes.
//!
//! Any validation failure — bad magic, size drift, payload checksum
//! mismatch — rejects the file, which is then deleted and re-packed
//! from the source weights: the store is a cache, never an authority.
//! `gc` ([`Store::gc`]) removes entries not referenced by the live
//! registry (and stale temp files); unlinking never invalidates live
//! mappings (see [`mmap`]).

pub mod mmap;

use std::collections::HashMap;
use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, Weak};
use std::time::{Duration, SystemTime};

use anyhow::{Context, Result};

use crate::memory::{storage_width, PackedBuf, PackedPanels, WordBacking};
use crate::quant::QFormat;
use crate::util::json::Json;
use crate::util::sha256::Sha256;

/// Store file magic: identifies the format *and* pins little-endian
/// word order (the payload view is a raw `&[u64]` reinterpretation).
const MAGIC: &[u8; 8] = b"QBWSTOR1";
/// Bump when the header layout changes; older files become misses.
const VERSION: u32 = 1;
/// Fixed header size; the payload words start here (8-byte aligned).
const HEADER_BYTES: usize = 128;

const KIND_PANELS: u32 = 1;
const KIND_BUF: u32 = 2;

/// Self-describing store-file header. Every field a reader needs to
/// interpret — or distrust — the payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Header {
    kind: u32,
    width: u32,
    /// Stored values.
    len: u64,
    /// Payload length in `u64` words.
    n_words: u64,
    kd: u64,
    nr: u64,
    n_panels: u64,
    ibits: i32,
    fbits: i32,
    /// First 8 bytes (LE) of SHA-256 over the payload bytes.
    check: u64,
}

impl Header {
    fn encode(&self) -> [u8; HEADER_BYTES] {
        let mut h = [0u8; HEADER_BYTES];
        h[0..8].copy_from_slice(MAGIC);
        h[8..12].copy_from_slice(&VERSION.to_le_bytes());
        h[12..16].copy_from_slice(&self.kind.to_le_bytes());
        h[16..20].copy_from_slice(&self.width.to_le_bytes());
        h[24..32].copy_from_slice(&self.len.to_le_bytes());
        h[32..40].copy_from_slice(&self.n_words.to_le_bytes());
        h[40..48].copy_from_slice(&self.kd.to_le_bytes());
        h[48..56].copy_from_slice(&self.nr.to_le_bytes());
        h[56..64].copy_from_slice(&self.n_panels.to_le_bytes());
        h[64..68].copy_from_slice(&self.ibits.to_le_bytes());
        h[68..72].copy_from_slice(&self.fbits.to_le_bytes());
        h[72..80].copy_from_slice(&self.check.to_le_bytes());
        h
    }

    /// Decode and structurally validate a header. `None` on anything
    /// unexpected — wrong magic/version, impossible sizes — never a
    /// panic: the bytes are untrusted disk content.
    fn decode(bytes: &[u8]) -> Option<Header> {
        if bytes.len() < HEADER_BYTES || &bytes[0..8] != MAGIC {
            return None;
        }
        // Offsets are all inside the length-checked 128-byte prefix.
        let u32_at = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().expect("4 bytes"));
        let u64_at = |o: usize| u64::from_le_bytes(bytes[o..o + 8].try_into().expect("8 bytes"));
        let i32_at = |o: usize| i32::from_le_bytes(bytes[o..o + 4].try_into().expect("4 bytes"));
        if u32_at(8) != VERSION {
            return None;
        }
        let h = Header {
            kind: u32_at(12),
            width: u32_at(16),
            len: u64_at(24),
            n_words: u64_at(32),
            kd: u64_at(40),
            nr: u64_at(48),
            n_panels: u64_at(56),
            ibits: i32_at(64),
            fbits: i32_at(68),
            check: u64_at(72),
        };
        // Size fields are untrusted: checked arithmetic, no panics.
        let ok = (h.kind == KIND_PANELS || h.kind == KIND_BUF)
            && h.width >= 1
            && h.width <= 64
            && h.len.checked_mul(h.width as u64).map(|b| b.div_ceil(64)) == Some(h.n_words)
            && (h.kind != KIND_PANELS
                || h.n_panels.checked_mul(h.kd).and_then(|v| v.checked_mul(h.nr))
                    == Some(h.len));
        ok.then_some(h)
    }

    fn fmt_label(&self) -> String {
        if self.ibits < 0 {
            "fp32".to_string()
        } else {
            format!("{}.{}", self.ibits, self.fbits)
        }
    }
}

/// First 8 bytes (LE) of SHA-256 over a word slice's bytes — the
/// payload integrity check. 64 bits of a cryptographic digest is ample
/// for corruption detection (the 256-bit *naming* hash is what guards
/// against collisions).
fn payload_check(words: &[u64]) -> u64 {
    let mut h = Sha256::new();
    let mut buf = [0u8; 4096];
    for chunk in words.chunks(512) {
        for (i, w) in chunk.iter().enumerate() {
            buf[8 * i..8 * i + 8].copy_from_slice(&w.to_le_bytes());
        }
        h.update(&buf[..chunk.len() * 8]);
    }
    u64::from_le_bytes(h.finish()[..8].try_into().expect("8-byte prefix"))
}

/// SHA-256 over a raw f32 tensor (little-endian bytes), as hex — the
/// content half of every store key.
pub fn content_hash(raw: &[f32]) -> String {
    let mut h = Sha256::new();
    let mut buf = [0u8; 4096];
    for chunk in raw.chunks(1024) {
        for (i, v) in chunk.iter().enumerate() {
            buf[4 * i..4 * i + 4].copy_from_slice(&v.to_le_bytes());
        }
        h.update(&buf[..chunk.len() * 4]);
    }
    crate::util::sha256::hex(&h.finish())
}

/// Store key of a GEMM weight tensor packed into `kd`×`nr` panels
/// covering `n` output columns. 160 bits of content hash plus the
/// full layout and format, all legible in `store ls`.
pub fn panels_key(raw: &[f32], fmt: QFormat, kd: usize, n: usize, nr: usize) -> String {
    format!("{}-g{kd}x{n}r{nr}-{fmt}", &content_hash(raw)[..40])
}

/// Store key of a flat (bias) tensor packed at `fmt`.
pub fn bias_key(raw: &[f32], fmt: QFormat) -> String {
    format!("{}-b{}-{fmt}", &content_hash(raw)[..40], raw.len())
}

/// Word view into a mapped store file's payload: the [`WordBacking`]
/// that lets a [`PackedBuf`] borrow an mmap'd region.
#[derive(Debug)]
struct RegionWords {
    region: Arc<mmap::Region>,
    n_words: usize,
}

impl WordBacking for RegionWords {
    fn words(&self) -> &[u64] {
        // Range and alignment were validated when the region was
        // admitted to the registry; the region is immutable after.
        self.region
            .words_at(HEADER_BYTES, self.n_words)
            .expect("payload range validated at load")
    }
}

/// One live mapping in the in-process registry.
struct SharedEntry {
    region: Weak<mmap::Region>,
    /// Strip-cache identity every sharer of this key reuses.
    panels_id: u64,
}

/// Cumulative per-store counters (process lifetime). `packs` is the
/// warm-start acceptance counter: a fully warm start performs zero.
#[derive(Clone, Copy, Debug, Default)]
pub struct StoreStats {
    /// Key already mapped in this process (zero-cost share).
    pub hits_shared: u64,
    /// Key loaded from disk (one mmap, no pack).
    pub hits_disk: u64,
    /// Key absent — had to pack from source weights.
    pub misses: u64,
    /// Pack operations performed (== misses unless saving failed).
    pub packs: u64,
    /// Files published (atomic tmp + rename).
    pub writes: u64,
    /// Files rejected by validation (then deleted and re-packed).
    pub invalid: u64,
}

#[derive(Default)]
struct StatsCells {
    hits_shared: AtomicU64,
    hits_disk: AtomicU64,
    misses: AtomicU64,
    packs: AtomicU64,
    writes: AtomicU64,
    invalid: AtomicU64,
}

/// A content-addressed packed-weight store rooted at one directory.
///
/// [`Store::open`] returns a per-directory process singleton, so every
/// opener of the same directory shares one registry and one set of
/// counters — that is what makes "one resident mapping per distinct
/// tensor" hold across serve workers, the coordinator pool, and CLI
/// commands inside one process.
pub struct Store {
    dir: PathBuf,
    shared: Mutex<HashMap<String, SharedEntry>>,
    stats: StatsCells,
}

/// Per-directory singletons (keyed by canonical path).
static INSTANCES: OnceLock<Mutex<HashMap<PathBuf, Arc<Store>>>> = OnceLock::new();

impl std::fmt::Debug for Store {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Store").field("dir", &self.dir).finish_non_exhaustive()
    }
}

impl Store {
    /// Open (creating if needed) the store at `dir`. Returns the
    /// process-wide instance for that directory.
    pub fn open(dir: &Path) -> Result<Arc<Store>> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating store dir {}", dir.display()))?;
        let canon = dir
            .canonicalize()
            .with_context(|| format!("resolving store dir {}", dir.display()))?;
        let mut map = INSTANCES
            .get_or_init(|| Mutex::new(HashMap::new()))
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        if let Some(s) = map.get(&canon) {
            return Ok(Arc::clone(s));
        }
        let store = Arc::new(Store {
            dir: canon.clone(),
            shared: Mutex::new(HashMap::new()),
            stats: StatsCells::default(),
        });
        map.insert(canon, Arc::clone(&store));
        Ok(store)
    }

    /// The store selected by `QBOUND_STORE_DIR`, if any. Open failures
    /// are logged and treated as "no store" — a broken store directory
    /// must not take inference down.
    pub fn from_env() -> Option<Arc<Store>> {
        match std::env::var("QBOUND_STORE_DIR") {
            Ok(d) if !d.trim().is_empty() => match Store::open(Path::new(d.trim())) {
                Ok(s) => Some(s),
                Err(e) => {
                    log::warn!("QBOUND_STORE_DIR unusable, continuing without store: {e:#}");
                    None
                }
            },
            _ => None,
        }
    }

    /// Store root directory (canonical).
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn file_path(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.qbw"))
    }

    // ---- load-or-pack ------------------------------------------------------

    /// Panel bitstream for the GEMM tensor `raw` under (`fmt`, `kd`,
    /// `n`, `nr`): shared mapping if this process already holds the
    /// key, an mmap load if the store file exists and validates, else
    /// `pack()` + atomic publish. Never fails — every store problem
    /// degrades to the plain owned pack the caller would have done
    /// anyway.
    pub fn panels_for(
        &self,
        raw: &[f32],
        fmt: QFormat,
        kd: usize,
        n: usize,
        nr: usize,
        pack: impl FnOnce() -> PackedPanels,
    ) -> PackedPanels {
        let key = panels_key(raw, fmt, kd, n, nr);
        let expect_len = (n.div_ceil(nr) * kd * nr) as u64;
        let expect = Header {
            kind: KIND_PANELS,
            width: storage_width(fmt),
            len: expect_len,
            n_words: (expect_len * storage_width(fmt) as u64).div_ceil(64),
            kd: kd as u64,
            nr: nr as u64,
            n_panels: n.div_ceil(nr) as u64,
            ibits: fmt.ibits as i32,
            fbits: fmt.fbits as i32,
            check: 0, // filled/verified per path
        };
        match self.load_or_insert(&key, &expect) {
            Some((region, h, id)) => {
                let buf = shared_buf(region, &h);
                PackedPanels::from_buf(buf, fmt, kd, nr, id)
            }
            None => {
                let pp = pack();
                self.count(|s| &s.packs, "qbound_store_packs_total", &[]);
                debug_assert_eq!(pp.len() as u64, expect.len, "pack layout drifted from key");
                let mut h = expect;
                h.check = payload_check(pp.buf().words());
                self.publish(&key, &h, pp.buf().words());
                // Load the published file back so this executor also
                // decodes from the shared mapping (and later loaders
                // share with it); fall back to the owned pack if that
                // fails for any reason.
                match self.load_or_insert(&key, &expect) {
                    Some((region, h, id)) => {
                        PackedPanels::from_buf(shared_buf(region, &h), fmt, kd, nr, id)
                    }
                    None => pp,
                }
            }
        }
    }

    /// Flat (bias) bitstream for `raw` under `fmt` — same protocol as
    /// [`Store::panels_for`].
    pub fn buf_for(
        &self,
        raw: &[f32],
        fmt: QFormat,
        pack: impl FnOnce() -> PackedBuf,
    ) -> PackedBuf {
        let key = bias_key(raw, fmt);
        let expect = Header {
            kind: KIND_BUF,
            width: storage_width(fmt),
            len: raw.len() as u64,
            n_words: (raw.len() as u64 * storage_width(fmt) as u64).div_ceil(64),
            kd: 0,
            nr: 0,
            n_panels: 0,
            ibits: fmt.ibits as i32,
            fbits: fmt.fbits as i32,
            check: 0,
        };
        match self.load_or_insert(&key, &expect) {
            Some((region, h, _)) => shared_buf(region, &h),
            None => {
                let buf = pack();
                self.count(|s| &s.packs, "qbound_store_packs_total", &[]);
                debug_assert_eq!(buf.len() as u64, expect.len, "pack length drifted from key");
                let mut h = expect;
                h.check = payload_check(buf.words());
                self.publish(&key, &h, buf.words());
                match self.load_or_insert(&key, &expect) {
                    Some((region, h, _)) => shared_buf(region, &h),
                    None => buf,
                }
            }
        }
    }

    /// Resolve `key` to a live mapped region: registry first, then the
    /// store file (validated, then admitted to the registry). `None`
    /// means "not available — pack it". Also returns the header and
    /// the key's strip-cache id.
    fn load_or_insert(
        &self,
        key: &str,
        expect: &Header,
    ) -> Option<(Arc<mmap::Region>, Header, u64)> {
        // Fast path: someone in this process already mapped the key.
        {
            let mut shared = self.shared.lock().unwrap_or_else(|e| e.into_inner());
            if let Some(entry) = shared.get(key) {
                if let Some(region) = entry.region.upgrade() {
                    if let Some(h) = Header::decode(region.bytes()) {
                        if headers_compatible(&h, expect) {
                            self.count(
                                |s| &s.hits_shared,
                                "qbound_store_hits_total",
                                &[("source", "shared")],
                            );
                            return Some((region, h, entry.panels_id));
                        }
                    }
                }
                shared.remove(key); // dead weak or stale mapping
            }
        }

        // Disk path: map + validate the store file.
        let path = self.file_path(key);
        let mut file = match File::open(&path) {
            Ok(f) => f,
            Err(_) => {
                self.count(|s| &s.misses, "qbound_store_misses_total", &[]);
                return None;
            }
        };
        let region = match mmap::Region::map(&mut file) {
            Ok(r) => Arc::new(r),
            Err(e) => {
                log::warn!("store: mapping {} failed: {e}", path.display());
                self.reject(&path);
                return None;
            }
        };
        let h = match Header::decode(region.bytes()) {
            Some(h) if headers_compatible(&h, expect) => h,
            _ => {
                log::warn!("store: {} failed header validation, re-packing", path.display());
                self.reject(&path);
                return None;
            }
        };
        let payload_len = (h.n_words as usize).checked_mul(8);
        let words = match region.words_at(HEADER_BYTES, h.n_words as usize) {
            // Exact length: a valid file is header + payload, nothing else.
            Some(w) if payload_len.map(|p| HEADER_BYTES + p) == Some(region.len()) => w,
            _ => {
                log::warn!("store: {} is truncated or oversized, re-packing", path.display());
                self.reject(&path);
                return None;
            }
        };
        if payload_check(words) != h.check {
            log::warn!("store: {} payload checksum mismatch, re-packing", path.display());
            self.reject(&path);
            return None;
        }
        let id = PackedPanels::alloc_id();
        let mut shared = self.shared.lock().unwrap_or_else(|e| e.into_inner());
        // Another thread may have won the race while we validated; share
        // its region (and id) so the process still holds one mapping.
        if let Some(entry) = shared.get(key) {
            if let Some(r) = entry.region.upgrade() {
                self.count(|s| &s.hits_shared, "qbound_store_hits_total", &[("source", "shared")]);
                return Some((r, h, entry.panels_id));
            }
        }
        shared.insert(
            key.to_string(),
            SharedEntry { region: Arc::downgrade(&region), panels_id: id },
        );
        self.count(|s| &s.hits_disk, "qbound_store_hits_total", &[("source", "disk")]);
        Some((region, h, id))
    }

    /// Atomically publish `words` under `key`: write header + payload
    /// to a unique temp file, then `rename` into place. Concurrent
    /// same-key writers both succeed (last rename wins; the contents
    /// are identical by construction). IO failures are logged, not
    /// fatal — the caller keeps its owned pack.
    fn publish(&self, key: &str, header: &Header, words: &[u64]) {
        static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
        let tmp = self.dir.join(format!(
            "{key}.{}-{}.tmp",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let path = self.file_path(key);
        let write = || -> std::io::Result<()> {
            let mut f = File::create(&tmp)?;
            f.write_all(&header.encode())?;
            let mut buf = Vec::with_capacity(4096);
            for chunk in words.chunks(512) {
                buf.clear();
                for w in chunk {
                    buf.extend_from_slice(&w.to_le_bytes());
                }
                f.write_all(&buf)?;
            }
            f.sync_all()?;
            std::fs::rename(&tmp, &path)
        };
        match write() {
            Ok(()) => {
                self.count(|s| &s.writes, "qbound_store_writes_total", &[]);
            }
            Err(e) => {
                log::warn!("store: publishing {} failed: {e}", path.display());
                let _ = std::fs::remove_file(&tmp);
            }
        }
    }

    /// Drop an invalid store file (best-effort) and count the rejection.
    fn reject(&self, path: &Path) {
        self.count(|s| &s.invalid, "qbound_store_invalid_total", &[]);
        let _ = std::fs::remove_file(path);
    }

    fn count(
        &self,
        cell: impl Fn(&StatsCells) -> &AtomicU64,
        obs_name: &'static str,
        labels: &[(&str, &str)],
    ) {
        cell(&self.stats).fetch_add(1, Ordering::Relaxed);
        crate::obs::counter(obs_name, "", labels).inc();
    }

    // ---- introspection -----------------------------------------------------

    pub fn stats(&self) -> StoreStats {
        StoreStats {
            hits_shared: self.stats.hits_shared.load(Ordering::Relaxed),
            hits_disk: self.stats.hits_disk.load(Ordering::Relaxed),
            misses: self.stats.misses.load(Ordering::Relaxed),
            packs: self.stats.packs.load(Ordering::Relaxed),
            writes: self.stats.writes.load(Ordering::Relaxed),
            invalid: self.stats.invalid.load(Ordering::Relaxed),
        }
    }

    /// Bytes of store files currently mapped and alive in this process
    /// — the de-duplicated resident weight total, counted once per
    /// distinct key no matter how many executors share it.
    pub fn resident_shared_bytes(&self) -> u64 {
        self.live_regions().iter().map(|(_, r)| r.len() as u64).sum()
    }

    /// Number of distinct live mappings.
    pub fn resident_mappings(&self) -> usize {
        self.live_regions().len()
    }

    fn live_regions(&self) -> Vec<(String, Arc<mmap::Region>)> {
        let mut shared = self.shared.lock().unwrap_or_else(|e| e.into_inner());
        // Prune dead weaks while we're here.
        shared.retain(|_, e| e.region.strong_count() > 0);
        shared
            .iter()
            .filter_map(|(k, e)| e.region.upgrade().map(|r| (k.clone(), r)))
            .collect()
    }

    /// `/v1/stats` + `STORE_stats.json` block.
    pub fn stats_json(&self) -> Json {
        let s = self.stats();
        Json::obj(vec![
            ("dir", Json::str(self.dir.display().to_string())),
            ("hits_shared", Json::num(s.hits_shared as f64)),
            ("hits_disk", Json::num(s.hits_disk as f64)),
            ("misses", Json::num(s.misses as f64)),
            ("packs", Json::num(s.packs as f64)),
            ("writes", Json::num(s.writes as f64)),
            ("invalid", Json::num(s.invalid as f64)),
            ("resident_shared_bytes", Json::num(self.resident_shared_bytes() as f64)),
            ("resident_mappings", Json::num(self.resident_mappings() as f64)),
        ])
    }

    /// One `ls` row per store file.
    pub fn ls(&self) -> Result<Vec<LsEntry>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir).context("reading store dir")? {
            let entry = entry?;
            let path = entry.path();
            let name = entry.file_name().to_string_lossy().into_owned();
            let Some(key) = name.strip_suffix(".qbw") else { continue };
            let meta = entry.metadata()?;
            let age = meta
                .modified()
                .ok()
                .and_then(|m| SystemTime::now().duration_since(m).ok())
                .unwrap_or_default();
            let (desc, valid) = match describe(&path) {
                Some(h) => (
                    format!(
                        "{} {} {}v x {}b",
                        if h.kind == KIND_PANELS { "panels" } else { "buf" },
                        h.fmt_label(),
                        h.len,
                        h.width,
                    ),
                    true,
                ),
                None => ("INVALID".to_string(), false),
            };
            out.push(LsEntry {
                key: key.to_string(),
                desc,
                valid,
                file_bytes: meta.len(),
                age_secs: age.as_secs(),
            });
        }
        out.sort_by(|a, b| a.key.cmp(&b.key));
        Ok(out)
    }

    /// Remove store files that are (a) not referenced by this process's
    /// live registry and (b) at least `min_age` old; stale temp files
    /// (crashed writers) older than a minute go unconditionally. Never
    /// touches live keys — and even for another process's live
    /// mappings, unlink is safe: Linux keeps an unlinked file alive
    /// until the last mapping drops, and a later cold loader just
    /// re-packs.
    pub fn gc(&self, min_age: Duration, dry_run: bool) -> Result<GcReport> {
        let live: std::collections::HashSet<String> =
            self.live_regions().into_iter().map(|(k, _)| k).collect();
        let mut report = GcReport::default();
        for entry in std::fs::read_dir(&self.dir).context("reading store dir")? {
            let entry = entry?;
            let path = entry.path();
            let name = entry.file_name().to_string_lossy().into_owned();
            let meta = entry.metadata()?;
            let age = meta
                .modified()
                .ok()
                .and_then(|m| SystemTime::now().duration_since(m).ok())
                .unwrap_or_default();
            if name.ends_with(".tmp") {
                if age >= Duration::from_secs(60) {
                    report.removed_tmp += 1;
                    if !dry_run {
                        let _ = std::fs::remove_file(&path);
                    }
                }
                continue;
            }
            let Some(key) = name.strip_suffix(".qbw") else { continue };
            if live.contains(key) {
                report.kept_live += 1;
            } else if age < min_age {
                report.kept_young += 1;
            } else {
                report.removed += 1;
                report.removed_bytes += meta.len();
                if !dry_run {
                    let _ = std::fs::remove_file(&path);
                }
            }
        }
        Ok(report)
    }
}

/// One row of [`Store::ls`].
#[derive(Clone, Debug)]
pub struct LsEntry {
    pub key: String,
    /// Human summary: kind, format, value count, width — or `INVALID`.
    pub desc: String,
    pub valid: bool,
    pub file_bytes: u64,
    pub age_secs: u64,
}

/// What [`Store::gc`] did (or would do, under `--dry-run`).
#[derive(Clone, Copy, Debug, Default)]
pub struct GcReport {
    pub removed: usize,
    pub removed_bytes: u64,
    pub kept_live: usize,
    pub kept_young: usize,
    pub removed_tmp: usize,
}

/// Build the shared-backed [`PackedBuf`] over a validated region.
fn shared_buf(region: Arc<mmap::Region>, h: &Header) -> PackedBuf {
    let backing: Arc<dyn WordBacking> =
        Arc::new(RegionWords { region, n_words: h.n_words as usize });
    PackedBuf::from_shared(backing, 0, h.n_words as usize, h.len as usize, h.width)
}

/// Whether a decoded header matches what the caller's key implies
/// (everything except the checksum, which is verified against the
/// payload separately).
fn headers_compatible(h: &Header, expect: &Header) -> bool {
    h.kind == expect.kind
        && h.width == expect.width
        && h.len == expect.len
        && h.n_words == expect.n_words
        && h.kd == expect.kd
        && h.nr == expect.nr
        && h.n_panels == expect.n_panels
        && h.ibits == expect.ibits
        && h.fbits == expect.fbits
}

/// Full-file validation for `ls`: header + exact length + checksum.
fn describe(path: &Path) -> Option<Header> {
    let mut file = File::open(path).ok()?;
    let region = mmap::Region::map(&mut file).ok()?;
    let h = Header::decode(region.bytes())?;
    if (h.n_words as usize).checked_mul(8).map(|p| HEADER_BYTES + p) != Some(region.len()) {
        return None;
    }
    let words = region.words_at(HEADER_BYTES, h.n_words as usize)?;
    (payload_check(words) == h.check).then_some(h)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip_and_rejection() {
        let h = Header {
            kind: KIND_PANELS,
            width: 9,
            len: 96,
            n_words: (96 * 9u64).div_ceil(64),
            kd: 6,
            nr: 16,
            n_panels: 1,
            ibits: 1,
            fbits: 8,
            check: 0xdeadbeef,
        };
        let bytes = h.encode();
        assert_eq!(Header::decode(&bytes), Some(h));
        // Wrong magic, wrong version, inconsistent sizes: all rejected.
        let mut bad = bytes;
        bad[0] ^= 1;
        assert!(Header::decode(&bad).is_none());
        let mut bad = bytes;
        bad[8] = 99;
        assert!(Header::decode(&bad).is_none());
        let mut bad = bytes;
        bad[32] ^= 1; // n_words no longer matches len*width
        assert!(Header::decode(&bad).is_none());
        assert!(Header::decode(&bytes[..64]).is_none());
    }

    #[test]
    fn keys_separate_content_layout_and_format() {
        let a = vec![0.5f32; 96];
        let mut b = a.clone();
        b[41] += 0.25;
        let fmt = QFormat::new(1, 8);
        let base = panels_key(&a, fmt, 6, 16, 16);
        assert_ne!(base, panels_key(&b, fmt, 6, 16, 16), "content");
        assert_ne!(base, panels_key(&a, QFormat::new(2, 7), 6, 16, 16), "format");
        assert_ne!(base, panels_key(&a, fmt, 3, 16, 16), "layout");
        assert_ne!(base, bias_key(&a, fmt), "kind");
        assert_eq!(base, panels_key(&a.clone(), fmt, 6, 16, 16), "deterministic");
    }

    #[test]
    fn open_is_a_per_directory_singleton() {
        let dir = std::env::temp_dir()
            .join(format!("qbound-store-singleton-{}", std::process::id()));
        let a = Store::open(&dir).unwrap();
        let b = Store::open(&dir).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
