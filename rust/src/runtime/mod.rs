//! PJRT runtime (behind `--features pjrt`): load AOT-compiled HLO text
//! and execute it from rust.
//!
//! One [`Engine`] wraps one compiled executable (one network, fixed batch).
//! The executable's input signature is `params…, images, wq, dq[, sq]` —
//! see `python/compile/aot.py`. Engines keep the trained weights
//! **device-resident** (`PjRtBuffer`s created once at load), so a per-call
//! execute only uploads the image batch (and the 2·L-float precision
//! configs): this is the L3 hot path.
//!
//! Interchange is HLO *text* (never serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod kernel;

use anyhow::{bail, Context, Result};

use crate::nets::NetManifest;
use crate::tensor::ntf;

pub use crate::backend::Variant;

/// A PJRT CPU session: the client plus host-side weight storage.
///
/// `PjRtClient` is `Rc`-based (not `Send`); coordinator workers each own a
/// `Session` on their own thread.
pub struct Session {
    pub client: xla::PjRtClient,
}

impl Session {
    pub fn cpu() -> Result<Session> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Session { client })
    }

    /// Load + compile an engine for `manifest`.
    pub fn load_engine(&self, manifest: &NetManifest, variant: Variant) -> Result<Engine> {
        Engine::load(self, manifest, variant)
    }
}

/// One compiled network executable with device-resident weights.
pub struct Engine {
    exe: xla::PjRtLoadedExecutable,
    weight_buffers: Vec<xla::PjRtBuffer>,
    pub manifest: NetManifest,
    pub variant: Variant,
    pub batch: usize,
    n_layers: usize,
    n_stages: usize,
    /// Cumulative executions (for utilization metrics).
    pub executions: std::cell::Cell<u64>,
}

impl Engine {
    pub fn load(session: &Session, manifest: &NetManifest, variant: Variant) -> Result<Engine> {
        let hlo_path = match variant {
            Variant::Standard => manifest.hlo_path(),
            Variant::Stages => {
                let sv = manifest
                    .stage_variant
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("{} has no stage variant", manifest.name))?;
                manifest.dir.join(&sv.hlo)
            }
        };
        let proto = xla::HloModuleProto::from_text_file(&hlo_path)
            .map_err(|e| anyhow::anyhow!("loading {}: {e:?}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = session
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", manifest.name))?;

        // Upload weights once; they stay device-resident for the engine's life.
        let weights = ntf::read_file(&manifest.weights_path())?;
        let mut weight_buffers = Vec::with_capacity(manifest.params.len());
        for p in &manifest.params {
            let t = weights
                .get(&p.name)
                .ok_or_else(|| anyhow::anyhow!("weights file missing {:?}", p.name))?;
            if t.dims != p.shape {
                bail!("{}: shape {:?} != manifest {:?}", p.name, t.dims, p.shape);
            }
            let buf = session
                .client
                .buffer_from_host_buffer(t.as_f32()?, &p.shape, None)
                .map_err(|e| anyhow::anyhow!("uploading {}: {e:?}", p.name))?;
            weight_buffers.push(buf);
        }

        let n_stages = manifest.stage_variant.as_ref().map(|s| s.n_stages).unwrap_or(0);
        Ok(Engine {
            exe,
            weight_buffers,
            batch: manifest.batch,
            n_layers: manifest.n_layers(),
            n_stages,
            manifest: manifest.clone(),
            variant,
            executions: std::cell::Cell::new(0),
        })
    }

    /// Upload one image batch to a device buffer for reuse across many
    /// executions (the eval hot path re-reads the same eval split for
    /// every configuration — see EXPERIMENTS.md §Perf).
    pub fn upload_images(&self, session: &Session, images: &[f32]) -> Result<xla::PjRtBuffer> {
        let img_elems: usize = self.manifest.input_shape.iter().product::<usize>() * self.batch;
        if images.len() != img_elems {
            bail!("images len {} != batch image elems {img_elems}", images.len());
        }
        let mut img_dims = vec![self.batch];
        img_dims.extend_from_slice(&self.manifest.input_shape);
        session
            .client
            .buffer_from_host_buffer(images, &img_dims, None)
            .map_err(|e| anyhow::anyhow!("upload images: {e:?}"))
    }

    /// Execute one batch. `images` is (batch, H, W, C) row-major; `wq`/`dq`
    /// are flattened (L, 2) wire configs; `sq` only for [`Variant::Stages`].
    ///
    /// Returns logits, row-major (batch, num_classes).
    pub fn infer(
        &self,
        session: &Session,
        images: &[f32],
        wq: &[f32],
        dq: &[f32],
        sq: Option<&[f32]>,
    ) -> Result<Vec<f32>> {
        let img_buf = self.upload_images(session, images)?;
        self.infer_prepared(session, &img_buf, wq, dq, sq)
    }

    /// [`Engine::infer`] with a pre-uploaded (device-resident) image batch.
    pub fn infer_prepared(
        &self,
        session: &Session,
        img_buf: &xla::PjRtBuffer,
        wq: &[f32],
        dq: &[f32],
        sq: Option<&[f32]>,
    ) -> Result<Vec<f32>> {
        if wq.len() != 2 * self.n_layers || dq.len() != 2 * self.n_layers {
            bail!("wq/dq must be 2*{} floats", self.n_layers);
        }
        let client = &session.client;
        let wq_buf = client
            .buffer_from_host_buffer(wq, &[self.n_layers, 2], None)
            .map_err(|e| anyhow::anyhow!("upload wq: {e:?}"))?;
        let dq_buf = client
            .buffer_from_host_buffer(dq, &[self.n_layers, 2], None)
            .map_err(|e| anyhow::anyhow!("upload dq: {e:?}"))?;

        let mut args: Vec<&xla::PjRtBuffer> = self.weight_buffers.iter().collect();
        args.push(img_buf);
        args.push(&wq_buf);
        args.push(&dq_buf);

        let sq_buf;
        match (self.variant, sq) {
            (Variant::Stages, Some(sq)) => {
                if sq.len() != 2 * self.n_stages {
                    bail!("sq must be 2*{} floats", self.n_stages);
                }
                sq_buf = client
                    .buffer_from_host_buffer(sq, &[self.n_stages, 2], None)
                    .map_err(|e| anyhow::anyhow!("upload sq: {e:?}"))?;
                args.push(&sq_buf);
            }
            (Variant::Stages, None) => bail!("stage variant needs sq"),
            (Variant::Standard, Some(_)) => bail!("standard variant takes no sq"),
            (Variant::Standard, None) => {}
        }

        let result = self.exe.execute_b(&args).map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        self.executions.set(self.executions.get() + 1);
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        // Lowered with return_tuple=True → 1-tuple of logits.
        let logits = lit.to_tuple1().map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        let v = logits.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
        let want = self.batch * self.manifest.num_classes;
        if v.len() != want {
            bail!("logits len {} != {}", v.len(), want);
        }
        Ok(v)
    }

    pub fn num_classes(&self) -> usize {
        self.manifest.num_classes
    }
}
