//! Standalone L1-kernel executables: the compiled Pallas quantizer outside
//! any network, for device-vs-host parity checks, kernel benchmarking, and
//! the stochastic-rounding study (paper §4 future work).

use anyhow::{bail, Result};

use super::Session;
use crate::quant::QFormat;

/// Rounding mode of the standalone kernel artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rounding {
    /// Round-to-nearest-even (the paper's mode).
    Nearest,
    /// Stochastic rounding (extension; needs a noise operand).
    Stochastic,
}

/// A compiled standalone quantize kernel over `n` fp32 elements.
pub struct KernelEngine {
    exe: xla::PjRtLoadedExecutable,
    pub n: usize,
    pub rounding: Rounding,
}

impl KernelEngine {
    /// Load `kernel_rne.hlo.txt` / `kernel_sr.hlo.txt` from `dir`.
    pub fn load(session: &Session, dir: &std::path::Path, rounding: Rounding) -> Result<Self> {
        let file = match rounding {
            Rounding::Nearest => "kernel_rne.hlo.txt",
            Rounding::Stochastic => "kernel_sr.hlo.txt",
        };
        let path = dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("loading {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = session
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {file}: {e:?}"))?;
        // Element count from the artifact index.
        let index = crate::nets::ArtifactIndexExt::kernel_n(dir)?;
        Ok(KernelEngine { exe, n: index, rounding })
    }

    /// Quantize `x` on device. `u` is the noise operand for
    /// [`Rounding::Stochastic`] (uniform [0,1), same length as `x`).
    pub fn quantize(
        &self,
        session: &Session,
        x: &[f32],
        fmt: QFormat,
        u: Option<&[f32]>,
    ) -> Result<Vec<f32>> {
        if x.len() != self.n {
            bail!("kernel expects {} elems, got {}", self.n, x.len());
        }
        let client = &session.client;
        let xb = client
            .buffer_from_host_buffer(x, &[self.n], None)
            .map_err(|e| anyhow::anyhow!("upload x: {e:?}"))?;
        let cfg = fmt.wire();
        let cb = client
            .buffer_from_host_buffer(&cfg, &[2], None)
            .map_err(|e| anyhow::anyhow!("upload cfg: {e:?}"))?;
        let mut args = vec![&xb, &cb];
        let ub;
        match (self.rounding, u) {
            (Rounding::Stochastic, Some(u)) => {
                if u.len() != self.n {
                    bail!("noise must be {} elems", self.n);
                }
                ub = client
                    .buffer_from_host_buffer(u, &[self.n], None)
                    .map_err(|e| anyhow::anyhow!("upload u: {e:?}"))?;
                args.push(&ub);
            }
            (Rounding::Stochastic, None) => bail!("stochastic kernel needs noise"),
            (Rounding::Nearest, Some(_)) => bail!("nearest kernel takes no noise"),
            (Rounding::Nearest, None) => {}
        }
        let out = self.exe.execute_b(&args).map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let lit = out[0][0].to_literal_sync().map_err(|e| anyhow::anyhow!("fetch: {e:?}"))?;
        let q = lit.to_tuple1().map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        q.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))
    }
}
