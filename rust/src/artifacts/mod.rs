//! Pure-Rust synthetic artifact generation.
//!
//! The python build path (`make artifacts`) trains the five scaled
//! networks under JAX and AOT-lowers them to HLO. That path needs a JAX
//! toolchain no CI box has — so this module produces a **self-contained
//! artifact set from Rust alone**: everything the reference backend,
//! the coordinator, the search stack, the benches and the integration
//! tests consume.
//!
//! Per network (from the [`crate::nets::arch`] registry):
//!
//! * He-initialized weights (`<net>.weights.ntf`),
//! * a synthetic eval split (`<net>.dataset.ntf`) whose labels are the
//!   network's **own fp32 top-1** ("network-as-teacher"): the fp32
//!   baseline is exact by construction and quantization degrades it the
//!   same way it degrades a trained net's accuracy,
//! * a validated `<net>.manifest.json` whose layer/param metadata comes
//!   from the same shape walk the python side uses,
//! * a placeholder `<net>.hlo.txt` (the reference backend never reads
//!   it; the PJRT backend needs real HLO from `make artifacts`).
//!
//! Candidate images are filtered for *label robustness*: a candidate is
//! kept only if its top-1 margin clears a relative threshold and (for
//! the small nets the test-suite stresses hardest) its label survives a
//! set of probe quantizations. This gives the precision sweeps a
//! realistic knee instead of a cliff.
//!
//! Plus one cross-implementation lock: `golden_quant.ntf`, quantization
//! vectors computed by an **independent f64 oracle**
//! ([`golden_quantize`]) that the `QFormat` host quantizer must match
//! bit-for-bit.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::backend::reference::Interpreter;
use crate::nets::arch::{self, Arch};
use crate::prng::Xoshiro256pp;
use crate::quant::QFormat;
use crate::tensor::{ntf, Tensor};

/// Bump when generated content changes shape (testkit keys its shared
/// artifact cache directory on this).
pub const SCHEMA_VERSION: u32 = 1;

/// Generation options.
#[derive(Clone, Debug)]
pub struct GenOptions {
    pub seed: u64,
    /// Eval images per network.
    pub n_eval: usize,
    /// Batch size recorded in the index/manifests.
    pub batch: usize,
    /// Element count of the standalone kernel artifacts.
    pub kernel_n: usize,
    /// Recorded in index.json (this generator always produces the
    /// CI-scale artifact set).
    pub quick: bool,
}

impl Default for GenOptions {
    fn default() -> Self {
        Self { seed: 0x9b0d_5eed, n_eval: 256, batch: 64, kernel_n: 1024, quick: true }
    }
}

/// The per-user cache directory where [`crate::testkit::ensure_artifacts`]
/// synthesizes the default artifact set (`~/.cache/qbound/...`, falling
/// back to a uid-free temp path only when `HOME` is unset).
/// [`crate::util::artifacts_dir`] knows to look here, so no process-wide
/// environment mutation is needed to share it.
pub fn default_cache_dir() -> std::path::PathBuf {
    let opts = GenOptions::default();
    let base = match std::env::var_os("HOME") {
        Some(h) if !h.is_empty() => std::path::PathBuf::from(h).join(".cache").join("qbound"),
        _ => std::env::temp_dir().join("qbound-cache"),
    };
    base.join(format!("synth-artifacts-v{}-seed{:x}", SCHEMA_VERSION, opts.seed))
}

/// Generate the full artifact set into `dir`.
pub fn generate(dir: &Path, opts: &GenOptions) -> Result<()> {
    std::fs::create_dir_all(dir)
        .map_err(|e| anyhow::anyhow!("creating {}: {e}", dir.display()))?;
    for name in arch::NET_ORDER {
        let t0 = std::time::Instant::now();
        gen_net(dir, opts, name).with_context(|| format!("generating {name}"))?;
        log::info!("generated {name} artifacts in {:.2}s", t0.elapsed().as_secs_f64());
    }
    write_golden_quant(dir)?;
    write_kernel_stubs(dir, opts)?;
    write_index(dir, opts)?;
    Ok(())
}

/// FNV-1a, for stable per-net seed derivation.
fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// He-normal parameter init (zeros for biases), python-compatible order.
pub fn init_params(arch: &Arch, seed: u64) -> Result<Vec<Vec<f32>>> {
    let specs = arch::param_specs(arch)?;
    let mut rng = Xoshiro256pp::new(seed);
    Ok(specs
        .iter()
        .map(|s| {
            if s.fan_in == 0 {
                vec![0.0; s.elems()]
            } else {
                let scale = (2.0 / s.fan_in as f64).sqrt();
                (0..s.elems()).map(|_| (rng.normal() * scale) as f32).collect()
            }
        })
        .collect())
}

/// Probe quantizations a kept image's label must survive. The small
/// nets are the ones the integration tests sweep aggressively; the
/// ImageNet-scale nets rely on the margin filter alone.
fn probe_configs(net: &str) -> Vec<(QFormat, QFormat)> {
    match net {
        "lenet" => vec![
            (QFormat::new(1, 6), QFormat::new(8, 3)),
            (QFormat::new(1, 5), QFormat::new(10, 2)),
            (QFormat::new(1, 8), QFormat::new(10, 4)),
        ],
        "convnet" => vec![(QFormat::new(1, 6), QFormat::new(8, 3))],
        _ => Vec::new(),
    }
}

/// Smooth random "blob" image in [0, 1], shared structure across
/// channels with per-channel amplitude variation.
fn gen_image(rng: &mut Xoshiro256pp, h: usize, w: usize, c: usize) -> Vec<f32> {
    const BLOBS: usize = 4;
    struct Blob {
        cy: f32,
        cx: f32,
        inv2s2: f32,
        amp: [f32; 4],
    }
    let mut blobs = Vec::with_capacity(BLOBS);
    for _ in 0..BLOBS {
        let sigma = rng.uniform_f32(1.5, h as f32 / 3.0);
        let mut amp = [0f32; 4];
        let base = rng.uniform_f32(-0.55, 0.55);
        for a in amp.iter_mut().take(c.min(4)) {
            *a = base * rng.uniform_f32(0.6, 1.4);
        }
        blobs.push(Blob {
            cy: rng.uniform_f32(0.0, h as f32),
            cx: rng.uniform_f32(0.0, w as f32),
            inv2s2: 1.0 / (2.0 * sigma * sigma),
            amp,
        });
    }
    let mut img = vec![0f32; h * w * c];
    for y in 0..h {
        for x in 0..w {
            let px = &mut img[(y * w + x) * c..][..c];
            for (ch, v) in px.iter_mut().enumerate() {
                let mut acc = 0.5f32;
                for b in &blobs {
                    let dy = y as f32 - b.cy;
                    let dx = x as f32 - b.cx;
                    acc += b.amp[ch.min(3)] * (-(dy * dy + dx * dx) * b.inv2s2).exp();
                }
                *v = acc.clamp(0.0, 1.0);
            }
        }
    }
    img
}

fn argmax_margin(logits: &[f32]) -> (usize, f32) {
    let mut best = 0usize;
    for (i, v) in logits.iter().enumerate() {
        if *v > logits[best] {
            best = i;
        }
    }
    let mut second = f32::NEG_INFINITY;
    for (i, v) in logits.iter().enumerate() {
        if i != best && *v > second {
            second = *v;
        }
    }
    (best, logits[best] - second)
}

fn rms(xs: &[f32]) -> f32 {
    (xs.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>() / xs.len() as f64).sqrt() as f32
}

fn gen_net(dir: &Path, opts: &GenOptions, name: &str) -> Result<()> {
    let arch = arch::get(name)
        .ok_or_else(|| anyhow::anyhow!("no architecture registered for {name:?}"))?;
    let net_seed = opts.seed ^ fnv1a(name);
    let params = init_params(&arch, net_seed)?;
    let specs = arch::param_specs(&arch)?;
    let interp = Interpreter::new(arch.clone(), params)?;
    let nl = arch.n_layers();

    // Pre-quantize weights for each probe config once.
    let probes = probe_configs(name);
    let probe_sets: Vec<(Vec<Vec<f32>>, Vec<QFormat>)> = probes
        .iter()
        .map(|&(wq, dq)| (interp.quantize_params(&vec![wq; nl]), vec![dq; nl]))
        .collect();

    // Candidate filtering: margin threshold + probe-stable label.
    let (h, w, c) = arch.input_shape;
    let mut rng = Xoshiro256pp::new(net_seed ^ 0xda7a_da7a);
    let mut images: Vec<f32> = Vec::with_capacity(opts.n_eval * h * w * c);
    let mut labels: Vec<i32> = Vec::with_capacity(opts.n_eval);
    // (margin, image, label) fallback pool if filtering is too strict.
    let mut rejects: Vec<(f32, Vec<f32>, i32)> = Vec::new();
    let mut attempts = 0usize;
    while labels.len() < opts.n_eval && attempts < opts.n_eval * 10 {
        attempts += 1;
        let img = gen_image(&mut rng, h, w, c);
        let logits = interp.forward_fp32(&img)?;
        let (label, margin) = argmax_margin(&logits);
        let strong = margin >= 0.05 * (rms(&logits) + 1e-6);
        let stable = strong
            && probe_sets.iter().all(|(qp, dq)| {
                interp
                    .forward_one(qp, &img, dq, None)
                    .map(|l| argmax_margin(&l).0 == label)
                    .unwrap_or(false)
            });
        if stable {
            images.extend_from_slice(&img);
            labels.push(label as i32);
        } else {
            rejects.push((margin, img, label as i32));
        }
    }
    if labels.len() < opts.n_eval {
        // Backfill with the highest-margin rejects; labels stay the fp32
        // teacher labels, so the baseline remains exact.
        rejects.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        for (_, img, label) in rejects.into_iter().take(opts.n_eval - labels.len()) {
            images.extend_from_slice(&img);
            labels.push(label);
        }
        log::warn!("{name}: backfilled eval split from low-margin candidates");
    }
    anyhow::ensure!(labels.len() == opts.n_eval, "{name}: only {} eval images", labels.len());

    // Weights NTF.
    let mut wmap = BTreeMap::new();
    for (spec, data) in specs.iter().zip(&interp.params) {
        wmap.insert(spec.name.clone(), Tensor::from_f32(spec.shape.clone(), data.clone())?);
    }
    ntf::write_file(&dir.join(format!("{name}.weights.ntf")), &wmap)?;

    // Dataset NTF.
    let mut dmap = BTreeMap::new();
    dmap.insert("images".to_string(), Tensor::from_f32(vec![opts.n_eval, h, w, c], images)?);
    dmap.insert("labels".to_string(), Tensor::from_i32(vec![opts.n_eval], labels)?);
    ntf::write_file(&dir.join(format!("{name}.dataset.ntf")), &dmap)?;

    // Placeholder HLO (PJRT needs the python build path for real HLO).
    let stub = hlo_stub(name);
    crate::util::write_file(&dir.join(format!("{name}.hlo.txt")), stub.as_bytes())?;
    if name == "alexnet" {
        crate::util::write_file(&dir.join("alexnet_stages.hlo.txt"), stub.as_bytes())?;
    }

    // Manifest.
    let manifest = render_manifest(&arch, opts, name)?;
    crate::util::write_file(&dir.join(format!("{name}.manifest.json")), manifest.as_bytes())?;
    Ok(())
}

fn hlo_stub(name: &str) -> String {
    format!(
        "// placeholder HLO for {name} — synthesized by `qbound gen-artifacts`.\n\
         // The pure-Rust reference backend interprets the graph directly and\n\
         // never reads this file; the PJRT backend requires real HLO text\n\
         // produced by the python build path (`make artifacts`).\n"
    )
}

fn render_manifest(arch: &Arch, opts: &GenOptions, name: &str) -> Result<String> {
    let (walks, _) = arch::shape_walk(arch)?;
    let specs = arch::param_specs(arch)?;
    let (h, w, c) = arch.input_shape;
    let mut s = String::with_capacity(4096);
    s.push_str("{\n");
    s.push_str(&format!("  \"name\": \"{name}\",\n"));
    s.push_str(&format!("  \"dataset\": \"{}\",\n", arch.dataset));
    s.push_str(&format!("  \"num_classes\": {},\n", arch.num_classes));
    s.push_str(&format!("  \"input_shape\": [{h}, {w}, {c}],\n"));
    s.push_str(&format!("  \"batch\": {},\n", opts.batch));
    s.push_str(&format!("  \"n_eval\": {},\n", opts.n_eval));
    // Teacher labelling makes the fp32 baseline exact by construction.
    s.push_str("  \"baseline_top1\": 1.0,\n");
    s.push_str("  \"layers\": [\n");
    for (i, l) in walks.iter().enumerate() {
        let stages: Vec<String> =
            l.stages.iter().map(|st| format!("{{\"name\": \"{st}\"}}")).collect();
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"kind\": \"{}\", \"in_elems\": {}, \"out_elems\": {}, \
             \"weight_elems\": {}, \"macs\": {}, \"stages\": [{}]}}{}\n",
            l.name,
            l.kind,
            l.in_elems,
            l.out_elems,
            l.weight_elems,
            l.macs,
            stages.join(", "),
            if i + 1 < walks.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"params\": [\n");
    for (i, p) in specs.iter().enumerate() {
        let dims: Vec<String> = p.shape.iter().map(|d| d.to_string()).collect();
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"shape\": [{}]}}{}\n",
            p.name,
            dims.join(", "),
            if i + 1 < specs.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!(
        "  \"files\": {{\"hlo\": \"{name}.hlo.txt\", \"weights\": \"{name}.weights.ntf\", \
         \"dataset\": \"{name}.dataset.ntf\"}},\n"
    ));
    if name == "alexnet" {
        // Fig-1 stage granularity: layer 2 (index 1), stages conv/relu/pool/norm.
        s.push_str(
            "  \"stage_variant\": {\"hlo\": \"alexnet_stages.hlo.txt\", \"group_index\": 1, \
             \"n_stages\": 4, \"stage_names\": [\"conv\", \"relu\", \"pool\", \"norm\"]}\n",
        );
    } else {
        s.push_str("  \"stage_variant\": null\n");
    }
    s.push_str("}\n");
    Ok(s)
}

fn write_index(dir: &Path, opts: &GenOptions) -> Result<()> {
    let nets: Vec<String> =
        arch::NET_ORDER.iter().map(|n| format!("    {{\"name\": \"{n}\"}}")).collect();
    let index = format!(
        "{{\n  \"nets\": [\n{}\n  ],\n  \"batch\": {},\n  \"quick\": {},\n  \"kernel_n\": {}\n}}\n",
        nets.join(",\n"),
        opts.batch,
        opts.quick,
        opts.kernel_n
    );
    crate::util::write_file(&dir.join("index.json"), index.as_bytes())
}

fn write_kernel_stubs(dir: &Path, _opts: &GenOptions) -> Result<()> {
    for f in ["kernel_rne.hlo.txt", "kernel_sr.hlo.txt"] {
        crate::util::write_file(&dir.join(f), hlo_stub("standalone-kernel").as_bytes())?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Golden quantization vectors (independent oracle)
// ---------------------------------------------------------------------------

/// Independent Q(I.F) oracle in f64: explicit round-half-to-even on the
/// scaled value, saturate, return as f32. Deliberately a *different
/// implementation path* from [`QFormat::quantize`] (which works in f32
/// with `round_ties_even`): the golden tests assert the two agree
/// bit-for-bit, locking the semantics from two directions the same way
/// the python jnp-oracle/Pallas pair does.
pub fn golden_quantize(x: f32, ibits: i32, fbits: i32) -> f32 {
    if ibits < 0 {
        return x;
    }
    let scale = (fbits as f64).exp2();
    let inv = (-(fbits as f64)).exp2();
    let hi_pow = ((ibits as f64) - 1.0).exp2();
    let lo = -hi_pow;
    let hi = hi_pow - inv;
    let v = x as f64 * scale;
    let r = round_half_even(v);
    ((r * inv).clamp(lo, hi)) as f32
}

/// Round-half-to-even on f64 without `round_ties_even` (independent path).
fn round_half_even(v: f64) -> f64 {
    let fl = v.floor();
    let diff = v - fl;
    if diff > 0.5 {
        fl + 1.0
    } else if diff < 0.5 {
        fl
    } else {
        // exact tie: pick the even neighbour (|fl| < 2^53 whenever a tie
        // is representable, so the cast is exact)
        if (fl as i64) % 2 == 0 {
            fl
        } else {
            fl + 1.0
        }
    }
}

/// The (I, F) grid covered by the golden vectors: paper-range formats
/// (I+F ≤ 16 keeps every grid point exactly representable in f32, so
/// the f32 and f64 paths must agree exactly).
pub fn golden_formats() -> Vec<(i32, i32)> {
    let mut out = Vec::new();
    for &i in &[0, 1, 2, 3, 4, 6, 8, 12] {
        for &f in &[0, 1, 2, 4, 7, 8, 14] {
            if i + f >= 1 && i + f <= 16 {
                out.push((i, f));
            }
        }
    }
    out
}

/// The golden input vector: boundary values plus deterministic noise at
/// several scales.
pub fn golden_inputs() -> Vec<f32> {
    let mut xs: Vec<f32> = vec![
        0.0,
        -0.0,
        0.25,
        -0.25,
        0.375,
        0.5,
        -0.5,
        0.75,
        1.0,
        -1.0,
        1.5,
        -1.5,
        2.5,
        -2.5,
        7.75,
        -8.0,
        1e-8,
        -1e-8,
        123.456,
        -123.456,
        32767.5,
        -32768.0,
        1e6,
        -1e6,
        f32::MAX,
        f32::MIN,
    ];
    let mut rng = Xoshiro256pp::new(0x601d);
    for scale in [0.1f32, 1.0, 16.0, 1024.0, 60000.0] {
        for _ in 0..96 {
            xs.push((rng.normal() as f32) * scale);
        }
    }
    xs
}

fn write_golden_quant(dir: &Path) -> Result<()> {
    let xs = golden_inputs();
    let mut map = BTreeMap::new();
    map.insert("x".to_string(), Tensor::from_f32(vec![xs.len()], xs.clone())?);
    for (i, f) in golden_formats() {
        let q: Vec<f32> = xs.iter().map(|&x| golden_quantize(x, i, f)).collect();
        map.insert(format!("q_{i}_{f}"), Tensor::from_f32(vec![xs.len()], q)?);
    }
    map.insert("q_sentinel".to_string(), Tensor::from_f32(vec![xs.len()], xs)?);
    ntf::write_file(&dir.join("golden_quant.ntf"), &map)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_formats_cover_the_grid() {
        let fmts = golden_formats();
        assert!(fmts.len() >= 40, "{} formats", fmts.len());
        assert!(fmts.iter().all(|&(i, f)| i + f >= 1 && i + f <= 16));
    }

    #[test]
    fn oracle_matches_host_quantizer_on_the_grid() {
        let xs = golden_inputs();
        for (i, f) in golden_formats() {
            let fmt = QFormat::new(i as i8, f as i8);
            for &x in &xs {
                let a = golden_quantize(x, i, f);
                let b = fmt.quantize(x);
                assert!(
                    a.to_bits() == b.to_bits() || (a == 0.0 && b == 0.0),
                    "Q{i}.{f}: oracle {a:e} vs host {b:e} at x={x:e}"
                );
            }
        }
    }

    #[test]
    fn oracle_sentinel_passthrough() {
        for &x in &golden_inputs() {
            assert_eq!(golden_quantize(x, -1, 0).to_bits(), x.to_bits());
        }
    }

    #[test]
    fn round_half_even_reference_cases() {
        assert_eq!(round_half_even(0.5), 0.0);
        assert_eq!(round_half_even(1.5), 2.0);
        assert_eq!(round_half_even(2.5), 2.0);
        assert_eq!(round_half_even(-0.5), 0.0);
        assert_eq!(round_half_even(-1.5), -2.0);
        assert_eq!(round_half_even(-2.3), -2.0);
        assert_eq!(round_half_even(2.7), 3.0);
    }

    #[test]
    fn blob_images_are_normalized() {
        let mut rng = Xoshiro256pp::new(3);
        let img = gen_image(&mut rng, 16, 16, 3);
        assert_eq!(img.len(), 16 * 16 * 3);
        assert!(img.iter().all(|v| (0.0..=1.0).contains(v)));
        // not constant
        let (lo, hi) = img.iter().fold((1f32, 0f32), |(l, h), &v| (l.min(v), h.max(v)));
        assert!(hi - lo > 0.05, "flat image {lo}..{hi}");
    }

    #[test]
    fn argmax_margin_basic() {
        let (l, m) = argmax_margin(&[0.1, 0.9, 0.3]);
        assert_eq!(l, 1);
        assert!((m - 0.6).abs() < 1e-6);
    }
}
