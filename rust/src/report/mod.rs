//! Report emitters: aligned text tables, ASCII line charts, CSV and
//! markdown fragments — everything `qbound repro` writes into `reports/`.

use std::fmt::Write as _;

/// An aligned text/markdown table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Monospace text rendering.
    pub fn text(&self) -> String {
        let w = self.widths();
        let mut s = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(s, "== {} ==", self.title);
        }
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(s, "{}", line(&self.headers, &w));
        let _ = writeln!(s, "{}", w.iter().map(|n| "-".repeat(*n)).collect::<Vec<_>>().join("  "));
        for r in &self.rows {
            let _ = writeln!(s, "{}", line(r, &w));
        }
        s
    }

    /// GitHub-flavoured markdown rendering.
    pub fn markdown(&self) -> String {
        let mut s = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(s, "### {}\n", self.title);
        }
        let _ = writeln!(s, "| {} |", self.headers.join(" | "));
        let dashes = self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|");
        let _ = writeln!(s, "|{dashes}|");
        for r in &self.rows {
            let _ = writeln!(s, "| {} |", r.join(" | "));
        }
        s
    }

    /// CSV rendering (quotes cells containing separators).
    pub fn csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut s = String::new();
        let head = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        let _ = writeln!(s, "{head}");
        for r in &self.rows {
            let _ = writeln!(s, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        s
    }
}

/// An ASCII line chart for sweep/scatter series (the textual stand-in for
/// the paper's figures).
pub struct Chart {
    pub title: String,
    pub x_label: String,
    pub y_label: String,
    pub width: usize,
    pub height: usize,
    series: Vec<(char, Vec<(f64, f64)>)>,
}

impl Chart {
    pub fn new(title: &str, x_label: &str, y_label: &str) -> Chart {
        Chart {
            title: title.to_string(),
            x_label: x_label.to_string(),
            y_label: y_label.to_string(),
            width: 72,
            height: 18,
            series: Vec::new(),
        }
    }

    pub fn series(&mut self, marker: char, points: Vec<(f64, f64)>) -> &mut Self {
        self.series.push((marker, points));
        self
    }

    pub fn render(&self) -> String {
        let all: Vec<(f64, f64)> =
            self.series.iter().flat_map(|(_, p)| p.iter().copied()).collect();
        if all.is_empty() {
            return format!("== {} == (no data)\n", self.title);
        }
        let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
        for &(x, y) in &all {
            x0 = x0.min(x);
            x1 = x1.max(x);
            y0 = y0.min(y);
            y1 = y1.max(y);
        }
        if (x1 - x0).abs() < 1e-12 {
            x1 = x0 + 1.0;
        }
        if (y1 - y0).abs() < 1e-12 {
            y1 = y0 + 1.0;
        }
        let mut grid = vec![vec![' '; self.width]; self.height];
        for (marker, pts) in &self.series {
            for &(x, y) in pts {
                let cx = (((x - x0) / (x1 - x0)) * (self.width - 1) as f64).round() as usize;
                let cy = (((y - y0) / (y1 - y0)) * (self.height - 1) as f64).round() as usize;
                let row = self.height - 1 - cy.min(self.height - 1);
                grid[row][cx.min(self.width - 1)] = *marker;
            }
        }
        let mut s = String::new();
        let _ = writeln!(s, "== {} ==  (y: {})", self.title, self.y_label);
        let _ = writeln!(s, "{:>8.3} ┐", y1);
        for row in &grid {
            let _ = writeln!(s, "         │{}", row.iter().collect::<String>());
        }
        let _ = writeln!(s, "{:>8.3} └{}", y0, "─".repeat(self.width));
        let _ = writeln!(s, "          {:<10}{:^52}{:>10.3}", format!("{x0:.3}"), self.x_label, x1);
        s
    }
}

/// Percentage with one decimal: `0.7158` → `"71.6%"`.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// Fixed-point ratio with two decimals: `0.28`.
pub fn ratio(x: f64) -> String {
    format!("{x:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("demo", &["net", "top-1"]);
        t.row(vec!["lenet".into(), "99.0%".into()]);
        t.row(vec!["googlenet-long-name".into(), "40.6%".into()]);
        t
    }

    #[test]
    fn text_alignment() {
        let txt = sample().text();
        let lines: Vec<&str> = txt.lines().collect();
        assert!(lines[1].starts_with("net"));
        assert!(lines[3].starts_with("lenet "));
        // columns align: "top-1" header starts at same column in all rows
        let col = lines[1].find("top-1").unwrap();
        assert_eq!(&lines[3][col..col + 5], "99.0%");
    }

    #[test]
    fn markdown_shape() {
        let md = sample().markdown();
        assert!(md.contains("| net | top-1 |"));
        assert!(md.contains("|---|---|"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"q".into()]);
        let csv = t.csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"q\""));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        Table::new("", &["a", "b"]).row(vec!["only-one".into()]);
    }

    #[test]
    fn chart_renders_extremes() {
        let mut c = Chart::new("t", "bits", "acc");
        c.series('*', vec![(0.0, 0.0), (8.0, 1.0), (4.0, 0.5)]);
        let r = c.render();
        assert!(r.contains('*'));
        assert!(r.contains("1.000"));
        assert!(r.contains("0.000"));
    }

    #[test]
    fn chart_empty_safe() {
        let c = Chart::new("t", "x", "y");
        assert!(c.render().contains("no data"));
    }

    #[test]
    fn formatters() {
        assert_eq!(pct(0.7158), "71.6%");
        assert_eq!(ratio(0.283), "0.28");
    }
}
