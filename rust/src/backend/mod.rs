//! Pluggable execution backends.
//!
//! Everything above the runtime — [`crate::eval`], [`crate::coordinator`],
//! [`crate::search`] — asks one question: *"run a quantized forward batch
//! of this network and give me the logits"*. This module turns that
//! question into a trait pair so the answer can come from different
//! engines (with the CPU hot loops themselves dispatched once per
//! process to an ISA-specific micro-kernel variant — see [`kernels`]):
//!
//! * [`Backend`] — a factory bound to one execution technology; it loads
//!   a network (manifest + weights) into a [`NetExecutor`].
//! * [`NetExecutor`] — one loaded network with resident weights; `infer`
//!   runs a single batch under a wire-encoded precision config.
//!
//! Three implementations ship today:
//!
//! | kind | module | availability |
//! |---|---|---|
//! | [`BackendKind::Reference`] | [`reference`] | always (pure Rust) |
//! | [`BackendKind::Fast`]      | [`fast`]      | always (pure Rust) |
//! | `BackendKind::Pjrt`        | `pjrt`        | `--features pjrt`   |
//!
//! The reference backend interprets the CNN forward pass directly from
//! the architecture registry ([`crate::nets::arch`]) with bit-exact
//! [`crate::quant::QFormat`] semantics — it is the semantic oracle. The
//! fast backend runs the same lowered plan ([`lowering`]) through
//! im2col + blocked GEMM ([`gemm`]) with multi-threaded batching
//! (`QBOUND_THREADS`), agreeing with the reference up to fp32
//! accumulation order. The PJRT backend executes the AOT-compiled HLO
//! through the `xla` crate. Selection is explicit (`--backend` on the
//! CLI) or via the `QBOUND_BACKEND` env var; the default is the
//! reference backend, which works on any machine.
//!
//! Both pure-Rust executors additionally honour an opt-in
//! **storage mode** ([`crate::memory::StorageMode`], `--storage packed`
//! / `QBOUND_STORAGE=packed`): between layers only packed
//! reduced-precision bitstreams persist, decoded in streaming windows
//! by the consuming ops, and the *weights* are resident only as
//! bitstreams at each group's weight width (panel strips decoded
//! inside the GEMM, biases into a scratch window, the interpreter's
//! tensors per layer), with numerically identical results (see
//! `tests/integration_storage.rs` for the parity contract and
//! `tests/integration_memory.rs` for the measured whole-model
//! residency bound).
//! The PJRT backend executes on-device and emits a one-time no-op
//! warning when a packed storage mode is requested.
//!
//! Executors are **not** `Send` (the PJRT client is `Rc`-based);
//! the coordinator gives each worker thread its own backend instance,
//! created from the `Send + Copy` [`BackendKind`].

pub mod fast;
pub mod gemm;
pub mod kernels;
pub mod lowering;
pub mod reference;

#[cfg(feature = "pjrt")]
pub mod pjrt;

use anyhow::{bail, Result};

use crate::nets::NetManifest;
use crate::quant::QFormat;

/// Which executable variant of a network to load.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// The standard per-layer-precision executable.
    Standard,
    /// The Fig-1 stage-granularity executable (extra `sq` input).
    Stages,
}

/// A network-execution technology: loads manifests into executors.
pub trait Backend {
    /// Human-readable backend name (for logs and reports).
    fn name(&self) -> &'static str;

    /// Load `manifest` (weights become resident) for `variant`.
    fn load(&self, manifest: &NetManifest, variant: Variant) -> Result<Box<dyn NetExecutor>>;
}

/// One loaded network: resident weights, runs quantized forward batches.
///
/// `wq`/`dq` are flattened `(L, 2)` wire configs — per layer `(I, F)` as
/// f32 with `I < 0` meaning the fp32 sentinel (see [`QFormat::wire`]);
/// `sq` is the per-stage config required by [`Variant::Stages`].
pub trait NetExecutor {
    /// The manifest this executor was loaded from.
    fn manifest(&self) -> &NetManifest;

    /// Which variant was loaded.
    fn variant(&self) -> Variant;

    /// Cumulative `infer` calls (utilization metrics).
    fn executions(&self) -> u64;

    /// Execute one batch. `images` is `(batch, H, W, C)` row-major; the
    /// batch is derived from `images.len()` and must not exceed
    /// [`NetExecutor::max_batch`] (compiled-batch backends additionally
    /// require it to equal [`NetExecutor::batch`]). Returns logits,
    /// row-major `(batch, num_classes)`.
    fn infer(&mut self, images: &[f32], wq: &[f32], dq: &[f32], sq: Option<&[f32]>)
        -> Result<Vec<f32>>;

    /// [`NetExecutor::infer`] with a stable identity for the image batch:
    /// callers that replay the same batches many times (the eval hot
    /// path) pass a dense `key` so backends with expensive host→device
    /// transfers can keep the batch resident. The default ignores the
    /// hint.
    fn infer_keyed(
        &mut self,
        key: usize,
        images: &[f32],
        wq: &[f32],
        dq: &[f32],
        sq: Option<&[f32]>,
    ) -> Result<Vec<f32>> {
        let _ = key;
        self.infer(images, wq, dq, sq)
    }

    /// Batch size the network was compiled/loaded for.
    fn batch(&self) -> usize {
        self.manifest().batch
    }

    /// Largest batch one `infer` call accepts. Compiled-batch backends
    /// (PJRT) are pinned to [`NetExecutor::batch`]; the interpreted and
    /// GEMM backends take any batch — the evaluator exploits this to
    /// hand a whole eval split to one call so image-level parallelism
    /// has work to spread.
    fn max_batch(&self) -> usize {
        self.batch()
    }

    fn num_classes(&self) -> usize {
        self.manifest().num_classes
    }
}

/// Which backend to instantiate — `Send + Copy`, so it can cross into
/// coordinator worker threads that then build their own (non-`Send`)
/// [`Backend`] instance.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Pure-Rust interpreted fixed-point forward pass (always available).
    #[default]
    Reference,
    /// Pure-Rust im2col + blocked-GEMM executor, multi-threaded
    /// (`QBOUND_THREADS`); always available.
    Fast,
    /// AOT-compiled HLO through PJRT (`--features pjrt`).
    #[cfg(feature = "pjrt")]
    Pjrt,
}

impl BackendKind {
    /// Parse a CLI/env spelling: `reference` (aliases `ref`, `interp`),
    /// `fast` (aliases `im2col`, `gemm`), or `pjrt` (alias `xla`).
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "reference" | "ref" | "interp" => Ok(BackendKind::Reference),
            "fast" | "im2col" | "gemm" => Ok(BackendKind::Fast),
            #[cfg(feature = "pjrt")]
            "pjrt" | "xla" => Ok(BackendKind::Pjrt),
            #[cfg(not(feature = "pjrt"))]
            "pjrt" | "xla" => {
                bail!("backend \"pjrt\" requires building with `--features pjrt`")
            }
            other => bail!("unknown backend {other:?} (expected: reference | fast | pjrt)"),
        }
    }

    /// Backend selected by `QBOUND_BACKEND`, defaulting to the reference
    /// backend. An invalid value is an error (not a silent fallback).
    pub fn from_env() -> Result<BackendKind> {
        match std::env::var("QBOUND_BACKEND") {
            Ok(s) if !s.is_empty() => BackendKind::parse(&s),
            _ => Ok(BackendKind::default()),
        }
    }

    /// CLI resolution: an explicit `--backend` value wins; empty falls
    /// back to [`BackendKind::from_env`].
    pub fn from_arg_or_env(arg: &str) -> Result<BackendKind> {
        if arg.trim().is_empty() {
            BackendKind::from_env()
        } else {
            BackendKind::parse(arg)
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            BackendKind::Reference => "reference",
            BackendKind::Fast => "fast",
            #[cfg(feature = "pjrt")]
            BackendKind::Pjrt => "pjrt",
        }
    }

    /// Instantiate the backend. The result is thread-local (not `Send`).
    pub fn create(self) -> Result<Box<dyn Backend>> {
        match self {
            BackendKind::Reference => Ok(Box::new(reference::ReferenceBackend::new()?)),
            BackendKind::Fast => Ok(Box::new(fast::FastBackend::new()?)),
            #[cfg(feature = "pjrt")]
            BackendKind::Pjrt => Ok(Box::new(pjrt::PjrtBackend::new()?)),
        }
    }
}

/// Shared request validation so every backend rejects malformed inputs
/// identically (the integration tests lock this behaviour). Returns the
/// batch size derived from `images.len()`; backends with a fixed
/// compiled batch must additionally check it against their own limit.
pub(crate) fn validate_request(
    m: &NetManifest,
    variant: Variant,
    n_stages: usize,
    images: &[f32],
    wq: &[f32],
    dq: &[f32],
    sq: Option<&[f32]>,
) -> Result<usize> {
    let nl = m.n_layers();
    if wq.len() != 2 * nl || dq.len() != 2 * nl {
        bail!("wq/dq must be 2*{nl} floats");
    }
    let img_elems: usize = m.input_shape.iter().product();
    if img_elems == 0 || images.is_empty() || images.len() % img_elems != 0 {
        bail!(
            "images len {} is not a positive multiple of image elems {img_elems}",
            images.len()
        );
    }
    match (variant, sq) {
        (Variant::Stages, Some(sq)) => {
            if sq.len() != 2 * n_stages {
                bail!("sq must be 2*{n_stages} floats");
            }
        }
        (Variant::Stages, None) => bail!("stage variant needs sq"),
        (Variant::Standard, Some(_)) => bail!("standard variant takes no sq"),
        (Variant::Standard, None) => {}
    }
    Ok(images.len() / img_elems)
}

/// Decode a flattened `(L, 2)` wire config into per-layer formats.
pub(crate) fn wire_to_formats(wire: &[f32]) -> Vec<QFormat> {
    wire.chunks_exact(2).map(|c| QFormat::from_wire(c[0], c[1])).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_reference_spellings() {
        for s in ["reference", "ref", "REF", "interp"] {
            assert_eq!(BackendKind::parse(s).unwrap(), BackendKind::Reference);
        }
        assert!(BackendKind::parse("tpu").is_err());
    }

    #[test]
    fn parse_fast_spellings() {
        for s in ["fast", "FAST", "im2col", "gemm"] {
            assert_eq!(BackendKind::parse(s).unwrap(), BackendKind::Fast);
        }
        assert_eq!(BackendKind::Fast.label(), "fast");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_requires_feature() {
        let err = BackendKind::parse("pjrt").unwrap_err().to_string();
        assert!(err.contains("--features pjrt"), "{err}");
    }

    #[test]
    fn default_is_reference() {
        assert_eq!(BackendKind::default(), BackendKind::Reference);
        assert_eq!(BackendKind::default().label(), "reference");
    }

    #[test]
    fn arg_overrides_env_fallback() {
        // explicit value parses; empty falls through to the env default
        assert_eq!(BackendKind::from_arg_or_env("reference").unwrap(), BackendKind::Reference);
        assert!(BackendKind::from_arg_or_env("bogus").is_err());
        if std::env::var_os("QBOUND_BACKEND").is_none() {
            assert_eq!(BackendKind::from_arg_or_env("").unwrap(), BackendKind::Reference);
            assert_eq!(BackendKind::from_arg_or_env("  ").unwrap(), BackendKind::Reference);
        }
    }

    #[test]
    fn wire_decoding() {
        let fmts = wire_to_formats(&[-1.0, 0.0, 3.0, 4.0]);
        assert!(fmts[0].is_fp32());
        assert_eq!(fmts[1], QFormat::new(3, 4));
    }
}
