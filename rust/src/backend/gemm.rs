//! Cache-blocked, register-tiled f32 GEMM with fused bias — the compute
//! core of the fast backend.
//!
//! `C[m][j] = bias[j] + Σ_k A[m][k] · B[k][j]` with row-major operands
//! and an independent row stride for `C` (so inception branches can
//! write straight into their concat columns).
//!
//! Two properties matter more than raw FLOPs here:
//!
//! * **Accumulation order.** Every output element accumulates its `k`
//!   terms in ascending order starting from the bias, exactly like the
//!   reference interpreter's inner loops: `C` is initialized from the
//!   bias, and each `k`-panel loads the current `C` tile into registers,
//!   adds its terms in ascending `k`, and stores back. f32 loads/stores
//!   are lossless, so the float addition sequence per element is
//!   *identical* to the naive loop — the cross-backend parity suite gets
//!   fp32-accumulation-order agreement essentially for free.
//! * **No `mul_add`.** Fusing would change results vs the reference.
//!
//! Register tiling is [`MR`]×[`NR`] (4×16 f32 = 8 YMM accumulators on
//! AVX2; the inner loop over `NR` is a clean auto-vectorization target),
//! cache blocking is `KC`×`MC`. Optional row-block threading splits `M`
//! across `std::thread::scope` workers — rows are independent, so
//! results are bit-identical for every thread count.

/// Register-tile rows (distinct A broadcasts per micro-kernel).
pub const MR: usize = 4;
/// Register-tile columns (contiguous B/C lanes per micro-kernel).
pub const NR: usize = 16;
/// k-panel depth: B panel (KC×NR f32) stays L1-resident.
const KC: usize = 256;
/// Row block per cache sweep.
const MC: usize = 128;

/// `C = bias + A·B`, threaded over row blocks.
///
/// * `a`: `m`×`kd`, row stride `lda` (≥ `kd`), len ≥ `(m-1)*lda + kd`
/// * `b`: `kd`×`n`, row-major contiguous (stride `n`)
/// * `bias`: len `n`
/// * `c`: row stride `ldc` (≥ `n`), len ≥ `(m-1)*ldc + n`; fully
///   overwritten on the `n` columns, untouched between them
/// * `threads`: ≤ 1 runs inline; otherwise `M` row blocks are spread
///   over scoped threads (bit-identical results either way)
pub fn gemm_bias(
    m: usize,
    n: usize,
    kd: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    bias: &[f32],
    c: &mut [f32],
    ldc: usize,
    threads: usize,
) {
    if m == 0 || n == 0 {
        return;
    }
    debug_assert!(lda >= kd && ldc >= n);
    debug_assert!(a.len() >= (m - 1) * lda + kd);
    debug_assert!(b.len() >= kd * n);
    debug_assert!(bias.len() >= n);
    debug_assert!(c.len() >= (m - 1) * ldc + n);

    // Each worker needs a few row tiles to be worth a spawn.
    let t = threads.min(m / (2 * MR)).max(1);
    if t <= 1 {
        gemm_block(m, n, kd, a, lda, b, bias, c, ldc);
        return;
    }
    let rows_per = (m + t - 1) / t;
    std::thread::scope(|s| {
        let mut c_rest: &mut [f32] = c;
        let mut row0 = 0usize;
        while row0 < m {
            let rows = rows_per.min(m - row0);
            let last = row0 + rows == m;
            let take = if last { (rows - 1) * ldc + n } else { rows * ldc };
            let (chunk, rest) = std::mem::take(&mut c_rest).split_at_mut(take);
            c_rest = rest;
            let a_rows = &a[row0 * lda..];
            s.spawn(move || gemm_block(rows, n, kd, a_rows, lda, b, bias, chunk, ldc));
            row0 += rows;
        }
    });
}

/// Single-threaded blocked kernel over one row range.
fn gemm_block(
    m: usize,
    n: usize,
    kd: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    bias: &[f32],
    c: &mut [f32],
    ldc: usize,
) {
    for r in 0..m {
        c[r * ldc..r * ldc + n].copy_from_slice(&bias[..n]);
    }
    // k panels outermost: every output element sees panels in ascending
    // k, and the micro-kernel round-trips C through registers per panel.
    let mut kp = 0usize;
    while kp < kd {
        let ke = (kp + KC).min(kd);
        let mut mb = 0usize;
        while mb < m {
            let me = (mb + MC).min(m);
            let mut r = mb;
            while r < me {
                let mr = MR.min(me - r);
                let mut nb = 0usize;
                while nb < n {
                    let nr = NR.min(n - nb);
                    if mr == MR && nr == NR {
                        micro_full(r, nb, kp, ke, kd, a, lda, b, n, c, ldc);
                    } else {
                        micro_edge(r, mr, nb, nr, kp, ke, a, lda, b, n, c, ldc);
                    }
                    nb += nr;
                }
                r += mr;
            }
            mb = me;
        }
        kp = ke;
    }
}

/// Full MR×NR register tile: C tile in registers, ascending-k updates.
#[inline]
fn micro_full(
    r0: usize,
    n0: usize,
    kp: usize,
    ke: usize,
    kd: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
) {
    let arows: [&[f32]; MR] = std::array::from_fn(|i| &a[(r0 + i) * lda..][..kd]);
    let mut acc = [[0f32; NR]; MR];
    for (i, accr) in acc.iter_mut().enumerate() {
        accr.copy_from_slice(&c[(r0 + i) * ldc + n0..][..NR]);
    }
    for kk in kp..ke {
        let brow = &b[kk * ldb + n0..][..NR];
        for (accr, arow) in acc.iter_mut().zip(&arows) {
            let av = arow[kk];
            for (x, &bv) in accr.iter_mut().zip(brow) {
                *x += av * bv;
            }
        }
    }
    for (i, accr) in acc.iter().enumerate() {
        c[(r0 + i) * ldc + n0..][..NR].copy_from_slice(accr);
    }
}

/// Edge tile with runtime mr×nr ≤ MR×NR.
#[inline]
fn micro_edge(
    r0: usize,
    mr: usize,
    n0: usize,
    nr: usize,
    kp: usize,
    ke: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
) {
    let mut acc = [[0f32; NR]; MR];
    for i in 0..mr {
        acc[i][..nr].copy_from_slice(&c[(r0 + i) * ldc + n0..][..nr]);
    }
    for kk in kp..ke {
        let brow = &b[kk * ldb + n0..][..nr];
        for i in 0..mr {
            let av = a[(r0 + i) * lda + kk];
            for (x, &bv) in acc[i][..nr].iter_mut().zip(brow) {
                *x += av * bv;
            }
        }
    }
    for i in 0..mr {
        c[(r0 + i) * ldc + n0..][..nr].copy_from_slice(&acc[i][..nr]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Naive triple loop in the reference interpreter's order.
    fn naive(m: usize, n: usize, kd: usize, a: &[f32], b: &[f32], bias: &[f32]) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for r in 0..m {
            let row = &mut c[r * n..(r + 1) * n];
            row.copy_from_slice(bias);
            for k in 0..kd {
                let av = a[r * kd + k];
                for (x, &bv) in row.iter_mut().zip(&b[k * n..(k + 1) * n]) {
                    *x += av * bv;
                }
            }
        }
        c
    }

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::prng::Xoshiro256pp::new(seed);
        (0..n).map(|_| rng.uniform_f32(-2.0, 2.0)).collect()
    }

    #[test]
    fn matches_naive_bit_for_bit_across_shapes() {
        // Shapes straddle every tile edge: m % MR, n % NR, kd % KC.
        for &(m, n, kd) in &[
            (1usize, 1usize, 1usize),
            (1, 10, 256),
            (3, 5, 7),
            (4, 16, 9),
            (5, 17, 300),
            (64, 24, 75),
            (130, 33, 513),
        ] {
            let a = rand_vec(m * kd, 1 + m as u64);
            let b = rand_vec(kd * n, 2 + n as u64);
            let bias = rand_vec(n, 3 + kd as u64);
            let want = naive(m, n, kd, &a, &b, &bias);
            let mut c = vec![f32::NAN; m * n];
            gemm_bias(m, n, kd, &a, kd, &b, &bias, &mut c, n, 1);
            for (i, (x, y)) in c.iter().zip(&want).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "({m},{n},{kd}) elem {i}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn threaded_matches_single_thread_bit_for_bit() {
        let (m, n, kd) = (97, 19, 111);
        let a = rand_vec(m * kd, 7);
        let b = rand_vec(kd * n, 8);
        let bias = rand_vec(n, 9);
        let mut c1 = vec![0f32; m * n];
        gemm_bias(m, n, kd, &a, kd, &b, &bias, &mut c1, n, 1);
        for threads in [2, 3, 8, 64] {
            let mut ct = vec![0f32; m * n];
            gemm_bias(m, n, kd, &a, kd, &b, &bias, &mut ct, n, threads);
            assert!(
                c1.iter().zip(&ct).all(|(x, y)| x.to_bits() == y.to_bits()),
                "threads={threads} diverged"
            );
        }
    }

    #[test]
    fn strided_c_leaves_gap_columns_untouched() {
        // Write a 4x3 product into a C with ldc 8 at column offset 0;
        // columns 3..8 must keep their sentinel.
        let (m, n, kd) = (4usize, 3usize, 5usize);
        let a = rand_vec(m * kd, 11);
        let b = rand_vec(kd * n, 12);
        let bias = vec![0.5; n];
        let ldc = 8;
        let mut c = vec![-7.0f32; (m - 1) * ldc + n + 5];
        gemm_bias(m, n, kd, &a, kd, &b, &bias, &mut c, ldc, 1);
        let want = naive(m, n, kd, &a, &b, &bias);
        for r in 0..m {
            for j in 0..n {
                assert_eq!(c[r * ldc + j], want[r * n + j]);
            }
            if r + 1 < m {
                assert!(c[r * ldc + n..r * ldc + ldc].iter().all(|&v| v == -7.0));
            }
        }
    }

    #[test]
    fn zero_k_is_pure_bias() {
        let bias = vec![1.0, 2.0];
        let mut c = vec![0f32; 6];
        gemm_bias(3, 2, 0, &[], 0, &[], &bias, &mut c, 2, 4);
        assert_eq!(c, vec![1.0, 2.0, 1.0, 2.0, 1.0, 2.0]);
    }
}
