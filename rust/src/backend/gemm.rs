//! Cache-blocked, register-tiled f32 GEMM with fused bias — the compute
//! core of the fast backend.
//!
//! `C[m][j] = bias[j] + Σ_k A[m][k] · B[k][j]` with row-major operands
//! and an independent row stride for `C` (so inception branches can
//! write straight into their concat columns).
//!
//! Two properties matter more than raw FLOPs here:
//!
//! * **Accumulation order.** Every output element accumulates its `k`
//!   terms in ascending order starting from the bias, exactly like the
//!   reference interpreter's inner loops: `C` is initialized from the
//!   bias, and each `k`-panel loads the current `C` tile into registers,
//!   adds its terms in ascending `k`, and stores back. f32 loads/stores
//!   are lossless, so the float addition sequence per element is
//!   *identical* to the naive loop — the cross-backend parity suite gets
//!   fp32-accumulation-order agreement essentially for free.
//! * **No `mul_add`.** Fusing would change results vs the reference.
//!
//! Register tiling is [`MR`]×[`NR`] (4×16 f32 = 8 YMM accumulators on
//! AVX2), cache blocking is `KC`×`MC`. The full-tile micro-kernel is
//! dispatched through [`super::kernels`]: explicit AVX2/NEON variants
//! when the host supports them, the portable scalar tile otherwise —
//! all bound by the same bit-exactness contract, so dispatch never
//! changes results. Edge tiles (runtime `mr`×`nr`) stay scalar on
//! every variant. Optional row-block threading splits `M` across
//! `std::thread::scope` workers — rows are independent, so results are
//! bit-identical for every thread count.
//!
//! The `B` operand comes in three forms ([`GemmB`]): row-major, f32
//! NR-lane panels ([`pack_b_panels`]), or a **packed weight bitstream**
//! ([`PackedPanels`]) — the fused packed executor's form, where each
//! `KC`-row strip of a panel is decoded into a small per-thread f32
//! scratch tile immediately before the multiply, so no f32 copy of the
//! weights exists beyond one tile per thread. All three run the same
//! micro-kernels in the same ascending-`k` order; decoding is a pure
//! prefetch step, so the bitstream form is bit-identical to the f32
//! panels holding the same (quantized) values.

use super::kernels;
use crate::memory::PackedPanels;

/// Register-tile rows (distinct A broadcasts per micro-kernel).
pub const MR: usize = 4;
/// Register-tile columns (contiguous B/C lanes per micro-kernel).
pub const NR: usize = 16;
/// k-panel depth: B panel (KC×NR f32) stays L1-resident.
const KC: usize = 256;
/// Row block per cache sweep.
const MC: usize = 128;

/// `C = bias + A·B`, threaded over row blocks.
///
/// * `a`: `m`×`kd`, row stride `lda` (≥ `kd`), len ≥ `(m-1)*lda + kd`
/// * `b`: `kd`×`n`, row-major contiguous (stride `n`)
/// * `bias`: len `n`
/// * `c`: row stride `ldc` (≥ `n`), len ≥ `(m-1)*ldc + n`; fully
///   overwritten on the `n` columns, untouched between them
/// * `threads`: ≤ 1 runs inline; otherwise `M` row blocks are spread
///   over scoped threads (bit-identical results either way)
pub fn gemm_bias(
    m: usize,
    n: usize,
    kd: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    bias: &[f32],
    c: &mut [f32],
    ldc: usize,
    threads: usize,
) {
    debug_assert!(b.len() >= kd * n);
    gemm_bias_b(m, n, kd, a, lda, GemmB::Flat(b), bias, c, ldc, threads)
}

/// `C = bias + A·B` with `B` pre-packed into NR-column panels by
/// [`pack_b_panels`] — the per-weight-config memoized form the fast
/// backend uses, so the panel layout is built once per config instead
/// of the micro-kernel re-striding `B` on every `infer`.
///
/// Numerically identical to [`gemm_bias`] (same micro-kernels, same
/// ascending-k accumulation; only the `B` memory layout differs), which
/// the tests pin bit-for-bit.
pub fn gemm_bias_packed(
    m: usize,
    n: usize,
    kd: usize,
    a: &[f32],
    lda: usize,
    bp: &[f32],
    bias: &[f32],
    c: &mut [f32],
    ldc: usize,
    threads: usize,
) {
    debug_assert!(bp.len() >= ((n + NR - 1) / NR) * kd * NR);
    gemm_bias_b(m, n, kd, a, lda, GemmB::Panels(bp), bias, c, ldc, threads)
}

/// `C = bias + A·B` with `B` a [`PackedPanels`] weight bitstream — the
/// packed-B microkernel path. Each `KC`-row strip of a panel is decoded
/// (at the bitstream's own pack-time format) into a per-thread f32
/// scratch tile right before the multiply; the decode precedes the
/// unchanged ascending-`k` accumulation, so results are bit-identical
/// to [`gemm_bias_packed`] over the decoded panel values (the property
/// suite pins this for every weight width).
pub fn gemm_bias_bits(
    m: usize,
    n: usize,
    kd: usize,
    a: &[f32],
    lda: usize,
    bp: &PackedPanels,
    bias: &[f32],
    c: &mut [f32],
    ldc: usize,
    threads: usize,
) {
    gemm_bias_b(m, n, kd, a, lda, GemmB::Bits(bp), bias, c, ldc, threads)
}

/// [`gemm_bias_bits`] with an optional decoded-strip cache. When the
/// row range is small enough that the driver would run single-threaded
/// anyway, strips decode through `cache` (keyed by the bitstream's
/// identity — repeated calls against the same weights skip the decode
/// entirely); a multi-threaded split falls back to the per-thread
/// stack-tile path, where the shared cache cannot be handed out.
/// Bit-identical to [`gemm_bias_bits`] either way: a cached strip holds
/// exactly the f32 values `read_strip` would decode.
pub fn gemm_bias_bits_cached(
    m: usize,
    n: usize,
    kd: usize,
    a: &[f32],
    lda: usize,
    bp: &PackedPanels,
    bias: &[f32],
    c: &mut [f32],
    ldc: usize,
    threads: usize,
    cache: Option<&mut StripCache>,
) {
    if m == 0 || n == 0 {
        return;
    }
    let t = threads.min(m / (2 * MR)).max(1);
    if t <= 1 {
        debug_assert!(lda >= kd && ldc >= n);
        debug_assert!(a.len() >= (m - 1) * lda + kd);
        debug_assert!(bias.len() >= n);
        debug_assert!(c.len() >= (m - 1) * ldc + n);
        let _sp = crate::obs::span!("gemm", "m={m} n={n} k={kd} b=bits-cached");
        gemm_block_bits(m, n, kd, a, lda, bp, bias, c, ldc, cache);
        return;
    }
    gemm_bias_b(m, n, kd, a, lda, GemmB::Bits(bp), bias, c, ldc, threads)
}

/// The general thread-splitting driver behind every entry point.
pub fn gemm_bias_b(
    m: usize,
    n: usize,
    kd: usize,
    a: &[f32],
    lda: usize,
    b: GemmB,
    bias: &[f32],
    c: &mut [f32],
    ldc: usize,
    threads: usize,
) {
    if m == 0 || n == 0 {
        return;
    }
    debug_assert!(lda >= kd && ldc >= n);
    debug_assert!(a.len() >= (m - 1) * lda + kd);
    debug_assert!(bias.len() >= n);
    debug_assert!(c.len() >= (m - 1) * ldc + n);
    let _sp = crate::obs::span!("gemm", "m={m} n={n} k={kd} b={}", b.label());

    // Each worker needs a few row tiles to be worth a spawn.
    let t = threads.min(m / (2 * MR)).max(1);
    if t <= 1 {
        gemm_block(m, n, kd, a, lda, b, bias, c, ldc);
        return;
    }
    let rows_per = (m + t - 1) / t;
    std::thread::scope(|s| {
        let mut c_rest: &mut [f32] = c;
        let mut row0 = 0usize;
        while row0 < m {
            let rows = rows_per.min(m - row0);
            let last = row0 + rows == m;
            let take = if last { (rows - 1) * ldc + n } else { rows * ldc };
            let (chunk, rest) = std::mem::take(&mut c_rest).split_at_mut(take);
            c_rest = rest;
            let a_rows = &a[row0 * lda..];
            s.spawn(move || gemm_block(rows, n, kd, a_rows, lda, b, bias, chunk, ldc));
            row0 += rows;
        }
    });
}

/// Repack a row-major `kd`×`n` B into NR-wide column panels: panel `p`
/// holds columns `[p·NR, (p+1)·NR)` as `kd` contiguous NR-float rows
/// (the ragged last panel is zero-padded). The micro-kernel then reads
/// one contiguous NR-lane row per k step instead of striding across the
/// full matrix width.
pub fn pack_b_panels(b: &[f32], kd: usize, n: usize) -> Vec<f32> {
    debug_assert!(b.len() >= kd * n);
    let n_panels = (n + NR - 1) / NR;
    let mut out = vec![0f32; n_panels * kd * NR];
    for p in 0..n_panels {
        let j0 = p * NR;
        let w = NR.min(n - j0);
        for k in 0..kd {
            out[(p * kd + k) * NR..][..w].copy_from_slice(&b[k * n + j0..][..w]);
        }
    }
    out
}

/// B operand of one blocked GEMM: row-major, f32 panels, or a packed
/// weight bitstream.
#[derive(Clone, Copy)]
pub enum GemmB<'a> {
    /// Row-major `kd`×`n`, stride `n`.
    Flat(&'a [f32]),
    /// [`pack_b_panels`] f32 layout.
    Panels(&'a [f32]),
    /// [`PackedPanels`] bitstream (which carries its pack-time weight
    /// format); strips are decoded into a per-thread f32 tile ahead of
    /// the multiply.
    Bits(&'a PackedPanels),
}

impl<'a> GemmB<'a> {
    /// Operand-flavor tag for the `gemm` span's `b=` field.
    fn label(self) -> &'static str {
        match self {
            GemmB::Flat(_) => "flat",
            GemmB::Panels(_) => "panels",
            GemmB::Bits(_) => "bits",
        }
    }

    /// The slice + row stride + column offset addressing panel columns
    /// `[nb, nb+NR)` as `slice[kk * stride + off ..]`.
    #[inline]
    fn panel(self, nb: usize, n: usize, kd: usize) -> (&'a [f32], usize, usize) {
        match self {
            GemmB::Flat(b) => (b, n, nb),
            GemmB::Panels(bp) => (&bp[(nb / NR) * kd * NR..], NR, 0),
            GemmB::Bits(..) => unreachable!("bitstream operand takes the tile path"),
        }
    }
}

/// Single-threaded blocked kernel over one row range.
fn gemm_block(
    m: usize,
    n: usize,
    kd: usize,
    a: &[f32],
    lda: usize,
    b: GemmB,
    bias: &[f32],
    c: &mut [f32],
    ldc: usize,
) {
    if let GemmB::Bits(bp) = b {
        return gemm_block_bits(m, n, kd, a, lda, bp, bias, c, ldc, None);
    }
    let micro = kernels::active().micro_full;
    for r in 0..m {
        c[r * ldc..r * ldc + n].copy_from_slice(&bias[..n]);
    }
    // k panels outermost: every output element sees panels in ascending
    // k, and the micro-kernel round-trips C through registers per panel.
    let mut kp = 0usize;
    while kp < kd {
        let ke = (kp + KC).min(kd);
        let mut mb = 0usize;
        while mb < m {
            let me = (mb + MC).min(m);
            let mut r = mb;
            while r < me {
                let mr = MR.min(me - r);
                let mut nb = 0usize;
                while nb < n {
                    let nr = NR.min(n - nb);
                    let (bs, ldb, bn0) = b.panel(nb, n, kd);
                    if mr == MR && nr == NR {
                        micro(r, nb, kp, ke, kd, a, lda, bs, ldb, bn0, 0, c, ldc);
                    } else {
                        micro_edge(r, mr, nb, nr, kp, ke, a, lda, bs, ldb, bn0, 0, c, ldc);
                    }
                    nb += nr;
                }
                r += mr;
            }
            mb = me;
        }
        kp = ke;
    }
}

/// The packed-B tile kernel over one row range: decode one `KC`-deep
/// strip of one NR-lane panel at a time into a stack f32 tile (~16 KiB,
/// one per thread), then run the same micro-kernels over it. The `nb`
/// loop moves outside the row loops so each strip is decoded exactly
/// once per row range — per output element the accumulation is still
/// one visit per `kp` panel in ascending order with ascending `kk`
/// inside, i.e. the exact float-add sequence of the f32-panel path.
fn gemm_block_bits(
    m: usize,
    n: usize,
    kd: usize,
    a: &[f32],
    lda: usize,
    bp: &PackedPanels,
    bias: &[f32],
    c: &mut [f32],
    ldc: usize,
    mut cache: Option<&mut StripCache>,
) {
    debug_assert_eq!(bp.nr(), NR);
    debug_assert_eq!(bp.kd(), kd);
    let micro = kernels::active().micro_full;
    for r in 0..m {
        c[r * ldc..r * ldc + n].copy_from_slice(&bias[..n]);
    }
    let mut tile = [0f32; KC * NR];
    let mut kp = 0usize;
    while kp < kd {
        let ke = (kp + KC).min(kd);
        let mut nb = 0usize;
        while nb < n {
            let nr = NR.min(n - nb);
            let cached = cache.as_deref_mut().and_then(|sc| sc.strip(bp, nb / NR, kp, ke));
            let strip: &[f32] = match cached {
                Some(s) => s,
                None => {
                    bp.read_strip(nb / NR, kp, ke, &mut tile[..(ke - kp) * NR]);
                    &tile[..(ke - kp) * NR]
                }
            };
            let mut mb = 0usize;
            while mb < m {
                let me = (mb + MC).min(m);
                let mut r = mb;
                while r < me {
                    let mr = MR.min(me - r);
                    if mr == MR && nr == NR {
                        micro(r, nb, kp, ke, kd, a, lda, strip, NR, 0, kp, c, ldc);
                    } else {
                        micro_edge(r, mr, nb, nr, kp, ke, a, lda, strip, NR, 0, kp, c, ldc);
                    }
                    r += mr;
                }
                mb = me;
            }
            nb += nr;
        }
        kp = ke;
    }
}

/// LRU cache of decoded `(bitstream, k-panel, NR-panel)` strips for
/// packed-B GEMMs. The streamed 1×1-conv path calls the GEMM once per
/// `A`-row block against the *same* weight bitstream, so without a
/// cache every row block re-decodes every strip; with one, each strip
/// decodes once per `infer` and later blocks reuse the f32 copy
/// (bit-identical by construction — the cache stores exactly what
/// [`PackedPanels::read_strip`] produces).
///
/// Capacity is in f32 elements and is part of the lowering plan's
/// priced scratch (`LoweredPlan::strip_cache_elems`), so the measured
/// memory envelope accounts for it. A capacity of 0 disables caching
/// (every lookup misses without storing).
pub struct StripCache {
    cap: usize,
    used: usize,
    tick: u64,
    entries: Vec<StripEntry>,
    hits: u64,
    misses: u64,
}

struct StripEntry {
    /// (bitstream identity, k-panel start, NR-panel index)
    key: (u64, usize, usize),
    tick: u64,
    data: Vec<f32>,
}

impl StripCache {
    /// Cache bounded at `cap_elems` decoded f32 values.
    pub fn new(cap_elems: usize) -> StripCache {
        StripCache { cap: cap_elems, used: 0, tick: 0, entries: Vec::new(), hits: 0, misses: 0 }
    }

    pub fn cap_elems(&self) -> usize {
        self.cap
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// The decoded strip for panel columns `[panel*NR, (panel+1)*NR)`
    /// rows `[k0, k1)`, decoding on miss and evicting least-recently
    /// used strips to stay within capacity. `None` when the strip
    /// cannot fit at all — the caller then streams through its stack
    /// tile as if no cache existed.
    fn strip(&mut self, bp: &PackedPanels, panel: usize, k0: usize, k1: usize) -> Option<&[f32]> {
        let elems = (k1 - k0) * bp.nr();
        if elems > self.cap {
            return None;
        }
        self.tick += 1;
        let key = (bp.id(), k0, panel);
        if let Some(i) = self.entries.iter().position(|e| e.key == key) {
            // Same bitstream + same k0 implies the same k1 (strips are
            // KC-quantized over a fixed kd), so the entry is the whole
            // requested strip.
            debug_assert_eq!(self.entries[i].data.len(), elems);
            self.entries[i].tick = self.tick;
            self.hits += 1;
            return Some(&self.entries[i].data);
        }
        self.misses += 1;
        while self.used + elems > self.cap {
            let lru = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.tick)
                .map(|(i, _)| i)?;
            self.used -= self.entries[lru].data.len();
            self.entries.swap_remove(lru);
        }
        let mut data = vec![0f32; elems];
        bp.read_strip(panel, k0, k1, &mut data);
        self.used += elems;
        self.entries.push(StripEntry { key, tick: self.tick, data });
        self.entries.last().map(|e| e.data.as_slice())
    }
}

/// Edge tile with runtime mr×nr ≤ MR×NR.
#[inline]
fn micro_edge(
    r0: usize,
    mr: usize,
    n0: usize,
    nr: usize,
    kp: usize,
    ke: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    bn0: usize,
    bk0: usize,
    c: &mut [f32],
    ldc: usize,
) {
    let mut acc = [[0f32; NR]; MR];
    for i in 0..mr {
        acc[i][..nr].copy_from_slice(&c[(r0 + i) * ldc + n0..][..nr]);
    }
    for kk in kp..ke {
        let brow = &b[(kk - bk0) * ldb + bn0..][..nr];
        for i in 0..mr {
            let av = a[(r0 + i) * lda + kk];
            for (x, &bv) in acc[i][..nr].iter_mut().zip(brow) {
                *x += av * bv;
            }
        }
    }
    for i in 0..mr {
        c[(r0 + i) * ldc + n0..][..nr].copy_from_slice(&acc[i][..nr]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Naive triple loop in the reference interpreter's order.
    fn naive(m: usize, n: usize, kd: usize, a: &[f32], b: &[f32], bias: &[f32]) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for r in 0..m {
            let row = &mut c[r * n..(r + 1) * n];
            row.copy_from_slice(bias);
            for k in 0..kd {
                let av = a[r * kd + k];
                for (x, &bv) in row.iter_mut().zip(&b[k * n..(k + 1) * n]) {
                    *x += av * bv;
                }
            }
        }
        c
    }

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::prng::Xoshiro256pp::new(seed);
        (0..n).map(|_| rng.uniform_f32(-2.0, 2.0)).collect()
    }

    #[test]
    fn matches_naive_bit_for_bit_across_shapes() {
        // Shapes straddle every tile edge: m % MR, n % NR, kd % KC.
        for &(m, n, kd) in &[
            (1usize, 1usize, 1usize),
            (1, 10, 256),
            (3, 5, 7),
            (4, 16, 9),
            (5, 17, 300),
            (64, 24, 75),
            (130, 33, 513),
        ] {
            let a = rand_vec(m * kd, 1 + m as u64);
            let b = rand_vec(kd * n, 2 + n as u64);
            let bias = rand_vec(n, 3 + kd as u64);
            let want = naive(m, n, kd, &a, &b, &bias);
            let mut c = vec![f32::NAN; m * n];
            gemm_bias(m, n, kd, &a, kd, &b, &bias, &mut c, n, 1);
            for (i, (x, y)) in c.iter().zip(&want).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "({m},{n},{kd}) elem {i}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn threaded_matches_single_thread_bit_for_bit() {
        let (m, n, kd) = (97, 19, 111);
        let a = rand_vec(m * kd, 7);
        let b = rand_vec(kd * n, 8);
        let bias = rand_vec(n, 9);
        let mut c1 = vec![0f32; m * n];
        gemm_bias(m, n, kd, &a, kd, &b, &bias, &mut c1, n, 1);
        for threads in [2, 3, 8, 64] {
            let mut ct = vec![0f32; m * n];
            gemm_bias(m, n, kd, &a, kd, &b, &bias, &mut ct, n, threads);
            assert!(
                c1.iter().zip(&ct).all(|(x, y)| x.to_bits() == y.to_bits()),
                "threads={threads} diverged"
            );
        }
    }

    #[test]
    fn strided_c_leaves_gap_columns_untouched() {
        // Write a 4x3 product into a C with ldc 8 at column offset 0;
        // columns 3..8 must keep their sentinel.
        let (m, n, kd) = (4usize, 3usize, 5usize);
        let a = rand_vec(m * kd, 11);
        let b = rand_vec(kd * n, 12);
        let bias = vec![0.5; n];
        let ldc = 8;
        let mut c = vec![-7.0f32; (m - 1) * ldc + n + 5];
        gemm_bias(m, n, kd, &a, kd, &b, &bias, &mut c, ldc, 1);
        let want = naive(m, n, kd, &a, &b, &bias);
        for r in 0..m {
            for j in 0..n {
                assert_eq!(c[r * ldc + j], want[r * n + j]);
            }
            if r + 1 < m {
                assert!(c[r * ldc + n..r * ldc + ldc].iter().all(|&v| v == -7.0));
            }
        }
    }

    #[test]
    fn zero_k_is_pure_bias() {
        let bias = vec![1.0, 2.0];
        let mut c = vec![0f32; 6];
        gemm_bias(3, 2, 0, &[], 0, &[], &bias, &mut c, 2, 4);
        assert_eq!(c, vec![1.0, 2.0, 1.0, 2.0, 1.0, 2.0]);
    }

    #[test]
    fn packed_b_layout_by_hand() {
        // kd=2, n=3 (one ragged panel): rows [1,2,3], [4,5,6]
        let b = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let bp = pack_b_panels(&b, 2, 3);
        assert_eq!(bp.len(), 2 * NR);
        assert_eq!(&bp[..3], &[1.0, 2.0, 3.0]);
        assert!(bp[3..NR].iter().all(|&v| v == 0.0)); // panel padding
        assert_eq!(&bp[NR..NR + 3], &[4.0, 5.0, 6.0]);
        // n spanning two panels: column NR lands at the second panel's row 0
        let n = NR + 2;
        let wide: Vec<f32> = (0..2 * n).map(|v| v as f32).collect();
        let wp = pack_b_panels(&wide, 2, n);
        assert_eq!(wp.len(), 2 * 2 * NR);
        assert_eq!(wp[2 * NR], wide[NR]); // panel 1, k=0, lane 0
        assert_eq!(wp[3 * NR], wide[n + NR]); // panel 1, k=1, lane 0
    }

    #[test]
    fn packed_matches_flat_bit_for_bit_across_shapes() {
        for &(m, n, kd) in &[
            (1usize, 1usize, 1usize),
            (1, 10, 256),
            (3, 5, 7),
            (4, 16, 9),
            (5, 17, 300),
            (64, 24, 75),
            (130, 33, 513),
        ] {
            let a = rand_vec(m * kd, 21 + m as u64);
            let b = rand_vec(kd * n, 22 + n as u64);
            let bias = rand_vec(n, 23 + kd as u64);
            let bp = pack_b_panels(&b, kd, n);
            let mut want = vec![0f32; m * n];
            gemm_bias(m, n, kd, &a, kd, &b, &bias, &mut want, n, 1);
            for threads in [1usize, 3] {
                let mut c = vec![f32::NAN; m * n];
                gemm_bias_packed(m, n, kd, &a, kd, &bp, &bias, &mut c, n, threads);
                for (i, (x, y)) in c.iter().zip(&want).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "({m},{n},{kd}) t={threads} elem {i}: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn bits_matches_f32_panels_bit_for_bit_across_shapes() {
        // Weight values on the quantizer grid (what a real packed-weight
        // GEMM multiplies): the bitstream path must reproduce the f32
        // panel path exactly, tile edges and KC strips included.
        let fmt = crate::quant::QFormat::new(2, 6);
        for &(m, n, kd) in &[
            (1usize, 1usize, 1usize),
            (1, 10, 256),
            (3, 5, 7),
            (4, 16, 9),
            (5, 17, 300),
            (64, 24, 75),
            (130, 33, 513),
        ] {
            let a = rand_vec(m * kd, 41 + m as u64);
            let b = crate::testkit::quantized_canonical(fmt, &rand_vec(kd * n, 42 + n as u64));
            let bias = rand_vec(n, 43 + kd as u64);
            let bp = pack_b_panels(&b, kd, n);
            let bits = PackedPanels::pack(fmt, &bp, kd, NR);
            let mut want = vec![0f32; m * n];
            gemm_bias_packed(m, n, kd, &a, kd, &bp, &bias, &mut want, n, 1);
            for threads in [1usize, 3] {
                let mut c = vec![f32::NAN; m * n];
                gemm_bias_bits(m, n, kd, &a, kd, &bits, &bias, &mut c, n, threads);
                for (i, (x, y)) in c.iter().zip(&want).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "({m},{n},{kd}) t={threads} elem {i}: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn bits_strided_c_leaves_gap_columns_untouched() {
        let fmt = crate::quant::QFormat::new(3, 5);
        let (m, n, kd) = (4usize, 3usize, 5usize);
        let a = rand_vec(m * kd, 51);
        let b = crate::testkit::quantized_canonical(fmt, &rand_vec(kd * n, 52));
        let bias = vec![0.5; n];
        let bp = pack_b_panels(&b, kd, n);
        let bits = PackedPanels::pack(fmt, &bp, kd, NR);
        let ldc = 8;
        let mut c = vec![-7.0f32; (m - 1) * ldc + n + 5];
        gemm_bias_bits(m, n, kd, &a, kd, &bits, &bias, &mut c, ldc, 1);
        let want = naive(m, n, kd, &a, &b, &bias);
        for r in 0..m {
            for j in 0..n {
                assert_eq!(c[r * ldc + j], want[r * n + j]);
            }
            if r + 1 < m {
                assert!(c[r * ldc + n..r * ldc + ldc].iter().all(|&v| v == -7.0));
            }
        }
    }

    #[test]
    fn cached_bits_matches_uncached_bit_for_bit() {
        let fmt = crate::quant::QFormat::new(2, 6);
        let (m, n, kd) = (64usize, 33usize, 300usize);
        let a = rand_vec(m * kd, 61);
        let b = crate::testkit::quantized_canonical(fmt, &rand_vec(kd * n, 62));
        let bias = rand_vec(n, 63);
        let bpn = pack_b_panels(&b, kd, n);
        let bits = PackedPanels::pack(fmt, &bpn, kd, NR);
        let mut want = vec![f32::NAN; m * n];
        gemm_bias_bits(m, n, kd, &a, kd, &bits, &bias, &mut want, n, 1);
        // Generous capacity: the second pass reuses every strip.
        let mut cache = StripCache::new(1 << 20);
        for pass in 0..2 {
            let mut c = vec![f32::NAN; m * n];
            gemm_bias_bits_cached(m, n, kd, &a, kd, &bits, &bias, &mut c, n, 1, Some(&mut cache));
            assert!(
                c.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits()),
                "pass {pass} diverged from the uncached path"
            );
        }
        // 3 NR-panels × 2 k-strips, decoded once each on pass 0, all
        // hits on pass 1.
        assert_eq!((cache.misses(), cache.hits()), (6, 6));

        // Zero capacity: every strip streams through the stack tile.
        let mut none = StripCache::new(0);
        let mut c = vec![f32::NAN; m * n];
        gemm_bias_bits_cached(m, n, kd, &a, kd, &bits, &bias, &mut c, n, 1, Some(&mut none));
        assert!(c.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert_eq!((none.hits(), none.misses()), (0, 0));
    }

    #[test]
    fn tiny_strip_cache_evicts_and_stays_exact() {
        let fmt = crate::quant::QFormat::new(1, 7);
        let (m, n, kd) = (16usize, 40usize, 70usize);
        let a = rand_vec(m * kd, 71);
        let b = crate::testkit::quantized_canonical(fmt, &rand_vec(kd * n, 72));
        let bias = rand_vec(n, 73);
        let bpn = pack_b_panels(&b, kd, n);
        let bits = PackedPanels::pack(fmt, &bpn, kd, NR);
        let mut want = vec![f32::NAN; m * n];
        gemm_bias_bits(m, n, kd, &a, kd, &bits, &bias, &mut want, n, 1);
        // Room for a single 70×16 strip: panels evict each other on
        // every access, results must not change.
        let mut cache = StripCache::new(kd * NR);
        for _ in 0..2 {
            let mut c = vec![f32::NAN; m * n];
            gemm_bias_bits_cached(m, n, kd, &a, kd, &bits, &bias, &mut c, n, 1, Some(&mut cache));
            assert!(c.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits()));
        }
        assert!(cache.misses() > 0);
    }

    #[test]
    fn packed_strided_c_leaves_gap_columns_untouched() {
        let (m, n, kd) = (4usize, 3usize, 5usize);
        let a = rand_vec(m * kd, 31);
        let b = rand_vec(kd * n, 32);
        let bias = vec![0.5; n];
        let bp = pack_b_panels(&b, kd, n);
        let ldc = 8;
        let mut c = vec![-7.0f32; (m - 1) * ldc + n + 5];
        gemm_bias_packed(m, n, kd, &a, kd, &bp, &bias, &mut c, ldc, 1);
        let want = naive(m, n, kd, &a, &b, &bias);
        for r in 0..m {
            for j in 0..n {
                assert_eq!(c[r * ldc + j], want[r * n + j]);
            }
            if r + 1 < m {
                assert!(c[r * ldc + n..r * ldc + ldc].iter().all(|&v| v == -7.0));
            }
        }
    }
}
