//! The PJRT execution backend (`--features pjrt`): AOT-compiled HLO
//! executables driven through the `xla` crate.
//!
//! This is a thin adapter from [`crate::runtime`] (Session/Engine, the
//! original L3 hot path) onto the [`Backend`]/[`NetExecutor`] traits.
//! One [`PjrtBackend`] owns one PJRT CPU client; executors share it via
//! `Rc` (the client is `Rc`-based internally and must stay on one
//! thread — the coordinator builds one backend per worker).
//!
//! `infer_keyed` keeps image batches device-resident per key — the
//! §Perf optimization the evaluator leans on (disable with
//! `QBOUND_NO_PRELOAD=1` for A/B benchmarking).

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::Result;

use super::{validate_request, Backend, NetExecutor, Variant};
use crate::memory::StorageMode;
use crate::nets::NetManifest;
use crate::runtime::{Engine, Session};

/// Factory for PJRT-backed executors (one shared CPU client).
pub struct PjrtBackend {
    session: Rc<Session>,
}

impl PjrtBackend {
    pub fn new() -> Result<PjrtBackend> {
        // PJRT executes on-device; a requested packed storage mode
        // cannot apply to memory the host never sees. Surface that once
        // instead of silently ignoring QBOUND_STORAGE — and keep a
        // malformed value an error, like every other backend.
        StorageMode::from_env()?.warn_ignored_by("pjrt");
        Ok(PjrtBackend { session: Rc::new(Session::cpu()?) })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn load(&self, manifest: &NetManifest, variant: Variant) -> Result<Box<dyn NetExecutor>> {
        let engine = Engine::load(&self.session, manifest, variant)?;
        Ok(Box::new(PjrtExecutor {
            session: Rc::clone(&self.session),
            engine,
            image_bufs: HashMap::new(),
            preload: std::env::var_os("QBOUND_NO_PRELOAD").is_none(),
        }))
    }
}

/// One compiled network executable with device-resident weights.
pub struct PjrtExecutor {
    session: Rc<Session>,
    engine: Engine,
    /// Device-resident image batches, keyed by the caller's batch id.
    image_bufs: HashMap<usize, xla::PjRtBuffer>,
    preload: bool,
}

impl PjrtExecutor {
    /// The executable is AOT-compiled for one batch size; unlike the
    /// interpreted backends, a request must match it exactly.
    fn check_batch(&self, batch: usize) -> Result<()> {
        let want = self.engine.manifest.batch;
        anyhow::ensure!(
            batch == want,
            "pjrt executable is compiled for batch {want}, got {batch}"
        );
        Ok(())
    }
}

impl NetExecutor for PjrtExecutor {
    fn manifest(&self) -> &NetManifest {
        &self.engine.manifest
    }

    fn variant(&self) -> Variant {
        self.engine.variant
    }

    fn executions(&self) -> u64 {
        self.engine.executions.get()
    }

    fn infer(
        &mut self,
        images: &[f32],
        wq: &[f32],
        dq: &[f32],
        sq: Option<&[f32]>,
    ) -> Result<Vec<f32>> {
        let n_stages = self.engine.manifest.n_stages();
        let batch =
            validate_request(&self.engine.manifest, self.variant(), n_stages, images, wq, dq, sq)?;
        self.check_batch(batch)?;
        self.engine.infer(&self.session, images, wq, dq, sq)
    }

    fn infer_keyed(
        &mut self,
        key: usize,
        images: &[f32],
        wq: &[f32],
        dq: &[f32],
        sq: Option<&[f32]>,
    ) -> Result<Vec<f32>> {
        let n_stages = self.engine.manifest.n_stages();
        let batch =
            validate_request(&self.engine.manifest, self.variant(), n_stages, images, wq, dq, sq)?;
        self.check_batch(batch)?;
        if !self.preload {
            return self.engine.infer(&self.session, images, wq, dq, sq);
        }
        if !self.image_bufs.contains_key(&key) {
            let buf = self.engine.upload_images(&self.session, images)?;
            self.image_bufs.insert(key, buf);
        }
        let buf = &self.image_bufs[&key];
        self.engine.infer_prepared(&self.session, buf, wq, dq, sq)
    }
}
