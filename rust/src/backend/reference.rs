//! The pure-Rust **reference backend**: an interpreted fixed-point CNN
//! forward pass.
//!
//! No XLA, no compiled artifacts — the network graph comes from the
//! architecture registry ([`crate::nets::arch`]), the trained weights
//! from the manifest's NTF file, and the quantization semantics are the
//! host [`QFormat`] quantizer, which is bit-locked to the Pallas kernel
//! and the jnp oracle by the golden-vector tests. That makes this
//! backend the *semantic reference* for every other execution engine:
//! anything a faster backend (PJRT, SIMD, GPU) computes must agree with
//! it up to fp32 accumulation order.
//!
//! Quantization placement comes from the shared lowering
//! ([`super::lowering`], mirroring `python/compile/layers.py::apply`):
//! both this interpreter and the fast backend walk one
//! [`LoweredPlan`], so *where* quantization happens cannot drift
//! between them.
//!
//! All arithmetic is fp32 ("convert at layer read/write, compute in
//! fp32" — paper §2.1).

use anyhow::{bail, Result};

use super::lowering::{self, LoweredPlan};
use super::{Backend, NetExecutor, Variant};
use crate::memory::{PackedBuf, StorageMode};
use crate::nets::arch::{self, same_pad_before, Arch, Op, Padding, Shape};
use crate::nets::NetManifest;
use crate::quant::QFormat;

/// Factory for [`ReferenceExecutor`]s.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReferenceBackend {
    storage: StorageMode,
}

impl ReferenceBackend {
    /// Storage mode from the environment (`QBOUND_STORAGE`). Also
    /// resolves the kernel dispatch (`QBOUND_KERNEL`) — the packed
    /// decode path runs through it — so a misconfiguration surfaces
    /// here as a clean error instead of a hot-path panic.
    pub fn new() -> Result<ReferenceBackend> {
        super::kernels::init()?;
        Ok(ReferenceBackend { storage: StorageMode::from_env()? })
    }

    /// Explicit inter-layer storage mode.
    pub fn with_storage(storage: StorageMode) -> ReferenceBackend {
        ReferenceBackend { storage }
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn load(&self, manifest: &NetManifest, variant: Variant) -> Result<Box<dyn NetExecutor>> {
        let net = lowering::load_network(manifest, variant)?;
        let interp = Interpreter::with_stage(net.arch, net.params, net.stage_group)?;
        let weights = match self.storage {
            StorageMode::F32 => RefWeights::F32(lowering::WeightMemo::default()),
            StorageMode::Packed => RefWeights::Packed(PackedParamMemo::default()),
        };
        Ok(Box::new(ReferenceExecutor {
            interp,
            manifest: manifest.clone(),
            variant,
            weights,
            storage: self.storage,
            executions: 0,
        }))
    }
}

/// Weight memo of one executor, matching its storage mode: resident
/// quantized f32 tensors, or bitstreams at each group's weight width.
enum RefWeights {
    F32(lowering::WeightMemo),
    Packed(PackedParamMemo),
}

/// One loaded network on the reference backend.
pub struct ReferenceExecutor {
    interp: Interpreter,
    manifest: NetManifest,
    variant: Variant,
    weights: RefWeights,
    storage: StorageMode,
    executions: u64,
}

impl NetExecutor for ReferenceExecutor {
    fn manifest(&self) -> &NetManifest {
        &self.manifest
    }

    fn variant(&self) -> Variant {
        self.variant
    }

    fn executions(&self) -> u64 {
        self.executions
    }

    fn max_batch(&self) -> usize {
        usize::MAX
    }

    fn infer(
        &mut self,
        images: &[f32],
        wq: &[f32],
        dq: &[f32],
        sq: Option<&[f32]>,
    ) -> Result<Vec<f32>> {
        let req = lowering::decode_request(&self.manifest, self.variant, images, wq, dq, sq)?;
        let view = match &mut self.weights {
            RefWeights::F32(memo) => {
                ParamView::F32(memo.get(self.interp.plan(), &self.interp.params, &req.wfmt))
            }
            RefWeights::Packed(pm) => {
                pm.ensure(self.interp.plan(), &self.interp.params, &req.wfmt);
                ParamView::Packed(&*pm)
            }
        };

        let elems = self.interp.arch.input_elems();
        let classes = self.manifest.num_classes;
        let mut out = Vec::with_capacity(req.batch * classes);
        for b in 0..req.batch {
            let image = &images[b * elems..(b + 1) * elems];
            let logits = match view {
                ParamView::F32(qparams) => self.interp.forward_one_stored(
                    qparams,
                    image,
                    &req.dfmt,
                    req.sfmt.as_deref(),
                    self.storage,
                )?,
                ParamView::Packed(_) => {
                    // Packed weights pair with the packed activation
                    // loop: bitstreams everywhere, decoded per layer.
                    self.interp.forward_one_packed(view, image, &req.dfmt, req.sfmt.as_deref())?
                }
            };
            out.extend_from_slice(&logits);
        }
        self.executions += 1;
        Ok(out)
    }
}

/// Packed-storage weight memo: every parameter tensor resident only as
/// a bitstream at its group's weight width. The interpreter decodes a
/// layer's tensors right before applying its op and frees them after —
/// the weight-side counterpart of the fused activation loop.
#[derive(Default)]
struct PackedParamMemo {
    cached_wq: Vec<QFormat>,
    /// Each tensor's bitstream paired with its pack format (its group's
    /// `wq` row) — one entry per parameter, so the format can never
    /// drift from the codes it decodes.
    packed: Vec<(QFormat, PackedBuf)>,
}

impl PackedParamMemo {
    /// Rebuild the bitstreams when the weight config changes. Packing
    /// *is* the quantizer (pack→decode equals `quantize_slice` modulo
    /// the single two's-complement zero), so the raw fp32 tensors pack
    /// directly.
    fn ensure(&mut self, plan: &LoweredPlan, params: &[Vec<f32>], wfmt: &[QFormat]) {
        if self.cached_wq == wfmt {
            return;
        }
        let fmts = plan.per_tensor_formats(wfmt);
        self.packed = Vec::with_capacity(params.len());
        for (p, f) in params.iter().zip(&fmts) {
            self.packed.push((*f, PackedBuf::pack(*f, p)));
        }
        self.cached_wq = wfmt.to_vec();
    }

    /// Decode tensor `i` into a fresh vector.
    fn decode(&self, i: usize) -> Vec<f32> {
        let (fmt, buf) = &self.packed[i];
        let mut out = vec![0f32; buf.len()];
        buf.unpack_into(*fmt, &mut out);
        out
    }
}

/// Parameter source of one forward pass: resident f32 tensors, or
/// bitstreams decoded per step.
#[derive(Clone, Copy)]
enum ParamView<'a> {
    F32(&'a [Vec<f32>]),
    Packed(&'a PackedParamMemo),
}

// ---------------------------------------------------------------------------
// The interpreter
// ---------------------------------------------------------------------------

/// An activation tensor flowing through the graph (one image).
#[derive(Clone, Debug)]
struct Feat {
    shape: Shape,
    data: Vec<f32>,
}

/// Interprets an [`Arch`] over a flat parameter list. Independent of
/// manifests so the artifact generator can run networks it is still
/// building artifacts for. Executes the shared [`LoweredPlan`] — the
/// same step list the fast backend runs.
pub struct Interpreter {
    pub arch: Arch,
    /// Flat fp32 parameter list, init order.
    pub params: Vec<Vec<f32>>,
    plan: LoweredPlan,
}

impl Interpreter {
    /// Standard-variant interpreter.
    pub fn new(arch: Arch, params: Vec<Vec<f32>>) -> Result<Interpreter> {
        Interpreter::with_stage(arch, params, None)
    }

    /// Interpreter whose plan routes `sq` quantization to `stage_group`
    /// ([`Variant::Stages`]).
    pub fn with_stage(
        arch: Arch,
        params: Vec<Vec<f32>>,
        stage_group: Option<usize>,
    ) -> Result<Interpreter> {
        let specs = arch::param_specs(&arch)?;
        if specs.len() != params.len() {
            bail!("{}: {} params given, arch wants {}", arch.name, params.len(), specs.len());
        }
        for (s, p) in specs.iter().zip(&params) {
            if s.elems() != p.len() {
                bail!(
                    "{}: param {} has {} elems, spec wants {}",
                    arch.name,
                    s.name,
                    p.len(),
                    s.elems()
                );
            }
        }
        let plan = LoweredPlan::new(&arch, stage_group)?;
        Ok(Interpreter { arch, params, plan })
    }

    /// The lowered plan this interpreter executes.
    pub fn plan(&self) -> &LoweredPlan {
        &self.plan
    }

    /// Quantize every group's parameters with its `wq` row (biases
    /// included, matching `quantize_group_params` on the python side).
    pub fn quantize_params(&self, wq: &[QFormat]) -> Vec<Vec<f32>> {
        self.plan.quantize_params(&self.params, wq)
    }

    /// Forward one image. `qparams` must come from [`Self::quantize_params`]
    /// (or be `&self.params` for fp32); `sfmt` carries the per-stage
    /// formats for the Fig-1 stage-granularity mode (the plan decides
    /// where they apply).
    pub fn forward_one(
        &self,
        qparams: &[Vec<f32>],
        image: &[f32],
        dq: &[QFormat],
        sfmt: Option<&[QFormat]>,
    ) -> Result<Vec<f32>> {
        self.forward_one_stored(qparams, image, dq, sfmt, StorageMode::F32)
    }

    /// [`Interpreter::forward_one`] under an explicit inter-layer
    /// storage mode. With [`StorageMode::Packed`] only bitstreams
    /// persist between steps: each boundary activation is dropped from
    /// f32 the moment it is packed and materialized again only when the
    /// next op consumes it. Results are numerically identical to the
    /// in-f32 path (pack→decode is exactly the quantizer, modulo the
    /// single two's-complement zero).
    pub fn forward_one_stored(
        &self,
        qparams: &[Vec<f32>],
        image: &[f32],
        dq: &[QFormat],
        sfmt: Option<&[QFormat]>,
        storage: StorageMode,
    ) -> Result<Vec<f32>> {
        if storage == StorageMode::Packed {
            return self.forward_one_packed(ParamView::F32(qparams), image, dq, sfmt);
        }
        let (h, w, c) = self.arch.input_shape;
        let mut feat = Feat { shape: Shape::Hwc(h, w, c), data: image.to_vec() };
        dq[0].quantize_slice(&mut feat.data);

        for step in &self.plan.steps {
            let t_obs = crate::obs::step_start();
            let mut cursor = step.param_base;
            feat = apply_op(&step.op, feat, qparams, &mut cursor)?;
            if let Some(fmt) = lowering::post_format(step.post, dq, sfmt) {
                fmt.quantize_slice(&mut feat.data);
            }
            crate::obs::step_end(t_obs, self.plan.name, step.group, "f32", || {
                format!(
                    "net={} op={} kind={} out={:?} dq={}",
                    self.plan.name,
                    step.op.stage_name(),
                    step.op.kind(),
                    feat.shape,
                    dq[step.group],
                )
            });
        }
        if feat.shape != Shape::Flat(self.arch.num_classes) {
            bail!("{}: output shape {:?}", self.arch.name, feat.shape);
        }
        Ok(feat.data)
    }

    /// The fused packed interpreter loop: `packed` holds the current
    /// boundary bitstream (at `fmt`), `feat` a carried unquantized
    /// intra-group tensor — never both. Shape-only ops pass the
    /// bitstream through untouched; any other op materializes its input
    /// right before applying (the interpreter is clarity-first — the
    /// fast backend is the one that streams windows into its kernels).
    /// With a [`ParamView::Packed`] source the weights are bitstreams
    /// too: each step's tensors are decoded right before its op applies
    /// and freed after, so resident weights stay at the packed width.
    fn forward_one_packed(
        &self,
        params: ParamView,
        image: &[f32],
        dq: &[QFormat],
        sfmt: Option<&[QFormat]>,
    ) -> Result<Vec<f32>> {
        let (h, w, c) = self.arch.input_shape;
        let mut shape = Shape::Hwc(h, w, c);
        let mut packed = PackedBuf::pack(dq[0], image);
        let mut fmt = dq[0];
        let mut feat: Option<Feat> = None;

        for step in &self.plan.steps {
            let t_obs = crate::obs::step_start();
            match (&step.op, feat.take()) {
                (Op::Flatten | Op::Dropout, None) => {
                    shape = arch::op_out_shape(&step.op, shape)?;
                }
                (op, carried) => {
                    let f = match carried {
                        Some(f) => f,
                        None => {
                            let mut data = vec![0f32; shape.elems()];
                            packed.unpack_into(fmt, &mut data);
                            Feat { shape, data }
                        }
                    };
                    let out = match params {
                        ParamView::F32(qparams) => {
                            let mut cursor = step.param_base;
                            apply_op(op, f, qparams, &mut cursor)?
                        }
                        ParamView::Packed(pm) => {
                            let step_params: Vec<Vec<f32>> = (0..op.param_count())
                                .map(|i| pm.decode(step.param_base + i))
                                .collect();
                            let mut cursor = 0;
                            apply_op(op, f, &step_params, &mut cursor)?
                        }
                    };
                    shape = out.shape;
                    feat = Some(out);
                }
            }
            if let Some(pfmt) = lowering::post_format(step.post, dq, sfmt) {
                match feat.take() {
                    Some(f) => packed.pack_into(pfmt, &f.data),
                    None => {
                        // Boundary straight after pass-through ops:
                        // re-quantize through f32 exactly as the in-f32
                        // path would.
                        let mut data = vec![0f32; shape.elems()];
                        packed.unpack_into(fmt, &mut data);
                        packed.pack_into(pfmt, &data);
                    }
                }
                fmt = pfmt;
            }
            crate::obs::step_end(t_obs, self.plan.name, step.group, "packed", || {
                format!(
                    "net={} op={} kind={} out={:?} dq={}",
                    self.plan.name,
                    step.op.stage_name(),
                    step.op.kind(),
                    shape,
                    dq[step.group],
                )
            });
        }
        if shape != Shape::Flat(self.arch.num_classes) {
            bail!("{}: output shape {:?}", self.arch.name, shape);
        }
        Ok(match feat {
            Some(f) => f.data,
            None => {
                let mut data = vec![0f32; self.arch.num_classes];
                packed.unpack_into(fmt, &mut data);
                data
            }
        })
    }

    /// Convenience: fp32 logits of one image (teacher labelling, tests).
    pub fn forward_fp32(&self, image: &[f32]) -> Result<Vec<f32>> {
        let nl = self.arch.n_layers();
        self.forward_one(&self.params, image, &vec![QFormat::FP32; nl], None)
    }
}

fn apply_op(op: &Op, x: Feat, qparams: &[Vec<f32>], cursor: &mut usize) -> Result<Feat> {
    Ok(match (op, x.shape) {
        (&Op::Conv { out_c, k, stride, padding, .. }, Shape::Hwc(h, w, c)) => {
            let wgt = &qparams[*cursor];
            let bias = &qparams[*cursor + 1];
            *cursor += 2;
            conv2d(&x.data, h, w, c, wgt, bias, out_c, k, stride, padding)
        }
        (&Op::Dense { out, .. }, Shape::Flat(n)) => {
            let wgt = &qparams[*cursor];
            let bias = &qparams[*cursor + 1];
            *cursor += 2;
            dense(&x.data, n, wgt, bias, out)
        }
        (Op::ReLU, _) => {
            let mut x = x;
            for v in &mut x.data {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
            x
        }
        (&Op::MaxPool { k, stride }, Shape::Hwc(h, w, c)) => maxpool(&x.data, h, w, c, k, stride),
        (&Op::AvgPool { k, stride }, Shape::Hwc(h, w, c)) => avgpool(&x.data, h, w, c, k, stride),
        (Op::GlobalAvgPool, Shape::Hwc(h, w, c)) => {
            let mut out = vec![0f32; c];
            gap_into(&x.data, h, w, c, &mut out);
            Feat { shape: Shape::Flat(c), data: out }
        }
        (&Op::Lrn { n, alpha, beta }, Shape::Hwc(h, w, c)) => lrn(&x.data, h, w, c, n, alpha, beta),
        (Op::Flatten, Shape::Hwc(h, w, c)) => {
            Feat { shape: Shape::Flat(h * w * c), data: x.data }
        }
        (Op::Dropout, _) => x,
        (op @ Op::Inception { .. }, Shape::Hwc(h, w, c)) => {
            inception(op, &x.data, h, w, c, qparams, cursor)?
        }
        (op, s) => bail!("op {op:?} cannot apply to shape {s:?}"),
    })
}

/// NHWC × HWIO convolution with bias. Inner loops are laid out so the
/// output-channel accumulation runs over contiguous memory (both the
/// filter's last axis and the accumulator) — the auto-vectorizable hot
/// loop of the whole backend.
#[allow(clippy::too_many_arguments)]
fn conv2d(
    x: &[f32],
    h: usize,
    w: usize,
    c: usize,
    wgt: &[f32],
    bias: &[f32],
    out_c: usize,
    k: usize,
    stride: usize,
    padding: Padding,
) -> Feat {
    let (oh, ow) = arch::conv_out_hw(h, w, k, stride, padding);
    let (pad_y, pad_x) = match padding {
        Padding::Same => (same_pad_before(h, oh, k, stride), same_pad_before(w, ow, k, stride)),
        Padding::Valid => (0, 0),
    };
    let mut out = vec![0f32; oh * ow * out_c];
    for oy in 0..oh {
        for ox in 0..ow {
            let acc = &mut out[(oy * ow + ox) * out_c..][..out_c];
            acc.copy_from_slice(bias);
            for ky in 0..k {
                let iy = (oy * stride + ky) as isize - pad_y as isize;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                for kx in 0..k {
                    let ix = (ox * stride + kx) as isize - pad_x as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let xrow = &x[((iy as usize) * w + ix as usize) * c..][..c];
                    let wbase = ((ky * k + kx) * c) * out_c;
                    for (ic, &xv) in xrow.iter().enumerate() {
                        let wrow = &wgt[wbase + ic * out_c..][..out_c];
                        for (a, &wv) in acc.iter_mut().zip(wrow) {
                            *a += xv * wv;
                        }
                    }
                }
            }
        }
    }
    Feat { shape: Shape::Hwc(oh, ow, out_c), data: out }
}

fn dense(x: &[f32], n: usize, wgt: &[f32], bias: &[f32], out: usize) -> Feat {
    debug_assert_eq!(x.len(), n);
    debug_assert_eq!(wgt.len(), n * out);
    let mut acc = bias.to_vec();
    for (i, &xv) in x.iter().enumerate() {
        let wrow = &wgt[i * out..][..out];
        for (a, &wv) in acc.iter_mut().zip(wrow) {
            *a += xv * wv;
        }
    }
    Feat { shape: Shape::Flat(out), data: acc }
}

fn maxpool(x: &[f32], h: usize, w: usize, c: usize, k: usize, stride: usize) -> Feat {
    let (oh, ow) = arch::conv_out_hw(h, w, k, stride, Padding::Same);
    let mut out = vec![0f32; oh * ow * c];
    maxpool_into(x, h, w, c, k, stride, &mut out);
    Feat { shape: Shape::Hwc(oh, ow, c), data: out }
}

fn avgpool(x: &[f32], h: usize, w: usize, c: usize, k: usize, stride: usize) -> Feat {
    let (oh, ow) = arch::conv_out_hw(h, w, k, stride, Padding::Same);
    let mut out = vec![0f32; oh * ow * c];
    avgpool_into(x, h, w, c, k, stride, &mut out);
    Feat { shape: Shape::Hwc(oh, ow, c), data: out }
}

fn lrn(x: &[f32], h: usize, w: usize, c: usize, n: usize, alpha: f32, beta: f32) -> Feat {
    let mut out = vec![0f32; x.len()];
    lrn_into(x, h, w, c, n, alpha, beta, &mut out);
    Feat { shape: Shape::Hwc(h, w, c), data: out }
}

// The `*_into` kernels below are the single implementation of the
// non-GEMM ops for BOTH CPU backends — the fast executor calls them
// with its scratch arenas, the wrappers above allocate fresh output.

pub(crate) fn maxpool_into(
    x: &[f32],
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    out: &mut [f32],
) {
    let (oh, ow) = arch::conv_out_hw(h, w, k, stride, Padding::Same);
    let pad_y = same_pad_before(h, oh, k, stride);
    let pad_x = same_pad_before(w, ow, k, stride);
    out.fill(f32::NEG_INFINITY);
    for oy in 0..oh {
        for ox in 0..ow {
            let acc = &mut out[(oy * ow + ox) * c..][..c];
            for ky in 0..k {
                let iy = (oy * stride + ky) as isize - pad_y as isize;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                for kx in 0..k {
                    let ix = (ox * stride + kx) as isize - pad_x as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let xrow = &x[((iy as usize) * w + ix as usize) * c..][..c];
                    for (a, &v) in acc.iter_mut().zip(xrow) {
                        if v > *a {
                            *a = v;
                        }
                    }
                }
            }
        }
    }
}

pub(crate) fn avgpool_into(
    x: &[f32],
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    out: &mut [f32],
) {
    let (oh, ow) = arch::conv_out_hw(h, w, k, stride, Padding::Same);
    let pad_y = same_pad_before(h, oh, k, stride);
    let pad_x = same_pad_before(w, ow, k, stride);
    out.fill(0.0);
    for oy in 0..oh {
        for ox in 0..ow {
            let acc = &mut out[(oy * ow + ox) * c..][..c];
            let mut count = 0u32;
            for ky in 0..k {
                let iy = (oy * stride + ky) as isize - pad_y as isize;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                for kx in 0..k {
                    let ix = (ox * stride + kx) as isize - pad_x as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    count += 1;
                    let xrow = &x[((iy as usize) * w + ix as usize) * c..][..c];
                    for (a, &v) in acc.iter_mut().zip(xrow) {
                        *a += v;
                    }
                }
            }
            // SAME avg-pool divides by the number of *valid* cells (the
            // L2 graph computes counts with zero-padded ones).
            if count > 0 {
                let inv = 1.0 / count as f32;
                for a in acc.iter_mut() {
                    *a *= inv;
                }
            }
        }
    }
}

pub(crate) fn gap_into(x: &[f32], h: usize, w: usize, c: usize, out: &mut [f32]) {
    out.fill(0.0);
    for pos in 0..h * w {
        let row = &x[pos * c..(pos + 1) * c];
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
    let inv = 1.0 / (h * w) as f32;
    for o in out {
        *o *= inv;
    }
}

/// Caffe-style across-channel LRN: `x / (1 + alpha/n * sum_win x^2)^beta`.
pub(crate) fn lrn_into(
    x: &[f32],
    h: usize,
    w: usize,
    c: usize,
    n: usize,
    alpha: f32,
    beta: f32,
    out: &mut [f32],
) {
    let half = n / 2;
    let scale = alpha / n as f32;
    for pos in 0..h * w {
        let xrow = &x[pos * c..][..c];
        let orow = &mut out[pos * c..][..c];
        for ch in 0..c {
            let lo = ch.saturating_sub(half);
            let hi = (ch + half).min(c - 1);
            let mut acc = 0f32;
            for v in &xrow[lo..=hi] {
                acc += v * v;
            }
            orow[ch] = xrow[ch] / (1.0 + scale * acc).powf(beta);
        }
    }
}

fn relu_inplace(f: &mut Feat) {
    for v in &mut f.data {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

fn inception(
    op: &Op,
    x: &[f32],
    h: usize,
    w: usize,
    c: usize,
    qparams: &[Vec<f32>],
    cursor: &mut usize,
) -> Result<Feat> {
    let &Op::Inception { b1, b3r, b3, b5r, b5, pp, .. } = op else {
        bail!("not an inception op");
    };
    // Parameter order: b1, b3r, b3, b5r, b5, pp — each (w, b).
    let mut takes = Vec::with_capacity(12);
    for _ in 0..12 {
        takes.push(&qparams[*cursor]);
        *cursor += 1;
    }
    let cv = |x: &[f32], ic: usize, wi: usize, oc: usize, k: usize| -> Feat {
        let mut f = conv2d(x, h, w, ic, takes[wi], takes[wi + 1], oc, k, 1, Padding::Same);
        relu_inplace(&mut f);
        f
    };
    let br1 = cv(x, c, 0, b1, 1);
    let r3 = cv(x, c, 2, b3r, 1);
    let br3 = cv(&r3.data, b3r, 4, b3, 3);
    let r5 = cv(x, c, 6, b5r, 1);
    let br5 = cv(&r5.data, b5r, 8, b5, 5);
    let pooled = maxpool(x, h, w, c, 3, 1);
    let brp = cv(&pooled.data, c, 10, pp, 1);

    let out_c = b1 + b3 + b5 + pp;
    let mut out = vec![0f32; h * w * out_c];
    for pos in 0..h * w {
        let dst = &mut out[pos * out_c..][..out_c];
        dst[..b1].copy_from_slice(&br1.data[pos * b1..][..b1]);
        dst[b1..b1 + b3].copy_from_slice(&br3.data[pos * b3..][..b3]);
        dst[b1 + b3..b1 + b3 + b5].copy_from_slice(&br5.data[pos * b5..][..b5]);
        dst[b1 + b3 + b5..].copy_from_slice(&brp.data[pos * pp..][..pp]);
    }
    Ok(Feat { shape: Shape::Hwc(h, w, out_c), data: out })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::arch::Shape;

    fn feat(h: usize, w: usize, c: usize, data: Vec<f32>) -> Feat {
        Feat { shape: Shape::Hwc(h, w, c), data }
    }

    #[test]
    fn conv2d_identity_kernel() {
        // 1x1 conv with identity weight reproduces the input channel.
        let x = vec![1.0, 2.0, 3.0, 4.0]; // 2x2x1
        let f = conv2d(&x, 2, 2, 1, &[1.0], &[0.0], 1, 1, 1, Padding::Same);
        assert_eq!(f.data, x);
        assert_eq!(f.shape, Shape::Hwc(2, 2, 1));
    }

    #[test]
    fn conv2d_valid_sums_window() {
        // 3x3 input, 2x2 kernel of ones, VALID -> 2x2 of window sums.
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let f = conv2d(&x, 3, 3, 1, &[1.0; 4], &[0.5], 1, 2, 1, Padding::Valid);
        assert_eq!(f.shape, Shape::Hwc(2, 2, 1));
        // windows: (1+2+4+5, 2+3+5+6, 4+5+7+8, 5+6+8+9) + bias
        assert_eq!(f.data, vec![12.5, 16.5, 24.5, 28.5]);
    }

    #[test]
    fn conv2d_same_pads_symmetrically() {
        // 2x2 input, 3x3 ones kernel SAME: each output sums the valid
        // 3x3 neighbourhood.
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let f = conv2d(&x, 2, 2, 1, &[1.0; 9], &[0.0], 1, 3, 1, Padding::Same);
        // every neighbourhood covers all four cells
        assert_eq!(f.data, vec![10.0, 10.0, 10.0, 10.0]);
    }

    #[test]
    fn dense_matmul() {
        // x = [1, 2], w = [[1, 10], [100, 1000]], b = [0.5, -0.5]
        let f = dense(&[1.0, 2.0], 2, &[1.0, 10.0, 100.0, 1000.0], &[0.5, -0.5], 2);
        assert_eq!(f.data, vec![201.5, 2009.5]);
    }

    #[test]
    fn maxpool_basic() {
        let x = vec![1.0, 3.0, 2.0, 4.0]; // 2x2x1
        let f = maxpool(&x, 2, 2, 1, 2, 2);
        assert_eq!(f.shape, Shape::Hwc(1, 1, 1));
        assert_eq!(f.data, vec![4.0]);
    }

    #[test]
    fn avgpool_ignores_padding() {
        // 2x2 input pooled 3x3 stride 2 SAME -> 1x1; only 4 valid cells.
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let f = avgpool(&x, 2, 2, 1, 3, 2);
        assert_eq!(f.data, vec![2.5]);
    }

    #[test]
    fn gap_means_channels() {
        // 1x2x2: positions [(1, 10), (3, 30)]
        let x = feat(1, 2, 2, vec![1.0, 10.0, 3.0, 30.0]);
        let mut cursor = 0;
        let f = apply_op(&Op::GlobalAvgPool, x, &[], &mut cursor).unwrap();
        assert_eq!(f.data, vec![2.0, 20.0]);
        assert_eq!(f.shape, Shape::Flat(2));
    }

    #[test]
    fn lrn_identity_for_tiny_activations() {
        // alpha*x^2 << 1 -> ~identity
        let f = lrn(&[0.01, -0.02], 1, 1, 2, 5, 1e-4, 0.75);
        assert!((f.data[0] - 0.01).abs() < 1e-6);
        assert!((f.data[1] + 0.02).abs() < 1e-6);
    }

    #[test]
    fn lrn_shrinks_large_activations() {
        let f = lrn(&[100.0], 1, 1, 1, 5, 1e-1, 0.75);
        assert!(f.data[0] < 100.0 * 0.9, "{}", f.data[0]);
        assert!(f.data[0] > 0.0);
    }

    #[test]
    fn relu_and_flatten() {
        let x = feat(1, 1, 3, vec![-1.0, 0.5, -0.2]);
        let mut cursor = 0;
        let f = apply_op(&Op::ReLU, x, &[], &mut cursor).unwrap();
        assert_eq!(f.data, vec![0.0, 0.5, 0.0]);
        let f = apply_op(&Op::Flatten, f, &[], &mut cursor).unwrap();
        assert_eq!(f.shape, Shape::Flat(3));
    }

    #[test]
    fn interpreter_runs_lenet_end_to_end() {
        let arch = arch::get("lenet").unwrap();
        let specs = arch::param_specs(&arch).unwrap();
        let mut rng = crate::prng::Xoshiro256pp::new(7);
        let params: Vec<Vec<f32>> = specs
            .iter()
            .map(|s| {
                if s.fan_in == 0 {
                    vec![0.0; s.elems()]
                } else {
                    let scale = (2.0 / s.fan_in as f64).sqrt();
                    (0..s.elems()).map(|_| (rng.normal() * scale) as f32).collect()
                }
            })
            .collect();
        let interp = Interpreter::new(arch, params).unwrap();
        let image: Vec<f32> = (0..interp.arch.input_elems())
            .map(|_| rng.uniform_f32(0.0, 1.0))
            .collect();
        let logits = interp.forward_fp32(&image).unwrap();
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|v| v.is_finite()));
        // deterministic
        assert_eq!(logits, interp.forward_fp32(&image).unwrap());
        // fp32 sentinel config == explicit fp32 helper
        let nl = interp.arch.n_layers();
        let viaq = interp
            .forward_one(&interp.params, &image, &vec![QFormat::FP32; nl], None)
            .unwrap();
        assert_eq!(logits, viaq);
    }

    #[test]
    fn quantize_params_respects_groups() {
        let arch = arch::get("lenet").unwrap();
        let specs = arch::param_specs(&arch).unwrap();
        let params: Vec<Vec<f32>> = specs.iter().map(|s| vec![0.3; s.elems()]).collect();
        let interp = Interpreter::new(arch, params).unwrap();
        let mut wq = vec![QFormat::FP32; 4];
        wq[0] = QFormat::new(1, 1); // L1 rounds 0.3 -> 0.5
        let q = interp.quantize_params(&wq);
        assert_eq!(q[0][0], 0.5); // L1.conv.w quantized
        assert_eq!(q[2][0], 0.3); // L2.conv.w untouched
    }

    #[test]
    fn packed_param_memo_decodes_quantized_tensors() {
        let arch = arch::get("lenet").unwrap();
        let specs = arch::param_specs(&arch).unwrap();
        let params: Vec<Vec<f32>> = specs.iter().map(|s| vec![0.3; s.elems()]).collect();
        let interp = Interpreter::new(arch, params).unwrap();
        let mut wq = vec![QFormat::FP32; 4];
        wq[0] = QFormat::new(1, 1); // L1 rounds 0.3 -> 0.5
        let mut memo = PackedParamMemo::default();
        memo.ensure(interp.plan(), &interp.params, &wq);
        assert_eq!(memo.packed.len(), interp.params.len());
        assert_eq!(memo.decode(0)[0], 0.5); // L1 weights at Q(1.1)
        assert_eq!(memo.decode(2)[0], 0.3); // L2 weights fp32 passthrough
        // A packed forward equals the f32-weights packed forward.
        let image = vec![0.5f32; interp.arch.input_elems()];
        let dq = vec![QFormat::new(9, 4); 4];
        let q = interp.quantize_params(&wq);
        let want = interp
            .forward_one_stored(&q, &image, &dq, None, StorageMode::Packed)
            .unwrap();
        let got = interp
            .forward_one_packed(ParamView::Packed(&memo), &image, &dq, None)
            .unwrap();
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() == 0.0, "{a} vs {b}");
        }
    }
}
