//! Shared network lowering: the single plan every CPU executor consumes.
//!
//! The reference interpreter and the fast (im2col + GEMM) executor must
//! place quantization at *exactly* the same points — the placement rules
//! mirror `python/compile/layers.py::apply`:
//!
//!   * each group's parameters (weights + biases) are quantized with that
//!     group's `wq` row,
//!   * the network input is quantized with `dq[0]`,
//!   * each group's *output* is quantized with its `dq` row,
//!   * in [`Variant::Stages`][crate::backend::Variant::Stages] mode, the
//!     stage group's intermediate op outputs are quantized with `sq` rows
//!     instead of the group's `dq`.
//!
//! Rather than each backend re-implementing that walk, [`LoweredPlan`]
//! flattens the grouped graph once at load time into a step list where
//! every step carries its input/output shape, its slot in the flat
//! parameter list, and a structural [`PostQuant`] rule. Executors then
//! only have to run ops and call [`post_format`] — drift between
//! backends in *where* quantization happens becomes impossible, and the
//! cross-backend parity suite (`tests/integration_parity.rs`) locks the
//! remaining numeric agreement.

use anyhow::{bail, Result};

use super::gemm::NR;
use super::Variant;
use crate::memory::storage_width;
use crate::nets::arch::{self, conv_out_hw, Arch, Op, Shape};
use crate::nets::NetManifest;
use crate::quant::QFormat;
use crate::tensor::ntf;

/// Structural quantization rule for one step's output, resolved against
/// the decoded `dq`/`sq` formats at infer time by [`post_format`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PostQuant {
    /// Intermediate op inside a group: output flows through unquantized.
    None,
    /// Last op of group `g`: output quantized with `dq[g]`.
    Group(usize),
    /// Op `index` inside the stage group: output quantized with
    /// `sq[index]`. When no `sq` is supplied (callers outside the Stages
    /// variant), falls back to `dq[g]` if this is also the group's last
    /// op (`group = Some(g)`).
    Stage { index: usize, group: Option<usize> },
}

/// Row block of the streamed GEMM `A` read in the fused packed path:
/// 1×1 stride-1 convs and dense layers decode at most this many `A`
/// rows from the input bitstream at a time (per-row output independence
/// keeps the result bit-identical to a whole-matrix GEMM).
pub const FUSED_A_ROWS: usize = 128;

/// Ceiling (f32 elements) on the fused path's decoded-weight-strip
/// cache: a streamed 1×1 conv re-decodes every weight panel once per
/// [`FUSED_A_ROWS`] block, so the executor memoizes decoded strips up
/// to this budget (64 KiB at 4 bytes/element). The plan prices the
/// actual per-net need into [`LoweredPlan::strip_cache_elems`], clamped
/// here so the envelope stays bounded on any architecture.
pub const STRIP_CACHE_CAP: usize = 16 * 1024;

/// Resolve a step's output format from the decoded wire configs.
pub fn post_format(
    post: PostQuant,
    dfmt: &[QFormat],
    sfmt: Option<&[QFormat]>,
) -> Option<QFormat> {
    match post {
        PostQuant::None => None,
        PostQuant::Group(g) => Some(dfmt[g]),
        PostQuant::Stage { index, group } => match sfmt {
            Some(s) => Some(s[index]),
            None => group.map(|g| dfmt[g]),
        },
    }
}

/// One executable step of the flattened graph.
#[derive(Clone, Debug)]
pub struct Step {
    pub op: Op,
    /// Precision group ("layer") this op belongs to.
    pub group: usize,
    /// First index of this op's tensors in the flat parameter list.
    pub param_base: usize,
    pub in_shape: Shape,
    pub out_shape: Shape,
    pub post: PostQuant,
}

/// A network flattened for execution: steps, parameter layout, and the
/// scratch-buffer high-water marks the fast backend sizes its arenas
/// from.
///
/// # Examples
///
/// Plans come straight from the static architecture registry — no
/// artifacts needed — and carry the scratch high-water marks and panel
/// padding that [`FootprintModel::fused_envelope`] prices:
///
/// ```
/// use qbound::backend::lowering::LoweredPlan;
/// use qbound::nets::arch;
/// use qbound::quant::QFormat;
///
/// let lenet = arch::get("lenet").unwrap();
/// let plan = LoweredPlan::new(&lenet, None).unwrap();
/// assert_eq!(plan.weight_pad_elems.len(), plan.n_layers);
/// assert!(plan.max_win_elems > 0 && plan.max_bias_elems > 0);
///
/// // Packed Q1.8 weights (10-bit codes) store well under the f32 cost
/// // of the same GEMM panels + biases.
/// let wq = vec![QFormat::new(1, 8); plan.n_layers];
/// let f32_bytes = 4 * (plan.panel_param_elems + plan.bias_param_elems);
/// assert!(plan.packed_weight_bytes(&wq) < f32_bytes);
/// ```
///
/// [`FootprintModel::fused_envelope`]: crate::memory::FootprintModel::fused_envelope
#[derive(Clone, Debug)]
pub struct LoweredPlan {
    pub name: &'static str,
    pub steps: Vec<Step>,
    /// Parameter tensors consumed by each group (weight-quant grouping).
    pub group_param_counts: Vec<usize>,
    pub n_layers: usize,
    pub input_shape: (usize, usize, usize),
    pub num_classes: usize,
    /// Largest activation tensor (elements) at any step boundary.
    pub max_act_elems: usize,
    /// Largest im2col patch matrix (elements) any conv needs.
    pub max_col_elems: usize,
    /// Largest inception temporary (branch-reduce output / pooled input).
    pub max_tmp_elems: usize,
    /// Fused packed mode: largest streaming decode window (elements) any
    /// step needs — one input row for im2col, one [`FUSED_A_ROWS`] block
    /// for a streamed GEMM `A`, the whole module input for inception
    /// (its four branches each re-read it).
    pub max_win_elems: usize,
    /// Fused packed mode: largest *per-thread* im2col decode window
    /// (one input row, `iw·ic` elements) over the packed-input non-1×1
    /// convs. The parallel packed im2col gives every extra thread its
    /// own row window; [`Self::fused_window_elems`] prices them.
    pub max_row_win_elems: usize,
    /// Fused packed mode: decoded-weight-strip cache capacity (f32
    /// elements) the executor allocates — the largest panel-strip set
    /// (`ceil(out_c/NR)·NR·kd`) over the 1×1 stride-1 convs that stream
    /// their `A` in more than one [`FUSED_A_ROWS`] block, clamped to
    /// [`STRIP_CACHE_CAP`]. Zero when no conv re-decodes weights.
    pub strip_cache_elems: usize,
    /// Fused packed mode: largest f32 working set (elements) live during
    /// any single step — decode window (or carried intra-group input)
    /// plus the step's output — excluding the col/tmp scratch tracked
    /// above. [`FootprintModel`](crate::memory::FootprintModel) callers
    /// use it to bound the transient churn of a fused forward pass.
    pub max_fused_elems: usize,
    /// Largest bias tensor (elements) any single GEMM consumes — the
    /// packed-weight path decodes biases into a scratch window this big.
    pub max_bias_elems: usize,
    /// Per group: zero-padding elements the NR-lane GEMM panel layout
    /// adds on top of the true weight elements. Priced at the group's
    /// weight width by `FootprintModel::fused_envelope` — the gap
    /// between the modeled weight term and what the panel bitstreams
    /// actually store.
    pub weight_pad_elems: Vec<usize>,
    /// Total GEMM panel elements across the plan, padding included (the
    /// f32 path keeps exactly these at 4 bytes each).
    pub panel_param_elems: usize,
    /// Total bias elements across the plan.
    pub bias_param_elems: usize,
}

impl LoweredPlan {
    /// Flatten `arch`; `stage_group` is the group whose op outputs take
    /// `sq` quantization (the Stages variant), `None` for Standard.
    pub fn new(arch: &Arch, stage_group: Option<usize>) -> Result<LoweredPlan> {
        let (h, w, c) = arch.input_shape;
        let mut shape = Shape::Hwc(h, w, c);
        let mut steps = Vec::new();
        let mut param_base = 0usize;
        let mut max_act = shape.elems();
        let mut max_col = 0usize;
        let mut max_tmp = 0usize;
        let mut max_win = 0usize;
        let mut max_row_win = 0usize;
        let mut strip_cache = 0usize;
        let mut max_fused = 0usize;
        // Whether the *current* step's input is a packed bitstream in
        // fused mode: true at entry (the network input is packed at
        // dq[0]) and after every quantized post; shape-only ops pass the
        // bitstream through untouched.
        let mut packed_in = true;
        let mut group_param_counts = Vec::with_capacity(arch.groups.len());

        for (gi, g) in arch.groups.iter().enumerate() {
            let mut group_params = 0usize;
            for (oi, op) in g.ops.iter().enumerate() {
                let out_shape = arch::op_out_shape(op, shape)?;
                let last = oi + 1 == g.ops.len();
                let post = if stage_group == Some(gi) {
                    PostQuant::Stage { index: oi, group: if last { Some(gi) } else { None } }
                } else if last {
                    PostQuant::Group(gi)
                } else {
                    PostQuant::None
                };
                // Scratch high-water marks for the fast backend.
                match (op, shape) {
                    (&Op::Conv { k, stride, padding, .. }, Shape::Hwc(ih, iw, ic)) => {
                        if !(k == 1 && stride == 1) {
                            let (oh, ow) = conv_out_hw(ih, iw, k, stride, padding);
                            max_col = max_col.max(oh * ow * k * k * ic);
                        }
                    }
                    (&Op::Inception { b3r, b5r, .. }, Shape::Hwc(ih, iw, ic)) => {
                        // 3x3 / 5x5 branches run im2col over the reduce
                        // outputs; the pool branch needs a pooled copy of
                        // the module input.
                        max_col = max_col.max(ih * iw * 9 * b3r).max(ih * iw * 25 * b5r);
                        max_tmp = max_tmp.max(ih * iw * b3r.max(b5r).max(ic));
                    }
                    _ => {}
                }
                // Fused-mode working-set high-water marks. Costs mirror
                // the fast backend's fused step execution exactly.
                let (in_e, out_e) = (shape.elems(), out_shape.elems());
                let (win, fused) = if packed_in {
                    match (op, shape) {
                        (&Op::Conv { out_c, k, stride, .. }, Shape::Hwc(_, iw, ic)) => {
                            if k == 1 && stride == 1 {
                                // streamed GEMM A: one row block at a time.
                                // More than one block re-reads every weight
                                // strip — size the strip cache for it.
                                let w = FUSED_A_ROWS.min(in_e / ic) * ic;
                                if in_e / ic > FUSED_A_ROWS {
                                    let strips = out_c.div_ceil(NR) * NR * ic;
                                    strip_cache =
                                        strip_cache.max(strips.min(STRIP_CACHE_CAP));
                                }
                                (w, w + out_e)
                            } else {
                                // im2col decodes one input row at a time
                                // (one row window *per thread* when the
                                // packed im2col splits output rows).
                                max_row_win = max_row_win.max(iw * ic);
                                (iw * ic, iw * ic + out_e)
                            }
                        }
                        (Op::Dense { .. }, _) => (in_e, in_e + out_e),
                        (Op::Inception { .. }, _) => (in_e, in_e + out_e),
                        // A pass-through is free unless it carries a
                        // quantized post on a still-packed activation —
                        // then the runtime materializes out_e to
                        // re-quantize through f32.
                        (Op::Flatten | Op::Dropout, _) => {
                            (0, if post == PostQuant::None { 0 } else { out_e })
                        }
                        // materialize-then-run fallback (stage-variant
                        // boundaries can precede any op)
                        (Op::ReLU, _) => (0, in_e),
                        _ => (0, in_e + out_e),
                    }
                } else {
                    match op {
                        // in-place / shape-only on a carried f32 tensor
                        Op::ReLU | Op::Flatten | Op::Dropout => (0, in_e),
                        _ => (0, in_e + out_e),
                    }
                };
                max_win = max_win.max(win);
                max_fused = max_fused.max(fused);
                packed_in = match post {
                    PostQuant::None => packed_in && matches!(op, Op::Flatten | Op::Dropout),
                    _ => true,
                };
                steps.push(Step {
                    op: op.clone(),
                    group: gi,
                    param_base,
                    in_shape: shape,
                    out_shape,
                    post,
                });
                param_base += op.param_count();
                group_params += op.param_count();
                shape = out_shape;
                max_act = max_act.max(shape.elems());
            }
            group_param_counts.push(group_params);
        }
        if shape != Shape::Flat(arch.num_classes) {
            bail!("{}: lowered output shape {shape:?}", arch.name);
        }
        // GEMM parameter accounting over the finished step list, derived
        // from the same walk the executors build their weight panels
        // from ([`gemm_tensors`]): a tensor consumed as a GEMM `B` is
        // stored as ceil(n/NR)·NR·kd panel elements, and its bias holds
        // `n` — the GEMM's output width — in every case.
        let mut max_bias = 0usize;
        let mut weight_pad = vec![0usize; arch.groups.len()];
        let mut panel_elems = 0usize;
        let mut bias_elems = 0usize;
        for t in gemm_tensors(&steps) {
            let padded = t.n.div_ceil(NR) * NR;
            weight_pad[t.group] += (padded - t.n) * t.kd;
            panel_elems += padded * t.kd;
            bias_elems += t.n;
            max_bias = max_bias.max(t.n);
        }
        Ok(LoweredPlan {
            name: arch.name,
            steps,
            group_param_counts,
            n_layers: arch.groups.len(),
            input_shape: arch.input_shape,
            num_classes: arch.num_classes,
            max_act_elems: max_act,
            max_col_elems: max_col,
            max_tmp_elems: max_tmp,
            max_win_elems: max_win,
            max_row_win_elems: max_row_win,
            strip_cache_elems: strip_cache,
            max_fused_elems: max_fused,
            max_bias_elems: max_bias,
            weight_pad_elems: weight_pad,
            panel_param_elems: panel_elems,
            bias_param_elems: bias_elems,
        })
    }

    pub fn input_elems(&self) -> usize {
        let (h, w, c) = self.input_shape;
        h * w * c
    }

    /// Fused-mode scratch-window budget (f32 elements) for a `threads`
    /// worker budget: the largest decode window, one extra im2col row
    /// window per additional thread, the bias decode window, and the
    /// decoded-weight-strip cache. This is the "windows" term of the
    /// modeled envelope
    /// ([`FootprintModel::fused_envelope`](crate::memory::FootprintModel::fused_envelope));
    /// envelope call sites price the single-threaded budget (`threads =
    /// 1`) — the extra per-thread rows are short-lived transients
    /// covered by the bound checker's slack, not steady-state residency.
    pub fn fused_window_elems(&self, threads: usize) -> usize {
        self.max_win_elems
            + self.max_row_win_elems * (threads.max(1) - 1)
            + self.max_bias_elems
            + self.strip_cache_elems
    }

    /// Quantize every group's parameters with its `wq` row (biases
    /// included, matching `quantize_group_params` on the python side).
    pub fn quantize_params(&self, params: &[Vec<f32>], wq: &[QFormat]) -> Vec<Vec<f32>> {
        let mut out = Vec::with_capacity(params.len());
        let mut idx = 0usize;
        for (gi, &count) in self.group_param_counts.iter().enumerate() {
            for _ in 0..count {
                out.push(wq[gi].quantize_vec(&params[idx]));
                idx += 1;
            }
        }
        out
    }

    /// Per-tensor pack formats: each group's `wq` row repeated over its
    /// parameter tensors — the same expansion [`Self::quantize_params`]
    /// applies, shared by both executors' packed-weight memos so the
    /// assignment cannot drift between them.
    pub fn per_tensor_formats(&self, wfmt: &[QFormat]) -> Vec<QFormat> {
        let mut fmts = Vec::with_capacity(self.group_param_counts.iter().sum());
        for (gi, &count) in self.group_param_counts.iter().enumerate() {
            fmts.extend((0..count).map(|_| wfmt[gi]));
        }
        fmts
    }

    /// Realized bytes of the packed weight set under `wfmt`, computed
    /// from the plan alone (no weights I/O): per GEMM tensor, the
    /// NR-padded panel bitstream plus its bias bitstream, each
    /// byte-ceiled at the group's storage width. Equals
    /// `fast::packed_weight_bytes` over the real tensors exactly — the
    /// tests pin the equality — so report paths can price the weight
    /// half of the bound without packing anything.
    pub fn packed_weight_bytes(&self, wfmt: &[QFormat]) -> usize {
        let mut total = 0usize;
        for t in gemm_tensors(&self.steps) {
            let width = storage_width(wfmt[t.group]) as usize;
            let padded = t.n.div_ceil(NR) * NR * t.kd;
            total += (padded * width).div_ceil(8);
            total += (t.n * width).div_ceil(8);
        }
        total
    }
}

/// A tensor a step list consumes as a GEMM `B`.
#[derive(Clone, Copy, Debug)]
pub struct GemmTensor {
    /// Index in the flat parameter list (its bias sits at `param + 1`).
    pub param: usize,
    /// Precision group of the owning step.
    pub group: usize,
    /// GEMM depth (rows of `B`).
    pub kd: usize,
    /// GEMM output width (columns of `B`; also the bias length).
    pub n: usize,
}

/// Every tensor `steps` consumes as a GEMM `B` — conv + dense kernels,
/// and all six convs of each inception module (branch order b1, b3r,
/// b3, b5r, b5, pp; each `(w, b)` pair). The executors build their
/// weight panels from this walk and [`LoweredPlan::new`] derives its
/// parameter accounting (`weight_pad_elems` & co) from it, so the two
/// cannot drift.
pub fn gemm_tensors(steps: &[Step]) -> Vec<GemmTensor> {
    let mut out = Vec::new();
    for step in steps {
        let (base, group) = (step.param_base, step.group);
        match (&step.op, step.in_shape) {
            (&Op::Conv { out_c, k, .. }, Shape::Hwc(_, _, c)) => {
                out.push(GemmTensor { param: base, group, kd: k * k * c, n: out_c });
            }
            (&Op::Dense { out: n, .. }, Shape::Flat(kd)) => {
                out.push(GemmTensor { param: base, group, kd, n });
            }
            (&Op::Inception { b1, b3r, b3, b5r, b5, pp, .. }, Shape::Hwc(_, _, c)) => {
                let dims = [(c, b1), (c, b3r), (9 * b3r, b3), (c, b5r), (25 * b5r, b5), (c, pp)];
                for (i, &(kd, n)) in dims.iter().enumerate() {
                    out.push(GemmTensor { param: base + 2 * i, group, kd, n });
                }
            }
            _ => {}
        }
    }
    out
}

/// A validated, decoded infer request — the shared front half of every
/// CPU executor's `infer`.
pub(crate) struct Request {
    /// Batch derived from the image buffer length.
    pub batch: usize,
    pub wfmt: Vec<QFormat>,
    pub dfmt: Vec<QFormat>,
    pub sfmt: Option<Vec<QFormat>>,
}

/// Validate one request against `m`/`variant` and decode the wire
/// configs (see [`super::validate_request`] for the rejection rules).
pub(crate) fn decode_request(
    m: &NetManifest,
    variant: Variant,
    images: &[f32],
    wq: &[f32],
    dq: &[f32],
    sq: Option<&[f32]>,
) -> Result<Request> {
    let batch = super::validate_request(m, variant, m.n_stages(), images, wq, dq, sq)?;
    Ok(Request {
        batch,
        wfmt: super::wire_to_formats(wq),
        dfmt: super::wire_to_formats(dq),
        sfmt: sq.map(|s| super::wire_to_formats(s)),
    })
}

/// Weight-quantization memo shared by the CPU executors: resident
/// weights are re-quantized only when the weight config changes (an
/// eval sweeps many batches under one config).
#[derive(Default)]
pub(crate) struct WeightMemo {
    cached_wq: Vec<QFormat>,
    qparams: Vec<Vec<f32>>,
}

impl WeightMemo {
    /// Quantized parameters for `wfmt`, recomputed only on change.
    pub fn get(
        &mut self,
        plan: &LoweredPlan,
        params: &[Vec<f32>],
        wfmt: &[QFormat],
    ) -> &[Vec<f32>] {
        if self.cached_wq != wfmt {
            self.qparams = plan.quantize_params(params, wfmt);
            self.cached_wq = wfmt.to_vec();
        }
        &self.qparams
    }
}

/// A manifest resolved against the registry with weights resident —
/// the common front half of every CPU backend's `load`.
pub struct LoadedNet {
    pub arch: Arch,
    /// Flat fp32 parameter list, init order.
    pub params: Vec<Vec<f32>>,
    /// Stage group index for [`Variant::Stages`], `None` for Standard.
    pub stage_group: Option<usize>,
}

/// Resolve `manifest` against the architecture registry, cross-validate
/// it, load + shape-check the weights, and resolve the stage group.
pub fn load_network(manifest: &NetManifest, variant: Variant) -> Result<LoadedNet> {
    let arch = arch::get(&manifest.name).ok_or_else(|| {
        anyhow::anyhow!("no architecture registered for {:?}", manifest.name)
    })?;
    arch::check_manifest(&arch, manifest)?;

    // Load weights in manifest order (== arch init order, validated
    // above), with shape checks like the PJRT engine performs.
    let mut weights = ntf::read_file(&manifest.weights_path())?;
    let mut params = Vec::with_capacity(manifest.params.len());
    for p in &manifest.params {
        let t = weights
            .remove(&p.name)
            .ok_or_else(|| anyhow::anyhow!("weights file missing {:?}", p.name))?;
        if t.dims != p.shape {
            bail!("{}: shape {:?} != manifest {:?}", p.name, t.dims, p.shape);
        }
        params.push(t.as_f32()?.to_vec());
    }

    let stage_group = match variant {
        Variant::Standard => None,
        Variant::Stages => {
            let sv = manifest
                .stage_variant
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("{} has no stage variant", manifest.name))?;
            let ops = arch.groups.get(sv.group_index).map(|g| g.ops.len()).unwrap_or(0);
            if ops != sv.n_stages {
                bail!(
                    "{}: stage variant declares {} stages but group {} has {} ops",
                    manifest.name,
                    sv.n_stages,
                    sv.group_index,
                    ops
                );
            }
            Some(sv.group_index)
        }
    };
    Ok(LoadedNet { arch, params, stage_group })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet_plan_flattens_in_group_order() {
        let arch = arch::get("lenet").unwrap();
        let plan = LoweredPlan::new(&arch, None).unwrap();
        assert_eq!(plan.n_layers, 4);
        assert_eq!(plan.steps.len(), 8); // conv,pool | conv,pool | flat,fc,relu | fc
        assert_eq!(plan.group_param_counts, vec![2, 2, 2, 2]);
        // Group boundaries get Group posts, intermediates None.
        assert_eq!(plan.steps[0].post, PostQuant::None);
        assert_eq!(plan.steps[1].post, PostQuant::Group(0));
        assert_eq!(plan.steps.last().unwrap().post, PostQuant::Group(3));
        // Param bases track consumed tensors.
        assert_eq!(plan.steps[0].param_base, 0);
        assert_eq!(plan.steps[2].param_base, 2);
        assert_eq!(plan.input_elems(), 28 * 28);
        assert!(plan.max_act_elems >= 24 * 24 * 8);
        // lenet L1 conv: 24*24 outputs x 5*5*1 patch
        assert!(plan.max_col_elems >= 24 * 24 * 25);
    }

    #[test]
    fn stage_group_takes_stage_posts() {
        let arch = arch::get("alexnet").unwrap();
        let plan = LoweredPlan::new(&arch, Some(1)).unwrap();
        let stage_steps: Vec<&Step> = plan.steps.iter().filter(|s| s.group == 1).collect();
        assert_eq!(stage_steps.len(), 4); // conv relu pool norm
        for (i, s) in stage_steps.iter().enumerate() {
            let last = i + 1 == stage_steps.len();
            assert_eq!(
                s.post,
                PostQuant::Stage { index: i, group: if last { Some(1) } else { None } }
            );
        }
        // Other groups keep the standard rule.
        assert_eq!(plan.steps[0].post, PostQuant::None);
    }

    #[test]
    fn post_format_resolution() {
        let dfmt = vec![QFormat::new(8, 2), QFormat::new(9, 3)];
        let sfmt = vec![QFormat::new(1, 1), QFormat::new(2, 2)];
        assert_eq!(post_format(PostQuant::None, &dfmt, Some(&sfmt)), None);
        assert_eq!(post_format(PostQuant::Group(1), &dfmt, None), Some(QFormat::new(9, 3)));
        assert_eq!(
            post_format(PostQuant::Stage { index: 1, group: Some(0) }, &dfmt, Some(&sfmt)),
            Some(QFormat::new(2, 2))
        );
        // No sq supplied: stage posts fall back to the group rule.
        assert_eq!(
            post_format(PostQuant::Stage { index: 1, group: Some(0) }, &dfmt, None),
            Some(QFormat::new(8, 2))
        );
        assert_eq!(post_format(PostQuant::Stage { index: 0, group: None }, &dfmt, None), None);
    }

    #[test]
    fn inception_scratch_sizing() {
        let arch = arch::get("googlenet").unwrap();
        let plan = LoweredPlan::new(&arch, None).unwrap();
        // i3a at 8x8x32: pool branch needs an 8*8*32 pooled copy.
        assert!(plan.max_tmp_elems >= 8 * 8 * 32);
        assert!(plan.max_col_elems > 0);
    }

    #[test]
    fn lenet_fused_sizing_by_hand() {
        let arch = arch::get("lenet").unwrap();
        let plan = LoweredPlan::new(&arch, None).unwrap();
        // Largest decode window: the L3 fc reads its whole flattened
        // input (Flatten keeps the bitstream packed), 4*4*16 = 256 —
        // bigger than any conv row (28) or 1x1 block (none in lenet).
        assert_eq!(plan.max_win_elems, 256);
        // Largest im2col row window: the L2 conv reads 12x12x8 rows.
        assert_eq!(plan.max_row_win_elems, 12 * 8);
        // No 1x1 conv streams multiple A blocks -> no strip cache.
        assert_eq!(plan.strip_cache_elems, 0);
        // The windows term: threads=1 prices no extra row windows.
        assert_eq!(plan.fused_window_elems(1), 256 + plan.max_bias_elems);
        assert_eq!(plan.fused_window_elems(4), 256 + 3 * 96 + plan.max_bias_elems);
        // Largest fused working set: the L1 maxpool carries its f32
        // conv input (24*24*8) plus its own output (12*12*8).
        assert_eq!(plan.max_fused_elems, 24 * 24 * 8 + 12 * 12 * 8);
        // The windows are far below the full arenas the f32 path keeps.
        assert!(plan.max_win_elems < plan.max_act_elems / 4);
        assert!(plan.max_fused_elems < 2 * plan.max_act_elems);
    }

    #[test]
    fn lenet_gemm_param_accounting_by_hand() {
        let arch = arch::get("lenet").unwrap();
        let plan = LoweredPlan::new(&arch, None).unwrap();
        // L1 conv 5x5x1 -> 8 filters: kd=25, n=8 padded to 16 lanes;
        // L2 conv 5x5x8 -> 16: no padding; L3 fc 256 -> 64: no padding;
        // L4 fc 64 -> 10 padded to 16.
        assert_eq!(plan.weight_pad_elems, vec![(16 - 8) * 25, 0, 0, (16 - 10) * 64]);
        assert_eq!(plan.panel_param_elems, 16 * 25 + 16 * 200 + 64 * 256 + 16 * 64);
        assert_eq!(plan.bias_param_elems, 8 + 16 + 64 + 10);
        assert_eq!(plan.max_bias_elems, 64);
    }

    #[test]
    fn gemm_param_accounting_covers_every_arch() {
        for name in arch::NET_ORDER {
            let a = arch::get(name).unwrap();
            let plan = LoweredPlan::new(&a, None).unwrap();
            assert_eq!(plan.weight_pad_elems.len(), plan.n_layers, "{name}");
            assert!(plan.panel_param_elems > 0, "{name}");
            assert!(plan.bias_param_elems > 0, "{name}");
            assert!(plan.max_bias_elems > 0, "{name}");
            // Padding is what the panel layout adds beyond true weight
            // elements — it can never exceed the panels themselves.
            let pad: usize = plan.weight_pad_elems.iter().sum();
            assert!(pad < plan.panel_param_elems, "{name}");
        }
    }

    #[test]
    fn fused_sizing_bounded_on_every_arch() {
        for name in arch::NET_ORDER {
            let a = arch::get(name).unwrap();
            let plan = LoweredPlan::new(&a, None).unwrap();
            assert!(plan.max_win_elems > 0, "{name}");
            assert!(plan.max_win_elems <= plan.max_act_elems, "{name}");
            assert!(plan.max_row_win_elems <= plan.max_win_elems, "{name}");
            assert!(plan.strip_cache_elems <= STRIP_CACHE_CAP, "{name}");
            assert!(
                plan.fused_window_elems(1)
                    == plan.max_win_elems + plan.max_bias_elems + plan.strip_cache_elems,
                "{name}"
            );
            // No single step's fused f32 working set reaches the two
            // max-sized arenas of the default path — the source of the
            // measured residency reduction.
            assert!(plan.max_fused_elems < 2 * plan.max_act_elems, "{name}");
        }
        // googlenet: the widest inception input (i3b at 8x8 over 40
        // channels) is staged whole for its four branch readers.
        let plan = LoweredPlan::new(&arch::get("googlenet").unwrap(), None).unwrap();
        assert!(plan.max_win_elems >= 8 * 8 * 40);
    }
}
