//! The **fast backend**: im2col + blocked-GEMM execution with
//! multi-threaded batched inference.
//!
//! Same semantics as the reference interpreter — both executors consume
//! the one [`LoweredPlan`](super::lowering::LoweredPlan), so quantization
//! placement is shared by construction — but the compute path is built
//! for throughput:
//!
//! * every `Op::Conv` lowers to im2col patch extraction followed by the
//!   cache-blocked, register-tiled GEMM in [`super::gemm`] (`Op::Dense`
//!   is the degenerate `M = 1` GEMM; 1×1 stride-1 convs skip im2col and
//!   feed the activation matrix to the GEMM directly),
//! * the GEMM `B` operand (each layer's weights) is repacked into
//!   NR-column panels **once per weight config** and memoized alongside
//!   the quantized weights ([`FastWeights`]) — an eval sweeps thousands
//!   of batches under one config, so the panel build amortizes to zero
//!   and every `infer` reads contiguous B lanes,
//! * per-thread scratch arenas hold the im2col matrix, the ping-pong
//!   activation buffers and the inception temporaries — sized once at
//!   load from the plan's high-water marks and reused across `infer`
//!   calls, so the steady state allocates nothing,
//! * two-level `std::thread::scope` parallelism: images are split over
//!   worker threads within a batch, and when the batch is narrower than
//!   the thread budget the leftover threads split GEMM row blocks *and*
//!   im2col row blocks within a layer. Thread count comes from
//!   `QBOUND_THREADS` (default: available parallelism); results are
//!   bit-identical for every thread count.
//!
//! With `--storage packed` ([`StorageMode::Packed`]) the executor runs
//! the **fused** forward path: between layers only
//! [`PackedBuf`](crate::memory::PackedBuf) bitstreams persist, at the
//! boundary format's width. The max-sized ping-pong f32 arenas are not
//! allocated at all — consumers decode windows of the input bitstream
//! on the fly (im2col pulls one input row at a time, 1×1-conv/dense
//! GEMMs stream `A` row blocks through a
//! [`PackedCursor`](crate::memory::PackedCursor), inception stages its
//! module input once for its four branch readers) and each step's f32
//! output lives only until it is packed at the next boundary. The
//! **weights** are packed the same way: every parameter tensor is
//! resident only as a bitstream at its group's weight width — GEMM
//! weights in the NR-lane panel layout
//! ([`PackedPanels`](crate::memory::PackedPanels)), decoded one `KC`
//! strip at a time into a per-thread tile inside the GEMM
//! ([`super::gemm::gemm_bias_bits`]), biases decoded into a small
//! scratch window per step — so the resident weight bytes match the
//! modeled footprint instead of staying f32. Results stay numerically
//! identical to the default in-f32 path
//! (`tests/integration_storage.rs`), and the residency claim is
//! measured by `tests/integration_memory.rs` under a counting
//! allocator. The fused path trades the zero-allocation steady state of
//! the f32 path for minimal residency: per-step working vectors are
//! allocated fresh so the resident set really is bitstreams + windows.
//! Three decode-side optimizations keep that residency cheap, each
//! priced into the plan's fused envelope
//! ([`LoweredPlan::fused_window_elems`]): every bit-field span decode
//! goes through the dispatched SIMD unpacker
//! ([`super::kernels::unpack_span`]), single-threaded streamed 1×1
//! GEMMs memoize decoded weight strips across `A` row blocks in a
//! bounded per-executor [`StripCache`], and the packed im2col splits
//! output-row blocks across threads with a private one-row decode
//! window each — all bit-identical to their serial/scalar forms.
//!
//! Numeric contract: agreement with the reference backend up to fp32
//! accumulation order (see `tests/integration_parity.rs`). The GEMM
//! preserves the interpreter's ascending-`k` accumulation, so in
//! practice the two backends differ at most in the sign of zeros
//! (im2col materializes padding as `0.0` where the interpreter skips
//! out-of-bounds taps).

use std::sync::Arc;

use anyhow::Result;

use super::gemm::{gemm_bias_b, gemm_bias_bits_cached, pack_b_panels, GemmB, StripCache, NR};
use super::lowering::{self, LoweredPlan};
use super::reference::{avgpool_into, gap_into, lrn_into, maxpool_into};
use super::{Backend, NetExecutor, Variant};
use crate::memory::{PackedBuf, PackedCursor, PackedPanels, StorageMode};
use crate::nets::arch::{conv_out_hw, same_pad_before, Op, Padding, Shape};
use crate::nets::NetManifest;
use crate::quant::QFormat;
use crate::store::Store;

/// Worker-thread budget: `QBOUND_THREADS`, defaulting to available
/// parallelism. `0`/garbage is an error (not a silent fallback).
pub fn threads_from_env() -> Result<usize> {
    match std::env::var("QBOUND_THREADS") {
        Ok(s) if !s.trim().is_empty() => match s.trim().parse::<usize>() {
            Ok(n) if n > 0 => Ok(n),
            _ => anyhow::bail!("QBOUND_THREADS must be a positive integer, got {s:?}"),
        },
        _ => Ok(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)),
    }
}

/// Factory for [`FastExecutor`]s.
#[derive(Clone, Debug)]
pub struct FastBackend {
    threads: usize,
    storage: StorageMode,
    /// Packed-weight store executors load/publish bitstreams through
    /// (`--storage packed` only); `None` = always pack locally.
    store: Option<Arc<Store>>,
}

impl FastBackend {
    /// Thread budget, storage mode, packed-weight store and kernel
    /// dispatch from the environment (`QBOUND_THREADS`,
    /// `QBOUND_STORAGE`, `QBOUND_STORE_DIR`, `QBOUND_KERNEL`).
    /// Resolving the kernel here surfaces a misconfigured
    /// `QBOUND_KERNEL` as a clean load-time error and emits the
    /// one-time dispatch log before any compute runs.
    pub fn new() -> Result<FastBackend> {
        super::kernels::init()?;
        Ok(FastBackend {
            threads: threads_from_env()?,
            storage: StorageMode::from_env()?,
            store: Store::from_env(),
        })
    }

    /// Explicit thread budget, default f32 storage (tests, embedding).
    pub fn with_threads(threads: usize) -> FastBackend {
        FastBackend::with_options(threads, StorageMode::F32)
    }

    /// Fully explicit construction (no store; see
    /// [`FastBackend::with_store`]).
    pub fn with_options(threads: usize, storage: StorageMode) -> FastBackend {
        FastBackend { threads: threads.max(1), storage, store: None }
    }

    /// Attach (or detach) a packed-weight store. The explicit value is
    /// final — it overrides whatever `QBOUND_STORE_DIR` said at
    /// construction, which is how the serve daemon pins every worker to
    /// the `--store-dir` it was started with.
    pub fn with_store(mut self, store: Option<Arc<Store>>) -> FastBackend {
        self.store = store;
        self
    }
}

impl Backend for FastBackend {
    fn name(&self) -> &'static str {
        "fast"
    }

    fn load(&self, manifest: &NetManifest, variant: Variant) -> Result<Box<dyn NetExecutor>> {
        let net = lowering::load_network(manifest, variant)?;
        let plan = LoweredPlan::new(&net.arch, net.stage_group)?;
        Ok(Box::new(FastExecutor {
            manifest: manifest.clone(),
            variant,
            plan,
            params: net.params,
            weights: FastWeights::new(self.storage),
            scratch: Vec::new(),
            threads: self.threads,
            storage: self.storage,
            store: self.store.clone(),
            executions: 0,
        }))
    }
}

/// One loaded network on the fast backend.
pub struct FastExecutor {
    manifest: NetManifest,
    variant: Variant,
    plan: LoweredPlan,
    /// Flat fp32 parameter list, init order.
    params: Vec<Vec<f32>>,
    weights: FastWeights,
    /// One arena per image-level worker, grown on first use and reused
    /// across `infer` calls.
    scratch: Vec<Scratch>,
    threads: usize,
    storage: StorageMode,
    /// Packed-weight store rebuilds go through (None = pack locally).
    store: Option<Arc<Store>>,
    executions: u64,
}

impl NetExecutor for FastExecutor {
    fn manifest(&self) -> &NetManifest {
        &self.manifest
    }

    fn variant(&self) -> Variant {
        self.variant
    }

    fn executions(&self) -> u64 {
        self.executions
    }

    fn max_batch(&self) -> usize {
        usize::MAX
    }

    fn infer(
        &mut self,
        images: &[f32],
        wq: &[f32],
        dq: &[f32],
        sq: Option<&[f32]>,
    ) -> Result<Vec<f32>> {
        let req = lowering::decode_request(&self.manifest, self.variant, images, wq, dq, sq)?;
        let batch = req.batch;
        let wts = self.weights.view(&self.plan, &self.params, &req.wfmt, self.store.as_deref());

        let elems = self.plan.input_elems();
        let classes = self.plan.num_classes;
        // Image-level workers first; leftover budget goes to GEMM row
        // blocks inside each worker's layers.
        let outer = self.threads.min(batch).max(1);
        let inner = (self.threads / outer).max(1);
        while self.scratch.len() < outer {
            self.scratch.push(Scratch::new(&self.plan, self.storage));
        }

        let mut out = vec![0f32; batch * classes];
        let plan = &self.plan;
        let dfmt = &req.dfmt;
        let sfmt = req.sfmt.as_deref();
        let storage = self.storage;
        if outer == 1 {
            let scr = &mut self.scratch[0];
            for i in 0..batch {
                dispatch_image(
                    plan,
                    wts,
                    &images[i * elems..(i + 1) * elems],
                    dfmt,
                    sfmt,
                    storage,
                    scr,
                    inner,
                    &mut out[i * classes..(i + 1) * classes],
                );
            }
        } else {
            let per = (batch + outer - 1) / outer;
            std::thread::scope(|s| {
                let mut img_rest = images;
                let mut out_rest: &mut [f32] = &mut out;
                for scr in self.scratch[..outer].iter_mut() {
                    let n_here = per.min(img_rest.len() / elems);
                    if n_here == 0 {
                        break;
                    }
                    let (imgs, ir) = img_rest.split_at(n_here * elems);
                    let (rows, or) = std::mem::take(&mut out_rest).split_at_mut(n_here * classes);
                    img_rest = ir;
                    out_rest = or;
                    s.spawn(move || {
                        for i in 0..n_here {
                            dispatch_image(
                                plan,
                                wts,
                                &imgs[i * elems..(i + 1) * elems],
                                dfmt,
                                sfmt,
                                storage,
                                scr,
                                inner,
                                &mut rows[i * classes..(i + 1) * classes],
                            );
                        }
                    });
                }
            });
        }
        self.executions += 1;
        Ok(out)
    }
}

/// Weight state memoized per weight config, in the representation the
/// executor's storage mode calls for. Rebuilt only when the weight
/// config changes (an eval sweeps many batches under one config).
enum FastWeights {
    /// Default mode: quantized f32 tensors plus, for every tensor
    /// consumed as a GEMM `B`, its [`pack_b_panels`] layout — the
    /// ROADMAP "pack the B panel once per weight config" item.
    F32 {
        cached_wq: Vec<QFormat>,
        qparams: Vec<Vec<f32>>,
        /// Indexed like `qparams`; `None` for biases / non-GEMM tensors.
        panels: Vec<Option<Vec<f32>>>,
    },
    /// `--storage packed`: every tensor resident only as a bitstream at
    /// its group's weight width — the realized weight half of the
    /// memory bound.
    Packed(PackedWeights),
}

impl FastWeights {
    fn new(storage: StorageMode) -> FastWeights {
        match storage {
            StorageMode::F32 => FastWeights::F32 {
                cached_wq: Vec::new(),
                qparams: Vec::new(),
                panels: Vec::new(),
            },
            StorageMode::Packed => FastWeights::Packed(PackedWeights::default()),
        }
    }

    /// The weight view for `wfmt`, rebuilt only when the config
    /// changes. `store` (packed mode only) turns a rebuild into a
    /// load-or-pack against the content-addressed store — a warm store
    /// makes it a pure mmap share; f32 mode has no bitstream to share
    /// and ignores it.
    fn view(
        &mut self,
        plan: &LoweredPlan,
        params: &[Vec<f32>],
        wfmt: &[QFormat],
        store: Option<&Store>,
    ) -> WView<'_> {
        match self {
            FastWeights::F32 { cached_wq, qparams, panels } => {
                if cached_wq != wfmt {
                    *qparams = plan.quantize_params(params, wfmt);
                    *panels = pack_plan_panels(plan, qparams);
                    // The panel is now the only consumer of each GEMM
                    // weight tensor — drop the flat quantized copy so
                    // resident weight memory isn't doubled (biases keep
                    // theirs).
                    for (q, p) in qparams.iter_mut().zip(panels.iter()) {
                        if p.is_some() {
                            *q = Vec::new();
                        }
                    }
                    *cached_wq = wfmt.to_vec();
                }
                WView::F32 { qparams: &*qparams, panels: &*panels }
            }
            FastWeights::Packed(w) => {
                if w.cached_wq != wfmt {
                    w.rebuild(plan, params, wfmt, store);
                }
                WView::Packed(w)
            }
        }
    }
}

/// One parameter tensor resident as a bitstream: a GEMM weight in the
/// [`pack_b_panels`] layout (the [`PackedPanels`] carries its pack-time
/// format) or a bias as a plain [`PackedBuf`] paired with its format.
enum PackedTensor {
    Gemm(PackedPanels),
    Bias(PackedBuf, QFormat),
}

/// Every parameter tensor as a bitstream at its group's weight width,
/// one [`PackedTensor`] per parameter in init order. Each entry carries
/// its own decode format, so there is no parallel format vector to
/// drift out of sync with the bitstreams.
#[derive(Default)]
struct PackedWeights {
    cached_wq: Vec<QFormat>,
    tensors: Vec<PackedTensor>,
}

impl PackedWeights {
    fn rebuild(
        &mut self,
        plan: &LoweredPlan,
        params: &[Vec<f32>],
        wfmt: &[QFormat],
        store: Option<&Store>,
    ) {
        let fmts = plan.per_tensor_formats(wfmt);
        let mut gemm_shape: Vec<Option<(usize, usize)>> = vec![None; params.len()];
        for t in lowering::gemm_tensors(&plan.steps) {
            gemm_shape[t.param] = Some((t.kd, t.n));
        }
        // Packing *is* the quantizer (pack→decode equals
        // `quantize_slice` modulo the single two's-complement zero), so
        // the raw fp32 tensors pack directly — no transient quantized
        // copy is built. With a store attached, each tensor resolves
        // content-addressed first: an existing valid file is mmap'd and
        // shared (zero pack work, zero marginal resident bytes within
        // the process); only genuinely new (tensor, layout, format)
        // keys pack — and then publish for the next loader. The decode
        // paths see identical bitstream words either way, so logits are
        // bit-identical with or without the store.
        self.tensors = params
            .iter()
            .enumerate()
            .map(|(i, p)| match gemm_shape[i] {
                Some((kd, n)) => {
                    let pack = || PackedPanels::pack(fmts[i], &pack_b_panels(p, kd, n), kd, NR);
                    PackedTensor::Gemm(match store {
                        Some(s) => s.panels_for(p, fmts[i], kd, n, NR, pack),
                        None => pack(),
                    })
                }
                None => {
                    let pack = || PackedBuf::pack(fmts[i], p);
                    let buf = match store {
                        Some(s) => s.buf_for(p, fmts[i], pack),
                        None => pack(),
                    };
                    PackedTensor::Bias(buf, fmts[i])
                }
            })
            .collect();
        self.cached_wq = wfmt.to_vec();
    }

    /// Resident payload bytes of the packed weight set.
    fn resident_bytes(&self) -> usize {
        self.tensors
            .iter()
            .map(|t| match t {
                PackedTensor::Gemm(p) => p.packed_bytes(),
                PackedTensor::Bias(b, _) => b.packed_bytes(),
            })
            .sum()
    }
}

/// Resident bytes of the packed weight set (panel bitstreams including
/// the NR-lane zero padding, plus bias bitstreams) a fused executor
/// memoizes for `wfmt` — the realized weight half of the memory bound,
/// asserted against the f32 weight bytes and the
/// [`FootprintModel`](crate::memory::FootprintModel) weight term by
/// `tests/integration_memory.rs` and reported by `qbound eval
/// --mem-json`.
pub fn packed_weight_bytes(plan: &LoweredPlan, params: &[Vec<f32>], wfmt: &[QFormat]) -> usize {
    let mut w = PackedWeights::default();
    w.rebuild(plan, params, wfmt, None);
    w.resident_bytes()
}

/// Borrowed weight state for one `infer`: resolves parameter indices to
/// GEMM `B` operands and bias slices regardless of representation.
#[derive(Clone, Copy)]
enum WView<'a> {
    F32 {
        qparams: &'a [Vec<f32>],
        panels: &'a [Option<Vec<f32>>],
    },
    Packed(&'a PackedWeights),
}

impl<'a> WView<'a> {
    /// The GEMM `B` operand of parameter `i` (always present for
    /// tensors the plan consumes as a GEMM `B`).
    fn gemm_b(self, i: usize) -> GemmB<'a> {
        match self {
            WView::F32 { panels, .. } => {
                GemmB::Panels(panels[i].as_deref().expect("GEMM weight panel"))
            }
            WView::Packed(w) => match &w.tensors[i] {
                PackedTensor::Gemm(p) => GemmB::Bits(p),
                PackedTensor::Bias(..) => unreachable!("parameter {i} is a bias"),
            },
        }
    }

    /// The bias values of parameter `i`: a direct borrow in f32 mode,
    /// decoded into `buf` (the scratch bias window) in packed mode.
    fn bias<'b>(self, i: usize, buf: &'b mut Vec<f32>) -> &'b [f32]
    where
        'a: 'b,
    {
        match self {
            WView::F32 { qparams, .. } => &qparams[i],
            WView::Packed(w) => match &w.tensors[i] {
                PackedTensor::Bias(b, fmt) => {
                    buf.resize(b.len(), 0.0);
                    b.unpack_into(*fmt, buf);
                    buf
                }
                PackedTensor::Gemm(_) => unreachable!("parameter {i} is a GEMM weight"),
            },
        }
    }
}

/// Build the packed B panel for every GEMM weight tensor of the plan
/// (the shared [`lowering::gemm_tensors`] walk).
fn pack_plan_panels(plan: &LoweredPlan, qparams: &[Vec<f32>]) -> Vec<Option<Vec<f32>>> {
    let mut panels: Vec<Option<Vec<f32>>> = vec![None; qparams.len()];
    for t in lowering::gemm_tensors(&plan.steps) {
        panels[t.param] = Some(pack_b_panels(&qparams[t.param], t.kd, t.n));
    }
    panels
}

/// Per-worker arena: all per-layer buffers, allocated once. The f32
/// ping-pong arenas exist only in [`StorageMode::F32`]; the fused
/// packed path replaces them with the streaming decode window plus two
/// reusable boundary bitstreams — that swap *is* the measured residency
/// reduction.
struct Scratch {
    /// Ping-pong activation buffers (f32 storage mode only).
    act_a: Vec<f32>,
    act_b: Vec<f32>,
    /// im2col patch matrix.
    col: Vec<f32>,
    /// Inception temporaries (reduce outputs / pooled input).
    tmp: Vec<f32>,
    /// Streaming decode window (fused packed mode only).
    win: Vec<f32>,
    /// Bias decode window (fused packed mode only — f32 mode borrows
    /// biases straight from the quantized tensors).
    bias: Vec<f32>,
    /// Decoded-strip cache for streamed packed-B GEMMs (fused packed
    /// mode only; capacity comes from the plan, so it is priced into
    /// the fused envelope — 0 on plans with no streamed 1×1 conv).
    strip: StripCache,
    /// Ping-pong boundary bitstreams (fused packed mode only).
    pk_in: PackedBuf,
    pk_out: PackedBuf,
}

impl Scratch {
    fn new(plan: &LoweredPlan, storage: StorageMode) -> Scratch {
        let fused = storage == StorageMode::Packed;
        let act = if fused { 0 } else { plan.max_act_elems };
        Scratch {
            act_a: vec![0f32; act],
            act_b: vec![0f32; act],
            col: vec![0f32; plan.max_col_elems],
            tmp: vec![0f32; plan.max_tmp_elems],
            win: vec![0f32; if fused { plan.max_win_elems } else { 0 }],
            bias: Vec::with_capacity(if fused { plan.max_bias_elems } else { 0 }),
            strip: StripCache::new(if fused { plan.strip_cache_elems } else { 0 }),
            pk_in: PackedBuf::default(),
            pk_out: PackedBuf::default(),
        }
    }
}

/// Run one image under the executor's storage mode: the arena-based
/// in-f32 path, or the fused bitstream path.
fn dispatch_image(
    plan: &LoweredPlan,
    wts: WView,
    image: &[f32],
    dfmt: &[QFormat],
    sfmt: Option<&[QFormat]>,
    storage: StorageMode,
    scr: &mut Scratch,
    threads: usize,
    out_row: &mut [f32],
) {
    match storage {
        StorageMode::F32 => forward_image(plan, wts, image, dfmt, sfmt, scr, threads, out_row),
        StorageMode::Packed => {
            forward_image_fused(plan, wts, image, dfmt, sfmt, scr, threads, out_row)
        }
    }
}

/// Forward one image through the lowered plan. Infallible: the plan's
/// shape chain was validated at load time.
fn forward_image(
    plan: &LoweredPlan,
    wts: WView,
    image: &[f32],
    dfmt: &[QFormat],
    sfmt: Option<&[QFormat]>,
    scr: &mut Scratch,
    threads: usize,
    out_row: &mut [f32],
) {
    let Scratch { act_a, act_b, col, tmp, bias, .. } = scr;
    let (mut src, mut dst) = (&mut act_a[..], &mut act_b[..]);
    src[..image.len()].copy_from_slice(image);
    dfmt[0].quantize_slice(&mut src[..image.len()]);

    for step in &plan.steps {
        let t_obs = crate::obs::step_start();
        let in_e = step.in_shape.elems();
        let out_e = step.out_shape.elems();
        let base = step.param_base;
        match (&step.op, step.in_shape) {
            (&Op::Conv { out_c, k, stride, padding, .. }, Shape::Hwc(h, w, c)) => {
                let bs = wts.bias(base + 1, bias);
                conv_gemm(
                    &src[..in_e],
                    h,
                    w,
                    c,
                    wts.gemm_b(base),
                    bs,
                    out_c,
                    k,
                    stride,
                    padding,
                    col,
                    &mut dst[..out_e],
                    out_c,
                    0,
                    threads,
                );
                std::mem::swap(&mut src, &mut dst);
            }
            (&Op::Dense { out, .. }, Shape::Flat(n)) => {
                let bs = wts.bias(base + 1, bias);
                gemm_bias_b(
                    1,
                    out,
                    n,
                    &src[..n],
                    n,
                    wts.gemm_b(base),
                    bs,
                    &mut dst[..out],
                    out,
                    threads,
                );
                std::mem::swap(&mut src, &mut dst);
            }
            (Op::ReLU, _) => relu(&mut src[..in_e]),
            (&Op::MaxPool { k, stride }, Shape::Hwc(h, w, c)) => {
                maxpool_into(&src[..in_e], h, w, c, k, stride, &mut dst[..out_e]);
                std::mem::swap(&mut src, &mut dst);
            }
            (&Op::AvgPool { k, stride }, Shape::Hwc(h, w, c)) => {
                avgpool_into(&src[..in_e], h, w, c, k, stride, &mut dst[..out_e]);
                std::mem::swap(&mut src, &mut dst);
            }
            (Op::GlobalAvgPool, Shape::Hwc(h, w, c)) => {
                gap_into(&src[..in_e], h, w, c, &mut dst[..c]);
                std::mem::swap(&mut src, &mut dst);
            }
            (&Op::Lrn { n, alpha, beta }, Shape::Hwc(h, w, c)) => {
                lrn_into(&src[..in_e], h, w, c, n, alpha, beta, &mut dst[..out_e]);
                std::mem::swap(&mut src, &mut dst);
            }
            (Op::Flatten | Op::Dropout, _) => {}
            (op @ Op::Inception { .. }, Shape::Hwc(h, w, c)) => {
                inception_gemm(
                    op,
                    &src[..in_e],
                    h,
                    w,
                    c,
                    wts,
                    base,
                    col,
                    tmp,
                    bias,
                    &mut dst[..out_e],
                    threads,
                );
                std::mem::swap(&mut src, &mut dst);
            }
            (op, s) => unreachable!("lowered plan let op {op:?} reach shape {s:?}"),
        }
        if let Some(fmt) = lowering::post_format(step.post, dfmt, sfmt) {
            fmt.quantize_slice(&mut src[..out_e]);
        }
        crate::obs::step_end(t_obs, plan.name, step.group, "f32", || {
            format!(
                "net={} op={} kind={} in={:?} out={:?} dq={} kernel={}",
                plan.name,
                step.op.stage_name(),
                step.op.kind(),
                step.in_shape,
                step.out_shape,
                dfmt[step.group],
                super::kernels::active_kind().label(),
            )
        });
    }
    out_row.copy_from_slice(&src[..plan.num_classes]);
}

/// The fused packed forward: between steps the activation is either a
/// boundary bitstream (`pk_in`, at `cur_fmt`) or an unquantized
/// intra-group f32 tensor (`cur`). Consumers decode what they need from
/// the bitstream — nothing else of the input exists in f32 — and every
/// step's output vector is freed as soon as it is packed at the next
/// boundary. Values are identical to [`forward_image`] because
/// pack→decode is exactly the quantizer (modulo `-0.0` → `+0.0`, which
/// the storage-parity suite shows the forward pass cannot distinguish).
fn forward_image_fused(
    plan: &LoweredPlan,
    wts: WView,
    image: &[f32],
    dfmt: &[QFormat],
    sfmt: Option<&[QFormat]>,
    scr: &mut Scratch,
    threads: usize,
    out_row: &mut [f32],
) {
    let Scratch { col, tmp, win, bias, strip, pk_in, pk_out, .. } = scr;
    let (mut pk_in, mut pk_out) = (pk_in, pk_out);
    pk_in.pack_into(dfmt[0], image);
    let mut cur_fmt = dfmt[0];
    // `None` = the activation lives only in `pk_in`.
    let mut cur: Option<Vec<f32>> = None;

    for step in &plan.steps {
        let t_obs = crate::obs::step_start();
        let in_e = step.in_shape.elems();
        let out_e = step.out_shape.elems();
        let base = step.param_base;
        match (&step.op, step.in_shape) {
            // Shape-only: whichever representation is current passes
            // through untouched (a packed boundary stays packed).
            (Op::Flatten | Op::Dropout, _) => {}
            (Op::ReLU, _) => {
                if let Some(v) = &mut cur {
                    relu(&mut v[..in_e]);
                } else {
                    // Stage-granularity boundaries can precede any op:
                    // materialize, then proceed in f32.
                    let mut v = vec![0f32; in_e];
                    pk_in.unpack_into(cur_fmt, &mut v);
                    relu(&mut v);
                    cur = Some(v);
                }
            }
            (&Op::Conv { out_c, k, stride, padding, .. }, Shape::Hwc(h, w, c)) => {
                let mut next = vec![0f32; out_e];
                let bs = wts.bias(base + 1, bias);
                match cur.take() {
                    Some(v) => conv_gemm(
                        &v[..in_e],
                        h,
                        w,
                        c,
                        wts.gemm_b(base),
                        bs,
                        out_c,
                        k,
                        stride,
                        padding,
                        col,
                        &mut next,
                        out_c,
                        0,
                        threads,
                    ),
                    None => conv_from_packed(
                        pk_in,
                        cur_fmt,
                        h,
                        w,
                        c,
                        wts.gemm_b(base),
                        bs,
                        out_c,
                        k,
                        stride,
                        padding,
                        win,
                        col,
                        strip,
                        &mut next,
                        threads,
                    ),
                }
                cur = Some(next);
            }
            (&Op::Dense { out, .. }, Shape::Flat(n)) => {
                let mut next = vec![0f32; out];
                let bs = wts.bias(base + 1, bias);
                let a: &[f32] = match &cur {
                    Some(v) => &v[..n],
                    None => {
                        pk_in.unpack_into(cur_fmt, &mut win[..n]);
                        &win[..n]
                    }
                };
                gemm_bias_b(1, out, n, a, n, wts.gemm_b(base), bs, &mut next, out, threads);
                cur = Some(next);
            }
            (op @ Op::Inception { .. }, Shape::Hwc(h, w, c)) => {
                let mut next = vec![0f32; out_e];
                let x: &[f32] = match &cur {
                    Some(v) => &v[..in_e],
                    None => {
                        // Four branches each re-read the module input:
                        // stage it once in the decode window.
                        pk_in.unpack_into(cur_fmt, &mut win[..in_e]);
                        &win[..in_e]
                    }
                };
                inception_gemm(op, x, h, w, c, wts, base, col, tmp, bias, &mut next, threads);
                cur = Some(next);
            }
            (op, in_shape) => {
                // Pools / LRN / GAP: intra-group f32 consumers (with the
                // stage-variant materialize fallback).
                let v = match cur.take() {
                    Some(v) => v,
                    None => {
                        let mut v = vec![0f32; in_e];
                        pk_in.unpack_into(cur_fmt, &mut v);
                        v
                    }
                };
                let mut next = vec![0f32; out_e];
                match (op, in_shape) {
                    (&Op::MaxPool { k, stride }, Shape::Hwc(h, w, c)) => {
                        maxpool_into(&v[..in_e], h, w, c, k, stride, &mut next)
                    }
                    (&Op::AvgPool { k, stride }, Shape::Hwc(h, w, c)) => {
                        avgpool_into(&v[..in_e], h, w, c, k, stride, &mut next)
                    }
                    (Op::GlobalAvgPool, Shape::Hwc(h, w, c)) => {
                        gap_into(&v[..in_e], h, w, c, &mut next)
                    }
                    (&Op::Lrn { n, alpha, beta }, Shape::Hwc(h, w, c)) => {
                        lrn_into(&v[..in_e], h, w, c, n, alpha, beta, &mut next)
                    }
                    (op, s) => unreachable!("fused plan let op {op:?} reach shape {s:?}"),
                }
                cur = Some(next);
            }
        }
        if let Some(fmt) = lowering::post_format(step.post, dfmt, sfmt) {
            match cur.take() {
                Some(v) => pk_out.pack_into(fmt, &v[..out_e]),
                None => {
                    // Boundary straight after pass-through ops:
                    // re-quantize through f32, exactly as the in-f32
                    // path would.
                    let mut v = vec![0f32; out_e];
                    pk_in.unpack_into(cur_fmt, &mut v);
                    pk_out.pack_into(fmt, &v);
                }
            }
            std::mem::swap(&mut pk_in, &mut pk_out);
            cur_fmt = fmt;
        }
        crate::obs::step_end(t_obs, plan.name, step.group, "packed", || {
            format!(
                "net={} op={} kind={} in={:?} out={:?} dq={} kernel={}",
                plan.name,
                step.op.stage_name(),
                step.op.kind(),
                step.in_shape,
                step.out_shape,
                dfmt[step.group],
                super::kernels::active_kind().label(),
            )
        });
    }
    match cur {
        Some(v) => out_row.copy_from_slice(&v[..plan.num_classes]),
        None => pk_in.unpack_into(cur_fmt, out_row),
    }
}

fn relu(xs: &mut [f32]) {
    for v in xs {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// ReLU over an `m`×`n` region at column `off` of a row-stride-`ldc`
/// buffer (inception branches live in their concat columns).
fn relu_strided(buf: &mut [f32], m: usize, n: usize, ldc: usize, off: usize) {
    for r in 0..m {
        relu(&mut buf[r * ldc + off..][..n]);
    }
}

/// NHWC conv as (im2col ·) GEMM over a pre-packed weight panel operand
/// (f32 panels or a weight bitstream), writing `(oh*ow, out_c)` rows
/// into `dst` at column `dst_off` with row stride `ldc`.
fn conv_gemm(
    x: &[f32],
    h: usize,
    w: usize,
    c: usize,
    wgt: GemmB,
    bias: &[f32],
    out_c: usize,
    k: usize,
    stride: usize,
    padding: Padding,
    col: &mut [f32],
    dst: &mut [f32],
    ldc: usize,
    dst_off: usize,
    threads: usize,
) {
    let (oh, ow) = conv_out_hw(h, w, k, stride, padding);
    let m = oh * ow;
    if k == 1 && stride == 1 {
        // 1×1 stride-1: the activation matrix (h*w, c) is already the
        // patch matrix — skip im2col (the NIN cccp / inception-reduce
        // hot case).
        gemm_bias_b(m, out_c, c, x, c, wgt, bias, &mut dst[dst_off..], ldc, threads);
        return;
    }
    let (pad_y, pad_x) = match padding {
        Padding::Same => (same_pad_before(h, oh, k, stride), same_pad_before(w, ow, k, stride)),
        Padding::Valid => (0, 0),
    };
    let kd = k * k * c;
    im2col(x, h, w, c, k, stride, pad_y, pad_x, oh, ow, &mut col[..m * kd], threads);
    gemm_bias_b(m, out_c, kd, &col[..m * kd], kd, wgt, bias, &mut dst[dst_off..], ldc, threads);
}

/// NHWC conv reading its input straight off a boundary bitstream: the
/// fused-consumer form of [`conv_gemm`]. 1×1 stride-1 convs stream GEMM
/// `A` row blocks through a [`PackedCursor`] — with a bitstream `B`
/// operand the row blocks share `cache`, so each weight strip is
/// decoded once per conv instead of once per block. Everything else
/// builds the im2col patch matrix from one decoded input row at a time
/// ([`im2col_from_packed`]). Output writes are the same GEMM as the
/// in-f32 path, so results are bit-identical to running [`conv_gemm`]
/// over a fully unpacked input.
fn conv_from_packed(
    p: &PackedBuf,
    fmt: QFormat,
    h: usize,
    w: usize,
    c: usize,
    wgt: GemmB,
    bias: &[f32],
    out_c: usize,
    k: usize,
    stride: usize,
    padding: Padding,
    win: &mut [f32],
    col: &mut [f32],
    cache: &mut StripCache,
    dst: &mut [f32],
    threads: usize,
) {
    let (oh, ow) = conv_out_hw(h, w, k, stride, padding);
    let m = oh * ow;
    if k == 1 && stride == 1 {
        // The activation matrix (h*w, c) is the GEMM A; decode and
        // multiply one row block at a time. Each output row's
        // accumulation is independent and unchanged, so splitting M is
        // bit-identical to one whole-matrix call.
        let mut cursor = PackedCursor::new(p, fmt);
        let mut r0 = 0usize;
        while r0 < m {
            let rb = lowering::FUSED_A_ROWS.min(m - r0);
            let a = &mut win[..rb * c];
            cursor.read_into(a);
            match wgt {
                GemmB::Bits(bp) => gemm_bias_bits_cached(
                    rb,
                    out_c,
                    c,
                    a,
                    c,
                    bp,
                    bias,
                    &mut dst[r0 * out_c..],
                    out_c,
                    threads,
                    Some(&mut *cache),
                ),
                _ => gemm_bias_b(
                    rb,
                    out_c,
                    c,
                    a,
                    c,
                    wgt,
                    bias,
                    &mut dst[r0 * out_c..],
                    out_c,
                    threads,
                ),
            }
            r0 += rb;
        }
        return;
    }
    let (pad_y, pad_x) = match padding {
        Padding::Same => (same_pad_before(h, oh, k, stride), same_pad_before(w, ow, k, stride)),
        Padding::Valid => (0, 0),
    };
    let kd = k * k * c;
    im2col_from_packed(
        p,
        fmt,
        h,
        w,
        c,
        k,
        stride,
        pad_y,
        pad_x,
        oh,
        ow,
        &mut win[..w * c],
        &mut col[..m * kd],
        threads,
    );
    gemm_bias_b(m, out_c, kd, &col[..m * kd], kd, wgt, bias, dst, out_c, threads);
}

/// im2col driven by the streaming window reader: each input row is
/// decoded into a one-row window and scattered to every patch position
/// that uses it; out-of-bounds taps stay at the pre-filled `0.0`.
/// Output-row blocks split across scoped threads when the budget
/// allows, each thread with its *own* decode window (priced into the
/// fused envelope via `LoweredPlan::fused_window_elems`) — blocks write
/// disjoint `col` rows and only read the bitstream, so the result is
/// bit-identical to the serial pass, which produces the exact patch
/// matrix [`im2col`] builds from an f32 input holding the same values.
fn im2col_from_packed(
    p: &PackedBuf,
    fmt: QFormat,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    pad_y: usize,
    pad_x: usize,
    oh: usize,
    ow: usize,
    win_row: &mut [f32],
    col: &mut [f32],
    threads: usize,
) {
    let kd = k * k * c;
    let t = threads.min(oh).max(1);
    if t <= 1 || oh * ow * kd < IM2COL_PAR_MIN {
        col.fill(0.0);
        im2col_packed_rows(p, fmt, h, w, c, k, stride, pad_y, pad_x, 0, oh, ow, win_row, col);
        return;
    }
    let rows_per = (oh + t - 1) / t;
    std::thread::scope(|s| {
        let mut col_rest: &mut [f32] = col;
        let mut oy0 = 0usize;
        while oy0 < oh {
            let rows = rows_per.min(oh - oy0);
            let (chunk, rest) = std::mem::take(&mut col_rest).split_at_mut(rows * ow * kd);
            col_rest = rest;
            s.spawn(move || {
                // Adjacent blocks re-decode their overlapping boundary
                // input rows into private windows; decode is pure, so
                // overlap costs time, never correctness.
                let mut win = vec![0f32; w * c];
                chunk.fill(0.0);
                im2col_packed_rows(
                    p,
                    fmt,
                    h,
                    w,
                    c,
                    k,
                    stride,
                    pad_y,
                    pad_x,
                    oy0,
                    oy0 + rows,
                    ow,
                    &mut win,
                    chunk,
                );
            });
            oy0 += rows;
        }
    });
}

/// The serial packed-im2col kernel over output rows `[oy0, oy1)`; `col`
/// holds exactly those rows (pre-filled with `0.0`). Decodes only the
/// input rows those output rows tap.
fn im2col_packed_rows(
    p: &PackedBuf,
    fmt: QFormat,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    pad_y: usize,
    pad_x: usize,
    oy0: usize,
    oy1: usize,
    ow: usize,
    win_row: &mut [f32],
    col: &mut [f32],
) {
    let kd = k * k * c;
    // Input rows feeding output rows [oy0, oy1): the union of their
    // [oy*stride - pad_y, oy*stride - pad_y + k) windows, clipped to
    // the input (saturation may admit an edge row whose oy range below
    // comes up empty — a wasted decode at most, never a wrong write).
    let iy_lo = (oy0 * stride).saturating_sub(pad_y);
    let iy_hi = ((oy1 - 1) * stride + k - 1).saturating_sub(pad_y).min(h - 1);
    for iy in iy_lo..=iy_hi {
        p.unpack_rows(fmt, w * c, iy, win_row);
        // Output rows oy with a tap on input row iy: ky = iy + pad_y -
        // oy*stride must land in [0, k).
        let top = iy + pad_y;
        let oy_lo =
            (if top + 1 > k { (top + 1 - k + stride - 1) / stride } else { 0 }).max(oy0);
        let oy_hi = (top / stride).min(oy1 - 1);
        // An inclusive range with oy_lo > oy_hi is empty (rows only
        // feeding padding-clipped or out-of-range windows).
        for oy in oy_lo..=oy_hi {
            let ky = top - oy * stride;
            for ox in 0..ow {
                let seg = &mut col[((oy - oy0) * ow + ox) * kd + ky * k * c..][..k * c];
                for kx in 0..k {
                    let ix = (ox * stride + kx) as isize - pad_x as isize;
                    if ix >= 0 && (ix as usize) < w {
                        seg[kx * c..][..c].copy_from_slice(&win_row[(ix as usize) * c..][..c]);
                    }
                }
            }
        }
    }
}

/// Patch matrices below this size aren't worth a thread spawn.
const IM2COL_PAR_MIN: usize = 8192;

/// Extract `(oh*ow, k*k*c)` patch rows; out-of-bounds taps become `0.0`
/// (HWIO weight layout makes the flattened filter exactly the GEMM `B`).
/// Output rows are independent, so `oy` blocks split across scoped
/// threads when the budget allows — bit-identical for every count.
fn im2col(
    x: &[f32],
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    pad_y: usize,
    pad_x: usize,
    oh: usize,
    ow: usize,
    col: &mut [f32],
    threads: usize,
) {
    let kd = k * k * c;
    let t = threads.min(oh).max(1);
    if t <= 1 || oh * ow * kd < IM2COL_PAR_MIN {
        im2col_rows(x, h, w, c, k, stride, pad_y, pad_x, 0, oh, ow, col);
        return;
    }
    let rows_per = (oh + t - 1) / t;
    std::thread::scope(|s| {
        let mut col_rest: &mut [f32] = col;
        let mut oy0 = 0usize;
        while oy0 < oh {
            let rows = rows_per.min(oh - oy0);
            let (chunk, rest) = std::mem::take(&mut col_rest).split_at_mut(rows * ow * kd);
            col_rest = rest;
            s.spawn(move || {
                im2col_rows(x, h, w, c, k, stride, pad_y, pad_x, oy0, oy0 + rows, ow, chunk)
            });
            oy0 += rows;
        }
    });
}

/// The serial kernel over output rows `[oy0, oy1)`; `col` holds exactly
/// those rows.
fn im2col_rows(
    x: &[f32],
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    pad_y: usize,
    pad_x: usize,
    oy0: usize,
    oy1: usize,
    ow: usize,
    col: &mut [f32],
) {
    let kd = k * k * c;
    for oy in oy0..oy1 {
        for ox in 0..ow {
            let row = &mut col[((oy - oy0) * ow + ox) * kd..][..kd];
            for ky in 0..k {
                let iy = (oy * stride + ky) as isize - pad_y as isize;
                let seg = &mut row[ky * k * c..][..k * c];
                if iy < 0 || iy >= h as isize {
                    seg.fill(0.0);
                    continue;
                }
                let xrow = (iy as usize) * w;
                for kx in 0..k {
                    let ix = (ox * stride + kx) as isize - pad_x as isize;
                    let d = &mut seg[kx * c..][..c];
                    if ix < 0 || ix >= w as isize {
                        d.fill(0.0);
                    } else {
                        d.copy_from_slice(&x[(xrow + ix as usize) * c..][..c]);
                    }
                }
            }
        }
    }
}

/// GoogLeNet inception module: each branch conv is a GEMM writing
/// straight into its concat columns of `dst` (row stride = module
/// `out_c`), with ReLU applied per branch exactly as the interpreter
/// does. `tmp` holds one reduce output / pooled input at a time;
/// `bias_win` stages one decoded bias at a time under packed weights.
fn inception_gemm(
    op: &Op,
    x: &[f32],
    h: usize,
    w: usize,
    c: usize,
    wts: WView,
    base: usize,
    col: &mut [f32],
    tmp: &mut [f32],
    bias_win: &mut Vec<f32>,
    dst: &mut [f32],
    threads: usize,
) {
    let &Op::Inception { b1, b3r, b3, b5r, b5, pp, .. } = op else {
        unreachable!("inception_gemm on {op:?}");
    };
    let out_c = b1 + b3 + b5 + pp;
    let m = h * w;
    let p = |i: usize| wts.gemm_b(base + i);
    let same = Padding::Same;

    // 1×1 branch → columns [0, b1)
    let bs = wts.bias(base + 1, bias_win);
    conv_gemm(x, h, w, c, p(0), bs, b1, 1, 1, same, col, dst, out_c, 0, threads);
    relu_strided(dst, m, b1, out_c, 0);
    // 3×3 branch: reduce into tmp, then 3×3 → columns [b1, b1+b3)
    let bs = wts.bias(base + 3, bias_win);
    conv_gemm(x, h, w, c, p(2), bs, b3r, 1, 1, same, col, &mut tmp[..m * b3r], b3r, 0, threads);
    relu(&mut tmp[..m * b3r]);
    let bs = wts.bias(base + 5, bias_win);
    conv_gemm(&tmp[..m * b3r], h, w, b3r, p(4), bs, b3, 3, 1, same, col, dst, out_c, b1, threads);
    relu_strided(dst, m, b3, out_c, b1);
    // 5×5 branch → columns [b1+b3, b1+b3+b5)
    let bs = wts.bias(base + 7, bias_win);
    conv_gemm(x, h, w, c, p(6), bs, b5r, 1, 1, same, col, &mut tmp[..m * b5r], b5r, 0, threads);
    relu(&mut tmp[..m * b5r]);
    let bs = wts.bias(base + 9, bias_win);
    conv_gemm(
        &tmp[..m * b5r],
        h,
        w,
        b5r,
        p(8),
        bs,
        b5,
        5,
        1,
        same,
        col,
        dst,
        out_c,
        b1 + b3,
        threads,
    );
    relu_strided(dst, m, b5, out_c, b1 + b3);
    // Pool branch: 3×3 stride-1 maxpool, then 1×1 → last pp columns
    maxpool_into(x, h, w, c, 3, 1, &mut tmp[..m * c]);
    let bs = wts.bias(base + 11, bias_win);
    conv_gemm(
        &tmp[..m * c],
        h,
        w,
        c,
        p(10),
        bs,
        pp,
        1,
        1,
        same,
        col,
        dst,
        out_c,
        b1 + b3 + b5,
        threads,
    );
    relu_strided(dst, m, pp, out_c, b1 + b3 + b5);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn im2col_serial(
        x: &[f32],
        h: usize,
        w: usize,
        c: usize,
        k: usize,
        stride: usize,
        pad_y: usize,
        pad_x: usize,
        oh: usize,
        ow: usize,
        col: &mut [f32],
    ) {
        im2col_rows(x, h, w, c, k, stride, pad_y, pad_x, 0, oh, ow, col)
    }

    #[test]
    fn im2col_identity_for_1x1() {
        // k=3 SAME over 2x2x1: center taps equal the input.
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let mut col = vec![f32::NAN; 4 * 9];
        im2col_serial(&x, 2, 2, 1, 3, 1, 1, 1, 2, 2, &mut col);
        // output (0,0): patch rows (-1..2)x(-1..2); center (index 4) = x[0]
        assert_eq!(col[4], 1.0);
        // top-left tap of output (0,0) is padding
        assert_eq!(col[0], 0.0);
        // output (1,1) center = x[3]
        assert_eq!(col[3 * 9 + 4], 4.0);
    }

    #[test]
    fn im2col_valid_no_padding() {
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect(); // 3x3x1
        let mut col = vec![0f32; 4 * 4];
        im2col_serial(&x, 3, 3, 1, 2, 1, 0, 0, 2, 2, &mut col);
        assert_eq!(&col[..4], &[1.0, 2.0, 4.0, 5.0]); // window at (0,0)
        assert_eq!(&col[12..], &[5.0, 6.0, 8.0, 9.0]); // window at (1,1)
    }

    #[test]
    fn im2col_parallel_matches_serial_bit_for_bit() {
        // Big enough to clear IM2COL_PAR_MIN: 24x24x4 input, k=3 SAME.
        let (h, w, c, k) = (24usize, 24usize, 4usize, 3usize);
        let mut rng = crate::prng::Xoshiro256pp::new(99);
        let x: Vec<f32> = (0..h * w * c).map(|_| rng.uniform_f32(-2.0, 2.0)).collect();
        let (oh, ow) = conv_out_hw(h, w, k, 1, Padding::Same);
        let kd = k * k * c;
        let mut want = vec![f32::NAN; oh * ow * kd];
        im2col_serial(&x, h, w, c, k, 1, 1, 1, oh, ow, &mut want);
        for threads in [2usize, 3, 7, 64] {
            let mut got = vec![f32::NAN; oh * ow * kd];
            im2col(&x, h, w, c, k, 1, 1, 1, oh, ow, &mut got, threads);
            assert!(
                want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads={threads} diverged"
            );
        }
    }

    #[test]
    fn conv_gemm_matches_hand_conv() {
        // Same case as reference::conv2d_valid_sums_window.
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let panels = pack_b_panels(&[1.0; 4], 4, 1);
        let mut col = vec![0f32; 4 * 4];
        let mut dst = vec![0f32; 4];
        conv_gemm(
            &x,
            3,
            3,
            1,
            GemmB::Panels(&panels),
            &[0.5],
            1,
            2,
            1,
            Padding::Valid,
            &mut col,
            &mut dst,
            1,
            0,
            1,
        );
        assert_eq!(dst, vec![12.5, 16.5, 24.5, 28.5]);
    }

    /// Quantize + canonicalize `-0.0` — the values a bitstream carries.
    fn quantized(fmt: QFormat, xs: &[f32]) -> Vec<f32> {
        crate::testkit::quantized_canonical(fmt, xs)
    }

    #[test]
    fn im2col_from_packed_matches_f32_im2col() {
        let mut rng = crate::prng::Xoshiro256pp::new(42);
        let fmt = QFormat::new(5, 4); // 9 bits: windows straddle words
        for &(h, w, c, k, stride, padding) in &[
            (7usize, 7usize, 3usize, 3usize, 1usize, Padding::Same),
            (8, 6, 2, 5, 1, Padding::Same),
            (9, 9, 1, 2, 2, Padding::Same),
            (8, 8, 2, 3, 2, Padding::Same),
            (7, 7, 2, 3, 1, Padding::Valid),
            (10, 5, 4, 2, 2, Padding::Valid),
        ] {
            let raw: Vec<f32> = (0..h * w * c).map(|_| rng.uniform_f32(-4.0, 4.0)).collect();
            let x = quantized(fmt, &raw);
            let (oh, ow) = conv_out_hw(h, w, k, stride, padding);
            let (pad_y, pad_x) = match padding {
                Padding::Same => {
                    (same_pad_before(h, oh, k, stride), same_pad_before(w, ow, k, stride))
                }
                Padding::Valid => (0, 0),
            };
            let kd = k * k * c;
            let mut want = vec![f32::NAN; oh * ow * kd];
            im2col(&x, h, w, c, k, stride, pad_y, pad_x, oh, ow, &mut want, 1);
            let p = PackedBuf::pack(fmt, &x);
            let mut win = vec![0f32; w * c];
            let mut got = vec![f32::NAN; oh * ow * kd];
            im2col_from_packed(
                &p, fmt, h, w, c, k, stride, pad_y, pad_x, oh, ow, &mut win, &mut got, 1,
            );
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "({h},{w},{c},{k},{stride},{padding:?}) elem {i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn conv_from_packed_streams_bit_identical() {
        let fmt = QFormat::new(6, 2);
        let mut rng = crate::prng::Xoshiro256pp::new(7);
        // 1x1 stride-1: the (12*12, 5) A matrix spans two cursor row
        // blocks (144 > FUSED_A_ROWS).
        let (h, w, c, out_c) = (12usize, 12usize, 5usize, 3usize);
        let raw: Vec<f32> = (0..h * w * c).map(|_| rng.uniform_f32(-2.0, 2.0)).collect();
        let x = quantized(fmt, &raw);
        let wgt: Vec<f32> = (0..c * out_c).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let bias: Vec<f32> = (0..out_c).map(|_| rng.uniform_f32(-0.5, 0.5)).collect();
        let panels = pack_b_panels(&wgt, c, out_c);
        let mut col = vec![0f32; h * w * 9 * c]; // big enough for both cases
        let mut want = vec![f32::NAN; h * w * out_c];
        conv_gemm(
            &x,
            h,
            w,
            c,
            GemmB::Panels(&panels),
            &bias,
            out_c,
            1,
            1,
            Padding::Same,
            &mut col,
            &mut want,
            out_c,
            0,
            1,
        );
        let p = PackedBuf::pack(fmt, &x);
        let mut win = vec![0f32; lowering::FUSED_A_ROWS * c];
        let mut got = vec![f32::NAN; h * w * out_c];
        conv_from_packed(
            &p,
            fmt,
            h,
            w,
            c,
            GemmB::Panels(&panels),
            &bias,
            out_c,
            1,
            1,
            Padding::Same,
            &mut win,
            &mut col,
            &mut StripCache::new(0),
            &mut got,
            1,
        );
        assert!(want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()));

        // k=3 SAME: streamed im2col + the identical GEMM.
        let (k, c2, oc2) = (3usize, 2usize, 4usize);
        let raw2: Vec<f32> = (0..h * w * c2).map(|_| rng.uniform_f32(-2.0, 2.0)).collect();
        let x2 = quantized(fmt, &raw2);
        let wgt2: Vec<f32> =
            (0..k * k * c2 * oc2).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let bias2 = vec![0.25f32; oc2];
        let panels2 = pack_b_panels(&wgt2, k * k * c2, oc2);
        let mut col2 = vec![0f32; h * w * k * k * c2];
        let mut want2 = vec![f32::NAN; h * w * oc2];
        conv_gemm(
            &x2,
            h,
            w,
            c2,
            GemmB::Panels(&panels2),
            &bias2,
            oc2,
            k,
            1,
            Padding::Same,
            &mut col2,
            &mut want2,
            oc2,
            0,
            1,
        );
        let p2 = PackedBuf::pack(fmt, &x2);
        let mut win2 = vec![0f32; w * c2];
        let mut got2 = vec![f32::NAN; h * w * oc2];
        conv_from_packed(
            &p2,
            fmt,
            h,
            w,
            c2,
            GemmB::Panels(&panels2),
            &bias2,
            oc2,
            k,
            1,
            Padding::Same,
            &mut win2,
            &mut col2,
            &mut StripCache::new(0),
            &mut got2,
            1,
        );
        assert!(want2.iter().zip(&got2).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn packed_im2col_parallel_matches_serial_bit_for_bit() {
        // Big enough to clear IM2COL_PAR_MIN: 24x24x4 input, k=3 SAME
        // (oh*ow*kd = 576*36).
        let fmt = QFormat::new(5, 4);
        let (h, w, c, k) = (24usize, 24usize, 4usize, 3usize);
        let mut rng = crate::prng::Xoshiro256pp::new(98);
        let raw: Vec<f32> = (0..h * w * c).map(|_| rng.uniform_f32(-2.0, 2.0)).collect();
        let x = quantized(fmt, &raw);
        let p = PackedBuf::pack(fmt, &x);
        let (oh, ow) = conv_out_hw(h, w, k, 1, Padding::Same);
        let kd = k * k * c;
        let mut win = vec![0f32; w * c];
        let mut want = vec![f32::NAN; oh * ow * kd];
        im2col_from_packed(&p, fmt, h, w, c, k, 1, 1, 1, oh, ow, &mut win, &mut want, 1);
        for threads in [2usize, 3, 7, 64] {
            let mut got = vec![f32::NAN; oh * ow * kd];
            im2col_from_packed(&p, fmt, h, w, c, k, 1, 1, 1, oh, ow, &mut win, &mut got, threads);
            assert!(
                want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads={threads} diverged"
            );
        }
    }

    #[test]
    fn streamed_conv_strip_cache_is_bit_identical_and_hit() {
        // 1x1 stride-1 over packed weights: (16*16, 5) A spans two
        // cursor row blocks, so the second block re-reads every weight
        // strip — with a cache those re-reads must hit, without one the
        // output must be unchanged.
        let fmt = QFormat::new(6, 2);
        let wfmt = QFormat::new(2, 6);
        let mut rng = crate::prng::Xoshiro256pp::new(17);
        let (h, w, c, out_c) = (16usize, 16usize, 5usize, 7usize);
        let raw: Vec<f32> = (0..h * w * c).map(|_| rng.uniform_f32(-2.0, 2.0)).collect();
        let x = quantized(fmt, &raw);
        let wgt: Vec<f32> =
            (0..c * out_c).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let wq = quantized(wfmt, &wgt);
        let bias: Vec<f32> = (0..out_c).map(|_| rng.uniform_f32(-0.5, 0.5)).collect();
        let bp = PackedPanels::pack(wfmt, &pack_b_panels(&wq, c, out_c), c, NR);
        let p = PackedBuf::pack(fmt, &x);
        let mut col = vec![0f32; 1]; // 1x1 path never touches col
        let mut win = vec![0f32; lowering::FUSED_A_ROWS * c];
        let mut run = |cache: &mut StripCache| {
            let mut dst = vec![f32::NAN; h * w * out_c];
            conv_from_packed(
                &p,
                fmt,
                h,
                w,
                c,
                GemmB::Bits(&bp),
                &bias,
                out_c,
                1,
                1,
                Padding::Same,
                &mut win,
                &mut col,
                cache,
                &mut dst,
                1,
            );
            dst
        };
        let mut cold = StripCache::new(0);
        let want = run(&mut cold);
        assert_eq!((cold.hits(), cold.misses()), (0, 0));
        let mut warm = StripCache::new(1 << 20);
        let got = run(&mut warm);
        assert!(warm.hits() > 0, "second row block should hit the cache");
        assert!(want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn packed_weights_partition_every_tensor_on_every_arch() {
        // Every parameter tensor ends up as exactly one bitstream —
        // GEMM weights as panels (kd·n true elements each), biases as
        // plain buffers whose lengths sum to the plan's accounting.
        for name in crate::nets::arch::NET_ORDER {
            let a = crate::nets::arch::get(name).unwrap();
            let plan = LoweredPlan::new(&a, None).unwrap();
            let specs = crate::nets::arch::param_specs(&a).unwrap();
            let params: Vec<Vec<f32>> = specs.iter().map(|s| vec![0.1; s.elems()]).collect();
            let wfmt = vec![QFormat::new(1, 7); plan.n_layers];
            let mut w = PackedWeights::default();
            w.rebuild(&plan, &params, &wfmt, None);
            assert_eq!(w.tensors.len(), params.len(), "{name}");
            let mut panel_elems = 0usize;
            let mut bias_elems = 0usize;
            for (i, t) in w.tensors.iter().enumerate() {
                match t {
                    PackedTensor::Gemm(p) => {
                        assert_eq!(p.nr(), NR, "{name} tensor {i}");
                        assert_eq!(p.fmt(), wfmt[0], "{name} tensor {i}");
                        assert_eq!(p.kd() * p.n_panels() * NR, p.len(), "{name} tensor {i}");
                        panel_elems += p.len();
                    }
                    PackedTensor::Bias(b, fmt) => {
                        assert_eq!(*fmt, wfmt[0], "{name} tensor {i}");
                        bias_elems += b.len();
                    }
                }
            }
            assert_eq!(panel_elems, plan.panel_param_elems, "{name}");
            assert_eq!(bias_elems, plan.bias_param_elems, "{name}");
        }
    }

    #[test]
    fn packed_weights_shrink_and_decode_to_quantized_params() {
        let arch = crate::nets::arch::get("lenet").unwrap();
        let plan = LoweredPlan::new(&arch, None).unwrap();
        let specs = crate::nets::arch::param_specs(&arch).unwrap();
        let mut rng = crate::prng::Xoshiro256pp::new(11);
        let params: Vec<Vec<f32>> = specs
            .iter()
            .map(|s| (0..s.elems()).map(|_| rng.uniform_f32(-1.0, 1.0)).collect())
            .collect();
        let wfmt = vec![QFormat::new(1, 7); plan.n_layers]; // 8 bits
        let mut w = PackedWeights::default();
        w.rebuild(&plan, &params, &wfmt, None);
        // 8-bit codes: exactly one byte per stored element (panels carry
        // NR-lane padding), modulo per-tensor byte rounding.
        let elems = plan.panel_param_elems + plan.bias_param_elems;
        assert!(w.resident_bytes() <= elems + params.len());
        assert!(w.resident_bytes() >= elems);
        assert_eq!(packed_weight_bytes(&plan, &params, &wfmt), w.resident_bytes());
        // The plan-only pricing must agree with the real packing.
        assert_eq!(plan.packed_weight_bytes(&wfmt), w.resident_bytes());
        // Biases decode to exactly the quantized tensors.
        let q = plan.quantize_params(&params, &wfmt);
        let mut buf = Vec::new();
        for i in 0..w.tensors.len() {
            if matches!(w.tensors[i], PackedTensor::Bias(..)) {
                let got = WView::Packed(&w).bias(i, &mut buf);
                let want = crate::testkit::quantized_canonical(wfmt[0], &params[i]);
                assert_eq!(got, &want[..], "bias tensor {i}");
                assert_eq!(got.len(), q[i].len());
            }
        }
    }

    #[test]
    fn threads_env_parses_and_rejects() {
        // (runs with the var unset in the test env)
        if std::env::var_os("QBOUND_THREADS").is_none() {
            assert!(threads_from_env().unwrap() >= 1);
        }
        assert!(FastBackend::with_threads(0).threads >= 1);
        assert_eq!(FastBackend::with_threads(2).storage, StorageMode::F32);
        let b = FastBackend::with_options(2, StorageMode::Packed);
        assert_eq!(b.storage, StorageMode::Packed);
    }
}
