//! aarch64 NEON kernels. NEON (ASIMD) is part of the aarch64 baseline
//! target, so no runtime detection is needed — the dispatch table
//! compiles this module in whenever the target is aarch64.
//!
//! Bit-exactness: the micro-kernel uses separate `vmulq_f32` +
//! `vaddq_f32` (never `vfmaq_f32`) so each of the NR independent
//! output lanes sees exactly the scalar kernel's `acc += a * b`
//! rounding sequence; the unpacker extracts sign-extended codes with
//! the scalar decoder's arithmetic and vectorizes only the exact
//! int→f32 convert + power-of-two scale.

use std::arch::aarch64::*;

use super::super::gemm::{MR, NR};

/// NEON MR×NR register tile: 4 rows × 4 × 128-bit accumulators.
/// Safe wrapper — asserts the same bounds the scalar kernel's slice
/// indexing enforces, then calls the intrinsic body.
pub(super) fn micro_full(
    r0: usize,
    n0: usize,
    kp: usize,
    ke: usize,
    kd: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    bn0: usize,
    bk0: usize,
    c: &mut [f32],
    ldc: usize,
) {
    assert!(kp < ke && ke <= kd && bk0 <= kp);
    assert!(a.len() >= (r0 + MR - 1) * lda + kd);
    assert!(b.len() >= (ke - 1 - bk0) * ldb + bn0 + NR);
    assert!(c.len() >= (r0 + MR - 1) * ldc + n0 + NR);
    // SAFETY: NEON is baseline on aarch64; all pointer offsets are
    // covered by the bounds checks above.
    unsafe { micro_full_neon(r0, n0, kp, ke, a, lda, b, ldb, bn0, bk0, c, ldc) }
}

unsafe fn micro_full_neon(
    r0: usize,
    n0: usize,
    kp: usize,
    ke: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    bn0: usize,
    bk0: usize,
    c: &mut [f32],
    ldc: usize,
) {
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let cp = c.as_mut_ptr();
    // C tile in registers: 4 rows × 16 cols as 4 quad-lane vectors.
    let mut acc = [[vdupq_n_f32(0.0); 4]; MR];
    for (i, accr) in acc.iter_mut().enumerate() {
        let row = cp.add((r0 + i) * ldc + n0);
        for (q, accq) in accr.iter_mut().enumerate() {
            *accq = vld1q_f32(row.add(4 * q));
        }
    }
    for kk in kp..ke {
        let brow = bp.add((kk - bk0) * ldb + bn0);
        let bq = [
            vld1q_f32(brow),
            vld1q_f32(brow.add(4)),
            vld1q_f32(brow.add(8)),
            vld1q_f32(brow.add(12)),
        ];
        for (i, accr) in acc.iter_mut().enumerate() {
            let av = vdupq_n_f32(*ap.add((r0 + i) * lda + kk));
            for (accq, bv) in accr.iter_mut().zip(&bq) {
                // mul + add, not vfmaq: keeps lane rounding identical
                // to the scalar kernel.
                *accq = vaddq_f32(*accq, vmulq_f32(av, *bv));
            }
        }
    }
    for (i, accr) in acc.iter().enumerate() {
        let row = cp.add((r0 + i) * ldc + n0);
        for (q, accq) in accr.iter().enumerate() {
            vst1q_f32(row.add(4 * q), *accq);
        }
    }
}

/// NEON bit-field span decoder: codes are extracted with the scalar
/// word-shift arithmetic (bitstream loads stay safe slice indexing),
/// then converted and scaled four lanes at a time.
pub(super) fn unpack_span(words: &[u64], start: usize, width: u32, inv: f32, out: &mut [f32]) {
    debug_assert!((1..=crate::memory::MAX_PACK_BITS).contains(&width));
    debug_assert!((start + out.len()) * width as usize <= words.len() * 64);
    let n = out.len();
    let w = width as usize;
    let shift = 64 - width;
    let mut bitpos = start * w;
    let mut chunks = out.chunks_exact_mut(4);
    for chunk in &mut chunks {
        let mut codes = [0i32; 4];
        for code in &mut codes {
            let (wd, off) = (bitpos >> 6, (bitpos & 63) as u32);
            let mut raw = words[wd] >> off;
            if off + width > 64 {
                raw |= words[wd + 1] << (64 - off);
            }
            *code = (((raw << shift) as i64) >> shift) as i32;
            bitpos += w;
        }
        // SAFETY: NEON is baseline on aarch64; `chunk` is exactly 4
        // lanes and `codes` is a local 4-lane array.
        unsafe {
            let v = vcvtq_f32_s32(vld1q_s32(codes.as_ptr()));
            vst1q_f32(chunk.as_mut_ptr(), vmulq_n_f32(v, inv));
        }
    }
    let rem = chunks.into_remainder();
    if !rem.is_empty() {
        super::scalar_unpack_span(words, start + (n - rem.len()), width, inv, rem);
    }
}
