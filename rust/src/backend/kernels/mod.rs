//! Explicit SIMD microkernels behind runtime feature detection.
//!
//! The GEMM's hot loops used to lean on auto-vectorization of the
//! scalar [`MR`]×[`NR`] tile; this module makes the vector code
//! explicit and dispatches it once per process:
//!
//! | kind | micro-kernel | packed decode | available |
//! |---|---|---|---|
//! | `scalar` | portable [`MR`]×[`NR`] tile (auto-vectorizable) | word-shift loop | always |
//! | `avx2` | 8 × 256-bit accumulators (4 rows × 2 halves) | 64-bit gathers + variable shifts, 8 lanes/iter | x86_64 with AVX2 detected |
//! | `neon` | 16 × 128-bit accumulators (4 rows × 4 quads) | scalar extract + vector convert, 4 lanes/iter | aarch64 (NEON is baseline) |
//!
//! Selection: `QBOUND_KERNEL={auto,scalar,avx2,neon}` (invalid or
//! unavailable values are errors, not silent fallbacks), default
//! `auto` = best detected. The choice is resolved once ([`init`]) and
//! cached; [`active`] is the hot-path accessor the GEMM and the packed
//! decoder read a fn pointer from. [`force`] pins a variant for tests
//! and benches — safe to call at any time *because of the contract
//! below*.
//!
//! # Bit-exactness contract
//!
//! Every kernel variant must produce **bit-identical** results to the
//! scalar kernel:
//!
//! * The micro-kernel accumulates each output element's `k` terms in
//!   ascending order starting from the current `C` value, one
//!   `mul` + `add` per term — **never** a fused multiply-add, which
//!   would change the rounding vs the reference interpreter. SIMD
//!   vectorizes across the [`NR`] *independent* output lanes (and the
//!   decoder across independent values), which cannot change any
//!   per-element float sequence.
//! * The unpacker sign-extends each `width`-bit two's-complement code
//!   and multiplies by an exact power of two; `|code| ≤ 2^23 <
//!   2^24`, so the int→f32 conversion is exact on every path.
//!
//! `tests/property_gemm_packed.rs` and `tests/integration_parity.rs`
//! sweep every available variant against the scalar baseline.

use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU8, Ordering};

use super::gemm::{MR, NR};
use crate::memory::MAX_PACK_BITS;

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "aarch64")]
mod neon;

/// Full [`MR`]×[`NR`] register-tile micro-kernel. Arguments mirror
/// `gemm.rs`: rows `r0..r0+MR` of `a` (stride `lda`, depth `kd`),
/// columns `n0..n0+NR` of `c` (stride `ldc`), accumulating the k-panel
/// `kp..ke`; `b` is addressed as `b[(kk - bk0) * ldb + bn0 ..]`.
pub type MicroFull = fn(
    usize,     // r0
    usize,     // n0
    usize,     // kp
    usize,     // ke
    usize,     // kd
    &[f32],    // a
    usize,     // lda
    &[f32],    // b
    usize,     // ldb
    usize,     // bn0
    usize,     // bk0
    &mut [f32], // c
    usize,     // ldc
);

/// Bit-field span decoder: `out.len()` consecutive `width`-bit
/// two's-complement codes starting at element `start` of the LSB-first
/// little-endian bitstream `words`, each scaled by `inv` (an exact
/// power of two) into f32.
pub type UnpackSpan = fn(&[u64], usize, u32, f32, &mut [f32]);

/// A dispatchable kernel variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum KernelKind {
    /// Portable scalar tile — always available, the baseline every
    /// other variant must match bit-for-bit.
    Scalar = 1,
    /// x86_64 AVX2 (FMA deliberately unused: fusing would change
    /// rounding vs the scalar kernel).
    Avx2 = 2,
    /// aarch64 NEON.
    Neon = 3,
}

impl KernelKind {
    pub fn label(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Avx2 => "avx2",
            KernelKind::Neon => "neon",
        }
    }

    /// Parse a `QBOUND_KERNEL` spelling. `auto` is `None` (pick the
    /// best detected variant); anything unknown is an error.
    pub fn parse(s: &str) -> Result<Option<KernelKind>> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" | "" => Ok(None),
            "scalar" => Ok(Some(KernelKind::Scalar)),
            "avx2" => Ok(Some(KernelKind::Avx2)),
            "neon" => Ok(Some(KernelKind::Neon)),
            other => {
                bail!("unknown kernel {other:?} (expected: auto | scalar | avx2 | neon)")
            }
        }
    }

    /// Whether this variant can run on the current host.
    pub fn is_available(self) -> bool {
        match self {
            KernelKind::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            KernelKind::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(not(target_arch = "x86_64"))]
            KernelKind::Avx2 => false,
            // NEON is part of the aarch64 baseline target.
            KernelKind::Neon => cfg!(target_arch = "aarch64"),
        }
    }
}

/// One dispatch-table row: the fn pointers the hot paths call through.
pub struct Kernel {
    pub kind: KernelKind,
    pub micro_full: MicroFull,
    pub unpack_span: UnpackSpan,
}

static SCALAR: Kernel = Kernel {
    kind: KernelKind::Scalar,
    micro_full: scalar_micro_full,
    unpack_span: scalar_unpack_span,
};

#[cfg(target_arch = "x86_64")]
static AVX2: Kernel = Kernel {
    kind: KernelKind::Avx2,
    micro_full: avx2::micro_full,
    unpack_span: avx2::unpack_span,
};

#[cfg(target_arch = "aarch64")]
static NEON: Kernel = Kernel {
    kind: KernelKind::Neon,
    micro_full: neon::micro_full,
    unpack_span: neon::unpack_span,
};

/// The dispatch table row for an *available* kind ([`KernelKind::is_available`]).
pub fn get(kind: KernelKind) -> &'static Kernel {
    assert!(kind.is_available(), "kernel {:?} is not available on this host", kind.label());
    match kind {
        KernelKind::Scalar => &SCALAR,
        #[cfg(target_arch = "x86_64")]
        KernelKind::Avx2 => &AVX2,
        #[cfg(target_arch = "aarch64")]
        KernelKind::Neon => &NEON,
        // At most one SIMD arm compiles per target, so this arm always
        // covers at least one (unavailable) variant.
        _ => unreachable!("unavailable kind passed the availability assert"),
    }
}

/// Every variant the current host can run, scalar first — the sweep
/// order the cross-variant test suites and benches iterate.
pub fn available() -> Vec<KernelKind> {
    [KernelKind::Scalar, KernelKind::Avx2, KernelKind::Neon]
        .into_iter()
        .filter(|k| k.is_available())
        .collect()
}

/// Best variant the host supports (the `auto` choice).
fn detect_best() -> KernelKind {
    if KernelKind::Avx2.is_available() {
        KernelKind::Avx2
    } else if KernelKind::Neon.is_available() {
        KernelKind::Neon
    } else {
        KernelKind::Scalar
    }
}

/// Variant selected by `QBOUND_KERNEL` (default/`auto`: best
/// detected). Requesting a variant the host cannot run is an error,
/// like every other `QBOUND_*` misconfiguration.
pub fn from_env() -> Result<KernelKind> {
    match std::env::var("QBOUND_KERNEL") {
        Ok(s) if !s.trim().is_empty() => match KernelKind::parse(&s)? {
            None => Ok(detect_best()),
            Some(k) if k.is_available() => Ok(k),
            Some(k) => bail!(
                "QBOUND_KERNEL={} requested but this host does not support it \
                 (available: {})",
                k.label(),
                available().iter().map(|k| k.label()).collect::<Vec<_>>().join(", ")
            ),
        },
        _ => Ok(detect_best()),
    }
}

/// 0 = unresolved; otherwise a `KernelKind` discriminant. All variants
/// are bit-identical, so a resolution race is benign by contract.
static ACTIVE: AtomicU8 = AtomicU8::new(0);

fn kind_from_u8(v: u8) -> KernelKind {
    match v {
        1 => KernelKind::Scalar,
        2 => KernelKind::Avx2,
        3 => KernelKind::Neon,
        _ => unreachable!("invalid kernel discriminant {v}"),
    }
}

/// Resolve the dispatched variant once per process (from
/// `QBOUND_KERNEL` / auto-detection), cache it, and report it with a
/// one-time startup log line. Backend constructors call this so a
/// misconfigured `QBOUND_KERNEL` surfaces as a clean error before any
/// compute runs.
pub fn init() -> Result<KernelKind> {
    let v = ACTIVE.load(Ordering::Relaxed);
    if v != 0 {
        return Ok(kind_from_u8(v));
    }
    let kind = from_env()?;
    if ACTIVE.compare_exchange(0, kind as u8, Ordering::Relaxed, Ordering::Relaxed).is_ok() {
        let how = match std::env::var("QBOUND_KERNEL") {
            Ok(s) if !s.trim().is_empty() => "QBOUND_KERNEL",
            _ => "auto-detected",
        };
        log::info!("kernel dispatch: {} ({how})", kind.label());
        crate::obs::gauge(
            "qbound_kernel",
            "dispatched SIMD microkernel variant (1 = active)",
            &[("variant", kind.label())],
        )
        .set(1);
        Ok(kind)
    } else {
        // Lost the race (or a concurrent `force`): honour the winner.
        Ok(kind_from_u8(ACTIVE.load(Ordering::Relaxed)))
    }
}

/// The active dispatch row — resolved on first use. Panics only on a
/// malformed `QBOUND_KERNEL` that no backend constructor surfaced
/// first (constructors call [`init`] and return the error cleanly).
pub fn active() -> &'static Kernel {
    get(init().unwrap_or_else(|e| panic!("{e}")))
}

/// The active variant's kind (telemetry: serve `/v1/stats`, bench
/// records, smoke artifacts).
pub fn active_kind() -> KernelKind {
    init().unwrap_or_else(|e| panic!("{e}"))
}

/// Pin the dispatched variant (tests/benches sweeping variants). The
/// kind must be available on this host. Safe to call concurrently:
/// every variant is bit-identical, so compute started under the old
/// pin stays correct.
pub fn force(kind: KernelKind) {
    assert!(kind.is_available(), "cannot force unavailable kernel {:?}", kind.label());
    ACTIVE.store(kind as u8, Ordering::Relaxed);
}

// ---- scalar kernels ------------------------------------------------------

/// Full MR×NR register tile: C tile in registers, ascending-k updates,
/// one `mul` + `add` per term (never `mul_add` — fusing would change
/// results vs the reference interpreter). `n0` addresses the C columns;
/// `bn0` the same columns within `b` (equal for a row-major B, 0 for a
/// packed panel); `bk0` is the `k` index of `b`'s first row (0 for a
/// full B, `kp` for a decoded strip tile).
fn scalar_micro_full(
    r0: usize,
    n0: usize,
    kp: usize,
    ke: usize,
    kd: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    bn0: usize,
    bk0: usize,
    c: &mut [f32],
    ldc: usize,
) {
    let arows: [&[f32]; MR] = std::array::from_fn(|i| &a[(r0 + i) * lda..][..kd]);
    let mut acc = [[0f32; NR]; MR];
    for (i, accr) in acc.iter_mut().enumerate() {
        accr.copy_from_slice(&c[(r0 + i) * ldc + n0..][..NR]);
    }
    for kk in kp..ke {
        let brow = &b[(kk - bk0) * ldb + bn0..][..NR];
        for (accr, arow) in acc.iter_mut().zip(&arows) {
            let av = arow[kk];
            for (x, &bv) in accr.iter_mut().zip(brow) {
                *x += av * bv;
            }
        }
    }
    for (i, accr) in acc.iter().enumerate() {
        c[(r0 + i) * ldc + n0..][..NR].copy_from_slice(accr);
    }
}

/// Scalar bit-field span decoder: the word-shift loop every SIMD
/// unpacker must match bit-for-bit (and the tail path they fall back
/// to near the end of the bitstream). Sign-extends each `width`-bit
/// code, then scales by `inv` — exact, since `|code| < 2^24` and `inv`
/// is a power of two.
pub(crate) fn scalar_unpack_span(
    words: &[u64],
    start: usize,
    width: u32,
    inv: f32,
    out: &mut [f32],
) {
    let shift = 64 - width;
    let mut bitpos = start * width as usize;
    for o in out.iter_mut() {
        let (w, off) = (bitpos >> 6, (bitpos & 63) as u32);
        let mut raw = words[w] >> off;
        if off + width > 64 {
            raw |= words[w + 1] << (64 - off);
        }
        let code = ((raw << shift) as i64) >> shift;
        *o = code as f32 * inv;
        bitpos += width as usize;
    }
}

/// Decode a span through the *active* kernel's vector unpacker — the
/// width-checked entry `memory/packed.rs` routes every fixed-point
/// window decode through.
pub fn unpack_span(words: &[u64], start: usize, width: u32, inv: f32, out: &mut [f32]) {
    unpack_span_with(active(), words, start, width, inv, out)
}

/// Kind-addressed variant of [`unpack_span`] (cross-variant tests and
/// benches). Bounds are checked here so every arch implementation can
/// assume an in-range span.
pub fn unpack_span_with(
    k: &Kernel,
    words: &[u64],
    start: usize,
    width: u32,
    inv: f32,
    out: &mut [f32],
) {
    assert!((1..=MAX_PACK_BITS).contains(&width), "unpackable span width {width}");
    assert!(
        (start + out.len()) * width as usize <= words.len() * 64,
        "span {start}+{} at width {width} overruns {} words",
        out.len(),
        words.len()
    );
    (k.unpack_span)(words, start, width, inv, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spellings() {
        assert_eq!(KernelKind::parse("auto").unwrap(), None);
        assert_eq!(KernelKind::parse(" Scalar ").unwrap(), Some(KernelKind::Scalar));
        assert_eq!(KernelKind::parse("AVX2").unwrap(), Some(KernelKind::Avx2));
        assert_eq!(KernelKind::parse("neon").unwrap(), Some(KernelKind::Neon));
        assert!(KernelKind::parse("sse9").is_err());
        assert_eq!(KernelKind::Scalar.label(), "scalar");
        assert_eq!(KernelKind::Avx2.label(), "avx2");
        assert_eq!(KernelKind::Neon.label(), "neon");
    }

    #[test]
    fn scalar_always_available_and_first() {
        let av = available();
        assert_eq!(av.first(), Some(&KernelKind::Scalar));
        for k in &av {
            assert!(k.is_available());
            // The table row must exist and agree on its kind.
            assert_eq!(get(*k).kind, *k);
        }
        // At most one SIMD variant per arch.
        assert!(av.len() <= 2);
    }

    #[test]
    fn active_resolves_to_an_available_kind() {
        let kind = active_kind();
        assert!(kind.is_available());
        assert_eq!(active().kind, kind);
        // Resolution is cached: a second read agrees.
        assert_eq!(active_kind(), kind);
        assert_eq!(init().unwrap(), kind);
    }

    /// Pack `codes` (already masked to `width` bits) LSB-first into
    /// little-endian words — an independent reference packer.
    fn pack_codes(codes: &[u64], width: u32) -> Vec<u64> {
        let mut words = vec![0u64; (codes.len() * width as usize).div_ceil(64)];
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        for (i, &code) in codes.iter().enumerate() {
            let bits = code & mask;
            let bitpos = i * width as usize;
            let (w, off) = (bitpos >> 6, (bitpos & 63) as u32);
            words[w] |= bits << off;
            if off + width > 64 {
                words[w + 1] |= bits >> (64 - off);
            }
        }
        words
    }

    #[test]
    fn every_variant_unpacks_bit_identically_to_scalar() {
        let mut rng = crate::prng::Xoshiro256pp::new(0xdec0de);
        for width in 1..=MAX_PACK_BITS {
            // 0..135 values: exercises the 8-lane SIMD body, the
            // non-multiple-of-8 tail, and the end-of-buffer scalar
            // fallback (the last values sit within 64 bits of the end).
            let n = 135usize;
            let codes: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let words = pack_codes(&codes, width);
            let inv = (-((width as i32) / 2) as f32).exp2();
            for start in [0usize, 1, 7, 64, n - 9] {
                let len = n - start;
                let mut want = vec![f32::NAN; len];
                unpack_span_with(get(KernelKind::Scalar), &words, start, width, inv, &mut want);
                for kind in available() {
                    let mut got = vec![f32::NAN; len];
                    unpack_span_with(get(kind), &words, start, width, inv, &mut got);
                    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                        assert_eq!(
                            g.to_bits(),
                            w.to_bits(),
                            "{:?} width {width} start {start} elem {i}: {g} vs {w}",
                            kind.label()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn every_variant_micro_full_is_bit_identical_to_scalar() {
        let mut rng = crate::prng::Xoshiro256pp::new(0x516e);
        let (kd, lda, ldb, ldc) = (37usize, 40usize, NR, NR + 5);
        let a: Vec<f32> = (0..(MR + 2) * lda).map(|_| rng.uniform_f32(-2.0, 2.0)).collect();
        let b: Vec<f32> = (0..kd * ldb).map(|_| rng.uniform_f32(-2.0, 2.0)).collect();
        let c0: Vec<f32> = (0..(MR + 2) * ldc).map(|_| rng.uniform_f32(-2.0, 2.0)).collect();
        // Both addressing modes: flat-B (bk0 = 0) and strip tile
        // (bk0 = kp, b holds only rows kp..ke).
        for (r0, kp, ke, bk0) in [(0usize, 0usize, kd, 0usize), (2, 5, 31, 5), (1, 0, 1, 0)] {
            let bview = &b[..(ke - bk0) * ldb];
            let mut want = c0.clone();
            scalar_micro_full(r0, 0, kp, ke, kd, &a, lda, bview, ldb, 0, bk0, &mut want, ldc);
            for kind in available() {
                let mut got = c0.clone();
                (get(kind).micro_full)(
                    r0, 0, kp, ke, kd, &a, lda, bview, ldb, 0, bk0, &mut got, ldc,
                );
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        w.to_bits(),
                        "{:?} r0={r0} kp={kp} ke={ke} elem {i}",
                        kind.label()
                    );
                }
            }
        }
    }
}
