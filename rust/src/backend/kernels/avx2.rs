//! x86_64 AVX2 kernels.
//!
//! Bit-exactness: the micro-kernel uses separate `_mm256_mul_ps` +
//! `_mm256_add_ps` (never `_mm256_fmadd_ps`) so each of the NR
//! independent output lanes sees exactly the scalar kernel's
//! `acc += a * b` rounding sequence; the unpacker reproduces the
//! scalar decoder's sign-extend-then-scale arithmetic, which is exact
//! for every `|code| ≤ 2^23`.
//!
//! `unsafe` hygiene: both entry points are safe fns that check every
//! bound the raw-pointer bodies rely on before entering the
//! `#[target_feature]` inner fn. The dispatch table only installs this
//! module when `is_x86_feature_detected!("avx2")` holds.

use std::arch::x86_64::*;

use super::super::gemm::{MR, NR};

/// AVX2 MR×NR register tile: 4 rows × 2 × 256-bit accumulators.
/// Safe wrapper — asserts the same bounds the scalar kernel's slice
/// indexing enforces, then calls the intrinsic body.
pub(super) fn micro_full(
    r0: usize,
    n0: usize,
    kp: usize,
    ke: usize,
    kd: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    bn0: usize,
    bk0: usize,
    c: &mut [f32],
    ldc: usize,
) {
    debug_assert!(is_x86_feature_detected!("avx2"));
    assert!(kp < ke && ke <= kd && bk0 <= kp);
    assert!(a.len() >= (r0 + MR - 1) * lda + kd);
    assert!(b.len() >= (ke - 1 - bk0) * ldb + bn0 + NR);
    assert!(c.len() >= (r0 + MR - 1) * ldc + n0 + NR);
    // SAFETY: AVX2 availability is asserted above and guaranteed by
    // the dispatch table; all pointer offsets are covered by the
    // bounds checks above.
    unsafe { micro_full_avx2(r0, n0, kp, ke, a, lda, b, ldb, bn0, bk0, c, ldc) }
}

#[target_feature(enable = "avx2")]
unsafe fn micro_full_avx2(
    r0: usize,
    n0: usize,
    kp: usize,
    ke: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    bn0: usize,
    bk0: usize,
    c: &mut [f32],
    ldc: usize,
) {
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let cp = c.as_mut_ptr();
    // C tile lives in registers across the k-panel: 4 rows × 16 cols.
    let mut acc = [[_mm256_setzero_ps(); 2]; MR];
    for (i, accr) in acc.iter_mut().enumerate() {
        let row = cp.add((r0 + i) * ldc + n0);
        accr[0] = _mm256_loadu_ps(row);
        accr[1] = _mm256_loadu_ps(row.add(8));
    }
    for kk in kp..ke {
        let brow = bp.add((kk - bk0) * ldb + bn0);
        let b0 = _mm256_loadu_ps(brow);
        let b1 = _mm256_loadu_ps(brow.add(8));
        for (i, accr) in acc.iter_mut().enumerate() {
            let av = _mm256_set1_ps(*ap.add((r0 + i) * lda + kk));
            // mul + add, not fmadd: keeps lane rounding identical to
            // the scalar kernel.
            accr[0] = _mm256_add_ps(accr[0], _mm256_mul_ps(av, b0));
            accr[1] = _mm256_add_ps(accr[1], _mm256_mul_ps(av, b1));
        }
    }
    for (i, accr) in acc.iter().enumerate() {
        let row = cp.add((r0 + i) * ldc + n0);
        _mm256_storeu_ps(row, accr[0]);
        _mm256_storeu_ps(row.add(8), accr[1]);
    }
}

/// AVX2 bit-field span decoder: 8 values per iteration via 64-bit
/// gathers at byte granularity + per-lane variable shifts. Values
/// whose 8-byte gather window would overrun the bitstream fall back to
/// the scalar tail (bounds computed here, not per lane).
pub(super) fn unpack_span(words: &[u64], start: usize, width: u32, inv: f32, out: &mut [f32]) {
    debug_assert!((1..=crate::memory::MAX_PACK_BITS).contains(&width));
    debug_assert!((start + out.len()) * width as usize <= words.len() * 64);
    let w = width as usize;
    let total_bits = words.len() * 64;
    // Each SIMD lane loads the 8 bytes at its value's byte offset, so
    // a value at bit position p needs p ≤ total_bits - 64. Gather
    // offsets are i32 bytes — cap the stream size accordingly (far
    // above any real tensor; the scalar path covers the rest).
    let mut n_simd = 0usize;
    if total_bits >= 64 && words.len() <= i32::MAX as usize / 8 {
        let max_v = (total_bits - 64) / w;
        if max_v >= start {
            n_simd = (max_v - start + 1).min(out.len()) & !7;
        }
    }
    if n_simd > 0 {
        // SAFETY: AVX2 is guaranteed by the dispatch table; every
        // gather window [p/8, p/8 + 8) is within the words buffer by
        // the n_simd bound above, and the output is sliced to the
        // exact SIMD span.
        unsafe { unpack_span_avx2(words, start, width, inv, &mut out[..n_simd]) };
    }
    if n_simd < out.len() {
        super::scalar_unpack_span(words, start + n_simd, width, inv, &mut out[n_simd..]);
    }
}

#[target_feature(enable = "avx2")]
unsafe fn unpack_span_avx2(words: &[u64], start: usize, width: u32, inv: f32, out: &mut [f32]) {
    debug_assert_eq!(out.len() % 8, 0);
    let base = words.as_ptr() as *const i64;
    let w = width as usize;
    let invv = _mm256_set1_ps(inv);
    // Sign-extend a width-bit code sitting in the low bits of an i32
    // lane: shift left then arithmetic-shift right by 32 - width.
    let sh = _mm_cvtsi32_si128(32 - width as i32);
    // After the 64-bit variable shift each value occupies the low
    // ≤ 31 bits of its qword; compress the even (low) dwords of both
    // gathers into one vector of 8 codes.
    let lo32 = _mm256_setr_epi32(0, 2, 4, 6, 0, 2, 4, 6);
    let mut pos = start * w;
    let mut o = out.as_mut_ptr();
    for _ in 0..out.len() / 8 {
        let byte = |j: usize| ((pos + j * w) >> 3) as i32;
        let bit = |j: usize| ((pos + j * w) & 7) as i64;
        // Byte-granular gathers (scale 1): value bits start at
        // (p & 7) ≤ 7 and end before bit 7 + 24 = 31 of the loaded
        // qword, so one unaligned 8-byte load always covers a value.
        let off0 = _mm_setr_epi32(byte(0), byte(1), byte(2), byte(3));
        let off1 = _mm_setr_epi32(byte(4), byte(5), byte(6), byte(7));
        let g0 = _mm256_i32gather_epi64::<1>(base, off0);
        let g1 = _mm256_i32gather_epi64::<1>(base, off1);
        let r0 = _mm256_srlv_epi64(g0, _mm256_setr_epi64x(bit(0), bit(1), bit(2), bit(3)));
        let r1 = _mm256_srlv_epi64(g1, _mm256_setr_epi64x(bit(4), bit(5), bit(6), bit(7)));
        let lo0 = _mm256_permutevar8x32_epi32(r0, lo32);
        let lo1 = _mm256_permutevar8x32_epi32(r1, lo32);
        let codes = _mm256_inserti128_si256::<1>(lo0, _mm256_castsi256_si128(lo1));
        let ext = _mm256_sra_epi32(_mm256_sll_epi32(codes, sh), sh);
        // Exact: |code| ≤ 2^23 converts exactly, inv is a power of two.
        let vals = _mm256_mul_ps(_mm256_cvtepi32_ps(ext), invv);
        _mm256_storeu_ps(o, vals);
        o = o.add(8);
        pos += 8 * w;
    }
}
