//! # qbound — per-layer reduced-precision CNN framework
//!
//! Reproduction of Judd et al., *"Reduced-Precision Strategies for Bounded
//! Memory in Deep Neural Nets"* (2015), as a three-layer Rust + JAX +
//! Pallas system:
//!
//! * **L1** — a Pallas fixed-point quantization kernel (build path,
//!   `python/compile/kernels/`),
//! * **L2** — JAX forward graphs for the paper's five CNNs with per-layer
//!   precision as *runtime operands* (`python/compile/`), AOT-lowered to
//!   HLO text,
//! * **L3** — this crate: the coordinator that loads the compiled
//!   executables through PJRT (`xla` crate) and drives the paper's
//!   characterization sweeps, traffic model, and precision search.
//!
//! Python never runs on the request path; after `make artifacts` the rust
//! binary is self-contained.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`quant`] | the Q(I.F) fixed-point format and host-side quantizer |
//! | [`nets`] | network manifests (layers, params, counts) |
//! | [`traffic`] | the paper's Fig-4 memory-access model |
//! | [`runtime`] | PJRT engine: load HLO text, execute with resident weights |
//! | [`eval`] | batched top-1 evaluation with config-keyed memoization |
//! | [`coordinator`] | worker-pool evaluation service (one engine/thread) |
//! | [`search`] | uniform/per-layer sweeps, greedy descent, Pareto, Table 2 |
//! | [`report`] | tables, ASCII charts, CSV/markdown emitters |
//! | [`tensor`], [`util`], [`cli`], [`prng`], [`testkit`], [`benchkit`] | substrates |

pub mod benchkit;
pub mod cli;
pub mod coordinator;
pub mod eval;
pub mod nets;
pub mod prng;
pub mod quant;
pub mod report;
pub mod repro;
pub mod runtime;
pub mod search;
pub mod tensor;
pub mod testkit;
pub mod traffic;
pub mod util;
