//! # qbound — per-layer reduced-precision CNN framework
//!
//! Reproduction of Judd et al., *"Reduced-Precision Strategies for Bounded
//! Memory in Deep Neural Nets"* (2015), as a three-layer Rust + JAX +
//! Pallas system:
//!
//! * **L1** — a Pallas fixed-point quantization kernel (build path,
//!   `python/compile/kernels/`),
//! * **L2** — JAX forward graphs for the paper's five CNNs with per-layer
//!   precision as *runtime operands* (`python/compile/`), AOT-lowered to
//!   HLO text,
//! * **L3** — this crate: the coordinator that drives the paper's
//!   characterization sweeps, traffic model, and precision search over a
//!   pluggable execution backend.
//!
//! Execution is backend-agnostic ([`backend`]): the default **reference
//! backend** interprets the fixed-point forward pass in pure Rust (no
//! native deps — this is what CI runs), while `--features pjrt` adds the
//! PJRT backend that executes the AOT-compiled HLO. Artifacts come from
//! the python build path (`make artifacts`) or from the pure-Rust
//! synthesizer ([`artifacts`], `qbound gen-artifacts`).
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`quant`] | the Q(I.F) fixed-point format and host-side quantizer |
//! | [`nets`] | network manifests + the architecture registry ([`nets::arch`]) |
//! | [`backend`] | `Backend`/`NetExecutor` traits, reference + PJRT impls |
//! | [`artifacts`] | pure-Rust synthetic artifact generation + golden oracle |
//! | [`memory`] | packed reduced-precision storage + data-footprint model |
//! | [`traffic`] | the paper's Fig-4 memory-access model |
//! | `runtime` | PJRT engine (behind `--features pjrt`) |
//! | [`eval`] | batched top-1 evaluation with config-keyed memoization |
//! | [`coordinator`] | worker-pool evaluation service (one backend/thread) |
//! | [`search`] | uniform/per-layer sweeps, greedy descent, Pareto, Table 2 |
//! | [`serve`] | footprint-budgeted HTTP inference daemon (`qbound serve`) |
//! | [`store`] | content-addressed packed-weight store, mmap'd zero-copy sharing (`qbound store`) |
//! | [`obs`] | metrics registry (Prometheus exposition), span tracing, per-layer profiling substrate |
//! | [`report`] | tables, ASCII charts, CSV/markdown emitters |
//! | [`tensor`], [`util`], [`cli`], [`prng`], [`testkit`], [`benchkit`] | substrates |

#![allow(clippy::too_many_arguments, clippy::type_complexity)]

pub mod artifacts;
pub mod backend;
pub mod benchkit;
pub mod cli;
pub mod coordinator;
pub mod eval;
pub mod memory;
pub mod nets;
pub mod obs;
pub mod prng;
pub mod quant;
pub mod report;
pub mod repro;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod search;
pub mod serve;
pub mod store;
pub mod tensor;
pub mod testkit;
pub mod traffic;
pub mod util;
