//! Admission control for in-flight requests: a bounded counting gate.
//!
//! The daemon accepts a connection, parses the request, then tries to
//! take a slot from the [`InflightGate`] before touching dispatch
//! state. When every slot is taken the request is refused immediately
//! with `429 Too Many Requests` + `Retry-After` — bounded queueing is
//! part of the memory story: an unbounded backlog of parsed request
//! bodies is exactly the kind of hidden allocation the footprint model
//! can't see, so the daemon refuses work instead of buffering it.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// A counting semaphore over `capacity` in-flight requests.
///
/// `try_acquire` never blocks: dispatch either gets a [`InflightSlot`]
/// (RAII — dropping it releases the slot, on success and on every error
/// path alike) or learns the queue is full and answers 429.
pub struct InflightGate {
    inflight: Arc<AtomicUsize>,
    capacity: usize,
}

/// An acquired slot; releases itself on drop.
pub struct InflightSlot {
    inflight: Arc<AtomicUsize>,
}

impl InflightGate {
    pub fn new(capacity: usize) -> InflightGate {
        InflightGate { inflight: Arc::new(AtomicUsize::new(0)), capacity: capacity.max(1) }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Currently held slots (stats reporting; racy by nature).
    pub fn in_flight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Take a slot, or `None` when `capacity` requests are already in
    /// flight. Lock-free compare-exchange so refusal stays cheap under
    /// overload — the one moment it matters.
    pub fn try_acquire(&self) -> Option<InflightSlot> {
        let mut cur = self.inflight.load(Ordering::Relaxed);
        loop {
            if cur >= self.capacity {
                return None;
            }
            match self.inflight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::Acquire,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Some(InflightSlot { inflight: Arc::clone(&self.inflight) }),
                Err(seen) => cur = seen,
            }
        }
    }
}

impl Drop for InflightSlot {
    fn drop(&mut self) {
        self.inflight.fetch_sub(1, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gate_fills_to_capacity_and_refuses() {
        let g = InflightGate::new(2);
        let a = g.try_acquire().expect("slot 1");
        let b = g.try_acquire().expect("slot 2");
        assert_eq!(g.in_flight(), 2);
        assert!(g.try_acquire().is_none(), "third request must be refused");
        drop(a);
        let c = g.try_acquire().expect("slot freed by drop");
        assert_eq!(g.in_flight(), 2);
        drop(b);
        drop(c);
        assert_eq!(g.in_flight(), 0);
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let g = InflightGate::new(0);
        assert_eq!(g.capacity(), 1);
        let slot = g.try_acquire().expect("one slot");
        assert!(g.try_acquire().is_none());
        drop(slot);
    }

    #[test]
    fn concurrent_acquires_never_exceed_capacity() {
        use std::sync::atomic::AtomicBool;
        let g = Arc::new(InflightGate::new(3));
        let peak = Arc::new(AtomicUsize::new(0));
        let over = Arc::new(AtomicBool::new(false));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let (g, peak, over) = (Arc::clone(&g), Arc::clone(&peak), Arc::clone(&over));
                std::thread::spawn(move || {
                    for _ in 0..200 {
                        if let Some(slot) = g.try_acquire() {
                            let now = g.in_flight();
                            peak.fetch_max(now, Ordering::Relaxed);
                            if now > 3 {
                                over.store(true, Ordering::Relaxed);
                            }
                            drop(slot);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!(!over.load(Ordering::Relaxed), "in-flight exceeded capacity");
        assert!(peak.load(Ordering::Relaxed) >= 1);
        assert_eq!(g.in_flight(), 0);
    }
}
