//! Minimal hand-rolled HTTP/1.1 layer for the serve daemon — pure std,
//! no registry deps.
//!
//! Scope is exactly what a bounded inference endpoint needs: one
//! request parser over a [`BufRead`] (keep-alive and pipelining fall
//! out of calling it in a loop on one connection) and one response
//! writer that always emits `Content-Length` so the connection framing
//! never depends on close semantics. Chunked transfer encoding is
//! deliberately not implemented (501): request bodies are small JSON
//! documents whose size must be known up front for admission control.
//!
//! Error mapping (locked by the unit tests):
//!
//! | condition | status |
//! |---|---|
//! | malformed start line / header / version | 400 |
//! | body without `Content-Length`           | 411 |
//! | body over the configured cap            | 413 |
//! | headers over [`MAX_HEADER_BYTES`]       | 431 |
//! | `Transfer-Encoding: chunked`            | 501 |

use std::io::{BufRead, Read, Write};

use crate::util::json::Json;

/// Cap on the start line + headers of one request. Far above anything a
/// legitimate client sends; a stream that exceeds it is hostile or
/// corrupt and gets a 431.
pub const MAX_HEADER_BYTES: usize = 16 * 1024;

/// One parsed request. Header names are lowercased at parse time;
/// values keep their case with surrounding whitespace trimmed.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    /// Path without the query string.
    pub path: String,
    /// Raw query string (without the `?`), empty if absent.
    pub query: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// Whether the connection should stay open after the response
    /// (HTTP/1.1 default, overridden by `Connection: close`; HTTP/1.0
    /// only with `Connection: keep-alive`).
    pub keep_alive: bool,
}

impl HttpRequest {
    /// First value of header `name` (case-insensitive).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }
}

/// A request-level protocol error, mapped to the status the connection
/// handler should answer with before closing.
#[derive(Debug)]
pub struct HttpError {
    pub status: u16,
    pub reason: String,
}

impl HttpError {
    fn new(status: u16, reason: impl Into<String>) -> HttpError {
        HttpError { status, reason: reason.into() }
    }
}

/// Outcome of one [`read_request`] call on a keep-alive connection.
#[derive(Debug)]
pub enum ReadOutcome {
    /// Clean EOF before any request byte — the peer closed between
    /// requests, not an error.
    Closed,
    Request(HttpRequest),
}

/// Read and parse one request. `max_body` caps the declared
/// `Content-Length` (413 beyond it). I/O failures mid-request surface
/// as 400 — by then the stream framing is unrecoverable either way.
pub fn read_request(r: &mut impl BufRead, max_body: usize) -> Result<ReadOutcome, HttpError> {
    let mut header_bytes = 0usize;
    let start = match read_line(r, &mut header_bytes)? {
        None => return Ok(ReadOutcome::Closed),
        Some(line) => line,
    };
    let mut parts = start.split(' ');
    let (method, target, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => (m, t, v),
            _ => return Err(HttpError::new(400, format!("malformed start line {start:?}"))),
        };
    if !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(HttpError::new(400, format!("malformed method {method:?}")));
    }
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        _ => return Err(HttpError::new(400, format!("unsupported version {version:?}"))),
    };
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };

    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let line = match read_line(r, &mut header_bytes)? {
            None => return Err(HttpError::new(400, "eof inside headers")),
            Some(line) => line,
        };
        if line.is_empty() {
            break;
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| HttpError::new(400, format!("malformed header {line:?}")))?;
        if name.is_empty() || name.contains(' ') {
            return Err(HttpError::new(400, format!("malformed header name {name:?}")));
        }
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }

    let find = |name: &str| headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str());
    if find("transfer-encoding").is_some_and(|v| !v.eq_ignore_ascii_case("identity")) {
        return Err(HttpError::new(501, "transfer-encoding not supported"));
    }
    let body = match find("content-length") {
        Some(v) => {
            let n: usize = v
                .parse()
                .map_err(|_| HttpError::new(400, format!("bad content-length {v:?}")))?;
            if n > max_body {
                return Err(HttpError::new(413, format!("body {n} B over the {max_body} B cap")));
            }
            let mut body = vec![0u8; n];
            r.read_exact(&mut body).map_err(|e| HttpError::new(400, format!("body read: {e}")))?;
            body
        }
        None if method == "POST" || method == "PUT" => {
            return Err(HttpError::new(411, "length required"));
        }
        None => Vec::new(),
    };

    let keep_alive = match find("connection").map(str::to_ascii_lowercase) {
        Some(c) if c == "close" => false,
        Some(c) if c == "keep-alive" => true,
        _ => http11,
    };
    Ok(ReadOutcome::Request(HttpRequest {
        method: method.to_string(),
        path,
        query,
        headers,
        body,
        keep_alive,
    }))
}

/// One CRLF-terminated line (LF tolerated), `None` on clean EOF at a
/// line start, 431 when the cumulative header budget runs out.
fn read_line(r: &mut impl BufRead, header_bytes: &mut usize) -> Result<Option<String>, HttpError> {
    let mut buf = Vec::new();
    let budget = MAX_HEADER_BYTES - *header_bytes;
    let n = r
        .by_ref()
        .take(budget as u64)
        .read_until(b'\n', &mut buf)
        .map_err(|e| HttpError::new(400, format!("read: {e}")))?;
    if n == 0 {
        return if budget == 0 {
            Err(HttpError::new(431, "headers too large"))
        } else {
            Ok(None)
        };
    }
    if buf.last() != Some(&b'\n') {
        // Budget exhausted mid-line or EOF without a terminator.
        return if n == budget {
            Err(HttpError::new(431, "headers too large"))
        } else {
            Err(HttpError::new(400, "truncated line"))
        };
    }
    *header_bytes += n;
    buf.pop();
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    String::from_utf8(buf).map(Some).map_err(|_| HttpError::new(400, "non-utf8 header line"))
}

/// The standard reason phrase for the statuses the daemon emits.
pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        507 => "Insufficient Storage",
        _ => "Unknown",
    }
}

/// One response, always framed with an explicit `Content-Length`.
#[derive(Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub body: Vec<u8>,
    pub content_type: &'static str,
    /// Emitted as a `Retry-After` header (seconds) — the backpressure
    /// hint on 429/503.
    pub retry_after: Option<u32>,
    /// Ask the client to close (mirrors the request's keep-alive and
    /// forces close after protocol errors).
    pub close: bool,
}

impl HttpResponse {
    /// A JSON 200/error payload.
    pub fn json(status: u16, body: &Json) -> HttpResponse {
        HttpResponse {
            status,
            body: body.to_string().into_bytes(),
            content_type: "application/json",
            retry_after: None,
            close: false,
        }
    }

    /// A plain-text payload in the Prometheus exposition content type
    /// (`GET /metrics`).
    pub fn text(status: u16, body: String) -> HttpResponse {
        HttpResponse {
            status,
            body: body.into_bytes(),
            content_type: "text/plain; version=0.0.4",
            retry_after: None,
            close: false,
        }
    }

    /// A JSON error envelope: `{"error": status, "reason": msg}`.
    pub fn error(status: u16, reason: &str) -> HttpResponse {
        HttpResponse::json(
            status,
            &Json::obj(vec![
                ("error", Json::num(status as f64)),
                ("reason", Json::str(reason)),
            ]),
        )
    }

    pub fn with_retry_after(mut self, secs: u32) -> HttpResponse {
        self.retry_after = Some(secs);
        self
    }

    /// Serialize to the wire.
    pub fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        write!(w, "HTTP/1.1 {} {}\r\n", self.status, status_reason(self.status))?;
        write!(w, "Content-Type: {}\r\n", self.content_type)?;
        write!(w, "Content-Length: {}\r\n", self.body.len())?;
        if let Some(secs) = self.retry_after {
            write!(w, "Retry-After: {secs}\r\n")?;
        }
        if self.close {
            write!(w, "Connection: close\r\n")?;
        }
        write!(w, "\r\n")?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(bytes: &[u8]) -> Result<ReadOutcome, HttpError> {
        read_request(&mut std::io::BufReader::new(bytes), 4096)
    }

    fn request(bytes: &[u8]) -> HttpRequest {
        match parse(bytes) {
            Ok(ReadOutcome::Request(r)) => r,
            other => panic!("expected a request, got {other:?}"),
        }
    }

    #[test]
    fn parses_get_with_query_and_headers() {
        let r = request(b"GET /v1/stats?pretty=1 HTTP/1.1\r\nHost: x\r\nX-Tag: a b \r\n\r\n");
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/v1/stats");
        assert_eq!(r.query, "pretty=1");
        assert_eq!(r.header("host"), Some("x"));
        assert_eq!(r.header("X-TAG"), Some("a b"));
        assert!(r.body.is_empty());
        assert!(r.keep_alive);
    }

    #[test]
    fn parses_post_body_exactly() {
        let r = request(b"POST /v1/classify HTTP/1.1\r\ncontent-length: 4\r\n\r\nabcd");
        assert_eq!(r.method, "POST");
        assert_eq!(r.body, b"abcd");
    }

    #[test]
    fn clean_eof_is_closed_not_error() {
        assert!(matches!(parse(b"").unwrap(), ReadOutcome::Closed));
    }

    #[test]
    fn malformed_start_lines_are_400() {
        for bad in [
            &b"GET\r\n\r\n"[..],
            b"GET /x\r\n\r\n",
            b"GET /x HTTP/1.1 extra\r\n\r\n",
            b"get /x HTTP/1.1\r\n\r\n",
            b"GET /x HTTP/2\r\n\r\n",
            b" /x HTTP/1.1\r\n\r\n",
        ] {
            let err = parse(bad).unwrap_err();
            assert_eq!(err.status, 400, "{:?} -> {}", bad, err.reason);
        }
    }

    #[test]
    fn malformed_headers_are_400() {
        assert_eq!(parse(b"GET / HTTP/1.1\r\nno-colon-here\r\n\r\n").unwrap_err().status, 400);
        assert_eq!(parse(b"GET / HTTP/1.1\r\nbad name: v\r\n\r\n").unwrap_err().status, 400);
        assert_eq!(parse(b"GET / HTTP/1.1\r\nHost: x").unwrap_err().status, 400); // truncated
    }

    #[test]
    fn oversized_body_is_413_and_missing_length_is_411() {
        let huge = b"POST / HTTP/1.1\r\nContent-Length: 5000\r\n\r\n";
        assert_eq!(parse(huge).unwrap_err().status, 413);
        assert_eq!(parse(b"POST / HTTP/1.1\r\n\r\n").unwrap_err().status, 411);
        let neg = parse(b"POST / HTTP/1.1\r\nContent-Length: -1\r\n\r\nx");
        assert_eq!(neg.unwrap_err().status, 400);
    }

    #[test]
    fn oversized_headers_are_431() {
        let mut req = b"GET / HTTP/1.1\r\n".to_vec();
        req.extend_from_slice(format!("X-Pad: {}\r\n", "p".repeat(MAX_HEADER_BYTES)).as_bytes());
        req.extend_from_slice(b"\r\n");
        assert_eq!(parse(&req).unwrap_err().status, 431);
    }

    #[test]
    fn chunked_is_501() {
        let req = b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n";
        assert_eq!(parse(req).unwrap_err().status, 501);
    }

    #[test]
    fn connection_semantics() {
        assert!(!request(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n").keep_alive);
        assert!(!request(b"GET / HTTP/1.0\r\n\r\n").keep_alive);
        assert!(request(b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").keep_alive);
    }

    #[test]
    fn pipelined_keep_alive_requests_parse_in_sequence() {
        let wire = b"POST /a HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi\
                     GET /b HTTP/1.1\r\n\r\n\
                     GET /c HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut r = std::io::BufReader::new(&wire[..]);
        let a = match read_request(&mut r, 4096).unwrap() {
            ReadOutcome::Request(req) => req,
            other => panic!("{other:?}"),
        };
        assert_eq!((a.path.as_str(), &a.body[..]), ("/a", &b"hi"[..]));
        assert!(a.keep_alive);
        let b = match read_request(&mut r, 4096).unwrap() {
            ReadOutcome::Request(req) => req,
            other => panic!("{other:?}"),
        };
        assert_eq!(b.path, "/b");
        let c = match read_request(&mut r, 4096).unwrap() {
            ReadOutcome::Request(req) => req,
            other => panic!("{other:?}"),
        };
        assert_eq!(c.path, "/c");
        assert!(!c.keep_alive);
        assert!(matches!(read_request(&mut r, 4096).unwrap(), ReadOutcome::Closed));
    }

    #[test]
    fn response_wire_format() {
        let body = Json::obj(vec![("ok", Json::Bool(true))]);
        let mut out = Vec::new();
        HttpResponse::json(200, &body).write_to(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: 11\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{\"ok\":true}"), "{text}");

        let mut out = Vec::new();
        HttpResponse::error(429, "queue full").with_retry_after(1).write_to(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("Retry-After: 1\r\n"), "{text}");
    }
}
