//! Footprint-budgeted executor admission: the paper's §3 memory model
//! as serving capacity currency.
//!
//! The daemon keys resident executors by [`CacheKey`] — `(net,
//! PrecisionConfig, backend, storage)` — and admits a new one only
//! while the sum of every resident executor's
//! [`FootprintModel::fused_envelope`](crate::memory::FootprintModel::fused_envelope)
//! cost stays within the global `--mem-budget`. When a new key doesn't
//! fit, least-recently-used keys are evicted until it does (or the
//! request is refused outright if the key alone exceeds the budget).
//!
//! [`CacheLedger`] is deliberately executor-free — it tracks keys,
//! modeled costs, recency and worker placement, nothing that needs a
//! loaded network — so the admission math is unit-testable without
//! artifacts, and the server layer owns the actual executor lifetime
//! (workers drop evicted executors when the eviction message reaches
//! them). The invariant the tests pin: the resident cost sum never
//! exceeds the budget, before or after any admission.
//!
//! When the daemon runs with a packed-weight store ([`crate::store`]),
//! executors that share weight bitstreams (same network, same weight
//! formats, same storage mode) hold **one** mapping between them — the
//! ledger mirrors that by pricing the shared weight bytes once per
//! sharing key ([`CacheLedger::resident_cost`] is deduplicated;
//! [`CacheLedger::dedup_saved_bytes`] reports the discount).

use std::collections::HashMap;

use crate::backend::BackendKind;
use crate::memory::StorageMode;
use crate::search::space::PrecisionConfig;

/// Identity of one resident executor: everything that changes the
/// resident bytes or the numerics.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub net: String,
    pub cfg: PrecisionConfig,
    pub backend: BackendKind,
    pub storage: StorageMode,
}

#[derive(Clone, Debug)]
struct Entry {
    /// Modeled resident bytes (the fused envelope of the config).
    cost: f64,
    /// Logical clock of the last touch (admission or routed request).
    last_used: u64,
    /// Worker the executor lives on.
    worker: usize,
    /// When the executor's packed weights come out of the shared store
    /// ([`crate::store`]): the sharing key (net + weight formats +
    /// storage) and the weight bytes included in `cost` that are backed
    /// by one shared mapping. Entries with the same sharing key pay
    /// those bytes **once** in [`CacheLedger::resident_cost`].
    shared: Option<(String, f64)>,
}

/// Verdict of one [`CacheLedger::admit`] call.
#[derive(Clone, Debug, PartialEq)]
pub enum Admission {
    /// Already resident: route to its worker.
    Resident { worker: usize },
    /// Admitted after evicting `evicted` (possibly empty): the caller
    /// must load the executor on `worker` and drop the evicted ones.
    Admitted { worker: usize, evicted: Vec<CacheKey> },
    /// The key's cost alone exceeds the budget — no eviction pattern
    /// can ever fit it.
    TooLarge,
}

/// The executor-placement ledger: budget arithmetic, LRU recency and
/// worker load, no executors.
pub struct CacheLedger {
    budget: f64,
    n_workers: usize,
    tick: u64,
    entries: HashMap<CacheKey, Entry>,
    /// Lifetime counters surfaced in `/v1/stats` and `SERVE_*.json`.
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl CacheLedger {
    /// A ledger admitting executors worth at most `budget` modeled
    /// bytes, spread over `n_workers` workers.
    pub fn new(budget: f64, n_workers: usize) -> CacheLedger {
        CacheLedger {
            budget,
            n_workers: n_workers.max(1),
            tick: 0,
            entries: HashMap::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Deduplicated sum of resident modeled costs: entries that share a
    /// packed-weight mapping (same sharing key) pay the shared weight
    /// bytes once, because the process really does hold one mapping.
    pub fn resident_cost(&self) -> f64 {
        Self::deduped_cost(self.entries.values())
    }

    /// Undiscounted sum of the entries' modeled costs (as if nothing
    /// were shared) — `raw - resident_cost` is the dedup saving.
    pub fn raw_resident_cost(&self) -> f64 {
        self.entries.values().map(|e| e.cost).sum()
    }

    /// Bytes the budget arithmetic saves right now because resident
    /// executors share packed-weight mappings.
    pub fn dedup_saved_bytes(&self) -> f64 {
        self.raw_resident_cost() - self.resident_cost()
    }

    /// Deduped cost of an arbitrary entry set: total cost minus, per
    /// sharing key, everything beyond the largest member's shared bytes
    /// (the one physical mapping is priced at the largest claim).
    fn deduped_cost<'a>(entries: impl Iterator<Item = &'a Entry>) -> f64 {
        let mut total = 0f64;
        let mut groups: HashMap<&str, (f64, f64)> = HashMap::new(); // key -> (sum, max)
        for e in entries {
            total += e.cost;
            if let Some((key, bytes)) = &e.shared {
                let g = groups.entry(key.as_str()).or_insert((0.0, 0.0));
                g.0 += bytes;
                g.1 = g.1.max(*bytes);
            }
        }
        total - groups.values().map(|(sum, max)| sum - max).sum::<f64>()
    }

    /// What `resident_cost()` would be after also admitting an entry
    /// with (`cost`, `shared`).
    fn cost_with(&self, cost: f64, shared: &Option<(String, f64)>) -> f64 {
        let probe = Entry { cost, last_used: 0, worker: 0, shared: shared.clone() };
        Self::deduped_cost(self.entries.values().chain(std::iter::once(&probe)))
    }

    pub fn resident_len(&self) -> usize {
        self.entries.len()
    }

    pub fn budget(&self) -> f64 {
        self.budget
    }

    /// Resolve `key` (with modeled cost `cost`): touch-and-route on a
    /// hit, or find a placement by evicting LRU keys until it fits.
    /// Eviction victims come off the ledger immediately — the caller
    /// owns telling the victims' workers to drop the executors.
    ///
    /// `shared` declares the store-backed weight sharing of the new
    /// entry (see [`Entry::shared`]): while a same-key peer is
    /// resident, the shared bytes don't count against the budget a
    /// second time — so a config differing only in activation formats
    /// admits at roughly its activation cost.
    pub fn admit(
        &mut self,
        key: &CacheKey,
        cost: f64,
        shared: Option<(String, f64)>,
    ) -> Admission {
        self.tick += 1;
        if let Some(e) = self.entries.get_mut(key) {
            e.last_used = self.tick;
            self.hits += 1;
            return Admission::Resident { worker: e.worker };
        }
        self.misses += 1;
        if cost > self.budget {
            return Admission::TooLarge;
        }
        let mut evicted = Vec::new();
        while self.cost_with(cost, &shared) > self.budget {
            // Strict LRU: the least-recently-touched key goes first.
            // An empty ledger that is still over budget would mean the
            // new entry alone exceeds it, which the `cost > budget`
            // check above already excluded — but a daemon must not die
            // on an accounting bug, so degrade to a refusal instead of
            // panicking (surfaces as 507 at the HTTP layer).
            let Some(victim) =
                self.entries.iter().min_by_key(|(_, e)| e.last_used).map(|(k, _)| k.clone())
            else {
                debug_assert!(false, "over budget with no resident entries (cost {cost})");
                log::error!(
                    "serve cache: admission accounting underflow for cost {cost} \
                     against budget {}; refusing the key",
                    self.budget
                );
                return Admission::TooLarge;
            };
            self.entries.remove(&victim);
            self.evictions += 1;
            evicted.push(victim);
        }
        let worker = self.least_loaded_worker();
        self.entries.insert(key.clone(), Entry { cost, last_used: self.tick, worker, shared });
        Admission::Admitted { worker, evicted }
    }

    /// The worker holding the fewest resident executors (ties to the
    /// lowest index) — new executors spread across the pool so one
    /// worker doesn't serialize every config.
    fn least_loaded_worker(&self) -> usize {
        let mut load = vec![0usize; self.n_workers];
        for e in self.entries.values() {
            load[e.worker] += 1;
        }
        (0..self.n_workers).min_by_key(|&w| load[w]).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QFormat;

    fn key(net: &str, fbits: i8) -> CacheKey {
        CacheKey {
            net: net.to_string(),
            cfg: PrecisionConfig::uniform(3, QFormat::new(1, fbits), QFormat::new(8, 0)),
            backend: BackendKind::Fast,
            storage: StorageMode::Packed,
        }
    }

    #[test]
    fn admit_at_budget_edge_fits_exactly() {
        let mut c = CacheLedger::new(100.0, 2);
        let admitted = |worker| Admission::Admitted { worker, evicted: vec![] };
        assert_eq!(c.admit(&key("a", 1), 60.0, None), admitted(0));
        // 60 + 40 == 100: exactly at the budget is admitted, no eviction.
        assert_eq!(c.admit(&key("b", 1), 40.0, None), admitted(1));
        assert_eq!(c.resident_cost(), 100.0);
        // One more byte would not have fit: a third key forces eviction.
        match c.admit(&key("c", 1), 1.0, None) {
            Admission::Admitted { evicted, .. } => assert_eq!(evicted, vec![key("a", 1)]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn over_budget_key_is_too_large_not_evicting() {
        let mut c = CacheLedger::new(100.0, 1);
        assert!(matches!(c.admit(&key("a", 1), 80.0, None), Admission::Admitted { .. }));
        assert_eq!(c.admit(&key("b", 1), 100.1, None), Admission::TooLarge);
        // Nothing was evicted for an impossible key.
        assert_eq!(c.resident_len(), 1);
        assert_eq!(c.evictions, 0);
    }

    #[test]
    fn lru_eviction_order_follows_touches() {
        let mut c = CacheLedger::new(90.0, 1);
        c.admit(&key("a", 1), 30.0, None);
        c.admit(&key("b", 1), 30.0, None);
        c.admit(&key("c", 1), 30.0, None);
        // Touch a, then b: c is now least recent.
        assert_eq!(c.admit(&key("a", 1), 30.0, None), Admission::Resident { worker: 0 });
        assert_eq!(c.admit(&key("b", 1), 30.0, None), Admission::Resident { worker: 0 });
        match c.admit(&key("d", 1), 60.0, None) {
            // Evicts c then a (two LRU victims) to fit 60.
            Admission::Admitted { evicted, .. } => {
                assert_eq!(evicted, vec![key("c", 1), key("a", 1)]);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!((c.hits, c.misses, c.evictions), (2, 4, 2));
    }

    #[test]
    fn resident_sum_never_exceeds_budget() {
        let mut c = CacheLedger::new(100.0, 3);
        let costs = [55.0, 10.0, 45.0, 100.0, 1.0, 99.5, 37.0, 63.0, 0.5];
        for (i, &cost) in costs.iter().enumerate() {
            let verdict = c.admit(&key("net", i as i8 + 1), cost, None);
            assert_ne!(verdict, Admission::TooLarge, "cost {cost} fits the budget");
            assert!(
                c.resident_cost() <= c.budget() + 1e-9,
                "after admitting {cost}: resident {} > budget {}",
                c.resident_cost(),
                c.budget()
            );
        }
    }

    #[test]
    fn workers_balance_by_resident_count() {
        let mut c = CacheLedger::new(1e9, 3);
        let workers: Vec<usize> = (0..6)
            .map(|i| match c.admit(&key("n", i as i8 + 1), 10.0, None) {
                Admission::Admitted { worker, .. } => worker,
                other => panic!("{other:?}"),
            })
            .collect();
        assert_eq!(workers, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn distinct_configs_are_distinct_keys() {
        let mut c = CacheLedger::new(1e9, 1);
        c.admit(&key("a", 1), 10.0, None);
        assert!(matches!(c.admit(&key("a", 2), 10.0, None), Admission::Admitted { .. }));
        assert_eq!(c.admit(&key("a", 1), 10.0, None), Admission::Resident { worker: 0 });
        assert_eq!(c.resident_len(), 2);
    }

    fn shared(bytes: f64) -> Option<(String, f64)> {
        Some(("lenet-w1.8-packed".to_string(), bytes))
    }

    #[test]
    fn shared_weight_bytes_are_priced_once() {
        let mut c = CacheLedger::new(1e9, 1);
        // Two executors, 100 bytes each, 60 of which is one shared
        // weight mapping: the process holds 100 + 40 real bytes.
        c.admit(&key("a", 1), 100.0, shared(60.0));
        c.admit(&key("a", 2), 100.0, shared(60.0));
        assert_eq!(c.raw_resident_cost(), 200.0);
        assert_eq!(c.resident_cost(), 140.0);
        assert_eq!(c.dedup_saved_bytes(), 60.0);
        // A third peer only adds its activation slice.
        c.admit(&key("a", 3), 100.0, shared(60.0));
        assert_eq!(c.resident_cost(), 180.0);
        // Unshared entries are unaffected.
        c.admit(&key("b", 1), 10.0, None);
        assert_eq!(c.resident_cost(), 190.0);
    }

    #[test]
    fn dedup_discount_expands_effective_capacity() {
        // Budget fits one full executor plus one deduped peer, but not
        // two full copies.
        let mut c = CacheLedger::new(150.0, 1);
        assert!(matches!(c.admit(&key("a", 1), 100.0, shared(60.0)), Admission::Admitted { .. }));
        // Without sharing this would evict; with it, 100 + 40 = 140 fits.
        assert_eq!(
            c.admit(&key("a", 2), 100.0, shared(60.0)),
            Admission::Admitted { worker: 0, evicted: vec![] }
        );
        assert_eq!(c.resident_cost(), 140.0);
        // An unshared 100-byte key can't coexist with even one full
        // copy (100 + 100 > 150): both peers must go.
        match c.admit(&key("a", 3), 100.0, None) {
            Admission::Admitted { evicted, .. } => {
                assert_eq!(evicted, vec![key("a", 1), key("a", 2)]);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(c.resident_cost(), 100.0);
    }

    #[test]
    fn budget_invariant_holds_with_sharing() {
        let mut c = CacheLedger::new(100.0, 2);
        for i in 0..8 {
            let sh = if i % 2 == 0 { shared(30.0) } else { None };
            let verdict = c.admit(&key("n", i + 1), 60.0, sh);
            assert_ne!(verdict, Admission::TooLarge);
            assert!(c.resident_cost() <= c.budget() + 1e-9);
        }
    }
}
