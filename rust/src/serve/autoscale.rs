//! Closed-loop precision autoscaling for the serve daemon.
//!
//! A QoS controller thread samples the daemon's own signals — queue
//! occupancy ([`super::queue::InflightGate`]), arrival rate, and the
//! p99 latency the dispatch [`super::metrics::ServeMetrics`] histogram
//! already tracks — and moves each net's *active*
//! [`PrecisionConfig`] along its precomputed accuracy↔footprint
//! ladder ([`super::frontier::Frontier`]):
//!
//! * sustained pressure above `--high-water` for `--burst-ticks`
//!   consecutive ticks degrades one rung toward narrower widths
//!   (smaller envelope → more concurrent executors fit the
//!   [`super::cache::CacheLedger`] budget, less decode traffic);
//! * sustained pressure below `--low-water` for `--hysteresis-ticks`
//!   ticks recovers one rung back toward full width;
//! * the band between the watermarks resets both streaks, so the
//!   controller cannot flap across a noisy boundary;
//! * no rung whose measured relative accuracy loss exceeds
//!   `--accuracy-floor` is ever reachable — the floor is applied when
//!   the frontier is loaded ([`Frontier::usable_rungs`]), clamping the
//!   ladder itself rather than checking per decision.
//!
//! Transitions are one-rung-at-a-time and fully observable: a
//! `qbound_autoscale_rung` gauge and reason-labelled transition
//! counters in the registry, a bounded in-memory transition log
//! surfaced under `/v1/stats`, a span in the Chrome trace when tracing
//! is on, and a stderr log line. With a packed-weight store attached,
//! [`prewarm_store`] packs every usable rung's weights at startup so a
//! swap costs one mmap plus a ledger re-price — never a re-pack.

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use super::frontier::Frontier;
use crate::backend::gemm::{pack_b_panels, NR};
use crate::backend::lowering::{self, LoweredPlan};
use crate::backend::Variant;
use crate::memory::{PackedBuf, PackedPanels};
use crate::nets::NetManifest;
use crate::obs;
use crate::search::space::PrecisionConfig;
use crate::store::Store;
use crate::util::json::Json;

/// Knobs for the controller loop; defaults match the CLI flag
/// defaults documented in `docs/AUTOSCALING.md`.
#[derive(Clone, Debug)]
pub struct AutoscaleOptions {
    /// Directory holding `FRONTIER_<net>.json` ladders.
    pub frontier_dir: String,
    /// Maximum relative accuracy loss vs fp32 any served rung may have.
    pub accuracy_floor: f64,
    /// Pressure above this degrades (after `burst_ticks` in a row).
    pub high_water: f64,
    /// Pressure below this recovers (after `hysteresis_ticks` in a row).
    pub low_water: f64,
    /// Consecutive hot ticks required before degrading one rung.
    pub burst_ticks: usize,
    /// Consecutive calm ticks required before recovering one rung.
    pub hysteresis_ticks: usize,
    /// Controller sampling period, milliseconds.
    pub tick_ms: u64,
    /// Optional p99 latency SLO in microseconds; when positive, the
    /// pressure signal is `max(queue occupancy, p99 / slo)`.
    pub p99_slo_us: f64,
}

impl Default for AutoscaleOptions {
    fn default() -> Self {
        AutoscaleOptions {
            frontier_dir: "bench-out".to_string(),
            accuracy_floor: 0.01,
            high_water: 0.75,
            low_water: 0.25,
            burst_ticks: 2,
            hysteresis_ticks: 3,
            tick_ms: 200,
            p99_slo_us: 0.0,
        }
    }
}

impl AutoscaleOptions {
    /// Reject knob combinations with no sane interpretation.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.accuracy_floor >= 0.0,
            "--accuracy-floor must be >= 0 (got {})",
            self.accuracy_floor
        );
        anyhow::ensure!(
            self.low_water < self.high_water,
            "--low-water ({}) must be below --high-water ({})",
            self.low_water,
            self.high_water
        );
        anyhow::ensure!(self.high_water > 0.0, "--high-water must be positive");
        anyhow::ensure!(self.burst_ticks >= 1, "--burst-ticks must be >= 1");
        anyhow::ensure!(self.hysteresis_ticks >= 1, "--hysteresis-ticks must be >= 1");
        anyhow::ensure!(self.tick_ms >= 1, "--tick-ms must be >= 1");
        Ok(())
    }
}

/// One controller-tick observation of the daemon's load signals.
#[derive(Clone, Copy, Debug, Default)]
pub struct MetricSample {
    /// In-flight requests over queue capacity, in [0, 1].
    pub queue_frac: f64,
    /// Requests per second since the previous tick.
    pub arrival_hz: f64,
    /// p99 request latency from the serve histogram, microseconds.
    pub p99_us: f64,
}

/// A rung change the controller decided on: `reason` is `"burst"`
/// (degrade, `to == from + 1`) or `"drain"` (recover, `to == from - 1`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Transition {
    pub from: usize,
    pub to: usize,
    pub reason: &'static str,
}

/// The per-net hysteresis state machine: pure and synchronous, so the
/// watermark/streak semantics are unit-testable with synthetic feeds.
///
/// ```
/// use qbound::serve::autoscale::{AutoscaleOptions, MetricSample, RungController};
///
/// let opts = AutoscaleOptions {
///     high_water: 0.75,
///     low_water: 0.25,
///     burst_ticks: 2,
///     hysteresis_ticks: 2,
///     ..AutoscaleOptions::default()
/// };
/// let mut c = RungController::new(3, &opts);
/// let hot = MetricSample { queue_frac: 1.0, ..Default::default() };
/// let calm = MetricSample { queue_frac: 0.0, ..Default::default() };
/// assert!(c.observe(&hot).is_none(), "one hot tick is not a burst");
/// let t = c.observe(&hot).expect("second hot tick degrades");
/// assert_eq!((t.from, t.to, t.reason), (0, 1, "burst"));
/// assert!(c.observe(&calm).is_none());
/// let t = c.observe(&calm).expect("second calm tick recovers");
/// assert_eq!((t.from, t.to, t.reason), (1, 0, "drain"));
/// ```
#[derive(Debug)]
pub struct RungController {
    usable: usize,
    active: usize,
    high_water: f64,
    low_water: f64,
    burst_ticks: usize,
    hysteresis_ticks: usize,
    p99_slo_us: f64,
    hot: usize,
    calm: usize,
}

impl RungController {
    /// A controller over `usable` floor-respecting rungs (indices
    /// `0..usable`), starting at rung 0 (widest).
    pub fn new(usable: usize, opts: &AutoscaleOptions) -> RungController {
        RungController {
            usable,
            active: 0,
            high_water: opts.high_water,
            low_water: opts.low_water,
            burst_ticks: opts.burst_ticks,
            hysteresis_ticks: opts.hysteresis_ticks,
            p99_slo_us: opts.p99_slo_us,
            hot: 0,
            calm: 0,
        }
    }

    /// The currently selected rung index.
    pub fn active(&self) -> usize {
        self.active
    }

    /// Feed one tick's sample; returns the transition taken, if any.
    pub fn observe(&mut self, s: &MetricSample) -> Option<Transition> {
        let mut pressure = s.queue_frac;
        if self.p99_slo_us > 0.0 {
            pressure = pressure.max(s.p99_us / self.p99_slo_us);
        }
        if pressure > self.high_water {
            self.hot += 1;
            self.calm = 0;
        } else if pressure < self.low_water {
            self.calm += 1;
            self.hot = 0;
        } else {
            // Dead band: reset both streaks so a load level hovering
            // between the watermarks can never flap the rung.
            self.hot = 0;
            self.calm = 0;
        }
        if self.hot >= self.burst_ticks && self.active + 1 < self.usable {
            let from = self.active;
            self.active += 1;
            self.hot = 0;
            self.calm = 0;
            return Some(Transition { from, to: self.active, reason: "burst" });
        }
        if self.calm >= self.hysteresis_ticks && self.active > 0 {
            let from = self.active;
            self.active -= 1;
            self.hot = 0;
            self.calm = 0;
            return Some(Transition { from, to: self.active, reason: "drain" });
        }
        None
    }
}

/// One transition as recorded for `/v1/stats` and `AUTOSCALE_*.json`.
#[derive(Clone, Debug)]
struct TransitionRecord {
    t_ms: f64,
    net: String,
    from: usize,
    to: usize,
    reason: &'static str,
    queue_frac: f64,
    arrival_hz: f64,
    p99_us: f64,
}

impl TransitionRecord {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("t_ms", Json::num(self.t_ms)),
            ("net", Json::str(self.net.clone())),
            ("from", Json::num(self.from as f64)),
            ("to", Json::num(self.to as f64)),
            ("reason", Json::str(self.reason)),
            ("queue_frac", Json::num(self.queue_frac)),
            ("arrival_hz", Json::num(self.arrival_hz)),
            ("p99_us", Json::num(self.p99_us)),
        ])
    }
}

/// Cap on the in-memory transition log surfaced by `/v1/stats` —
/// oldest entries drop first, counters keep the full totals.
const MAX_TRANSITIONS: usize = 256;

struct NetAutoscale {
    frontier: Frontier,
    usable: usize,
    active: AtomicUsize,
    controller: Mutex<RungController>,
    rung_gauge: obs::registry::Gauge,
}

/// Shared controller state: one ladder + state machine per net with a
/// frontier file, plus the bounded transition log.
pub struct AutoscaleState {
    opts: AutoscaleOptions,
    nets: BTreeMap<String, NetAutoscale>,
    transitions: Mutex<Vec<TransitionRecord>>,
    degrades: AtomicU64,
    recoveries: AtomicU64,
    started: Instant,
}

impl AutoscaleState {
    /// Load `FRONTIER_<net>.json` for every served net (from
    /// `opts.frontier_dir`), clamp each ladder at the accuracy floor,
    /// and build the per-net controllers. Nets without a frontier file
    /// are left static (logged); it is an error if *no* net has one,
    /// or if a loaded ladder disagrees with the net's layer count.
    pub fn build(
        opts: AutoscaleOptions,
        layer_counts: &HashMap<String, usize>,
    ) -> Result<AutoscaleState> {
        opts.validate()?;
        let dir = Path::new(&opts.frontier_dir);
        let mut nets = BTreeMap::new();
        let mut names: Vec<&String> = layer_counts.keys().collect();
        names.sort();
        for net in names {
            let path = dir.join(Frontier::file_name(net));
            if !path.exists() {
                log::warn!(
                    "autoscale: no {} — {net} will serve its static config \
                     (run `qbound frontier --net {net}`)",
                    path.display()
                );
                continue;
            }
            let frontier = Frontier::load(&path)?;
            anyhow::ensure!(
                frontier.net == *net,
                "frontier {} is for net {:?}, expected {net:?}",
                path.display(),
                frontier.net
            );
            anyhow::ensure!(
                frontier.rungs[0].cfg.n_layers() == layer_counts[net],
                "frontier {} has {}-layer configs but {net} has {} layers \
                 (stale artifacts? re-run `qbound frontier`)",
                path.display(),
                frontier.rungs[0].cfg.n_layers(),
                layer_counts[net]
            );
            let usable = frontier.usable_rungs(opts.accuracy_floor);
            anyhow::ensure!(
                usable >= 1,
                "frontier {}: no rung respects --accuracy-floor {}",
                path.display(),
                opts.accuracy_floor
            );
            let rung_gauge = obs::gauge(
                "qbound_autoscale_rung",
                "active precision rung per net (0 = widest)",
                &[("net", net)],
            );
            rung_gauge.set(0);
            log::info!(
                "autoscale: {net} ladder loaded — {} rung(s), {usable} within floor {}",
                frontier.rungs.len(),
                opts.accuracy_floor
            );
            nets.insert(
                net.clone(),
                NetAutoscale {
                    usable,
                    active: AtomicUsize::new(0),
                    controller: Mutex::new(RungController::new(usable, &opts)),
                    rung_gauge,
                    frontier,
                },
            );
        }
        anyhow::ensure!(
            !nets.is_empty(),
            "autoscale enabled but no FRONTIER_<net>.json found in {} \
             (run `qbound frontier` first)",
            dir.display()
        );
        Ok(AutoscaleState {
            opts,
            nets,
            transitions: Mutex::new(Vec::new()),
            degrades: AtomicU64::new(0),
            recoveries: AtomicU64::new(0),
            started: Instant::now(),
        })
    }

    pub fn opts(&self) -> &AutoscaleOptions {
        &self.opts
    }

    /// The config a request for `net` should execute right now, with
    /// its rung index; `None` for nets without a ladder (serve static).
    pub fn active_cfg(&self, net: &str) -> Option<(usize, PrecisionConfig)> {
        let na = self.nets.get(net)?;
        let rung = na.active.load(Ordering::Relaxed).min(na.usable - 1);
        Some((rung, na.frontier.rungs[rung].cfg.clone()))
    }

    /// Feed one sample to every net's controller; applies and records
    /// any transitions, returning them for the caller's logs.
    pub fn tick(&self, s: &MetricSample) -> Vec<(String, Transition)> {
        let mut taken = Vec::new();
        for (net, na) in &self.nets {
            let t = {
                let mut c = na.controller.lock().unwrap_or_else(|p| p.into_inner());
                c.observe(s)
            };
            if let Some(t) = t {
                na.active.store(t.to, Ordering::Relaxed);
                self.record(net, na, &t, s);
                taken.push((net.clone(), t));
            }
        }
        taken
    }

    fn record(&self, net: &str, na: &NetAutoscale, t: &Transition, s: &MetricSample) {
        na.rung_gauge.set(t.to as i64);
        obs::counter(
            "qbound_autoscale_transitions_total",
            "precision rung transitions by net and reason",
            &[("net", net), ("reason", t.reason)],
        )
        .inc();
        if t.reason == "burst" {
            self.degrades.fetch_add(1, Ordering::Relaxed);
        } else {
            self.recoveries.fetch_add(1, Ordering::Relaxed);
        }
        if obs::tracing_on() {
            obs::span::emit(
                "autoscale_transition",
                format!(
                    "net={net} rung={}->{} reason={} queue_frac={:.2} p99_us={:.0}",
                    t.from, t.to, t.reason, s.queue_frac, s.p99_us
                ),
                obs::span::now_us(),
                0,
            );
        }
        log::info!(
            "autoscale: {net} rung {} -> {} ({}) [queue {:.0}%, {:.1} req/s, p99 {:.0}us] \
             now serving {}",
            t.from,
            t.to,
            t.reason,
            s.queue_frac * 100.0,
            s.arrival_hz,
            s.p99_us,
            na.frontier.rungs[t.to].cfg.notation()
        );
        let rec = TransitionRecord {
            t_ms: self.started.elapsed().as_secs_f64() * 1e3,
            net: net.to_string(),
            from: t.from,
            to: t.to,
            reason: t.reason,
            queue_frac: s.queue_frac,
            arrival_hz: s.arrival_hz,
            p99_us: s.p99_us,
        };
        let mut log = self.transitions.lock().unwrap_or_else(|p| p.into_inner());
        if log.len() >= MAX_TRANSITIONS {
            log.remove(0);
        }
        log.push(rec);
    }

    /// The `autoscale` block of `/v1/stats` (and `AUTOSCALE_*.json`).
    pub fn stats_json(&self) -> Json {
        let mut net_map = BTreeMap::new();
        for (net, na) in &self.nets {
            let rung = na.active.load(Ordering::Relaxed).min(na.usable - 1);
            let r = &na.frontier.rungs[rung];
            net_map.insert(
                net.clone(),
                Json::obj(vec![
                    ("active_rung", Json::num(rung as f64)),
                    ("rungs", Json::num(na.frontier.rungs.len() as f64)),
                    ("usable_rungs", Json::num(na.usable as f64)),
                    ("active_rel_err", Json::num(r.rel_err)),
                    ("active_config", Json::str(r.cfg.notation())),
                    ("baseline_accuracy", Json::num(na.frontier.baseline_accuracy)),
                ]),
            );
        }
        let nets = Json::Obj(net_map);
        let transitions = {
            let log = self.transitions.lock().unwrap_or_else(|p| p.into_inner());
            Json::arr(log.iter().map(TransitionRecord::to_json))
        };
        Json::obj(vec![
            ("enabled", Json::Bool(true)),
            ("accuracy_floor", Json::num(self.opts.accuracy_floor)),
            ("high_water", Json::num(self.opts.high_water)),
            ("low_water", Json::num(self.opts.low_water)),
            ("burst_ticks", Json::num(self.opts.burst_ticks as f64)),
            ("hysteresis_ticks", Json::num(self.opts.hysteresis_ticks as f64)),
            ("tick_ms", Json::num(self.opts.tick_ms as f64)),
            ("degrades", Json::num(self.degrades.load(Ordering::Relaxed) as f64)),
            ("recoveries", Json::num(self.recoveries.load(Ordering::Relaxed) as f64)),
            ("nets", nets),
            ("transitions", transitions),
        ])
    }
}

/// Pack every usable rung's weight tensors through the store, exactly
/// as `qbound store warm` does for uniform ladders — same
/// `(tensor, layout, format)` keys the fast packed executors resolve —
/// so later rung swaps are pure mmap loads. Returns the number of
/// fresh packs (0 on a warm store).
pub fn prewarm_store(store: &Store, artifacts: &Path, state: &AutoscaleState) -> Result<u64> {
    let before = store.stats();
    for (net, na) in &state.nets {
        let manifest = NetManifest::load(artifacts, net)
            .with_context(|| format!("autoscale prewarm: loading {net} manifest"))?;
        let loaded = lowering::load_network(&manifest, Variant::Standard)?;
        let plan = LoweredPlan::new(&loaded.arch, None)?;
        let mut gemm_shape: Vec<Option<(usize, usize)>> = vec![None; loaded.params.len()];
        for t in lowering::gemm_tensors(&plan.steps) {
            gemm_shape[t.param] = Some((t.kd, t.n));
        }
        for rung in &na.frontier.rungs[..na.usable] {
            let per_tensor = plan.per_tensor_formats(&rung.cfg.wq);
            for (i, p) in loaded.params.iter().enumerate() {
                match gemm_shape[i] {
                    Some((kd, n)) => {
                        let _ = store.panels_for(p, per_tensor[i], kd, n, NR, || {
                            PackedPanels::pack(per_tensor[i], &pack_b_panels(p, kd, n), kd, NR)
                        });
                    }
                    None => {
                        let _ =
                            store.buf_for(p, per_tensor[i], || PackedBuf::pack(per_tensor[i], p));
                    }
                }
            }
        }
    }
    let after = store.stats();
    Ok(after.packs - before.packs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QFormat;
    use crate::serve::frontier::Rung;

    fn opts() -> AutoscaleOptions {
        AutoscaleOptions {
            high_water: 0.75,
            low_water: 0.25,
            burst_ticks: 2,
            hysteresis_ticks: 3,
            ..AutoscaleOptions::default()
        }
    }

    fn hot() -> MetricSample {
        MetricSample { queue_frac: 1.0, arrival_hz: 50.0, p99_us: 900.0 }
    }

    fn calm() -> MetricSample {
        MetricSample { queue_frac: 0.0, arrival_hz: 1.0, p99_us: 100.0 }
    }

    fn mid() -> MetricSample {
        MetricSample { queue_frac: 0.5, arrival_hz: 10.0, p99_us: 400.0 }
    }

    #[test]
    fn degrades_only_after_burst_ticks_in_a_row() {
        let mut c = RungController::new(3, &opts());
        assert_eq!(c.observe(&hot()), None);
        assert_eq!(
            c.observe(&hot()),
            Some(Transition { from: 0, to: 1, reason: "burst" })
        );
        // Streak resets after a transition: one more hot tick is not enough.
        assert_eq!(c.observe(&hot()), None);
        assert_eq!(
            c.observe(&hot()),
            Some(Transition { from: 1, to: 2, reason: "burst" })
        );
    }

    #[test]
    fn never_degrades_past_the_floor_clamped_ladder() {
        let mut c = RungController::new(2, &opts());
        assert_eq!(c.observe(&hot()), None);
        assert_eq!(c.observe(&hot()).map(|t| t.to), Some(1));
        for _ in 0..20 {
            assert_eq!(c.observe(&hot()), None, "rung must saturate at usable-1");
        }
        assert_eq!(c.active(), 1);
    }

    #[test]
    fn single_rung_ladder_never_moves() {
        let mut c = RungController::new(1, &opts());
        for _ in 0..10 {
            assert_eq!(c.observe(&hot()), None);
        }
        for _ in 0..10 {
            assert_eq!(c.observe(&calm()), None);
        }
        assert_eq!(c.active(), 0);
    }

    #[test]
    fn recovers_only_after_hysteresis_window() {
        let mut c = RungController::new(3, &opts());
        c.observe(&hot());
        c.observe(&hot());
        assert_eq!(c.active(), 1);
        assert_eq!(c.observe(&calm()), None);
        assert_eq!(c.observe(&calm()), None);
        assert_eq!(
            c.observe(&calm()),
            Some(Transition { from: 1, to: 0, reason: "drain" })
        );
        // At the widest rung, calm ticks are a no-op.
        for _ in 0..10 {
            assert_eq!(c.observe(&calm()), None);
        }
        assert_eq!(c.active(), 0);
    }

    #[test]
    fn dead_band_resets_streaks_so_no_flapping() {
        let mut c = RungController::new(3, &opts());
        // hot, mid, hot, mid ... never two hot in a row => never degrades.
        for _ in 0..10 {
            assert_eq!(c.observe(&hot()), None);
            assert_eq!(c.observe(&mid()), None);
        }
        assert_eq!(c.active(), 0);
        // Same once degraded: calm streaks broken by the dead band
        // never recover.
        c.observe(&hot());
        c.observe(&hot());
        assert_eq!(c.active(), 1);
        for _ in 0..10 {
            assert_eq!(c.observe(&calm()), None);
            assert_eq!(c.observe(&calm()), None);
            assert_eq!(c.observe(&mid()), None);
        }
        assert_eq!(c.active(), 1);
    }

    #[test]
    fn p99_slo_pressure_degrades_even_with_an_empty_queue() {
        let mut c = RungController::new(2, &AutoscaleOptions { p99_slo_us: 1000.0, ..opts() });
        let slow = MetricSample { queue_frac: 0.0, arrival_hz: 2.0, p99_us: 5000.0 };
        assert_eq!(c.observe(&slow), None);
        assert_eq!(c.observe(&slow).map(|t| t.reason), Some("burst"));
    }

    fn ladder(net: &str, n_layers: usize) -> Frontier {
        let rung = |w, acc: f64, fp: f64| Rung {
            cfg: PrecisionConfig::uniform(n_layers, w, QFormat::new(10, 4)),
            accuracy: acc,
            rel_err: (0.9 - acc) / 0.9,
            footprint_ratio: fp,
            envelope_bytes: fp * 1.0e6,
        };
        Frontier {
            net: net.to_string(),
            baseline_accuracy: 0.9,
            rungs: vec![
                rung(QFormat::new(2, 8), 0.9, 0.5),
                rung(QFormat::new(1, 8), 0.897, 0.42),
                rung(QFormat::new(1, 5), 0.85, 0.3), // 5.6% rel loss: outside a 1% floor
            ],
        }
    }

    fn temp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("qbound-autoscale-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn build_clamps_ladders_at_the_floor_and_scales_within_it() {
        let dir = temp_dir("build");
        ladder("lenet", 4).save(&dir.join(Frontier::file_name("lenet"))).unwrap();
        let counts = HashMap::from([("lenet".to_string(), 4usize)]);
        let opts = AutoscaleOptions {
            frontier_dir: dir.display().to_string(),
            ..AutoscaleOptions::default()
        };
        let state = AutoscaleState::build(opts, &counts).unwrap();
        let (rung, cfg) = state.active_cfg("lenet").unwrap();
        assert_eq!(rung, 0);
        assert_eq!(cfg.wq[0], QFormat::new(2, 8));

        // Drive a burst: rung must stop at 1 (rung 2 busts the floor).
        for _ in 0..10 {
            state.tick(&hot());
        }
        let (rung, cfg) = state.active_cfg("lenet").unwrap();
        assert_eq!(rung, 1, "floor-violating rung 2 must be unreachable");
        assert_eq!(cfg.wq[0], QFormat::new(1, 8));

        // Drain: back to the widest rung.
        for _ in 0..10 {
            state.tick(&calm());
        }
        assert_eq!(state.active_cfg("lenet").unwrap().0, 0);

        let j = state.stats_json();
        assert!(j.get("degrades").and_then(Json::as_u64).unwrap() >= 1);
        assert!(j.get("recoveries").and_then(Json::as_u64).unwrap() >= 1);
        assert_eq!(
            j.at(&["nets", "lenet", "usable_rungs"]).as_u64(),
            Some(2)
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn build_rejects_layer_count_drift_and_requires_some_ladder() {
        let dir = temp_dir("drift");
        ladder("lenet", 4).save(&dir.join(Frontier::file_name("lenet"))).unwrap();
        let counts = HashMap::from([("lenet".to_string(), 5usize)]);
        let opts = AutoscaleOptions {
            frontier_dir: dir.display().to_string(),
            ..AutoscaleOptions::default()
        };
        assert!(AutoscaleState::build(opts.clone(), &counts).is_err());

        let counts = HashMap::from([("other".to_string(), 4usize)]);
        assert!(
            AutoscaleState::build(opts, &counts).is_err(),
            "no net with a frontier file must be an error"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
