//! The accuracy↔footprint frontier the autoscaler walks: an ordered
//! ladder of precision "rungs" per network, precomputed offline by
//! `qbound frontier` from the greedy-descent trajectory (paper Fig 5 /
//! Table 2) and loaded by the serve daemon from `FRONTIER_<net>.json`.
//!
//! Rung 0 is the *widest* (highest-accuracy, largest-footprint)
//! operating point; each following rung narrows the per-layer widths
//! along the Pareto frontier. The controller
//! ([`super::autoscale`]) only ever moves one rung at a time, and only
//! inside the floor-clamped prefix ([`Frontier::usable_rungs`]), so the
//! configured relative-accuracy floor is enforced *structurally*: a
//! rung whose measured `rel_err` busts the floor is unreachable, not
//! merely discouraged.

use std::path::Path;

use anyhow::{Context, Result};

use crate::quant::QFormat;
use crate::search::space::PrecisionConfig;
use crate::util;
use crate::util::json::Json;

/// One operating point on a net's accuracy↔footprint frontier.
///
/// `rel_err` is the measured relative accuracy loss vs the fp32
/// baseline (`(baseline - accuracy) / baseline`), the quantity the
/// `--accuracy-floor` guarantee is stated in; `footprint_ratio` is the
/// modeled resident-byte ratio vs fp32
/// ([`crate::memory::FootprintModel::ratio`]); `envelope_bytes` is the
/// serve-admission cost of one executor at this rung
/// (`FootprintModel::fused_envelope`), so the daemon can price a swap
/// without re-deriving the model.
///
/// ```
/// use qbound::quant::QFormat;
/// use qbound::search::space::PrecisionConfig;
/// use qbound::serve::frontier::Rung;
///
/// let rung = Rung {
///     cfg: PrecisionConfig::uniform(3, QFormat::new(1, 8), QFormat::new(10, 4)),
///     accuracy: 0.94,
///     rel_err: 0.005,
///     footprint_ratio: 0.41,
///     envelope_bytes: 8.0e5,
/// };
/// assert_eq!(rung.cfg.n_layers(), 3);
/// assert!(rung.rel_err < 0.01, "within a 1% floor");
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct Rung {
    /// The per-layer precision assignment served at this rung.
    pub cfg: PrecisionConfig,
    /// Measured top-1 accuracy at this rung (same eval split as the
    /// descent that produced it).
    pub accuracy: f64,
    /// Relative accuracy loss vs the fp32 baseline, in [0, 1].
    pub rel_err: f64,
    /// Modeled data-footprint ratio vs fp32 (Table-2 ranking key).
    pub footprint_ratio: f64,
    /// Serve-admission envelope of one executor at this rung, in bytes.
    pub envelope_bytes: f64,
}

impl Rung {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("wq", fmts_json(&self.cfg.wq)),
            ("dq", fmts_json(&self.cfg.dq)),
            ("config", Json::str(self.cfg.notation())),
            ("accuracy", Json::num(self.accuracy)),
            ("rel_err", Json::num(self.rel_err)),
            ("footprint_ratio", Json::num(self.footprint_ratio)),
            ("envelope_bytes", Json::num(self.envelope_bytes)),
        ])
    }

    fn from_json(j: &Json) -> Result<Rung> {
        let num = |field: &str| {
            j.get(field)
                .and_then(Json::as_f64)
                .with_context(|| format!("rung: missing numeric field {field:?}"))
        };
        Ok(Rung {
            cfg: PrecisionConfig { wq: fmts_from(j, "wq")?, dq: fmts_from(j, "dq")? },
            accuracy: num("accuracy")?,
            rel_err: num("rel_err")?,
            footprint_ratio: num("footprint_ratio")?,
            envelope_bytes: num("envelope_bytes")?,
        })
    }
}

/// A net's full rung ladder: rung 0 widest, monotonically narrowing.
///
/// Round-trips through the `FRONTIER_<net>.json` schema `qbound
/// frontier` emits and `qbound serve --autoscale` loads:
///
/// ```
/// use qbound::quant::QFormat;
/// use qbound::search::space::PrecisionConfig;
/// use qbound::serve::frontier::{Frontier, Rung};
///
/// let rung = |w, d, acc: f64, fp: f64| Rung {
///     cfg: PrecisionConfig::uniform(2, w, d),
///     accuracy: acc,
///     rel_err: (0.95 - acc) / 0.95,
///     footprint_ratio: fp,
///     envelope_bytes: fp * 1.0e6,
/// };
/// let f = Frontier {
///     net: "lenet".to_string(),
///     baseline_accuracy: 0.95,
///     rungs: vec![
///         rung(QFormat::new(2, 7), QFormat::new(10, 4), 0.95, 0.45),
///         rung(QFormat::new(1, 7), QFormat::new(9, 3), 0.945, 0.38),
///         rung(QFormat::new(1, 5), QFormat::new(8, 2), 0.88, 0.30),
///     ],
/// };
/// f.validate().unwrap();
/// // The last rung loses ~7.4% relative accuracy: a 1% floor clamps it off.
/// assert_eq!(f.usable_rungs(0.01), 2);
/// let back = Frontier::from_json(&f.to_json()).unwrap();
/// assert_eq!(back.rungs.len(), 3);
/// assert_eq!(back.rungs[2].cfg, f.rungs[2].cfg);
/// ```
#[derive(Clone, Debug)]
pub struct Frontier {
    /// Network the ladder belongs to.
    pub net: String,
    /// fp32 top-1 accuracy the `rel_err` values are relative to.
    pub baseline_accuracy: f64,
    /// Operating points, widest first.
    pub rungs: Vec<Rung>,
}

impl Frontier {
    /// The artifact name convention: `FRONTIER_<net>.json`.
    pub fn file_name(net: &str) -> String {
        format!("FRONTIER_{net}.json")
    }

    /// Structural sanity: at least one rung, every rung over the same
    /// layer count, footprint non-increasing and relative error
    /// non-decreasing down the ladder (rung 0 widest). The serve daemon
    /// refuses a frontier that fails this rather than scaling along a
    /// mis-ordered ladder.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.rungs.is_empty(), "frontier for {}: no rungs", self.net);
        anyhow::ensure!(
            self.baseline_accuracy > 0.0,
            "frontier for {}: non-positive baseline accuracy",
            self.net
        );
        let n_layers = self.rungs[0].cfg.n_layers();
        for (i, r) in self.rungs.iter().enumerate() {
            anyhow::ensure!(
                r.cfg.n_layers() == n_layers,
                "frontier for {}: rung {i} has {} layers, rung 0 has {n_layers}",
                self.net,
                r.cfg.n_layers()
            );
            anyhow::ensure!(
                r.rel_err >= -1e-9,
                "frontier for {}: rung {i} has negative rel_err {}",
                self.net,
                r.rel_err
            );
            if i > 0 {
                let prev = &self.rungs[i - 1];
                anyhow::ensure!(
                    r.footprint_ratio <= prev.footprint_ratio + 1e-9,
                    "frontier for {}: rung {i} footprint {} above rung {} ({})",
                    self.net,
                    r.footprint_ratio,
                    i - 1,
                    prev.footprint_ratio
                );
                anyhow::ensure!(
                    r.rel_err >= prev.rel_err - 1e-9,
                    "frontier for {}: rung {i} rel_err {} below rung {} ({})",
                    self.net,
                    r.rel_err,
                    i - 1,
                    prev.rel_err
                );
            }
        }
        Ok(())
    }

    /// How many leading rungs respect an accuracy floor: the count `n`
    /// such that `rungs[..n]` all lose at most `floor` relative
    /// accuracy vs fp32. The controller never selects a rung at or past
    /// this index, which is the whole floor guarantee.
    pub fn usable_rungs(&self, floor: f64) -> usize {
        self.rungs.iter().take_while(|r| r.rel_err <= floor + 1e-12).count()
    }

    /// Serialize to the `FRONTIER_<net>.json` schema.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::num(1.0)),
            ("net", Json::str(self.net.clone())),
            ("baseline_accuracy", Json::num(self.baseline_accuracy)),
            ("rungs", Json::arr(self.rungs.iter().map(Rung::to_json))),
        ])
    }

    /// Parse the `FRONTIER_<net>.json` schema (inverse of
    /// [`Frontier::to_json`]); structural checks are the caller's
    /// [`Frontier::validate`].
    pub fn from_json(j: &Json) -> Result<Frontier> {
        let net = j.get("net").and_then(Json::as_str).context("frontier: missing \"net\"")?;
        let baseline = j
            .get("baseline_accuracy")
            .and_then(Json::as_f64)
            .context("frontier: missing \"baseline_accuracy\"")?;
        let rungs = j
            .get("rungs")
            .and_then(Json::as_arr)
            .context("frontier: missing \"rungs\" array")?
            .iter()
            .map(Rung::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Frontier { net: net.to_string(), baseline_accuracy: baseline, rungs })
    }

    /// Load and validate a frontier file.
    pub fn load(path: &Path) -> Result<Frontier> {
        let text = util::read_to_string(path)
            .with_context(|| format!("reading frontier {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(anyhow::Error::from)
            .with_context(|| format!("parsing frontier {}", path.display()))?;
        let f = Frontier::from_json(&j)?;
        f.validate()?;
        Ok(f)
    }

    /// Write the frontier as pretty JSON (creating parent directories).
    pub fn save(&self, path: &Path) -> Result<()> {
        util::write_file(path, self.to_json().pretty().as_bytes())
    }
}

fn fmts_json(v: &[QFormat]) -> Json {
    Json::arr(v.iter().map(|q| Json::str(q.to_string())))
}

fn fmts_from(j: &Json, field: &str) -> Result<Vec<QFormat>> {
    j.get(field)
        .and_then(Json::as_arr)
        .with_context(|| format!("rung: missing array field {field:?}"))?
        .iter()
        .map(|s| {
            let s = s.as_str().with_context(|| format!("rung: non-string entry in {field:?}"))?;
            QFormat::parse(s)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ladder() -> Frontier {
        let rung = |w, d, acc: f64, fp: f64| Rung {
            cfg: PrecisionConfig::uniform(4, w, d),
            accuracy: acc,
            rel_err: (0.9 - acc) / 0.9,
            footprint_ratio: fp,
            envelope_bytes: fp * 2.0e6,
        };
        Frontier {
            net: "lenet".to_string(),
            baseline_accuracy: 0.9,
            rungs: vec![
                rung(QFormat::new(2, 8), QFormat::new(10, 4), 0.9, 0.5),
                rung(QFormat::new(1, 8), QFormat::new(10, 4), 0.897, 0.42),
                rung(QFormat::new(1, 6), QFormat::new(9, 2), 0.88, 0.33),
            ],
        }
    }

    #[test]
    fn json_round_trip_preserves_every_field() {
        let f = ladder();
        let back = Frontier::from_json(&f.to_json()).unwrap();
        assert_eq!(back.net, f.net);
        assert_eq!(back.baseline_accuracy, f.baseline_accuracy);
        assert_eq!(back.rungs.len(), f.rungs.len());
        for (a, b) in back.rungs.iter().zip(&f.rungs) {
            assert_eq!(a, b);
        }
        back.validate().unwrap();
    }

    #[test]
    fn save_and_load_round_trip_on_disk() {
        let f = ladder();
        let dir = std::env::temp_dir()
            .join(format!("qbound-frontier-test-{}", std::process::id()));
        let path = dir.join(Frontier::file_name("lenet"));
        f.save(&path).unwrap();
        let back = Frontier::load(&path).unwrap();
        assert_eq!(back.rungs, f.rungs);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn usable_rungs_clamps_at_the_floor() {
        let f = ladder();
        // rung 1 loses 0.33% relative, rung 2 loses 2.2%.
        assert_eq!(f.usable_rungs(0.01), 2);
        assert_eq!(f.usable_rungs(0.05), 3);
        assert_eq!(f.usable_rungs(0.001), 1);
        assert_eq!(f.usable_rungs(0.0), 1, "rung 0 is exact — always usable");
    }

    #[test]
    fn validate_rejects_disorder_and_shape_drift() {
        let mut f = ladder();
        f.rungs.swap(0, 2); // widest no longer first
        assert!(f.validate().is_err());

        let mut f = ladder();
        f.rungs[1].cfg = PrecisionConfig::uniform(3, QFormat::new(1, 8), QFormat::new(10, 4));
        assert!(f.validate().is_err(), "layer-count drift must be rejected");

        let mut f = ladder();
        f.rungs.clear();
        assert!(f.validate().is_err(), "an empty ladder is unusable");
    }

    #[test]
    fn fp32_formats_survive_the_wire() {
        let f = Frontier {
            net: "n".to_string(),
            baseline_accuracy: 0.5,
            rungs: vec![Rung {
                cfg: PrecisionConfig::fp32(2),
                accuracy: 0.5,
                rel_err: 0.0,
                footprint_ratio: 1.0,
                envelope_bytes: 1.0e6,
            }],
        };
        let back = Frontier::from_json(&f.to_json()).unwrap();
        assert!(back.rungs[0].cfg.wq.iter().all(QFormat::is_fp32));
    }
}
