//! Serving metrics: latency percentiles and lifetime counters,
//! snapshotted into `/v1/stats` responses and `SERVE_*.json` artifacts.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// How many latency samples the reservoir keeps before it stops
/// recording new ones — a hard cap so the metrics themselves honor the
/// bounded-memory story (64k × 8 B = 512 KiB worst case).
const MAX_SAMPLES: usize = 65_536;

/// Accumulates per-request latency samples and per-status counters.
#[derive(Default)]
pub struct ServeMetrics {
    latencies_us: Vec<u64>,
    dropped_samples: u64,
    by_status: BTreeMap<u16, u64>,
    pub rejected_busy: u64,
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics::default()
    }

    /// Record one completed request: its HTTP status and, for
    /// successful classifications, the end-to-end latency.
    pub fn record(&mut self, status: u16, latency_us: Option<u64>) {
        *self.by_status.entry(status).or_insert(0) += 1;
        if let Some(us) = latency_us {
            if self.latencies_us.len() < MAX_SAMPLES {
                self.latencies_us.push(us);
            } else {
                self.dropped_samples += 1;
            }
        }
    }

    pub fn requests(&self) -> u64 {
        self.by_status.values().sum()
    }

    pub fn count(&self, status: u16) -> u64 {
        self.by_status.get(&status).copied().unwrap_or(0)
    }

    /// Latency percentile in microseconds over the recorded samples
    /// (nearest-rank on the sorted vector), or `None` with no samples.
    pub fn percentile_us(&self, q: f64) -> Option<u64> {
        if self.latencies_us.is_empty() {
            return None;
        }
        let mut sorted = self.latencies_us.clone();
        sorted.sort_unstable();
        let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
        Some(sorted[idx.min(sorted.len() - 1)])
    }

    /// The stats object served at `/v1/stats` and archived in
    /// `SERVE_*.json` (cache counters are merged in by the caller,
    /// which owns the ledger).
    pub fn snapshot(&self) -> Json {
        let statuses = Json::Obj(
            self.by_status.iter().map(|(s, n)| (s.to_string(), Json::num(*n as f64))).collect(),
        );
        let pct = |q: f64| match self.percentile_us(q) {
            Some(us) => Json::num(us as f64),
            None => Json::Null,
        };
        Json::obj(vec![
            ("requests", Json::num(self.requests() as f64)),
            ("rejected_busy", Json::num(self.rejected_busy as f64)),
            ("latency_samples", Json::num(self.latencies_us.len() as f64)),
            ("dropped_samples", Json::num(self.dropped_samples as f64)),
            ("latency_us_p50", pct(0.50)),
            ("latency_us_p95", pct(0.95)),
            ("latency_us_p99", pct(0.99)),
            ("by_status", statuses),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_over_known_distribution() {
        let mut m = ServeMetrics::new();
        // 1..=100 µs, shuffled order must not matter.
        for v in (1..=100u64).rev() {
            m.record(200, Some(v));
        }
        assert_eq!(m.percentile_us(0.0), Some(1));
        assert_eq!(m.percentile_us(0.50), Some(51)); // round(99 * 0.5) = 50
        assert_eq!(m.percentile_us(0.95), Some(95));
        assert_eq!(m.percentile_us(0.99), Some(99));
        assert_eq!(m.percentile_us(1.0), Some(100));
    }

    #[test]
    fn empty_metrics_have_no_percentiles_and_null_snapshot_fields() {
        let m = ServeMetrics::new();
        assert_eq!(m.percentile_us(0.5), None);
        let snap = m.snapshot();
        assert!(snap.get("latency_us_p50").unwrap().is_null());
        assert_eq!(snap.get("requests").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn status_counts_and_snapshot_roundtrip() {
        let mut m = ServeMetrics::new();
        m.record(200, Some(120));
        m.record(200, Some(80));
        m.record(404, None);
        m.record(429, None);
        m.rejected_busy = 1;
        assert_eq!(m.requests(), 4);
        assert_eq!(m.count(200), 2);
        assert_eq!(m.count(429), 1);
        let text = m.snapshot().to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("requests").unwrap().as_u64(), Some(4));
        assert_eq!(back.get("rejected_busy").unwrap().as_u64(), Some(1));
        assert_eq!(back.get("by_status").unwrap().get("200").unwrap().as_u64(), Some(2));
        assert_eq!(back.get("latency_us_p50").unwrap().as_u64(), Some(120));
    }

    #[test]
    fn sample_reservoir_is_capped() {
        let mut m = ServeMetrics::new();
        for i in 0..(MAX_SAMPLES as u64 + 10) {
            m.record(200, Some(i));
        }
        assert_eq!(m.snapshot().get("latency_samples").unwrap().as_usize(), Some(MAX_SAMPLES));
        assert_eq!(m.dropped_samples, 10);
    }
}
