//! Request metrics: latency distribution + status counters for
//! `/v1/stats`, `GET /metrics` and `SERVE_smoke.json`.
//!
//! Latencies live in a fixed-size log2 histogram
//! ([`crate::obs::hist::Histogram`], ~8 KiB): recording is O(1) with no
//! per-sample allocation, quantiles are O(buckets) with a documented
//! ≤ ~4% relative error (exact below 16 µs), and — unlike the
//! clone-and-sort reservoir this replaced — there is no sample cap and
//! nothing is ever dropped, no matter how long the daemon runs.
//! `ServeMetrics` is owned by the dispatch mutex, so the plain
//! (non-atomic) flavor suffices.

use std::collections::BTreeMap;

use crate::obs::hist::Histogram;
use crate::util::json::Json;

/// Latency + status accounting for the daemon.
#[derive(Default)]
pub struct ServeMetrics {
    latency: Histogram,
    /// Response counts per HTTP status.
    by_status: BTreeMap<u16, u64>,
    /// 429 refusals from the in-flight gate. Kept consistent with
    /// `by_status` by construction: [`ServeMetrics::record`] bumps both
    /// from the same status code.
    rejected_busy: u64,
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics::default()
    }

    /// Count one response; classification latencies pass
    /// `latency_us`, error/infra responses pass `None`.
    pub fn record(&mut self, status: u16, latency_us: Option<u64>) {
        *self.by_status.entry(status).or_insert(0) += 1;
        if status == 429 {
            self.rejected_busy += 1;
        }
        if let Some(us) = latency_us {
            self.latency.record(us);
        }
    }

    /// Total responses recorded.
    pub fn requests(&self) -> u64 {
        self.by_status.values().sum()
    }

    /// Responses with a given status.
    pub fn count(&self, status: u16) -> u64 {
        self.by_status.get(&status).copied().unwrap_or(0)
    }

    /// Requests refused by the in-flight gate (HTTP 429).
    pub fn rejected_busy(&self) -> u64 {
        self.rejected_busy
    }

    /// Latency quantile in µs (`q` in [0, 1]): the owning histogram
    /// bucket's midpoint, ≤ ~4% relative error. 0.0 before any sample.
    pub fn percentile_us(&self, q: f64) -> f64 {
        self.latency.quantile(q) as f64
    }

    /// The `/v1/stats` fragment.
    pub fn snapshot(&self) -> Json {
        let by_status: Vec<(String, Json)> =
            self.by_status.iter().map(|(s, n)| (s.to_string(), Json::num(*n as f64))).collect();
        Json::obj(vec![
            ("requests", Json::num(self.requests() as f64)),
            ("rejected_busy", Json::num(self.rejected_busy as f64)),
            ("latency_samples", Json::num(self.latency.count() as f64)),
            ("latency_us_p50", Json::num(self.percentile_us(0.50))),
            ("latency_us_p95", Json::num(self.percentile_us(0.95))),
            ("latency_us_p99", Json::num(self.percentile_us(0.99))),
            ("by_status", Json::Obj(by_status.into_iter().collect())),
        ])
    }

    /// Append this struct's series to a Prometheus text exposition:
    /// `qbound_http_requests_total{status=...}`,
    /// `qbound_http_rejected_busy_total`, and the
    /// `qbound_request_latency_us` histogram.
    pub fn render_prometheus(&self, out: &mut String) {
        use std::fmt::Write;
        let _ = writeln!(out, "# HELP qbound_http_requests_total responses by HTTP status");
        let _ = writeln!(out, "# TYPE qbound_http_requests_total counter");
        for (status, n) in &self.by_status {
            let _ = writeln!(out, "qbound_http_requests_total{{status=\"{status}\"}} {n}");
        }
        let _ = writeln!(
            out,
            "# HELP qbound_http_rejected_busy_total requests refused by the in-flight gate"
        );
        let _ = writeln!(out, "# TYPE qbound_http_rejected_busy_total counter");
        let _ = writeln!(out, "qbound_http_rejected_busy_total {}", self.rejected_busy);
        let _ =
            writeln!(out, "# HELP qbound_request_latency_us classification latency, microseconds");
        let _ = writeln!(out, "# TYPE qbound_request_latency_us histogram");
        self.latency.render_prometheus(out, "qbound_request_latency_us", "");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_track_known_distribution_within_error_bound() {
        let mut m = ServeMetrics::new();
        for us in 1..=100u64 {
            m.record(200, Some(us));
        }
        // Exact nearest-rank values are 51 / 95 / 99; the histogram
        // answers within its documented ≤ ~4% relative error.
        for (q, exact) in [(0.50, 51.0), (0.95, 95.0), (0.99, 99.0)] {
            let got = m.percentile_us(q);
            assert!(
                (got - exact).abs() <= (exact * 0.04).max(1.0),
                "q={q}: got {got}, exact {exact}"
            );
        }
        assert_eq!(m.requests(), 100);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = ServeMetrics::new();
        assert_eq!(m.percentile_us(0.99), 0.0);
        let snap = m.snapshot();
        assert_eq!(snap.get("requests").and_then(Json::as_u64), Some(0));
        assert_eq!(snap.get("latency_samples").and_then(Json::as_u64), Some(0));
    }

    #[test]
    fn no_sample_cap_unlike_the_old_reservoir() {
        let mut m = ServeMetrics::new();
        // Well past the old 64 Ki reservoir cap: every sample counts.
        for i in 0..200_000u64 {
            m.record(200, Some(i % 1000));
        }
        let snap = m.snapshot();
        assert_eq!(snap.get("latency_samples").and_then(Json::as_u64), Some(200_000));
        assert!(snap.get("dropped_samples").is_none(), "reservoir-era field must be gone");
    }

    #[test]
    fn status_counts_and_rejected_busy_stay_consistent() {
        let mut m = ServeMetrics::new();
        m.record(200, Some(1500));
        m.record(200, Some(900));
        m.record(404, None);
        m.record(429, None);
        assert_eq!(m.count(200), 2);
        assert_eq!(m.count(404), 1);
        // The 429 shows up in BOTH views from one record() call.
        assert_eq!(m.count(429), 1);
        assert_eq!(m.rejected_busy(), 1);
        let snap = m.snapshot();
        assert_eq!(snap.get("requests").and_then(Json::as_u64), Some(4));
        assert_eq!(snap.get("rejected_busy").and_then(Json::as_u64), Some(1));
        assert_eq!(snap.at(&["by_status", "429"]).as_u64(), Some(1));
        assert_eq!(snap.get("latency_samples").and_then(Json::as_u64), Some(2));
    }

    #[test]
    fn prometheus_render_has_all_three_families() {
        let mut m = ServeMetrics::new();
        m.record(200, Some(120));
        m.record(429, None);
        let mut out = String::new();
        m.render_prometheus(&mut out);
        assert!(out.contains("qbound_http_requests_total{status=\"200\"} 1"), "{out}");
        assert!(out.contains("qbound_http_requests_total{status=\"429\"} 1"), "{out}");
        assert!(out.contains("qbound_http_rejected_busy_total 1"), "{out}");
        assert!(out.contains("qbound_request_latency_us_count 1"), "{out}");
        assert!(out.contains("qbound_request_latency_us_bucket{le=\"+Inf\"} 1"), "{out}");
    }
}
