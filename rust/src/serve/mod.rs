//! The `qbound serve` daemon: a footprint-budgeted, network-facing
//! inference service over the fused packed executors.
//!
//! This is the paper's bounded-memory deployment story made operational:
//! the same `FootprintModel::fused_envelope` that the CI `check-mem`
//! gate holds measured peaks against becomes the *admission currency*
//! of a multi-tenant server. Every `(net, PrecisionConfig, backend,
//! storage)` combination a client asks for is one cacheable executor
//! with resident packed weights; the [`cache::CacheLedger`] admits
//! executors only while their modeled envelopes sum within the global
//! `--mem-budget`, evicting least-recently-used configs under pressure.
//!
//! Layering (pure std, no registry deps):
//!
//! * [`http`] — hand-rolled HTTP/1.1: one-request parser + explicit
//!   `Content-Length` responses (keep-alive and pipelining fall out of
//!   looping the parser over one connection),
//! * [`queue`] — bounded in-flight admission (429 + `Retry-After`
//!   backpressure instead of unbounded buffering),
//! * [`cache`] — the budget/LRU/placement ledger (executor-free, so the
//!   admission math is unit-tested without artifacts),
//! * [`metrics`] — latency percentiles + counters for `/v1/stats` and
//!   the `SERVE_*.json` artifacts,
//! * this module — the TCP listener, connection threads, and the worker
//!   pool. Executors are not `Send` (same constraint the
//!   [`coordinator`](crate::coordinator) works under), so each worker
//!   thread builds its own backend via the coordinator's per-worker
//!   thread-budget rule and owns the executors placed on it; dispatch
//!   routes requests to the worker whose resident packed weights
//!   already match the requested config.
//!
//! Endpoints: `GET /healthz`, `GET /v1/nets`, `GET /v1/stats`,
//! `GET /metrics` (Prometheus text exposition), and
//! `POST /v1/classify` with a JSON body like
//! `{"net": "lenet", "weights": "1.8", "data": "10.4", "index": 7}`.
//!
//! Observability: the daemon enables the [`crate::obs`] metrics
//! registry at startup (per-layer histograms populate as traffic
//! flows), and `--trace-dir` additionally turns on span tracing — on
//! shutdown the buffered spans are written as Chrome `trace_event`
//! JSON (`TRACE_serve.json`) loadable in `chrome://tracing`/Perfetto.
//!
//! With `--store-dir` the workers route packed-weight bitstreams
//! through the content-addressed store ([`crate::store`]): restarts
//! warm-start from disk with zero re-packs, executors whose weight
//! formats match share one mmap'd mapping, and the admission ledger
//! prices that mapping once (`/v1/stats` reports both the deduplicated
//! `resident_bytes` and the `dedup_saved_bytes` discount).
//!
//! Crash robustness: request handlers run under `catch_unwind` (a
//! panic costs one 500 + counter, never the daemon), and the dispatch
//! mutex recovers from poisoning ([`lock_dispatch`]) instead of
//! cascading `PoisonError` panics through every connection thread.
//!
//! With autoscaling enabled ([`ServeOptions::autoscale`], the CLI's
//! `--autoscale`) a controller thread samples queue occupancy, arrival
//! rate and p99 latency every tick and walks each net's precomputed
//! accuracy↔footprint ladder ([`frontier`], [`autoscale`]): sustained
//! pressure degrades the served precision one rung toward narrower
//! widths, a calm hysteresis window recovers it, and `--accuracy-floor`
//! bounds how much accuracy a reachable rung may give up. While a net
//! has a ladder, its active rung *overrides* the per-request
//! `weights`/`data` fields — clients see which rung answered in the
//! response's `rung` field and the ladder state under `/v1/stats`.

pub mod autoscale;
pub mod cache;
pub mod frontier;
pub mod http;
pub mod metrics;
pub mod queue;

use std::collections::HashMap;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::backend::lowering::LoweredPlan;
use crate::backend::{Backend, BackendKind, NetExecutor, Variant};
use crate::coordinator::{backend_for_worker, default_workers};
use crate::eval::Dataset;
use crate::memory::{FootprintModel, StorageMode};
use crate::nets::{arch, ArtifactIndex, NetManifest};
use crate::quant::QFormat;
use crate::search::space::PrecisionConfig;
use crate::util;
use crate::util::json::Json;

use crate::store::Store;

use cache::{Admission, CacheKey, CacheLedger};
use http::{HttpRequest, HttpResponse, ReadOutcome};
use metrics::ServeMetrics;
use queue::InflightGate;

/// Daemon configuration (the `qbound serve` CLI surface).
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Bind address; port 0 asks the OS for an ephemeral port (the
    /// smoke/test path — read the real one back from [`Server::addr`]).
    pub addr: String,
    /// Worker threads (0 = one per core).
    pub workers: usize,
    /// Max concurrently admitted requests; beyond it clients get 429.
    pub queue_depth: usize,
    /// Global executor-cache budget in modeled bytes.
    pub mem_budget_bytes: f64,
    pub backend: BackendKind,
    pub storage: StorageMode,
    /// Request-body cap (413 beyond it).
    pub max_body_bytes: usize,
    /// When set, span tracing is enabled and a Chrome trace JSON is
    /// written to `<trace_dir>/TRACE_serve.json` on shutdown.
    pub trace_dir: Option<String>,
    /// Packed-weight store directory ([`crate::store`]). When set, the
    /// workers load/publish packed bitstreams through the store — warm
    /// restarts skip re-packing, and executors sharing weight formats
    /// share one resident mapping (the cache ledger prices it once).
    /// The CLI resolves `--store-dir` / `QBOUND_STORE_DIR` into this;
    /// the server itself never reads the environment, so tests can run
    /// store-backed and store-free daemons side by side.
    pub store_dir: Option<String>,
    /// When set, the precision-autoscaling controller runs with these
    /// knobs: frontiers are loaded from `FRONTIER_<net>.json` files,
    /// usable rungs are pre-warmed through the store (if any), and a
    /// `serve-autoscale` thread moves each net's active config along
    /// its ladder under load. `None` (the default) serves statically.
    pub autoscale: Option<autoscale::AutoscaleOptions>,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            addr: "127.0.0.1:8484".to_string(),
            workers: 0,
            queue_depth: 64,
            mem_budget_bytes: 64.0 * 1024.0 * 1024.0,
            backend: BackendKind::default(),
            storage: StorageMode::default(),
            max_body_bytes: 64 * 1024,
            trace_dir: None,
            store_dir: None,
            autoscale: None,
        }
    }
}

/// Everything the daemon knows about one servable network, loaded once
/// at startup and shared read-only with workers and dispatch.
struct NetInfo {
    manifest: NetManifest,
    dataset: Dataset,
    fpm: FootprintModel,
    /// f32 scratch-window elements of the fused executor (decode + bias
    /// windows + strip cache, `LoweredPlan::fused_window_elems(1)`) —
    /// the `window_f32_elems` argument of `fused_envelope`.
    window_f32_elems: usize,
    /// Per-layer NR-lane padding elements of the packed GEMM panels.
    weight_pad_elems: Vec<usize>,
}

impl NetInfo {
    /// The admission cost of one executor for `cfg`: the same realized
    /// residency envelope `qbound eval --mem-json` archives and the CI
    /// `check-mem` gate enforces.
    fn envelope(&self, cfg: &PrecisionConfig) -> f64 {
        self.fpm.fused_envelope(cfg, self.window_f32_elems, &self.weight_pad_elems)
    }
}

struct JobReply {
    pred: usize,
    label: i32,
    /// Whether this request paid the executor load (cache miss).
    loaded: bool,
}

enum WorkerMsg {
    Job { key: CacheKey, index: usize, resp: Sender<Result<JobReply, String>> },
    Evict(CacheKey),
}

/// Mutable dispatch state, one lock: admission decisions and the
/// ordered per-worker sends must be atomic so an `Evict(K)` issued
/// before a later re-admission of `K` can never race past the reload on
/// the worker's FIFO channel.
struct Dispatch {
    ledger: CacheLedger,
    metrics: ServeMetrics,
    worker_txs: Vec<Sender<WorkerMsg>>,
}

struct Shared {
    nets: Arc<HashMap<String, NetInfo>>,
    dispatch: Mutex<Dispatch>,
    gate: InflightGate,
    backend: BackendKind,
    storage: StorageMode,
    /// The packed-weight store the workers were pinned to (if any) —
    /// also read by `/v1/stats` and by the admission path to price
    /// shared weight mappings once.
    store: Option<Arc<Store>>,
    /// Precision-autoscaling ladders + controllers (None = static).
    autoscale: Option<Arc<autoscale::AutoscaleState>>,
    max_body: usize,
    n_workers: usize,
    queue_depth: usize,
    stop: AtomicBool,
}

/// Lock the dispatch state, recovering from mutex poisoning instead of
/// propagating it: a connection thread that panicked while holding the
/// lock must not take the whole daemon down with it. `Dispatch` is
/// poison-safe by construction — every critical section leaves the
/// ledger/metrics in a consistent state before any fallible call — so
/// recovery is sound, and each occurrence is counted and logged.
fn lock_dispatch(sh: &Shared) -> std::sync::MutexGuard<'_, Dispatch> {
    sh.dispatch.lock().unwrap_or_else(|poisoned| {
        crate::obs::counter(
            "qbound_serve_lock_recoveries_total",
            "dispatch mutex poison recoveries (a thread panicked while holding the lock)",
            &[],
        )
        .inc();
        log::warn!("serve: dispatch mutex poisoned by a panicked thread; recovering");
        poisoned.into_inner()
    })
}

/// A running daemon: listener thread + worker pool. Dropping (or
/// calling [`Server::shutdown`]) stops the listener and joins the
/// workers.
pub struct Server {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    controller: Option<JoinHandle<()>>,
    trace_dir: Option<String>,
}

impl Server {
    /// Load every net in the artifact index at `dir`, spawn the worker
    /// pool, bind the listener, and start accepting.
    pub fn start(dir: &Path, opts: &ServeOptions) -> Result<Server> {
        let n_workers = if opts.workers == 0 { default_workers() } else { opts.workers };
        // Workers build backends from the environment (the coordinator
        // pattern): propagate the storage mode before spawning. The
        // packed-weight store is NOT propagated through the environment
        // — it is resolved here once and handed to each worker
        // explicitly, so concurrent servers (tests) can't race on a
        // process-global variable.
        opts.storage.set_env();
        let store = match &opts.store_dir {
            Some(d) => Some(
                Store::open(Path::new(d))
                    .with_context(|| format!("opening packed-weight store at {d}"))?,
            ),
            None => None,
        };
        // Per-layer histograms and decode counters populate from the
        // first request; span tracing only when a trace sink exists.
        crate::obs::set_metrics(true);
        if opts.trace_dir.is_some() {
            crate::obs::set_tracing(true);
        }

        let index = ArtifactIndex::load(dir)?;
        let mut nets = HashMap::new();
        for name in &index.nets {
            let manifest = NetManifest::load(dir, name)
                .with_context(|| format!("loading manifest for {name}"))?;
            let Some(a) = arch::get(name) else {
                log::warn!("serve: no registered architecture for {name:?}; not serving it");
                continue;
            };
            let plan = LoweredPlan::new(&a, None)?;
            let dataset = Dataset::load(&manifest)
                .with_context(|| format!("loading dataset for {name}"))?;
            nets.insert(name.clone(), NetInfo {
                fpm: FootprintModel::new(&manifest),
                window_f32_elems: plan.fused_window_elems(1),
                weight_pad_elems: plan.weight_pad_elems.clone(),
                manifest,
                dataset,
            });
        }
        anyhow::ensure!(!nets.is_empty(), "no servable networks in {}", dir.display());

        // Autoscaling: load the per-net frontier ladders (floor-clamped)
        // and pre-pack every usable rung's weights through the store, so
        // a later rung swap is one mmap + ledger re-price, never a
        // re-pack.
        let autoscale = match &opts.autoscale {
            Some(ao) => {
                let counts: HashMap<String, usize> =
                    nets.iter().map(|(n, i)| (n.clone(), i.manifest.n_layers())).collect();
                let state = Arc::new(autoscale::AutoscaleState::build(ao.clone(), &counts)?);
                if let Some(store) = &store {
                    if opts.storage == StorageMode::Packed && opts.backend == BackendKind::Fast {
                        let packs = autoscale::prewarm_store(store, dir, &state)
                            .context("pre-warming the store for autoscale rungs")?;
                        log::info!(
                            "serve: autoscale pre-warm packed {packs} fresh tensor key(s) \
                             (0 = store already warm)"
                        );
                    }
                }
                Some(state)
            }
            None => None,
        };
        let nets = Arc::new(nets);

        let mut worker_txs = Vec::with_capacity(n_workers);
        let mut workers = Vec::with_capacity(n_workers);
        for wid in 0..n_workers {
            let (tx, rx) = channel::<WorkerMsg>();
            worker_txs.push(tx);
            let nets = Arc::clone(&nets);
            let kind = opts.backend;
            let wstore = store.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("serve-worker-{wid}"))
                    .spawn(move || worker_loop(wid, rx, nets, kind, n_workers, wstore))?,
            );
        }

        let listener = TcpListener::bind(&opts.addr)
            .with_context(|| format!("binding {}", opts.addr))?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            nets,
            dispatch: Mutex::new(Dispatch {
                ledger: CacheLedger::new(opts.mem_budget_bytes, n_workers),
                metrics: ServeMetrics::new(),
                worker_txs,
            }),
            gate: InflightGate::new(opts.queue_depth),
            backend: opts.backend,
            storage: opts.storage,
            store,
            autoscale,
            max_body: opts.max_body_bytes,
            n_workers,
            queue_depth: opts.queue_depth,
            stop: AtomicBool::new(false),
        });

        let accept_shared = Arc::clone(&shared);
        let accept = std::thread::Builder::new()
            .name("serve-accept".to_string())
            .spawn(move || {
                for stream in listener.incoming() {
                    if accept_shared.stop.load(Ordering::SeqCst) {
                        return;
                    }
                    match stream {
                        Ok(s) => {
                            let sh = Arc::clone(&accept_shared);
                            // Connection threads are detached: they end
                            // when the peer closes or errors out.
                            let _ = std::thread::Builder::new()
                                .name("serve-conn".to_string())
                                .spawn(move || handle_connection(sh, s));
                        }
                        Err(e) => log::warn!("serve: accept failed: {e}"),
                    }
                }
            })?;

        let controller = match shared.autoscale.clone() {
            Some(state) => {
                let sh = Arc::clone(&shared);
                Some(
                    std::thread::Builder::new()
                        .name("serve-autoscale".to_string())
                        .spawn(move || controller_loop(sh, state))?,
                )
            }
            None => None,
        };

        log::info!(
            "serve: listening on {addr} ({} workers, budget {}, queue {})",
            n_workers,
            util::human_bytes(opts.mem_budget_bytes),
            opts.queue_depth
        );
        let trace_dir = opts.trace_dir.clone();
        Ok(Server { addr, shared, accept: Some(accept), workers, controller, trace_dir })
    }

    /// The bound address (the real port when the options asked for 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block the calling thread until the listener exits (daemon mode:
    /// forever, unless another thread calls shutdown).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting, drain the workers, join every pool thread.
    pub fn shutdown(mut self) {
        self.stop_impl();
    }

    fn stop_impl(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a wake-up connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.controller.take() {
            let _ = h.join();
        }
        // Dropping the senders ends the worker loops once their queues
        // drain; in-flight jobs still get answered first.
        lock_dispatch(&self.shared).worker_txs.clear();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Some(dir) = self.trace_dir.take() {
            crate::obs::set_tracing(false);
            let events = crate::obs::drain();
            let path = Path::new(&dir).join("TRACE_serve.json");
            match crate::obs::write_chrome_trace(&path, &events) {
                Ok(()) => log::info!("serve: wrote {} spans to {}", events.len(), path.display()),
                Err(e) => log::warn!("serve: writing trace {} failed: {e:#}", path.display()),
            }
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if self.accept.is_some() || self.controller.is_some() || !self.workers.is_empty() {
            self.stop_impl();
        }
    }
}

/// The autoscaling tick loop: sample the daemon's own signals, feed the
/// per-net controllers, let [`autoscale::AutoscaleState::tick`] apply
/// and record any transitions. Sleeps in short slices so shutdown never
/// waits out a full tick.
fn controller_loop(sh: Arc<Shared>, state: Arc<autoscale::AutoscaleState>) {
    let tick = Duration::from_millis(state.opts().tick_ms);
    let slice = Duration::from_millis(5).min(tick);
    let mut last = Instant::now();
    let mut last_requests = lock_dispatch(&sh).metrics.requests();
    while !sh.stop.load(Ordering::SeqCst) {
        let t0 = Instant::now();
        while t0.elapsed() < tick {
            if sh.stop.load(Ordering::SeqCst) {
                return;
            }
            std::thread::sleep(slice);
        }
        let (requests, p99_us) = {
            let d = lock_dispatch(&sh);
            (d.metrics.requests(), d.metrics.percentile_us(0.99))
        };
        let dt = last.elapsed().as_secs_f64().max(1e-9);
        last = Instant::now();
        let sample = autoscale::MetricSample {
            queue_frac: sh.gate.in_flight() as f64 / sh.queue_depth.max(1) as f64,
            arrival_hz: requests.saturating_sub(last_requests) as f64 / dt,
            p99_us,
        };
        last_requests = requests;
        state.tick(&sample);
    }
}

// ---- connection handling -----------------------------------------------

fn handle_connection(sh: Arc<Shared>, stream: TcpStream) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    loop {
        // Spans the read+parse of one request (includes any socket wait
        // on a keep-alive connection); emitted only on success.
        let t_read = crate::obs::tracing_on().then(crate::obs::span::now_us);
        match http::read_request(&mut reader, sh.max_body) {
            Ok(ReadOutcome::Closed) => return,
            Ok(ReadOutcome::Request(req)) => {
                if let Some(t0) = t_read {
                    let end = crate::obs::span::now_us();
                    crate::obs::span::emit(
                        "http_parse",
                        format!("{} {}", req.method, req.path),
                        t0,
                        end.saturating_sub(t0),
                    );
                }
                let keep = req.keep_alive;
                // A panicking handler must cost one 500, not the
                // daemon: catch it, count it, answer, close this
                // connection (its state is suspect). `AssertUnwindSafe`
                // is justified because nothing on this thread is reused
                // after a panic — shared state is either lock-protected
                // (and `lock_dispatch` recovers poisoning) or atomic.
                let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let _sp = crate::obs::span!("request", "{} {}", req.method, req.path);
                    route(&sh, &req)
                }));
                let panicked = caught.is_err();
                let (mut resp, latency_us) = caught.unwrap_or_else(|payload| {
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "non-string panic payload".to_string());
                    crate::obs::counter(
                        "qbound_serve_request_panics_total",
                        "request handlers that panicked and were converted to HTTP 500",
                        &[],
                    )
                    .inc();
                    log::error!(
                        "serve: handler panicked on {} {}: {msg}",
                        req.method,
                        req.path
                    );
                    (HttpResponse::error(500, "internal error (handler panicked)"), None)
                });
                resp.close = !keep || panicked;
                lock_dispatch(&sh).metrics.record(resp.status, latency_us);
                if resp.write_to(&mut writer).is_err() || resp.close {
                    return;
                }
            }
            Err(e) => {
                // Protocol errors poison the stream framing: answer and
                // close.
                let mut resp = HttpResponse::error(e.status, &e.reason);
                resp.close = true;
                lock_dispatch(&sh).metrics.record(e.status, None);
                let _ = resp.write_to(&mut writer);
                return;
            }
        }
    }
}

fn route(sh: &Arc<Shared>, req: &HttpRequest) -> (HttpResponse, Option<u64>) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            (HttpResponse::json(200, &Json::obj(vec![("ok", Json::Bool(true))])), None)
        }
        ("GET", "/v1/stats") => (stats_response(sh), None),
        ("GET", "/v1/nets") => (nets_response(sh), None),
        ("GET", "/metrics") => (metrics_response(sh), None),
        ("POST", "/v1/classify") => classify(sh, req),
        (_, "/healthz" | "/v1/stats" | "/v1/nets" | "/metrics") => {
            (HttpResponse::error(405, "use GET"), None)
        }
        (_, "/v1/classify") => (HttpResponse::error(405, "use POST"), None),
        (m, p) => (HttpResponse::error(404, &format!("no route {m} {p}")), None),
    }
}

fn stats_response(sh: &Arc<Shared>) -> HttpResponse {
    let d = lock_dispatch(sh);
    let Json::Obj(mut m) = d.metrics.snapshot() else { unreachable!("snapshot is an object") };
    m.insert(
        "cache".to_string(),
        Json::obj(vec![
            ("hits", Json::num(d.ledger.hits as f64)),
            ("misses", Json::num(d.ledger.misses as f64)),
            ("evictions", Json::num(d.ledger.evictions as f64)),
            ("resident", Json::num(d.ledger.resident_len() as f64)),
            // De-duplicated: executors sharing one store-backed weight
            // mapping pay its bytes once (what the process really holds).
            ("resident_bytes", Json::num(d.ledger.resident_cost())),
            // The same sum with no sharing discount, and the delta.
            ("raw_resident_bytes", Json::num(d.ledger.raw_resident_cost())),
            ("dedup_saved_bytes", Json::num(d.ledger.dedup_saved_bytes())),
            ("budget_bytes", Json::num(d.ledger.budget())),
        ]),
    );
    drop(d);
    m.insert(
        "store".to_string(),
        match &sh.store {
            Some(s) => {
                let Json::Obj(mut o) = s.stats_json() else { unreachable!("stats is an object") };
                o.insert("enabled".to_string(), Json::Bool(true));
                Json::Obj(o)
            }
            None => Json::obj(vec![("enabled", Json::Bool(false))]),
        },
    );
    m.insert(
        "autoscale".to_string(),
        match &sh.autoscale {
            Some(state) => state.stats_json(),
            None => Json::obj(vec![("enabled", Json::Bool(false))]),
        },
    );
    m.insert("workers".to_string(), Json::num(sh.n_workers as f64));
    m.insert("queue_depth".to_string(), Json::num(sh.queue_depth as f64));
    m.insert("in_flight".to_string(), Json::num(sh.gate.in_flight() as f64));
    m.insert("backend".to_string(), Json::str(sh.backend.label()));
    m.insert("storage".to_string(), Json::str(sh.storage.label()));
    m.insert(
        "kernel".to_string(),
        Json::str(crate::backend::kernels::active_kind().label()),
    );
    m.insert(
        "peak_rss_bytes".to_string(),
        util::peak_rss_bytes().map(|b| Json::num(b as f64)).unwrap_or(Json::Null),
    );
    m.insert("obs".to_string(), crate::obs::registry_json());
    m.insert("decode_bytes_total".to_string(), Json::num(crate::obs::decode_bytes() as f64));
    HttpResponse::json(200, &Json::Obj(m))
}

/// `GET /metrics`: the Prometheus text exposition — request-level
/// series owned by [`ServeMetrics`] followed by the process-global
/// registry (per-layer histograms, decode counters, kernel gauge).
fn metrics_response(sh: &Arc<Shared>) -> HttpResponse {
    let mut out = String::new();
    lock_dispatch(sh).metrics.render_prometheus(&mut out);
    out.push_str(&crate::obs::render_prometheus());
    HttpResponse::text(200, out)
}

fn nets_response(sh: &Arc<Shared>) -> HttpResponse {
    let mut names: Vec<&String> = sh.nets.keys().collect();
    names.sort();
    let arr = names
        .into_iter()
        .map(|n| {
            let info = &sh.nets[n];
            let fp32 = info.envelope(&PrecisionConfig::fp32(info.manifest.n_layers()));
            Json::obj(vec![
                ("net", Json::str(n.clone())),
                ("layers", Json::num(info.manifest.n_layers() as f64)),
                ("images", Json::num(info.dataset.n as f64)),
                ("classes", Json::num(info.manifest.num_classes as f64)),
                ("fp32_envelope_bytes", Json::num(fp32)),
            ])
        })
        .collect::<Vec<_>>();
    HttpResponse::json(200, &Json::arr(arr))
}

/// `POST /v1/classify`: parse, price, admit, route, infer, answer.
fn classify(sh: &Arc<Shared>, req: &HttpRequest) -> (HttpResponse, Option<u64>) {
    let t0 = Instant::now();
    let fail = |status: u16, msg: &str| (HttpResponse::error(status, msg), None);

    let text = match std::str::from_utf8(&req.body) {
        Ok(t) => t,
        Err(_) => return fail(400, "body is not utf-8"),
    };
    let body = match Json::parse(text) {
        Ok(j) => j,
        Err(e) => return fail(400, &format!("bad json body: {e}")),
    };
    let Some(net) = body.get("net").and_then(Json::as_str) else {
        return fail(400, "missing field \"net\"");
    };
    let Some(info) = sh.nets.get(net) else {
        return fail(404, &format!("unknown net {net:?}"));
    };
    let fmt_field = |field: &str| -> Result<QFormat, String> {
        match body.get(field) {
            None | Some(Json::Null) => Ok(QFormat::FP32),
            Some(j) => {
                let s = j.as_str().ok_or_else(|| format!("field {field:?} must be a string"))?;
                QFormat::parse(s).map_err(|e| format!("field {field:?}: {e}"))
            }
        }
    };
    let (wfmt, dfmt) = match (fmt_field("weights"), fmt_field("data")) {
        (Ok(w), Ok(d)) => (w, d),
        (Err(e), _) | (_, Err(e)) => return fail(400, &e),
    };
    let index = match body.get("index") {
        None => 0,
        Some(j) => match j.as_usize() {
            Some(i) => i,
            None => return fail(400, "field \"index\" must be a non-negative integer"),
        },
    };
    if index >= info.dataset.n {
        return fail(400, &format!("index {index} out of range ({} images)", info.dataset.n));
    }

    let mut cfg = PrecisionConfig::uniform(info.manifest.n_layers(), wfmt, dfmt);
    // Autoscaling overrides the requested formats with the net's active
    // rung: under load the whole fleet of clients is degraded together,
    // and every answer carries the rung that produced it.
    let mut rung: Option<usize> = None;
    if let Some(state) = &sh.autoscale {
        if let Some((r, rcfg)) = state.active_cfg(net) {
            rung = Some(r);
            cfg = rcfg;
        }
    }
    let cost = info.envelope(&cfg);
    let key = CacheKey {
        net: net.to_string(),
        cfg: cfg.clone(),
        backend: sh.backend,
        storage: sh.storage,
    };
    // Store-backed fast/packed executors share one weight mapping per
    // (net, weight formats): declare that slice of the envelope to the
    // ledger so peers differing only in activation formats are priced
    // at their activation cost.
    let shared_weights = if sh.store.is_some()
        && sh.storage == StorageMode::Packed
        && sh.backend == BackendKind::Fast
    {
        let wq: Vec<String> = cfg.wq.iter().map(|q| q.to_string()).collect();
        Some((
            format!("{net}|w{}|{}", wq.join(","), sh.storage.label()),
            info.fpm.shared_weight_bytes(&cfg, &info.weight_pad_elems),
        ))
    } else {
        None
    };

    // Backpressure first: a full queue refuses before touching
    // dispatch. The 429 is counted by `ServeMetrics::record` at the
    // connection layer (status counter and rejected_busy from the same
    // call, so the two views can't drift).
    let _sp = crate::obs::span!("admission", "net={net} cfg={} envelope={cost:.0}", cfg.notation());
    let Some(_slot) = sh.gate.try_acquire() else {
        return (HttpResponse::error(429, "queue full").with_retry_after(1), None);
    };

    let (resp_tx, resp_rx) = channel();
    let (worker, cache_state, evicted_n) = {
        let mut d = lock_dispatch(sh);
        if d.worker_txs.is_empty() {
            return fail(503, "shutting down");
        }
        match d.ledger.admit(&key, cost, shared_weights) {
            Admission::TooLarge => {
                let msg = format!(
                    "config envelope {} exceeds the --mem-budget {}",
                    util::human_bytes(cost),
                    util::human_bytes(d.ledger.budget())
                );
                return fail(507, &msg);
            }
            Admission::Resident { worker } => {
                let job = WorkerMsg::Job { key, index, resp: resp_tx };
                let _ = d.worker_txs[worker].send(job);
                (worker, "hit", 0)
            }
            Admission::Admitted { worker, evicted } => {
                let n = evicted.len();
                // Only the owning worker holds the executor, but the
                // ledger no longer knows which one — broadcast; drops
                // are idempotent.
                for victim in evicted {
                    for tx in &d.worker_txs {
                        let _ = tx.send(WorkerMsg::Evict(victim.clone()));
                    }
                }
                let job = WorkerMsg::Job { key, index, resp: resp_tx };
                let _ = d.worker_txs[worker].send(job);
                (worker, "load", n)
            }
        }
    };
    // The admission span ends here; the executor wait is the worker's
    // own `infer` span (same timeline, different tid).
    drop(_sp);

    match resp_rx.recv() {
        Ok(Ok(reply)) => {
            let us = t0.elapsed().as_micros() as u64;
            let doc = Json::obj(vec![
                ("net", Json::str(net)),
                ("config", Json::str(cfg.notation())),
                ("index", Json::num(index as f64)),
                ("pred", Json::num(reply.pred as f64)),
                ("label", Json::num(reply.label as f64)),
                ("correct", Json::Bool(reply.pred as i32 == reply.label)),
                ("latency_us", Json::num(us as f64)),
                ("worker", Json::num(worker as f64)),
                ("cache", Json::str(if reply.loaded { "load" } else { cache_state })),
                ("evicted", Json::num(evicted_n as f64)),
                ("envelope_bytes", Json::num(cost)),
                ("rung", rung.map(|r| Json::num(r as f64)).unwrap_or(Json::Null)),
            ]);
            (HttpResponse::json(200, &doc), Some(us))
        }
        Ok(Err(msg)) => fail(500, &msg),
        Err(_) => fail(500, "worker unavailable"),
    }
}

// ---- worker pool --------------------------------------------------------

fn worker_loop(
    wid: usize,
    rx: Receiver<WorkerMsg>,
    nets: Arc<HashMap<String, NetInfo>>,
    kind: BackendKind,
    n_workers: usize,
    store: Option<Arc<Store>>,
) {
    let backend = match backend_for_worker(kind, n_workers, store) {
        Ok(b) => b,
        Err(e) => {
            // Exiting drops `rx`; pending reply senders error out and
            // their requests answer 500.
            log::error!("serve worker {wid}: backend {} failed: {e:#}", kind.label());
            return;
        }
    };
    let mut executors: HashMap<CacheKey, Box<dyn NetExecutor>> = HashMap::new();
    while let Ok(msg) = rx.recv() {
        match msg {
            WorkerMsg::Evict(key) => {
                if executors.remove(&key).is_some() {
                    log::debug!("serve worker {wid}: evicted {} {}", key.net, key.cfg);
                }
            }
            WorkerMsg::Job { key, index, resp } => {
                let reply = serve_one(backend.as_ref(), &mut executors, &nets, &key, index);
                let _ = resp.send(reply);
            }
        }
    }
}

/// Run one classification on this worker: load the executor for `key`
/// if it isn't resident yet, decode nothing the executor doesn't need
/// (the dataset image block is shared read-only), argmax the logits.
fn serve_one(
    backend: &dyn Backend,
    executors: &mut HashMap<CacheKey, Box<dyn NetExecutor>>,
    nets: &HashMap<String, NetInfo>,
    key: &CacheKey,
    index: usize,
) -> Result<JobReply, String> {
    let info = nets.get(&key.net).ok_or_else(|| format!("unknown net {:?}", key.net))?;
    let loaded = !executors.contains_key(key);
    if loaded {
        let _sp = crate::obs::span!("cache_load", "net={} cfg={}", key.net, key.cfg);
        let exec = backend
            .load(&info.manifest, Variant::Standard)
            .map_err(|e| format!("loading {}: {e:#}", key.net))?;
        executors.insert(key.clone(), exec);
    }
    // Worker-thread span: the per-layer `layer` spans the executor
    // emits land on this same thread, so the viewer nests them here.
    let _sp = crate::obs::span!("infer", "net={} cfg={} index={index}", key.net, key.cfg);
    // The executor was either resident or inserted just above; if it is
    // somehow missing anyway, that's a worker-state bug — answer this
    // request with a 500 instead of panicking the worker thread (which
    // would orphan every executor placed on it).
    let Some(exec) = executors.get_mut(key) else {
        debug_assert!(false, "executor for {} {} missing after load", key.net, key.cfg);
        log::error!("serve: executor for {} {} missing after load", key.net, key.cfg);
        return Err("executor missing after load (worker-state bug)".to_string());
    };
    let wq = key.cfg.wire_wq();
    let dq = key.cfg.wire_dq();
    let d = &info.dataset;
    let img = &d.images[index * d.image_elems..(index + 1) * d.image_elems];
    let logits = if exec.max_batch() > exec.batch() {
        // Variable-batch executors (reference, fast) take one image.
        exec.infer(img, &wq, &dq, None)
    } else {
        // Compiled-batch backends need a full batch: replicate the
        // image and score row 0.
        let mut batch = Vec::with_capacity(exec.batch() * d.image_elems);
        for _ in 0..exec.batch() {
            batch.extend_from_slice(img);
        }
        exec.infer(&batch, &wq, &dq, None)
    }
    .map_err(|e| format!("inference failed: {e:#}"))?;
    let row = &logits[..exec.num_classes()];
    let mut pred = 0usize;
    for (i, v) in row.iter().enumerate() {
        if *v > row[pred] {
            pred = i;
        }
    }
    Ok(JobReply { pred, label: d.labels[index], loaded })
}

/// The serving accuracy oracle: classify image `index` of `net` under
/// `cfg` through a freshly loaded executor of `oracle` — what the smoke
/// workload checks every live HTTP answer against (same contract the
/// cross-backend equivalence tests pin).
pub fn reference_prediction(
    manifest: &NetManifest,
    dataset: &Dataset,
    oracle: &dyn Backend,
    cfg: &PrecisionConfig,
    index: usize,
) -> Result<usize> {
    let mut exec = oracle.load(manifest, Variant::Standard)?;
    let img = &dataset.images[index * dataset.image_elems..(index + 1) * dataset.image_elems];
    let logits = exec.infer(img, &cfg.wire_wq(), &cfg.wire_dq(), None)?;
    let row = &logits[..exec.num_classes()];
    let mut pred = 0usize;
    for (i, v) in row.iter().enumerate() {
        if *v > row[pred] {
            pred = i;
        }
    }
    Ok(pred)
}
