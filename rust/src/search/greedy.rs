//! The paper's §2.5 "slowest gradient descent" design-space explorer.
//!
//! 1. Initialize all layers to a uniform precision with < 0.1 % relative
//!    error (found from the Fig-2 uniform sweeps).
//! 2. Create delta configurations by reducing each tunable field (per
//!    layer: data I, data F, weight F) by one bit.
//! 3. Move to the delta with the best accuracy; repeat.
//!
//! Every iteration's deltas are evaluated as one coordinator burst (the
//! paper calls the search "time consuming" — here it fans out over the
//! worker pool). The full visited trajectory is kept: it *is* the Fig-5
//! scatter, and Table 2 selects from it.

use anyhow::Result;

use crate::coordinator::{Coordinator, EvalJob};
use crate::memory::FootprintModel;
use crate::nets::NetManifest;
use crate::quant::QFormat;
use crate::search::space::{DescentOptions, PrecisionConfig};
use crate::search::{uniform, Param};
use crate::traffic::{self, Mode};

/// Which delta the descent commits to each iteration (ablation axis — the
/// paper uses [`ChoicePolicy::BestAccuracy`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChoicePolicy {
    /// The paper's §2.5 rule: the delta with the best accuracy.
    BestAccuracy,
    /// Ablation: the delta with the best traffic-saved per accuracy-lost
    /// ratio ("cheapest bits first").
    TrafficPerError,
}

/// Options for one descent run.
#[derive(Clone, Copy, Debug)]
pub struct GreedyOptions {
    /// Images per accuracy evaluation (0 = full eval split).
    pub n_images: usize,
    /// Neighbour-generation floors/toggles.
    pub descent: DescentOptions,
    /// Stop once relative error exceeds this (maps past the paper's 10 %
    /// band so the Fig-5 drop-off is visible).
    pub stop_rel_err: f64,
    /// Hard iteration cap.
    pub max_iters: usize,
    /// Traffic mode for the recorded ratios (paper uses batch).
    pub mode: Mode,
    /// Per-iteration selection rule.
    pub policy: ChoicePolicy,
}

impl Default for GreedyOptions {
    fn default() -> Self {
        Self {
            n_images: 0,
            descent: DescentOptions::default(),
            stop_rel_err: 0.20,
            max_iters: 400,
            mode: Mode::Batch(64),
            policy: ChoicePolicy::BestAccuracy,
        }
    }
}

/// One visited configuration of the descent (a Fig-5 scatter point).
#[derive(Clone, Debug)]
pub struct Visited {
    pub step: usize,
    /// Which delta produced it ("d3.I-1", "start", …).
    pub move_label: String,
    pub cfg: PrecisionConfig,
    pub accuracy: f64,
    pub rel_err: f64,
    pub traffic_ratio: f64,
    /// Modeled data-footprint ratio vs fp32 ([`FootprintModel::ratio`])
    /// — the quantity Table-2 selection minimizes.
    pub footprint_ratio: f64,
}

/// Full result of a descent run.
#[derive(Clone, Debug)]
pub struct DescentResult {
    pub baseline: f64,
    pub visited: Vec<Visited>,
    /// All candidate evaluations (including non-chosen deltas) — these are
    /// Fig-5 "mixed" points too.
    pub explored: Vec<Visited>,
}

/// Find the uniform starting configuration (paper step 1): the narrowest
/// uniform (weight-F, data-I, data-F) whose fields are each within `tol`
/// in isolation, then widened together until the combined config is
/// within `tol` as well.
pub fn find_uniform_start(
    coord: &mut Coordinator,
    m: &NetManifest,
    tol: f64,
    fixed_data_f: Option<i8>,
    n_images: usize,
) -> Result<PrecisionConfig> {
    let nl = m.n_layers();
    let wf_pts = uniform::sweep(coord, &m.name, nl, Param::WeightF, (1, 12), n_images)?;
    let di_pts = uniform::sweep(coord, &m.name, nl, Param::DataI, (1, 14), n_images)?;
    // Fallbacks (sweep never within tol — i.e. tol below the eval noise
    // floor) stay moderate; the combined-effect safeguard below widens
    // further only if the *joint* config is actually off.
    let wf = uniform::min_bits_within(&wf_pts, tol).unwrap_or(10);
    let di = uniform::min_bits_within(&di_pts, tol).unwrap_or(12);
    let df = match fixed_data_f {
        Some(f) => f,
        None => {
            let df_pts = uniform::sweep(coord, &m.name, nl, Param::DataF, (0, 8), n_images)?;
            uniform::min_bits_within(&df_pts, tol).unwrap_or(8)
        }
    };
    let mut cfg =
        PrecisionConfig::uniform(nl, QFormat::new(1, wf), QFormat::new(di, df));
    // Combined-effect safeguard: widen uniformly until within tol.
    let base = coord.eval_one(EvalJob {
        net: m.name.clone(),
        cfg: PrecisionConfig::fp32(nl),
        n_images,
    })?;
    for _ in 0..8 {
        let acc = coord.eval_one(EvalJob { net: m.name.clone(), cfg: cfg.clone(), n_images })?;
        if base <= 0.0 || (base - acc) / base <= tol {
            break;
        }
        for l in 0..nl {
            cfg.wq[l].fbits = (cfg.wq[l].fbits + 1).min(14);
            cfg.dq[l].ibits = (cfg.dq[l].ibits + 1).min(15);
        }
    }
    Ok(cfg)
}

/// Run the descent from `start`.
pub fn descend(
    coord: &mut Coordinator,
    m: &NetManifest,
    start: PrecisionConfig,
    opts: &GreedyOptions,
) -> Result<DescentResult> {
    let nl = m.n_layers();
    let fpm = FootprintModel::new(m);
    let baseline = coord.eval_one(EvalJob {
        net: m.name.clone(),
        cfg: PrecisionConfig::fp32(nl),
        n_images: opts.n_images,
    })?;
    let mk = |step: usize, label: String, cfg: PrecisionConfig, acc: f64| Visited {
        step,
        move_label: label,
        rel_err: if baseline > 0.0 { (baseline - acc) / baseline } else { 1.0 },
        traffic_ratio: traffic::traffic_ratio(m, opts.mode, &cfg),
        footprint_ratio: fpm.ratio(&cfg),
        cfg,
        accuracy: acc,
    };

    let start_acc = coord.eval_one(EvalJob {
        net: m.name.clone(),
        cfg: start.clone(),
        n_images: opts.n_images,
    })?;
    let mut visited = vec![mk(0, "start".into(), start.clone(), start_acc)];
    let mut explored = visited.clone();
    let mut cur = start;

    for step in 1..=opts.max_iters {
        let neighbours = cur.descent_neighbours(&opts.descent);
        if neighbours.is_empty() {
            log::debug!("{}: no neighbours at step {step}", m.name);
            break;
        }
        let jobs: Vec<EvalJob> = neighbours
            .iter()
            .map(|(_, cfg)| EvalJob {
                net: m.name.clone(),
                cfg: cfg.clone(),
                n_images: opts.n_images,
            })
            .collect();
        let accs = coord.eval_batch(&jobs)?;

        // Selection per policy; accuracy ties always break toward lower
        // modeled footprint (cheaper config).
        let cur_acc = visited.last().unwrap().accuracy;
        let cur_tr = visited.last().unwrap().traffic_ratio;
        let score = |i: usize| -> f64 {
            match opts.policy {
                ChoicePolicy::BestAccuracy => accs[i],
                ChoicePolicy::TrafficPerError => {
                    let tr = traffic::traffic_ratio(m, opts.mode, &neighbours[i].1);
                    let saved = (cur_tr - tr).max(0.0);
                    let lost = (cur_acc - accs[i]).max(0.0);
                    saved / (lost + 1e-4)
                }
            }
        };
        let mut best: Option<usize> = None;
        for (i, &acc) in accs.iter().enumerate() {
            let better = match best {
                None => true,
                Some(j) => {
                    score(i) > score(j)
                        || (score(i) == score(j)
                            && (acc > accs[j]
                                || fpm.ratio(&neighbours[i].1) < fpm.ratio(&neighbours[j].1)))
                }
            };
            if better {
                best = Some(i);
            }
        }
        for (i, &acc) in accs.iter().enumerate() {
            explored.push(mk(step, neighbours[i].0.clone(), neighbours[i].1.clone(), acc));
        }
        let bi = best.unwrap();
        let chosen = mk(step, neighbours[bi].0.clone(), neighbours[bi].1.clone(), accs[bi]);
        let stop = chosen.rel_err > opts.stop_rel_err;
        cur = chosen.cfg.clone();
        visited.push(chosen);
        if stop {
            log::debug!("{}: rel err exceeded {} at step {step}", m.name, opts.stop_rel_err);
            break;
        }
    }
    Ok(DescentResult { baseline, visited, explored })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_options_sane() {
        let o = GreedyOptions::default();
        assert!(o.stop_rel_err > 0.1);
        assert!(o.max_iters >= 100);
        assert_eq!(o.mode.batch(), 64);
    }
}
