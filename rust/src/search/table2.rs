//! Table-2 selection: minimum-footprint mixed configuration per error
//! tolerance, with the paper's notation.
//!
//! Ranking key: the **modeled data footprint** (weights + peak live
//! activations, [`crate::memory::FootprintModel`]) — the bytes the
//! packed storage subsystem actually keeps resident — not the raw
//! bit-weighted traffic count. Both the footprint and traffic ratios of
//! the winning config are reported.

use crate::search::greedy::Visited;
use crate::search::space::PrecisionConfig;

/// The paper's tolerance levels (relative error vs baseline accuracy).
pub const TOLERANCES: [f64; 4] = [0.01, 0.02, 0.05, 0.10];

/// One Table-2 row.
#[derive(Clone, Debug)]
pub struct ToleranceRow {
    pub tol: f64,
    pub cfg: PrecisionConfig,
    pub accuracy: f64,
    pub rel_err: f64,
    /// TR — traffic ratio vs the 32-bit baseline.
    pub traffic_ratio: f64,
    /// FP — modeled data-footprint ratio vs fp32 (the ranking key).
    pub footprint_ratio: f64,
}

/// For each tolerance, the minimum-footprint visited config whose
/// relative error is within tolerance. `None` when nothing qualifies
/// (shouldn't happen — the fp32-adjacent start always qualifies).
pub fn select(visited: &[Visited], tolerances: &[f64]) -> Vec<Option<ToleranceRow>> {
    tolerances
        .iter()
        .map(|&tol| {
            visited
                .iter()
                .filter(|v| v.rel_err <= tol)
                .min_by(|a, b| a.footprint_ratio.partial_cmp(&b.footprint_ratio).unwrap())
                .map(|v| ToleranceRow {
                    tol,
                    cfg: v.cfg.clone(),
                    accuracy: v.accuracy,
                    rel_err: v.rel_err,
                    traffic_ratio: v.traffic_ratio,
                    footprint_ratio: v.footprint_ratio,
                })
        })
        .collect()
}

/// Paper notation for the data formats: `I.F` per layer joined with `-`
/// (LeNet/Convnet style, both fields tuned).
pub fn notation_if(cfg: &PrecisionConfig) -> String {
    cfg.dq.iter().map(|q| format!("{}.{}", q.ibits, q.fbits)).collect::<Vec<_>>().join("-")
}

/// Paper notation when data F is fixed: total data bits `I+F` per layer
/// (AlexNet/NiN/GoogLeNet style).
pub fn notation_total(cfg: &PrecisionConfig) -> String {
    cfg.dq
        .iter()
        .map(|q| format!("{}", q.ibits as i32 + q.fbits as i32))
        .collect::<Vec<_>>()
        .join("-")
}

/// Weight-format notation (F per layer; I is pinned to 1).
pub fn notation_weights(cfg: &PrecisionConfig) -> String {
    cfg.wq.iter().map(|q| format!("{}", q.fbits)).collect::<Vec<_>>().join("-")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QFormat;

    fn v(rel_err: f64, fp: f64) -> Visited {
        Visited {
            step: 0,
            move_label: "t".into(),
            cfg: PrecisionConfig::uniform(2, QFormat::new(1, 4), QFormat::new(8, 1)),
            accuracy: 1.0 - rel_err,
            rel_err,
            // traffic tracks footprint loosely in real descents; keep
            // them distinct here so tests see which one ranks.
            traffic_ratio: fp + 0.05,
            footprint_ratio: fp,
        }
    }

    #[test]
    fn selects_min_footprint_within_tol() {
        let visited = vec![v(0.001, 0.5), v(0.009, 0.3), v(0.03, 0.2), v(0.2, 0.1)];
        let rows = select(&visited, &TOLERANCES);
        assert!((rows[0].as_ref().unwrap().footprint_ratio - 0.3).abs() < 1e-12); // 1%
        assert!((rows[1].as_ref().unwrap().footprint_ratio - 0.3).abs() < 1e-12); // 2%
        assert!((rows[2].as_ref().unwrap().footprint_ratio - 0.2).abs() < 1e-12); // 5%
        assert!((rows[3].as_ref().unwrap().footprint_ratio - 0.2).abs() < 1e-12); // 10%
        // the winner's traffic ratio rides along
        assert!((rows[0].as_ref().unwrap().traffic_ratio - 0.35).abs() < 1e-12);
    }

    #[test]
    fn ranks_by_footprint_not_traffic() {
        // b has lower footprint but higher traffic than a: b must win.
        let mut a = v(0.001, 0.4);
        a.traffic_ratio = 0.30;
        let mut b = v(0.001, 0.3);
        b.traffic_ratio = 0.45;
        let rows = select(&[a, b], &[0.01]);
        let row = rows[0].as_ref().unwrap();
        assert!((row.footprint_ratio - 0.3).abs() < 1e-12);
        assert!((row.traffic_ratio - 0.45).abs() < 1e-12);
    }

    #[test]
    fn none_when_nothing_qualifies() {
        let visited = vec![v(0.5, 0.5)];
        let rows = select(&visited, &[0.01]);
        assert!(rows[0].is_none());
    }

    #[test]
    fn notations() {
        let mut cfg = PrecisionConfig::uniform(3, QFormat::new(1, 4), QFormat::new(8, 1));
        cfg.dq[2] = QFormat::new(5, 0);
        assert_eq!(notation_if(&cfg), "8.1-8.1-5.0");
        assert_eq!(notation_total(&cfg), "9-9-5");
        assert_eq!(notation_weights(&cfg), "4-4-4");
    }
}
