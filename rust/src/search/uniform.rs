//! Uniform-representation sweeps (paper §2.2, Fig 2).
//!
//! All layers share one format; one field is swept while the others are
//! pinned safe. Jobs for the whole bit range are submitted to the
//! coordinator as one burst, so they fan out over the worker pool.

use anyhow::Result;

use crate::coordinator::{Coordinator, EvalJob};
use crate::quant::QFormat;
use crate::search::space::PrecisionConfig;
use crate::search::{Param, SweepPoint, SAFE_DATA_F, SAFE_DATA_I};

/// Build the uniform config that sweeps `param = bits`.
pub fn uniform_cfg(n_layers: usize, param: Param, bits: i8) -> PrecisionConfig {
    match param {
        Param::WeightF => PrecisionConfig::uniform(
            n_layers,
            QFormat::new(1, bits),
            // data untouched: fp32 — isolates the weight effect, §2.2
            QFormat::FP32,
        ),
        Param::DataI => PrecisionConfig::uniform(
            n_layers,
            QFormat::FP32,
            QFormat::new(bits, SAFE_DATA_F),
        ),
        Param::DataF => PrecisionConfig::uniform(
            n_layers,
            QFormat::FP32,
            QFormat::new(SAFE_DATA_I, bits),
        ),
    }
}

/// Sweep `param` over `bit_range` (inclusive) for `net`.
pub fn sweep(
    coord: &mut Coordinator,
    net: &str,
    n_layers: usize,
    param: Param,
    bit_range: (i8, i8),
    n_images: usize,
) -> Result<Vec<SweepPoint>> {
    let bits: Vec<i8> = (bit_range.0..=bit_range.1).collect();
    let mut jobs: Vec<EvalJob> = bits
        .iter()
        .map(|&b| EvalJob {
            net: net.to_string(),
            cfg: uniform_cfg(n_layers, param, b),
            n_images,
        })
        .collect();
    // Baseline rides along in the same burst.
    jobs.push(EvalJob { net: net.to_string(), cfg: PrecisionConfig::fp32(n_layers), n_images });
    let accs = coord.eval_batch(&jobs)?;
    let base = *accs.last().unwrap();
    Ok(bits
        .iter()
        .zip(&accs)
        .map(|(&b, &acc)| SweepPoint {
            bits: b,
            cfg: uniform_cfg(n_layers, param, b),
            accuracy: acc,
            relative: if base > 0.0 { acc / base } else { 0.0 },
        })
        .collect())
}

/// Smallest bits value in `points` whose relative accuracy is within
/// `tol` of baseline (None if none qualify). Scans from the narrow end:
/// tolerance curves are noisy, so we require the qualifying point AND all
/// wider settings to stay within tolerance ("stable knee").
pub fn min_bits_within(points: &[SweepPoint], tol: f64) -> Option<i8> {
    let mut sorted: Vec<&SweepPoint> = points.iter().collect();
    sorted.sort_by_key(|p| p.bits);
    for i in 0..sorted.len() {
        if sorted[i..].iter().all(|p| p.relative >= 1.0 - tol) {
            return Some(sorted[i].bits);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_cfg_shapes() {
        let c = uniform_cfg(3, Param::WeightF, 5);
        assert_eq!(c.wq[0], QFormat::new(1, 5));
        assert!(c.dq[0].is_fp32());
        let c = uniform_cfg(3, Param::DataI, 9);
        assert_eq!(c.dq[2], QFormat::new(9, SAFE_DATA_F));
        assert!(c.wq[1].is_fp32());
        let c = uniform_cfg(2, Param::DataF, 1);
        assert_eq!(c.dq[0], QFormat::new(SAFE_DATA_I, 1));
    }

    fn pt(bits: i8, rel: f64) -> SweepPoint {
        SweepPoint {
            bits,
            cfg: PrecisionConfig::fp32(1),
            accuracy: rel,
            relative: rel,
        }
    }

    #[test]
    fn min_bits_finds_stable_knee() {
        let pts = vec![pt(2, 0.2), pt(3, 0.991), pt(4, 0.999), pt(5, 1.0)];
        assert_eq!(min_bits_within(&pts, 0.01), Some(3));
        assert_eq!(min_bits_within(&pts, 0.001), Some(4));
    }

    #[test]
    fn min_bits_requires_stability_above() {
        // dip at 4 bits disqualifies 3 even though 3 itself is fine
        let pts = vec![pt(3, 0.995), pt(4, 0.9), pt(5, 1.0)];
        assert_eq!(min_bits_within(&pts, 0.01), Some(5));
    }

    #[test]
    fn min_bits_none_when_all_bad() {
        let pts = vec![pt(2, 0.1), pt(3, 0.2)];
        assert_eq!(min_bits_within(&pts, 0.01), None);
    }
}
