//! On-disk descent-trajectory cache.
//!
//! A greedy descent is the expensive half of `qbound footprint` (and of
//! any report that re-ranks visited configurations): hundreds of
//! accuracy evaluations per network. The *ranking* step, by contrast,
//! is pure arithmetic over the visited list. This module persists the
//! trajectory — visited configs with their accuracies and modeled
//! ratios — so repeat invocations re-rank from disk without a single
//! forward pass.
//!
//! Invalidation is by identity, not by age: [`CacheKey`] captures
//! everything the trajectory depends on (network, backend, eval-subset
//! size, layer count, a **content hash of the weights file** —
//! [`weights_fingerprint`], so rewriting even one weight byte
//! invalidates the trajectory — and the manifest's recorded baseline,
//! which moves with the eval data the accuracies were measured on).
//! Any mismatch — or a garbled/missing file, or a schema bump — is a
//! miss that triggers recompute + overwrite, never an error.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::quant::QFormat;
use crate::search::greedy::{DescentResult, Visited};
use crate::search::space::PrecisionConfig;
use crate::util::{self, json::Json};

/// Bump when the on-disk layout changes; older files become misses.
/// (2.0: the artifact fingerprint grew a content hash of the weights
/// file next to the recorded baseline. 3.0: [`weights_fingerprint`]
/// moved from 64-bit FNV-1a to SHA-256 — the digest now also names
/// files in the shared packed-weight store, where an FNV collision
/// would silently serve the wrong weights.)
pub const SCHEMA: f64 = 3.0;

/// Identity of one descent run. Every field change invalidates the
/// cached trajectory.
#[derive(Clone, Debug, PartialEq)]
pub struct CacheKey {
    pub net: String,
    pub backend: String,
    /// Images per accuracy evaluation (0 = full split).
    pub n_images: usize,
    pub n_layers: usize,
    /// Content hash of the weights file ([`weights_fingerprint`]):
    /// catches weight rewrites the recorded baseline cannot see.
    pub weights_hash: String,
    /// The manifest's recorded baseline — it moves with the eval data
    /// split, which the weights hash alone does not cover.
    pub baseline_top1: f64,
}

/// SHA-256 over the weights file bytes (plus the byte length, which is
/// redundant but keeps the digest self-describing in logs). Stable
/// across platforms, and any one-byte rewrite flips the digest. This
/// fingerprint also names files in the content-addressed packed-weight
/// store ([`crate::store`]), a shared namespace where a collision
/// silently serves the wrong weights — hence a real 256-bit hash rather
/// than the FNV-1a it replaced.
pub fn weights_fingerprint(path: &Path) -> Result<String> {
    let bytes = std::fs::read(path)?;
    Ok(format!("{}-{}", crate::util::sha256::sha256_hex(&bytes), bytes.len()))
}

/// Cache file for `net` under `dir`.
pub fn cache_path(dir: &Path, net: &str) -> PathBuf {
    dir.join(format!("dse_{net}.json"))
}

fn fmt_json(q: QFormat) -> Json {
    Json::arr([Json::num(q.ibits as f64), Json::num(q.fbits as f64)])
}

fn fmt_from(j: &Json) -> Option<QFormat> {
    let a = j.as_arr()?;
    if a.len() != 2 {
        return None;
    }
    Some(QFormat::from_wire(a[0].as_f64()? as f32, a[1].as_f64()? as f32))
}

fn cfg_json(c: &PrecisionConfig) -> Json {
    Json::obj(vec![
        ("wq", Json::arr(c.wq.iter().map(|q| fmt_json(*q)))),
        ("dq", Json::arr(c.dq.iter().map(|q| fmt_json(*q)))),
    ])
}

fn cfg_from(j: &Json, n_layers: usize) -> Option<PrecisionConfig> {
    let row = |key: &str| -> Option<Vec<QFormat>> {
        j.get(key)?.as_arr()?.iter().map(fmt_from).collect()
    };
    let (wq, dq) = (row("wq")?, row("dq")?);
    if wq.len() != n_layers || dq.len() != n_layers {
        return None;
    }
    Some(PrecisionConfig { wq, dq })
}

/// Persist `res.visited` (the ranking input; the `explored` superset is
/// Fig-5 plotting data and is not cached) under `key`.
pub fn save(path: &Path, key: &CacheKey, res: &DescentResult) -> Result<()> {
    let visited = res.visited.iter().map(|v| {
        Json::obj(vec![
            ("step", Json::num(v.step as f64)),
            ("move", Json::str(v.move_label.clone())),
            ("cfg", cfg_json(&v.cfg)),
            ("accuracy", Json::num(v.accuracy)),
            ("rel_err", Json::num(v.rel_err)),
            ("traffic_ratio", Json::num(v.traffic_ratio)),
            ("footprint_ratio", Json::num(v.footprint_ratio)),
        ])
    });
    let doc = Json::obj(vec![
        ("schema", Json::num(SCHEMA)),
        ("net", Json::str(key.net.clone())),
        ("backend", Json::str(key.backend.clone())),
        ("n_images", Json::num(key.n_images as f64)),
        ("n_layers", Json::num(key.n_layers as f64)),
        ("weights_hash", Json::str(key.weights_hash.clone())),
        ("baseline_top1", Json::num(key.baseline_top1)),
        ("baseline", Json::num(res.baseline)),
        ("visited", Json::arr(visited)),
    ]);
    util::write_file(path, doc.pretty().as_bytes())
}

/// Load the trajectory at `path` if it exists *and* matches `key`.
/// Every failure mode — missing file, parse error, key mismatch, schema
/// drift, truncated entries — is a silent miss.
pub fn load(path: &Path, key: &CacheKey) -> Option<DescentResult> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = Json::parse(&text).ok()?;
    if j.at(&["schema"]).as_f64()? != SCHEMA
        || j.at(&["net"]).as_str()? != key.net
        || j.at(&["backend"]).as_str()? != key.backend
        || j.at(&["n_images"]).as_usize()? != key.n_images
        || j.at(&["n_layers"]).as_usize()? != key.n_layers
        || j.at(&["weights_hash"]).as_str()? != key.weights_hash
        || (j.at(&["baseline_top1"]).as_f64()? - key.baseline_top1).abs() > 1e-12
    {
        return None;
    }
    let baseline = j.at(&["baseline"]).as_f64()?;
    let mut visited = Vec::new();
    for v in j.at(&["visited"]).as_arr()? {
        visited.push(Visited {
            step: v.at(&["step"]).as_usize()?,
            move_label: v.at(&["move"]).as_str()?.to_string(),
            cfg: cfg_from(v.at(&["cfg"]), key.n_layers)?,
            accuracy: v.at(&["accuracy"]).as_f64()?,
            rel_err: v.at(&["rel_err"]).as_f64()?,
            traffic_ratio: v.at(&["traffic_ratio"]).as_f64()?,
            footprint_ratio: v.at(&["footprint_ratio"]).as_f64()?,
        });
    }
    if visited.is_empty() {
        return None;
    }
    Some(DescentResult { baseline, visited, explored: Vec::new() })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("qbound-dse-cache-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample_key() -> CacheKey {
        CacheKey {
            net: "lenet".into(),
            backend: "fast".into(),
            n_images: 128,
            n_layers: 2,
            weights_hash: "cafebabe01234567-96".into(),
            baseline_top1: 0.9904,
        }
    }

    fn sample_result() -> DescentResult {
        let mut mixed = PrecisionConfig::uniform(2, QFormat::new(1, 6), QFormat::new(9, 2));
        mixed.dq[1] = QFormat::FP32; // exercise the sentinel round-trip
        DescentResult {
            baseline: 0.9904,
            visited: vec![
                Visited {
                    step: 0,
                    move_label: "start".into(),
                    cfg: PrecisionConfig::fp32(2),
                    accuracy: 0.9904,
                    rel_err: 0.0,
                    traffic_ratio: 1.0,
                    footprint_ratio: 1.0,
                },
                Visited {
                    step: 1,
                    move_label: "d0.I-1".into(),
                    cfg: mixed,
                    accuracy: 0.9851,
                    rel_err: 0.00535,
                    traffic_ratio: 0.41,
                    footprint_ratio: 0.37,
                },
            ],
            explored: Vec::new(),
        }
    }

    #[test]
    fn hit_round_trips_the_trajectory() {
        let dir = tmp_dir("hit");
        let (key, res) = (sample_key(), sample_result());
        let path = cache_path(&dir, &key.net);
        save(&path, &key, &res).unwrap();
        let got = load(&path, &key).expect("cache hit");
        assert_eq!(got.baseline, res.baseline);
        assert_eq!(got.visited.len(), 2);
        for (a, b) in got.visited.iter().zip(&res.visited) {
            assert_eq!(a.step, b.step);
            assert_eq!(a.move_label, b.move_label);
            assert_eq!(a.cfg, b.cfg);
            assert_eq!(a.accuracy, b.accuracy);
            assert_eq!(a.rel_err, b.rel_err);
            assert_eq!(a.traffic_ratio, b.traffic_ratio);
            assert_eq!(a.footprint_ratio, b.footprint_ratio);
        }
        assert!(got.visited[1].cfg.dq[1].is_fp32());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn any_key_drift_invalidates() {
        let dir = tmp_dir("inval");
        let (key, res) = (sample_key(), sample_result());
        let path = cache_path(&dir, &key.net);
        save(&path, &key, &res).unwrap();
        let mutations: [fn(&mut CacheKey); 6] = [
            |k| k.n_images = 256,
            |k| k.backend = "reference".into(),
            |k| k.net = "convnet".into(),
            |k| k.n_layers = 3,
            |k| k.weights_hash = "0000000000000000-96".into(),
            |k| k.baseline_top1 = 0.9,
        ];
        for mutate in mutations {
            let mut k = sample_key();
            mutate(&mut k);
            assert!(load(&path, &k).is_none(), "{k:?} should miss");
        }
        // The matching key still hits after all those misses.
        assert!(load(&path, &key).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn one_rewritten_weight_byte_invalidates() {
        // The ROADMAP item this key exists for: a weights file whose
        // recorded baseline would not change (same length, one flipped
        // byte) must still miss the cache.
        let dir = tmp_dir("hash");
        let wfile = dir.join("weights.ntf");
        std::fs::write(&wfile, [0x4e, 0x54, 0x46, 0x00, 0x7f, 0x01]).unwrap();
        let mut key = sample_key();
        key.weights_hash = weights_fingerprint(&wfile).unwrap();
        let path = cache_path(&dir, &key.net);
        save(&path, &key, &sample_result()).unwrap();
        assert!(load(&path, &key).is_some());

        std::fs::write(&wfile, [0x4e, 0x54, 0x46, 0x00, 0x7e, 0x01]).unwrap();
        let mut stale = sample_key();
        stale.weights_hash = weights_fingerprint(&wfile).unwrap();
        assert_ne!(key.weights_hash, stale.weights_hash, "digest must move");
        assert!(load(&path, &stale).is_none(), "stale trajectory served");
        // Truncation changes the digest too (length is part of it).
        std::fs::write(&wfile, [0x4e, 0x54, 0x46, 0x00, 0x7f]).unwrap();
        assert_ne!(key.weights_hash, weights_fingerprint(&wfile).unwrap());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn garbled_or_missing_files_are_silent_misses() {
        let dir = tmp_dir("garbled");
        let key = sample_key();
        let path = cache_path(&dir, &key.net);
        assert!(load(&path, &key).is_none()); // missing
        std::fs::write(&path, b"{not json").unwrap();
        assert!(load(&path, &key).is_none()); // unparseable
        std::fs::write(&path, b"{\"schema\": 99}").unwrap();
        assert!(load(&path, &key).is_none()); // wrong schema
        // Valid envelope but empty trajectory is also a miss.
        save(&path, &key, &DescentResult {
            baseline: 0.9904,
            visited: Vec::new(),
            explored: Vec::new(),
        })
        .unwrap();
        assert!(load(&path, &key).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
