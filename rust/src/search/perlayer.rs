//! Per-layer sweeps (paper §2.3, Fig 3): quantize ONE layer's weights or
//! data while every other layer stays at fp32 — the paper's key
//! characterization showing tolerance varies *within* a network.

use anyhow::Result;

use crate::coordinator::{Coordinator, EvalJob};
use crate::quant::QFormat;
use crate::search::space::PrecisionConfig;
use crate::search::{Param, SweepPoint, SAFE_DATA_F, SAFE_DATA_I};

/// Config with only layer `layer`'s `param` quantized at `bits`.
pub fn single_layer_cfg(n_layers: usize, layer: usize, param: Param, bits: i8) -> PrecisionConfig {
    let mut cfg = PrecisionConfig::fp32(n_layers);
    match param {
        Param::WeightF => cfg.wq[layer] = QFormat::new(1, bits),
        Param::DataI => cfg.dq[layer] = QFormat::new(bits, SAFE_DATA_F),
        Param::DataF => cfg.dq[layer] = QFormat::new(SAFE_DATA_I, bits),
    }
    cfg
}

/// Sweep one (layer, param) pair over `bit_range`.
pub fn sweep_layer(
    coord: &mut Coordinator,
    net: &str,
    n_layers: usize,
    layer: usize,
    param: Param,
    bit_range: (i8, i8),
    n_images: usize,
) -> Result<Vec<SweepPoint>> {
    let bits: Vec<i8> = (bit_range.0..=bit_range.1).collect();
    let mut jobs: Vec<EvalJob> = bits
        .iter()
        .map(|&b| EvalJob {
            net: net.to_string(),
            cfg: single_layer_cfg(n_layers, layer, param, b),
            n_images,
        })
        .collect();
    jobs.push(EvalJob { net: net.to_string(), cfg: PrecisionConfig::fp32(n_layers), n_images });
    let accs = coord.eval_batch(&jobs)?;
    let base = *accs.last().unwrap();
    Ok(bits
        .iter()
        .zip(&accs)
        .map(|(&b, &acc)| SweepPoint {
            bits: b,
            cfg: single_layer_cfg(n_layers, layer, param, b),
            accuracy: acc,
            relative: if base > 0.0 { acc / base } else { 0.0 },
        })
        .collect())
}

/// The full Fig-3 matrix for one network: `result[param][layer]` is the
/// sweep series. Submitted as one giant burst for maximal pool overlap.
pub fn sweep_all_layers(
    coord: &mut Coordinator,
    net: &str,
    n_layers: usize,
    params: &[Param],
    bit_range: (i8, i8),
    n_images: usize,
) -> Result<Vec<Vec<Vec<SweepPoint>>>> {
    let bits: Vec<i8> = (bit_range.0..=bit_range.1).collect();
    let mut jobs: Vec<EvalJob> = Vec::new();
    for &param in params {
        for layer in 0..n_layers {
            for &b in &bits {
                jobs.push(EvalJob {
                    net: net.to_string(),
                    cfg: single_layer_cfg(n_layers, layer, param, b),
                    n_images,
                });
            }
        }
    }
    jobs.push(EvalJob { net: net.to_string(), cfg: PrecisionConfig::fp32(n_layers), n_images });
    let accs = coord.eval_batch(&jobs)?;
    let base = *accs.last().unwrap();

    let mut out = Vec::with_capacity(params.len());
    let mut k = 0usize;
    for &param in params {
        let mut per_layer = Vec::with_capacity(n_layers);
        for layer in 0..n_layers {
            let series = bits
                .iter()
                .map(|&b| {
                    let acc = accs[k];
                    k += 1;
                    SweepPoint {
                        bits: b,
                        cfg: single_layer_cfg(n_layers, layer, param, b),
                        accuracy: acc,
                        relative: if base > 0.0 { acc / base } else { 0.0 },
                    }
                })
                .collect();
            per_layer.push(series);
        }
        out.push(per_layer);
    }
    Ok(out)
}

/// Per-layer minimum bits within tolerance — the per-layer variance
/// summary quoted in the paper's abstract ("14 bits worst case, 2 best").
pub fn min_bits_per_layer(matrix: &[Vec<SweepPoint>], tol: f64) -> Vec<Option<i8>> {
    matrix.iter().map(|series| super::uniform::min_bits_within(series, tol)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_layer_cfg_touches_one_layer() {
        let c = single_layer_cfg(4, 2, Param::DataI, 7);
        for l in 0..4 {
            assert!(c.wq[l].is_fp32());
            if l == 2 {
                assert_eq!(c.dq[l], QFormat::new(7, SAFE_DATA_F));
            } else {
                assert!(c.dq[l].is_fp32());
            }
        }
    }

    #[test]
    fn weight_param_pins_sign_bit() {
        let c = single_layer_cfg(3, 0, Param::WeightF, 4);
        assert_eq!(c.wq[0], QFormat::new(1, 4));
        assert!(c.dq[0].is_fp32());
    }
}
