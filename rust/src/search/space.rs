//! The precision-configuration space: one (I, F) per layer for weights and
//! for data (paper §2.5).

use std::fmt;

use crate::quant::QFormat;

/// A full per-layer precision assignment for one network.
///
/// `wq[l]` applies to layer *l*'s weights, `dq[l]` to its output data (the
/// network input is quantized with `dq[0]`, matching the L2 graph).
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PrecisionConfig {
    pub wq: Vec<QFormat>,
    pub dq: Vec<QFormat>,
}

impl PrecisionConfig {
    /// All-fp32 baseline.
    pub fn fp32(n_layers: usize) -> Self {
        Self { wq: vec![QFormat::FP32; n_layers], dq: vec![QFormat::FP32; n_layers] }
    }

    /// Same format everywhere ("uniform" in the paper's Fig-5 taxonomy).
    pub fn uniform(n_layers: usize, wq: QFormat, dq: QFormat) -> Self {
        Self { wq: vec![wq; n_layers], dq: vec![dq; n_layers] }
    }

    pub fn n_layers(&self) -> usize {
        self.wq.len()
    }

    /// Wire encoding for the executable: flattened (L, 2) row-major f32.
    pub fn wire_wq(&self) -> Vec<f32> {
        self.wq.iter().flat_map(|q| q.wire()).collect()
    }

    pub fn wire_dq(&self) -> Vec<f32> {
        self.dq.iter().flat_map(|q| q.wire()).collect()
    }

    /// Is any layer quantized at all?
    pub fn any_quantized(&self) -> bool {
        self.wq.iter().chain(&self.dq).any(|q| !q.is_fp32())
    }

    /// The paper's Table-2 notation: weights as `I.F` per layer joined
    /// with `-`, data likewise (reported separately).
    pub fn notation(&self) -> String {
        format!(
            "w[{}] d[{}]",
            self.wq.iter().map(|q| q.to_string()).collect::<Vec<_>>().join("-"),
            self.dq.iter().map(|q| q.to_string()).collect::<Vec<_>>().join("-"),
        )
    }

    /// All "delta" neighbours per the paper's slowest-gradient-descent:
    /// each tunable field (per-layer data I, data F, weight F — and weight
    /// I if `tune_weight_i`) reduced by one, subject to floors.
    ///
    /// Fields already at their floor produce no neighbour. The returned
    /// label describes the move, e.g. `"d3.I-1"`.
    pub fn descent_neighbours(&self, opts: &DescentOptions) -> Vec<(String, PrecisionConfig)> {
        let mut out = Vec::new();
        for l in 0..self.n_layers() {
            // data integer bits
            if self.dq[l].ibits > opts.min_data_i {
                let mut c = self.clone();
                c.dq[l].ibits -= 1;
                out.push((format!("d{l}.I-1"), c));
            }
            // data fraction bits
            if opts.tune_data_f && self.dq[l].fbits > opts.min_data_f {
                let mut c = self.clone();
                c.dq[l].fbits -= 1;
                out.push((format!("d{l}.F-1"), c));
            }
            // weight fraction bits
            if self.wq[l].fbits > opts.min_weight_f {
                let mut c = self.clone();
                c.wq[l].fbits -= 1;
                out.push((format!("w{l}.F-1"), c));
            }
            if opts.tune_weight_i && self.wq[l].ibits > opts.min_weight_i {
                let mut c = self.clone();
                c.wq[l].ibits -= 1;
                out.push((format!("w{l}.I-1"), c));
            }
        }
        out
    }
}

impl fmt::Display for PrecisionConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.notation())
    }
}

/// Floors and toggles for [`PrecisionConfig::descent_neighbours`].
///
/// Defaults mirror the paper: weights keep I=1 fixed (sign bit only) and
/// vary F; data varies I always and F only for the simple networks
/// (LeNet, Convnet) — the complex nets fix data F (§2.5).
#[derive(Clone, Copy, Debug)]
pub struct DescentOptions {
    pub tune_data_f: bool,
    pub tune_weight_i: bool,
    pub min_data_i: i8,
    pub min_data_f: i8,
    pub min_weight_f: i8,
    pub min_weight_i: i8,
}

impl Default for DescentOptions {
    fn default() -> Self {
        Self {
            tune_data_f: true,
            tune_weight_i: false,
            min_data_i: 1,
            min_data_f: 0,
            min_weight_f: 1,
            min_weight_i: 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp32_baseline_is_unquantized() {
        let c = PrecisionConfig::fp32(4);
        assert!(!c.any_quantized());
        assert_eq!(c.n_layers(), 4);
        assert_eq!(c.wire_dq(), vec![-1.0, 0.0, -1.0, 0.0, -1.0, 0.0, -1.0, 0.0]);
    }

    #[test]
    fn uniform_wire_layout() {
        let c = PrecisionConfig::uniform(2, QFormat::new(1, 8), QFormat::new(12, 2));
        assert_eq!(c.wire_wq(), vec![1.0, 8.0, 1.0, 8.0]);
        assert_eq!(c.wire_dq(), vec![12.0, 2.0, 12.0, 2.0]);
        assert!(c.any_quantized());
    }

    #[test]
    fn neighbours_respect_floors() {
        let c = PrecisionConfig::uniform(2, QFormat::new(1, 1), QFormat::new(1, 0));
        // data I at floor (1), data F at floor (0), weight F at floor (1)
        let n = c.descent_neighbours(&DescentOptions::default());
        assert!(n.is_empty(), "{n:?}");
    }

    #[test]
    fn neighbours_count_and_labels() {
        let c = PrecisionConfig::uniform(3, QFormat::new(1, 8), QFormat::new(10, 2));
        let n = c.descent_neighbours(&DescentOptions::default());
        // per layer: d.I, d.F, w.F => 9 neighbours
        assert_eq!(n.len(), 9);
        assert!(n.iter().any(|(lbl, _)| lbl == "d1.F-1"));
        // every neighbour differs from the base in exactly one field by one bit
        for (_, cand) in &n {
            let mut diffs = 0;
            for l in 0..3 {
                diffs += (cand.dq[l].ibits != c.dq[l].ibits) as u32;
                diffs += (cand.dq[l].fbits != c.dq[l].fbits) as u32;
                diffs += (cand.wq[l].ibits != c.wq[l].ibits) as u32;
                diffs += (cand.wq[l].fbits != c.wq[l].fbits) as u32;
            }
            assert_eq!(diffs, 1);
        }
    }

    #[test]
    fn fixed_data_f_mode() {
        let c = PrecisionConfig::uniform(2, QFormat::new(1, 8), QFormat::new(10, 0));
        let opts = DescentOptions { tune_data_f: false, ..Default::default() };
        let n = c.descent_neighbours(&opts);
        assert!(n.iter().all(|(lbl, _)| !lbl.contains(".F-1") || lbl.starts_with('w')));
        assert_eq!(n.len(), 4); // d.I and w.F per layer
    }

    #[test]
    fn config_hashable_and_ordered() {
        use std::collections::HashSet;
        let a = PrecisionConfig::uniform(2, QFormat::new(1, 4), QFormat::new(8, 0));
        let b = a.clone();
        let mut set = HashSet::new();
        set.insert(a);
        assert!(set.contains(&b));
    }
}
