//! Stage-granularity sweep (paper Fig 1): vary the data precision of the
//! individual computational stages *inside* one layer (AlexNet layer 2:
//! conv2 / relu2 / pool2 / norm2) to show stages within a layer share a
//! tolerance — the justification for per-layer (not per-stage) assignment.
//!
//! Uses the dedicated stage-variant executable (extra `sq` operand);
//! runs on a caller-provided [`NetExecutor`] rather than the coordinator
//! since only this experiment needs the variant.

use anyhow::Result;

use crate::backend::{NetExecutor, Variant};
use crate::eval::{top1, Dataset};
use crate::nets::NetManifest;
use crate::quant::QFormat;
use crate::search::space::PrecisionConfig;
use crate::search::SweepPoint;

/// Sweep stage `stage` of the manifest's stage-variant group over data
/// integer bits `bit_range` (fraction pinned to `fbits`). All other
/// stages, all layers, and all weights stay fp32.
pub fn sweep_stage(
    exec: &mut dyn NetExecutor,
    m: &NetManifest,
    dataset: &Dataset,
    stage: usize,
    bit_range: (i8, i8),
    fbits: i8,
    n_images: usize,
) -> Result<Vec<SweepPoint>> {
    let sv = m
        .stage_variant
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("{} has no stage variant", m.name))?;
    anyhow::ensure!(stage < sv.n_stages, "stage {stage} out of {}", sv.n_stages);
    let nl = m.n_layers();
    let fp32 = PrecisionConfig::fp32(nl);
    let wq = fp32.wire_wq();
    let dq = fp32.wire_dq();

    let sentinel = sentinel_sq(sv.n_stages);
    let baseline = run_with_sq(exec, dataset, &wq, &dq, &sentinel, n_images)?;

    let mut out = Vec::new();
    for bits in bit_range.0..=bit_range.1 {
        let mut sq = sentinel_sq(sv.n_stages);
        sq[stage * 2] = bits as f32;
        sq[stage * 2 + 1] = fbits as f32;
        let acc = run_with_sq(exec, dataset, &wq, &dq, &sq, n_images)?;
        let mut cfg = fp32.clone();
        // annotate the config with the stage format on the group's layer
        cfg.dq[sv.group_index] = QFormat::new(bits, fbits);
        out.push(SweepPoint {
            bits,
            cfg,
            accuracy: acc,
            relative: if baseline > 0.0 { acc / baseline } else { 0.0 },
        });
    }
    Ok(out)
}

fn sentinel_sq(n_stages: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; n_stages * 2];
    for s in 0..n_stages {
        v[s * 2] = -1.0;
    }
    v
}

fn run_with_sq(
    exec: &mut dyn NetExecutor,
    dataset: &Dataset,
    wq: &[f32],
    dq: &[f32],
    sq: &[f32],
    n_images: usize,
) -> Result<f64> {
    anyhow::ensure!(exec.variant() == Variant::Stages, "need the stage-variant executor");
    let batch = exec.batch();
    let n = if n_images == 0 { dataset.n } else { n_images.min(dataset.n) };
    let n_batches = (n / batch).max(1);
    let classes = exec.num_classes();
    let mut correct = 0.0;
    for b in 0..n_batches {
        let logits = exec.infer(dataset.batch_images(b, batch), wq, dq, Some(sq))?;
        correct += top1(&logits, dataset.batch_labels(b, batch), classes) * batch as f64;
    }
    Ok(correct / (n_batches * batch) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sentinel_layout() {
        let s = sentinel_sq(3);
        assert_eq!(s, vec![-1.0, 0.0, -1.0, 0.0, -1.0, 0.0]);
    }
}
