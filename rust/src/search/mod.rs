//! Precision-search machinery: the paper's characterization sweeps (§2.2,
//! §2.3), the slowest-gradient-descent explorer (§2.5), Pareto-frontier
//! extraction (Fig 5) and the Table-2 selection rule.

pub mod cache;
pub mod greedy;
pub mod pareto;
pub mod perlayer;
pub mod space;
pub mod stages;
pub mod table2;
pub mod uniform;

use crate::search::space::PrecisionConfig;

/// One measured point of any sweep: a config, the bits value that was
/// swept, and the resulting accuracy.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub bits: i8,
    pub cfg: PrecisionConfig,
    pub accuracy: f64,
    /// Accuracy relative to the fp32 baseline (paper's Fig 2/3 y-axis).
    pub relative: f64,
}

/// Which representation field a sweep varies (paper's three columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Param {
    /// Weight fraction bits (integer part pinned to 1 — the sign bit).
    WeightF,
    /// Data integer bits (fraction pinned to a safe value).
    DataI,
    /// Data fraction bits (integer pinned to a safe value).
    DataF,
}

impl Param {
    pub fn label(&self) -> &'static str {
        match self {
            Param::WeightF => "weight fraction bits",
            Param::DataI => "data integer bits",
            Param::DataF => "data fraction bits",
        }
    }
}

/// Safe pin values used for the non-swept field, chosen from Fig-2-style
/// headroom: data I=14 / F=8 introduce no measurable error on any of the
/// five networks.
pub const SAFE_DATA_I: i8 = 14;
pub const SAFE_DATA_F: i8 = 8;
pub const SAFE_WEIGHT_F: i8 = 12;
