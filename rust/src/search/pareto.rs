//! Pareto-frontier extraction in the (cost ↓, accuracy ↑) plane — the
//! "best" category of the paper's Fig 5. The cost axis is whatever the
//! caller prices configs in; since the memory subsystem landed, the
//! repro harness and `qbound footprint` rank by **modeled data
//! footprint** ([`crate::memory::FootprintModel::ratio`]) rather than
//! raw bit-weighted traffic.

/// Indices of the non-dominated points among `(cost, accuracy)` pairs.
///
/// A point dominates another if it has ≤ cost AND ≥ accuracy with at
/// least one strict. Returned indices are sorted by cost ascending;
/// duplicate (cost, accuracy) pairs keep their first occurrence.
pub fn frontier(points: &[(f64, f64)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    // Sort by cost asc, accuracy desc so a single sweep suffices.
    idx.sort_by(|&a, &b| {
        points[a]
            .0
            .partial_cmp(&points[b].0)
            .unwrap()
            .then(points[b].1.partial_cmp(&points[a].1).unwrap())
            .then(a.cmp(&b))
    });
    let mut out = Vec::new();
    let mut best_acc = f64::NEG_INFINITY;
    let mut last_traffic = f64::NEG_INFINITY;
    for &i in &idx {
        let (t, a) = points[i];
        if a > best_acc {
            // strictly better accuracy than anything cheaper → frontier
            out.push(i);
            best_acc = a;
            last_traffic = t;
        } else if a == best_acc && t == last_traffic {
            // exact duplicate of the frontier point — skip
        }
    }
    out
}

/// True if `p` is dominated by any point in `points`.
pub fn dominated(p: (f64, f64), points: &[(f64, f64)]) -> bool {
    points.iter().any(|&(t, a)| {
        (t <= p.0 && a >= p.1) && (t < p.0 || a > p.1)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_frontier() {
        // (traffic, acc)
        let pts = vec![(1.0, 0.5), (0.5, 0.4), (0.8, 0.45), (0.3, 0.2), (0.9, 0.3)];
        let f = frontier(&pts);
        // sorted by traffic: 0.3/0.2, 0.5/0.4, 0.8/0.45, 1.0/0.5 — all rising
        assert_eq!(f, vec![3, 1, 2, 0]);
    }

    #[test]
    fn dominated_points_excluded() {
        let pts = vec![(0.5, 0.9), (0.6, 0.8), (0.7, 0.95)];
        let f = frontier(&pts);
        assert!(f.contains(&0));
        assert!(f.contains(&2));
        assert!(!f.contains(&1)); // worse than 0 in both dims
    }

    #[test]
    fn equal_points_kept_once() {
        let pts = vec![(0.5, 0.9), (0.5, 0.9), (0.4, 0.9)];
        let f = frontier(&pts);
        // 0.4/0.9 dominates both 0.5/0.9
        assert_eq!(f, vec![2]);
    }

    #[test]
    fn dominated_predicate() {
        let pts = vec![(0.5, 0.9)];
        assert!(dominated((0.6, 0.8), &pts));
        assert!(dominated((0.5, 0.8), &pts));
        assert!(!dominated((0.5, 0.9), &pts)); // equal is not dominated
        assert!(!dominated((0.4, 0.1), &pts)); // cheaper
    }

    #[test]
    fn empty_and_singleton() {
        assert!(frontier(&[]).is_empty());
        assert_eq!(frontier(&[(1.0, 1.0)]), vec![0]);
    }

    #[test]
    fn frontier_is_monotone() {
        // property-style: random cloud, frontier accuracy must rise with traffic
        let mut rng = crate::prng::Xoshiro256pp::new(21);
        let pts: Vec<(f64, f64)> =
            (0..200).map(|_| (rng.uniform(), rng.uniform())).collect();
        let f = frontier(&pts);
        for w in f.windows(2) {
            assert!(pts[w[0]].0 <= pts[w[1]].0);
            assert!(pts[w[0]].1 < pts[w[1]].1);
        }
        // no frontier point dominated by any cloud point
        for &i in &f {
            assert!(!dominated(pts[i], &pts));
        }
    }
}
