//! The evaluation coordinator: a worker-pool service that answers
//! accuracy queries for (network, precision-config) pairs.
//!
//! This is the L3 systems core (vLLM-router-shaped, scaled to this
//! paper's workload): sweeps and searches generate bursts of hundreds of
//! evaluation jobs; the coordinator
//!
//!   * deduplicates identical jobs within a burst,
//!   * consults a global memo cache (shared across workers and bursts),
//!   * dispatches remaining work over N worker threads — each worker owns
//!     its own backend instance (+ per-net executors with resident
//!     weights, created lazily on first use), because executors are not
//!     `Send` (the PJRT client is `Rc`-based) and must not cross threads,
//!   * preserves job order in the returned results.
//!
//! `tokio` is unavailable offline; the pool is std threads + mpsc channels
//! with a `Mutex<Receiver>` work queue (work-stealing by contention).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::backend::{Backend, BackendKind};
use crate::eval::Evaluator;
use crate::nets::{ArtifactIndex, NetManifest};
use crate::search::space::PrecisionConfig;

/// One unit of work: evaluate top-1 accuracy of `cfg` on `net`.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct EvalJob {
    pub net: String,
    pub cfg: PrecisionConfig,
    /// Number of images (0 = full eval split).
    pub n_images: usize,
}

type JobMsg = (u64, EvalJob);
type DoneMsg = (u64, Result<f64, String>);

/// Aggregate service counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct CoordinatorStats {
    pub submitted: u64,
    pub cache_hits: u64,
    pub deduped: u64,
    pub executed: u64,
    pub errors: u64,
}

/// Worker-pool evaluation service. Single consumer (`&mut self` API),
/// many internal workers.
pub struct Coordinator {
    job_tx: Sender<JobMsg>,
    done_rx: Receiver<DoneMsg>,
    workers: Vec<std::thread::JoinHandle<()>>,
    cache: Arc<Mutex<HashMap<EvalJob, f64>>>,
    stats: Arc<Stats>,
    /// Evaluator batch override shared with the workers (0 = auto).
    eval_batch: Arc<AtomicUsize>,
    next_id: u64,
    pub n_workers: usize,
    pub backend: BackendKind,
}

#[derive(Default)]
struct Stats {
    submitted: AtomicU64,
    cache_hits: AtomicU64,
    deduped: AtomicU64,
    executed: AtomicU64,
    errors: AtomicU64,
    busy_ns: AtomicU64,
}

/// Worker-count heuristic: one worker per available core. Workers run
/// compute-bound forward passes (and a PJRT worker owns a full XLA CPU
/// client with its own thread pool); oversubscribing cores makes bursts
/// *slower* (measured 2.2× on a 1-core box — see EXPERIMENTS.md
/// §Perf), so the default never exceeds the core count.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

impl Coordinator {
    /// Start `n_workers` workers (0 = auto, one per core) serving the
    /// networks listed in the artifact index at `dir`, on the backend
    /// selected by `QBOUND_BACKEND` (default: reference).
    pub fn new(dir: &std::path::Path, n_workers: usize) -> Result<Coordinator> {
        Coordinator::with_backend(dir, n_workers, BackendKind::from_env()?)
    }

    /// [`Coordinator::new`] with an explicit execution backend.
    pub fn with_backend(
        dir: &std::path::Path,
        n_workers: usize,
        backend: BackendKind,
    ) -> Result<Coordinator> {
        let n_workers = if n_workers == 0 { default_workers() } else { n_workers };
        let index = ArtifactIndex::load(dir)?;
        let manifests: Arc<Vec<NetManifest>> = Arc::new(
            index
                .nets
                .iter()
                .map(|n| NetManifest::load(dir, n))
                .collect::<Result<Vec<_>>>()
                .context("loading manifests")?,
        );
        let (job_tx, job_rx) = channel::<JobMsg>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (done_tx, done_rx) = channel::<DoneMsg>();
        let cache: Arc<Mutex<HashMap<EvalJob, f64>>> = Arc::new(Mutex::new(HashMap::new()));
        let stats = Arc::new(Stats::default());
        let eval_batch = Arc::new(AtomicUsize::new(0));

        let mut workers = Vec::with_capacity(n_workers);
        for wid in 0..n_workers {
            let job_rx = Arc::clone(&job_rx);
            let done_tx = done_tx.clone();
            let manifests = Arc::clone(&manifests);
            let cache = Arc::clone(&cache);
            let stats = Arc::clone(&stats);
            let eval_batch = Arc::clone(&eval_batch);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("qbound-worker-{wid}"))
                    .spawn(move || {
                        worker_loop(
                            job_rx, done_tx, manifests, cache, stats, eval_batch, backend,
                            n_workers,
                        )
                    })
                    .context("spawning worker")?,
            );
        }
        Ok(Coordinator {
            job_tx,
            done_rx,
            workers,
            cache,
            stats,
            eval_batch,
            next_id: 0,
            n_workers,
            backend,
        })
    }

    /// Force every worker's evaluator to a fixed infer batch (0 = auto:
    /// the largest the backend allows). Affects jobs dispatched after
    /// the call. The memo cache is dropped: a job's evaluated image
    /// count is `floor(n/batch)*batch`, so entries computed under a
    /// different batch may cover a different span.
    pub fn set_eval_batch(&self, batch: usize) {
        self.eval_batch.store(batch, Ordering::Relaxed);
        self.cache.lock().unwrap().clear();
    }

    /// Convenience: coordinator over the default artifacts dir.
    pub fn from_artifacts(n_workers: usize) -> Result<Coordinator> {
        Coordinator::new(&crate::util::artifacts_dir()?, n_workers)
    }

    /// Evaluate a burst of jobs; results are positionally aligned with
    /// `jobs`. Duplicate jobs and cache hits cost nothing.
    pub fn eval_batch(&mut self, jobs: &[EvalJob]) -> Result<Vec<f64>> {
        self.stats.submitted.fetch_add(jobs.len() as u64, Ordering::Relaxed);
        let mut results: Vec<Option<f64>> = vec![None; jobs.len()];

        // Cache pass + in-burst dedup.
        let mut pending: HashMap<EvalJob, Vec<usize>> = HashMap::new();
        {
            let cache = self.cache.lock().unwrap();
            for (i, job) in jobs.iter().enumerate() {
                if let Some(&v) = cache.get(job) {
                    self.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
                    results[i] = Some(v);
                } else {
                    let slot = pending.entry(job.clone()).or_default();
                    if !slot.is_empty() {
                        self.stats.deduped.fetch_add(1, Ordering::Relaxed);
                    }
                    slot.push(i);
                }
            }
        }

        // Dispatch unique misses.
        let mut inflight: HashMap<u64, EvalJob> = HashMap::new();
        for job in pending.keys() {
            let id = self.next_id;
            self.next_id += 1;
            self.job_tx.send((id, job.clone())).context("job queue closed")?;
            inflight.insert(id, job.clone());
        }

        // Collect.
        while !inflight.is_empty() {
            let (id, res) = self
                .done_rx
                .recv_timeout(Duration::from_secs(600))
                .context("worker pool stalled (>600s)")?;
            let job = match inflight.remove(&id) {
                Some(j) => j,
                None => continue, // stale completion from an aborted burst
            };
            let v = res.map_err(|e| anyhow::anyhow!("eval {job:?}: {e}"))?;
            for &i in &pending[&job] {
                results[i] = Some(v);
            }
        }
        Ok(results.into_iter().map(|r| r.expect("all slots filled")).collect())
    }

    /// Evaluate one job.
    pub fn eval_one(&mut self, job: EvalJob) -> Result<f64> {
        Ok(self.eval_batch(std::slice::from_ref(&job))?[0])
    }

    /// Replay a timed request stream (serve mode). `arrivals` carries
    /// (offset-from-start, job); returns per-request (queueing+service)
    /// latency, in arrival order. Wall-clock faithful: requests are not
    /// dispatched before their arrival offset.
    pub fn run_stream(&mut self, arrivals: &[(Duration, EvalJob)]) -> Result<Vec<Duration>> {
        let start = Instant::now();
        let mut latencies: Vec<Option<Duration>> = vec![None; arrivals.len()];
        let mut inflight: HashMap<u64, (usize, Instant)> = HashMap::new();
        let mut next = 0usize;
        while next < arrivals.len() || !inflight.is_empty() {
            // Dispatch everything whose arrival time has passed.
            while next < arrivals.len() && start.elapsed() >= arrivals[next].0 {
                let id = self.next_id;
                self.next_id += 1;
                // Serve mode bypasses the memo cache: every request pays
                // for real inference (cache would trivialize the bench).
                self.job_tx.send((id, arrivals[next].1.clone())).context("queue closed")?;
                inflight.insert(id, (next, Instant::now()));
                next += 1;
            }
            // Wait for either the next arrival or a completion.
            let wait = if next < arrivals.len() {
                arrivals[next].0.saturating_sub(start.elapsed()).min(Duration::from_millis(50))
            } else {
                Duration::from_millis(50)
            };
            match self.done_rx.recv_timeout(wait.max(Duration::from_millis(1))) {
                Ok((id, res)) => {
                    if let Some((idx, t0)) = inflight.remove(&id) {
                        res.map_err(|e| anyhow::anyhow!("serve job failed: {e}"))?;
                        latencies[idx] = Some(t0.elapsed());
                    }
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(e) => anyhow::bail!("worker pool died: {e}"),
            }
        }
        Ok(latencies.into_iter().map(|l| l.expect("completed")).collect())
    }

    pub fn stats(&self) -> CoordinatorStats {
        CoordinatorStats {
            submitted: self.stats.submitted.load(Ordering::Relaxed),
            cache_hits: self.stats.cache_hits.load(Ordering::Relaxed),
            deduped: self.stats.deduped.load(Ordering::Relaxed),
            executed: self.stats.executed.load(Ordering::Relaxed),
            errors: self.stats.errors.load(Ordering::Relaxed),
        }
    }

    /// Total busy time across workers (utilization numerator).
    pub fn busy_time(&self) -> Duration {
        Duration::from_nanos(self.stats.busy_ns.load(Ordering::Relaxed))
    }

    /// Number of memoized results.
    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Closing the channel ends the worker loops.
        let (dead_tx, _) = channel();
        drop(std::mem::replace(&mut self.job_tx, dead_tx));
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Build one worker's backend. The fast backend would otherwise default
/// to one thread *per core* in every worker — `workers × cores` compute
/// threads for the pool — so when `QBOUND_THREADS` is unset the core
/// budget is divided across the workers instead (an explicit setting
/// always wins). Shared with the serve daemon's worker pool
/// ([`crate::serve`]), which has the same per-worker thread-budget
/// problem.
/// `store` is the packed-weight store the worker's fast backend should
/// load/publish bitstreams through — the *final* word, overriding
/// `QBOUND_STORE_DIR` (the serve daemon pins workers to its
/// `--store-dir`; the coordinator passes the env resolution through).
pub(crate) fn backend_for_worker(
    kind: BackendKind,
    n_workers: usize,
    store: Option<Arc<crate::store::Store>>,
) -> Result<Box<dyn Backend>> {
    if kind == BackendKind::Fast {
        let backend = if std::env::var_os("QBOUND_THREADS").is_none() {
            let per_worker = (default_workers() / n_workers.max(1)).max(1);
            crate::backend::fast::FastBackend::with_options(
                per_worker,
                crate::memory::StorageMode::from_env()?,
            )
        } else {
            crate::backend::fast::FastBackend::new()?
        };
        return Ok(Box::new(backend.with_store(store)));
    }
    kind.create()
}

fn worker_loop(
    job_rx: Arc<Mutex<Receiver<JobMsg>>>,
    done_tx: Sender<DoneMsg>,
    manifests: Arc<Vec<NetManifest>>,
    cache: Arc<Mutex<HashMap<EvalJob, f64>>>,
    stats: Arc<Stats>,
    eval_batch: Arc<AtomicUsize>,
    kind: BackendKind,
    n_workers: usize,
) {
    // Backend + evaluators are created lazily per worker: a worker that
    // never sees a googlenet job never loads googlenet.
    let backend = match backend_for_worker(kind, n_workers, crate::store::Store::from_env()) {
        Ok(b) => b,
        Err(e) => {
            log::error!("worker failed to create {} backend: {e:#}", kind.label());
            return;
        }
    };
    let mut evaluators: HashMap<String, Evaluator> = HashMap::new();
    loop {
        let msg = { job_rx.lock().unwrap().recv() };
        let (id, job) = match msg {
            Ok(m) => m,
            Err(_) => return, // coordinator dropped
        };
        let t0 = Instant::now();
        let batch_override = eval_batch.load(Ordering::Relaxed);
        let res = run_job(backend.as_ref(), &mut evaluators, &manifests, &job, batch_override);
        stats.busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        stats.executed.fetch_add(1, Ordering::Relaxed);
        if let Ok(v) = res {
            // Memoize only if the batch setting is unchanged since the
            // job started — a result computed under a stale setting may
            // cover a different image span (set_eval_batch clears the
            // cache, so re-inserting would undo that).
            if eval_batch.load(Ordering::Relaxed) == batch_override {
                cache.lock().unwrap().insert(job.clone(), v);
            }
        } else {
            stats.errors.fetch_add(1, Ordering::Relaxed);
        }
        if done_tx.send((id, res.map_err(|e| format!("{e:#}")))).is_err() {
            return;
        }
    }
}

fn run_job(
    backend: &dyn Backend,
    evaluators: &mut HashMap<String, Evaluator>,
    manifests: &[NetManifest],
    job: &EvalJob,
    batch_override: usize,
) -> Result<f64> {
    if !evaluators.contains_key(&job.net) {
        let m = manifests
            .iter()
            .find(|m| m.name == job.net)
            .ok_or_else(|| anyhow::anyhow!("unknown net {:?}", job.net))?;
        let t0 = Instant::now();
        let ev = Evaluator::new(backend, m)?;
        log::debug!("worker loaded {} in {:?}", job.net, t0.elapsed());
        evaluators.insert(job.net.clone(), ev);
    }
    let ev = evaluators.get_mut(&job.net).unwrap();
    ev.batch_override = batch_override;
    ev.accuracy(&job.cfg, job.n_images)
}
