//! Dense named tensors and the NTF container format.
//!
//! The rust side only needs host-resident dense tensors for marshalling
//! into PJRT literals/buffers and for the traffic model — no autodiff, no
//! broadcasting. Two dtypes (f32, i32) cover the whole artifact surface.

pub mod ntf;

use anyhow::{bail, Result};

/// Element type of a [`Tensor`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn id(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::I32 => 1,
        }
    }

    pub fn from_id(id: u8) -> Result<Self> {
        match id {
            0 => Ok(DType::F32),
            1 => Ok(DType::I32),
            _ => bail!("unknown dtype id {id}"),
        }
    }
}

/// Tensor payload (one vector per dtype; both 4-byte elements).
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host-resident dense tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    pub fn from_f32(dims: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {n} elems, got {}", dims, data.len());
        }
        Ok(Self { dims, data: Data::F32(data) })
    }

    pub fn from_i32(dims: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {n} elems, got {}", dims, data.len());
        }
        Ok(Self { dims, data: Data::I32(data) })
    }

    pub fn zeros_f32(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        Self { dims, data: Data::F32(vec![0.0; n]) }
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    /// Row-major slice of the leading axis: rows [start, start+count).
    pub fn slice_rows(&self, start: usize, count: usize) -> Result<Tensor> {
        if self.dims.is_empty() {
            bail!("cannot row-slice a scalar");
        }
        let rows = self.dims[0];
        if start + count > rows {
            bail!("row slice {start}+{count} out of {rows}");
        }
        let stride: usize = self.dims[1..].iter().product();
        let mut dims = self.dims.clone();
        dims[0] = count;
        Ok(match &self.data {
            Data::F32(v) => Tensor {
                dims,
                data: Data::F32(v[start * stride..(start + count) * stride].to_vec()),
            },
            Data::I32(v) => Tensor {
                dims,
                data: Data::I32(v[start * stride..(start + count) * stride].to_vec()),
            },
        })
    }

    pub fn size_bytes(&self) -> usize {
        self.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_shape_check() {
        let t = Tensor::from_f32(vec![2, 3], vec![0.0; 6]).unwrap();
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), DType::F32);
        assert!(Tensor::from_f32(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn dtype_roundtrip() {
        for d in [DType::F32, DType::I32] {
            assert_eq!(DType::from_id(d.id()).unwrap(), d);
        }
        assert!(DType::from_id(9).is_err());
    }

    #[test]
    fn slice_rows_basic() {
        let t = Tensor::from_f32(vec![4, 2], (0..8).map(|i| i as f32).collect()).unwrap();
        let s = t.slice_rows(1, 2).unwrap();
        assert_eq!(s.dims, vec![2, 2]);
        assert_eq!(s.as_f32().unwrap(), &[2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn slice_rows_bounds() {
        let t = Tensor::from_i32(vec![3], vec![1, 2, 3]).unwrap();
        assert!(t.slice_rows(2, 2).is_err());
        assert_eq!(t.slice_rows(2, 1).unwrap().as_i32().unwrap(), &[3]);
    }

    #[test]
    fn wrong_dtype_access_errors() {
        let t = Tensor::from_i32(vec![1], vec![7]).unwrap();
        assert!(t.as_f32().is_err());
        assert_eq!(t.as_i32().unwrap(), &[7]);
    }
}
