//! NTF — named-tensor file format (rust reader/writer).
//!
//! Byte-level layout is defined in `python/compile/ntf.py` (the writer of
//! the shipped artifacts); the two implementations are locked together by
//! round-trip tests on both sides. Little-endian throughout:
//!
//! ```text
//! magic  b"NTF1"
//! u32    entry count
//! entry* { u16 name_len; name; u8 dtype; u8 ndim; u64*ndim dims; raw f32/i32 }
//! u32    CRC32 (IEEE) of all preceding bytes
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{Data, DType, Tensor};

const MAGIC: &[u8; 4] = b"NTF1";

// ---- crc32 (IEEE 802.3, reflected) — table-driven ---------------------------

fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// CRC32 of `data` (zlib.crc32-compatible).
pub fn crc32(data: &[u8]) -> u32 {
    // const-fn tables aren't worth the MSRV dance; compute once.
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(crc32_table);
    let mut c = !0u32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

// ---- read -------------------------------------------------------------------

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated NTF at byte {} (want {n} more)", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
}

/// Parse NTF bytes into an ordered name → tensor map.
pub fn read_bytes(raw: &[u8]) -> Result<BTreeMap<String, Tensor>> {
    if raw.len() < 12 {
        bail!("NTF too short ({} bytes)", raw.len());
    }
    if &raw[..4] != MAGIC {
        bail!("bad NTF magic {:?}", &raw[..4]);
    }
    let stored = u32::from_le_bytes(raw[raw.len() - 4..].try_into().unwrap());
    let computed = crc32(&raw[..raw.len() - 4]);
    if stored != computed {
        bail!("NTF CRC mismatch: stored {stored:#x} computed {computed:#x}");
    }
    let body = &raw[..raw.len() - 4];
    let mut r = Reader { buf: body, pos: 4 };
    let count = r.u32()?;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let name_len = r.u16()? as usize;
        let name = std::str::from_utf8(r.take(name_len)?)
            .context("tensor name not utf-8")?
            .to_string();
        let dtype = DType::from_id(r.u8()?)?;
        let ndim = r.u8()? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(r.u64()? as usize);
        }
        let n: usize = dims.iter().product();
        let bytes = r.take(n * 4)?;
        let data = match dtype {
            DType::F32 => Data::F32(
                bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
            DType::I32 => Data::I32(
                bytes.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
        };
        if out.insert(name.clone(), Tensor { dims, data }).is_some() {
            bail!("duplicate tensor name {name:?}");
        }
    }
    if r.pos != body.len() {
        bail!("{} trailing bytes after last entry", body.len() - r.pos);
    }
    Ok(out)
}

/// Read an NTF file.
pub fn read_file(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let raw = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    read_bytes(&raw).with_context(|| format!("parsing {}", path.display()))
}

// ---- write ------------------------------------------------------------------

/// Serialize tensors to NTF bytes (iteration order = map order).
pub fn write_bytes(tensors: &BTreeMap<String, Tensor>) -> Result<Vec<u8>> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, t) in tensors {
        let nb = name.as_bytes();
        if nb.len() > u16::MAX as usize {
            bail!("tensor name too long");
        }
        buf.extend_from_slice(&(nb.len() as u16).to_le_bytes());
        buf.extend_from_slice(nb);
        buf.push(t.dtype().id());
        buf.push(t.dims.len() as u8);
        for &d in &t.dims {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        match &t.data {
            Data::F32(v) => {
                for x in v {
                    buf.extend_from_slice(&x.to_le_bytes());
                }
            }
            Data::I32(v) => {
                for x in v {
                    buf.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }
    let crc = crc32(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());
    Ok(buf)
}

/// Write an NTF file.
pub fn write_file(path: &Path, tensors: &BTreeMap<String, Tensor>) -> Result<()> {
    let bytes = write_bytes(tensors)?;
    crate::util::write_file(path, &bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BTreeMap<String, Tensor> {
        let mut m = BTreeMap::new();
        m.insert(
            "w".to_string(),
            Tensor::from_f32(vec![2, 2], vec![1.5, -2.25, 0.0, 3.0e7]).unwrap(),
        );
        m.insert("labels".to_string(), Tensor::from_i32(vec![3], vec![0, -5, 19]).unwrap());
        m.insert("scalarish".to_string(), Tensor::from_f32(vec![1], vec![42.0]).unwrap());
        m
    }

    #[test]
    fn roundtrip() {
        let m = sample();
        let bytes = write_bytes(&m).unwrap();
        let back = read_bytes(&bytes).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn crc_detects_corruption() {
        let m = sample();
        let mut bytes = write_bytes(&m).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        assert!(read_bytes(&bytes).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let m = sample();
        let mut bytes = write_bytes(&m).unwrap();
        bytes[0] = b'X';
        assert!(read_bytes(&bytes).is_err());
    }

    #[test]
    fn truncation_rejected() {
        let m = sample();
        let bytes = write_bytes(&m).unwrap();
        for cut in [5, bytes.len() / 2, bytes.len() - 1] {
            assert!(read_bytes(&bytes[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn crc32_known_value() {
        // zlib.crc32(b"123456789") == 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn empty_container_roundtrips() {
        let m = BTreeMap::new();
        let bytes = write_bytes(&m).unwrap();
        assert_eq!(read_bytes(&bytes).unwrap().len(), 0);
    }
}
