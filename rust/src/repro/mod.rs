//! Paper-experiment reproduction harnesses — one function per table/figure.
//!
//! Every harness prints a textual rendering (table + ASCII chart) and
//! writes machine-readable CSV/markdown into the report directory. The
//! mapping to the paper (DESIGN.md §5):
//!
//! | fn | paper artifact |
//! |---|---|
//! | [`table1`] | Table 1 — networks + fp32 baselines (re-measured through the rust runtime) |
//! | [`fig1`]   | Fig 1 — AlexNet layer-2 stage-granularity sweep |
//! | [`fig2`]   | Fig 2 — uniform representation sweeps (3 params × 5 nets) |
//! | [`fig3`]   | Fig 3 — per-layer sweeps (3 params × every layer) |
//! | [`fig4`]   | Fig 4 — traffic model, single vs batch |
//! | [`fig5_table2`] | Fig 5 scatter + Table 2 min-traffic configs |

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::backend::{BackendKind, Variant};
use crate::coordinator::{Coordinator, EvalJob};
use crate::eval::Dataset;
use crate::memory::FootprintModel;
use crate::nets::{ArtifactIndex, NetManifest};
use crate::quant::QFormat;
use crate::report::{pct, ratio, Chart, Table};
use crate::search::greedy::{self, GreedyOptions};
use crate::search::space::{DescentOptions, PrecisionConfig};
use crate::search::{cache, pareto, perlayer, stages, table2, uniform, Param};
use crate::traffic::{self, Mode};
use crate::util;

/// Shared context for the repro harnesses.
pub struct ReproCtx {
    pub artifacts: PathBuf,
    pub out_dir: PathBuf,
    pub coord: Coordinator,
    pub index: ArtifactIndex,
    pub manifests: Vec<NetManifest>,
    /// Images per accuracy evaluation (0 = full eval split).
    pub n_images: usize,
    /// Execution backend for the coordinator and the Fig-1 harness.
    pub backend: BackendKind,
}

impl ReproCtx {
    /// Context on the `QBOUND_BACKEND`-selected backend (default:
    /// reference).
    pub fn new(out_dir: &Path, workers: usize, n_images: usize) -> Result<ReproCtx> {
        ReproCtx::with_backend(out_dir, workers, n_images, BackendKind::from_env()?)
    }

    /// [`ReproCtx::new`] with an explicit execution backend.
    pub fn with_backend(
        out_dir: &Path,
        workers: usize,
        n_images: usize,
        backend: BackendKind,
    ) -> Result<ReproCtx> {
        let artifacts = util::artifacts_dir()?;
        let index = ArtifactIndex::load(&artifacts)?;
        let manifests = index
            .nets
            .iter()
            .map(|n| NetManifest::load(&artifacts, n))
            .collect::<Result<Vec<_>>>()?;
        let coord = Coordinator::with_backend(&artifacts, workers, backend)?;
        std::fs::create_dir_all(out_dir)?;
        Ok(ReproCtx {
            artifacts,
            out_dir: out_dir.to_path_buf(),
            coord,
            index,
            manifests,
            n_images,
            backend,
        })
    }

    pub fn manifest(&self, net: &str) -> Result<&NetManifest> {
        self.manifests
            .iter()
            .find(|m| m.name == net)
            .ok_or_else(|| anyhow::anyhow!("no manifest for {net:?}"))
    }

    fn write(&self, name: &str, contents: &str) -> Result<()> {
        util::write_file(&self.out_dir.join(name), contents.as_bytes())
    }
}

/// The paper's §2.5 per-net data-fraction policy: for the complex nets,
/// data F is PINNED to "a value achieving less than 0.1% error in
/// Figure 3 (right column)" and only data I + weight F are searched;
/// LeNet/Convnet tune F too.
///
/// The paper's absolute pins were 0/0/2 — its ImageNet networks carry
/// large-dynamic-range activations where the integer part dominates. Our
/// scaled nets normalize inputs to [0,1] (and AlexNet's LRN shrinks
/// activations further), shifting the need toward fraction bits; the pins
/// below are this repo's own measured Fig-3 values, same methodology
/// (see EXPERIMENTS.md §Fig5/Table2 for the deviation note).
pub fn data_f_policy(net: &str) -> Option<i8> {
    match net {
        "alexnet" => Some(4),
        "nin" => Some(4),
        "googlenet" => Some(5),
        _ => None,
    }
}

/// Human layer summary, e.g. "2 CONV + 2 FC" / "2 CONV + 9 IM".
fn layer_summary(m: &NetManifest) -> String {
    let count = |k: &str| m.layers.iter().filter(|l| l.kind == k).count();
    let (c, f, i) = (count("conv"), count("fc"), count("inception"));
    let mut parts = Vec::new();
    if c > 0 {
        parts.push(format!("{c} CONV"));
    }
    if f > 0 {
        parts.push(format!("{f} FC"));
    }
    if i > 0 {
        parts.push(format!("{i} IM"));
    }
    parts.join(" + ")
}

// ---------------------------------------------------------------------------
// Table 1
// ---------------------------------------------------------------------------

/// Table 1: networks studied + baseline top-1, re-measured end-to-end
/// through the PJRT runtime (runtime-parity check vs the python-recorded
/// value in the manifest).
pub fn table1(ctx: &mut ReproCtx) -> Result<String> {
    let mut t = Table::new(
        "Table 1 — networks studied (baseline = fp32)",
        &["network", "dataset", "layers", "params", "MACs/img", "top-1 (py)", "top-1 (rust)", "Δ"],
    );
    for m in ctx.manifests.clone() {
        let measured = ctx.coord.eval_one(EvalJob {
            net: m.name.clone(),
            cfg: PrecisionConfig::fp32(m.n_layers()),
            n_images: 0, // full split: this is the headline parity check
        })?;
        t.row(vec![
            m.name.clone(),
            m.dataset.clone(),
            layer_summary(&m),
            util::human_count(m.total_weights() as f64),
            util::human_count(m.total_macs() as f64),
            format!("{:.4}", m.baseline_top1),
            format!("{measured:.4}"),
            format!("{:+.4}", measured - m.baseline_top1),
        ]);
    }
    let text = t.text();
    println!("{text}");
    ctx.write("table1.md", &t.markdown())?;
    ctx.write("table1.csv", &t.csv())?;
    Ok(text)
}

// ---------------------------------------------------------------------------
// Fig 1
// ---------------------------------------------------------------------------

/// Fig 1: accuracy vs data bits for each stage inside AlexNet layer 2
/// (conv/relu/pool/norm quantized one at a time). Demonstrates stages
/// within a layer share tolerance — the per-layer granularity argument.
pub fn fig1(ctx: &mut ReproCtx) -> Result<String> {
    let m = ctx.manifest("alexnet")?.clone();
    let sv = m
        .stage_variant
        .clone()
        .ok_or_else(|| anyhow::anyhow!("alexnet manifest lacks stage variant"))?;
    let backend = ctx.backend.create()?;
    let mut exec = backend.load(&m, Variant::Stages)?;
    let dataset = Dataset::load(&m)?;

    let mut chart = Chart::new(
        "Fig 1 — AlexNet layer-2 stage tolerance (accuracy vs data integer bits)",
        "data integer bits (F=2)",
        "relative accuracy",
    );
    let mut t = Table::new(
        "Fig 1 — per-stage minimum bits (rel. accuracy ≥ 99%)",
        &["stage", "min bits", "series (bits: rel-acc)"],
    );
    let markers = ['c', 'r', 'p', 'n', 'x', 'y'];
    let mut out = String::new();
    for (si, stage_name) in sv.stage_names.iter().enumerate() {
        let pts = stages::sweep_stage(
            exec.as_mut(),
            &m,
            &dataset,
            si,
            (1, 12),
            2,
            ctx.n_images,
        )?;
        chart.series(
            markers[si % markers.len()],
            pts.iter().map(|p| (p.bits as f64, p.relative)).collect(),
        );
        let min_bits = uniform::min_bits_within(&pts, 0.01);
        t.row(vec![
            stage_name.clone(),
            min_bits.map(|b| b.to_string()).unwrap_or_else(|| "-".into()),
            pts.iter()
                .map(|p| format!("{}:{:.3}", p.bits, p.relative))
                .collect::<Vec<_>>()
                .join(" "),
        ]);
    }
    out.push_str(&chart.render());
    out.push_str(&t.text());
    println!("{out}");
    ctx.write("fig1.md", &t.markdown())?;
    ctx.write("fig1.csv", &t.csv())?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Fig 2
// ---------------------------------------------------------------------------

/// Fig 2: uniform sweeps — (a) weight fraction bits, (b) data integer
/// bits, (c) data fraction bits — across all networks.
pub fn fig2(ctx: &mut ReproCtx) -> Result<String> {
    let specs: [(Param, (i8, i8), &str); 3] = [
        (Param::WeightF, (1, 12), "fig2a"),
        (Param::DataI, (1, 14), "fig2b"),
        (Param::DataF, (0, 8), "fig2c"),
    ];
    let markers = ['l', 'c', 'a', 'n', 'g'];
    let mut out = String::new();
    let manifests = ctx.manifests.clone();
    for (param, range, tag) in specs {
        let mut chart = Chart::new(
            &format!("Fig 2 ({tag}) — uniform {}", param.label()),
            param.label(),
            "relative accuracy",
        );
        let mut csv = Table::new("", &["net", "bits", "accuracy", "relative"]);
        let mut summary = Table::new(
            &format!("{tag} — minimum uniform {} within tolerance", param.label()),
            &["net", "min bits @1%", "min bits @0.1%"],
        );
        for (ni, m) in manifests.iter().enumerate() {
            let pts = uniform::sweep(
                &mut ctx.coord,
                &m.name,
                m.n_layers(),
                param,
                range,
                ctx.n_images,
            )?;
            let series: Vec<(f64, f64)> =
                pts.iter().map(|p| (p.bits as f64, p.relative)).collect();
            chart.series(markers[ni % markers.len()], series);
            for p in &pts {
                csv.row(vec![
                    m.name.clone(),
                    p.bits.to_string(),
                    format!("{:.4}", p.accuracy),
                    format!("{:.4}", p.relative),
                ]);
            }
            summary.row(vec![
                m.name.clone(),
                uniform::min_bits_within(&pts, 0.01).map(|b| b.to_string()).unwrap_or("-".into()),
                uniform::min_bits_within(&pts, 0.001).map(|b| b.to_string()).unwrap_or("-".into()),
            ]);
        }
        out.push_str(&chart.render());
        out.push_str(&summary.text());
        out.push('\n');
        ctx.write(&format!("{tag}.csv"), &csv.csv())?;
        ctx.write(&format!("{tag}.md"), &summary.markdown())?;
    }
    println!("{out}");
    Ok(out)
}

// ---------------------------------------------------------------------------
// Fig 3
// ---------------------------------------------------------------------------

/// Fig 3: per-layer sweeps — every (layer, param) pair swept in isolation,
/// the paper's key "tolerance varies WITHIN networks" result.
pub fn fig3(ctx: &mut ReproCtx) -> Result<String> {
    let params = [Param::WeightF, Param::DataI, Param::DataF];
    let ranges = [(1i8, 10i8), (1, 12), (0, 6)];
    let mut out = String::new();
    let manifests = ctx.manifests.clone();
    for m in &manifests {
        let mut per_net = Table::new(
            &format!("Fig 3 — {}: per-layer minimum bits (rel. acc ≥ 99%)", m.name),
            &["layer", "kind", "weight F", "data I", "data F"],
        );
        let mut csv = Table::new("", &["param", "layer", "bits", "accuracy", "relative"]);
        let mut mins: Vec<Vec<Option<i8>>> = Vec::new();
        for (pi, &param) in params.iter().enumerate() {
            let matrix = perlayer::sweep_all_layers(
                &mut ctx.coord,
                &m.name,
                m.n_layers(),
                &[param],
                ranges[pi],
                ctx.n_images,
            )?;
            for (layer, series) in matrix[0].iter().enumerate() {
                for p in series {
                    csv.row(vec![
                        format!("{param:?}"),
                        m.layers[layer].name.clone(),
                        p.bits.to_string(),
                        format!("{:.4}", p.accuracy),
                        format!("{:.4}", p.relative),
                    ]);
                }
            }
            mins.push(perlayer::min_bits_per_layer(&matrix[0], 0.01));
        }
        for l in 0..m.n_layers() {
            per_net.row(vec![
                m.layers[l].name.clone(),
                m.layers[l].kind.clone(),
                mins[0][l].map(|b| b.to_string()).unwrap_or("-".into()),
                mins[1][l].map(|b| b.to_string()).unwrap_or("-".into()),
                mins[2][l].map(|b| b.to_string()).unwrap_or("-".into()),
            ]);
        }
        out.push_str(&per_net.text());
        out.push('\n');
        ctx.write(&format!("fig3_{}.csv", m.name), &csv.csv())?;
        ctx.write(&format!("fig3_{}.md", m.name), &per_net.markdown())?;
    }
    println!("{out}");
    Ok(out)
}

// ---------------------------------------------------------------------------
// Fig 4
// ---------------------------------------------------------------------------

/// Fig 4: per-layer access counts, single-image vs batch use cases.
pub fn fig4(ctx: &mut ReproCtx) -> Result<String> {
    let mut out = String::new();
    for m in &ctx.manifests {
        let batch = Mode::Batch(m.batch);
        let mut t = Table::new(
            &format!("Fig 4 — {}: accesses per image (batch = {})", m.name, m.batch),
            &["layer", "kind", "weights (single)", "weights (batch)", "data"],
        );
        let single = traffic::accesses_per_image(m, Mode::Single);
        let batched = traffic::accesses_per_image(m, batch);
        for (s, b) in single.iter().zip(&batched) {
            t.row(vec![
                s.name.clone(),
                m.layers
                    .iter()
                    .find(|l| l.name == s.name)
                    .map(|l| l.kind.clone())
                    .unwrap_or_default(),
                util::human_count(s.weight_accesses),
                util::human_count(b.weight_accesses),
                util::human_count(s.data_accesses),
            ]);
        }
        t.row(vec![
            "TOTAL".into(),
            "".into(),
            util::human_count(single.iter().map(|l| l.weight_accesses).sum::<f64>()),
            util::human_count(batched.iter().map(|l| l.weight_accesses).sum::<f64>()),
            util::human_count(single.iter().map(|l| l.data_accesses).sum::<f64>()),
        ]);
        out.push_str(&t.text());
        out.push('\n');
        ctx.write(&format!("fig4_{}.csv", m.name), &t.csv())?;
        ctx.write(&format!("fig4_{}.md", m.name), &t.markdown())?;
    }
    println!("{out}");
    Ok(out)
}

// ---------------------------------------------------------------------------
// Fig 5 + Table 2
// ---------------------------------------------------------------------------

/// Result bundle of the design-space exploration for one network.
pub struct DseResult {
    pub net: String,
    pub descent: greedy::DescentResult,
    pub rows: Vec<Option<table2::ToleranceRow>>,
}

/// Run the §2.5 exploration for one network and derive its Table-2 rows.
pub fn explore_net(ctx: &mut ReproCtx, net: &str) -> Result<DseResult> {
    let m = ctx.manifest(net)?.clone();
    let fixed_f = data_f_policy(net);
    let opts = GreedyOptions {
        n_images: ctx.n_images,
        descent: DescentOptions {
            tune_data_f: fixed_f.is_none(),
            ..Default::default()
        },
        ..Default::default()
    };
    // Start tolerance: the paper's 0.1 % — floored at the eval-subset
    // noise level (one image flip = 1/n of absolute accuracy; below that
    // the criterion is unattainable and the start balloons to max width).
    let n_eff = if ctx.n_images == 0 { m.n_eval } else { ctx.n_images.min(m.n_eval) };
    let start_tol = (0.001f64).max(2.0 / n_eff as f64 / m.baseline_top1.max(0.1));
    let start = greedy::find_uniform_start(&mut ctx.coord, &m, start_tol, fixed_f, ctx.n_images)
        .context("finding uniform start")?;
    log::info!("{net}: descent start {}", start);
    let descent = greedy::descend(&mut ctx.coord, &m, start, &opts)?;
    let rows = table2::select(&descent.visited, &table2::TOLERANCES);
    Ok(DseResult { net: net.to_string(), descent, rows })
}

/// [`explore_net`] behind the on-disk trajectory cache
/// ([`crate::search::cache`]): a hit re-ranks the stored visited list
/// without a single evaluation; a miss (or any key mismatch) runs the
/// descent and refreshes the cache. The cached result's `explored` list
/// is empty — callers that need the full Fig-5 scatter should use
/// [`explore_net`] directly.
pub fn explore_net_cached(ctx: &mut ReproCtx, net: &str, cache_dir: &Path) -> Result<DseResult> {
    let m = ctx.manifest(net)?.clone();
    // The artifact fingerprint is a content hash of the weights file —
    // any rewrite (even one byte) recomputes. An unreadable file just
    // disables caching; the descent itself will surface the real error.
    let weights_hash = match cache::weights_fingerprint(&m.weights_path()) {
        Ok(h) => h,
        Err(e) => {
            log::warn!("{net}: cannot fingerprint weights ({e:#}); descent cache disabled");
            return explore_net(ctx, net);
        }
    };
    let key = cache::CacheKey {
        net: net.to_string(),
        backend: ctx.backend.label().to_string(),
        n_images: ctx.n_images,
        n_layers: m.n_layers(),
        weights_hash,
        baseline_top1: m.baseline_top1,
    };
    let path = cache::cache_path(cache_dir, net);
    if let Some(descent) = cache::load(&path, &key) {
        log::info!(
            "{net}: descent trajectory from cache ({}, {} visited configs)",
            path.display(),
            descent.visited.len()
        );
        let rows = table2::select(&descent.visited, &table2::TOLERANCES);
        return Ok(DseResult { net: net.to_string(), descent, rows });
    }
    let dse = explore_net(ctx, net)?;
    if let Err(e) = cache::save(&path, &key, &dse.descent) {
        log::warn!("{net}: could not persist descent cache: {e:#}");
    }
    Ok(dse)
}

/// Fig 5 scatter + Table 2 rows for every network, plus the paper's
/// headline aggregate (average data-footprint reduction at 1 %
/// tolerance). Since the memory subsystem landed, configs are ranked —
/// and the scatter's x-axis priced — by **modeled data footprint**
/// ([`FootprintModel`]); the traffic ratios still ride along in the
/// table for the paper's original TR columns.
pub fn fig5_table2(ctx: &mut ReproCtx) -> Result<String> {
    let mut out = String::new();
    let mut headline = Vec::new();
    let nets: Vec<String> = ctx.index.nets.clone();
    let mut t2 = Table::new(
        "Table 2 — minimum-footprint mixed configs per tolerance",
        &[
            "net", "tol", "data bits per layer", "weight F per layer", "top-1", "rel err",
            "FP(32b)", "TR(32b)", "TR(16b)",
        ],
    );
    for net in &nets {
        let m = ctx.manifest(net)?.clone();
        let dse = explore_net(ctx, net)?;

        // Fig-5 scatter: uniform grid ('u'), explored mixed ('.'), frontier ('#').
        let mut chart = Chart::new(
            &format!("Fig 5 — {net}: data footprint vs accuracy"),
            "footprint ratio vs fp32",
            "top-1 accuracy",
        );
        let uniform_pts = uniform_grid_points(ctx, &m)?;
        let mixed: Vec<(f64, f64)> =
            dse.descent.explored.iter().map(|v| (v.footprint_ratio, v.accuracy)).collect();
        let front_idx = pareto::frontier(&mixed);
        chart.series('u', uniform_pts.clone());
        chart.series('.', mixed.clone());
        chart.series('#', front_idx.iter().map(|&i| mixed[i]).collect());
        out.push_str(&chart.render());

        let mut csv =
            Table::new("", &["kind", "footprint_ratio", "traffic_ratio", "accuracy", "config"]);
        for (fp, acc) in &uniform_pts {
            csv.row(vec![
                "uniform".into(),
                format!("{fp:.4}"),
                String::new(),
                format!("{acc:.4}"),
                String::new(),
            ]);
        }
        for v in &dse.descent.explored {
            csv.row(vec![
                "mixed".into(),
                format!("{:.4}", v.footprint_ratio),
                format!("{:.4}", v.traffic_ratio),
                format!("{:.4}", v.accuracy),
                v.cfg.notation(),
            ]);
        }
        ctx.write(&format!("fig5_{net}.csv"), &csv.csv())?;

        for row in dse.rows.iter().flatten() {
            let data_bits = if data_f_policy(net).is_some() {
                table2::notation_total(&row.cfg)
            } else {
                table2::notation_if(&row.cfg)
            };
            t2.row(vec![
                net.clone(),
                format!("{:.0}%", row.tol * 100.0),
                data_bits,
                table2::notation_weights(&row.cfg),
                pct(row.accuracy),
                format!("{:.3}", row.rel_err),
                ratio(row.footprint_ratio),
                ratio(row.traffic_ratio),
                ratio(traffic::traffic_ratio_vs16(&m, Mode::Batch(m.batch), &row.cfg)),
            ]);
            if (row.tol - 0.01).abs() < 1e-9 {
                headline.push((net.clone(), row.footprint_ratio));
            }
        }
    }
    out.push_str(&t2.text());
    let avg_fp: f64 =
        headline.iter().map(|(_, fp)| fp).sum::<f64>() / headline.len().max(1) as f64;
    let min_fp = headline.iter().map(|(_, fp)| *fp).fold(f64::INFINITY, f64::min);
    let headline_txt = format!(
        "\nHEADLINE (paper: 74% avg / up to 92% data-footprint reduction @1% tol):\n  \
         measured: avg reduction {:.0}%  best net {:.0}%  ({} nets)\n",
        (1.0 - avg_fp) * 100.0,
        (1.0 - min_fp) * 100.0,
        headline.len()
    );
    out.push_str(&headline_txt);
    println!("{out}");
    ctx.write("table2.md", &t2.markdown())?;
    ctx.write("table2.csv", &t2.csv())?;
    ctx.write("headline.txt", &headline_txt)?;
    Ok(out)
}

/// The Fig-5 "uniform" comparison series: a small grid of uniform
/// configs priced by modeled footprint.
fn uniform_grid_points(ctx: &mut ReproCtx, m: &NetManifest) -> Result<Vec<(f64, f64)>> {
    let nl = m.n_layers();
    let df = data_f_policy(&m.name).unwrap_or(1);
    let fpm = FootprintModel::new(m);
    let mut jobs = Vec::new();
    let mut cfgs = Vec::new();
    for wf in [2i8, 4, 6, 8, 10] {
        for di in [4i8, 6, 8, 10, 12] {
            let cfg = PrecisionConfig::uniform(nl, QFormat::new(1, wf), QFormat::new(di, df));
            jobs.push(EvalJob { net: m.name.clone(), cfg: cfg.clone(), n_images: ctx.n_images });
            cfgs.push(cfg);
        }
    }
    let accs = ctx.coord.eval_batch(&jobs)?;
    Ok(cfgs.iter().zip(&accs).map(|(cfg, &acc)| (fpm.ratio(cfg), acc)).collect())
}

// ---------------------------------------------------------------------------
// Ablations (design choices DESIGN.md calls out)
// ---------------------------------------------------------------------------

/// Ablation 1: evaluation-subset sensitivity — how much do the sweep
/// accuracies drift with the number of images per evaluation? Justifies
/// the default `--n-images 256`.
pub fn ablation_eval_subset(ctx: &mut ReproCtx) -> Result<String> {
    let mut t = Table::new(
        "Ablation — accuracy vs evaluation-subset size",
        &["net", "config", "n=64", "n=128", "n=256", "n=512", "full", "max drift vs full"],
    );
    let sizes = [64usize, 128, 256, 512, 0];
    let manifests = ctx.manifests.clone();
    for m in &manifests {
        let nl = m.n_layers();
        let cfgs = [
            ("fp32", PrecisionConfig::fp32(nl)),
            ("1.8/10.2", PrecisionConfig::uniform(nl, QFormat::new(1, 8), QFormat::new(10, 2))),
        ];
        for (label, cfg) in cfgs {
            let jobs: Vec<EvalJob> = sizes
                .iter()
                .map(|&n| EvalJob { net: m.name.clone(), cfg: cfg.clone(), n_images: n })
                .collect();
            let accs = ctx.coord.eval_batch(&jobs)?;
            let full = *accs.last().unwrap();
            let drift = accs[..accs.len() - 1]
                .iter()
                .map(|a| (a - full).abs())
                .fold(0.0f64, f64::max);
            t.row(vec![
                m.name.clone(),
                label.into(),
                format!("{:.4}", accs[0]),
                format!("{:.4}", accs[1]),
                format!("{:.4}", accs[2]),
                format!("{:.4}", accs[3]),
                format!("{full:.4}"),
                format!("{drift:.4}"),
            ]);
        }
    }
    let text = t.text();
    println!("{text}");
    ctx.write("ablation_eval_subset.md", &t.markdown())?;
    ctx.write("ablation_eval_subset.csv", &t.csv())?;
    Ok(text)
}

/// Ablation 2: descent choice policy — the paper's best-accuracy rule vs
/// a traffic-saved-per-error-lost rule, compared at the Table-2 selection.
pub fn ablation_policy(ctx: &mut ReproCtx, net: &str) -> Result<String> {
    use crate::search::greedy::ChoicePolicy;
    let m = ctx.manifest(net)?.clone();
    let fixed_f = data_f_policy(net);
    let start =
        greedy::find_uniform_start(&mut ctx.coord, &m, 0.001, fixed_f, ctx.n_images)?;
    let mut t = Table::new(
        &format!("Ablation — descent policy on {net}"),
        &["policy", "steps", "TR @1%", "TR @5%", "TR @10%"],
    );
    for (label, policy) in [
        ("best-accuracy (paper)", ChoicePolicy::BestAccuracy),
        ("traffic-per-error", ChoicePolicy::TrafficPerError),
    ] {
        let opts = GreedyOptions {
            n_images: ctx.n_images,
            descent: DescentOptions { tune_data_f: fixed_f.is_none(), ..Default::default() },
            policy,
            ..Default::default()
        };
        let res = greedy::descend(&mut ctx.coord, &m, start.clone(), &opts)?;
        let rows = table2::select(&res.visited, &[0.01, 0.05, 0.10]);
        let tr = |i: usize| {
            rows[i]
                .as_ref()
                .map(|r| format!("{:.3}", r.traffic_ratio))
                .unwrap_or_else(|| "-".into())
        };
        t.row(vec![label.into(), res.visited.len().to_string(), tr(0), tr(1), tr(2)]);
    }
    let text = t.text();
    println!("{text}");
    ctx.write(&format!("ablation_policy_{net}.md"), &t.markdown())?;
    Ok(text)
}

// ---------------------------------------------------------------------------

/// Run everything in paper order.
pub fn all(ctx: &mut ReproCtx) -> Result<String> {
    let mut out = String::new();
    out.push_str(&table1(ctx)?);
    out.push_str(&fig2(ctx)?);
    out.push_str(&fig1(ctx)?);
    out.push_str(&fig3(ctx)?);
    out.push_str(&fig4(ctx)?);
    out.push_str(&fig5_table2(ctx)?);
    let stats = ctx.coord.stats();
    let foot = format!(
        "\ncoordinator: {} jobs submitted, {} cache hits, {} deduped, {} executed\n",
        stats.submitted, stats.cache_hits, stats.deduped, stats.executed
    );
    out.push_str(&foot);
    print!("{foot}");
    ctx.write("repro_all.txt", &out)?;
    Ok(out)
}
