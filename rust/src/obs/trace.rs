//! Chrome `trace_event` export: serialize drained [`SpanEvent`]s as a
//! JSON document `chrome://tracing` and Perfetto load directly.
//!
//! Each span becomes one complete event (`"ph": "X"`) with `ts`/`dur`
//! in microseconds; nesting is inferred by the viewer from time
//! containment per `tid`, which holds for our spans because a request
//! span and the layer spans it contains run on the same worker thread.

use std::path::Path;

use anyhow::Result;

use super::span::SpanEvent;
use crate::util::json::Json;

/// Build the `trace_event` document for `events`.
pub fn chrome_trace_json(events: &[SpanEvent]) -> Json {
    let rows: Vec<Json> = events
        .iter()
        .map(|e| {
            let mut fields = vec![
                ("name", Json::str(e.name)),
                ("cat", Json::str("qbound")),
                ("ph", Json::str("X")),
                ("ts", Json::num(e.ts_us as f64)),
                ("dur", Json::num(e.dur_us as f64)),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(e.tid as f64)),
            ];
            if !e.detail.is_empty() {
                fields.push(("args", Json::obj(vec![("detail", Json::str(e.detail.clone()))])));
            }
            Json::obj(fields)
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::arr(rows)),
        ("displayTimeUnit", Json::str("ms")),
        ("dropped_events", Json::num(super::span::dropped_events() as f64)),
    ])
}

/// Write `events` to `path` as Chrome trace JSON (parents created).
pub fn write_chrome_trace(path: &Path, events: &[SpanEvent]) -> Result<()> {
    crate::util::write_file(path, chrome_trace_json(events).pretty().as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_document_shape() {
        let events = vec![
            SpanEvent {
                name: "request",
                detail: "net=lenet".into(),
                ts_us: 10,
                dur_us: 100,
                tid: 3,
            },
            SpanEvent { name: "layer", detail: String::new(), ts_us: 20, dur_us: 30, tid: 3 },
        ];
        let j = chrome_trace_json(&events);
        let rows = j.at(&["traceEvents"]).as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].at(&["ph"]).as_str(), Some("X"));
        assert_eq!(rows[0].at(&["ts"]).as_u64(), Some(10));
        assert_eq!(rows[0].at(&["args", "detail"]).as_str(), Some("net=lenet"));
        // Detail-less events omit args entirely.
        assert!(rows[1].get("args").is_none());
        // The document round-trips through the parser (valid JSON).
        let text = j.pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.at(&["traceEvents"]).as_arr().unwrap().len(), 2);
    }
}
