//! Observability: a process-global metrics registry with Prometheus
//! exposition, a lightweight span/tracing layer with a Chrome
//! `trace_event` exporter, and the per-layer instrumentation helpers
//! both CPU executors call.
//!
//! Three design rules govern everything in here:
//!
//! 1. **Always compiled, cheap when idle.** Instrumentation is not
//!    feature-gated; instead every entry point checks one relaxed
//!    atomic load ([`active`]) and returns immediately when both
//!    metrics and tracing are off — no clock read, no allocation, no
//!    lock. The serve daemon enables metrics at startup; `qbound
//!    profile` and the `--trace` flags enable what they need; plain
//!    `eval`/test runs pay only the load.
//! 2. **Bounded memory.** Histograms are fixed ~8 KiB
//!    ([`hist::N_BUCKETS`] buckets), registry families are capped at
//!    [`registry::MAX_SERIES`] series, span rings hold
//!    [`span::RING_CAP`] events per thread and drop the *oldest* on
//!    overflow. Nothing grows with request count, so `check-mem` and
//!    `integration_memory` envelopes are unaffected (and those paths
//!    run with observability off — zero allocations in the measured
//!    region).
//! 3. **No numerics.** Instrumentation reads clocks and counts bytes;
//!    it never touches tensor data, so the bit-exactness contract
//!    (`integration_parity` / `integration_storage`) is structurally
//!    out of reach. `tests/integration_obs.rs` still asserts
//!    instrumented and uninstrumented logits are bit-identical.
//!
//! The span macro is re-exported here: `obs::span!("name", "k={v}")`
//! opens a guard recorded on drop (see [`span_guard`]).

pub mod hist;
pub mod registry;
pub mod span;
pub mod trace;

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::time::Instant;

pub use registry::{counter, gauge, histogram, registry_json, render_prometheus};
pub use span::{drain, dropped_events, span_guard, SpanEvent};
pub use trace::{chrome_trace_json, write_chrome_trace};

pub use crate::obs_span as span;

const METRICS: u8 = 1;
const TRACING: u8 = 2;

static ACTIVE: AtomicU8 = AtomicU8::new(0);

/// Bitset of enabled subsystems — the one relaxed load every disabled
/// instrumentation site costs.
#[inline]
pub fn active() -> u8 {
    ACTIVE.load(Ordering::Relaxed)
}

#[inline]
pub fn metrics_on() -> bool {
    active() & METRICS != 0
}

#[inline]
pub fn tracing_on() -> bool {
    active() & TRACING != 0
}

/// Enable/disable metrics collection (registry histograms + decode-byte
/// accounting). The serve daemon, `qbound profile` and benchkit turn
/// this on.
pub fn set_metrics(on: bool) {
    set_bit(METRICS, on);
}

/// Enable/disable span tracing (`--trace` / `--trace-dir` flags).
pub fn set_tracing(on: bool) {
    set_bit(TRACING, on);
}

fn set_bit(bit: u8, on: bool) {
    let mut cur = ACTIVE.load(Ordering::Relaxed);
    loop {
        let next = if on { cur | bit } else { cur & !bit };
        match ACTIVE.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

// ---- packed-decode byte accounting --------------------------------------

static DECODE_BYTES: AtomicU64 = AtomicU64::new(0);

/// Count `bits` bits decoded from packed storage. Called from the one
/// chokepoint every packed decode path funnels through
/// (`PackedBuf::unpack_range_into`); a no-op unless metrics or tracing
/// is enabled, so the multi-threaded decode hot path stays free of
/// shared-counter traffic in production-default runs.
#[inline]
pub fn count_decode_bits(bits: u64) {
    if active() != 0 {
        DECODE_BYTES.fetch_add(bits / 8, Ordering::Relaxed);
    }
}

/// Total bytes decoded from packed storage since process start (only
/// accumulated while metrics/tracing are enabled).
pub fn decode_bytes() -> u64 {
    DECODE_BYTES.load(Ordering::Relaxed)
}

// ---- per-layer step instrumentation -------------------------------------

/// Open timing for one lowered step; `None` when observability is
/// fully disabled (one relaxed load, nothing else).
pub struct StepTimer {
    start: Instant,
    decode0: u64,
}

#[inline]
pub fn step_start() -> Option<StepTimer> {
    if active() == 0 {
        return None;
    }
    Some(StepTimer { start: Instant::now(), decode0: decode_bytes() })
}

/// Close a step: record its time into the per-layer histogram and its
/// decode bytes into the per-layer counter (labels: net, layer group,
/// storage), and emit a span when tracing. `detail` builds the span's
/// field string and is only invoked when tracing is on — include op
/// kind, shapes/MNK, formats, kernel variant there.
pub fn step_end(
    t: Option<StepTimer>,
    net: &str,
    layer: usize,
    storage: &'static str,
    detail: impl FnOnce() -> String,
) {
    let Some(t) = t else { return };
    let us = t.start.elapsed().as_micros() as u64;
    let dbytes = decode_bytes().saturating_sub(t.decode0);
    let layer_s = layer.to_string();
    if metrics_on() {
        let labels = [("net", net), ("layer", layer_s.as_str()), ("storage", storage)];
        histogram(
            "qbound_layer_us",
            "per-step execution time by layer group, microseconds",
            &labels,
        )
        .record(us);
        if dbytes > 0 {
            counter(
                "qbound_layer_decode_bytes_total",
                "bytes decoded from packed storage, by layer group",
                &labels,
            )
            .add(dbytes);
        }
    }
    if tracing_on() {
        // The step already ran: emit a completed event whose window is
        // the measured one (end = now on the trace epoch clock).
        let end_us = span::now_us();
        let mut d = detail();
        if !d.is_empty() {
            d.push(' ');
        }
        d.push_str(&format!("layer=g{layer} decode_bytes={dbytes}"));
        span::emit("layer", d, end_us.saturating_sub(us), us);
    }
}
