//! Fixed-bucket log2 histograms: bounded memory, no per-sample
//! allocation, O(buckets) quantiles.
//!
//! The bucket layout is HDR-style — 16 linear sub-buckets per power of
//! two. Values below 16 get one bucket each (exact); a value `v >= 16`
//! with leading octave `o = 63 - v.leading_zeros()` lands in sub-bucket
//! `(v >> (o - 4)) & 0xF` of octave `o`, so every bucket spans
//! `2^(o-4)` consecutive integers. Quantiles return the bucket
//! midpoint, which bounds the relative error by half a bucket width
//! over the bucket floor: `2^(o-5) / 2^o = 1/32 ≈ 3.1%` (documented as
//! "≤ ~4%"; values below 16 are exact). The whole `u64` range fits in
//! [`N_BUCKETS`] = 976 counters — about 8 KiB per histogram, fixed at
//! construction, regardless of how many samples are recorded.
//!
//! Two flavors share the layout: [`Histogram`] for externally
//! synchronized use (e.g. behind the serve dispatch mutex) and
//! [`AtomicHistogram`] for lock-free multi-writer use in the global
//! metrics registry.

use std::sync::atomic::{AtomicU64, Ordering};

/// Total bucket count: 16 exact small-value buckets + 16 sub-buckets
/// for each of the 60 octaves `2^4 ..= 2^63`.
pub const N_BUCKETS: usize = 16 + 60 * 16;

/// Bucket index of a value (total order, monotone in `v`).
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < 16 {
        return v as usize;
    }
    let o = 63 - v.leading_zeros() as usize; // 4..=63
    let sub = ((v >> (o - 4)) & 0xF) as usize;
    16 + (o - 4) * 16 + sub
}

/// Inclusive `(lo, hi)` value range of bucket `i`.
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    assert!(i < N_BUCKETS);
    if i < 16 {
        return (i as u64, i as u64);
    }
    let o = (i - 16) / 16 + 4;
    let sub = ((i - 16) % 16) as u64;
    let width = 1u64 << (o - 4);
    let lo = (1u64 << o) + sub * width;
    (lo, lo + (width - 1))
}

/// The representative value reported for bucket `i` (its midpoint).
pub fn bucket_mid(i: usize) -> u64 {
    let (lo, hi) = bucket_bounds(i);
    lo + (hi - lo) / 2
}

/// Single-writer / externally synchronized log2 histogram.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    sum: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram { counts: vec![0; N_BUCKETS], count: 0, sum: 0 }
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of all recorded values (saturating).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Nearest-rank quantile (`q` in [0, 1]), reported as the owning
    /// bucket's midpoint — relative error ≤ ~4% (exact below 16).
    /// Returns 0 on an empty histogram.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((self.count - 1) as f64 * q.clamp(0.0, 1.0)).round() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                return bucket_mid(i);
            }
        }
        bucket_mid(N_BUCKETS - 1)
    }

    /// Render this histogram as a Prometheus `histogram` family:
    /// cumulative `_bucket{le=...}` lines for every non-empty bucket
    /// (plus `+Inf`), then `_sum` and `_count`. `labels` is the
    /// pre-rendered label set *without* braces (empty for none).
    pub fn render_prometheus(&self, out: &mut String, name: &str, labels: &str) {
        use std::fmt::Write;
        let join = |extra: &str| {
            if labels.is_empty() {
                extra.to_string()
            } else {
                format!("{labels},{extra}")
            }
        };
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            cum += c;
            let (_, hi) = bucket_bounds(i);
            let _ = writeln!(out, "{name}_bucket{{{}}} {cum}", join(&format!("le=\"{hi}\"")));
        }
        let _ = writeln!(out, "{name}_bucket{{{}}} {}", join("le=\"+Inf\""), self.count);
        let brace = if labels.is_empty() { String::new() } else { format!("{{{labels}}}") };
        let _ = writeln!(out, "{name}_sum{brace} {}", self.sum);
        let _ = writeln!(out, "{name}_count{brace} {}", self.count);
    }
}

/// Lock-free multi-writer flavor for the global registry. Counters are
/// relaxed atomics: `snapshot` totals are eventually consistent but
/// each bucket count is exact.
pub struct AtomicHistogram {
    counts: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl AtomicHistogram {
    pub fn new() -> AtomicHistogram {
        AtomicHistogram {
            counts: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.counts[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Copy the current counts into a plain [`Histogram`].
    pub fn snapshot(&self) -> Histogram {
        let counts: Vec<u64> = self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let count = counts.iter().sum();
        Histogram { counts, count, sum: self.sum.load(Ordering::Relaxed) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotone_and_bounds_consistent() {
        let mut prev = 0usize;
        for v in [0u64, 1, 7, 15, 16, 17, 31, 32, 100, 1000, 65_535, 1 << 20, u64::MAX / 3] {
            let i = bucket_index(v);
            assert!(i >= prev, "index not monotone at {v}");
            prev = i;
            let (lo, hi) = bucket_bounds(i);
            assert!(lo <= v && v <= hi, "v={v} outside bucket [{lo},{hi}]");
            let mid = bucket_mid(i);
            assert!(lo <= mid && mid <= hi);
        }
        assert_eq!(bucket_index(u64::MAX), N_BUCKETS - 1);
        // Small values are their own (exact) buckets.
        for v in 0..16u64 {
            assert_eq!(bucket_bounds(bucket_index(v)), (v, v));
        }
    }

    /// Exact nearest-rank quantile over a sorted sample set — the
    /// oracle the histogram approximation is held against.
    fn exact_quantile(sorted: &[u64], q: f64) -> u64 {
        let rank = ((sorted.len() - 1) as f64 * q).round() as usize;
        sorted[rank]
    }

    fn assert_quantiles_close(samples: &[u64], what: &str) {
        let mut h = Histogram::new();
        let mut sorted = samples.to_vec();
        for &v in samples {
            h.record(v);
        }
        sorted.sort_unstable();
        for q in [0.0, 0.5, 0.9, 0.95, 0.99, 1.0] {
            let exact = exact_quantile(&sorted, q);
            let approx = h.quantile(q);
            // Documented bound: ≤ ~4% relative error (half a bucket
            // width over the bucket floor = 1/32), exact below 16.
            // Allow ±1 absolutely so tiny exact values don't divide
            // by ~0.
            let tol = (exact as f64 * 0.04).max(1.0);
            assert!(
                (approx as f64 - exact as f64).abs() <= tol,
                "{what}: q={q} approx {approx} vs exact {exact}"
            );
        }
        assert_eq!(h.count(), samples.len() as u64);
        assert_eq!(h.sum(), samples.iter().sum::<u64>());
    }

    #[test]
    fn quantile_error_bound_on_adversarial_distributions() {
        // Uniform ramp.
        let ramp: Vec<u64> = (1..=10_000).collect();
        assert_quantiles_close(&ramp, "uniform ramp");
        // Exponentially spread (every octave hit).
        let expo: Vec<u64> =
            (0..60).flat_map(|o| [1u64 << o, (1u64 << o) + (1 << o) / 3]).collect();
        assert_quantiles_close(&expo, "exponential");
        // Constant — all mass in one bucket.
        assert_quantiles_close(&vec![777u64; 1000], "constant");
        // Two-point bimodal with extreme separation.
        let mut bimodal = vec![3u64; 500];
        bimodal.extend(vec![1u64 << 40; 500]);
        assert_quantiles_close(&bimodal, "bimodal");
        // Heavy tail: 99% small, 1% huge (p99 straddles the jump).
        let mut tail: Vec<u64> = (0..990).map(|i| 100 + i % 7).collect();
        tail.extend((0..10).map(|_| 5_000_000u64));
        assert_quantiles_close(&tail, "heavy tail");
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.99), 0);
        assert_eq!((h.count(), h.sum()), (0, 0));
    }

    #[test]
    fn atomic_snapshot_matches_plain() {
        let a = AtomicHistogram::new();
        let mut p = Histogram::new();
        for v in [0u64, 5, 16, 99, 12_345, 1 << 30] {
            a.record(v);
            p.record(v);
        }
        let s = a.snapshot();
        assert_eq!(s.count(), p.count());
        assert_eq!(s.sum(), p.sum());
        for q in [0.25, 0.5, 0.75, 1.0] {
            assert_eq!(s.quantile(q), p.quantile(q));
        }
    }

    #[test]
    fn prometheus_render_is_cumulative_and_complete() {
        let mut h = Histogram::new();
        for v in [1u64, 1, 2, 100] {
            h.record(v);
        }
        let mut out = String::new();
        h.render_prometheus(&mut out, "t_us", "net=\"lenet\"");
        assert!(out.contains("t_us_bucket{net=\"lenet\",le=\"1\"} 2"), "{out}");
        assert!(out.contains("t_us_bucket{net=\"lenet\",le=\"2\"} 3"), "{out}");
        assert!(out.contains("t_us_bucket{net=\"lenet\",le=\"+Inf\"} 4"), "{out}");
        assert!(out.contains("t_us_sum{net=\"lenet\"} 104"), "{out}");
        assert!(out.contains("t_us_count{net=\"lenet\"} 4"), "{out}");
        // Unlabeled render uses bare names for _sum/_count.
        let mut bare = String::new();
        h.render_prometheus(&mut bare, "t_us", "");
        assert!(bare.contains("t_us_sum 104"), "{bare}");
        assert!(bare.contains("t_us_bucket{le=\"+Inf\"} 4"), "{bare}");
    }
}
