//! Lightweight spans: timed regions pushed into per-thread ring
//! buffers, drained by an exporter (Chrome `trace_event` JSON — see
//! [`super::trace`]).
//!
//! The design goal is *cheap when idle*: a disabled span is one relaxed
//! atomic load ([`super::tracing_on`]) and nothing else — no clock
//! read, no allocation, no lock. When tracing is enabled, each span
//! costs two `Instant` reads, one detail `String` (built lazily by the
//! caller's closure) and a push into the current thread's ring buffer
//! (an uncontended mutex — only the draining exporter ever takes it
//! from another thread). Rings are bounded at [`RING_CAP`] events;
//! overflow drops the *oldest* event and counts it, so a long-running
//! daemon's memory stays flat and the most recent window of activity is
//! what gets exported.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Events retained per thread before the oldest are dropped
/// (~100 bytes/event worst case → ≲ 1 MiB per tracing thread).
pub const RING_CAP: usize = 8192;

/// One completed span.
#[derive(Clone, Debug)]
pub struct SpanEvent {
    /// Static category name (`"request"`, `"layer"`, `"gemm"`, ...).
    pub name: &'static str,
    /// Free-form fields, built only when tracing is on.
    pub detail: String,
    /// Start, microseconds since the process trace epoch.
    pub ts_us: u64,
    /// Duration in microseconds.
    pub dur_us: u64,
    /// Small dense thread id (assigned per thread on first span).
    pub tid: u64,
}

struct Ring {
    events: VecDeque<SpanEvent>,
}

static RINGS: Mutex<Vec<Arc<Mutex<Ring>>>> = Mutex::new(Vec::new());
static NEXT_TID: AtomicU64 = AtomicU64::new(0);
static DROPPED: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static TL: (u64, Arc<Mutex<Ring>>) = {
        let ring = Arc::new(Mutex::new(Ring { events: VecDeque::new() }));
        RINGS.lock().unwrap().push(Arc::clone(&ring));
        (NEXT_TID.fetch_add(1, Ordering::Relaxed), ring)
    };
}

/// Microseconds since the process-wide trace epoch (first use).
pub fn now_us() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    let epoch = *EPOCH.get_or_init(Instant::now);
    Instant::now().duration_since(epoch).as_micros() as u64
}

/// An in-flight span; records itself into the thread's ring on drop.
pub struct SpanGuard {
    name: &'static str,
    detail: String,
    start_us: u64,
}

impl SpanGuard {
    /// Append a field discovered mid-span (e.g. bytes decoded).
    pub fn add_field(&mut self, field: &str) {
        if !self.detail.is_empty() {
            self.detail.push(' ');
        }
        self.detail.push_str(field);
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let end = now_us();
        emit(
            self.name,
            std::mem::take(&mut self.detail),
            self.start_us,
            end.saturating_sub(self.start_us),
        );
    }
}

/// Open a span named `name`; `detail` is only invoked when tracing is
/// enabled. Returns `None` (cost: one relaxed load) when tracing is
/// off — bind the result (`let _sp = ...`) so the guard lives to the
/// end of the region.
#[inline]
pub fn span_guard(name: &'static str, detail: impl FnOnce() -> String) -> Option<SpanGuard> {
    if !super::tracing_on() {
        return None;
    }
    Some(SpanGuard { name, detail: detail(), start_us: now_us() })
}

/// Push an already-completed event into the current thread's ring —
/// for regions whose timing was measured out-of-band (the per-layer
/// step instrumentation). The caller has checked tracing is on.
pub fn emit(name: &'static str, detail: String, ts_us: u64, dur_us: u64) {
    TL.with(|(tid, ring)| {
        let mut r = ring.lock().unwrap();
        if r.events.len() >= RING_CAP {
            r.events.pop_front();
            DROPPED.fetch_add(1, Ordering::Relaxed);
        }
        r.events.push_back(SpanEvent { name, detail, ts_us, dur_us, tid: *tid });
    });
}

/// Take every buffered event from every thread's ring, in timestamp
/// order. Rings stay registered (threads keep tracing into them).
pub fn drain() -> Vec<SpanEvent> {
    let rings: Vec<Arc<Mutex<Ring>>> = RINGS.lock().unwrap().clone();
    let mut all = Vec::new();
    for ring in rings {
        let mut r = ring.lock().unwrap();
        all.extend(r.events.drain(..));
    }
    all.sort_by_key(|e| e.ts_us);
    all
}

/// Total events dropped to ring overflow since process start.
pub fn dropped_events() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// Open a span: `obs::span!("name")` or
/// `obs::span!("name", "fmt {}", args)`. Expands to
/// [`span_guard`](crate::obs::span_guard) — bind the result so the
/// guard spans the region: `let _sp = obs::span!(...)`.
#[macro_export]
macro_rules! obs_span {
    ($name:expr) => {
        $crate::obs::span_guard($name, String::new)
    };
    ($name:expr, $($arg:tt)+) => {
        $crate::obs::span_guard($name, || format!($($arg)+))
    };
}
