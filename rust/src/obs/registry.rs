//! Process-global metrics registry: named counters, gauges and log2
//! histograms, rendered in the Prometheus text exposition format.
//!
//! Instruments are keyed by `(name, sorted label set)` and created on
//! first touch; handles are cheap `Arc` clones, so hot paths can
//! resolve once and record lock-free afterwards. Memory is bounded by
//! construction: each family holds at most [`MAX_SERIES`] series (a
//! handle past the cap still works — it just isn't retained for
//! exposition), every histogram is a fixed ~8 KiB, and counters/gauges
//! are one atomic word each. No per-sample allocation anywhere.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::hist::AtomicHistogram;
use crate::util::json::Json;

/// Cap on distinct label sets per metric family — the bound that keeps
/// a label-cardinality bug from growing the registry without limit.
pub const MAX_SERIES: usize = 4096;

/// A monotonically increasing counter.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    #[inline]
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A settable signed gauge.
#[derive(Clone)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    #[inline]
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A handle to a registered [`AtomicHistogram`].
#[derive(Clone)]
pub struct HistHandle(pub Arc<AtomicHistogram>);

impl HistHandle {
    #[inline]
    pub fn record(&self, v: u64) {
        self.0.record(v);
    }
}

/// `(metric name, sorted labels)` — the series identity.
type Key = (String, Vec<(String, String)>);

struct Family<T> {
    series: BTreeMap<Key, Arc<T>>,
}

impl<T: Default> Family<T> {
    fn new() -> Family<T> {
        Family { series: BTreeMap::new() }
    }

    fn get_or_create(&mut self, name: &str, labels: &[(&str, &str)]) -> Arc<T> {
        let mut ls: Vec<(String, String)> =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        ls.sort();
        let key = (name.to_string(), ls);
        if let Some(v) = self.series.get(&key) {
            return Arc::clone(v);
        }
        let v = Arc::new(T::default());
        if self.series.len() < MAX_SERIES {
            self.series.insert(key, Arc::clone(&v));
        }
        v
    }
}

struct Registry {
    counters: Mutex<Family<AtomicU64>>,
    gauges: Mutex<Family<AtomicI64>>,
    hists: Mutex<Family<AtomicHistogram>>,
    /// `metric name -> HELP text`, first registration wins.
    help: Mutex<BTreeMap<String, &'static str>>,
}

fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(|| Registry {
        counters: Mutex::new(Family::new()),
        gauges: Mutex::new(Family::new()),
        hists: Mutex::new(Family::new()),
        help: Mutex::new(BTreeMap::new()),
    })
}

fn note_help(name: &str, help: &'static str) {
    let mut h = registry().help.lock().unwrap();
    h.entry(name.to_string()).or_insert(help);
}

/// Get-or-create a counter series.
pub fn counter(name: &str, help: &'static str, labels: &[(&str, &str)]) -> Counter {
    note_help(name, help);
    Counter(registry().counters.lock().unwrap().get_or_create(name, labels))
}

/// Get-or-create a gauge series.
pub fn gauge(name: &str, help: &'static str, labels: &[(&str, &str)]) -> Gauge {
    note_help(name, help);
    Gauge(registry().gauges.lock().unwrap().get_or_create(name, labels))
}

/// Get-or-create a histogram series.
pub fn histogram(name: &str, help: &'static str, labels: &[(&str, &str)]) -> HistHandle {
    note_help(name, help);
    HistHandle(registry().hists.lock().unwrap().get_or_create(name, labels))
}

/// Escape a label value per the Prometheus text format: backslash,
/// double quote and newline.
pub fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn render_labels(labels: &[(String, String)]) -> String {
    labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect::<Vec<_>>()
        .join(",")
}

/// Render every registered series in the Prometheus text exposition
/// format (`# HELP` / `# TYPE` header per family, series sorted by
/// name then labels).
pub fn render_prometheus() -> String {
    use std::fmt::Write;
    let reg = registry();
    let help = reg.help.lock().unwrap().clone();
    let mut out = String::new();
    let mut header = |out: &mut String, name: &str, kind: &str| {
        let h = help.get(name).copied().unwrap_or("(undocumented)");
        let _ = writeln!(out, "# HELP {name} {h}");
        let _ = writeln!(out, "# TYPE {name} {kind}");
    };
    {
        let counters = reg.counters.lock().unwrap();
        let mut last = String::new();
        for ((name, labels), v) in &counters.series {
            if *name != last {
                header(&mut out, name, "counter");
                last = name.clone();
            }
            let ls = render_labels(labels);
            let brace = if ls.is_empty() { String::new() } else { format!("{{{ls}}}") };
            let _ = writeln!(out, "{name}{brace} {}", v.load(Ordering::Relaxed));
        }
    }
    {
        let gauges = reg.gauges.lock().unwrap();
        let mut last = String::new();
        for ((name, labels), v) in &gauges.series {
            if *name != last {
                header(&mut out, name, "gauge");
                last = name.clone();
            }
            let ls = render_labels(labels);
            let brace = if ls.is_empty() { String::new() } else { format!("{{{ls}}}") };
            let _ = writeln!(out, "{name}{brace} {}", v.load(Ordering::Relaxed));
        }
    }
    {
        let hists = reg.hists.lock().unwrap();
        let mut last = String::new();
        for ((name, labels), h) in &hists.series {
            if *name != last {
                header(&mut out, name, "histogram");
                last = name.clone();
            }
            h.snapshot().render_prometheus(&mut out, name, &render_labels(labels));
        }
    }
    out
}

/// Compact JSON summary of the registry (counters + gauges verbatim,
/// histograms as count/sum/p50/p95/p99) — merged into `/v1/stats` and
/// the smoke artifact.
pub fn registry_json() -> Json {
    let reg = registry();
    let series_name = |name: &str, labels: &[(String, String)]| {
        if labels.is_empty() {
            name.to_string()
        } else {
            format!("{name}{{{}}}", render_labels(labels))
        }
    };
    let mut counters = Vec::new();
    for ((name, labels), v) in &reg.counters.lock().unwrap().series {
        counters.push((series_name(name, labels), Json::num(v.load(Ordering::Relaxed) as f64)));
    }
    let mut gauges = Vec::new();
    for ((name, labels), v) in &reg.gauges.lock().unwrap().series {
        gauges.push((series_name(name, labels), Json::num(v.load(Ordering::Relaxed) as f64)));
    }
    let mut hists = Vec::new();
    for ((name, labels), h) in &reg.hists.lock().unwrap().series {
        let s = h.snapshot();
        hists.push((
            series_name(name, labels),
            Json::obj(vec![
                ("count", Json::num(s.count() as f64)),
                ("sum", Json::num(s.sum() as f64)),
                ("p50", Json::num(s.quantile(0.50) as f64)),
                ("p95", Json::num(s.quantile(0.95) as f64)),
                ("p99", Json::num(s.quantile(0.99) as f64)),
            ]),
        ));
    }
    let obj = |pairs: Vec<(String, Json)>| {
        Json::Obj(pairs.into_iter().collect::<BTreeMap<String, Json>>())
    };
    Json::obj(vec![
        ("counters", obj(counters)),
        ("gauges", obj(gauges)),
        ("histograms", obj(hists)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_escaping_covers_the_three_specials() {
        assert_eq!(escape_label("plain"), "plain");
        assert_eq!(escape_label("a\"b"), "a\\\"b");
        assert_eq!(escape_label("a\\b"), "a\\\\b");
        assert_eq!(escape_label("a\nb"), "a\\nb");
    }

    #[test]
    fn instruments_are_shared_by_key_and_label_order_is_canonical() {
        let a = counter("obs_test_shared_total", "test", &[("x", "1"), ("y", "2")]);
        let b = counter("obs_test_shared_total", "test", &[("y", "2"), ("x", "1")]);
        a.add(3);
        b.add(4);
        assert_eq!(a.get(), 7, "label order must not split the series");
        let g = gauge("obs_test_gauge", "test", &[]);
        g.set(-5);
        assert_eq!(gauge("obs_test_gauge", "test", &[]).get(), -5);
    }

    #[test]
    fn exposition_parses_name_type_help_and_series_lines() {
        counter("obs_test_expo_total", "an expo test counter", &[("net", "le\"net")]).add(2);
        histogram("obs_test_expo_us", "an expo test histogram", &[]).record(42);
        let text = render_prometheus();
        let mut saw_help = false;
        let mut saw_type = false;
        let mut saw_series = false;
        for line in text.lines() {
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("# HELP ") {
                assert!(rest.contains(' '), "HELP without text: {line}");
                saw_help |= rest.starts_with("obs_test_expo_total");
                continue;
            }
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let kind = rest.split_whitespace().nth(1).unwrap();
                assert!(matches!(kind, "counter" | "gauge" | "histogram"), "{line}");
                saw_type |= rest.starts_with("obs_test_expo_total");
                continue;
            }
            // Every sample line is `name[{labels}] value`.
            let (series, value) = line.rsplit_once(' ').expect(line);
            assert!(value.parse::<f64>().is_ok(), "unparsable value in {line}");
            let name = series.split('{').next().unwrap();
            assert!(
                name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
                "bad metric name in {line}"
            );
            saw_series |= series.starts_with("obs_test_expo_total");
        }
        assert!(saw_help && saw_type && saw_series, "{text}");
        // The escaped quote round-trips in the exposition.
        assert!(text.contains("net=\"le\\\"net\""), "{text}");
        // The histogram family renders its _count.
        assert!(text.contains("obs_test_expo_us_count"), "{text}");
    }

    #[test]
    fn registry_json_summarizes_families() {
        counter("obs_test_json_total", "test", &[]).add(9);
        histogram("obs_test_json_us", "test", &[]).record(100);
        let j = registry_json();
        assert_eq!(j.at(&["counters", "obs_test_json_total"]).as_u64(), Some(9));
        assert_eq!(j.at(&["histograms", "obs_test_json_us", "count"]).as_u64(), Some(1));
    }
}
