//! qbound CLI — the L3 entrypoint.
//!
//! Subcommands:
//!   info                     artifact inventory + per-net summary
//!   eval                     accuracy of one precision config
//!   sweep-uniform            Fig-2-style uniform sweep
//!   sweep-layer              Fig-3-style per-layer sweep
//!   search                   §2.5 greedy descent + Table-2 rows
//!   traffic                  Fig-4 traffic model
//!   footprint                fp32 vs best-config data footprint per net
//!   frontier                 export FRONTIER_<net>.json rung ladders for autoscaling
//!   check-mem                CI gate: measured peak RSS vs modeled envelope
//!   repro <exp>              regenerate a paper table/figure (or `all`)
//!   serve                    footprint-budgeted HTTP inference daemon
//!   store                    packed-weight store: ls / gc / warm
//!   profile                  per-layer time/decode/footprint breakdown
//!   gen-artifacts            synthesize a pure-Rust artifact set

use anyhow::Result;
use qbound::cli::CmdSpec;
use qbound::util;

mod commands;

fn main() {
    util::init_logging();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "qbound — per-layer reduced-precision CNN framework (Judd et al. 2015 reproduction)

USAGE: qbound <COMMAND> [OPTIONS]

COMMANDS:
  info           artifact inventory: nets, baselines, layer/weight counts
  eval           evaluate one precision configuration
  sweep-uniform  uniform-representation sweep (paper Fig 2)
  sweep-layer    one-layer-at-a-time sweep (paper Fig 3)
  search         greedy precision search (paper §2.5) + Table-2 rows
  traffic        memory-traffic model (paper Fig 4)
  footprint      fp32 vs best-config data footprint (text + JSON)
  frontier       export FRONTIER_<net>.json rung ladders for serve --autoscale
  check-mem      fail if measured MEM_*.json peaks escape the modeled envelope
  repro          regenerate paper experiments: table1 fig1 fig2 fig3 fig4 fig5 table2 all
  serve          footprint-budgeted HTTP inference daemon (--smoke self-test)
  store          content-addressed packed-weight store: ls / gc / warm
  profile        per-layer time/decode/footprint breakdown (+ JSON/trace)
  gen-artifacts  synthesize a pure-Rust artifact set (no python needed)

Run `qbound <COMMAND> --help` for options.
"
    .to_string()
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print!("{}", usage());
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "info" => commands::info::run(rest),
        "eval" => commands::eval::run(rest),
        "sweep-uniform" => commands::sweeps::run_uniform(rest),
        "sweep-layer" => commands::sweeps::run_layer(rest),
        "search" => commands::search_cmd::run(rest),
        "traffic" => commands::traffic_cmd::run(rest),
        "footprint" => commands::footprint_cmd::run(rest),
        "frontier" => commands::frontier_cmd::run(rest),
        "check-mem" => commands::check_mem::run(rest),
        "repro" => commands::repro_cmd::run(rest),
        "serve" => commands::serve::run(rest),
        "store" => commands::store_cmd::run(rest),
        "profile" => commands::profile::run(rest),
        "gen-artifacts" => commands::gen_artifacts::run(rest),
        "--help" | "-h" | "help" => {
            print!("{}", usage());
            Ok(())
        }
        other => anyhow::bail!("unknown command {other:?}\n\n{}", usage()),
    }
}

#[allow(dead_code)]
fn unused_cmdspec_keepalive() -> CmdSpec {
    // referenced so the import stays obviously intentional
    CmdSpec::new("", "")
}
