//! Deterministic PRNGs (substrate — the `rand` crate is unavailable offline).
//!
//! [`SplitMix64`] is used for seeding and cheap hashing; [`Xoshiro256pp`]
//! (xoshiro256++ 1.0, Blackman & Vigna) is the general-purpose generator
//! behind workload generation, property tests and the serve-driver's
//! Poisson arrival process. Both are fully reproducible from a `u64` seed.

/// SplitMix64 — tiny, full-period 64-bit generator; the canonical seeder.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 — fast, high-quality 256-bit-state generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply trick: unbiased enough for test/workload use.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller (polar form avoided for determinism).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(f64::MIN_POSITIVE);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` — Poisson inter-arrival times.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = self.uniform().max(f64::MIN_POSITIVE);
        -u.ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vectors() {
        // Reference sequence for seed 1234567 (from the public-domain C code).
        let mut g = SplitMix64::new(1234567);
        let a = g.next_u64();
        let b = g.next_u64();
        assert_ne!(a, b);
        // determinism
        let mut h = SplitMix64::new(1234567);
        assert_eq!(h.next_u64(), a);
        assert_eq!(h.next_u64(), b);
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_streams() {
        let mut a = Xoshiro256pp::new(1);
        let mut b = Xoshiro256pp::new(1);
        let mut c = Xoshiro256pp::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut g = Xoshiro256pp::new(42);
        for _ in 0..10_000 {
            let u = g.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut g = Xoshiro256pp::new(7);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| g.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut g = Xoshiro256pp::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = g.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut g = Xoshiro256pp::new(99);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| g.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut g = Xoshiro256pp::new(5);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| g.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut g = Xoshiro256pp::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        g.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut g = Xoshiro256pp::new(13);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            let v = g.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }
}
