//! The paper's memory-traffic model (§2.4, Fig 4; the TR column of Table 2).
//!
//! Counting rules (exactly the paper's):
//!   * every datum a layer touches moves to/from memory ONCE per layer
//!     execution (infinite on-chip reuse buffering assumed);
//!   * per layer l: reads its input (`in_elems`), writes its output
//!     (`out_elems`), reads its weights (`weight_elems`);
//!   * single-image mode: weights are re-read for every image;
//!   * batch mode (batch B): weights are read once per *batch*, i.e.
//!     amortized 1/B per image.
//!
//! Bit-weighted traffic multiplies each access class by its representation
//! length: layer l's input data uses layer l-1's data format (layer 0's
//! input uses `dq[0]`), its output uses `dq[l]`, weights use `wq[l]`.

use crate::nets::NetManifest;
use crate::quant::QFormat;
use crate::search::space::PrecisionConfig;

/// Classification use case (paper Fig 4 shows both).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// One image at a time — weights dominate for FC-heavy nets.
    Single,
    /// Batched classification; weights amortized over the batch.
    Batch(usize),
}

impl Mode {
    pub fn batch(self) -> usize {
        match self {
            Mode::Single => 1,
            Mode::Batch(b) => b,
        }
    }
}

/// Per-layer access counts, per image (f64 because of 1/B amortization).
#[derive(Clone, Debug, PartialEq)]
pub struct LayerTraffic {
    pub name: String,
    pub weight_accesses: f64,
    pub data_accesses: f64, // input reads + output writes
}

/// Access counts for a whole network under `mode`, per image.
pub fn accesses_per_image(m: &NetManifest, mode: Mode) -> Vec<LayerTraffic> {
    let b = mode.batch() as f64;
    m.layers
        .iter()
        .map(|l| LayerTraffic {
            name: l.name.clone(),
            weight_accesses: l.weight_elems as f64 / b,
            data_accesses: (l.in_elems + l.out_elems) as f64,
        })
        .collect()
}

/// Total accesses per image (weights + data), the Fig-4 y-axis.
pub fn total_accesses(m: &NetManifest, mode: Mode) -> f64 {
    accesses_per_image(m, mode).iter().map(|t| t.weight_accesses + t.data_accesses).sum()
}

/// Bit-weighted traffic per image under `cfg` (bits moved, not accesses).
pub fn traffic_bits(m: &NetManifest, mode: Mode, cfg: &PrecisionConfig) -> f64 {
    assert_eq!(cfg.n_layers(), m.n_layers(), "config/manifest layer mismatch");
    let b = mode.batch() as f64;
    let mut total = 0.0;
    for (l, layer) in m.layers.iter().enumerate() {
        let in_fmt: QFormat = if l == 0 { cfg.dq[0] } else { cfg.dq[l - 1] };
        let out_fmt = cfg.dq[l];
        let w_fmt = cfg.wq[l];
        total += layer.weight_elems as f64 * w_fmt.bits() as f64 / b;
        total += layer.in_elems as f64 * in_fmt.bits() as f64;
        total += layer.out_elems as f64 * out_fmt.bits() as f64;
    }
    total
}

/// Traffic ratio vs the all-fp32 baseline — the paper's TR column.
pub fn traffic_ratio(m: &NetManifest, mode: Mode, cfg: &PrecisionConfig) -> f64 {
    let base = traffic_bits(m, mode, &PrecisionConfig::fp32(m.n_layers()));
    traffic_bits(m, mode, cfg) / base
}

/// Traffic ratio vs a uniform 16-bit fixed-point baseline (paper §2.5
/// "Compared to a 16-bit fixed-point baseline...").
pub fn traffic_ratio_vs16(m: &NetManifest, mode: Mode, cfg: &PrecisionConfig) -> f64 {
    let base16 = PrecisionConfig::uniform(m.n_layers(), QFormat::new(1, 15), QFormat::new(14, 2));
    traffic_bits(m, mode, cfg) / traffic_bits(m, mode, &base16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::{LayerMeta, NetManifest, ParamMeta};
    use std::path::PathBuf;

    pub(crate) fn toy_manifest() -> NetManifest {
        NetManifest {
            name: "toy".into(),
            dataset: "synmnist".into(),
            num_classes: 10,
            input_shape: vec![4, 4, 1],
            batch: 8,
            n_eval: 64,
            baseline_top1: 0.9,
            layers: vec![
                LayerMeta {
                    name: "L1".into(),
                    kind: "conv".into(),
                    in_elems: 16,
                    out_elems: 8,
                    weight_elems: 20,
                    macs: 100,
                    stages: vec!["conv".into()],
                },
                LayerMeta {
                    name: "L2".into(),
                    kind: "fc".into(),
                    in_elems: 8,
                    out_elems: 10,
                    weight_elems: 90,
                    macs: 80,
                    stages: vec!["fc".into()],
                },
            ],
            params: vec![
                ParamMeta { name: "w1".into(), shape: vec![20] },
                ParamMeta { name: "w2".into(), shape: vec![90] },
            ],
            hlo_file: "x".into(),
            weights_file: "x".into(),
            dataset_file: "x".into(),
            stage_variant: None,
            dir: PathBuf::from("/tmp"),
        }
    }

    #[test]
    fn single_image_counts() {
        let m = toy_manifest();
        let t = accesses_per_image(&m, Mode::Single);
        assert_eq!(t[0].weight_accesses, 20.0);
        assert_eq!(t[0].data_accesses, 24.0);
        assert_eq!(t[1].weight_accesses, 90.0);
        assert_eq!(total_accesses(&m, Mode::Single), 20.0 + 24.0 + 90.0 + 18.0);
    }

    #[test]
    fn batch_amortizes_weights_only() {
        let m = toy_manifest();
        let t = accesses_per_image(&m, Mode::Batch(10));
        assert_eq!(t[0].weight_accesses, 2.0);
        assert_eq!(t[0].data_accesses, 24.0); // data not amortized
    }

    #[test]
    fn fp32_ratio_is_one() {
        let m = toy_manifest();
        let cfg = PrecisionConfig::fp32(2);
        assert!((traffic_ratio(&m, Mode::Batch(8), &cfg) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn bit_weighting_matches_hand_count() {
        let m = toy_manifest();
        // w: 1.7 (8 bits), d: 6.2 (8 bits) everywhere => ratio = 8/32
        let cfg = PrecisionConfig::uniform(2, QFormat::new(1, 7), QFormat::new(6, 2));
        let r = traffic_ratio(&m, Mode::Single, &cfg);
        assert!((r - 0.25).abs() < 1e-12, "r {r}");
    }

    #[test]
    fn mixed_config_uses_producer_format_for_input() {
        let m = toy_manifest();
        // L1 data 16-bit, L2 data 8-bit. L2's input (8 elems) must be
        // priced at L1's 16 bits.
        let mut cfg = PrecisionConfig::fp32(2);
        cfg.dq[0] = QFormat::new(14, 2); // 16 bits
        cfg.dq[1] = QFormat::new(6, 2); // 8 bits
        let bits = traffic_bits(&m, Mode::Single, &cfg);
        let expect = 20.0 * 32.0          // L1 weights fp32
            + 16.0 * 16.0                 // L1 input at dq[0]
            + 8.0 * 16.0                  // L1 output at dq[0]
            + 90.0 * 32.0                 // L2 weights
            + 8.0 * 16.0                  // L2 input at dq[0] (producer)
            + 10.0 * 8.0; // L2 output at dq[1]
        assert!((bits - expect).abs() < 1e-9, "bits {bits} expect {expect}");
    }

    #[test]
    fn monotone_in_bits() {
        let m = toy_manifest();
        let narrow = PrecisionConfig::uniform(2, QFormat::new(1, 3), QFormat::new(4, 0));
        let wide = PrecisionConfig::uniform(2, QFormat::new(1, 11), QFormat::new(10, 2));
        assert!(
            traffic_bits(&m, Mode::Batch(8), &narrow) < traffic_bits(&m, Mode::Batch(8), &wide)
        );
    }

    #[test]
    fn ratio_vs16_halves_vs32() {
        let m = toy_manifest();
        let cfg16 = PrecisionConfig::uniform(2, QFormat::new(1, 15), QFormat::new(14, 2));
        let r = traffic_ratio_vs16(&m, Mode::Batch(8), &cfg16);
        assert!((r - 1.0).abs() < 1e-12);
        let r32 = traffic_ratio(&m, Mode::Batch(8), &cfg16);
        assert!((r32 - 0.5).abs() < 1e-12);
    }
}
