//! The Q(I.F) fixed-point format and the host-side quantizer.
//!
//! Semantics are locked bit-for-bit against the L1 Pallas kernel and the
//! jnp oracle (`python/compile/kernels/ref.py`): round-to-nearest-even on
//! `x * 2^F`, multiply back by `2^-F`, saturate to `[-2^(I-1), 2^(I-1) -
//! 2^-F]`, all in fp32. `artifacts/golden_quant.ntf` carries python-
//! generated vectors that the integration tests replay against this
//! module.
//!
//! `I` counts integer bits *including* the sign bit; `F` counts fractional
//! bits (paper §2.1). [`QFormat::FP32`] is the pass-through sentinel
//! (encoded as `I = -1` on the wire, matching the kernels).

use std::fmt;

pub mod metrics;

/// A fixed-point representation: I integer bits (incl. sign) + F fraction bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct QFormat {
    pub ibits: i8,
    pub fbits: i8,
}

impl QFormat {
    /// The fp32 pass-through sentinel (no quantization).
    pub const FP32: QFormat = QFormat { ibits: -1, fbits: 0 };

    pub const fn new(ibits: i8, fbits: i8) -> Self {
        Self { ibits, fbits }
    }

    pub fn is_fp32(&self) -> bool {
        self.ibits < 0
    }

    /// Total representation length in bits (paper: N = I + F); 32 for fp32.
    pub fn bits(&self) -> u32 {
        if self.is_fp32() {
            32
        } else {
            (self.ibits + self.fbits) as u32
        }
    }

    /// Smallest representable increment, 2^-F.
    pub fn step(&self) -> f32 {
        (-(self.fbits as f64)).exp2() as f32
    }

    /// Saturation bounds (lo, hi) = (-2^(I-1), 2^(I-1) - 2^-F).
    pub fn range(&self) -> (f32, f32) {
        let hi_pow = ((self.ibits as f64) - 1.0).exp2();
        ((-hi_pow) as f32, (hi_pow - (-(self.fbits as f64)).exp2()) as f32)
    }

    /// Quantize one fp32 value (round-to-nearest-even + saturate).
    #[inline]
    pub fn quantize(&self, x: f32) -> f32 {
        if self.is_fp32() {
            return x;
        }
        let scale = (self.fbits as f32).exp2();
        let inv = (-(self.fbits as f32)).exp2();
        let (lo, hi) = self.range();
        ((x * scale).round_ties_even() * inv).clamp(lo, hi)
    }

    /// Quantize a slice in place. Bit-identical to mapping
    /// [`QFormat::quantize`] over the slice (a property test pins this),
    /// but the hot path is branch-free so it auto-vectorizes:
    /// `step`/range factors are hoisted out of the loop, the scaled
    /// value is clamped *before* rounding (the bounds are exact grid
    /// integers, so clamp-then-round equals round-then-clamp), and
    /// round-to-nearest-even is the classic `|v| + 1.5·2²³` trick with
    /// the sign restored by `copysign` — valid while the clamped value
    /// fits in ±2²², i.e. `I + F ≤ 23`, which covers every paper-range
    /// format; wider formats take the scalar loop.
    pub fn quantize_slice(&self, xs: &mut [f32]) {
        if self.is_fp32() {
            return;
        }
        let scale = (self.fbits as f32).exp2();
        let inv = (-(self.fbits as f32)).exp2();
        let (lo, hi) = self.range();
        if (self.ibits as i32) + (self.fbits as i32) <= 23 {
            const MAGIC: f32 = 12_582_912.0; // 1.5 * 2^23
            let (slo, shi) = (lo * scale, hi * scale);
            for x in xs {
                let v = (*x * scale).clamp(slo, shi);
                *x = ((v.abs() + MAGIC) - MAGIC).copysign(v) * inv;
            }
        } else {
            for x in xs {
                *x = ((*x * scale).round_ties_even() * inv).clamp(lo, hi);
            }
        }
    }

    /// Quantize into a new vector.
    pub fn quantize_vec(&self, xs: &[f32]) -> Vec<f32> {
        let mut v = xs.to_vec();
        self.quantize_slice(&mut v);
        v
    }

    /// Number of representable grid points (2^(I+F)); None for fp32.
    pub fn levels(&self) -> Option<u64> {
        if self.is_fp32() {
            None
        } else {
            Some(1u64 << (self.ibits as u32 + self.fbits as u32))
        }
    }

    /// Wire encoding used by the HLO executables: (I, F) as f32, I<0 = fp32.
    pub fn wire(&self) -> [f32; 2] {
        [self.ibits as f32, self.fbits as f32]
    }

    /// Decode one wire row (the inverse of [`QFormat::wire`], as the
    /// kernels interpret it: any negative I is the fp32 sentinel).
    pub fn from_wire(ibits: f32, fbits: f32) -> QFormat {
        if ibits < 0.0 {
            QFormat::FP32
        } else {
            QFormat::new(ibits as i8, fbits as i8)
        }
    }

    /// Parse the paper's "I.F" notation ("1.8", "12.2", or "fp32").
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let s = s.trim();
        if s.eq_ignore_ascii_case("fp32") || s == "-" {
            return Ok(Self::FP32);
        }
        let (i, f) = s
            .split_once('.')
            .ok_or_else(|| anyhow::anyhow!("bad QFormat {s:?} (want I.F or fp32)"))?;
        let ibits: i8 = i.parse().map_err(|e| anyhow::anyhow!("bad I in {s:?}: {e}"))?;
        let fbits: i8 = f.parse().map_err(|e| anyhow::anyhow!("bad F in {s:?}: {e}"))?;
        anyhow::ensure!(ibits >= 0 && fbits >= 0, "negative field in {s:?}");
        anyhow::ensure!(ibits + fbits > 0, "zero-width format {s:?}");
        Ok(Self { ibits, fbits })
    }
}

impl fmt::Display for QFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_fp32() {
            write!(f, "fp32")
        } else {
            write!(f, "{}.{}", self.ibits, self.fbits)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_and_step() {
        let q = QFormat::new(4, 2); // lo -8, hi 8 - 0.25
        assert_eq!(q.range(), (-8.0, 7.75));
        assert_eq!(q.step(), 0.25);
        assert_eq!(q.bits(), 6);
        assert_eq!(q.levels(), Some(64));
    }

    #[test]
    fn quantize_rounds_to_nearest_even() {
        let q = QFormat::new(8, 0);
        assert_eq!(q.quantize(0.5), 0.0); // ties to even
        assert_eq!(q.quantize(1.5), 2.0);
        assert_eq!(q.quantize(2.5), 2.0);
        assert_eq!(q.quantize(-0.5), 0.0);
        assert_eq!(q.quantize(-1.5), -2.0);
        assert_eq!(q.quantize(0.6), 1.0);
    }

    #[test]
    fn quantize_saturates() {
        let q = QFormat::new(4, 2);
        assert_eq!(q.quantize(100.0), 7.75);
        assert_eq!(q.quantize(-100.0), -8.0);
        assert_eq!(q.quantize(f32::INFINITY), 7.75);
        assert_eq!(q.quantize(f32::NEG_INFINITY), -8.0);
    }

    #[test]
    fn fp32_sentinel_is_identity() {
        let q = QFormat::FP32;
        for x in [0.1f32, -123.456, 1e20, f32::MIN_POSITIVE] {
            assert_eq!(q.quantize(x), x);
        }
        assert_eq!(q.bits(), 32);
        assert!(q.levels().is_none());
    }

    #[test]
    fn i_zero_formats_are_pure_fractions() {
        let q = QFormat::new(0, 3); // lo -0.5, hi 0.5 - 0.125
        assert_eq!(q.range(), (-0.5, 0.375));
        assert_eq!(q.quantize(0.4), 0.375);
        assert_eq!(q.quantize(-0.7), -0.5);
    }

    #[test]
    fn quantize_idempotent() {
        let q = QFormat::new(6, 4);
        for x in [-31.97f32, 0.33, 2.0, 17.1234] {
            let once = q.quantize(x);
            assert_eq!(q.quantize(once), once);
        }
    }

    #[test]
    fn slice_matches_scalar() {
        let q = QFormat::new(5, 3);
        let xs: Vec<f32> = (-40..40).map(|i| i as f32 * 0.37).collect();
        let mut ys = xs.clone();
        q.quantize_slice(&mut ys);
        for (x, y) in xs.iter().zip(&ys) {
            assert_eq!(q.quantize(*x), *y);
        }
    }

    #[test]
    fn parse_and_display_roundtrip() {
        for s in ["1.8", "12.2", "0.4", "16.0"] {
            let q = QFormat::parse(s).unwrap();
            assert_eq!(q.to_string(), s);
        }
        assert_eq!(QFormat::parse("fp32").unwrap(), QFormat::FP32);
        assert_eq!(QFormat::FP32.to_string(), "fp32");
        assert!(QFormat::parse("x.y").is_err());
        assert!(QFormat::parse("0.0").is_err());
        assert!(QFormat::parse("8").is_err());
    }

    #[test]
    fn wire_encoding() {
        assert_eq!(QFormat::new(12, 2).wire(), [12.0, 2.0]);
        assert_eq!(QFormat::FP32.wire(), [-1.0, 0.0]);
    }

    #[test]
    fn wire_roundtrip() {
        for q in [QFormat::new(12, 2), QFormat::new(0, 3), QFormat::FP32] {
            let [i, f] = q.wire();
            assert_eq!(QFormat::from_wire(i, f), q);
        }
    }
}
