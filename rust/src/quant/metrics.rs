//! Quantization-error metrics: SQNR, max-abs error, saturation rate.
//!
//! Used by the reports and by examples to characterize how hard a format
//! squeezes a tensor — complementary to the accuracy-level results.

use super::QFormat;

/// Error statistics of quantizing `xs` with `fmt`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantError {
    /// Signal-to-quantization-noise ratio in dB (f64 accumulation).
    pub sqnr_db: f64,
    /// max |x - q(x)|
    pub max_abs: f32,
    /// mean |x - q(x)|
    pub mean_abs: f64,
    /// Fraction of elements that hit the saturation bounds.
    pub sat_rate: f64,
}

/// Compute [`QuantError`] of `fmt` over `xs`.
pub fn quant_error(fmt: QFormat, xs: &[f32]) -> QuantError {
    if xs.is_empty() {
        return QuantError { sqnr_db: f64::INFINITY, max_abs: 0.0, mean_abs: 0.0, sat_rate: 0.0 };
    }
    let (lo, hi) = if fmt.is_fp32() { (f32::NEG_INFINITY, f32::INFINITY) } else { fmt.range() };
    let mut sig = 0.0f64;
    let mut noise = 0.0f64;
    let mut max_abs = 0.0f32;
    let mut sum_abs = 0.0f64;
    let mut sat = 0usize;
    for &x in xs {
        let q = fmt.quantize(x);
        let e = x - q;
        sig += (x as f64) * (x as f64);
        noise += (e as f64) * (e as f64);
        let a = e.abs();
        if a > max_abs {
            max_abs = a;
        }
        sum_abs += a as f64;
        if q <= lo || q >= hi {
            sat += 1;
        }
    }
    let sqnr_db = if noise == 0.0 { f64::INFINITY } else { 10.0 * (sig / noise).log10() };
    QuantError {
        sqnr_db,
        max_abs,
        mean_abs: sum_abs / xs.len() as f64,
        sat_rate: sat as f64 / xs.len() as f64,
    }
}

/// The classic "6 dB per bit" rule of thumb for a full-scale uniform
/// signal — used as a sanity anchor in tests and docs.
pub fn ideal_sqnr_db(bits: u32) -> f64 {
    6.020_599_913 * bits as f64 + 1.76
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Xoshiro256pp;

    #[test]
    fn exact_representation_has_infinite_sqnr() {
        let fmt = QFormat::new(4, 2);
        let xs = [0.25f32, -1.5, 3.0, 0.0];
        let e = quant_error(fmt, &xs);
        assert_eq!(e.sqnr_db, f64::INFINITY);
        assert_eq!(e.max_abs, 0.0);
        assert_eq!(e.sat_rate, 0.0);
    }

    #[test]
    fn saturation_detected() {
        let fmt = QFormat::new(2, 0); // range [-2, 1]
        let xs = [10.0f32, -10.0, 0.0, 1.0];
        let e = quant_error(fmt, &xs);
        // 10 -> 1 (hi), -10 -> -2 (lo), 1.0 -> 1 (== hi, counted)
        assert!(e.sat_rate >= 0.5, "sat {}", e.sat_rate);
        assert_eq!(e.max_abs, 9.0);
    }

    #[test]
    fn sqnr_improves_with_bits() {
        let mut rng = Xoshiro256pp::new(9);
        let xs: Vec<f32> = (0..4096).map(|_| rng.uniform_f32(-0.99, 0.99)).collect();
        let e4 = quant_error(QFormat::new(1, 3), &xs);
        let e8 = quant_error(QFormat::new(1, 7), &xs);
        let e12 = quant_error(QFormat::new(1, 11), &xs);
        assert!(e8.sqnr_db > e4.sqnr_db + 18.0, "{} vs {}", e8.sqnr_db, e4.sqnr_db);
        assert!(e12.sqnr_db > e8.sqnr_db + 18.0);
        // ~6 dB/bit anchor (loose band: signal isn't exactly full-scale)
        assert!((e8.sqnr_db - ideal_sqnr_db(8)).abs() < 8.0, "sqnr {}", e8.sqnr_db);
    }

    #[test]
    fn fp32_sentinel_no_error() {
        let xs = [1.1f32, -2.2, 3.3];
        let e = quant_error(QFormat::FP32, &xs);
        assert_eq!(e.max_abs, 0.0);
        assert_eq!(e.sat_rate, 0.0);
    }

    #[test]
    fn empty_slice_is_clean() {
        let e = quant_error(QFormat::new(4, 4), &[]);
        assert_eq!(e.mean_abs, 0.0);
    }
}
