//! Network manifests — the metadata contract between the python build path
//! and the rust runtime.
//!
//! `python/compile/aot.py` writes one `<net>.manifest.json` per network
//! describing its layers (with the element/weight/MAC counts that feed the
//! paper's Fig-4 traffic model), the ordered parameter list matching the
//! executable's input signature, baseline accuracy, and artifact file
//! names. This module parses and validates those manifests.

pub mod arch;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Per-layer metadata (the paper's "layer" granularity, Appendix A).
#[derive(Clone, Debug)]
pub struct LayerMeta {
    pub name: String,
    /// "conv" | "fc" | "inception"
    pub kind: String,
    /// Elements read by this layer per image (its input tensor).
    pub in_elems: u64,
    /// Elements written by this layer per image (its output tensor).
    pub out_elems: u64,
    /// Weight elements (kernels + biases) of the layer.
    pub weight_elems: u64,
    /// Multiply-accumulates per image.
    pub macs: u64,
    /// Stage names inside the layer (conv, relu, pool, norm, ...).
    pub stages: Vec<String>,
}

/// One entry of the flat parameter list (executable input order).
#[derive(Clone, Debug)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamMeta {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// The Fig-1 stage-granularity executable variant (AlexNet layer 2).
#[derive(Clone, Debug)]
pub struct StageVariant {
    pub hlo: String,
    pub group_index: usize,
    pub n_stages: usize,
    pub stage_names: Vec<String>,
}

/// Parsed, validated manifest of one network.
#[derive(Clone, Debug)]
pub struct NetManifest {
    pub name: String,
    pub dataset: String,
    pub num_classes: usize,
    pub input_shape: Vec<usize>,
    pub batch: usize,
    pub n_eval: usize,
    pub baseline_top1: f64,
    pub layers: Vec<LayerMeta>,
    pub params: Vec<ParamMeta>,
    pub hlo_file: String,
    pub weights_file: String,
    pub dataset_file: String,
    pub stage_variant: Option<StageVariant>,
    /// Directory the manifest was loaded from (for resolving files).
    pub dir: PathBuf,
}

fn req_str(j: &Json, key: &str) -> Result<String> {
    j.get(key)
        .and_then(|v| v.as_str())
        .map(|s| s.to_string())
        .ok_or_else(|| anyhow::anyhow!("manifest missing string {key:?}"))
}

fn req_u64(j: &Json, key: &str) -> Result<u64> {
    j.get(key).and_then(|v| v.as_u64()).ok_or_else(|| anyhow::anyhow!("manifest missing {key:?}"))
}

impl NetManifest {
    /// Load and validate `<dir>/<net>.manifest.json`.
    pub fn load(dir: &Path, net: &str) -> Result<NetManifest> {
        let path = dir.join(format!("{net}.manifest.json"));
        let text = crate::util::read_to_string(&path)?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(&j, dir).with_context(|| format!("validating {}", path.display()))
    }

    pub fn from_json(j: &Json, dir: &Path) -> Result<NetManifest> {
        let name = req_str(j, "name")?;
        let layers = j
            .get("layers")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("missing layers"))?
            .iter()
            .map(|l| {
                Ok(LayerMeta {
                    name: req_str(l, "name")?,
                    kind: req_str(l, "kind")?,
                    in_elems: req_u64(l, "in_elems")?,
                    out_elems: req_u64(l, "out_elems")?,
                    weight_elems: req_u64(l, "weight_elems")?,
                    macs: req_u64(l, "macs")?,
                    stages: l
                        .get("stages")
                        .and_then(|s| s.as_arr())
                        .map(|arr| {
                            arr.iter()
                                .filter_map(|st| st.get("name").and_then(|n| n.as_str()))
                                .map(|s| s.to_string())
                                .collect()
                        })
                        .unwrap_or_default(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        if layers.is_empty() {
            bail!("network {name} has no layers");
        }
        let params = j
            .get("params")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("missing params"))?
            .iter()
            .map(|p| {
                Ok(ParamMeta {
                    name: req_str(p, "name")?,
                    shape: p
                        .get("shape")
                        .and_then(|s| s.as_arr())
                        .ok_or_else(|| anyhow::anyhow!("param missing shape"))?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let files = j.get("files").ok_or_else(|| anyhow::anyhow!("missing files"))?;
        let stage_variant = match j.get("stage_variant") {
            Some(sv) if !sv.is_null() => Some(StageVariant {
                hlo: req_str(sv, "hlo")?,
                group_index: req_u64(sv, "group_index")? as usize,
                n_stages: req_u64(sv, "n_stages")? as usize,
                stage_names: sv
                    .get("stage_names")
                    .and_then(|v| v.as_arr())
                    .map(|a| a.iter().filter_map(|s| s.as_str().map(String::from)).collect())
                    .unwrap_or_default(),
            }),
            _ => None,
        };
        let m = NetManifest {
            name,
            dataset: req_str(j, "dataset")?,
            num_classes: req_u64(j, "num_classes")? as usize,
            input_shape: j
                .get("input_shape")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow::anyhow!("missing input_shape"))?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect(),
            batch: req_u64(j, "batch")? as usize,
            n_eval: req_u64(j, "n_eval")? as usize,
            baseline_top1: j
                .get("baseline_top1")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow::anyhow!("missing baseline_top1"))?,
            layers,
            params,
            hlo_file: req_str(files, "hlo")?,
            weights_file: req_str(files, "weights")?,
            dataset_file: req_str(files, "dataset")?,
            stage_variant,
            dir: dir.to_path_buf(),
        };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        if self.batch == 0 || self.num_classes == 0 {
            bail!("zero batch or classes");
        }
        if self.input_shape.len() != 3 {
            bail!("input_shape must be rank-3 (H, W, C)");
        }
        // Layer-0 input must equal the image element count.
        let img: u64 = self.input_shape.iter().product::<usize>() as u64;
        if self.layers[0].in_elems != img {
            bail!("layer 0 in_elems {} != image elems {img}", self.layers[0].in_elems);
        }
        // Chain consistency: layer l input == layer l-1 output.
        for w in self.layers.windows(2) {
            if w[1].in_elems != w[0].out_elems {
                bail!("layer chain broken: {} out {} vs {} in {}",
                    w[0].name, w[0].out_elems, w[1].name, w[1].in_elems);
            }
        }
        // Weight totals must match the parameter list.
        let param_total: u64 = self.params.iter().map(|p| p.elems() as u64).sum();
        let layer_total: u64 = self.layers.iter().map(|l| l.weight_elems).sum();
        if param_total != layer_total {
            bail!("params total {param_total} != layer weights total {layer_total}");
        }
        Ok(())
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Stages in the Fig-1 stage-granularity variant (0 when absent).
    pub fn n_stages(&self) -> usize {
        self.stage_variant.as_ref().map(|s| s.n_stages).unwrap_or(0)
    }

    pub fn hlo_path(&self) -> PathBuf {
        self.dir.join(&self.hlo_file)
    }

    pub fn weights_path(&self) -> PathBuf {
        self.dir.join(&self.weights_file)
    }

    pub fn dataset_path(&self) -> PathBuf {
        self.dir.join(&self.dataset_file)
    }

    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_elems).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }
}

/// The artifact index (`index.json`): build metadata + net list.
#[derive(Clone, Debug)]
pub struct ArtifactIndex {
    pub nets: Vec<String>,
    pub batch: usize,
    pub quick: bool,
}

impl ArtifactIndex {
    pub fn load(dir: &Path) -> Result<ArtifactIndex> {
        let text = crate::util::read_to_string(&dir.join("index.json"))?;
        let j = Json::parse(&text)?;
        Ok(ArtifactIndex {
            nets: j
                .get("nets")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow::anyhow!("index missing nets"))?
                .iter()
                .filter_map(|n| n.get("name").and_then(|s| s.as_str()).map(String::from))
                .collect(),
            batch: req_u64(&j, "batch")? as usize,
            quick: j.get("quick").and_then(|v| v.as_bool()).unwrap_or(false),
        })
    }
}

/// Extra index accessors that don't warrant full struct fields.
pub struct ArtifactIndexExt;

impl ArtifactIndexExt {
    /// Element count of the standalone kernel artifacts (`kernel_n`).
    pub fn kernel_n(dir: &Path) -> Result<usize> {
        let text = crate::util::read_to_string(&dir.join("index.json"))?;
        let j = Json::parse(&text)?;
        j.get("kernel_n")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("index.json lacks kernel_n — rebuild artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal_json() -> String {
        r#"{
          "name": "tiny", "dataset": "synmnist", "num_classes": 10,
          "input_shape": [4, 4, 1], "batch": 8, "n_eval": 64,
          "baseline_top1": 0.9,
          "layers": [
            {"name": "L1", "kind": "conv", "in_elems": 16, "out_elems": 8,
             "weight_elems": 20, "macs": 100, "stages": [{"name": "conv"}]},
            {"name": "L2", "kind": "fc", "in_elems": 8, "out_elems": 10,
             "weight_elems": 90, "macs": 80, "stages": [{"name": "fc"}]}
          ],
          "params": [
            {"name": "L1.conv.w", "shape": [20]},
            {"name": "L2.fc.w", "shape": [9, 10]}
          ],
          "files": {"hlo": "t.hlo.txt", "weights": "t.w.ntf", "dataset": "t.d.ntf"},
          "stage_variant": null
        }"#
        .to_string()
    }

    #[test]
    fn parses_minimal_manifest() {
        let j = Json::parse(&minimal_json()).unwrap();
        let m = NetManifest::from_json(&j, Path::new("/tmp")).unwrap();
        assert_eq!(m.name, "tiny");
        assert_eq!(m.n_layers(), 2);
        assert_eq!(m.total_weights(), 110);
        assert_eq!(m.params[1].elems(), 90);
        assert!(m.stage_variant.is_none());
        assert_eq!(m.hlo_path(), PathBuf::from("/tmp/t.hlo.txt"));
    }

    #[test]
    fn rejects_broken_layer_chain() {
        let bad = minimal_json().replace("\"in_elems\": 8", "\"in_elems\": 9");
        let j = Json::parse(&bad).unwrap();
        assert!(NetManifest::from_json(&j, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_weight_mismatch() {
        let bad = minimal_json().replace("\"shape\": [20]", "\"shape\": [21]");
        let j = Json::parse(&bad).unwrap();
        assert!(NetManifest::from_json(&j, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_wrong_input_elems() {
        let bad = minimal_json().replace("\"in_elems\": 16", "\"in_elems\": 15");
        let j = Json::parse(&bad).unwrap();
        assert!(NetManifest::from_json(&j, Path::new("/tmp")).is_err());
    }

    #[test]
    fn stage_variant_parses() {
        let with_sv = minimal_json().replace(
            "\"stage_variant\": null",
            r#""stage_variant": {"hlo": "s.hlo.txt", "group_index": 1,
                "n_stages": 4, "stage_names": ["conv","relu","pool","norm"]}"#,
        );
        let j = Json::parse(&with_sv).unwrap();
        let m = NetManifest::from_json(&j, Path::new("/tmp")).unwrap();
        let sv = m.stage_variant.unwrap();
        assert_eq!(sv.n_stages, 4);
        assert_eq!(sv.stage_names[3], "norm");
    }
}
