//! The five CNN architectures of the paper, as executable graph
//! descriptions — the rust mirror of `python/compile/nets.py`.
//!
//! The python build path and this registry describe the **same**
//! networks: op kinds, kernel sizes, channel widths, grouping of stages
//! into precision "layers", parameter order. The shape/weight/MAC walk
//! here reproduces `python/compile/layers.py::shape_walk` exactly, and
//! [`check_manifest`] cross-validates a loaded artifact manifest against
//! this registry — so the pure-Rust reference backend
//! ([`crate::backend::reference`]) is guaranteed to interpret the graph
//! the artifacts were built from, and drift between the two languages is
//! caught at load time rather than as silent accuracy skew.
//!
//! Shapes use NHWC; conv filters are HWIO, exactly like the L2 JAX
//! graphs.

use anyhow::{bail, Result};

use super::NetManifest;

/// Padding mode of a convolution (pools are always SAME, as in L2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Padding {
    Same,
    Valid,
}

/// One computational stage inside a precision layer.
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    /// 2-D convolution, NHWC × HWIO → NHWC, with bias.
    Conv { name: &'static str, out_c: usize, k: usize, stride: usize, padding: Padding },
    /// Fully-connected layer (expects flattened input), with bias.
    Dense { name: &'static str, out: usize },
    ReLU,
    MaxPool { k: usize, stride: usize },
    AvgPool { k: usize, stride: usize },
    GlobalAvgPool,
    /// Caffe-style across-channel local response normalization.
    Lrn { n: usize, alpha: f32, beta: f32 },
    Flatten,
    /// Identity at inference.
    Dropout,
    /// GoogLeNet inception module: 1x1 / 3x3(reduce) / 5x5(reduce) /
    /// pool-proj; all six convs form one precision group.
    Inception {
        name: &'static str,
        b1: usize,
        b3r: usize,
        b3: usize,
        b5r: usize,
        b5: usize,
        pp: usize,
    },
}

/// The standard AlexNet LRN hyper-parameters used by the L2 graphs.
pub const LRN_DEFAULT: Op = Op::Lrn { n: 5, alpha: 1e-4, beta: 0.75 };

impl Op {
    /// The stage name recorded in manifests (matches the python op names).
    pub fn stage_name(&self) -> &'static str {
        match self {
            Op::Conv { name, .. } | Op::Dense { name, .. } | Op::Inception { name, .. } => name,
            Op::ReLU => "relu",
            Op::MaxPool { .. } => "pool",
            Op::AvgPool { .. } => "avgpool",
            Op::GlobalAvgPool => "gap",
            Op::Lrn { .. } => "norm",
            Op::Flatten => "flatten",
            Op::Dropout => "drop",
        }
    }

    /// The op's kind independent of its instance name — the `op=` field
    /// of layer spans and the `qbound profile` kind column.
    pub fn kind(&self) -> &'static str {
        match self {
            Op::Conv { .. } => "conv",
            Op::Dense { .. } => "dense",
            Op::ReLU => "relu",
            Op::MaxPool { .. } => "maxpool",
            Op::AvgPool { .. } => "avgpool",
            Op::GlobalAvgPool => "gap",
            Op::Lrn { .. } => "lrn",
            Op::Flatten => "flatten",
            Op::Dropout => "dropout",
            Op::Inception { .. } => "inception",
        }
    }

    /// Number of flat parameter tensors this op consumes.
    pub fn param_count(&self) -> usize {
        match self {
            Op::Conv { .. } | Op::Dense { .. } => 2,
            Op::Inception { .. } => 12,
            _ => 0,
        }
    }

    fn inception_branches(&self) -> Vec<(&'static str, usize, InOut)> {
        match *self {
            Op::Inception { b1, b3r, b3, b5r, b5, pp, .. } => vec![
                ("b1", 1, InOut::FromInput(b1)),
                ("b3r", 1, InOut::FromInput(b3r)),
                ("b3", 3, InOut::Fixed(b3r, b3)),
                ("b5r", 1, InOut::FromInput(b5r)),
                ("b5", 5, InOut::Fixed(b5r, b5)),
                ("pp", 1, InOut::FromInput(pp)),
            ],
            _ => Vec::new(),
        }
    }
}

/// Branch channel spec helper: input channels either come from the
/// module input or are fixed by a reduce stage.
#[derive(Clone, Copy, Debug)]
enum InOut {
    FromInput(usize),
    Fixed(usize, usize),
}

/// Activation shape flowing between ops.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Shape {
    /// (height, width, channels), NHWC per image.
    Hwc(usize, usize, usize),
    /// Flattened vector.
    Flat(usize),
}

impl Shape {
    pub fn elems(&self) -> usize {
        match *self {
            Shape::Hwc(h, w, c) => h * w * c,
            Shape::Flat(n) => n,
        }
    }
}

/// Output spatial dims of a k×k window with stride s over (h, w).
pub fn conv_out_hw(h: usize, w: usize, k: usize, s: usize, padding: Padding) -> (usize, usize) {
    match padding {
        Padding::Same => ((h + s - 1) / s, (w + s - 1) / s),
        Padding::Valid => ((h - k) / s + 1, (w - k) / s + 1),
    }
}

/// XLA-style SAME padding offset: total pad split low-biased.
pub fn same_pad_before(in_dim: usize, out_dim: usize, k: usize, s: usize) -> usize {
    let needed = ((out_dim - 1) * s + k).saturating_sub(in_dim);
    needed / 2
}

/// Shape after applying `op` to `shape`.
pub fn op_out_shape(op: &Op, shape: Shape) -> Result<Shape> {
    Ok(match (op, shape) {
        (&Op::Conv { out_c, k, stride, padding, .. }, Shape::Hwc(h, w, _)) => {
            let (oh, ow) = conv_out_hw(h, w, k, stride, padding);
            Shape::Hwc(oh, ow, out_c)
        }
        (&Op::Dense { out, .. }, Shape::Flat(_)) => Shape::Flat(out),
        (&Op::MaxPool { k, stride } | &Op::AvgPool { k, stride }, Shape::Hwc(h, w, c)) => {
            let (oh, ow) = conv_out_hw(h, w, k, stride, Padding::Same);
            Shape::Hwc(oh, ow, c)
        }
        (Op::GlobalAvgPool, Shape::Hwc(_, _, c)) => Shape::Flat(c),
        (Op::Flatten, Shape::Hwc(h, w, c)) => Shape::Flat(h * w * c),
        (&Op::Inception { b1, b3, b5, pp, .. }, Shape::Hwc(h, w, _)) => {
            Shape::Hwc(h, w, b1 + b3 + b5 + pp)
        }
        (Op::ReLU | Op::Lrn { .. } | Op::Dropout, s) => s,
        (op, s) => bail!("op {op:?} cannot apply to shape {s:?}"),
    })
}

/// (weight elems incl. bias, MACs) of `op` at input `shape` — mirrors
/// `layers.py::_op_counts`.
pub fn op_counts(op: &Op, shape: Shape) -> (u64, u64) {
    match (op, shape) {
        (&Op::Conv { out_c, k, stride, padding, .. }, Shape::Hwc(h, w, c)) => {
            let (oh, ow) = conv_out_hw(h, w, k, stride, padding);
            let wts = k * k * c * out_c + out_c;
            let macs = oh * ow * out_c * k * k * c;
            (wts as u64, macs as u64)
        }
        (&Op::Dense { out, .. }, s) => {
            let fan_in = s.elems();
            ((fan_in * out + out) as u64, (fan_in * out) as u64)
        }
        (op @ Op::Inception { .. }, Shape::Hwc(h, w, c)) => {
            let mut wts = 0u64;
            let mut macs = 0u64;
            for (_, k, io) in op.inception_branches() {
                let (ic, oc) = match io {
                    InOut::FromInput(oc) => (c, oc),
                    InOut::Fixed(ic, oc) => (ic, oc),
                };
                wts += (k * k * ic * oc + oc) as u64;
                macs += (h * w * oc * k * k * ic) as u64;
            }
            (wts, macs)
        }
        _ => (0, 0),
    }
}

/// One paper-granularity precision layer.
#[derive(Clone, Debug)]
pub struct LayerGroup {
    pub name: &'static str,
    /// "conv" | "fc" | "inception"
    pub kind: &'static str,
    pub ops: Vec<Op>,
}

/// A full network description.
#[derive(Clone, Debug)]
pub struct Arch {
    pub name: &'static str,
    pub dataset: &'static str,
    /// (H, W, C)
    pub input_shape: (usize, usize, usize),
    pub num_classes: usize,
    pub groups: Vec<LayerGroup>,
}

impl Arch {
    pub fn input_elems(&self) -> usize {
        let (h, w, c) = self.input_shape;
        h * w * c
    }

    pub fn n_layers(&self) -> usize {
        self.groups.len()
    }
}

/// One entry of the flat parameter list, in initialization order.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// He-init fan-in; 0 means zero-init (biases).
    pub fan_in: usize,
}

impl ParamSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// The flat parameter list of `arch` — names, shapes and init fan-in, in
/// exactly the python `init_params` order.
pub fn param_specs(arch: &Arch) -> Result<Vec<ParamSpec>> {
    let mut specs = Vec::new();
    let (h, w, c) = arch.input_shape;
    let mut shape = Shape::Hwc(h, w, c);
    for g in &arch.groups {
        for op in &g.ops {
            let prefix = format!("{}.{}", g.name, op.stage_name());
            match (op, shape) {
                (&Op::Conv { out_c, k, .. }, Shape::Hwc(_, _, ic)) => {
                    specs.push(ParamSpec {
                        name: format!("{prefix}.w"),
                        shape: vec![k, k, ic, out_c],
                        fan_in: k * k * ic,
                    });
                    specs.push(ParamSpec {
                        name: format!("{prefix}.b"),
                        shape: vec![out_c],
                        fan_in: 0,
                    });
                }
                (&Op::Dense { out, .. }, s) => {
                    let fan_in = s.elems();
                    specs.push(ParamSpec {
                        name: format!("{prefix}.w"),
                        shape: vec![fan_in, out],
                        fan_in,
                    });
                    specs.push(ParamSpec {
                        name: format!("{prefix}.b"),
                        shape: vec![out],
                        fan_in: 0,
                    });
                }
                (op @ Op::Inception { .. }, Shape::Hwc(_, _, ic)) => {
                    for (branch, k, io) in op.inception_branches() {
                        let (bic, boc) = match io {
                            InOut::FromInput(oc) => (ic, oc),
                            InOut::Fixed(fic, oc) => (fic, oc),
                        };
                        specs.push(ParamSpec {
                            name: format!("{prefix}.{branch}.w"),
                            shape: vec![k, k, bic, boc],
                            fan_in: k * k * bic,
                        });
                        specs.push(ParamSpec {
                            name: format!("{prefix}.{branch}.b"),
                            shape: vec![boc],
                            fan_in: 0,
                        });
                    }
                }
                _ => {}
            }
            shape = op_out_shape(op, shape)?;
        }
    }
    Ok(specs)
}

/// Per-group analytic metadata — the rust `shape_walk`.
#[derive(Clone, Debug)]
pub struct LayerWalk {
    pub name: &'static str,
    pub kind: &'static str,
    pub in_elems: u64,
    pub out_elems: u64,
    pub weight_elems: u64,
    pub macs: u64,
    pub stages: Vec<&'static str>,
}

/// Walk the graph analytically: per-group in/out/weights/MACs/stages plus
/// the final output shape.
pub fn shape_walk(arch: &Arch) -> Result<(Vec<LayerWalk>, Shape)> {
    let (h, w, c) = arch.input_shape;
    let mut shape = Shape::Hwc(h, w, c);
    let mut walks = Vec::with_capacity(arch.groups.len());
    for g in &arch.groups {
        let in_elems = shape.elems() as u64;
        let mut wts = 0u64;
        let mut macs = 0u64;
        let mut stages = Vec::with_capacity(g.ops.len());
        for op in &g.ops {
            let (ow, om) = op_counts(op, shape);
            wts += ow;
            macs += om;
            shape = op_out_shape(op, shape)?;
            stages.push(op.stage_name());
        }
        walks.push(LayerWalk {
            name: g.name,
            kind: g.kind,
            in_elems,
            out_elems: shape.elems() as u64,
            weight_elems: wts,
            macs,
            stages,
        });
    }
    Ok((walks, shape))
}

/// Validate that `m` (a loaded artifact manifest) describes exactly the
/// network this registry would build — names, shapes, counts, parameter
/// list. A mismatch means the artifacts were built from a different
/// network definition than this binary carries.
pub fn check_manifest(arch: &Arch, m: &NetManifest) -> Result<()> {
    let (h, w, c) = arch.input_shape;
    if m.input_shape != vec![h, w, c] {
        bail!("{}: manifest input shape {:?} != arch {:?}", m.name, m.input_shape, (h, w, c));
    }
    if m.num_classes != arch.num_classes {
        bail!("{}: manifest classes {} != arch {}", m.name, m.num_classes, arch.num_classes);
    }
    let (walks, out) = shape_walk(arch)?;
    if out != Shape::Flat(arch.num_classes) {
        bail!("{}: arch output {out:?} != {} classes", arch.name, arch.num_classes);
    }
    if m.layers.len() != walks.len() {
        bail!("{}: manifest has {} layers, arch {}", m.name, m.layers.len(), walks.len());
    }
    for (lm, lw) in m.layers.iter().zip(&walks) {
        if lm.name != lw.name
            || lm.kind != lw.kind
            || lm.in_elems != lw.in_elems
            || lm.out_elems != lw.out_elems
            || lm.weight_elems != lw.weight_elems
            || lm.macs != lw.macs
        {
            bail!("{}: layer {:?} disagrees with arch walk {:?}", m.name, lm, lw);
        }
    }
    let specs = param_specs(arch)?;
    if m.params.len() != specs.len() {
        bail!("{}: manifest has {} params, arch {}", m.name, m.params.len(), specs.len());
    }
    for (pm, ps) in m.params.iter().zip(&specs) {
        if pm.name != ps.name || pm.shape != ps.shape {
            bail!("{}: param {:?} disagrees with arch spec {:?}", m.name, pm, ps);
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// The registry (mirrors nets.py exactly)
// ---------------------------------------------------------------------------

fn conv(name: &'static str, out_c: usize, k: usize) -> Op {
    Op::Conv { name, out_c, k, stride: 1, padding: Padding::Same }
}

fn conv_valid(name: &'static str, out_c: usize, k: usize) -> Op {
    Op::Conv { name, out_c, k, stride: 1, padding: Padding::Valid }
}

fn group(name: &'static str, kind: &'static str, ops: Vec<Op>) -> LayerGroup {
    LayerGroup { name, kind, ops }
}

fn lenet() -> Arch {
    Arch {
        name: "lenet",
        dataset: "synmnist",
        input_shape: (28, 28, 1),
        num_classes: 10,
        groups: vec![
            group("L1", "conv", vec![conv_valid("conv", 8, 5), Op::MaxPool { k: 2, stride: 2 }]),
            group("L2", "conv", vec![conv_valid("conv", 16, 5), Op::MaxPool { k: 2, stride: 2 }]),
            group("L3", "fc", vec![Op::Flatten, Op::Dense { name: "fc", out: 64 }, Op::ReLU]),
            group("L4", "fc", vec![Op::Dense { name: "fc", out: 10 }]),
        ],
    }
}

fn convnet() -> Arch {
    Arch {
        name: "convnet",
        dataset: "syncifar",
        input_shape: (32, 32, 3),
        num_classes: 10,
        groups: vec![
            group(
                "L1",
                "conv",
                vec![conv("conv", 16, 5), Op::MaxPool { k: 3, stride: 2 }, Op::ReLU],
            ),
            group(
                "L2",
                "conv",
                vec![conv("conv", 16, 5), Op::ReLU, Op::MaxPool { k: 3, stride: 2 }],
            ),
            group(
                "L3",
                "conv",
                vec![conv("conv", 16, 5), Op::ReLU, Op::MaxPool { k: 3, stride: 2 }],
            ),
            group("L4", "fc", vec![Op::Flatten, Op::Dense { name: "fc", out: 32 }]),
            group("L5", "fc", vec![Op::Dense { name: "fc", out: 10 }]),
        ],
    }
}

fn alexnet() -> Arch {
    Arch {
        name: "alexnet",
        dataset: "synimagenet",
        input_shape: (32, 32, 3),
        num_classes: 20,
        groups: vec![
            group(
                "L1",
                "conv",
                vec![conv("conv", 24, 3), Op::ReLU, Op::MaxPool { k: 3, stride: 2 }, LRN_DEFAULT],
            ),
            group(
                "L2",
                "conv",
                vec![conv("conv", 32, 3), Op::ReLU, Op::MaxPool { k: 3, stride: 2 }, LRN_DEFAULT],
            ),
            group("L3", "conv", vec![conv("conv", 48, 3), Op::ReLU]),
            group("L4", "conv", vec![conv("conv", 48, 3), Op::ReLU]),
            group(
                "L5",
                "conv",
                vec![conv("conv", 32, 3), Op::ReLU, Op::MaxPool { k: 3, stride: 2 }],
            ),
            group(
                "L6",
                "fc",
                vec![Op::Flatten, Op::Dense { name: "fc", out: 128 }, Op::ReLU, Op::Dropout],
            ),
            group("L7", "fc", vec![Op::Dense { name: "fc", out: 128 }, Op::ReLU, Op::Dropout]),
            group("L8", "fc", vec![Op::Dense { name: "fc", out: 20 }]),
        ],
    }
}

fn nin() -> Arch {
    Arch {
        name: "nin",
        dataset: "synimagenet",
        input_shape: (32, 32, 3),
        num_classes: 20,
        groups: vec![
            group("L1", "conv", vec![conv("conv", 32, 5), Op::ReLU]),
            group("L2", "conv", vec![conv("cccp", 24, 1), Op::ReLU]),
            group(
                "L3",
                "conv",
                vec![conv("cccp", 16, 1), Op::ReLU, Op::MaxPool { k: 3, stride: 2 }],
            ),
            group("L4", "conv", vec![conv("conv", 48, 5), Op::ReLU]),
            group("L5", "conv", vec![conv("cccp", 32, 1), Op::ReLU]),
            group(
                "L6",
                "conv",
                vec![conv("cccp", 32, 1), Op::ReLU, Op::MaxPool { k: 3, stride: 2 }],
            ),
            group("L7", "conv", vec![conv("conv", 48, 3), Op::ReLU]),
            group("L8", "conv", vec![conv("cccp", 48, 1), Op::ReLU]),
            group(
                "L9",
                "conv",
                vec![conv("cccp", 32, 1), Op::ReLU, Op::MaxPool { k: 3, stride: 2 }, Op::Dropout],
            ),
            group("L10", "conv", vec![conv("conv", 64, 3), Op::ReLU]),
            group("L11", "conv", vec![conv("cccp", 48, 1), Op::ReLU]),
            group("L12", "conv", vec![conv("cccp", 20, 1), Op::ReLU, Op::GlobalAvgPool]),
        ],
    }
}

fn inception(
    name: &'static str,
    b1: usize,
    b3r: usize,
    b3: usize,
    b5r: usize,
    b5: usize,
    pp: usize,
) -> Op {
    Op::Inception { name, b1, b3r, b3, b5r, b5, pp }
}

fn googlenet() -> Arch {
    Arch {
        name: "googlenet",
        dataset: "synimagenet",
        input_shape: (32, 32, 3),
        num_classes: 20,
        groups: vec![
            group(
                "L1",
                "conv",
                vec![conv("conv", 16, 3), Op::ReLU, Op::MaxPool { k: 3, stride: 2 }],
            ),
            group(
                "L2",
                "conv",
                vec![conv("conv", 32, 3), Op::ReLU, Op::MaxPool { k: 3, stride: 2 }],
            ),
            group("L3", "inception", vec![inception("i3a", 8, 8, 16, 4, 8, 8)]),
            group(
                "L4",
                "inception",
                vec![inception("i3b", 16, 16, 24, 4, 8, 8), Op::MaxPool { k: 3, stride: 2 }],
            ),
            group("L5", "inception", vec![inception("i4a", 16, 12, 24, 4, 8, 8)]),
            group("L6", "inception", vec![inception("i4b", 16, 12, 24, 4, 8, 8)]),
            group("L7", "inception", vec![inception("i4c", 16, 12, 24, 4, 8, 8)]),
            group("L8", "inception", vec![inception("i4d", 16, 12, 24, 4, 8, 8)]),
            group(
                "L9",
                "inception",
                vec![inception("i4e", 24, 16, 32, 6, 12, 12), Op::MaxPool { k: 3, stride: 2 }],
            ),
            group("L10", "inception", vec![inception("i5a", 24, 16, 32, 6, 12, 12)]),
            group(
                "L11",
                "inception",
                vec![
                    inception("i5b", 24, 16, 32, 6, 12, 12),
                    Op::GlobalAvgPool,
                    Op::Dense { name: "fc", out: 20 },
                ],
            ),
        ],
    }
}

/// Canonical net order (reports, manifests, reproduction).
pub const NET_ORDER: [&str; 5] = ["lenet", "convnet", "alexnet", "nin", "googlenet"];

/// Look up a network architecture by name.
pub fn get(name: &str) -> Option<Arch> {
    match name {
        "lenet" => Some(lenet()),
        "convnet" => Some(convnet()),
        "alexnet" => Some(alexnet()),
        "nin" => Some(nin()),
        "googlenet" => Some(googlenet()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_five_networks_resolve_and_walk() {
        for name in NET_ORDER {
            let arch = get(name).unwrap();
            let (walks, out) = shape_walk(&arch).unwrap();
            assert_eq!(out, Shape::Flat(arch.num_classes), "{name}");
            assert_eq!(walks.len(), arch.n_layers(), "{name}");
            // chain consistency, as the manifest validator demands
            assert_eq!(walks[0].in_elems as usize, arch.input_elems());
            for w in walks.windows(2) {
                assert_eq!(w[0].out_elems, w[1].in_elems, "{name}");
            }
            // parameter totals equal layer weight totals
            let specs = param_specs(&arch).unwrap();
            let p: u64 = specs.iter().map(|s| s.elems() as u64).sum();
            let l: u64 = walks.iter().map(|w| w.weight_elems).sum();
            assert_eq!(p, l, "{name}");
            assert!(p > 1000, "{name} too small: {p}");
            assert!(walks.iter().map(|w| w.macs).sum::<u64>() > 10_000, "{name}");
        }
    }

    #[test]
    fn paper_layer_structure() {
        let count = |name: &str, kind: &str| {
            get(name).unwrap().groups.iter().filter(|g| g.kind == kind).count()
        };
        assert_eq!((count("lenet", "conv"), count("lenet", "fc")), (2, 2));
        assert_eq!((count("convnet", "conv"), count("convnet", "fc")), (3, 2));
        assert_eq!((count("alexnet", "conv"), count("alexnet", "fc")), (5, 3));
        assert_eq!(count("nin", "conv"), 12);
        assert_eq!((count("googlenet", "conv"), count("googlenet", "inception")), (2, 9));
    }

    #[test]
    fn lenet_shapes_by_hand() {
        let arch = get("lenet").unwrap();
        let (walks, _) = shape_walk(&arch).unwrap();
        // 28x28x1 -> conv5 VALID -> 24x24x8 -> pool2 -> 12x12x8
        assert_eq!(walks[0].in_elems, 784);
        assert_eq!(walks[0].out_elems, 12 * 12 * 8);
        assert_eq!(walks[0].weight_elems, (5 * 5 * 8 + 8) as u64);
        // conv on 12x12x8 -> 8x8x16 -> pool -> 4x4x16
        assert_eq!(walks[1].out_elems, 4 * 4 * 16);
        assert_eq!(walks[2].out_elems, 64);
        assert_eq!(walks[3].out_elems, 10);
    }

    #[test]
    fn alexnet_stage_names_match_fig1() {
        let arch = get("alexnet").unwrap();
        let (walks, _) = shape_walk(&arch).unwrap();
        assert_eq!(walks[1].stages, vec!["conv", "relu", "pool", "norm"]);
    }

    #[test]
    fn same_padding_matches_xla() {
        // 32 -> stride 2, k 3: out 16, needed = 15*2+3-32 = 1, before = 0
        assert_eq!(conv_out_hw(32, 32, 3, 2, Padding::Same), (16, 16));
        assert_eq!(same_pad_before(32, 16, 3, 2), 0);
        // stride 1, k 5: out 32, needed 4, before 2
        assert_eq!(conv_out_hw(32, 32, 5, 1, Padding::Same), (32, 32));
        assert_eq!(same_pad_before(32, 32, 5, 1), 2);
        // VALID 28, k 5 -> 24
        assert_eq!(conv_out_hw(28, 28, 5, 1, Padding::Valid), (24, 24));
    }

    #[test]
    fn param_specs_order_and_names() {
        let arch = get("lenet").unwrap();
        let specs = param_specs(&arch).unwrap();
        let names: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(
            names,
            vec!["L1.conv.w", "L1.conv.b", "L2.conv.w", "L2.conv.b", "L3.fc.w", "L3.fc.b",
                 "L4.fc.w", "L4.fc.b"]
        );
        assert_eq!(specs[0].shape, vec![5, 5, 1, 8]);
        assert_eq!(specs[4].shape, vec![256, 64]);
        assert_eq!(specs[5].fan_in, 0);
    }

    #[test]
    fn inception_param_specs() {
        let arch = get("googlenet").unwrap();
        let specs = param_specs(&arch).unwrap();
        // L3 module: first conv group params come first (L1, L2), then 12
        // tensors for i3a.
        let i3a: Vec<&ParamSpec> =
            specs.iter().filter(|s| s.name.starts_with("L3.i3a")).collect();
        assert_eq!(i3a.len(), 12);
        assert_eq!(i3a[0].name, "L3.i3a.b1.w");
        assert_eq!(i3a[0].shape, vec![1, 1, 32, 8]);
        assert_eq!(i3a[4].name, "L3.i3a.b3.w");
        assert_eq!(i3a[4].shape, vec![3, 3, 8, 16]);
    }
}
