//! Minimal JSON parser + writer (substrate — `serde` is unavailable offline).
//!
//! Parses the python-emitted manifests (`artifacts/*.manifest.json`,
//! `index.json`) and writes report/experiment JSON. Supports the full JSON
//! grammar except exotic number forms beyond f64. Not performance-critical:
//! manifests are a few KB and parsed once per process.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` for deterministic ordering.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing paths.
    pub fn at(&self, path: &[&str]) -> &Json {
        static NULL: Json = Json::Null;
        let mut cur = self;
        for k in path {
            cur = cur.get(k).unwrap_or(&NULL);
        }
        cur
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ---- parse -----------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, text: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{text}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        // Surrogate pairs: accept and combine when present.
                        let ch = if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone surrogate"));
                            }
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                                low = low * 16
                                    + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                            }
                            0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                        } else {
                            code
                        };
                        out.push(char::from_u32(ch).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequences.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

// ---- writer ----------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write_json(self, f, None, 0)
    }
}

impl Json {
    /// Pretty-print with 1-space indent (matches python `json.dump(indent=1)`).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        use fmt::Write;
        struct W<'a>(&'a mut String);
        impl fmt::Write for W<'_> {
            fn write_str(&mut self, s: &str) -> fmt::Result {
                self.0.push_str(s);
                Ok(())
            }
        }
        let mut w = W(&mut s);
        let _ = write!(w, "{}", PrettyJson(self));
        s
    }
}

struct PrettyJson<'a>(&'a Json);

impl fmt::Display for PrettyJson<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write_json(self.0, f, Some(1), 0)
    }
}

fn write_str_escaped(s: &str, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

fn write_num(n: f64, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        write!(f, "{}", n as i64)
    } else {
        write!(f, "{n}")
    }
}

fn write_json(
    v: &Json,
    f: &mut fmt::Formatter<'_>,
    indent: Option<usize>,
    depth: usize,
) -> fmt::Result {
    let (nl, pad, pad_in) = match indent {
        Some(w) => ("\n", " ".repeat(w * depth), " ".repeat(w * (depth + 1))),
        None => ("", String::new(), String::new()),
    };
    match v {
        Json::Null => f.write_str("null"),
        Json::Bool(b) => write!(f, "{b}"),
        Json::Num(n) => write_num(*n, f),
        Json::Str(s) => write_str_escaped(s, f),
        Json::Arr(items) => {
            if items.is_empty() {
                return f.write_str("[]");
            }
            f.write_str("[")?;
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    f.write_str(",")?;
                }
                write!(f, "{nl}{pad_in}")?;
                write_json(item, f, indent, depth + 1)?;
            }
            write!(f, "{nl}{pad}]")
        }
        Json::Obj(map) => {
            if map.is_empty() {
                return f.write_str("{}");
            }
            f.write_str("{")?;
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    f.write_str(",")?;
                }
                write!(f, "{nl}{pad_in}")?;
                write_str_escaped(k, f)?;
                f.write_str(if indent.is_some() { ": " } else { ":" })?;
                write_json(val, f, indent, depth + 1)?;
            }
            write!(f, "{nl}{pad}}}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.at(&["a"]).as_arr().unwrap().len(), 3);
        assert!(j.at(&["a"]).as_arr().unwrap()[2].get("b").unwrap().is_null());
        assert_eq!(j.at(&["c"]).as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let j = Json::parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"q\" é 😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let j = Json::parse("\"héllo — ok\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"nets":[{"name":"lenet","top1":0.9904}],"batch":64,"ok":true}"#;
        let j = Json::parse(src).unwrap();
        let compact = j.to_string();
        let j2 = Json::parse(&compact).unwrap();
        assert_eq!(j, j2);
        let pretty = j.pretty();
        let j3 = Json::parse(&pretty).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn integers_render_without_fraction() {
        let j = Json::obj(vec![("n", Json::num(64.0))]);
        assert_eq!(j.to_string(), r#"{"n":64}"#);
    }

    #[test]
    fn at_missing_path_is_null() {
        let j = Json::parse(r#"{"a":{"b":1}}"#).unwrap();
        assert!(j.at(&["a", "zzz", "deep"]).is_null());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::Arr(vec![]).to_string(), "[]");
    }
}
