//! Shared utilities: logger, timers, human formatting, fs helpers.

pub mod json;
pub mod sha256;

use std::io::Write;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

// ---- logging ----------------------------------------------------------------

/// Minimal stderr logger for the `log` facade (env_logger is unavailable
/// offline). Level from `QBOUND_LOG` (error|warn|info|debug|trace; default
/// info).
struct StderrLogger;

static LOGGER: StderrLogger = StderrLogger;
static LOGGER_INIT: AtomicBool = AtomicBool::new(false);

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{:<5} {}] {}",
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent). Called by the CLI and test setups.
pub fn init_logging() {
    if LOGGER_INIT.swap(true, Ordering::SeqCst) {
        return;
    }
    let level = match std::env::var("QBOUND_LOG").as_deref() {
        Ok("error") => log::LevelFilter::Error,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        _ => log::LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

// ---- timing -------------------------------------------------------------------

/// Simple stopwatch for coarse phase timing.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    pub fn lap(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

// ---- human formatting -----------------------------------------------------------

/// "1.23 M", "456.7 k", "12" — engineering notation for counts.
pub fn human_count(n: f64) -> String {
    let a = n.abs();
    if a >= 1e9 {
        format!("{:.2} G", n / 1e9)
    } else if a >= 1e6 {
        format!("{:.2} M", n / 1e6)
    } else if a >= 1e3 {
        format!("{:.1} k", n / 1e3)
    } else {
        format!("{n:.0}")
    }
}

/// "3.21 MiB" style byte counts.
pub fn human_bytes(n: f64) -> String {
    let a = n.abs();
    if a >= (1u64 << 30) as f64 {
        format!("{:.2} GiB", n / (1u64 << 30) as f64)
    } else if a >= (1u64 << 20) as f64 {
        format!("{:.2} MiB", n / (1u64 << 20) as f64)
    } else if a >= 1024.0 {
        format!("{:.1} KiB", n / 1024.0)
    } else {
        format!("{n:.0} B")
    }
}

/// "1.23 s", "45.6 ms", "789 µs".
pub fn human_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.1} ms", s * 1e3)
    } else {
        format!("{:.0} µs", s * 1e6)
    }
}

// ---- process metrics ---------------------------------------------------------

/// Reset the kernel's peak-RSS watermark (`VmHWM`) to the current RSS
/// by writing `5` to `/proc/self/clear_refs` (Linux ≥ 4.0). Returns
/// whether the reset took, so callers can label a subsequent
/// [`peak_rss_bytes`] reading as scoped-from-here vs process-lifetime.
/// Without the reset, `VmHWM` includes everything the process did
/// before the region of interest (e.g. a baseline evaluation) and a
/// regression in the region can be invisible.
pub fn reset_peak_rss() -> bool {
    std::fs::write("/proc/self/clear_refs", "5\n").is_ok()
}

/// Peak resident-set size of this process in bytes (`VmHWM` from
/// `/proc/self/status`, since the last [`reset_peak_rss`] if any);
/// `None` on platforms without procfs. CI archives this next to the
/// modeled footprint so regressions in the measured memory bound are
/// visible per commit. Coarse by nature (page granularity, allocator
/// retention) — the precise measurement is `testkit::MeterAlloc` in
/// `tests/integration_memory.rs`; this is the in-production tripwire.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

// ---- fs helpers ----------------------------------------------------------------

/// Read a file to string with a path-annotated error.
pub fn read_to_string(path: &std::path::Path) -> anyhow::Result<String> {
    std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))
}

/// Write a file, creating parent directories.
pub fn write_file(path: &std::path::Path, contents: &[u8]) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, contents).map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
}

/// Locate the artifacts directory: $QBOUND_ARTIFACTS, ./artifacts (or
/// walking up from the current directory, so tests/examples work from
/// any cwd inside the repo), or the per-user synthetic-artifact cache
/// populated by `testkit::ensure_artifacts`.
pub fn artifacts_dir() -> anyhow::Result<std::path::PathBuf> {
    if let Ok(p) = std::env::var("QBOUND_ARTIFACTS") {
        let p = std::path::PathBuf::from(p);
        if p.join("index.json").exists() {
            return Ok(p);
        }
        anyhow::bail!("QBOUND_ARTIFACTS={} has no index.json", p.display());
    }
    let mut dir = std::env::current_dir()?;
    loop {
        let cand = dir.join("artifacts");
        if cand.join("index.json").exists() {
            return Ok(cand);
        }
        if !dir.pop() {
            break;
        }
    }
    let cache = crate::artifacts::default_cache_dir();
    if cache.join("index.json").exists() {
        return Ok(cache);
    }
    anyhow::bail!(
        "artifacts/index.json not found — run `qbound gen-artifacts` or `make artifacts` \
         (or set QBOUND_ARTIFACTS)"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_count_ranges() {
        assert_eq!(human_count(12.0), "12");
        assert_eq!(human_count(1536.0), "1.5 k");
        assert_eq!(human_count(2_300_000.0), "2.30 M");
        assert_eq!(human_count(5.1e9), "5.10 G");
    }

    #[test]
    fn human_bytes_ranges() {
        assert_eq!(human_bytes(512.0), "512 B");
        assert_eq!(human_bytes(2048.0), "2.0 KiB");
        assert_eq!(human_bytes(3.0 * 1048576.0), "3.00 MiB");
    }

    #[test]
    fn human_duration_ranges() {
        assert_eq!(human_duration(Duration::from_secs(2)), "2.00 s");
        assert_eq!(human_duration(Duration::from_millis(12)), "12.0 ms");
        assert_eq!(human_duration(Duration::from_micros(45)), "45 µs");
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn peak_rss_reads_procfs() {
        let peak = peak_rss_bytes().expect("VmHWM on linux");
        assert!(peak > 1024 * 1024, "implausible peak RSS {peak}");
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.elapsed_ms() >= 4.0);
    }

    #[test]
    fn logging_init_idempotent() {
        init_logging();
        init_logging();
        log::info!("logger smoke");
    }
}
