//! Batched top-1 evaluation with config-keyed memoization.
//!
//! An [`Evaluator`] owns a loaded [`NetExecutor`] plus the network's eval
//! dataset and answers "what is top-1 accuracy under precision config
//! C?" — the single query every experiment in the paper is built from.
//! Results are memoized by (config, n_images): sweeps and the greedy
//! search revisit configurations constantly (the fp32 baseline alone is
//! consulted once per tolerance level), and a cache hit must cost ~ns,
//! not a forward pass.
//!
//! The evaluator is backend-agnostic: it drives whatever
//! [`crate::backend::Backend`] loaded the network. Batches are replayed
//! through [`NetExecutor::infer_keyed`] so backends with expensive
//! host→device transfers (PJRT) can keep them resident.
//!
//! Under packed storage ([`StorageMode::Packed`]) the evaluator spills
//! the whole eval split to a [`PackedSplit`] bitstream at the config's
//! input format `dq[0]` and serves every batch from it — the input set
//! of the serve path is read from packed storage end-to-end, not just
//! the inter-layer activations. Accuracies are unchanged: packing at
//! `dq[0]` is exactly the quantization the executor applies to its
//! input, and quantization is idempotent on its own grid (locked by
//! `tests/integration_storage.rs`). The evaluator keeps the f32 master
//! alongside the bitstream because sweeps re-pack whenever `dq[0]`
//! changes (packing is lossy, so codes must come from the original
//! values); a fixed-format serve deployment that wants the master gone
//! uses [`Dataset::into_packed`] instead.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::backend::{Backend, NetExecutor, Variant};
use crate::memory::{PackedBuf, StorageMode};
use crate::nets::NetManifest;
use crate::quant::QFormat;
use crate::search::space::PrecisionConfig;
use crate::tensor::ntf;

/// The eval split shipped in `<net>.dataset.ntf`.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub image_elems: usize,
    pub n: usize,
}

impl Dataset {
    pub fn load(manifest: &NetManifest) -> Result<Dataset> {
        let mut m = ntf::read_file(&manifest.dataset_path())?;
        let images =
            m.remove("images").ok_or_else(|| anyhow::anyhow!("dataset missing images"))?;
        let labels =
            m.remove("labels").ok_or_else(|| anyhow::anyhow!("dataset missing labels"))?;
        let n = images.dims[0];
        let image_elems: usize = images.dims[1..].iter().product();
        let want: usize = manifest.input_shape.iter().product();
        if image_elems != want {
            bail!("dataset image elems {image_elems} != manifest {want}");
        }
        if labels.dims != vec![n] {
            bail!("labels shape {:?} != [{n}]", labels.dims);
        }
        Ok(Dataset {
            images: images.as_f32()?.to_vec(),
            labels: labels.as_i32()?.to_vec(),
            image_elems,
            n,
        })
    }

    /// Borrow the image block for batch `b` of size `batch`.
    pub fn batch_images(&self, b: usize, batch: usize) -> &[f32] {
        let start = b * batch * self.image_elems;
        &self.images[start..start + batch * self.image_elems]
    }

    pub fn batch_labels(&self, b: usize, batch: usize) -> &[i32] {
        &self.labels[b * batch..(b + 1) * batch]
    }

    /// Spill this split to packed storage at `fmt`, dropping the f32
    /// image block — the bounded-memory serve configuration. Returns
    /// the bitstream plus the (untouched) labels.
    pub fn into_packed(self, fmt: QFormat) -> (PackedSplit, Vec<i32>) {
        let split = PackedSplit::pack(&self, fmt);
        (split, self.labels)
    }
}

/// A whole eval split as a packed bitstream at one input format — the
/// ROADMAP "spill whole eval splits" item. Packing quantizes at `fmt`,
/// which is exactly what the executors do to the network input at
/// `dq[0]`, so serving batches from the bitstream leaves every
/// accuracy unchanged.
pub struct PackedSplit {
    buf: PackedBuf,
    fmt: QFormat,
    image_elems: usize,
    n: usize,
}

impl PackedSplit {
    /// Pack all of `d`'s images at `fmt`.
    pub fn pack(d: &Dataset, fmt: QFormat) -> PackedSplit {
        PackedSplit {
            buf: PackedBuf::pack(fmt, &d.images),
            fmt,
            image_elems: d.image_elems,
            n: d.n,
        }
    }

    pub fn fmt(&self) -> QFormat {
        self.fmt
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Physical bitstream bytes of the whole split.
    pub fn packed_bytes(&self) -> usize {
        self.buf.packed_bytes()
    }

    /// Decode the image block for batch `b` of size `batch` into `out`
    /// (resized to fit).
    pub fn unpack_batch(&self, b: usize, batch: usize, out: &mut Vec<f32>) {
        out.resize(batch * self.image_elems, 0.0);
        self.buf.unpack_rows(self.fmt, self.image_elems, b * batch, out);
    }
}

/// Top-1 accuracy: fraction of rows whose argmax equals the label.
pub fn top1(logits: &[f32], labels: &[i32], classes: usize) -> f64 {
    assert_eq!(logits.len(), labels.len() * classes);
    let mut correct = 0usize;
    for (row, &label) in labels.iter().enumerate() {
        let r = &logits[row * classes..(row + 1) * classes];
        let mut best = 0usize;
        for (i, v) in r.iter().enumerate() {
            if *v > r[best] {
                best = i;
            }
        }
        if best as i32 == label {
            correct += 1;
        }
    }
    correct as f64 / labels.len() as f64
}

/// Accuracy evaluator for one network on one thread.
pub struct Evaluator {
    pub exec: Box<dyn NetExecutor>,
    pub dataset: Dataset,
    /// Images per `infer_keyed` call; `0` = auto (the largest batch the
    /// executor allows — the whole requested span for the pure-Rust
    /// backends, so their image-level parallelism has work to spread).
    pub batch_override: usize,
    /// Inter-layer storage mode of the driven backend; under
    /// [`StorageMode::Packed`] batches are served from a [`PackedSplit`]
    /// bitstream packed at the config's `dq[0]`.
    storage: StorageMode,
    packed_split: Option<PackedSplit>,
    /// Reusable decode buffer for packed-served batches.
    batch_buf: Vec<f32>,
    cache: HashMap<(PrecisionConfig, usize), f64>,
    /// Counters for cache instrumentation.
    pub hits: u64,
    pub misses: u64,
}

impl Evaluator {
    /// Evaluator with the storage mode taken from `QBOUND_STORAGE` —
    /// the same resolution the pure-Rust backends apply, so coordinator
    /// workers built after [`StorageMode::set_env`] serve packed inputs
    /// whenever their executors store packed activations.
    pub fn new(backend: &dyn Backend, manifest: &NetManifest) -> Result<Evaluator> {
        Evaluator::with_storage(backend, manifest, StorageMode::from_env()?)
    }

    /// [`Evaluator::new`] with an explicit storage mode.
    pub fn with_storage(
        backend: &dyn Backend,
        manifest: &NetManifest,
        storage: StorageMode,
    ) -> Result<Evaluator> {
        let exec = backend.load(manifest, Variant::Standard)?;
        let dataset = Dataset::load(manifest)?;
        Ok(Evaluator {
            exec,
            dataset,
            batch_override: 0,
            storage,
            packed_split: None,
            batch_buf: Vec::new(),
            cache: HashMap::new(),
            hits: 0,
            misses: 0,
        })
    }

    /// Number of images available.
    pub fn n_images(&self) -> usize {
        self.dataset.n
    }

    /// Top-1 accuracy of `cfg` over the first `n_images` (rounded down to
    /// whole batches; `0` means the full eval set). Memoized by
    /// (config, images actually evaluated) — batch size only shapes the
    /// calls, never the result, since every image is scored
    /// independently.
    pub fn accuracy(&mut self, cfg: &PrecisionConfig, n_images: usize) -> Result<f64> {
        let n = if n_images == 0 { self.dataset.n } else { n_images.min(self.dataset.n) };
        // Variable-batch executors (max_batch > compiled batch) take any
        // span down to one image; compiled-batch backends need at least
        // one full batch.
        let min_batch =
            if self.exec.max_batch() > self.exec.batch() { 1 } else { self.exec.batch() };
        if n < min_batch {
            bail!("n_images {n} < batch {min_batch}");
        }
        // An override is clamped into the executor's supported range in
        // both directions (a compiled-batch backend pins it to its one
        // legal batch rather than failing mid-eval).
        let batch = match self.batch_override {
            0 => n.min(self.exec.max_batch()),
            b => b.clamp(min_batch, self.exec.max_batch()).min(n),
        };
        let n_batches = n / batch;
        let n_used = n_batches * batch;
        let key = (cfg.clone(), n_used);
        if let Some(&acc) = self.cache.get(&key) {
            self.hits += 1;
            return Ok(acc);
        }
        self.misses += 1;
        let wq = cfg.wire_wq();
        let dq = cfg.wire_dq();
        let classes = self.exec.num_classes();
        // Packed input serving: variable-batch executors only (the
        // compiled-batch PJRT path keys device-resident image uploads by
        // batch id, and re-keying config-dependent quantized images
        // would go stale across configs; it ignores storage modes
        // anyway, with a one-time warning), and only for genuinely
        // quantized input formats — an fp32 `dq[0]` would spill a
        // byte-for-byte duplicate of the split at the 32-bit fallback
        // for zero benefit (the fp32 baseline eval hits this).
        // Re-packing on a `dq[0]` change costs one pass over the split,
        // noise next to the forward passes the config evaluation runs.
        let serve_packed = self.storage == StorageMode::Packed
            && self.exec.max_batch() > self.exec.batch()
            && !cfg.dq[0].is_fp32();
        if serve_packed && self.packed_split.as_ref().map(|p| p.fmt()) != Some(cfg.dq[0]) {
            self.packed_split = Some(PackedSplit::pack(&self.dataset, cfg.dq[0]));
        }
        let mut correct = 0.0f64;
        for b in 0..n_batches {
            let logits = if serve_packed {
                self.packed_split.as_ref().unwrap().unpack_batch(b, batch, &mut self.batch_buf);
                self.exec.infer_keyed(b, &self.batch_buf, &wq, &dq, None)?
            } else {
                self.exec.infer_keyed(b, self.dataset.batch_images(b, batch), &wq, &dq, None)?
            };
            correct +=
                top1(&logits, self.dataset.batch_labels(b, batch), classes) * batch as f64;
        }
        let acc = correct / n_used as f64;
        self.cache.insert(key, acc);
        Ok(acc)
    }

    /// Relative accuracy loss vs the fp32 baseline (paper's "error"):
    /// `(base - acc) / base`.
    pub fn relative_error(&mut self, cfg: &PrecisionConfig, n_images: usize) -> Result<f64> {
        let base = self.accuracy(&PrecisionConfig::fp32(cfg.n_layers()), n_images)?;
        let acc = self.accuracy(cfg, n_images)?;
        Ok((base - acc) / base)
    }

    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top1_basic() {
        // 3 rows, 4 classes
        let logits = vec![
            0.1, 0.9, 0.0, 0.0, // -> 1
            5.0, 1.0, 2.0, 3.0, // -> 0
            0.0, 0.0, 1.0, 2.0, // -> 3
        ];
        let acc = top1(&logits, &[1, 0, 2], 4);
        assert!((acc - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn top1_ties_take_first() {
        let logits = vec![1.0, 1.0, 1.0];
        assert_eq!(top1(&logits, &[0], 3), 1.0);
        assert_eq!(top1(&logits, &[1], 3), 0.0);
    }

    #[test]
    fn top1_perfect_and_zero() {
        let logits = vec![1.0, 0.0, 0.0, 1.0]; // rows -> 0, 1
        assert_eq!(top1(&logits, &[0, 1], 2), 1.0);
        assert_eq!(top1(&logits, &[1, 0], 2), 0.0);
    }

    #[test]
    fn packed_split_serves_quantized_batches() {
        let fmt = QFormat::new(4, 2); // 6-bit codes
        let d = Dataset {
            images: (0..24).map(|i| i as f32 * 0.3 - 3.0).collect(),
            labels: vec![0, 1, 2, 0],
            image_elems: 6,
            n: 4,
        };
        let split = PackedSplit::pack(&d, fmt);
        assert_eq!(split.n(), 4);
        assert_eq!(split.fmt(), fmt);
        assert_eq!(split.packed_bytes(), (24 * 6 + 7) / 8);
        // Batches decode to exactly the quantized (zero-canonicalized)
        // images — what the executor derives from raw inputs anyway.
        let want = crate::testkit::quantized_canonical(fmt, &d.images);
        let mut out = Vec::new();
        split.unpack_batch(1, 2, &mut out);
        assert_eq!(out, want[12..24]);
        // Spilling consumes the f32 master and keeps the labels.
        let (split2, labels) = d.into_packed(fmt);
        assert_eq!(labels, vec![0, 1, 2, 0]);
        let mut all = Vec::new();
        split2.unpack_batch(0, 4, &mut all);
        assert_eq!(all, want);
    }
}
