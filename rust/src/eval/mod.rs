//! Batched top-1 evaluation with config-keyed memoization.
//!
//! An [`Evaluator`] owns a loaded [`NetExecutor`] plus the network's eval
//! dataset and answers "what is top-1 accuracy under precision config
//! C?" — the single query every experiment in the paper is built from.
//! Results are memoized by (config, n_images): sweeps and the greedy
//! search revisit configurations constantly (the fp32 baseline alone is
//! consulted once per tolerance level), and a cache hit must cost ~ns,
//! not a forward pass.
//!
//! The evaluator is backend-agnostic: it drives whatever
//! [`crate::backend::Backend`] loaded the network. Batches are replayed
//! through [`NetExecutor::infer_keyed`] so backends with expensive
//! host→device transfers (PJRT) can keep them resident.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::backend::{Backend, NetExecutor, Variant};
use crate::nets::NetManifest;
use crate::search::space::PrecisionConfig;
use crate::tensor::ntf;

/// The eval split shipped in `<net>.dataset.ntf`.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub image_elems: usize,
    pub n: usize,
}

impl Dataset {
    pub fn load(manifest: &NetManifest) -> Result<Dataset> {
        let mut m = ntf::read_file(&manifest.dataset_path())?;
        let images =
            m.remove("images").ok_or_else(|| anyhow::anyhow!("dataset missing images"))?;
        let labels =
            m.remove("labels").ok_or_else(|| anyhow::anyhow!("dataset missing labels"))?;
        let n = images.dims[0];
        let image_elems: usize = images.dims[1..].iter().product();
        let want: usize = manifest.input_shape.iter().product();
        if image_elems != want {
            bail!("dataset image elems {image_elems} != manifest {want}");
        }
        if labels.dims != vec![n] {
            bail!("labels shape {:?} != [{n}]", labels.dims);
        }
        Ok(Dataset {
            images: images.as_f32()?.to_vec(),
            labels: labels.as_i32()?.to_vec(),
            image_elems,
            n,
        })
    }

    /// Borrow the image block for batch `b` of size `batch`.
    pub fn batch_images(&self, b: usize, batch: usize) -> &[f32] {
        let start = b * batch * self.image_elems;
        &self.images[start..start + batch * self.image_elems]
    }

    pub fn batch_labels(&self, b: usize, batch: usize) -> &[i32] {
        &self.labels[b * batch..(b + 1) * batch]
    }
}

/// Top-1 accuracy: fraction of rows whose argmax equals the label.
pub fn top1(logits: &[f32], labels: &[i32], classes: usize) -> f64 {
    assert_eq!(logits.len(), labels.len() * classes);
    let mut correct = 0usize;
    for (row, &label) in labels.iter().enumerate() {
        let r = &logits[row * classes..(row + 1) * classes];
        let mut best = 0usize;
        for (i, v) in r.iter().enumerate() {
            if *v > r[best] {
                best = i;
            }
        }
        if best as i32 == label {
            correct += 1;
        }
    }
    correct as f64 / labels.len() as f64
}

/// Accuracy evaluator for one network on one thread.
pub struct Evaluator {
    pub exec: Box<dyn NetExecutor>,
    pub dataset: Dataset,
    /// Images per `infer_keyed` call; `0` = auto (the largest batch the
    /// executor allows — the whole requested span for the pure-Rust
    /// backends, so their image-level parallelism has work to spread).
    pub batch_override: usize,
    cache: HashMap<(PrecisionConfig, usize), f64>,
    /// Counters for cache instrumentation.
    pub hits: u64,
    pub misses: u64,
}

impl Evaluator {
    pub fn new(backend: &dyn Backend, manifest: &NetManifest) -> Result<Evaluator> {
        let exec = backend.load(manifest, Variant::Standard)?;
        let dataset = Dataset::load(manifest)?;
        Ok(Evaluator {
            exec,
            dataset,
            batch_override: 0,
            cache: HashMap::new(),
            hits: 0,
            misses: 0,
        })
    }

    /// Number of images available.
    pub fn n_images(&self) -> usize {
        self.dataset.n
    }

    /// Top-1 accuracy of `cfg` over the first `n_images` (rounded down to
    /// whole batches; `0` means the full eval set). Memoized by
    /// (config, images actually evaluated) — batch size only shapes the
    /// calls, never the result, since every image is scored
    /// independently.
    pub fn accuracy(&mut self, cfg: &PrecisionConfig, n_images: usize) -> Result<f64> {
        let n = if n_images == 0 { self.dataset.n } else { n_images.min(self.dataset.n) };
        // Variable-batch executors (max_batch > compiled batch) take any
        // span down to one image; compiled-batch backends need at least
        // one full batch.
        let min_batch =
            if self.exec.max_batch() > self.exec.batch() { 1 } else { self.exec.batch() };
        if n < min_batch {
            bail!("n_images {n} < batch {min_batch}");
        }
        // An override is clamped into the executor's supported range in
        // both directions (a compiled-batch backend pins it to its one
        // legal batch rather than failing mid-eval).
        let batch = match self.batch_override {
            0 => n.min(self.exec.max_batch()),
            b => b.clamp(min_batch, self.exec.max_batch()).min(n),
        };
        let n_batches = n / batch;
        let n_used = n_batches * batch;
        let key = (cfg.clone(), n_used);
        if let Some(&acc) = self.cache.get(&key) {
            self.hits += 1;
            return Ok(acc);
        }
        self.misses += 1;
        let wq = cfg.wire_wq();
        let dq = cfg.wire_dq();
        let classes = self.exec.num_classes();
        let mut correct = 0.0f64;
        for b in 0..n_batches {
            let logits =
                self.exec.infer_keyed(b, self.dataset.batch_images(b, batch), &wq, &dq, None)?;
            correct +=
                top1(&logits, self.dataset.batch_labels(b, batch), classes) * batch as f64;
        }
        let acc = correct / n_used as f64;
        self.cache.insert(key, acc);
        Ok(acc)
    }

    /// Relative accuracy loss vs the fp32 baseline (paper's "error"):
    /// `(base - acc) / base`.
    pub fn relative_error(&mut self, cfg: &PrecisionConfig, n_images: usize) -> Result<f64> {
        let base = self.accuracy(&PrecisionConfig::fp32(cfg.n_layers()), n_images)?;
        let acc = self.accuracy(cfg, n_images)?;
        Ok((base - acc) / base)
    }

    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top1_basic() {
        // 3 rows, 4 classes
        let logits = vec![
            0.1, 0.9, 0.0, 0.0, // -> 1
            5.0, 1.0, 2.0, 3.0, // -> 0
            0.0, 0.0, 1.0, 2.0, // -> 3
        ];
        let acc = top1(&logits, &[1, 0, 2], 4);
        assert!((acc - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn top1_ties_take_first() {
        let logits = vec![1.0, 1.0, 1.0];
        assert_eq!(top1(&logits, &[0], 3), 1.0);
        assert_eq!(top1(&logits, &[1], 3), 0.0);
    }

    #[test]
    fn top1_perfect_and_zero() {
        let logits = vec![1.0, 0.0, 0.0, 1.0]; // rows -> 0, 1
        assert_eq!(top1(&logits, &[0, 1], 2), 1.0);
        assert_eq!(top1(&logits, &[1, 0], 2), 0.0);
    }
}
