//! Packed two's-complement storage for Q(I.F) tensors.
//!
//! [`PackedBuf`] stores a quantized tensor as a contiguous bitstream at
//! the format's *representation width* — `N = I + F` bits per value,
//! two's complement — instead of one f32 per value. This is the piece
//! the paper's "bounded memory" claim rests on: a layer's activations
//! only cost `N` bits each if `N` bits suffices to carry them between
//! layers (Hashemi et al., arXiv:1612.03940, make the same point for
//! energy). Under `--storage packed` the CPU executors keep *only*
//! these bitstreams between layers: consumers decode what they need on
//! the fly through the streaming window reader
//! ([`PackedBuf::unpack_rows`] / [`PackedCursor`]) instead of unpacking
//! into a resident f32 arena, so the reduced width is what actually
//! lives in memory (`tests/integration_memory.rs` measures it under a
//! counting allocator).
//!
//! Semantics contract (locked by `tests/property_packed.rs`):
//! `unpack(pack(x))` is bit-identical to [`QFormat::quantize_slice`]
//! output for every format, *up to zero-sign canonicalization* — two's
//! complement has a single zero, so a quantized `-0.0` is stored and
//! recovered as `+0.0` (numerically equal; the parity suite shows the
//! forward pass cannot distinguish them).
//!
//! Layout: values are packed LSB-first into little-endian `u64` words;
//! a value may straddle a word boundary. Widths:
//!
//! * `1..=24` — the fixed-point bitstream path. The pack kernel is a
//!   single hoisted pass (scale/clamp factors lifted out of the loop,
//!   no per-value format dispatch); codes are
//!   `round_ties_even(clamp(x·2^F))`, exactly the quantizer's grid.
//! * `32` — the word-aligned fallback: the fp32 sentinel and any
//!   format wider than 24 bits store raw quantized f32 bits (wider
//!   codes would not round-trip through f32's 24-bit mantissa anyway).
//!
//! Non-finite inputs follow the quantizer (±∞ saturates); NaN has no
//! fixed-point encoding and packs to code 0.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::quant::QFormat;

/// Read-only word storage a [`PackedBuf`] can borrow instead of own —
/// e.g. one mmap'd packed-weight store file ([`crate::store`]) serving
/// every executor that holds the same tensor. Implementations promise
/// the words are immutable for the backing's lifetime.
pub trait WordBacking: Send + Sync + std::fmt::Debug {
    /// The backing's `u64` words (little-endian bitstream words, same
    /// layout as an owned [`PackedBuf`]).
    fn words(&self) -> &[u64];
}

/// The storage behind a [`PackedBuf`]: its own words, or a window into
/// a shared read-only backing. Decode paths are identical either way —
/// both resolve to `&[u64]` before any bit is touched.
#[derive(Clone, Debug)]
enum Words {
    Owned(Vec<u64>),
    Shared {
        backing: Arc<dyn WordBacking>,
        /// Word offset of this buffer's window inside the backing.
        off: usize,
        /// Window length in words.
        n_words: usize,
    },
}

impl Default for Words {
    fn default() -> Self {
        Words::Owned(Vec::new())
    }
}

/// Widest fixed-point bitstream width; wider formats (and fp32) take
/// the 32-bit word-aligned fallback.
pub const MAX_PACK_BITS: u32 = 24;

/// Physical storage width of `fmt` inside a [`PackedBuf`]: `I + F` for
/// packable fixed-point formats, 32 for fp32 and anything wider than
/// [`MAX_PACK_BITS`].
pub fn storage_width(fmt: QFormat) -> u32 {
    let bits = fmt.bits();
    if fmt.is_fp32() || bits > MAX_PACK_BITS {
        32
    } else {
        bits
    }
}

/// A tensor stored as a packed bitstream of fixed-point codes.
///
/// Reusable: [`PackedBuf::pack_into`] re-sizes in place, so executors
/// keep one buffer per scratch arena and the steady state allocates
/// nothing.
///
/// # Examples
///
/// Pack a tensor at Q4.2 (6 bits per value) and decode it back; the
/// decode is bit-identical to [`QFormat::quantize_slice`] up to the
/// single two's-complement zero:
///
/// ```
/// use qbound::memory::PackedBuf;
/// use qbound::quant::QFormat;
///
/// let fmt = QFormat::new(4, 2);
/// let xs = [0.3f32, -1.26, 7.9, -8.0];
/// let buf = PackedBuf::pack(fmt, &xs);
/// assert_eq!(buf.len(), 4);
/// assert_eq!(buf.width(), 6);
/// assert_eq!(buf.packed_bytes(), (4 * 6 + 7) / 8); // 3 bytes, not 16
///
/// let mut out = [0f32; 4];
/// buf.unpack_into(fmt, &mut out);
/// assert_eq!(out, [0.25, -1.25, 7.75, -8.0]);
/// ```
#[derive(Clone, Debug, Default)]
pub struct PackedBuf {
    words: Words,
    len: usize,
    width: u32,
}

impl PackedBuf {
    /// Pack `xs` under `fmt` into a fresh buffer.
    pub fn pack(fmt: QFormat, xs: &[f32]) -> PackedBuf {
        let mut buf = PackedBuf::default();
        buf.pack_into(fmt, xs);
        buf
    }

    /// A buffer whose words live in a shared read-only backing (one
    /// mmap'd store file, typically): `n_words` words starting at word
    /// `off` of `backing` hold `len` values of `width` bits each.
    /// Decode behavior is identical to an owned buffer; cloning shares
    /// the backing (`Arc`) instead of copying words.
    pub fn from_shared(
        backing: Arc<dyn WordBacking>,
        off: usize,
        n_words: usize,
        len: usize,
        width: u32,
    ) -> PackedBuf {
        assert!(width >= 1 && width <= 64, "bad packed width {width}");
        assert_eq!(n_words, (len * width as usize + 63) / 64, "word count mismatch");
        assert!(
            off + n_words <= backing.words().len(),
            "shared window {off}+{n_words} outside backing of {} words",
            backing.words().len()
        );
        PackedBuf { words: Words::Shared { backing, off, n_words }, len, width }
    }

    /// Whether the words live in a shared backing rather than an owned
    /// vector (diagnostics / tests; decode semantics do not differ).
    pub fn is_shared(&self) -> bool {
        matches!(self.words, Words::Shared { .. })
    }

    /// The bitstream words, wherever they live.
    pub(crate) fn words(&self) -> &[u64] {
        match &self.words {
            Words::Owned(v) => v,
            Words::Shared { backing, off, n_words } => &backing.words()[*off..*off + *n_words],
        }
    }

    /// Mutable owned words for (re)packing. A shared buffer detaches to
    /// an empty owned vector first — packing never writes through a
    /// read-only backing.
    fn words_mut(&mut self) -> &mut Vec<u64> {
        if let Words::Shared { .. } = self.words {
            self.words = Words::Owned(Vec::new());
        }
        match &mut self.words {
            Words::Owned(v) => v,
            Words::Shared { .. } => unreachable!("detached above"),
        }
    }

    /// Number of stored values.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bits per stored value (the [`storage_width`] of the pack format).
    pub fn width(&self) -> u32 {
        self.width
    }

    /// Physical footprint of the payload, rounded up to whole bytes.
    pub fn packed_bytes(&self) -> usize {
        (self.len * self.width as usize + 7) / 8
    }

    /// Quantize `xs` with `fmt` and store the codes, replacing any
    /// previous contents. The capacity of the backing word vector is
    /// kept across calls.
    pub fn pack_into(&mut self, fmt: QFormat, xs: &[f32]) {
        let width = storage_width(fmt);
        self.width = width;
        self.len = xs.len();
        let n_words = (xs.len() * width as usize + 63) / 64;
        let words = self.words_mut();
        words.clear();
        // Exact reservation: Vec's amortized doubling would otherwise
        // leave up to 2× the needed capacity resident, which the
        // allocation-tracking memory tests would charge against the
        // packed envelope.
        if words.capacity() < n_words {
            words.reserve_exact(n_words);
        }
        words.resize(n_words, 0);

        if width == 32 {
            // Word-aligned fallback, two values per u64, LSB-first. The
            // fp32 sentinel is a raw-bit passthrough; wide fixed-point
            // formats store quantized bits with -0.0 canonicalized to
            // +0.0 (`+ 0.0`), keeping the zero-sign contract uniform
            // with the two's-complement bitstream path.
            if fmt.is_fp32() {
                for (i, &x) in xs.iter().enumerate() {
                    words[i / 2] |= (x.to_bits() as u64) << ((i % 2) * 32);
                }
            } else {
                for (i, &x) in xs.iter().enumerate() {
                    let bits = (fmt.quantize(x) + 0.0).to_bits() as u64;
                    words[i / 2] |= bits << ((i % 2) * 32);
                }
            }
            return;
        }

        // Fixed-point bitstream. Everything format-dependent is hoisted
        // out of the loop; the code is round_ties_even(clamp(x*2^F)) —
        // clamp-before-round equals round-before-clamp because the
        // bounds are exact grid integers (same argument as the
        // quantizer's fast path).
        let scale = (fmt.fbits as f32).exp2();
        let (lo, hi) = fmt.range();
        let (slo, shi) = (lo * scale, hi * scale);
        let mask = (1u64 << width) - 1;
        let mut bitpos = 0usize;
        for &x in xs {
            let code = (x * scale).clamp(slo, shi).round_ties_even() as i32;
            let bits = (code as u32 as u64) & mask;
            let (w, off) = (bitpos >> 6, (bitpos & 63) as u32);
            words[w] |= bits << off;
            if off + width > 64 {
                words[w + 1] |= bits >> (64 - off);
            }
            bitpos += width as usize;
        }
    }

    /// Decode the stored codes into `out`. `fmt` must be the format the
    /// buffer was packed with (same [`storage_width`]) and `out` must
    /// have exactly [`PackedBuf::len`] elements.
    pub fn unpack_into(&self, fmt: QFormat, out: &mut [f32]) {
        assert_eq!(out.len(), self.len, "unpack length mismatch");
        self.unpack_range_into(fmt, 0, out);
    }

    /// Streaming window decode: the `out.len()` values starting at
    /// element `start`. This is how fused consumers read a bitstream —
    /// one row window / GEMM A-panel block at a time — without ever
    /// materializing the whole tensor in f32. Windows may begin and end
    /// at any bit offset; values straddling `u64` word boundaries are
    /// handled exactly like the bulk path.
    pub fn unpack_range_into(&self, fmt: QFormat, start: usize, out: &mut [f32]) {
        assert!(start + out.len() <= self.len, "window out of range");
        assert_eq!(storage_width(fmt), self.width, "unpack format mismatch");

        // Every packed decode path in the tree funnels through here
        // (bulk, row window, cursor, panel strip), so this is the one
        // chokepoint where decode volume is metered. No-op (one relaxed
        // load) unless observability is enabled.
        crate::obs::count_decode_bits(out.len() as u64 * self.width as u64);

        let words = self.words();
        if self.width == 32 {
            for (i, o) in out.iter_mut().enumerate() {
                let j = start + i;
                *o = f32::from_bits((words[j / 2] >> ((j % 2) * 32)) as u32);
            }
            return;
        }

        // Sign-extend-and-scale through the dispatched span decoder
        // (SIMD when the host supports it, the scalar word-shift loop
        // otherwise — bit-identical either way; see `backend::kernels`).
        let inv = (-(fmt.fbits as f32)).exp2();
        crate::backend::kernels::unpack_span(words, start, self.width, inv, out);
    }

    /// Row-granular window decode for HWC tensors stored row-major:
    /// fills `out` with whole rows of `row_elems` values starting at row
    /// `row0`. `out.len()` must be a multiple of `row_elems`.
    pub fn unpack_rows(&self, fmt: QFormat, row_elems: usize, row0: usize, out: &mut [f32]) {
        assert!(row_elems > 0 && out.len() % row_elems == 0, "ragged row window");
        self.unpack_range_into(fmt, row0 * row_elems, out);
    }

    /// Decode one value (tests, debugging; the bulk path is
    /// [`PackedBuf::unpack_into`]).
    pub fn get(&self, fmt: QFormat, i: usize) -> f32 {
        assert!(i < self.len);
        assert_eq!(storage_width(fmt), self.width);
        let words = self.words();
        if self.width == 32 {
            return f32::from_bits((words[i / 2] >> ((i % 2) * 32)) as u32);
        }
        let bitpos = i * self.width as usize;
        let (w, off) = (bitpos >> 6, (bitpos & 63) as u32);
        let mut raw = words[w] >> off;
        if off + self.width > 64 {
            raw |= words[w + 1] << (64 - off);
        }
        let shift = 64 - self.width;
        let code = ((raw << shift) as i64) >> shift;
        code as f32 * (-(fmt.fbits as f32)).exp2()
    }

    /// Quantize `xs` through packed storage in place: pack, then unpack
    /// back into the same slice. A validation helper and bench kernel
    /// (`benches/bench_packed.rs` prices the encode+decode bandwidth per
    /// width with it); the executors themselves no longer round-trip —
    /// they keep the bitstream and decode windows on demand, see the
    /// fused paths in `backend/{fast,reference}.rs`.
    pub fn roundtrip(&mut self, fmt: QFormat, xs: &mut [f32]) {
        self.pack_into(fmt, xs);
        self.unpack_into(fmt, xs);
    }
}

/// A sequential reader over a [`PackedBuf`]: decodes successive windows
/// of the bitstream without tracking element offsets at the call site.
/// The GEMM A-panel read drives one of these — unpack a block of rows,
/// multiply, advance — so a layer's input never exists in f32 beyond
/// the current block.
pub struct PackedCursor<'a> {
    buf: &'a PackedBuf,
    fmt: QFormat,
    pos: usize,
}

impl<'a> PackedCursor<'a> {
    /// Cursor at element 0. `fmt` must match the buffer's pack format.
    pub fn new(buf: &'a PackedBuf, fmt: QFormat) -> PackedCursor<'a> {
        assert_eq!(storage_width(fmt), buf.width(), "cursor format mismatch");
        PackedCursor { buf, fmt, pos: 0 }
    }

    /// Elements not yet read.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Decode the next `out.len()` values and advance past them.
    pub fn read_into(&mut self, out: &mut [f32]) {
        self.buf.unpack_range_into(self.fmt, self.pos, out);
        self.pos += out.len();
    }
}

/// A GEMM `B` weight matrix, pre-strided into `nr`-lane column panels
/// (the `gemm::pack_b_panels` layout), stored as one packed bitstream —
/// the panel-aware reader of the packed-weight path.
///
/// Layout: panel `p` holds `kd` contiguous rows of `nr` lanes each, so
/// rows `[k0, k1)` of panel `p` are the contiguous element range
/// `[(p·kd + k0)·nr, (p·kd + k1)·nr)` of the bitstream. The packed-B
/// GEMM kernel decodes one such strip at a time into a small per-thread
/// f32 tile right before the multiply ([`PackedPanels::read_strip`]),
/// so no f32 copy of the weights ever exists beyond one tile per
/// thread. Packing carries the [`PackedBuf`] semantics contract: decode
/// returns exactly the quantized weights (modulo the single
/// two's-complement zero), so decoding before an unchanged ascending-k
/// accumulation is bit-identical to multiplying the quantized f32
/// panels directly.
///
/// The pack-time [`QFormat`] is stored inside the struct and every
/// decode uses it — a same-width wrong-format read (e.g. Q4.3 codes
/// rescaled as Q3.4) is structurally impossible, not merely asserted.
///
/// # Examples
///
/// ```
/// use qbound::memory::PackedPanels;
/// use qbound::quant::QFormat;
///
/// // Two panels of 3 rows x 4 lanes, packed at Q2.5 (7 bits/value).
/// let fmt = QFormat::new(2, 5);
/// let vals: Vec<f32> = (0..24).map(|i| i as f32 * 0.11 - 1.3).collect();
/// let pp = PackedPanels::pack(fmt, &vals, 3, 4);
/// assert_eq!((pp.fmt(), pp.n_panels(), pp.width()), (fmt, 2, 7));
///
/// // Decode rows 1..3 of panel 1: one GEMM tile strip.
/// let mut strip = [0f32; 2 * 4];
/// pp.read_strip(1, 1, 3, &mut strip);
/// assert_eq!(strip[0], fmt.quantize(vals[(3 + 1) * 4]));
/// ```
#[derive(Clone, Debug)]
pub struct PackedPanels {
    buf: PackedBuf,
    fmt: QFormat,
    kd: usize,
    nr: usize,
    n_panels: usize,
    id: u64,
}

/// Monotonic pack-time identity source for [`PackedPanels::id`].
static NEXT_PANELS_ID: AtomicU64 = AtomicU64::new(1);

impl PackedPanels {
    /// Pack a panelized matrix (`n_panels · kd · nr` values, ragged
    /// last panel already zero-padded) under `fmt`. The format is
    /// captured in the struct; [`PackedPanels::read_strip`] decodes
    /// with it.
    pub fn pack(fmt: QFormat, panels: &[f32], kd: usize, nr: usize) -> PackedPanels {
        assert!(kd > 0 && nr > 0, "degenerate panel shape {kd}x{nr}");
        assert!(panels.len() % (kd * nr) == 0, "ragged panel slice");
        PackedPanels {
            buf: PackedBuf::pack(fmt, panels),
            fmt,
            kd,
            nr,
            n_panels: panels.len() / (kd * nr),
            id: NEXT_PANELS_ID.fetch_add(1, Ordering::Relaxed),
        }
    }

    /// Rebuild panels around an existing bitstream — the store's load
    /// path ([`crate::store`]): `buf` typically borrows an mmap'd file
    /// via [`PackedBuf::from_shared`]. `id` carries the strip-cache
    /// identity; the store assigns one id per distinct store key so
    /// every executor sharing a mapping also shares cached strips.
    pub fn from_buf(buf: PackedBuf, fmt: QFormat, kd: usize, nr: usize, id: u64) -> PackedPanels {
        assert!(kd > 0 && nr > 0, "degenerate panel shape {kd}x{nr}");
        assert_eq!(storage_width(fmt), buf.width(), "panel format mismatch");
        assert!(buf.len() % (kd * nr) == 0, "ragged panel buffer");
        let n_panels = buf.len() / (kd * nr);
        PackedPanels { buf, fmt, kd, nr, n_panels, id }
    }

    /// Mint a fresh strip-cache identity from the same sequence pack()
    /// uses — callers building panels via [`PackedPanels::from_buf`]
    /// (the store) draw ids here so they never collide with packed ones.
    pub fn alloc_id() -> u64 {
        NEXT_PANELS_ID.fetch_add(1, Ordering::Relaxed)
    }

    /// Process-unique identity assigned at pack time — the decoded-strip
    /// cache key (`gemm::StripCache`). Clones share the id: their
    /// bitstreams are byte-identical, so cached strips decoded from one
    /// are valid for the other.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The underlying bitstream (store serialization reads it).
    pub(crate) fn buf(&self) -> &PackedBuf {
        &self.buf
    }

    /// Whether the bitstream lives in a shared backing (see
    /// [`PackedBuf::is_shared`]).
    pub fn is_shared(&self) -> bool {
        self.buf.is_shared()
    }

    /// The format the panels were packed (and are decoded) with.
    pub fn fmt(&self) -> QFormat {
        self.fmt
    }

    /// Rows per panel (the GEMM `k` depth).
    pub fn kd(&self) -> usize {
        self.kd
    }

    /// Lanes per panel row (the GEMM register-tile width).
    pub fn nr(&self) -> usize {
        self.nr
    }

    pub fn n_panels(&self) -> usize {
        self.n_panels
    }

    /// Total stored values (padding lanes included).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Bits per stored value.
    pub fn width(&self) -> u32 {
        self.buf.width()
    }

    /// Physical footprint of the payload, rounded up to whole bytes.
    pub fn packed_bytes(&self) -> usize {
        self.buf.packed_bytes()
    }

    /// Decode rows `[k0, k1)` of panel `panel` into `out`
    /// (`(k1 - k0) · nr` values) — one GEMM tile strip, decoded with
    /// the stored pack-time format.
    pub fn read_strip(&self, panel: usize, k0: usize, k1: usize, out: &mut [f32]) {
        assert!(panel < self.n_panels, "panel {panel} out of {}", self.n_panels);
        assert!(k0 <= k1 && k1 <= self.kd, "strip rows {k0}..{k1} out of {}", self.kd);
        assert_eq!(out.len(), (k1 - k0) * self.nr, "strip window size");
        self.buf.unpack_range_into(self.fmt, (panel * self.kd + k0) * self.nr, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::quantized_canonical;

    #[test]
    fn storage_widths() {
        assert_eq!(storage_width(QFormat::new(4, 2)), 6);
        assert_eq!(storage_width(QFormat::new(12, 12)), 24);
        assert_eq!(storage_width(QFormat::new(14, 12)), 32); // > 24 bits
        assert_eq!(storage_width(QFormat::FP32), 32);
    }

    #[test]
    fn roundtrip_matches_quantizer() {
        let fmt = QFormat::new(4, 3); // 7 bits: straddles word boundaries
        let xs: Vec<f32> = (-40..40).map(|i| i as f32 * 0.29).collect();
        let buf = PackedBuf::pack(fmt, &xs);
        assert_eq!(buf.len(), xs.len());
        assert_eq!(buf.width(), 7);
        let mut out = vec![f32::NAN; xs.len()];
        buf.unpack_into(fmt, &mut out);
        let want = quantized_canonical(fmt, &xs);
        for (i, (a, b)) in out.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "elem {i}: {a} vs {b}");
        }
    }

    #[test]
    fn clamp_edges_and_negatives() {
        let fmt = QFormat::new(3, 1); // range [-4, 3.5]
        let xs = [-100.0f32, -4.0, -3.75, -0.25, -0.1, 0.0, 0.1, 3.5, 3.75, 1e9];
        let buf = PackedBuf::pack(fmt, &xs);
        let mut out = vec![0f32; xs.len()];
        buf.unpack_into(fmt, &mut out);
        assert_eq!(out, quantized_canonical(fmt, &xs));
        assert_eq!(out[0], -4.0);
        assert_eq!(out[9], 3.5);
    }

    #[test]
    fn one_bit_format() {
        let fmt = QFormat::new(1, 0); // codes {-1, 0}
        let xs = [-5.0f32, -1.0, -0.4, 0.0, 0.4, 5.0];
        let buf = PackedBuf::pack(fmt, &xs);
        assert_eq!(buf.width(), 1);
        assert_eq!(buf.packed_bytes(), 1);
        let mut out = vec![0f32; xs.len()];
        buf.unpack_into(fmt, &mut out);
        assert_eq!(out, vec![-1.0, -1.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn fp32_fallback_is_bit_exact() {
        let xs = [0.1f32, -123.456, 1e20, f32::MIN_POSITIVE, -0.0];
        let buf = PackedBuf::pack(QFormat::FP32, &xs);
        assert_eq!(buf.width(), 32);
        let mut out = vec![0f32; xs.len()];
        buf.unpack_into(QFormat::FP32, &mut out);
        for (a, b) in xs.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits()); // raw bits, -0.0 kept
        }
    }

    #[test]
    fn wide_format_takes_word_aligned_fallback() {
        let fmt = QFormat::new(14, 12); // 26 bits -> stored as f32
        let xs = [1234.5678f32, -8000.25, 0.000244140625];
        let buf = PackedBuf::pack(fmt, &xs);
        assert_eq!(buf.width(), 32);
        let mut out = vec![0f32; xs.len()];
        buf.unpack_into(fmt, &mut out);
        assert_eq!(out, quantized_canonical(fmt, &xs));
    }

    #[test]
    fn packed_bytes_accounting() {
        let fmt = QFormat::new(2, 3); // 5 bits
        let buf = PackedBuf::pack(fmt, &[0.0; 13]);
        assert_eq!(buf.packed_bytes(), (13 * 5 + 7) / 8); // 9 bytes
        let f = PackedBuf::pack(QFormat::FP32, &[0.0; 3]);
        assert_eq!(f.packed_bytes(), 12);
    }

    #[test]
    fn reuse_shrinks_and_grows() {
        let mut buf = PackedBuf::default();
        let fmt = QFormat::new(5, 3);
        let long: Vec<f32> = (0..100).map(|i| i as f32 * 0.11).collect();
        buf.pack_into(fmt, &long);
        let mut out = vec![0f32; 100];
        buf.unpack_into(fmt, &mut out);
        assert_eq!(out, quantized_canonical(fmt, &long));
        // Shorter repack on the same buffer must not see stale words.
        let short = [7.77f32, -1.23];
        buf.pack_into(fmt, &short);
        let mut out2 = vec![0f32; 2];
        buf.unpack_into(fmt, &mut out2);
        assert_eq!(out2, quantized_canonical(fmt, &short));
    }

    #[test]
    fn roundtrip_in_place() {
        let fmt = QFormat::new(6, 2);
        let mut xs: Vec<f32> = (-20..20).map(|i| i as f32 * 0.77).collect();
        let want = quantized_canonical(fmt, &xs);
        let mut buf = PackedBuf::default();
        buf.roundtrip(fmt, &mut xs);
        assert_eq!(xs, want);
        // Idempotent: a second roundtrip changes nothing.
        let again = xs.clone();
        buf.roundtrip(fmt, &mut xs);
        assert_eq!(xs, again);
    }

    #[test]
    fn window_reads_match_full_unpack() {
        let fmt = QFormat::new(4, 3); // 7 bits: every window straddles words
        let xs: Vec<f32> = (0..61).map(|i| i as f32 * 0.43 - 12.0).collect();
        let buf = PackedBuf::pack(fmt, &xs);
        let mut want = vec![0f32; xs.len()];
        buf.unpack_into(fmt, &mut want);
        for start in [0usize, 1, 7, 8, 9, 30, 60] {
            for len in [1usize, 2, 13] {
                if start + len > xs.len() {
                    continue;
                }
                let mut got = vec![f32::NAN; len];
                buf.unpack_range_into(fmt, start, &mut got);
                for (i, (a, b)) in got.iter().zip(&want[start..start + len]).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "start {start} len {len} elem {i}");
                }
            }
        }
        // Row windows over a (9 rows x 7 elems) layout, dropping the rest.
        let mut rows = vec![0f32; 3 * 7];
        buf.unpack_rows(fmt, 7, 2, &mut rows);
        assert_eq!(rows, want[14..35]);
    }

    #[test]
    fn cursor_reads_sequentially() {
        let fmt = QFormat::new(3, 2); // 5 bits
        let xs: Vec<f32> = (0..40).map(|i| (i as f32 - 20.0) * 0.31).collect();
        let buf = PackedBuf::pack(fmt, &xs);
        let mut want = vec![0f32; xs.len()];
        buf.unpack_into(fmt, &mut want);
        let mut cur = PackedCursor::new(&buf, fmt);
        assert_eq!(cur.remaining(), 40);
        let mut got = Vec::new();
        for chunk in [1usize, 13, 13, 13] {
            let mut w = vec![0f32; chunk];
            cur.read_into(&mut w);
            got.extend_from_slice(&w);
        }
        assert_eq!(cur.remaining(), 0);
        assert_eq!(got, want);
    }

    #[test]
    fn window_reads_on_word_aligned_fallback() {
        let xs = [0.5f32, -1.25, 3.0, -0.0, 1e9];
        let buf = PackedBuf::pack(QFormat::FP32, &xs);
        let mut got = vec![0f32; 2];
        buf.unpack_range_into(QFormat::FP32, 3, &mut got);
        assert_eq!(got[0].to_bits(), (-0.0f32).to_bits());
        assert_eq!(got[1], 1e9);
    }

    #[test]
    fn panel_strips_read_back_row_ranges() {
        let fmt = QFormat::new(4, 3); // 7 bits: strips straddle words
        let (kd, nr, n_panels) = (5usize, 4usize, 3usize);
        let raw: Vec<f32> = (0..n_panels * kd * nr).map(|i| i as f32 * 0.31 - 9.0).collect();
        let want = quantized_canonical(fmt, &raw);
        let pp = PackedPanels::pack(fmt, &raw, kd, nr);
        assert_eq!((pp.kd(), pp.nr(), pp.n_panels()), (kd, nr, n_panels));
        assert_eq!(pp.fmt(), fmt);
        assert_eq!(pp.len(), raw.len());
        assert_eq!(pp.width(), 7);
        // Whole panels and interior strips, every panel.
        for p in 0..n_panels {
            for (k0, k1) in [(0usize, kd), (0, 1), (1, 4), (kd - 1, kd), (2, 2)] {
                let mut got = vec![f32::NAN; (k1 - k0) * nr];
                pp.read_strip(p, k0, k1, &mut got);
                let lo = (p * kd + k0) * nr;
                for (i, (a, b)) in got.iter().zip(&want[lo..lo + got.len()]).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "panel {p} rows {k0}..{k1} elem {i}");
                }
            }
        }
    }

    #[test]
    fn panel_fp32_fallback_is_bit_exact() {
        let raw = [0.1f32, -0.0, 1e20, -3.5]; // kd=2, nr=2, one panel
        let pp = PackedPanels::pack(QFormat::FP32, &raw, 2, 2);
        assert_eq!(pp.width(), 32);
        assert!(pp.fmt().is_fp32());
        assert_eq!(pp.packed_bytes(), 16);
        let mut got = vec![0f32; 2];
        pp.read_strip(0, 1, 2, &mut got);
        assert_eq!(got[0].to_bits(), 1e20f32.to_bits());
        assert_eq!(got[1], -3.5);
    }

    #[derive(Debug)]
    struct VecBacking(Vec<u64>);
    impl WordBacking for VecBacking {
        fn words(&self) -> &[u64] {
            &self.0
        }
    }

    #[test]
    fn shared_backing_decodes_bit_identically() {
        let fmt = QFormat::new(4, 3); // 7 bits: windows straddle words
        let xs: Vec<f32> = (0..57).map(|i| i as f32 * 0.37 - 9.0).collect();
        let owned = PackedBuf::pack(fmt, &xs);
        assert!(!owned.is_shared());
        let backing: Arc<dyn WordBacking> = Arc::new(VecBacking(owned.words().to_vec()));
        let n_words = owned.words().len();
        let shared = PackedBuf::from_shared(backing, 0, n_words, xs.len(), owned.width());
        assert!(shared.is_shared());
        let (mut a, mut b) = (vec![0f32; xs.len()], vec![0f32; xs.len()]);
        owned.unpack_into(fmt, &mut a);
        shared.unpack_into(fmt, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(shared.get(fmt, 13).to_bits(), owned.get(fmt, 13).to_bits());
        // Repacking a shared buffer detaches to owned words.
        let mut shared = shared;
        shared.pack_into(fmt, &[1.0, 2.0]);
        assert!(!shared.is_shared());
        assert_eq!(shared.len(), 2);
    }

    #[test]
    fn shared_panels_match_packed_panels() {
        let fmt = QFormat::new(2, 5);
        let (kd, nr) = (3usize, 4usize);
        let raw: Vec<f32> = (0..2 * kd * nr).map(|i| i as f32 * 0.11 - 1.3).collect();
        let packed = PackedPanels::pack(fmt, &raw, kd, nr);
        let backing: Arc<dyn WordBacking> = Arc::new(VecBacking(packed.buf().words().to_vec()));
        let buf = PackedBuf::from_shared(
            backing,
            0,
            packed.buf().words().len(),
            packed.len(),
            packed.width(),
        );
        let id = PackedPanels::alloc_id();
        let shared = PackedPanels::from_buf(buf, fmt, kd, nr, id);
        assert_eq!(shared.id(), id);
        assert!(shared.is_shared());
        assert_eq!(shared.n_panels(), packed.n_panels());
        let (mut a, mut b) = (vec![0f32; 2 * nr], vec![0f32; 2 * nr]);
        packed.read_strip(1, 1, 3, &mut a);
        shared.read_strip(1, 1, 3, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn negative_zero_canonicalizes() {
        let fmt = QFormat::new(4, 0);
        let xs = [-0.2f32, -0.0];
        let mut v = xs.to_vec();
        fmt.quantize_slice(&mut v);
        assert_eq!(v[0].to_bits(), (-0.0f32).to_bits()); // quantizer keeps the sign
        let buf = PackedBuf::pack(fmt, &xs);
        let mut out = vec![1.0f32; 2];
        buf.unpack_into(fmt, &mut out);
        assert_eq!(out[0].to_bits(), 0.0f32.to_bits()); // single two's-complement zero
        assert_eq!(out[1].to_bits(), 0.0f32.to_bits());
    }
}
