//! The bounded-memory subsystem: packed reduced-precision storage and
//! data-footprint accounting.
//!
//! The paper's title promises *bounded memory*, and its headline result
//! is a 74%-average data-footprint reduction at <1% accuracy loss —
//! but neither materializes if every activation still lives as an f32
//! and nothing measures bytes. This module closes that loop:
//!
//! | piece | role |
//! |---|---|
//! | [`PackedBuf`] | a quantized tensor as a contiguous two's-complement bitstream at `I+F` bits per value, with a streaming window reader ([`PackedBuf::unpack_rows`] / [`PackedCursor`]) |
//! | [`PackedPanels`] | a GEMM `B` weight matrix as a panel-strided bitstream, decoded one tile strip at a time by the packed-B GEMM |
//! | [`FootprintModel`] | per-layer / per-network resident-byte model (weights + peak live activations) for any `PrecisionConfig` ([`footprint`]) |
//! | [`StorageMode`] | the opt-in inter-layer storage switch both CPU executors honour (`--storage packed` / `QBOUND_STORAGE=packed`) |
//!
//! Under [`StorageMode::Packed`] only bitstreams persist between
//! layers: each boundary activation is packed at its layer-boundary
//! format, and the consuming op decodes windows of the bitstream on
//! the fly (im2col pulls one input row at a time, the GEMM A read one
//! row block, see `backend/fast.rs`) instead of unpacking into a
//! resident f32 arena. The evaluator spills whole eval splits the same
//! way ([`crate::eval::PackedSplit`]), so the serve path's input set is
//! packed too. The *weights* are packed as well: the fast backend keeps
//! every parameter tensor as a bitstream at its group's weight width —
//! GEMM weights in the panel layout ([`PackedPanels`]), decoded one
//! tile strip at a time inside the GEMM — and the reference interpreter
//! decodes each layer's tensors right before applying its op. Results
//! are numerically identical to the default
//! quantize-in-f32 path (locked by `tests/integration_storage.rs`),
//! and the byte claim is *measured*, not just modeled:
//! `tests/integration_memory.rs` runs both modes under a counting
//! allocator ([`crate::testkit::MeterAlloc`]) and asserts the packed
//! resident set lands strictly below the f32 run and within the
//! [`FootprintModel`] envelope ([`FootprintModel::fused_envelope`]).
//! The precision search ranks configurations by modeled footprint
//! ([`FootprintModel::ratio`]), and `qbound footprint` reports the
//! fp32-vs-best-config byte table.

pub mod footprint;
pub mod packed;

pub use footprint::{Footprint, FootprintModel, LayerFootprint};
pub use packed::{storage_width, PackedBuf, PackedCursor, PackedPanels, WordBacking, MAX_PACK_BITS};

use anyhow::{bail, Result};

/// How executors store activations *between* layers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum StorageMode {
    /// Quantize in place, keep the f32 representation (default).
    #[default]
    F32,
    /// Quantize→pack into a [`PackedBuf`] bitstream at the boundary
    /// format's width, unpack into the arena on the next read.
    Packed,
}

impl StorageMode {
    /// Parse a CLI/env spelling: `f32` (aliases `fp32`, `dense`) or
    /// `packed` (alias `pack`).
    pub fn parse(s: &str) -> Result<StorageMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "dense" => Ok(StorageMode::F32),
            "packed" | "pack" => Ok(StorageMode::Packed),
            other => bail!("unknown storage mode {other:?} (expected: f32 | packed)"),
        }
    }

    /// Mode selected by `QBOUND_STORAGE`, defaulting to [`StorageMode::F32`].
    /// An invalid value is an error (not a silent fallback).
    pub fn from_env() -> Result<StorageMode> {
        match std::env::var("QBOUND_STORAGE") {
            Ok(s) if !s.is_empty() => StorageMode::parse(&s),
            _ => Ok(StorageMode::default()),
        }
    }

    /// CLI resolution: an explicit `--storage` value wins; empty falls
    /// back to [`StorageMode::from_env`].
    pub fn from_arg_or_env(arg: &str) -> Result<StorageMode> {
        if arg.trim().is_empty() {
            StorageMode::from_env()
        } else {
            StorageMode::parse(arg)
        }
    }

    /// Propagate the mode to `QBOUND_STORAGE` so coordinator workers
    /// (which construct their backends from the environment) inherit
    /// it — the same pattern `QBOUND_THREADS` uses. Call before
    /// spawning workers.
    pub fn set_env(self) {
        std::env::set_var("QBOUND_STORAGE", self.label());
    }

    pub fn label(self) -> &'static str {
        match self {
            StorageMode::F32 => "f32",
            StorageMode::Packed => "packed",
        }
    }

    /// One-time no-op warning for backends that execute outside host
    /// memory and therefore cannot honour a requested storage mode (the
    /// PJRT path: activations live in device buffers the host never
    /// sees). Returns whether this call emitted the warning, so the
    /// once-only behaviour is unit-testable without scraping logs.
    pub fn warn_ignored_by(self, backend: &str) -> bool {
        use std::sync::atomic::{AtomicBool, Ordering};
        static WARNED: AtomicBool = AtomicBool::new(false);
        if self != StorageMode::Packed || WARNED.swap(true, Ordering::Relaxed) {
            return false;
        }
        log::warn!(
            "the {backend} backend executes outside host memory and ignores \
             QBOUND_STORAGE=packed; activations stay in the device's own format"
        );
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spellings() {
        for s in ["f32", "FP32", "dense"] {
            assert_eq!(StorageMode::parse(s).unwrap(), StorageMode::F32);
        }
        for s in ["packed", "PACK"] {
            assert_eq!(StorageMode::parse(s).unwrap(), StorageMode::Packed);
        }
        assert!(StorageMode::parse("mmap").is_err());
    }

    #[test]
    fn default_is_f32() {
        assert_eq!(StorageMode::default(), StorageMode::F32);
        assert_eq!(StorageMode::default().label(), "f32");
        assert_eq!(StorageMode::Packed.label(), "packed");
    }

    #[test]
    fn ignored_storage_warns_exactly_once() {
        // F32 never warns; the first Packed call does; later calls are
        // silent (process-global once).
        assert!(!StorageMode::F32.warn_ignored_by("pjrt"));
        assert!(StorageMode::Packed.warn_ignored_by("pjrt"));
        assert!(!StorageMode::Packed.warn_ignored_by("pjrt"));
        assert!(!StorageMode::F32.warn_ignored_by("pjrt"));
    }

    #[test]
    fn arg_overrides_env_fallback() {
        assert_eq!(StorageMode::from_arg_or_env("packed").unwrap(), StorageMode::Packed);
        assert!(StorageMode::from_arg_or_env("bogus").is_err());
        if std::env::var_os("QBOUND_STORAGE").is_none() {
            assert_eq!(StorageMode::from_arg_or_env("").unwrap(), StorageMode::F32);
            assert_eq!(StorageMode::from_arg_or_env("  ").unwrap(), StorageMode::F32);
        }
    }
}
