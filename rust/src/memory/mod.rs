//! The bounded-memory subsystem: packed reduced-precision storage and
//! data-footprint accounting.
//!
//! The paper's title promises *bounded memory*, and its headline result
//! is a 74%-average data-footprint reduction at <1% accuracy loss —
//! but neither materializes if every activation still lives as an f32
//! and nothing measures bytes. This module closes that loop:
//!
//! | piece | role |
//! |---|---|
//! | [`PackedBuf`] | a quantized tensor as a contiguous two's-complement bitstream at `I+F` bits per value ([`packed`]) |
//! | [`FootprintModel`] | per-layer / per-network resident-byte model (weights + peak live activations) for any `PrecisionConfig` ([`footprint`]) |
//! | [`StorageMode`] | the opt-in inter-layer storage switch both CPU executors honour (`--storage packed` / `QBOUND_STORAGE=packed`) |
//!
//! Under [`StorageMode::Packed`] the executors quantize→pack each
//! activation at its layer-boundary format and unpack it again before
//! the next op reads it, so every boundary value is carried by — and
//! re-derived from — its reduced-width bitstream code on real forward
//! passes; results are numerically identical to the default
//! quantize-in-f32 path (locked by `tests/integration_storage.rs`).
//! The mode validates the packed representation end-to-end; it does
//! not yet shrink the executors' resident set, because the values are
//! unpacked into the existing f32 arenas (fusing unpack into the
//! consumers is a ROADMAP open item). The byte savings are *measured*
//! by [`FootprintModel`]: the precision search ranks configurations by
//! modeled footprint ([`FootprintModel::ratio`]), and `qbound
//! footprint` reports the fp32-vs-best-config byte table.

pub mod footprint;
pub mod packed;

pub use footprint::{Footprint, FootprintModel, LayerFootprint};
pub use packed::{storage_width, PackedBuf, MAX_PACK_BITS};

use anyhow::{bail, Result};

/// How executors store activations *between* layers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum StorageMode {
    /// Quantize in place, keep the f32 representation (default).
    #[default]
    F32,
    /// Quantize→pack into a [`PackedBuf`] bitstream at the boundary
    /// format's width, unpack into the arena on the next read.
    Packed,
}

impl StorageMode {
    /// Parse a CLI/env spelling: `f32` (aliases `fp32`, `dense`) or
    /// `packed` (alias `pack`).
    pub fn parse(s: &str) -> Result<StorageMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "dense" => Ok(StorageMode::F32),
            "packed" | "pack" => Ok(StorageMode::Packed),
            other => bail!("unknown storage mode {other:?} (expected: f32 | packed)"),
        }
    }

    /// Mode selected by `QBOUND_STORAGE`, defaulting to [`StorageMode::F32`].
    /// An invalid value is an error (not a silent fallback).
    pub fn from_env() -> Result<StorageMode> {
        match std::env::var("QBOUND_STORAGE") {
            Ok(s) if !s.is_empty() => StorageMode::parse(&s),
            _ => Ok(StorageMode::default()),
        }
    }

    /// CLI resolution: an explicit `--storage` value wins; empty falls
    /// back to [`StorageMode::from_env`].
    pub fn from_arg_or_env(arg: &str) -> Result<StorageMode> {
        if arg.trim().is_empty() {
            StorageMode::from_env()
        } else {
            StorageMode::parse(arg)
        }
    }

    /// Propagate the mode to `QBOUND_STORAGE` so coordinator workers
    /// (which construct their backends from the environment) inherit
    /// it — the same pattern `QBOUND_THREADS` uses. Call before
    /// spawning workers.
    pub fn set_env(self) {
        std::env::set_var("QBOUND_STORAGE", self.label());
    }

    pub fn label(self) -> &'static str {
        match self {
            StorageMode::F32 => "f32",
            StorageMode::Packed => "packed",
        }
    }

    /// Quantize a boundary activation under this mode: in place for f32
    /// storage, through the packed bitstream otherwise (numerically
    /// identical either way — two's complement just canonicalizes
    /// `-0.0`). Both CPU executors call this at every quantization
    /// boundary, so the dispatch lives in exactly one place.
    #[inline]
    pub fn store(self, fmt: crate::quant::QFormat, xs: &mut [f32], packed: &mut PackedBuf) {
        match self {
            StorageMode::F32 => fmt.quantize_slice(xs),
            StorageMode::Packed => packed.roundtrip(fmt, xs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spellings() {
        for s in ["f32", "FP32", "dense"] {
            assert_eq!(StorageMode::parse(s).unwrap(), StorageMode::F32);
        }
        for s in ["packed", "PACK"] {
            assert_eq!(StorageMode::parse(s).unwrap(), StorageMode::Packed);
        }
        assert!(StorageMode::parse("mmap").is_err());
    }

    #[test]
    fn default_is_f32() {
        assert_eq!(StorageMode::default(), StorageMode::F32);
        assert_eq!(StorageMode::default().label(), "f32");
        assert_eq!(StorageMode::Packed.label(), "packed");
    }

    #[test]
    fn arg_overrides_env_fallback() {
        assert_eq!(StorageMode::from_arg_or_env("packed").unwrap(), StorageMode::Packed);
        assert!(StorageMode::from_arg_or_env("bogus").is_err());
        if std::env::var_os("QBOUND_STORAGE").is_none() {
            assert_eq!(StorageMode::from_arg_or_env("").unwrap(), StorageMode::F32);
            assert_eq!(StorageMode::from_arg_or_env("  ").unwrap(), StorageMode::F32);
        }
    }
}
